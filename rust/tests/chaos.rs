//! Chaos / elasticity tests: the cluster loses and gains nodes while real
//! jobs run, and the data must not care.
//!
//! The acceptance scenario is the paper's elasticity claim driven to the
//! byte level: a Terasort that loses a node mid-map-phase and gains a
//! batch-allocator replacement still produces **byte-identical, validated
//! output**. `HPCW_CHAOS=1` (the CI chaos step) multiplies the property
//! iterations.

use hpcw::cluster::{ClusterManager, NodeId};
use hpcw::config::{ElasticConfig, StackConfig};
use hpcw::lustre::{Dfs, LustreFs};
use hpcw::mapreduce::{
    counters, ElasticAction, ElasticPlan, FailurePlan, MrEngine, TaskId,
};
use hpcw::metrics::Metrics;
use hpcw::terasort::{
    run_teragen, run_terasort, summarize_dir, teravalidate, TeragenSpec, TerasortJob,
};
use hpcw::testkit::{props, Gen};
use hpcw::util::ids::IdGen;
use hpcw::util::pool::Pool;
use hpcw::util::time::Micros;
use hpcw::wrapper::DynamicCluster;
use std::collections::BTreeMap;
use std::sync::Arc;

/// Iteration multiplier for the CI chaos step (`HPCW_CHAOS=1`).
fn chaos_iters(base: u64) -> u64 {
    if std::env::var("HPCW_CHAOS").is_ok() {
        base * 4
    } else {
        base
    }
}

fn elastic_cfg() -> ElasticConfig {
    ElasticConfig {
        nodes_min: 3,
        nodes_max: 8,
        queue_delay_ms: 20,
        lease_walltime_s: 3_600,
        nm_timeout_ms: 3_000,
        ..Default::default()
    }
}

fn build_cluster(fs: &LustreFs, cfg: &StackConfig, tag: &str) -> DynamicCluster {
    let nodes: Vec<NodeId> = (0..5).map(NodeId).collect(); // RM, JHS, 3 slaves
    DynamicCluster::build(
        cfg,
        &nodes,
        fs,
        Arc::new(IdGen::default()),
        Arc::new(Metrics::new()),
        tag,
        Micros::ZERO,
    )
    .unwrap()
}

fn sorted_output(fs: &LustreFs, files: &[String]) -> BTreeMap<String, Vec<u8>> {
    files
        .iter()
        .map(|f| {
            let name = f.rsplit('/').next().unwrap().to_string();
            (name, fs.read(f).unwrap())
        })
        .collect()
}

/// THE acceptance test: a Terasort run that loses a node mid-map-phase
/// and gains a batch-allocator replacement produces byte-identical,
/// validated output, with the loss/join visible in the counters.
#[test]
fn chaos_terasort_node_loss_with_replacement_is_byte_identical() {
    let cfg = StackConfig::tiny();
    let fs = Arc::new(LustreFs::new(&cfg.lustre, &cfg.cluster));
    let pool = Pool::new(4);
    let rows = 6_000u64;
    let gen = TeragenSpec {
        rows,
        maps: 3,
        output_dir: "/lustre/scratch/chaos-in".into(),
        seed: 42,
    };

    // Reference run on a healthy cluster.
    let mut dc_ref = build_cluster(&fs, &cfg, "chaos-ref");
    {
        let mut engine =
            MrEngine::new(&mut dc_ref, fs.clone() as Arc<dyn Dfs>, &pool, 1024, 1024);
        run_teragen(&mut engine, &gen, Micros::ZERO).unwrap();
    }
    let input = summarize_dir(&*fs, "/lustre/scratch/chaos-in").unwrap();
    let ts_ref = TerasortJob {
        split_bytes: 60_000, // ~10 maps over 600 KB
        samples_per_file: 200,
        ..TerasortJob::new("/lustre/scratch/chaos-in", "/lustre/scratch/chaos-ref-out", 4)
    };
    let ref_outcome = {
        let mut engine =
            MrEngine::new(&mut dc_ref, fs.clone() as Arc<dyn Dfs>, &pool, 1024, 1024);
        run_terasort(&mut engine, &ts_ref, None, Micros::ZERO).unwrap()
    };
    teravalidate(&*fs, "/lustre/scratch/chaos-ref-out", input.clone()).unwrap();
    let reference = sorted_output(&fs, &ref_outcome.output_files);

    // Elastic run: once two maps have committed, crash the node holding
    // map 0's shuffle output. The cluster manager (floor = 3 slaves)
    // acquires a replacement node from the batch allocator mid-job.
    let mut dc = build_cluster(&fs, &cfg, "chaos-elastic");
    let cm = ClusterManager::new(elastic_cfg(), (100..104).map(NodeId).collect());
    let plan = ElasticPlan::new().at_maps(2, ElasticAction::FailMapHost(0));
    let ts = TerasortJob {
        output_dir: "/lustre/scratch/chaos-el-out".into(),
        ..ts_ref.clone()
    };
    let outcome = {
        let mut engine = MrEngine::new(&mut dc, fs.clone() as Arc<dyn Dfs>, &pool, 1024, 1024)
            .with_cluster_manager(cm)
            .with_plan(plan);
        run_terasort(&mut engine, &ts, None, Micros::ZERO).unwrap()
    };
    let validated = teravalidate(&*fs, "/lustre/scratch/chaos-el-out", input).unwrap();
    assert_eq!(validated.records, rows);

    assert_eq!(outcome.counters.get(counters::NODES_FAILED), 1);
    assert!(
        outcome.counters.get(counters::MAPS_INVALIDATED) >= 1,
        "the crashed node held at least map 0's committed output"
    );
    assert!(
        outcome.counters.get(counters::NODES_JOINED) >= 1,
        "the batch allocator must deliver a replacement node"
    );

    // Byte-identical: same part files, same bytes, despite the chaos.
    let elastic = sorted_output(&fs, &outcome.output_files);
    assert_eq!(reference.len(), elastic.len());
    for (name, bytes) in &reference {
        assert_eq!(
            Some(bytes),
            elastic.get(name),
            "part file {name} must be byte-identical after node loss + rejoin"
        );
    }
    dc.rm.check_invariants().unwrap();
    let (_, used) = dc.rm.cluster_resources();
    assert_eq!(used.mem_mb, 0, "all containers released");
}

/// Two-level-storage chaos (PR 7): the same node-loss Terasort, but on a
/// backend whose burst tier is ~6× smaller than the input, so the job
/// runs with files evicted to the backing tier and shuffle segments
/// spilled — and a node dies while spilled segments exist. Output must
/// still be byte-identical to the unbounded all-in-RAM run.
#[test]
fn chaos_terasort_under_memory_pressure_and_node_loss_is_byte_identical() {
    let cfg = StackConfig::tiny();
    let pool = Pool::new(4);
    let rows = 6_000u64; // ~600 KB of 100-byte records
    let gen = TeragenSpec {
        rows,
        maps: 3,
        output_dir: "/lustre/scratch/mp-in".into(),
        seed: 42,
    };
    let ts = TerasortJob {
        split_bytes: 60_000,
        samples_per_file: 200,
        ..TerasortJob::new("/lustre/scratch/mp-in", "/lustre/scratch/mp-out", 4)
    };

    // Reference: unbounded backend, healthy cluster.
    let fs_ref = Arc::new(LustreFs::new(&cfg.lustre, &cfg.cluster));
    let mut dc_ref = build_cluster(&fs_ref, &cfg, "mp-ref");
    {
        let mut engine =
            MrEngine::new(&mut dc_ref, fs_ref.clone() as Arc<dyn Dfs>, &pool, 1024, 1024);
        run_teragen(&mut engine, &gen, Micros::ZERO).unwrap();
    }
    let input = summarize_dir(&*fs_ref, "/lustre/scratch/mp-in").unwrap();
    let ref_outcome = {
        let mut engine =
            MrEngine::new(&mut dc_ref, fs_ref.clone() as Arc<dyn Dfs>, &pool, 1024, 1024);
        run_terasort(&mut engine, &ts, None, Micros::ZERO).unwrap()
    };
    let reference = sorted_output(&fs_ref, &ref_outcome.output_files);

    // Constrained run: 96 KB burst tier (explicit budget — no env races),
    // same deterministic Teragen, node loss after two committed maps.
    let fs = Arc::new(LustreFs::with_mem_budget(
        &cfg.lustre,
        &cfg.cluster,
        Some(96 * 1024),
    ));
    let mut dc = build_cluster(&fs, &cfg, "mp-con");
    {
        let mut engine =
            MrEngine::new(&mut dc, fs.clone() as Arc<dyn Dfs>, &pool, 1024, 1024);
        run_teragen(&mut engine, &gen, Micros::ZERO).unwrap();
    }
    let cm = ClusterManager::new(elastic_cfg(), (100..104).map(NodeId).collect());
    let plan = ElasticPlan::new().at_maps(2, ElasticAction::FailMapHost(0));
    let outcome = {
        let mut engine = MrEngine::new(&mut dc, fs.clone() as Arc<dyn Dfs>, &pool, 1024, 1024)
            .with_cluster_manager(cm)
            .with_plan(plan);
        run_terasort(&mut engine, &ts, None, Micros::ZERO).unwrap()
    };

    // Same sorted bytes as the unbounded run, validated end to end.
    let validated = teravalidate(&*fs, "/lustre/scratch/mp-out", input).unwrap();
    assert_eq!(validated.records, rows);
    let constrained = sorted_output(&fs, &outcome.output_files);
    assert_eq!(
        reference, constrained,
        "memory pressure + node loss must never change bytes"
    );

    // The pressure was real: the job itself evicted file extents and
    // spilled shuffle segments, and the node died while tiered state
    // existed.
    assert_eq!(outcome.counters.get(counters::NODES_FAILED), 1);
    assert!(
        outcome.counters.get(counters::TIER_EVICTIONS) > 0,
        "input ≥ 4× budget must evict: {:?}",
        fs.tier_stats()
    );
    assert!(
        outcome.counters.get(counters::SPILL_BYTES) > 0,
        "shuffle must spill under a 96 KB budget: {:?}",
        fs.tier_stats()
    );
    assert!(outcome.counters.get(counters::TIER_MISSES) > 0);
    dc.rm.check_invariants().unwrap();
}

/// Property: random attempt failures + a random committed-map host crash
/// never change Terasort's bytes relative to a clean reference run.
#[test]
fn chaos_random_faults_preserve_terasort_bytes_property() {
    let cfg = StackConfig::tiny();
    props(chaos_iters(4), |g: &mut Gen| {
        let fs = Arc::new(LustreFs::new(&cfg.lustre, &cfg.cluster));
        let pool = Pool::new(4);
        let rows = 1_500 + g.u64(0..1_500);
        let gen = TeragenSpec {
            rows,
            maps: 2,
            output_dir: "/lustre/scratch/cr-in".into(),
            seed: 7,
        };
        let mut dc_ref = build_cluster(&fs, &cfg, "cr-ref");
        {
            let mut engine =
                MrEngine::new(&mut dc_ref, fs.clone() as Arc<dyn Dfs>, &pool, 1024, 1024);
            run_teragen(&mut engine, &gen, Micros::ZERO).unwrap();
        }
        let ts = TerasortJob {
            split_bytes: 40_000,
            samples_per_file: 100,
            ..TerasortJob::new("/lustre/scratch/cr-in", "/lustre/scratch/cr-ref-out", 3)
        };
        let ref_outcome = {
            let mut engine =
                MrEngine::new(&mut dc_ref, fs.clone() as Arc<dyn Dfs>, &pool, 1024, 1024);
            run_terasort(&mut engine, &ts, None, Micros::ZERO).unwrap()
        };
        let reference = sorted_output(&fs, &ref_outcome.output_files);
        let n_maps = ref_outcome.maps;

        // Chaos run: random attempt-0 failures plus a node crash pinned to
        // a random committed map's host, with auto-replacement.
        let mut dc = build_cluster(&fs, &cfg, "cr-chaos");
        let cm = ClusterManager::new(elastic_cfg(), (200..206).map(NodeId).collect());
        let victim_map = g.u32(0..n_maps);
        let fire_at = 1 + g.u32(0..n_maps.max(2) - 1);
        let plan = ElasticPlan::new().at_maps(fire_at, ElasticAction::FailMapHost(victim_map));
        let mut failures = FailurePlan::none();
        for _ in 0..g.usize(0..3) {
            failures = failures.fail_attempt(TaskId::map(g.u32(0..n_maps)), 0);
        }
        let mut job = ts.clone();
        job.output_dir = "/lustre/scratch/cr-chaos-out".into();
        let outcome = {
            let mut engine =
                MrEngine::new(&mut dc, fs.clone() as Arc<dyn Dfs>, &pool, 1024, 1024)
                    .with_cluster_manager(cm)
                    .with_plan(plan);
            // TerasortJob has no failure hook; drive the identity job
            // directly through the sort spec.
            run_terasort_with_failures(&mut engine, &job, failures)
        };
        let chaotic = sorted_output(&fs, &outcome.output_files);
        assert_eq!(reference, chaotic, "fault injection must never change bytes");
        dc.rm.check_invariants().unwrap();
        let (_, used) = dc.rm.cluster_resources();
        assert_eq!(used.mem_mb, 0);
    });
}

/// `run_terasort` with a failure plan injected into the sort job's spec.
fn run_terasort_with_failures(
    engine: &mut MrEngine<'_>,
    job: &TerasortJob,
    failures: FailurePlan,
) -> hpcw::mapreduce::MrOutcome {
    use hpcw::mapreduce::{InputFormat, JobSpec, OutputFormat};
    use hpcw::terasort::{sample_input, RangePartitioner};
    let samples =
        sample_input(&*engine.dfs, &job.input_dir, job.samples_per_file).unwrap();
    let part = RangePartitioner::from_samples(samples, job.reduces).unwrap();
    let mut spec =
        JobSpec::identity("terasort-chaos", &job.input_dir, &job.output_dir, job.reduces);
    spec.input_format = InputFormat::TeraRecords;
    spec.output_format = OutputFormat::TeraRecords;
    spec.split_bytes = job.split_bytes;
    spec.partitioner = Arc::new(part);
    spec.failures = failures;
    engine.run(Arc::new(spec), "chaos", Micros::ZERO).unwrap()
}

/// PR 10: heterogeneous-cluster chaos. One slave runs at 400 MIPS (2.5×
/// slower wall clock), a node dies mid-map-phase, and the run repeats
/// under every speculation mode (`off` — the oracle — then `static` and
/// `adaptive`). Outputs must be byte-identical across all three: neither
/// the per-node speed model, the estimator-driven duplicate attempts,
/// nor fast-node placement bias may ever change the data. This is the
/// scenario the CI scheduler matrix replays under each
/// `HPCW_SPECULATION` token.
#[test]
fn chaos_hetero_cluster_speculation_modes_are_byte_identical() {
    use hpcw::config::SpeculationMode;
    let cfg = StackConfig::tiny();
    let fs = Arc::new(LustreFs::new(&cfg.lustre, &cfg.cluster));
    let pool = Pool::new(4);
    let rows = 4_000u64;
    let gen = TeragenSpec {
        rows,
        maps: 2,
        output_dir: "/lustre/scratch/hchaos-in".into(),
        seed: 11,
    };
    {
        let mut dc = build_cluster(&fs, &cfg, "hchaos-gen");
        let mut engine =
            MrEngine::new(&mut dc, fs.clone() as Arc<dyn Dfs>, &pool, 1024, 1024);
        run_teragen(&mut engine, &gen, Micros::ZERO).unwrap();
    }
    let input = summarize_dir(&*fs, "/lustre/scratch/hchaos-in").unwrap();

    let mut outputs: Vec<(SpeculationMode, BTreeMap<String, Vec<u8>>)> = Vec::new();
    for mode in [SpeculationMode::Off, SpeculationMode::Static, SpeculationMode::Adaptive] {
        let out_dir = format!("/lustre/scratch/hchaos-out-{}", mode.name());
        let ts = TerasortJob {
            split_bytes: 50_000,
            samples_per_file: 200,
            ..TerasortJob::new("/lustre/scratch/hchaos-in", &out_dir, 3)
        };
        let mut dc = build_cluster(&fs, &cfg, &format!("hchaos-{}", mode.name()));
        let cm = ClusterManager::new(
            ElasticConfig {
                node_mips: vec![(2, 400)],
                ..elastic_cfg()
            },
            (200..204).map(NodeId).collect(),
        );
        let ecfg = ElasticConfig {
            speculation: mode,
            // Slave 2 (the node ids are RM, JHS, then slaves 2..5) is the
            // slow tier; batch-allocator replacements (200..) fall back
            // to the reference speed.
            node_mips: vec![(2, 400)],
            speculation_floor_ms: 10,
            ..elastic_cfg()
        };
        let plan = ElasticPlan::new().at_maps(2, ElasticAction::FailMapHost(0));
        let outcome = {
            let mut engine =
                MrEngine::new(&mut dc, fs.clone() as Arc<dyn Dfs>, &pool, 1024, 1024)
                    .with_elastic_cfg(ecfg)
                    .with_cluster_manager(cm)
                    .with_plan(plan);
            run_terasort(&mut engine, &ts, None, Micros::ZERO).unwrap()
        };
        let validated = teravalidate(&*fs, &out_dir, input.clone()).unwrap();
        assert_eq!(validated.records, rows, "{} run lost rows", mode.name());
        assert_eq!(
            outcome.counters.get(counters::NODES_FAILED),
            1,
            "{} run must see the injected node loss",
            mode.name()
        );
        // Every committed attempt feeds the runtime estimator (it learns
        // in every mode; only `adaptive` *acts* on the predictions).
        assert_eq!(
            outcome.counters.get(counters::ESTIMATOR_UPDATES),
            (outcome.maps + outcome.reduces) as u64
        );
        dc.rm.check_invariants().unwrap();
        outputs.push((mode, sorted_output(&fs, &outcome.output_files)));
    }

    let (_, oracle) = &outputs[0];
    for (mode, bytes) in &outputs[1..] {
        assert_eq!(oracle.len(), bytes.len());
        for (name, reference) in oracle {
            assert_eq!(
                Some(reference),
                bytes.get(name),
                "part file {name} must be byte-identical under {} speculation",
                mode.name()
            );
        }
    }
}

/// Property: arbitrary admit/drain/partition sequences through the
/// cluster manager keep the RM ledger consistent, expire silent nodes
/// exactly once, and drains always return leases to the allocator.
#[test]
fn chaos_join_drain_partition_invariants_property() {
    let cfg = StackConfig::tiny();
    props(chaos_iters(10), |g: &mut Gen| {
        let fs = LustreFs::new(&cfg.lustre, &cfg.cluster);
        let mut dc = build_cluster(&fs, &cfg, "jdp");
        let base = dc.rm.nm_count() as u32;
        let pool_n = 6u32;
        let mut cm = ClusterManager::new(
            ElasticConfig {
                nodes_min: 1,
                nodes_max: base + pool_n,
                queue_delay_ms: 0,
                nm_timeout_ms: 500,
                lease_walltime_s: 3_600,
                ..Default::default()
            },
            (300..300 + pool_n).map(NodeId).collect(),
        );
        let mut now = Micros::ZERO;
        let mut expired_total = 0usize;
        for _ in 0..g.usize(4..25) {
            now += Micros::ms(g.u64(1..400));
            match g.u32(0..4) {
                0 => {
                    cm.request_grow(&dc, g.u32(1..3), now);
                }
                1 => {
                    // Drain a random slave (may refuse; both paths legal).
                    if let Some(&node) = dc.slaves.get(g.usize(0..dc.slaves.len().max(1))) {
                        let _ = cm.drain(&mut dc, node, now);
                    }
                }
                2 => {
                    // Partition a random slave: it must expire (exactly
                    // once) on a later tick.
                    if let Some(&node) = dc.slaves.get(g.usize(0..dc.slaves.len().max(1))) {
                        cm.partition(node);
                    }
                }
                _ => {}
            }
            let delta = cm.tick(&mut dc, g.u32(0..3), now).unwrap();
            expired_total += delta.failed.len();
            for (node, _) in &delta.failed {
                assert!(!dc.rm.has_nm(*node), "expired node must be gone");
                assert!(!dc.nms.contains_key(node));
            }
            dc.rm.check_invariants().expect("rm ledger under churn");
            assert_eq!(
                dc.rm.nm_count(),
                dc.nms.len(),
                "RM registry and NM set must agree"
            );
        }
        // Every partitioned node that expired did so exactly once: the
        // failed_total tally equals the observed expiries.
        assert_eq!(cm.failed_total as usize, expired_total);
    });
}
