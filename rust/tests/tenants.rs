//! Multi-tenant front door acceptance: fair-share scheduling under a
//! greedy flood, admission control (401/429 + Retry-After) on both the
//! versioned and the legacy redirect paths, quota + breaker rejections
//! with stable error codes, and the preemption byte-parity oracle — the
//! same workload with preemption on and off produces byte-identical
//! output.
//!
//! `HPCW_CHAOS=1` (the CI chaos step) multiplies the flood size.

use hpcw::api::http::request_with_headers;
use hpcw::api::{ApiClient, ApiServer, AppPayload, Stack};
use hpcw::codec::json::Json;
use hpcw::config::{StackConfig, TenantSpec};
use hpcw::mapreduce::counters as mrc;
use hpcw::scheduler::JobState;
use std::collections::BTreeMap;
use std::time::Duration;

const KEYS: &str = "k-alice:alice:root.research.alice,\
                    k-bob:bob:root.research.bob:2,\
                    k-carol:carol:root.eng.carol";

fn tenant_cfg() -> StackConfig {
    let mut cfg = StackConfig::tiny();
    cfg.tenant.keys = TenantSpec::parse_list(KEYS).unwrap();
    cfg
}

fn teragen(dir: &str, rows: u64) -> AppPayload {
    AppPayload::Teragen {
        rows,
        maps: 1,
        dir: dir.to_string(),
    }
}

fn flood_size() -> usize {
    if std::env::var("HPCW_CHAOS").is_ok() {
        100
    } else {
        30
    }
}

fn counter(doc: &hpcw::api::wire::JobDoc, name: &str) -> Option<u64> {
    doc.result
        .as_ref()?
        .counters
        .iter()
        .find(|(k, _)| k == name)
        .map(|&(_, v)| v)
}

/// THE acceptance test: one tenant floods the queue with jobs while two
/// others submit a handful each. Fair-share dispatch interleaves the
/// tenants (the small tenants' last jobs finish before the flood
/// drains), every job completes, and the per-queue ledger is visible in
/// `/v1/queues`, `/v1/tenants` and each job's counters.
#[test]
fn greedy_flood_cannot_starve_other_tenants() {
    let mut cfg = tenant_cfg();
    // The flood must hit the fair-share queue, not the rate limiter.
    cfg.tenant.submit_rate_per_s = 10_000.0;
    cfg.tenant.submit_burst = 1_000;
    let server = ApiServer::start(Stack::new(cfg).unwrap()).unwrap();
    let alice = ApiClient::with_key(&server.addr, "k-alice");
    let bob = ApiClient::with_key(&server.addr, "k-bob");
    let carol = ApiClient::with_key(&server.addr, "k-carol");

    // Greedy tenant first, so its jobs are ahead in FIFO order — plain
    // FIFO would run all of them before bob's and carol's.
    let n = flood_size();
    let alice_jobs: Vec<u64> = (0..n)
        .map(|i| {
            alice
                .submit(2, "x", &teragen(&format!("/lustre/scratch/ten-a-{i}"), 50))
                .unwrap()
        })
        .collect();
    let bob_jobs: Vec<u64> = (0..4)
        .map(|i| {
            bob.submit(2, "x", &teragen(&format!("/lustre/scratch/ten-b-{i}"), 50))
                .unwrap()
        })
        .collect();
    let carol_jobs: Vec<u64> = (0..4)
        .map(|i| {
            carol
                .submit(2, "x", &teragen(&format!("/lustre/scratch/ten-c-{i}"), 50))
                .unwrap()
        })
        .collect();

    // Drive the small tenants to completion first; the pump advances
    // everyone's jobs while we wait.
    let mut bob_doc = None;
    for &j in bob_jobs.iter().chain(&carol_jobs) {
        let doc = bob.wait(j, Duration::from_secs(120)).unwrap();
        assert_eq!(doc.state, JobState::Done, "job {j} error={:?}", doc.error);
        bob_doc = Some(doc);
    }
    for &j in &alice_jobs {
        let doc = alice.wait(j, Duration::from_secs(300)).unwrap();
        assert_eq!(doc.state, JobState::Done, "job {j} error={:?}", doc.error);
    }

    // Interleaving proof from the journal: carol's LAST job finished
    // before alice's last — the flood did not run to exhaustion first.
    let events = alice.events(0, 0).unwrap().events;
    let done_seq = |id: u64| {
        events
            .iter()
            .find(|e| e.kind == "job" && e.id == id && e.state == "DONE")
            .unwrap_or_else(|| panic!("no DONE event for job {id}"))
            .seq
    };
    let carol_last = carol_jobs.iter().map(|&j| done_seq(j)).max().unwrap();
    let alice_last = alice_jobs.iter().map(|&j| done_seq(j)).max().unwrap();
    assert!(
        carol_last < alice_last,
        "carol's last DONE (seq {carol_last}) should precede alice's (seq {alice_last})"
    );

    // The fair-share ledger over the wire.
    let queues = alice.queues().unwrap();
    let q = |name: &str| {
        queues
            .iter()
            .find(|q| q.name == name)
            .unwrap_or_else(|| panic!("queue {name} missing from {queues:?}"))
    };
    let qa = q("root.research.alice");
    let qb = q("root.research.bob");
    let qc = q("root.eng.carol");
    assert_eq!(qb.weight, 2);
    assert!(qa.served >= n as u64 && qb.served >= 4 && qc.served >= 4);
    assert!(
        qa.share_pct > qc.share_pct && qc.share_pct > 0,
        "alice={} carol={}",
        qa.share_pct,
        qc.share_pct
    );

    let tenants = alice.tenants().unwrap();
    let t = |name: &str| tenants.iter().find(|t| t.name == name).unwrap();
    assert_eq!(t("alice").submitted, n as u64);
    assert_eq!(t("bob").submitted, 4);
    assert_eq!(t("alice").rate_limited, 0);
    assert_eq!(t("alice").running_apps, 0, "all terminal — leases released");
    assert_eq!(t("alice").breaker, "closed");

    // Per-job view: the queue ledger is stamped into the job counters.
    let doc = bob_doc.unwrap();
    assert!(counter(&doc, mrc::QUEUE_SHARE).is_some(), "doc={doc:?}");
    assert!(counter(&doc, mrc::QUEUE_WAIT_US).is_some());
    assert!(counter(&doc, mrc::PREEMPTIONS).is_some());
}

/// Satellite 6 regression: the legacy 301 paths sit BEHIND the same
/// admission gate as `/v1/*` — an unknown key gets 401 and an exhausted
/// rate bucket gets 429, never the redirect side door.
#[test]
fn admission_gates_cover_legacy_redirect_paths() {
    let mut cfg = tenant_cfg();
    cfg.tenant.anonymous_queue = String::new(); // unauthenticated ⇒ 401
    cfg.tenant.submit_burst = 2;
    cfg.tenant.submit_rate_per_s = 0.001;
    let server = ApiServer::start(Stack::new(cfg).unwrap()).unwrap();
    let addr = &server.addr;

    let code_of = |body: &[u8]| {
        Json::parse(std::str::from_utf8(body).unwrap())
            .unwrap()
            .get("error")
            .and_then(|e| e.get("code"))
            .and_then(Json::as_str)
            .map(str::to_string)
    };

    // No key / unknown key → 401 on versioned AND legacy paths.
    let (status, _, body) = request_with_headers(addr, "GET", "/v1/jobs", None, &[]).unwrap();
    assert_eq!(status, 401);
    assert_eq!(code_of(&body).as_deref(), Some("unauthorized"));
    let bad = [("X-HPCW-Key", "nope")];
    let (status, _, _) = request_with_headers(addr, "GET", "/v1/jobs", None, &bad).unwrap();
    assert_eq!(status, 401);
    let (status, _, body) =
        request_with_headers(addr, "POST", "/jobs", Some(b"{}"), &bad).unwrap();
    assert_eq!(status, 401, "legacy POST must not redirect unauthenticated");
    assert_eq!(code_of(&body).as_deref(), Some("unauthorized"));

    // An authenticated legacy POST is admitted (and charged) THEN
    // redirected; the third attempt drains the burst-2 bucket and is
    // shed with 429 + Retry-After instead of 301.
    let good = [("X-HPCW-Key", "k-alice")];
    for _ in 0..2 {
        let (status, headers, _) =
            request_with_headers(addr, "POST", "/jobs", Some(b"{}"), &good).unwrap();
        assert_eq!(status, 301);
        assert_eq!(headers.get("location").map(String::as_str), Some("/v1/jobs"));
        assert_eq!(headers.get("deprecation").map(String::as_str), Some("true"));
    }
    let (status, headers, body) =
        request_with_headers(addr, "POST", "/jobs", Some(b"{}"), &good).unwrap();
    assert_eq!(status, 429, "exhausted bucket must shed, not redirect");
    assert_eq!(code_of(&body).as_deref(), Some("rate_limited"));
    assert!(
        headers.get("retry-after").is_some(),
        "429 must carry Retry-After: {headers:?}"
    );

    // The versioned submission path answers the same way.
    let (status, _, body) = request_with_headers(
        addr,
        "POST",
        "/v1/jobs",
        Some(br#"{"nodes":2,"user":"x","payload":{"type":"teragen","rows":1,"maps":1,"dir":"/lustre/scratch/g"}}"#),
        &good,
    )
    .unwrap();
    assert_eq!(status, 429);
    assert_eq!(code_of(&body).as_deref(), Some("rate_limited"));

    // Reads still work for an authenticated caller.
    let (status, _, _) = request_with_headers(addr, "GET", "/v1/jobs", None, &good).unwrap();
    assert_eq!(status, 200);
}

/// The three rejection families — rate limit, quota, breaker — surface
/// as typed errors through the Rust client, with the Retry-After hint.
#[test]
fn rate_quota_and_breaker_reject_with_stable_codes() {
    // 1. Rate limit: burst of one, slow refill.
    let mut cfg = tenant_cfg();
    cfg.tenant.submit_burst = 1;
    cfg.tenant.submit_rate_per_s = 0.5;
    let server = ApiServer::start(Stack::new(cfg).unwrap()).unwrap();
    let alice = ApiClient::with_key(&server.addr, "k-alice");
    alice
        .submit(2, "x", &teragen("/lustre/scratch/rl-0", 50))
        .unwrap();
    let err = alice
        .submit(2, "x", &teragen("/lustre/scratch/rl-1", 50))
        .unwrap_err()
        .to_string();
    assert!(err.contains("rate_limited"), "{err}");
    assert!(err.contains("Retry-After"), "client surfaces the hint: {err}");

    // 2. DFS-bytes quota: charged when the first job lands its output.
    let mut cfg = tenant_cfg();
    cfg.tenant.max_dfs_bytes = 1;
    let server = ApiServer::start(Stack::new(cfg).unwrap()).unwrap();
    let bob = ApiClient::with_key(&server.addr, "k-bob");
    let job = bob
        .submit(2, "x", &teragen("/lustre/scratch/qt-0", 100))
        .unwrap();
    let doc = bob.wait(job, Duration::from_secs(60)).unwrap();
    assert_eq!(doc.state, JobState::Done, "error={:?}", doc.error);
    let err = bob
        .submit(2, "x", &teragen("/lustre/scratch/qt-1", 100))
        .unwrap_err()
        .to_string();
    assert!(err.contains("quota_exceeded"), "{err}");

    // 3. Circuit breaker: one failed job trips it; the next submission
    //    is rejected server-side without touching the scheduler.
    let mut cfg = tenant_cfg();
    cfg.tenant.breaker_threshold = 1;
    cfg.tenant.breaker_open_ms = 3_600_000;
    let server = ApiServer::start(Stack::new(cfg).unwrap()).unwrap();
    let carol = ApiClient::with_key(&server.addr, "k-carol");
    let doomed = AppPayload::HiveQuery {
        sql: "SELECT COUNT(a) FROM '/lustre/scratch/absent' SCHEMA (a) \
              INTO '/lustre/scratch/br-out'"
            .into(),
        reduces: 1,
    };
    let job = carol.submit(2, "x", &doomed).unwrap();
    let doc = carol.wait(job, Duration::from_secs(60)).unwrap();
    assert_eq!(doc.state, JobState::Exited, "the probe job must fail");
    let err = carol
        .submit(2, "x", &teragen("/lustre/scratch/br-1", 50))
        .unwrap_err()
        .to_string();
    assert!(err.contains("rate_limited"), "breaker presents as 429: {err}");
    let t = carol.tenants().unwrap();
    let c = t.iter().find(|t| t.name == "carol").unwrap();
    assert_eq!(c.breaker, "open");
    assert!(c.breaker_rejected >= 1);
}

/// The preemption byte-parity oracle: the same three-tenant workload
/// with preemption enabled and disabled produces byte-identical output
/// files — preempted containers re-run through the ordinary lost-
/// container path and never corrupt results.
#[test]
fn preemption_on_off_outputs_byte_identical() {
    fn run(preemption: bool) -> BTreeMap<String, Vec<u8>> {
        let mut cfg = tenant_cfg();
        cfg.tenant.preemption = preemption;
        cfg.tenant.submit_burst = 100;
        let mut stack = Stack::new(cfg).unwrap();
        let mut jobs = vec![stack
            .submit(
                3,
                "alice",
                AppPayload::Terasort {
                    rows: 2_000,
                    maps: 2,
                    reduces: 2,
                    use_kernel: false,
                },
            )
            .unwrap()];
        for (user, dir) in [("bob", "/lustre/scratch/pp-b"), ("carol", "/lustre/scratch/pp-c")] {
            jobs.push(stack.submit(2, user, teragen(dir, 500)).unwrap());
        }
        let terasort = jobs[0];
        let mut out = BTreeMap::new();
        for id in jobs {
            let result = stack.run_to_completion(id, 200).unwrap().clone();
            if id == terasort {
                assert!(result.validated, "terasort must validate");
            }
            for f in &result.output_files {
                out.insert(f.clone(), stack.read_output(f).unwrap());
            }
        }
        out
    }
    let with_preemption = run(true);
    let without = run(false);
    assert!(!with_preemption.is_empty());
    assert_eq!(
        with_preemption.keys().collect::<Vec<_>>(),
        without.keys().collect::<Vec<_>>(),
        "same output files either way"
    );
    for (file, bytes) in &with_preemption {
        assert_eq!(
            Some(bytes),
            without.get(file).as_deref(),
            "{file} differs between preemption on/off"
        );
    }
}
