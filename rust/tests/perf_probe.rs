//! One-off perf probe used for EXPERIMENTS.md §Perf (run with --ignored).
use hpcw::cluster::NodeId;
use hpcw::config::StackConfig;
use hpcw::lustre::LustreFs;
use hpcw::mapreduce::MrEngine;
use hpcw::metrics::Metrics;
use hpcw::runtime::RustBlockProcessor;
use hpcw::terasort::*;
use hpcw::util::ids::IdGen;
use hpcw::util::pool::Pool;
use hpcw::util::time::Micros;
use hpcw::wrapper::DynamicCluster;
use std::sync::Arc;

#[test]
#[ignore]
fn perkey_vs_block_map_path() {
    let cfg = StackConfig::tiny();
    let fs = Arc::new(LustreFs::new(&cfg.lustre, &cfg.cluster));
    let nodes: Vec<NodeId> = (0..8).map(NodeId).collect();
    let mut dc = DynamicCluster::build(&cfg, &nodes, &*fs, Arc::new(IdGen::default()),
        Arc::new(Metrics::new()), "probe", Micros::ZERO).unwrap();
    let pool = Pool::new(8);
    let rows = 1_000_000u64;
    {
        let mut engine = MrEngine::new(&mut dc, fs.clone(), &pool, cfg.yarn.map_memory_mb, cfg.yarn.reduce_memory_mb);
        run_teragen(&mut engine, &TeragenSpec { rows, maps: 6, output_dir: "/lustre/scratch/p-in".into(), seed: 1 }, Micros::ZERO).unwrap();
    }
    for (label, use_block) in [("rust-block", true), ("per-key", false), ("rust-block2", true), ("per-key2", false)] {
        let out = format!("/lustre/scratch/p-out-{label}");
        let ts = TerasortJob { split_bytes: 4 << 20, ..TerasortJob::new("/lustre/scratch/p-in", &out, 8) };
        let t0 = std::time::Instant::now();
        let mut engine = MrEngine::new(&mut dc, fs.clone(), &pool, cfg.yarn.map_memory_mb, cfg.yarn.reduce_memory_mb);
        if use_block {
            let samples = sample_input(&*fs, "/lustre/scratch/p-in", 1000).unwrap();
            let part = RangePartitioner::from_samples(samples, 8).unwrap();
            run_terasort_with_processor(&mut engine, &ts, Arc::new(RustBlockProcessor { partitioner: part }), Micros::ZERO).unwrap();
        } else {
            run_terasort(&mut engine, &ts, None, Micros::ZERO).unwrap();
        }
        let dt = t0.elapsed().as_secs_f64();
        println!("{label}: {:.2}s ({:.1} MB/s sort-only)", dt, rows as f64 * 100.0 / 1e6 / dt);
    }
}
