//! CLI smoke tests: the `hpcw` subcommands end to end (in-process).

#[test]
fn usage_on_no_command() {
    assert_eq!(hpcw::cli::run(vec![]), 0);
}

#[test]
fn unknown_subcommand_is_an_error() {
    assert_eq!(hpcw::cli::run(vec!["frobnicate".into()]), 1);
}

#[test]
fn wrapper_point_prints_and_succeeds() {
    let code = hpcw::cli::run(vec![
        "wrapper".into(),
        "--nodes".into(),
        "16".into(),
    ]);
    assert_eq!(code, 0);
}

#[test]
fn terasort_cli_end_to_end() {
    let code = hpcw::cli::run(vec![
        "terasort".into(),
        "--rows".into(),
        "2000".into(),
        "--nodes".into(),
        "4".into(),
        "--reduces".into(),
        "3".into(),
    ]);
    assert_eq!(code, 0);
}

#[test]
fn terasort_requires_rows() {
    assert_eq!(hpcw::cli::run(vec!["terasort".into()]), 1);
}

#[test]
fn hive_cli_reports_parse_errors() {
    let code = hpcw::cli::run(vec![
        "hive".into(),
        "--sql".into(),
        "DROP TABLE x".into(),
        "--tiny".into(),
    ]);
    assert_eq!(code, 1);
}
