//! CLI smoke tests: the `hpcw` subcommands end to end (in-process).

#[test]
fn usage_on_no_command() {
    assert_eq!(hpcw::cli::run(vec![]), 0);
}

#[test]
fn unknown_subcommand_is_an_error() {
    assert_eq!(hpcw::cli::run(vec!["frobnicate".into()]), 1);
}

#[test]
fn wrapper_point_prints_and_succeeds() {
    let code = hpcw::cli::run(vec![
        "wrapper".into(),
        "--nodes".into(),
        "16".into(),
    ]);
    assert_eq!(code, 0);
}

#[test]
fn terasort_cli_end_to_end() {
    let code = hpcw::cli::run(vec![
        "terasort".into(),
        "--rows".into(),
        "2000".into(),
        "--nodes".into(),
        "4".into(),
        "--reduces".into(),
        "3".into(),
    ]);
    assert_eq!(code, 0);
}

#[test]
fn terasort_requires_rows() {
    assert_eq!(hpcw::cli::run(vec!["terasort".into()]), 1);
}

#[test]
fn jobs_requires_addr() {
    assert_eq!(hpcw::cli::run(vec!["jobs".into()]), 1);
}

#[test]
fn events_requires_addr() {
    assert_eq!(hpcw::cli::run(vec!["events".into()]), 1);
}

#[test]
fn jobs_and_events_against_live_server() {
    // Start an in-process API server, then drive the client subcommands
    // against it exactly as a user would from another machine.
    let stack = hpcw::api::Stack::new(hpcw::config::StackConfig::tiny()).unwrap();
    let server = hpcw::api::ApiServer::start(stack).unwrap();
    let client = hpcw::api::ApiClient::new(&server.addr);
    let job = client
        .submit(
            2,
            "cli",
            &hpcw::api::AppPayload::Teragen {
                rows: 100,
                maps: 1,
                dir: "/lustre/scratch/cli-jobs".into(),
            },
        )
        .unwrap();
    client.wait(job, std::time::Duration::from_secs(30)).unwrap();
    let addr = server.addr.clone();
    assert_eq!(
        hpcw::cli::run(vec!["jobs".into(), "--addr".into(), addr.clone()]),
        0
    );
    assert_eq!(
        hpcw::cli::run(vec![
            "events".into(),
            "--addr".into(),
            addr,
            "--since".into(),
            "0".into(),
        ]),
        0
    );
}

#[test]
fn hive_cli_reports_parse_errors() {
    let code = hpcw::cli::run(vec![
        "hive".into(),
        "--sql".into(),
        "DROP TABLE x".into(),
        "--tiny".into(),
    ]);
    assert_eq!(code, 1);
}
