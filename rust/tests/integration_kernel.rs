//! Kernel-path integration: the AOT Pallas `mapphase` artifact running
//! inside the full stack (LSF → wrapper → YARN → MR with the PJRT block
//! processor), validated by Teravalidate and parity-checked against the
//! pure-Rust path. Skips gracefully when artifacts are not built.

use hpcw::api::{AppPayload, Stack};
use hpcw::config::StackConfig;
use hpcw::lustre::Dfs;
use hpcw::runtime::artifacts::default_dir;

fn artifacts_built() -> bool {
    default_dir().join("manifest.json").exists()
}

#[test]
fn kernel_terasort_validates_through_full_stack() {
    if !artifacts_built() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let mut s = Stack::new(StackConfig::tiny()).unwrap();
    let id = s
        .submit(
            6,
            "kernel-user",
            AppPayload::Terasort {
                rows: 4_000,
                maps: 3,
                reduces: 5,
                use_kernel: true,
            },
        )
        .unwrap();
    let r = s.run_to_completion(id, 10).unwrap();
    assert!(r.validated);
    assert_eq!(r.records, 4_000);
}

#[test]
fn kernel_and_rust_paths_produce_identical_output() {
    if !artifacts_built() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let run = |use_kernel: bool| {
        let mut s = Stack::new(StackConfig::tiny()).unwrap();
        let id = s
            .submit(
                6,
                "parity",
                AppPayload::Terasort {
                    rows: 2_500,
                    maps: 2,
                    reduces: 3,
                    use_kernel,
                },
            )
            .unwrap();
        let r = s.run_to_completion(id, 10).unwrap().clone();
        // Concatenate all output bytes in part order.
        let mut all = Vec::new();
        let mut files = r.output_files.clone();
        files.sort();
        for f in files {
            all.extend(s.read_output(&f).unwrap());
        }
        all
    };
    let rust = run(false);
    let kernel = run(true);
    assert_eq!(rust.len(), kernel.len());
    assert_eq!(rust, kernel, "byte-identical sorted output on both paths");
}
