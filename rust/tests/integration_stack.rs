//! Full-stack integration: LSF → wrapper → YARN → MapReduce → teardown,
//! across multiple jobs, users and failure cases.

use hpcw::api::{AppPayload, Stack};
use hpcw::cluster::NodeId;
use hpcw::config::StackConfig;
use hpcw::lustre::Dfs;
use hpcw::scheduler::JobState;
use hpcw::yarn::JobHistoryServer;

fn stack() -> Stack {
    Stack::new(StackConfig::tiny()).unwrap()
}

#[test]
fn many_sequential_jobs_leave_no_residue() {
    let mut s = stack();
    for i in 0..5 {
        let id = s
            .submit(
                4,
                "loop-user",
                AppPayload::Teragen {
                    rows: 300,
                    maps: 2,
                    dir: format!("/lustre/scratch/residue-{i}"),
                },
            )
            .unwrap();
        s.run_to_completion(id, 10).unwrap();
    }
    // All staging removed; all 5 outputs present; all nodes free.
    for i in 0..5 {
        assert!(s.dfs.exists(&format!("/lustre/scratch/residue-{i}/_SUCCESS")));
    }
    let leftovers: Vec<String> = s.dfs.list("/lustre/scratch/hpcw-jobs");
    assert!(leftovers.is_empty(), "staging left: {leftovers:?}");
    assert_eq!(s.lsf.free_nodes(), 8);
    s.lsf.check_invariants().unwrap();
    // JHS history survives teardown: reload from Lustre and count apps.
    let mut jhs = JobHistoryServer::new("/lustre/scratch/hpcw-history/done");
    let n = jhs.reload(&*s.dfs).unwrap();
    assert_eq!(n, 5, "one history report per MR app");
}

#[test]
fn concurrent_users_fair_queueing() {
    let mut s = stack();
    // Three 4-node jobs on an 8-node machine: two run, one queues.
    let ids: Vec<_> = (0..3)
        .map(|i| {
            s.submit(
                4,
                &format!("user{i}"),
                AppPayload::Teragen {
                    rows: 200,
                    maps: 1,
                    dir: format!("/lustre/scratch/cc-{i}"),
                },
            )
            .unwrap()
        })
        .collect();
    let first_wave = s.tick();
    assert_eq!(first_wave.len(), 2, "two fit at once");
    let second_wave = s.tick();
    assert_eq!(second_wave.len(), 1);
    for id in ids {
        assert_eq!(s.lsf.status(id).unwrap().state, JobState::Done);
    }
}

#[test]
fn kill_pending_job_never_runs() {
    let mut s = stack();
    let a = s
        .submit(
            8,
            "u",
            AppPayload::Teragen {
                rows: 200,
                maps: 1,
                dir: "/lustre/scratch/kill-a".into(),
            },
        )
        .unwrap();
    let b = s
        .submit(
            8,
            "u",
            AppPayload::Teragen {
                rows: 200,
                maps: 1,
                dir: "/lustre/scratch/kill-b".into(),
            },
        )
        .unwrap();
    s.kill(b).unwrap();
    s.tick();
    s.tick();
    assert_eq!(s.lsf.status(a).unwrap().state, JobState::Done);
    assert_eq!(s.lsf.status(b).unwrap().state, JobState::Killed);
    assert!(!s.dfs.exists("/lustre/scratch/kill-b"));
}

#[test]
fn node_failure_shrinks_pool_but_jobs_continue() {
    let mut s = stack();
    // Fail a node before dispatch: 7 remain.
    s.cluster.fail_node(NodeId(7)).unwrap();
    let victims = s.lsf.node_failed(NodeId(7));
    assert!(victims.is_empty());
    let id = s
        .submit(
            7,
            "u",
            AppPayload::Teragen {
                rows: 300,
                maps: 2,
                dir: "/lustre/scratch/nf".into(),
            },
        )
        .unwrap();
    let r = s.run_to_completion(id, 10).unwrap();
    assert_eq!(r.records, 300);
    assert_eq!(s.lsf.free_nodes(), 7);
}

#[test]
fn oversized_request_rejected_cleanly() {
    let mut s = stack();
    let err = s
        .submit(
            99,
            "u",
            AppPayload::Teragen {
                rows: 1,
                maps: 1,
                dir: "/lustre/scratch/x".into(),
            },
        )
        .unwrap_err();
    assert!(err.to_string().contains("exceeds cluster size"));
}

#[test]
fn hive_and_pig_agree_through_the_full_stack() {
    let mut s = stack();
    s.dfs.mkdirs("/lustre/scratch/agree").unwrap();
    let mut rows = String::new();
    for i in 0..200 {
        rows.push_str(&format!(
            "r{},p{},{}\n",
            i % 4,
            i % 3,
            (i * 37) % 500
        ));
    }
    s.dfs
        .create("/lustre/scratch/agree/part-0", rows.as_bytes())
        .unwrap();

    let pig = s
        .submit(
            4,
            "u",
            AppPayload::PigScript {
                script: "
        recs = LOAD '/lustre/scratch/agree' USING ',' AS (region, product, amount);
        big  = FILTER recs BY amount > 250;
        grp  = GROUP big BY region;
        out  = FOREACH grp GENERATE group, SUM(amount), MAX(amount);
        STORE out INTO '/lustre/scratch/agree-pig';"
                    .into(),
                reduces: 3,
            },
        )
        .unwrap();
    let hive = s
        .submit(
            4,
            "u",
            AppPayload::HiveQuery {
                sql: "SELECT region, SUM(amount), MAX(amount) \
                      FROM '/lustre/scratch/agree' USING ',' \
                      SCHEMA (region, product, amount) \
                      WHERE amount > 250 GROUP BY region \
                      INTO '/lustre/scratch/agree-hive'"
                    .into(),
                reduces: 3,
            },
        )
        .unwrap();
    let rp = s.run_to_completion(pig, 10).unwrap().clone();
    let rh = s.run_to_completion(hive, 10).unwrap().clone();

    let collect = |s: &Stack, files: &[String]| {
        let mut text = String::new();
        for f in files {
            text.push_str(&String::from_utf8(s.read_output(f).unwrap()).unwrap());
        }
        hpcw::frameworks::plan::sorted_result_lines(&text)
    };
    let a = collect(&s, &rp.output_files);
    let b = collect(&s, &rh.output_files);
    assert_eq!(a, b);
    assert!(!a.is_empty());
}

#[test]
fn metrics_timeline_orders_wrapper_events() {
    let mut s = stack();
    let id = s
        .submit(
            4,
            "u",
            AppPayload::Teragen {
                rows: 100,
                maps: 1,
                dir: "/lustre/scratch/tl".into(),
            },
        )
        .unwrap();
    s.run_to_completion(id, 10).unwrap();
    let timeline = s.metrics.timeline();
    let idx = |needle: &str| {
        timeline
            .iter()
            .position(|e| e.label.contains(needle))
            .unwrap_or_else(|| panic!("missing event '{needle}'"))
    };
    // Paper ordering: dispatch → staging dirs → RM → JHS → NMs → teardown.
    assert!(idx("dispatch job") < idx("staging dirs created"));
    assert!(idx("staging dirs created") < idx("RM started"));
    assert!(idx("RM started") < idx("JHS started"));
    assert!(idx("JHS started") < idx("NMs up"));
    assert!(idx("NMs up") < idx("cluster torn down"));
    assert!(idx("cluster torn down") < idx("finish job"));
}
