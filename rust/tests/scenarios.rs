//! Scenario harness acceptance: the shipped example specs run end to end
//! through `POST /v1/scenarios`, and on them the `sla_energy` policy
//! beats `grow_on_backlog` where it claims to — fewer SLA0 violations at
//! equal-or-lower energy on the spike, strictly less energy with no tier
//! regressions on the diurnal updown. The same comparison is gated in CI
//! by `benches/scenario_policies.rs` against committed baseline floors;
//! this test keeps the claim in `cargo test`.

use hpcw::api::wire::ScenarioState;
use hpcw::api::{ApiClient, ApiServer, Stack};
use hpcw::config::StackConfig;
use hpcw::scenario::{Runner, ScenarioSpec, ScoreDoc};
use std::time::Duration;

const SPIKE: &str = include_str!("../../examples/scenarios/spike.toml");
const UPDOWN: &str = include_str!("../../examples/scenarios/updown.toml");

fn spec_with_policy(toml: &str, policy: &str) -> ScenarioSpec {
    let mut spec = ScenarioSpec::from_toml(toml).unwrap();
    spec.policy = policy.to_string();
    spec.validate().unwrap();
    spec
}

fn run_over_api(client: &ApiClient, spec: &ScenarioSpec) -> ScoreDoc {
    let id = client.run_scenario(spec).unwrap();
    let doc = client.wait_scenario(id, Duration::from_secs(120)).unwrap();
    assert_eq!(doc.state, ScenarioState::Done, "error={:?}", doc.error);
    doc.score.unwrap()
}

fn total_violations(s: &ScoreDoc) -> u64 {
    s.tiers.iter().map(|t| t.violations).sum()
}

/// Acceptance: on the spike scenario the SLA/energy policy at least
/// halves the SLA0 violation rate of the legacy backlog policy, without
/// spending more energy — and the whole comparison runs through the API.
#[test]
fn sla_policy_beats_backlog_on_spike_over_api() {
    let server = ApiServer::start(Stack::new(StackConfig::tiny()).unwrap()).unwrap();
    let client = ApiClient::new(&server.addr);

    let backlog = run_over_api(&client, &spec_with_policy(SPIKE, "grow_on_backlog"));
    let sla = run_over_api(&client, &spec_with_policy(SPIKE, "sla_energy"));

    assert!(
        sla.sla0_violation_bp() * 2 <= backlog.sla0_violation_bp(),
        "sla_energy {}bp vs grow_on_backlog {}bp",
        sla.sla0_violation_bp(),
        backlog.sla0_violation_bp()
    );
    assert!(
        sla.energy.energy_mj <= backlog.energy.energy_mj,
        "the SLA win must not cost energy: {} mJ vs {} mJ",
        sla.energy.energy_mj,
        backlog.energy.energy_mj
    );
    // Both rows are listable and terminal; list rows omit the score.
    let page = client.list_scenarios(0, 10).unwrap();
    assert_eq!(page.total, 2);
    for row in &page.scenarios {
        assert_eq!(row.state, ScenarioState::Done);
        assert!(row.score.is_none(), "list rows omit the score");
    }
}

/// Acceptance: the diurnal updown scenario saves energy (the idle night
/// fleet sleeps) without making any tier's violation count worse.
#[test]
fn updown_saves_energy_without_sla_regressions() {
    let backlog = Runner::run(spec_with_policy(UPDOWN, "grow_on_backlog")).unwrap();
    let sla = Runner::run(spec_with_policy(UPDOWN, "sla_energy")).unwrap();
    assert!(
        sla.energy.energy_mj < backlog.energy.energy_mj,
        "{} mJ vs {} mJ",
        sla.energy.energy_mj,
        backlog.energy.energy_mj
    );
    assert!(
        total_violations(&sla) <= total_violations(&backlog),
        "{} vs {} violations",
        total_violations(&sla),
        total_violations(&backlog)
    );
    assert_eq!(backlog.ticks, sla.ticks, "same timeline under both policies");
}

/// The runner is a pure fixed-seed simulation: identical spec, identical
/// score — which is what lets the CI bench gate exact values.
#[test]
fn scenario_scores_are_deterministic() {
    for toml in [SPIKE, UPDOWN] {
        let a = Runner::run(ScenarioSpec::from_toml(toml).unwrap()).unwrap();
        let b = Runner::run(ScenarioSpec::from_toml(toml).unwrap()).unwrap();
        assert_eq!(a, b);
    }
}
