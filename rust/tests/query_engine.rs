//! Multi-stage query engine integration (PR 5 + PR 6 acceptance):
//!
//! * a Hive query with JOIN + ORDER BY runs end to end over the API as a
//!   workflow of ≥ 2 chained MR jobs, and its totally-ordered output is
//!   validated **row for row** against a single-threaded reference
//!   evaluation;
//! * the map-side combiner leaves aggregation output byte-identical
//!   while strictly reducing the `SHUFFLE_BYTES` counter (also asserted
//!   as a property over random integer tables);
//! * Pig's JOIN / ORDER / LIMIT pipeline runs as chained jobs on one
//!   dynamic cluster via the `query` payload, with per-stage counters;
//! * the cost-based optimizer (PR 6) is pinned against its oracles: the
//!   broadcast-hash join vs the repartition fallback
//!   (`HPCW_BROADCAST_MAX_BYTES=0`) and the fused plan vs the naive
//!   lowering (`HPCW_FUSION=0`) are byte-identical, fusion renumbers
//!   stages contiguously and leaves no orphan `.stage{i}` intermediates,
//!   the broadcast hash table survives map re-execution and node loss,
//!   and EXPLAIN output is pinned golden-file exact for a Pig and a
//!   Hive plan.

use hpcw::api::{parse_query_text, ApiClient, ApiServer, AppPayload, Stack};
use hpcw::api::wire::StepState;
use hpcw::cluster::{ClusterManager, NodeId};
use hpcw::config::{ElasticConfig, StackConfig};
use hpcw::frameworks::plan::StageKind;
use hpcw::lustre::{Dfs, LustreFs};
use hpcw::mapreduce::{
    counters, ElasticAction, ElasticPlan, FailurePlan, MrEngine, TaskId,
};
use hpcw::metrics::Metrics;
use hpcw::testkit::props;
use hpcw::util::ids::IdGen;
use hpcw::util::pool::Pool;
use hpcw::util::time::Micros;
use hpcw::wrapper::DynamicCluster;
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::Duration;

/// Serializes tests that read or write the planner's and scheduler's env
/// knobs (`HPCW_BROADCAST_MAX_BYTES`, `HPCW_FUSION`, `HPCW_SPECULATION`,
/// `HPCW_NODE_MIPS`). Rust tests share one process, so an unguarded
/// `set_var` would race every concurrent test whose plan compiles a join;
/// the guard also restores the previous values on drop, no matter how
/// the test exits.
static ENV_LOCK: Mutex<()> = Mutex::new(());

struct EnvGuard {
    _lock: MutexGuard<'static, ()>,
    saved: Vec<(&'static str, Option<String>)>,
}

impl EnvGuard {
    fn lock() -> EnvGuard {
        let lock = ENV_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let saved = [
            "HPCW_BROADCAST_MAX_BYTES",
            "HPCW_FUSION",
            "HPCW_SPECULATION",
            "HPCW_NODE_MIPS",
        ]
        .iter()
        .map(|k| (*k, std::env::var(k).ok()))
        .collect();
        EnvGuard { _lock: lock, saved }
    }

    fn set(&self, key: &str, value: &str) {
        std::env::set_var(key, value);
    }

    fn clear(&self, key: &str) {
        std::env::remove_var(key);
    }
}

impl Drop for EnvGuard {
    fn drop(&mut self) {
        for (k, v) in &self.saved {
            match v {
                Some(v) => std::env::set_var(k, v),
                None => std::env::remove_var(k),
            }
        }
    }
}

fn counter(result: &hpcw::api::AppResult, key: &str) -> Option<u64> {
    result.counters.iter().find(|(k, _)| k == key).map(|(_, v)| *v)
}

/// Concatenate a query's output parts in partition-file order (which is
/// global order for sort stages).
fn read_parts(dfs: &LustreFs, dir: &str) -> String {
    let mut files: Vec<String> = dfs
        .list(dir)
        .into_iter()
        .filter(|p| p.contains("/part-"))
        .collect();
    files.sort();
    let mut text = String::new();
    for f in &files {
        text.push_str(&String::from_utf8(dfs.read(f).unwrap()).unwrap());
    }
    text
}

/// The acceptance test: JOIN + ORDER BY over the v1 wire, executed as a
/// workflow DAG — one `query_stage` LSF job per MR stage — with the
/// final output validated row for row against a reference evaluation.
#[test]
fn hive_join_order_by_runs_as_chained_workflow_jobs() {
    let stack = Stack::new(StackConfig::tiny()).unwrap();
    let dfs = stack.dfs.clone();

    // Tables: sales(region, product, amount) and regions(region, country).
    // Amounts are unique so the total order is deterministic and the
    // row-for-row comparison is exact. 'norge' has no region row (inner
    // join drops it); amounts <= 100 are filtered by WHERE.
    let regions: &[(&str, &str)] =
        &[("wales", "UK"), ("england", "UK"), ("bayern", "DE"), ("ireland", "IE")];
    let mut sales: Vec<(String, String, u64)> = Vec::new();
    for i in 0..60u64 {
        let region = ["wales", "england", "bayern", "norge"][(i % 4) as usize];
        sales.push((region.to_string(), format!("p{i:02}"), 40 + i * 7));
    }
    dfs.mkdirs("/lustre/scratch/qe-sales").unwrap();
    dfs.mkdirs("/lustre/scratch/qe-regions").unwrap();
    // Two part files per table: the join must merge across files.
    for (part, chunk) in sales.chunks(30).enumerate() {
        let text: String = chunk
            .iter()
            .map(|(r, p, a)| format!("{r},{p},{a}\n"))
            .collect();
        dfs.create(
            &format!("/lustre/scratch/qe-sales/part-{part}"),
            text.as_bytes(),
        )
        .unwrap();
    }
    let rtext: String = regions.iter().map(|(r, c)| format!("{r},{c}\n")).collect();
    dfs.create("/lustre/scratch/qe-regions/part-0", rtext.as_bytes())
        .unwrap();

    // Reference evaluation (single-threaded): inner join, filter, total
    // order by amount descending.
    let mut expected: Vec<(u64, String)> = Vec::new();
    for (r, p, a) in &sales {
        if *a <= 100 {
            continue;
        }
        for (rr, c) in regions {
            if rr == r {
                expected.push((*a, format!("{r}\t{p}\t{a}\t{rr}\t{c}")));
            }
        }
    }
    expected.sort_by(|x, y| y.0.cmp(&x.0));
    let expected: Vec<String> = expected.into_iter().map(|(_, row)| row).collect();
    assert!(expected.len() > 20, "test data must survive the filter");

    let server = ApiServer::start(stack).unwrap();
    let client = ApiClient::new(&server.addr);
    let sql = "SELECT * FROM '/lustre/scratch/qe-sales' USING ',' \
               SCHEMA (region, product, amount) \
               JOIN '/lustre/scratch/qe-regions' USING ',' \
               SCHEMA (region, country) ON region = region \
               WHERE amount > 100 \
               ORDER BY amount DESC \
               INTO '/lustre/scratch/qe-top'";
    let wf = client
        .submit_query("hive", sql, 3, 6, "sid", true)
        .unwrap();
    let doc = client.wait_workflow(wf, Duration::from_secs(60)).unwrap();
    assert!(doc.complete, "doc={doc:?}");
    assert!(
        doc.steps.len() >= 2,
        "JOIN + ORDER BY must compile to >= 2 chained MR jobs, got {}",
        doc.steps.len()
    );
    for s in &doc.steps {
        assert_eq!(s.state, StepState::Done);
        assert!(s.job.is_some(), "every stage ran as its own LSF job");
    }
    // Steps chained: each later step consumed its predecessor's output.
    assert_eq!(
        doc.steps.last().unwrap().output_dir.as_deref(),
        Some("/lustre/scratch/qe-top")
    );

    // Row-for-row validation against the reference: concatenating the
    // sort stage's parts in partition order IS the total order.
    let got: Vec<String> = read_parts(&dfs, "/lustre/scratch/qe-top")
        .lines()
        .map(|l| l.to_string())
        .collect();
    assert_eq!(got, expected, "distributed result must match the reference");
}

fn engine_fixture() -> (StackConfig, Arc<LustreFs>, DynamicCluster, Pool) {
    let cfg = StackConfig::tiny();
    let fs = Arc::new(LustreFs::new(&cfg.lustre, &cfg.cluster));
    let nodes: Vec<NodeId> = (0..6).map(NodeId).collect();
    let dc = DynamicCluster::build(
        &cfg,
        &nodes,
        &*fs,
        Arc::new(IdGen::default()),
        Arc::new(Metrics::new()),
        "query-engine-test",
        Micros::ZERO,
    )
    .unwrap();
    (cfg, fs, dc, Pool::new(4))
}

/// Combiner acceptance: the aggregation stage run with and without its
/// combiner produces byte-identical output, and the combiner run ships
/// strictly fewer `SHUFFLE_BYTES`.
#[test]
fn combiner_is_invisible_in_output_but_cuts_shuffle_bytes() {
    let (cfg, fs, mut dc, pool) = engine_fixture();
    fs.mkdirs("/lustre/scratch/qc-in").unwrap();
    let mut text = String::new();
    for i in 0..400u64 {
        let region = ["wales", "england", "bayern", "alba", "eire"][(i % 5) as usize];
        text.push_str(&format!("{region},p{},{}\n", i % 7, 10 + (i % 97)));
    }
    fs.create("/lustre/scratch/qc-in/part-0", text.as_bytes()).unwrap();

    let run = |dc: &mut DynamicCluster, combine: bool, out: &str| {
        let plan = hpcw::api::parse_query_text(
            "hive",
            &format!(
                "SELECT region, SUM(amount), COUNT(amount), MIN(amount), MAX(amount) \
                 FROM '/lustre/scratch/qc-in' USING ',' \
                 SCHEMA (region, product, amount) GROUP BY region INTO '{out}'"
            ),
            3,
        )
        .unwrap();
        let stages = plan.compile_stages().unwrap();
        assert_eq!(stages.len(), 1);
        assert_eq!(stages[0].kind, StageKind::Agg);
        let mut spec = stages[0].compile(&*fs).unwrap();
        // Split small so several maps spill several runs each.
        spec.split_bytes = 1024;
        if !combine {
            spec.combiner = None;
        }
        let mut engine = MrEngine::new(
            dc,
            fs.clone() as Arc<dyn Dfs>,
            &pool,
            cfg.yarn.map_memory_mb,
            cfg.yarn.reduce_memory_mb,
        );
        engine.run(Arc::new(spec), "u", Micros::ZERO).unwrap()
    };

    let off = run(&mut dc, false, "/lustre/scratch/qc-off");
    let on = run(&mut dc, true, "/lustre/scratch/qc-on");

    // Byte-identical result (integer inputs: partial merging is exact).
    assert_eq!(
        read_parts(&fs, "/lustre/scratch/qc-off"),
        read_parts(&fs, "/lustre/scratch/qc-on"),
        "combiner must not change the query result"
    );
    let sb_off = off.counters.get("SHUFFLE_BYTES");
    let sb_on = on.counters.get("SHUFFLE_BYTES");
    assert!(
        sb_on < sb_off,
        "combiner must strictly cut shuffle bytes: on={sb_on} off={sb_off}"
    );
    assert!(on.counters.get("COMBINE_INPUT_RECORDS") > on.counters.get("COMBINE_OUTPUT_RECORDS"));
    assert_eq!(off.counters.get("COMBINE_INPUT_RECORDS"), 0);
}

/// Property: for random integer tables, combiner-on and combiner-off
/// aggregation runs are byte-identical and the combiner never increases
/// shuffle bytes (strict decrease whenever keys repeat within a map).
#[test]
fn prop_combiner_parity_on_random_tables() {
    props(8, |g| {
        // Fresh filesystem + cluster per case: seeds replay cleanly.
        let (cfg, fs, mut dc, pool) = engine_fixture();
        let in_dir = "/lustre/scratch/qp-in".to_string();
        fs.mkdirs(&in_dir).unwrap();
        let n_rows = g.usize(40..200);
        let n_keys = g.usize(1..6);
        let mut text = String::new();
        for _ in 0..n_rows {
            // Integer amounts only: f64 partial sums stay exact, so the
            // byte-identity assertion is sound.
            text.push_str(&format!(
                "k{},{}\n",
                g.u32(0..n_keys as u32),
                g.u64(0..10_000)
            ));
        }
        fs.create(&format!("{in_dir}/part-0"), text.as_bytes()).unwrap();
        let mut outcomes = Vec::new();
        for combine in [false, true] {
            let out = format!("/lustre/scratch/qp-out-{combine}");
            let plan = hpcw::api::parse_query_text(
                "hive",
                &format!(
                    "SELECT key, SUM(amount), COUNT(amount) FROM '{in_dir}' USING ',' \
                     SCHEMA (key, amount) GROUP BY key INTO '{out}'"
                ),
                2,
            )
            .unwrap();
            let mut spec = plan.compile_stages().unwrap()[0].compile(&*fs).unwrap();
            spec.split_bytes = 512;
            if !combine {
                spec.combiner = None;
            }
            let mut engine = MrEngine::new(
                &mut dc,
                fs.clone() as Arc<dyn Dfs>,
                &pool,
                cfg.yarn.map_memory_mb,
                cfg.yarn.reduce_memory_mb,
            );
            let outcome = engine.run(Arc::new(spec), "u", Micros::ZERO).unwrap();
            outcomes.push((out, outcome));
        }
        let (off_dir, off) = &outcomes[0];
        let (on_dir, on) = &outcomes[1];
        assert_eq!(read_parts(&fs, off_dir), read_parts(&fs, on_dir));
        let (sb_off, sb_on) = (off.counters.get("SHUFFLE_BYTES"), on.counters.get("SHUFFLE_BYTES"));
        assert!(sb_on <= sb_off, "combiner must never grow the shuffle");
        if on.counters.get("COMBINE_OUTPUT_RECORDS") < on.counters.get("COMBINE_INPUT_RECORDS") {
            assert!(sb_on < sb_off, "folded records must shrink shuffle bytes");
        }
    });
}

/// Pig JOIN / ORDER / LIMIT through the `query` payload: the stage chain
/// runs on ONE dynamic cluster (one LSF job), intermediates are cleaned
/// up, and the result carries merged plus per-stage (`s{i}.`) counters.
#[test]
fn pig_join_order_limit_runs_on_one_cluster() {
    // Default planner knobs: the 20-byte regions table auto-broadcasts.
    let _env = EnvGuard::lock();
    let mut stack = Stack::new(StackConfig::tiny()).unwrap();
    stack.dfs.mkdirs("/lustre/scratch/pg-sales").unwrap();
    stack.dfs.mkdirs("/lustre/scratch/pg-regions").unwrap();
    let mut text = String::new();
    for i in 0..30u64 {
        let region = ["wales", "england"][(i % 2) as usize];
        text.push_str(&format!("{region},p{i},{}\n", 50 + i * 11));
    }
    stack
        .dfs
        .create("/lustre/scratch/pg-sales/part-0", text.as_bytes())
        .unwrap();
    stack
        .dfs
        .create(
            "/lustre/scratch/pg-regions/part-0",
            b"wales,UK\nengland,UK\n",
        )
        .unwrap();
    let script = "
        sales   = LOAD '/lustre/scratch/pg-sales' USING ',' AS (region, product, amount);
        regions = LOAD '/lustre/scratch/pg-regions' USING ',' AS (region, country);
        j   = JOIN sales BY region, regions BY region;
        big = FILTER j BY amount > 100;
        srt = ORDER big BY amount DESC;
        top = LIMIT srt 5;
        STORE top INTO '/lustre/scratch/pg-top';
    ";
    let id = stack
        .submit(
            4,
            "ana",
            AppPayload::Query {
                engine: "pig".into(),
                text: script.into(),
                reduces: 2,
            },
        )
        .unwrap();
    let result = stack.run_to_completion(id, 20).unwrap().clone();
    assert_eq!(result.kind, "query");
    assert_eq!(result.records, 5, "LIMIT 5");
    let rows: Vec<String> = read_parts(&stack.dfs, "/lustre/scratch/pg-top")
        .lines()
        .map(str::to_string)
        .collect();
    assert_eq!(rows.len(), 5);
    // Descending amounts, and the top row is the global maximum (369).
    let amounts: Vec<u64> = rows
        .iter()
        .map(|r| r.split('\t').nth(2).unwrap().parse().unwrap())
        .collect();
    assert!(amounts.windows(2).all(|w| w[0] >= w[1]), "{amounts:?}");
    assert_eq!(amounts[0], 50 + 29 * 11);
    // Per-stage counters present: s0 = join, s1 = sort. The tiny
    // regions table is under the broadcast threshold, so the cost rule
    // makes stage 0 a map-only broadcast-hash join — it ships the build
    // side (BROADCAST_BYTES), not a shuffle.
    assert!(counter(&result, "s0.BROADCAST_BYTES").is_some_and(|v| v > 0));
    assert_eq!(counter(&result, "s0.SHUFFLE_BYTES"), None);
    assert!(counter(&result, "s1.SHUFFLE_BYTES").is_some_and(|v| v > 0));
    // Planner counters ride along in the merged set: the FILTER fused
    // into the join stage and its predicate pushed below the join.
    assert!(counter(&result, "STAGES_FUSED").is_some_and(|v| v >= 1));
    assert!(counter(&result, "PREDICATE_PUSHDOWNS").is_some_and(|v| v >= 1));
    // Intermediates were deleted after success.
    assert!(!stack.dfs.exists("/lustre/scratch/pg-top.stage0"));
    assert!(stack.dfs.exists("/lustre/scratch/pg-top/_SUCCESS"));
}

/// PR 6 regression (satellite b): a filter → project → join chain fuses
/// into the join stage itself, so the query runs as ONE map-only job,
/// the per-stage counters renumber contiguously from `s0.`, and no
/// orphan `.stage{i}` intermediate is ever created.
#[test]
fn fusion_collapses_pipeline_and_leaves_no_orphan_intermediates() {
    let _env = EnvGuard::lock();
    let mut stack = Stack::new(StackConfig::tiny()).unwrap();
    stack.dfs.mkdirs("/lustre/scratch/fu-sales").unwrap();
    stack.dfs.mkdirs("/lustre/scratch/fu-regions").unwrap();
    let mut text = String::new();
    for i in 0..20u64 {
        let region = ["wales", "england"][(i % 2) as usize];
        text.push_str(&format!("{region},p{i},{}\n", 90 + i * 10));
    }
    stack
        .dfs
        .create("/lustre/scratch/fu-sales/part-0", text.as_bytes())
        .unwrap();
    stack
        .dfs
        .create("/lustre/scratch/fu-regions/part-0", b"wales,UK\nengland,EN\n")
        .unwrap();
    let script = "
        sales   = LOAD '/lustre/scratch/fu-sales' USING ',' AS (region, product, amount);
        regions = LOAD '/lustre/scratch/fu-regions' USING ',' AS (region, country);
        j   = JOIN sales BY region, regions BY region;
        big = FILTER j BY amount > 100;
        out = FOREACH big GENERATE country, amount;
        STORE out INTO '/lustre/scratch/fu-out';
    ";
    let id = stack
        .submit(
            4,
            "ana",
            AppPayload::Query {
                engine: "pig".into(),
                text: script.into(),
                reduces: 2,
            },
        )
        .unwrap();
    let result = stack.run_to_completion(id, 20).unwrap().clone();
    // Naive lowering is Join, Select(filter), Select(project): the two
    // Selects fold into the join's map phase and the filter pushes below
    // the join, leaving a single broadcast (map-only) stage.
    assert_eq!(counter(&result, "STAGES_FUSED"), Some(2));
    assert_eq!(counter(&result, "PREDICATE_PUSHDOWNS"), Some(1));
    assert!(result.counters.iter().any(|(k, _)| k.starts_with("s0.")));
    assert!(
        !result
            .counters
            .iter()
            .any(|(k, _)| k.starts_with("s1.") || k.starts_with("s2.")),
        "fused stages must renumber contiguously: {:?}",
        result.counters
    );
    assert!(counter(&result, "s0.BROADCAST_BYTES").is_some_and(|v| v > 0));
    // No orphan intermediate directory under any pre-fusion number.
    for i in 0..3 {
        assert!(
            !stack.dfs.exists(&format!("/lustre/scratch/fu-out.stage{i}")),
            "orphan intermediate .stage{i} left behind"
        );
    }
    assert!(stack.dfs.exists("/lustre/scratch/fu-out/_SUCCESS"));
    // Output is the filtered projection: amounts 110..280 survive.
    let mut got: Vec<String> = read_parts(&stack.dfs, "/lustre/scratch/fu-out")
        .lines()
        .map(str::to_string)
        .collect();
    got.sort();
    let mut want: Vec<String> = (2..20u64)
        .map(|i| {
            let country = ["UK", "EN"][(i % 2) as usize];
            format!("{country}\t{}", 90 + i * 10)
        })
        .collect();
    want.sort();
    assert_eq!(got, want);
    assert_eq!(result.records, 18);
}

/// PR 6 acceptance: the broadcast-hash join and the repartition fallback
/// (`HPCW_BROADCAST_MAX_BYTES=0`) produce byte-identical query output,
/// and broadcasting kills the join stage's shuffle entirely.
#[test]
fn broadcast_and_repartition_joins_are_byte_identical() {
    let env = EnvGuard::lock();
    let mut stack = Stack::new(StackConfig::tiny()).unwrap();
    stack.dfs.mkdirs("/lustre/scratch/br-sales").unwrap();
    stack.dfs.mkdirs("/lustre/scratch/br-regions").unwrap();
    let mut text = String::new();
    for i in 0..40u64 {
        // Unique amounts: the ORDER BY output is a deterministic total
        // order, so the runs compare byte for byte. 'norge' has no
        // regions row and is dropped by the inner join.
        let region = ["wales", "england", "norge"][(i % 3) as usize];
        text.push_str(&format!("{region},p{i},{}\n", 500 + i * 100));
    }
    stack
        .dfs
        .create("/lustre/scratch/br-sales/part-0", text.as_bytes())
        .unwrap();
    stack
        .dfs
        .create("/lustre/scratch/br-regions/part-0", b"wales,UK\nengland,EN\n")
        .unwrap();
    let run = |stack: &mut Stack, out: &str| {
        let sql = format!(
            "SELECT country, amount FROM '/lustre/scratch/br-sales' USING ',' \
             SCHEMA (region, product, amount) \
             JOIN '/lustre/scratch/br-regions' USING ',' \
             SCHEMA (region, country) ON region = region \
             WHERE amount > 1000 \
             ORDER BY amount DESC \
             INTO '{out}'"
        );
        let id = stack
            .submit(
                4,
                "sid",
                AppPayload::Query {
                    engine: "hive".into(),
                    text: sql,
                    reduces: 2,
                },
            )
            .unwrap();
        stack.run_to_completion(id, 20).unwrap().clone()
    };

    let broadcast = run(&mut stack, "/lustre/scratch/br-bcast");
    env.set("HPCW_BROADCAST_MAX_BYTES", "0");
    let repart = run(&mut stack, "/lustre/scratch/br-repart");
    env.clear("HPCW_BROADCAST_MAX_BYTES");

    assert_eq!(
        read_parts(&stack.dfs, "/lustre/scratch/br-bcast"),
        read_parts(&stack.dfs, "/lustre/scratch/br-repart"),
        "join strategy must never change query bytes"
    );
    // Broadcast: the join stage is map-only — no shuffle at all, the
    // build side ships once per job via BROADCAST_BYTES.
    assert!(counter(&broadcast, "s0.BROADCAST_BYTES").is_some_and(|v| v > 0));
    assert_eq!(counter(&broadcast, "s0.SHUFFLE_BYTES"), None);
    // Repartition: the join stage shuffles both tagged inputs.
    assert!(counter(&repart, "s0.SHUFFLE_BYTES").is_some_and(|v| v > 0));
    assert_eq!(counter(&repart, "s0.BROADCAST_BYTES"), None);
    let total = |r: &hpcw::api::AppResult| {
        counter(r, "s0.SHUFFLE_BYTES").unwrap_or(0) + counter(r, "s1.SHUFFLE_BYTES").unwrap_or(0)
    };
    assert!(
        total(&broadcast) < total(&repart),
        "broadcast must cut total shuffle bytes: {} vs {}",
        total(&broadcast),
        total(&repart)
    );
}

/// PR 6 property (satellite c): over random tables, every optimizer
/// configuration — fused+broadcast (default), fused+repartition,
/// naive+broadcast, and naive+repartition (exactly the PR 5 plans) —
/// produces byte-identical query output.
#[test]
fn prop_optimizer_configurations_are_byte_identical() {
    let env = EnvGuard::lock();
    props(5, |g| {
        let mut stack = Stack::new(StackConfig::tiny()).unwrap();
        stack.dfs.mkdirs("/lustre/scratch/po-sales").unwrap();
        stack.dfs.mkdirs("/lustre/scratch/po-regions").unwrap();
        let keys = ["wales", "england", "bayern", "norge", "alba"];
        let n = g.usize(12..48);
        let mut text = String::new();
        for i in 0..n as u64 {
            // i*1000 + jitter < 1000 keeps amounts unique: the sorted
            // output is a deterministic total order.
            let region = keys[g.usize(0..keys.len())];
            text.push_str(&format!("{region},p{i},{}\n", 100 + i * 1000 + g.u64(0..1000)));
        }
        stack
            .dfs
            .create("/lustre/scratch/po-sales/part-0", text.as_bytes())
            .unwrap();
        // Two of the five regions have no country row (inner-join drops).
        stack
            .dfs
            .create(
                "/lustre/scratch/po-regions/part-0",
                b"wales,UK\nengland,UK\nbayern,DE\n",
            )
            .unwrap();
        let cutoff = g.u64(0..(n as u64 * 500));
        let configs: &[(&str, Option<&str>, Option<&str>)] = &[
            ("default", None, None),
            ("repart", Some("0"), None),
            ("naive", None, Some("0")),
            ("pr5", Some("0"), Some("0")),
        ];
        let mut outputs = Vec::new();
        for (tag, bcast, fusion) in configs {
            match bcast {
                Some(v) => env.set("HPCW_BROADCAST_MAX_BYTES", v),
                None => env.clear("HPCW_BROADCAST_MAX_BYTES"),
            }
            match fusion {
                Some(v) => env.set("HPCW_FUSION", v),
                None => env.clear("HPCW_FUSION"),
            }
            let out = format!("/lustre/scratch/po-out-{tag}");
            let script = format!(
                "sales   = LOAD '/lustre/scratch/po-sales' USING ',' AS (region, product, amount);
                 regions = LOAD '/lustre/scratch/po-regions' USING ',' AS (region, country);
                 j   = JOIN sales BY region, regions BY region;
                 big = FILTER j BY amount > {cutoff};
                 prj = FOREACH big GENERATE region, country, amount;
                 srt = ORDER prj BY amount DESC;
                 STORE srt INTO '{out}';"
            );
            let id = stack
                .submit(
                    4,
                    "prop",
                    AppPayload::Query {
                        engine: "pig".into(),
                        text: script,
                        reduces: 2,
                    },
                )
                .unwrap();
            stack.run_to_completion(id, 30).unwrap();
            outputs.push((*tag, read_parts(&stack.dfs, &out)));
        }
        env.clear("HPCW_BROADCAST_MAX_BYTES");
        env.clear("HPCW_FUSION");
        let (_, reference) = &outputs[0];
        for (tag, bytes) in &outputs[1..] {
            assert_eq!(
                bytes, reference,
                "optimizer config '{tag}' changed the query bytes"
            );
        }
    });
}

/// Iteration multiplier for the CI chaos step (`HPCW_CHAOS=1`).
fn chaos_iters(base: u64) -> u64 {
    if std::env::var("HPCW_CHAOS").is_ok() {
        base * 4
    } else {
        base
    }
}

/// PR 6 chaos (satellite c): the broadcast hash table is loaded once
/// before the map phase, so failed map attempts and a mid-job node loss
/// (with batch-allocator replacement) re-execute against the same
/// broadcast state and the join output stays byte-identical.
#[test]
fn chaos_broadcast_join_survives_map_reexecution_and_node_loss() {
    let _env = EnvGuard::lock();
    props(chaos_iters(2), |g| {
        let (cfg, fs, mut dc, pool) = engine_fixture();
        fs.mkdirs("/lustre/scratch/cb-sales").unwrap();
        fs.mkdirs("/lustre/scratch/cb-regions").unwrap();
        let mut text = String::new();
        for i in 0..150u64 {
            let region = ["wales", "england", "bayern"][(i % 3) as usize];
            text.push_str(&format!("{region},p{i},{}\n", 10 + i * 13));
        }
        fs.create("/lustre/scratch/cb-sales/part-0", text.as_bytes())
            .unwrap();
        fs.create(
            "/lustre/scratch/cb-regions/part-0",
            b"wales,UK\nengland,UK\nbayern,DE\n",
        )
        .unwrap();
        let stage = |out: &str| {
            let script = format!(
                "sales   = LOAD '/lustre/scratch/cb-sales' USING ',' AS (region, product, amount);
                 regions = LOAD '/lustre/scratch/cb-regions' USING ',' AS (region, country);
                 j   = JOIN sales BY region, regions BY region;
                 big = FILTER j BY amount > 100;
                 prj = FOREACH big GENERATE country, amount;
                 STORE prj INTO '{out}';"
            );
            let plan = parse_query_text("pig", &script, 2).unwrap();
            let (stages, _) = plan.optimized_stages().unwrap();
            assert_eq!(stages.len(), 1, "filter+project fuse into the join");
            let mut spec = stages[0].compile(&*fs).unwrap();
            assert_eq!(spec.name, "query-join-broadcast");
            spec.split_bytes = 512; // several maps: retries have targets
            spec
        };

        // Reference: a clean run on the same cluster.
        let ref_outcome = {
            let spec = stage("/lustre/scratch/cb-ref");
            let mut engine = MrEngine::new(
                &mut dc,
                fs.clone() as Arc<dyn Dfs>,
                &pool,
                cfg.yarn.map_memory_mb,
                cfg.yarn.reduce_memory_mb,
            );
            engine.run(Arc::new(spec), "chaos", Micros::ZERO).unwrap()
        };
        assert!(ref_outcome.counters.get(counters::BROADCAST_BYTES) > 0);
        let n_maps = ref_outcome.maps;

        // Chaos run: two random first attempts fail AND the host of a
        // committed map dies once one map has committed; the batch
        // allocator delivers a replacement node.
        let mut spec = stage("/lustre/scratch/cb-chaos");
        spec.failures = FailurePlan::none()
            .fail_attempt(TaskId::map(g.u32(0..n_maps)), 0)
            .fail_attempt(TaskId::map(g.u32(0..n_maps)), 0);
        let cm = ClusterManager::new(
            ElasticConfig {
                nodes_min: 3,
                nodes_max: 8,
                queue_delay_ms: 20,
                lease_walltime_s: 3_600,
                nm_timeout_ms: 3_000,
                ..Default::default()
            },
            (100..104).map(NodeId).collect(),
        );
        let plan = ElasticPlan::new()
            .at_maps(1, ElasticAction::FailMapHost(g.u32(0..n_maps)));
        let outcome = {
            let mut engine = MrEngine::new(
                &mut dc,
                fs.clone() as Arc<dyn Dfs>,
                &pool,
                cfg.yarn.map_memory_mb,
                cfg.yarn.reduce_memory_mb,
            )
            .with_cluster_manager(cm)
            .with_plan(plan);
            engine.run(Arc::new(spec), "chaos", Micros::ZERO).unwrap()
        };
        assert!(outcome.counters.get(counters::BROADCAST_BYTES) > 0);
        assert_eq!(
            read_parts(&fs, "/lustre/scratch/cb-ref"),
            read_parts(&fs, "/lustre/scratch/cb-chaos"),
            "broadcast state must survive map re-execution and node loss"
        );
    });
}

/// PR 6 golden file (satellite a): EXPLAIN for a Pig JOIN / FILTER /
/// ORDER / LIMIT plan, pinned byte-exact. The staged inputs have fixed
/// sizes (sales 40 B, regions 20 B), so the cost rule's strategy and
/// `est_input_bytes` are deterministic.
#[test]
fn explain_pig_plan_matches_golden_file() {
    let _env = EnvGuard::lock();
    let stack = Stack::new(StackConfig::tiny()).unwrap();
    stack.dfs.mkdirs("/lustre/scratch/gx-sales").unwrap();
    stack.dfs.mkdirs("/lustre/scratch/gx-regions").unwrap();
    stack
        .dfs
        .create(
            "/lustre/scratch/gx-sales/part-0",
            b"wales,p1,150\nengland,p2,90\nwales,p3,200\n",
        )
        .unwrap();
    stack
        .dfs
        .create("/lustre/scratch/gx-regions/part-0", b"wales,UK\nengland,UK\n")
        .unwrap();
    assert_eq!(stack.dfs.size("/lustre/scratch/gx-sales/part-0").unwrap(), 40);
    assert_eq!(stack.dfs.size("/lustre/scratch/gx-regions/part-0").unwrap(), 20);
    let script = "
        sales   = LOAD '/lustre/scratch/gx-sales' USING ',' AS (region, product, amount);
        regions = LOAD '/lustre/scratch/gx-regions' USING ',' AS (region, country);
        j   = JOIN sales BY region, regions BY region;
        big = FILTER j BY amount > 100;
        srt = ORDER big BY amount DESC;
        top = LIMIT srt 5;
        STORE top INTO '/lustre/scratch/gx-top';
    ";
    let doc = stack.explain_query("pig", script, 2).unwrap();
    assert_eq!(
        doc.pretty(),
        include_str!("golden/explain_pig.json").trim_end(),
        "EXPLAIN(pig) drifted from the golden file"
    );
}

/// PR 6 golden file (satellite a): EXPLAIN for a Hive WHERE / GROUP BY /
/// ORDER BY plan — the filter fuses into the aggregation's map phase.
#[test]
fn explain_hive_plan_matches_golden_file() {
    let _env = EnvGuard::lock();
    let stack = Stack::new(StackConfig::tiny()).unwrap();
    stack.dfs.mkdirs("/lustre/scratch/gx-sales").unwrap();
    stack
        .dfs
        .create(
            "/lustre/scratch/gx-sales/part-0",
            b"wales,p1,150\nengland,p2,90\nwales,p3,200\n",
        )
        .unwrap();
    let sql = "SELECT region, SUM(amount) FROM '/lustre/scratch/gx-sales' USING ',' \
               SCHEMA (region, product, amount) \
               WHERE amount > 100 \
               GROUP BY region \
               ORDER BY sum_amount DESC \
               INTO '/lustre/scratch/gx-agg'";
    let doc = stack.explain_query("hive", sql, 2).unwrap();
    assert_eq!(
        doc.pretty(),
        include_str!("golden/explain_hive.json").trim_end(),
        "EXPLAIN(hive) drifted from the golden file"
    );
}

/// PR 10: the CI scheduler matrix drives the engine through
/// `HPCW_SPECULATION`; every token the workflow exports must select the
/// mode it names (and restore cleanly — the guard serializes env access).
#[test]
fn speculation_env_knob_selects_mode() {
    use hpcw::config::SpeculationMode;
    let env = EnvGuard::lock();
    let mode = |v: &str| {
        env.set("HPCW_SPECULATION", v);
        let mut e = ElasticConfig::default();
        e.apply_env();
        e.speculation
    };
    assert_eq!(mode("adaptive"), SpeculationMode::Adaptive);
    assert_eq!(mode("static"), SpeculationMode::Static);
    assert_eq!(mode("off"), SpeculationMode::Off);
    // Pre-mode boolean spellings keep their historical meaning.
    assert_eq!(mode("1"), SpeculationMode::Static);
    assert_eq!(mode("true"), SpeculationMode::Static);
    assert_eq!(mode("0"), SpeculationMode::Off);
    assert_eq!(mode("false"), SpeculationMode::Off);
    // Unset leaves the configured default (static) alone.
    env.clear("HPCW_SPECULATION");
    let mut e = ElasticConfig::default();
    e.apply_env();
    assert_eq!(e.speculation, SpeculationMode::Static);
}

/// PR 10: `HPCW_NODE_MIPS` installs per-node performance profiles; the
/// resulting config passes validation and survives into a stack's
/// cluster model (what `GET /v1/cluster` reports).
#[test]
fn node_mips_env_knob_installs_profiles() {
    let env = EnvGuard::lock();
    env.set("HPCW_NODE_MIPS", "0:250, 3:2000 ,junk");
    let mut e = ElasticConfig::default();
    e.apply_env();
    assert_eq!(e.node_mips, vec![(0, 250), (3, 2000)]);
    e.validate().unwrap();

    let mut cfg = StackConfig::tiny();
    cfg.elastic.node_mips = e.node_mips.clone();
    let stack = Stack::new(cfg).unwrap();
    let doc = stack.cluster_doc();
    assert_eq!(doc.nodes[0].mips, 250);
    assert_eq!(doc.nodes[3].mips, 2000);
    assert_eq!(doc.nodes[1].mips, 1000);
}
