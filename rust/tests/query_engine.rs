//! Multi-stage query engine integration (PR 5 acceptance):
//!
//! * a Hive query with JOIN + ORDER BY runs end to end over the API as a
//!   workflow of ≥ 2 chained MR jobs, and its totally-ordered output is
//!   validated **row for row** against a single-threaded reference
//!   evaluation;
//! * the map-side combiner leaves aggregation output byte-identical
//!   while strictly reducing the `SHUFFLE_BYTES` counter (also asserted
//!   as a property over random integer tables);
//! * Pig's JOIN / ORDER / LIMIT pipeline runs as chained jobs on one
//!   dynamic cluster via the `query` payload, with per-stage counters.

use hpcw::api::{ApiClient, ApiServer, AppPayload, Stack};
use hpcw::api::wire::StepState;
use hpcw::cluster::NodeId;
use hpcw::config::StackConfig;
use hpcw::frameworks::plan::StageKind;
use hpcw::lustre::{Dfs, LustreFs};
use hpcw::mapreduce::MrEngine;
use hpcw::metrics::Metrics;
use hpcw::testkit::props;
use hpcw::util::ids::IdGen;
use hpcw::util::pool::Pool;
use hpcw::util::time::Micros;
use hpcw::wrapper::DynamicCluster;
use std::sync::Arc;
use std::time::Duration;

/// Concatenate a query's output parts in partition-file order (which is
/// global order for sort stages).
fn read_parts(dfs: &LustreFs, dir: &str) -> String {
    let mut files: Vec<String> = dfs
        .list(dir)
        .into_iter()
        .filter(|p| p.contains("/part-"))
        .collect();
    files.sort();
    let mut text = String::new();
    for f in &files {
        text.push_str(&String::from_utf8(dfs.read(f).unwrap()).unwrap());
    }
    text
}

/// The acceptance test: JOIN + ORDER BY over the v1 wire, executed as a
/// workflow DAG — one `query_stage` LSF job per MR stage — with the
/// final output validated row for row against a reference evaluation.
#[test]
fn hive_join_order_by_runs_as_chained_workflow_jobs() {
    let stack = Stack::new(StackConfig::tiny()).unwrap();
    let dfs = stack.dfs.clone();

    // Tables: sales(region, product, amount) and regions(region, country).
    // Amounts are unique so the total order is deterministic and the
    // row-for-row comparison is exact. 'norge' has no region row (inner
    // join drops it); amounts <= 100 are filtered by WHERE.
    let regions: &[(&str, &str)] =
        &[("wales", "UK"), ("england", "UK"), ("bayern", "DE"), ("ireland", "IE")];
    let mut sales: Vec<(String, String, u64)> = Vec::new();
    for i in 0..60u64 {
        let region = ["wales", "england", "bayern", "norge"][(i % 4) as usize];
        sales.push((region.to_string(), format!("p{i:02}"), 40 + i * 7));
    }
    dfs.mkdirs("/lustre/scratch/qe-sales").unwrap();
    dfs.mkdirs("/lustre/scratch/qe-regions").unwrap();
    // Two part files per table: the join must merge across files.
    for (part, chunk) in sales.chunks(30).enumerate() {
        let text: String = chunk
            .iter()
            .map(|(r, p, a)| format!("{r},{p},{a}\n"))
            .collect();
        dfs.create(
            &format!("/lustre/scratch/qe-sales/part-{part}"),
            text.as_bytes(),
        )
        .unwrap();
    }
    let rtext: String = regions.iter().map(|(r, c)| format!("{r},{c}\n")).collect();
    dfs.create("/lustre/scratch/qe-regions/part-0", rtext.as_bytes())
        .unwrap();

    // Reference evaluation (single-threaded): inner join, filter, total
    // order by amount descending.
    let mut expected: Vec<(u64, String)> = Vec::new();
    for (r, p, a) in &sales {
        if *a <= 100 {
            continue;
        }
        for (rr, c) in regions {
            if rr == r {
                expected.push((*a, format!("{r}\t{p}\t{a}\t{rr}\t{c}")));
            }
        }
    }
    expected.sort_by(|x, y| y.0.cmp(&x.0));
    let expected: Vec<String> = expected.into_iter().map(|(_, row)| row).collect();
    assert!(expected.len() > 20, "test data must survive the filter");

    let server = ApiServer::start(stack).unwrap();
    let client = ApiClient::new(&server.addr);
    let sql = "SELECT * FROM '/lustre/scratch/qe-sales' USING ',' \
               SCHEMA (region, product, amount) \
               JOIN '/lustre/scratch/qe-regions' USING ',' \
               SCHEMA (region, country) ON region = region \
               WHERE amount > 100 \
               ORDER BY amount DESC \
               INTO '/lustre/scratch/qe-top'";
    let wf = client
        .submit_query("hive", sql, 3, 6, "sid", true)
        .unwrap();
    let doc = client.wait_workflow(wf, Duration::from_secs(60)).unwrap();
    assert!(doc.complete, "doc={doc:?}");
    assert!(
        doc.steps.len() >= 2,
        "JOIN + ORDER BY must compile to >= 2 chained MR jobs, got {}",
        doc.steps.len()
    );
    for s in &doc.steps {
        assert_eq!(s.state, StepState::Done);
        assert!(s.job.is_some(), "every stage ran as its own LSF job");
    }
    // Steps chained: each later step consumed its predecessor's output.
    assert_eq!(
        doc.steps.last().unwrap().output_dir.as_deref(),
        Some("/lustre/scratch/qe-top")
    );

    // Row-for-row validation against the reference: concatenating the
    // sort stage's parts in partition order IS the total order.
    let got: Vec<String> = read_parts(&dfs, "/lustre/scratch/qe-top")
        .lines()
        .map(|l| l.to_string())
        .collect();
    assert_eq!(got, expected, "distributed result must match the reference");
}

fn engine_fixture() -> (StackConfig, Arc<LustreFs>, DynamicCluster, Pool) {
    let cfg = StackConfig::tiny();
    let fs = Arc::new(LustreFs::new(&cfg.lustre, &cfg.cluster));
    let nodes: Vec<NodeId> = (0..6).map(NodeId).collect();
    let dc = DynamicCluster::build(
        &cfg,
        &nodes,
        &*fs,
        Arc::new(IdGen::default()),
        Arc::new(Metrics::new()),
        "query-engine-test",
        Micros::ZERO,
    )
    .unwrap();
    (cfg, fs, dc, Pool::new(4))
}

/// Combiner acceptance: the aggregation stage run with and without its
/// combiner produces byte-identical output, and the combiner run ships
/// strictly fewer `SHUFFLE_BYTES`.
#[test]
fn combiner_is_invisible_in_output_but_cuts_shuffle_bytes() {
    let (cfg, fs, mut dc, pool) = engine_fixture();
    fs.mkdirs("/lustre/scratch/qc-in").unwrap();
    let mut text = String::new();
    for i in 0..400u64 {
        let region = ["wales", "england", "bayern", "alba", "eire"][(i % 5) as usize];
        text.push_str(&format!("{region},p{},{}\n", i % 7, 10 + (i % 97)));
    }
    fs.create("/lustre/scratch/qc-in/part-0", text.as_bytes()).unwrap();

    let run = |dc: &mut DynamicCluster, combine: bool, out: &str| {
        let plan = hpcw::api::parse_query_text(
            "hive",
            &format!(
                "SELECT region, SUM(amount), COUNT(amount), MIN(amount), MAX(amount) \
                 FROM '/lustre/scratch/qc-in' USING ',' \
                 SCHEMA (region, product, amount) GROUP BY region INTO '{out}'"
            ),
            3,
        )
        .unwrap();
        let stages = plan.compile_stages().unwrap();
        assert_eq!(stages.len(), 1);
        assert_eq!(stages[0].kind, StageKind::Agg);
        let mut spec = stages[0].compile(&*fs).unwrap();
        // Split small so several maps spill several runs each.
        spec.split_bytes = 1024;
        if !combine {
            spec.combiner = None;
        }
        let mut engine = MrEngine::new(
            dc,
            fs.clone() as Arc<dyn Dfs>,
            &pool,
            cfg.yarn.map_memory_mb,
            cfg.yarn.reduce_memory_mb,
        );
        engine.run(Arc::new(spec), "u", Micros::ZERO).unwrap()
    };

    let off = run(&mut dc, false, "/lustre/scratch/qc-off");
    let on = run(&mut dc, true, "/lustre/scratch/qc-on");

    // Byte-identical result (integer inputs: partial merging is exact).
    assert_eq!(
        read_parts(&fs, "/lustre/scratch/qc-off"),
        read_parts(&fs, "/lustre/scratch/qc-on"),
        "combiner must not change the query result"
    );
    let sb_off = off.counters.get("SHUFFLE_BYTES");
    let sb_on = on.counters.get("SHUFFLE_BYTES");
    assert!(
        sb_on < sb_off,
        "combiner must strictly cut shuffle bytes: on={sb_on} off={sb_off}"
    );
    assert!(on.counters.get("COMBINE_INPUT_RECORDS") > on.counters.get("COMBINE_OUTPUT_RECORDS"));
    assert_eq!(off.counters.get("COMBINE_INPUT_RECORDS"), 0);
}

/// Property: for random integer tables, combiner-on and combiner-off
/// aggregation runs are byte-identical and the combiner never increases
/// shuffle bytes (strict decrease whenever keys repeat within a map).
#[test]
fn prop_combiner_parity_on_random_tables() {
    props(8, |g| {
        // Fresh filesystem + cluster per case: seeds replay cleanly.
        let (cfg, fs, mut dc, pool) = engine_fixture();
        let in_dir = "/lustre/scratch/qp-in".to_string();
        fs.mkdirs(&in_dir).unwrap();
        let n_rows = g.usize(40..200);
        let n_keys = g.usize(1..6);
        let mut text = String::new();
        for _ in 0..n_rows {
            // Integer amounts only: f64 partial sums stay exact, so the
            // byte-identity assertion is sound.
            text.push_str(&format!(
                "k{},{}\n",
                g.u32(0..n_keys as u32),
                g.u64(0..10_000)
            ));
        }
        fs.create(&format!("{in_dir}/part-0"), text.as_bytes()).unwrap();
        let mut outcomes = Vec::new();
        for combine in [false, true] {
            let out = format!("/lustre/scratch/qp-out-{combine}");
            let plan = hpcw::api::parse_query_text(
                "hive",
                &format!(
                    "SELECT key, SUM(amount), COUNT(amount) FROM '{in_dir}' USING ',' \
                     SCHEMA (key, amount) GROUP BY key INTO '{out}'"
                ),
                2,
            )
            .unwrap();
            let mut spec = plan.compile_stages().unwrap()[0].compile(&*fs).unwrap();
            spec.split_bytes = 512;
            if !combine {
                spec.combiner = None;
            }
            let mut engine = MrEngine::new(
                &mut dc,
                fs.clone() as Arc<dyn Dfs>,
                &pool,
                cfg.yarn.map_memory_mb,
                cfg.yarn.reduce_memory_mb,
            );
            let outcome = engine.run(Arc::new(spec), "u", Micros::ZERO).unwrap();
            outcomes.push((out, outcome));
        }
        let (off_dir, off) = &outcomes[0];
        let (on_dir, on) = &outcomes[1];
        assert_eq!(read_parts(&fs, off_dir), read_parts(&fs, on_dir));
        let (sb_off, sb_on) = (off.counters.get("SHUFFLE_BYTES"), on.counters.get("SHUFFLE_BYTES"));
        assert!(sb_on <= sb_off, "combiner must never grow the shuffle");
        if on.counters.get("COMBINE_OUTPUT_RECORDS") < on.counters.get("COMBINE_INPUT_RECORDS") {
            assert!(sb_on < sb_off, "folded records must shrink shuffle bytes");
        }
    });
}

/// Pig JOIN / ORDER / LIMIT through the `query` payload: the stage chain
/// runs on ONE dynamic cluster (one LSF job), intermediates are cleaned
/// up, and the result carries merged plus per-stage (`s{i}.`) counters.
#[test]
fn pig_join_order_limit_runs_on_one_cluster() {
    let mut stack = Stack::new(StackConfig::tiny()).unwrap();
    stack.dfs.mkdirs("/lustre/scratch/pg-sales").unwrap();
    stack.dfs.mkdirs("/lustre/scratch/pg-regions").unwrap();
    let mut text = String::new();
    for i in 0..30u64 {
        let region = ["wales", "england"][(i % 2) as usize];
        text.push_str(&format!("{region},p{i},{}\n", 50 + i * 11));
    }
    stack
        .dfs
        .create("/lustre/scratch/pg-sales/part-0", text.as_bytes())
        .unwrap();
    stack
        .dfs
        .create(
            "/lustre/scratch/pg-regions/part-0",
            b"wales,UK\nengland,UK\n",
        )
        .unwrap();
    let script = "
        sales   = LOAD '/lustre/scratch/pg-sales' USING ',' AS (region, product, amount);
        regions = LOAD '/lustre/scratch/pg-regions' USING ',' AS (region, country);
        j   = JOIN sales BY region, regions BY region;
        big = FILTER j BY amount > 100;
        srt = ORDER big BY amount DESC;
        top = LIMIT srt 5;
        STORE top INTO '/lustre/scratch/pg-top';
    ";
    let id = stack
        .submit(
            4,
            "ana",
            AppPayload::Query {
                engine: "pig".into(),
                text: script.into(),
                reduces: 2,
            },
        )
        .unwrap();
    let result = stack.run_to_completion(id, 20).unwrap().clone();
    assert_eq!(result.kind, "query");
    assert_eq!(result.records, 5, "LIMIT 5");
    let rows: Vec<String> = read_parts(&stack.dfs, "/lustre/scratch/pg-top")
        .lines()
        .map(str::to_string)
        .collect();
    assert_eq!(rows.len(), 5);
    // Descending amounts, and the top row is the global maximum (369).
    let amounts: Vec<u64> = rows
        .iter()
        .map(|r| r.split('\t').nth(2).unwrap().parse().unwrap())
        .collect();
    assert!(amounts.windows(2).all(|w| w[0] >= w[1]), "{amounts:?}");
    assert_eq!(amounts[0], 50 + 29 * 11);
    // Per-stage counters present: s0 = join, s1 = sort.
    assert!(result.counters.iter().any(|(k, _)| k == "s0.SHUFFLE_BYTES"));
    assert!(result.counters.iter().any(|(k, _)| k == "s1.SHUFFLE_BYTES"));
    // Intermediates were deleted after success.
    assert!(!stack.dfs.exists("/lustre/scratch/pg-top.stage0"));
    assert!(stack.dfs.exists("/lustre/scratch/pg-top/_SUCCESS"));
}
