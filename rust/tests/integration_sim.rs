//! Event-driven control-plane integration: the YARN daemons driven by the
//! `simx::Sim` engine (heartbeats, liveness, staggered NM registration) —
//! the Sim-mode twin of the wrapper's Real-mode daemon handling.

use hpcw::cluster::NodeId;
use hpcw::config::StackConfig;
use hpcw::metrics::Metrics;
use hpcw::simx::Sim;
use hpcw::util::ids::IdGen;
use hpcw::util::rng::Rng;
use hpcw::util::time::Micros;
use hpcw::wrapper::sim::sliding_window_makespan;
use hpcw::yarn::ResourceManager;
use std::sync::Arc;

struct World {
    rm: ResourceManager,
    registrations: Vec<(NodeId, Micros)>,
    heartbeats: u64,
}

#[test]
fn staggered_nm_registration_through_event_engine() {
    let cfg = StackConfig::paper();
    let mut sim: Sim<World> = Sim::new();
    let mut world = World {
        rm: ResourceManager::new(
            cfg.yarn.clone(),
            Arc::new(IdGen::default()),
            Arc::new(Metrics::new()),
        ),
        registrations: Vec::new(),
        heartbeats: 0,
    };

    // Wrapper model: 30 slaves boot with log-normal jitter and register
    // when up; each then heartbeats every nm_heartbeat_ms.
    let mut rng = Rng::new(42);
    let hb = Micros::ms(cfg.yarn.nm_heartbeat_ms);
    for i in 0..30u32 {
        let boot = Micros::from_secs_f64(rng.lognormal(1.5, 0.2));
        sim.at(boot, move |w: &mut World, s| {
            let node = NodeId(i);
            w.rm.register_nm(node, s.now()).unwrap();
            w.registrations.push((node, s.now()));
            // Recurring heartbeat (3 beats are enough for the test).
            for beat in 1..=3u64 {
                s.after(Micros(hb.0 * beat), move |w: &mut World, s| {
                    w.rm.nm_heartbeat(node, s.now()).unwrap();
                    w.heartbeats += 1;
                });
            }
        });
    }
    let end = sim.run(&mut world);

    assert_eq!(world.rm.nm_count(), 30);
    assert_eq!(world.heartbeats, 90);
    // Registrations happened at distinct, ordered times (event ordering).
    let times: Vec<Micros> = world.registrations.iter().map(|r| r.1).collect();
    let mut sorted = times.clone();
    sorted.sort();
    assert_eq!(times, sorted, "events fire in time order");
    // The run ends exactly 3 heartbeats after the slowest boot.
    let slowest = *times.last().unwrap();
    assert_eq!(end, slowest + Micros(hb.0 * 3));
    world.rm.check_invariants().unwrap();
}

#[test]
fn event_engine_agrees_with_sliding_window_closed_form() {
    // The fan-out window model used by Fig 3 can also be computed by the
    // event engine; both must agree (cross-validation of the Fig 3 math).
    struct W {
        done_at: Vec<f64>,
    }
    let durations: Vec<f64> = (0..25).map(|i| 1.0 + (i % 7) as f64 * 0.3).collect();
    let width = 4usize;

    // Event-driven version: `width` workers pull tasks from a queue.
    let mut sim: Sim<W> = Sim::new();
    let mut w = W { done_at: Vec::new() };
    let queue = std::rc::Rc::new(std::cell::RefCell::new(
        durations.iter().copied().rev().collect::<Vec<f64>>(),
    ));
    fn pull(
        q: std::rc::Rc<std::cell::RefCell<Vec<f64>>>,
        sim: &mut Sim<W>,
    ) {
        let next = q.borrow_mut().pop();
        if let Some(d) = next {
            sim.after(Micros::from_secs_f64(d), move |w: &mut W, s| {
                w.done_at.push(s.now().as_secs_f64());
                pull(q, s);
            });
        }
    }
    for _ in 0..width {
        let q = std::rc::Rc::clone(&queue);
        sim.at(Micros::ZERO, move |_w: &mut W, s| pull(q, s));
    }
    let end = sim.run(&mut w);

    let closed_form = sliding_window_makespan(&durations, width);
    assert!(
        (end.as_secs_f64() - closed_form).abs() < 1e-3,
        "event engine {} vs closed form {}",
        end.as_secs_f64(),
        closed_form
    );
    assert_eq!(w.done_at.len(), durations.len());
}
