//! v1 API integration: the event-driven access path end to end —
//! long-poll waits, the event journal, DAG workflows with output
//! chaining, path-traversal containment, API metrics, and the HTTP
//! layer under adversarial input and concurrency.

use hpcw::api::http::{request, request_full};
use hpcw::api::wire::{StepSpec, StepState, WorkflowSpec};
use hpcw::api::{ApiClient, ApiServer, AppPayload, Stack};
use hpcw::codec::json::Json;
use hpcw::config::StackConfig;
use hpcw::lustre::Dfs as _;
use hpcw::scheduler::JobState;
use std::io::Write as _;
use std::net::TcpStream;
use std::time::Duration;

fn server() -> (ApiServer, ApiClient) {
    let stack = Stack::new(StackConfig::tiny()).unwrap();
    let server = ApiServer::start(stack).unwrap();
    let client = ApiClient::new(&server.addr);
    (server, client)
}

fn teragen(dir: &str) -> AppPayload {
    AppPayload::Teragen {
        rows: 200,
        maps: 1,
        dir: dir.to_string(),
    }
}

fn metric(metrics: &str, name: &str) -> u64 {
    metrics
        .lines()
        .find_map(|l| {
            let l = l.strip_prefix("counter ")?;
            let (k, v) = l.split_once(" = ")?;
            (k.trim() == name).then(|| v.trim().parse().ok())?
        })
        .unwrap_or(0)
}

/// Acceptance: a wait over a queued-then-running job costs O(transitions)
/// HTTP requests — bounded by 3 — instead of O(time / 25 ms).
#[test]
fn wait_is_event_driven_not_polling() {
    let (_server, client) = server();
    // Two 8-node jobs on an 8-node cluster: the second queues behind the
    // first, so its wait spans PEND → RUN→DONE transitions.
    let _first = client
        .submit(8, "u", &teragen("/lustre/scratch/lp-a"))
        .unwrap();
    let second = client
        .submit(8, "u", &teragen("/lustre/scratch/lp-b"))
        .unwrap();
    let before = client.request_count();
    let doc = client.wait(second, Duration::from_secs(30)).unwrap();
    let wait_requests = client.request_count() - before;
    assert_eq!(doc.state, JobState::Done, "error={:?}", doc.error);
    assert!(
        wait_requests <= 3,
        "wait used {wait_requests} HTTP requests; long-poll should need ≤ 3"
    );
    // The server recorded the long poll and the journal growth.
    let m = client.metrics().unwrap();
    assert!(metric(&m, "api.long_poll_waits") >= 1, "{m}");
    assert!(metric(&m, "api.events_emitted") >= 2, "{m}");
}

/// Acceptance: a diamond DAG runs its middle steps concurrently and
/// chains outputs through `${steps.<name>.output_dir}`.
#[test]
fn diamond_workflow_runs_middles_concurrently_and_chains_outputs() {
    let stack = Stack::new(StackConfig::tiny()).unwrap();
    // Stage the source data the root step will aggregate.
    stack.dfs.mkdirs("/lustre/scratch/di-src").unwrap();
    stack
        .dfs
        .create(
            "/lustre/scratch/di-src/part-0",
            b"wales,200\nwales,300\nengland,50\nengland,75\n",
        )
        .unwrap();
    let server = ApiServer::start(stack).unwrap();
    let client = ApiClient::new(&server.addr);

    let hive = |sql: &str| AppPayload::HiveQuery {
        sql: sql.into(),
        reduces: 1,
    };
    let step = |name: &str, after: &[&str], payload: AppPayload| StepSpec {
        name: name.into(),
        after: after.iter().map(|s| s.to_string()).collect(),
        retries: 0,
        payload,
    };
    let spec = WorkflowSpec {
        name: "diamond".into(),
        user: "sid".into(),
        nodes: 4,
        steps: vec![
            step(
                "gen",
                &[],
                AppPayload::PigScript {
                    script: "
                        recs = LOAD '/lustre/scratch/di-src' USING ',' AS (region, amount);
                        grp  = GROUP recs BY region;
                        out  = FOREACH grp GENERATE group, SUM(amount);
                        STORE out INTO '/lustre/scratch/di-report';"
                        .into(),
                    reduces: 1,
                },
            ),
            // Both middles read gen's ACTUAL output dir via the wire
            // reference. (Pig report lines are tab-separated.)
            step(
                "left",
                &["gen"],
                hive("SELECT region, SUM(total) FROM '${steps.gen.output_dir}' USING '\t' \
                      SCHEMA (region, total) GROUP BY region INTO '/lustre/scratch/di-left'"),
            ),
            step(
                "right",
                &["gen"],
                hive("SELECT region, MAX(total) FROM '${steps.gen.output_dir}' USING '\t' \
                      SCHEMA (region, total) GROUP BY region INTO '/lustre/scratch/di-right'"),
            ),
            step(
                "join",
                &["left", "right"],
                hive("SELECT region, COUNT(total) FROM '${steps.left.output_dir}' USING '\t' \
                      SCHEMA (region, total) GROUP BY region INTO '/lustre/scratch/di-join'"),
            ),
        ],
    };
    let wf = client.submit_workflow(&spec).unwrap();
    let doc = client.wait_workflow(wf, Duration::from_secs(60)).unwrap();
    assert!(doc.complete, "doc={doc:?}");
    assert!(doc.steps.iter().all(|s| s.state == StepState::Done));
    // Output chaining recorded the real dirs.
    let dir_of = |n: &str| {
        doc.steps
            .iter()
            .find(|s| s.name == n)
            .unwrap()
            .output_dir
            .clone()
            .unwrap()
    };
    assert_eq!(dir_of("gen"), "/lustre/scratch/di-report");
    assert_eq!(dir_of("join"), "/lustre/scratch/di-join");

    // Concurrency proof from the journal: both middles were RUNNING
    // before either was DONE.
    let page = client.events(0, 0).unwrap();
    let seq_of = |step: &str, state: &str| {
        page.events
            .iter()
            .find(|e| {
                e.kind == "step"
                    && e.id == wf
                    && e.step.as_deref() == Some(step)
                    && e.state == state
            })
            .unwrap_or_else(|| panic!("no event {step}:{state} in {:?}", page.events))
            .seq
    };
    assert!(seq_of("left", "RUNNING") < seq_of("right", "DONE"));
    assert!(seq_of("right", "RUNNING") < seq_of("left", "DONE"));
    // And the workflow-level COMPLETE event landed.
    assert!(page
        .events
        .iter()
        .any(|e| e.kind == "workflow" && e.id == wf && e.state == "COMPLETE"));
}

/// Satellite: output reads are confined to the job's output root with
/// the stable `bad_path` code.
#[test]
fn output_path_traversal_rejected() {
    let (_server, client) = server();
    let job = client
        .submit(2, "sid", &teragen("/lustre/scratch/esc"))
        .unwrap();
    client.wait(job, Duration::from_secs(30)).unwrap();
    for bad in ["..", "../other", "a/../../etc", "/etc/passwd", "/lustre/scratch/other"] {
        let err = client.read_output(job, bad).unwrap_err().to_string();
        assert!(err.contains("bad_path"), "path {bad:?} gave: {err}");
    }
    // Legit reads still work, absolute and relative.
    assert!(client
        .read_output(job, "/lustre/scratch/esc/_SUCCESS")
        .is_ok());
    assert!(client.read_output(job, "_SUCCESS").is_ok());
    // A job with no result yet answers not_ready, not a read.
    let err = client.read_output(99_999, "x").unwrap_err().to_string();
    assert!(err.contains("not_found"), "{err}");
}

/// Satellite: adversarial HTTP input cannot wedge or crash the server.
#[test]
fn adversarial_http_input_is_survivable() {
    let (_server, client) = server();

    // 1. Truncated request line, connection dropped.
    {
        let mut s = TcpStream::connect(&client.addr).unwrap();
        s.write_all(b"POST /v1/jo").unwrap();
    }
    // 2. Oversized header block.
    {
        let mut s = TcpStream::connect(&client.addr).unwrap();
        let mut req = String::from("GET /v1/jobs HTTP/1.1\r\n");
        req.push_str(&format!("X-Big: {}\r\n\r\n", "a".repeat(64 * 1024)));
        s.write_all(req.as_bytes()).unwrap();
    }
    // 3. Non-UTF-8 body on a JSON route → bad_json envelope.
    let (status, body) = request(
        &client.addr,
        "POST",
        "/v1/jobs",
        Some(&[0xff, 0xfe, 0x00, 0x80]),
    )
    .unwrap();
    assert_eq!(status, 400);
    let doc = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
    assert_eq!(
        doc.get("error").and_then(|e| e.get("code")).and_then(Json::as_str),
        Some("bad_json")
    );
    // 4. Malformed JSON → bad_json.
    let (status, _body) =
        request(&client.addr, "POST", "/v1/jobs", Some(b"{\"nodes\": ")).unwrap();
    assert_eq!(status, 400);

    // The server still does real work afterwards.
    let job = client
        .submit(2, "sid", &teragen("/lustre/scratch/adv"))
        .unwrap();
    let doc = client.wait(job, Duration::from_secs(30)).unwrap();
    assert_eq!(doc.state, JobState::Done);
}

/// Satellite: N concurrent clients submitting + long-polling against one
/// server make progress with no deadlock on the pump lock.
#[test]
fn concurrent_clients_no_deadlock() {
    let (server, _client) = server();
    let addr = server.addr.clone();
    let threads: Vec<_> = (0..8)
        .map(|i| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let client = ApiClient::new(&addr);
                let job = client
                    .submit(
                        2,
                        &format!("user{i}"),
                        &teragen(&format!("/lustre/scratch/cc-{i}")),
                    )
                    .unwrap();
                let doc = client.wait(job, Duration::from_secs(60)).unwrap();
                assert_eq!(doc.state, JobState::Done, "error={:?}", doc.error);
                // Poll the rest of the surface while others run.
                client.list_jobs(0, 100).unwrap();
                client.events(0, 0).unwrap();
                client.metrics().unwrap();
                job
            })
        })
        .collect();
    let mut jobs: Vec<u64> = threads.into_iter().map(|t| t.join().unwrap()).collect();
    jobs.sort();
    jobs.dedup();
    assert_eq!(jobs.len(), 8, "all submissions got distinct ids");
}

/// Satellite: the API layer's own counters are visible in /v1/metrics.
#[test]
fn api_metrics_exposed_and_accurate() {
    let (_server, client) = server();
    let job = client
        .submit(2, "m", &teragen("/lustre/scratch/met"))
        .unwrap();
    client.wait(job, Duration::from_secs(30)).unwrap();
    client.list_jobs(0, 10).unwrap();
    client.events(0, 0).unwrap();
    let m = client.metrics().unwrap();
    // request_count tracks every HTTP call this client made; the server
    // must have seen at least those (the count includes this /v1/metrics
    // request itself, counted server-side before rendering).
    assert!(metric(&m, "api.requests") >= client.request_count() - 1, "{m}");
    for counter in [
        "api.requests.post_job",
        "api.requests.get_job",
        "api.requests.list_jobs",
        "api.requests.get_events",
        "api.latency_us.get_job",
        "api.events_emitted",
    ] {
        assert!(metric(&m, counter) >= 1, "missing {counter} in:\n{m}");
    }
}

/// Legacy unversioned paths answer 301 + Deprecation and never execute.
#[test]
fn legacy_paths_are_deprecation_answered() {
    let (_server, client) = server();
    for (method, path) in [
        ("GET", "/jobs"),
        ("POST", "/jobs"),
        ("GET", "/jobs/1"),
        ("GET", "/workflows/0"),
        ("POST", "/workflows"),
        ("GET", "/metrics"),
    ] {
        let (status, headers, _body) =
            request_full(&client.addr, method, path, Some(b"{}")).unwrap();
        assert_eq!(status, 301, "{method} {path}");
        assert_eq!(
            headers.get("location").map(String::as_str),
            Some(format!("/v1{path}").as_str())
        );
        assert_eq!(headers.get("deprecation").map(String::as_str), Some("true"));
    }
    // Nothing was submitted by the legacy POSTs.
    assert_eq!(client.list_jobs(0, 10).unwrap().total, 0);
}

/// A failing DAG step with retries exhausts its budget, skips dependents
/// and reports ABORTED through the API.
#[test]
fn workflow_failure_skips_dependents_over_api() {
    let (_server, client) = server();
    let spec = WorkflowSpec {
        name: "doomed".into(),
        user: "sid".into(),
        nodes: 2,
        steps: vec![
            StepSpec {
                name: "bad".into(),
                after: vec![],
                retries: 1,
                payload: AppPayload::HiveQuery {
                    sql: "SELECT COUNT(a) FROM '/lustre/scratch/nope' SCHEMA (a) INTO '/lustre/scratch/nope-out'".into(),
                    reduces: 1,
                },
            },
            StepSpec {
                name: "never".into(),
                after: vec!["bad".into()],
                retries: 0,
                payload: teragen("/lustre/scratch/never"),
            },
        ],
    };
    let wf = client.submit_workflow(&spec).unwrap();
    let doc = client.wait_workflow(wf, Duration::from_secs(30)).unwrap();
    assert!(doc.aborted && !doc.complete);
    let get = |n: &str| doc.steps.iter().find(|s| s.name == n).unwrap();
    assert_eq!(get("bad").state, StepState::Failed);
    assert_eq!(get("bad").attempts, 2, "one retry consumed");
    assert_eq!(get("never").state, StepState::Skipped);
    // Cyclic specs are rejected client-side and server-side alike.
    let cyclic = r#"{"name":"c","user":"u","nodes":2,"steps":[
        {"name":"a","after":["b"],"payload":{"type":"teragen","rows":1,"maps":1,"dir":"/x"}},
        {"name":"b","after":["a"],"payload":{"type":"teragen","rows":1,"maps":1,"dir":"/y"}}]}"#;
    let (status, body) = request(
        &client.addr,
        "POST",
        "/v1/workflows",
        Some(cyclic.as_bytes()),
    )
    .unwrap();
    assert_eq!(status, 400);
    assert!(String::from_utf8_lossy(&body).contains("cycle"));
}
