//! Property tests over the coordinator invariants (DESIGN.md §6):
//! randomized job streams, allocation churn, shuffle delivery, and the
//! YARN resource ledger, driven by the in-repo testkit.

use hpcw::cluster::{ClusterModel, NodeId};
use hpcw::config::StackConfig;
use hpcw::mapreduce::shuffle::{merge_to_recordbuf, Segment, ShuffleStore};
use hpcw::mapreduce::RecordBuf;
use hpcw::metrics::Metrics;
use hpcw::scheduler::{JobCommand, JobState, Lsf, ResourceRequest};
use hpcw::testkit::{props, Gen};
use hpcw::util::ids::{IdGen, LsfJobId};
use hpcw::util::time::Micros;
use hpcw::yarn::container::{ContainerKind, ContainerRequest, Resource};
use hpcw::yarn::rm::{AppState, ResourceManager};
use std::sync::Arc;

/// The scheduler never double-books nodes, never loses them, and every
/// terminal job ends with zero holdings — across arbitrary interleavings
/// of submit / dispatch / finish / kill / node-failure.
#[test]
fn scheduler_conserves_nodes_under_churn() {
    props(40, |g: &mut Gen| {
        let cfg = StackConfig::tiny();
        let cluster = ClusterModel::new(&cfg.cluster);
        let mut lsf = Lsf::new(
            cfg.scheduler.clone(),
            &cluster,
            Arc::new(IdGen::default()),
            Arc::new(Metrics::new()),
        );
        let mut live: Vec<LsfJobId> = Vec::new();
        let mut now = Micros::ZERO;
        for _ in 0..g.usize(5..60) {
            now += Micros::secs(1);
            match g.u32(0..10) {
                0..=3 => {
                    let nodes = g.u32(1..9);
                    if let Ok(id) = lsf.submit(
                        ResourceRequest::bigdata(nodes, &g.ident(5)),
                        JobCommand::plain(&["w"]),
                        now,
                    ) {
                        live.push(id);
                    }
                }
                4..=6 => {
                    lsf.dispatch_cycle(now);
                }
                7 => {
                    if !live.is_empty() {
                        let id = live[g.usize(0..live.len())];
                        if lsf.status(id).map(|j| j.state) == Some(JobState::Running) {
                            lsf.finish(id, now).unwrap();
                        }
                    }
                }
                8 => {
                    if !live.is_empty() {
                        let id = live[g.usize(0..live.len())];
                        let state = lsf.status(id).map(|j| j.state).unwrap();
                        if !state.is_terminal() {
                            lsf.kill(id, now).unwrap();
                        }
                    }
                }
                _ => {
                    let node = NodeId(g.u32(0..8));
                    let victims = lsf.node_failed(node);
                    for v in victims {
                        let _ = lsf.fail(v, now);
                    }
                }
            }
            lsf.check_invariants().expect("scheduler invariant");
        }
        // Drain: finish everything still running.
        for id in live {
            if lsf.status(id).map(|j| j.state) == Some(JobState::Running) {
                lsf.finish(id, now).unwrap();
            }
        }
        lsf.check_invariants().unwrap();
    });
}

/// The RM's per-node ledger equals the sum of outstanding containers at
/// every step of a random allocate/release/fail sequence, and app
/// completion always returns the ledger to zero.
#[test]
fn yarn_ledger_balances_under_churn() {
    props(40, |g: &mut Gen| {
        let n_nodes = g.u32(1..6);
        let mut rm = ResourceManager::new(
            StackConfig::paper().yarn.clone(),
            Arc::new(IdGen::default()),
            Arc::new(Metrics::new()),
        );
        for i in 0..n_nodes {
            rm.register_nm(NodeId(i), Micros::ZERO).unwrap();
        }
        let h = rm.submit_app("prop", "u", Micros::ZERO).unwrap();
        let mut held = Vec::new();
        for _ in 0..g.usize(1..25) {
            match g.u32(0..3) {
                0 => {
                    let got = rm
                        .allocate(
                            h.app,
                            ContainerRequest {
                                resource: Resource::new(g.u64(256..8192), 1),
                                count: g.u32(1..10),
                            },
                            ContainerKind::Map,
                            Micros::ZERO,
                        )
                        .unwrap();
                    held.extend(got);
                }
                1 => {
                    if !held.is_empty() {
                        let i = g.usize(0..held.len());
                        let c: hpcw::yarn::Container = held.swap_remove(i);
                        rm.release(h.app, c.id).unwrap();
                    }
                }
                _ => {
                    if g.chance(0.2) && rm.nm_count() > 1 {
                        let node = NodeId(g.u32(0..n_nodes));
                        let lost = rm.node_failed(node);
                        held.retain(|c| !lost.iter().any(|l| l.id == c.id));
                    }
                }
            }
            rm.check_invariants().expect("yarn ledger");
        }
        rm.finish_app(h.app, AppState::Finished, Micros::secs(1)).unwrap();
        let (_, used) = rm.cluster_resources();
        assert_eq!(used, Resource::zero());
    });
}

/// Shuffle delivery is exactly-once and merge output equals a flat sort,
/// under random segment commits including duplicate (speculative) commits.
#[test]
fn shuffle_exactly_once_and_merge_correct() {
    props(40, |g: &mut Gen| {
        let n_maps = g.u32(1..6);
        let n_parts = g.u32(1..5);
        let store = ShuffleStore::new();
        let mut expected: Vec<Vec<u8>> = Vec::new();
        for m in 0..n_maps {
            for p in 0..n_parts {
                let mut keys: Vec<u8> =
                    (0..g.usize(0..15)).map(|_| g.u32(0..40) as u8).collect();
                keys.sort_unstable();
                let seg = Segment {
                    map: m,
                    partition: p,
                    node: NodeId(m),
                    records: RecordBuf::from_pairs(
                        keys.iter().map(|&k| (vec![k], Vec::<u8>::new())),
                    ),
                };
                // Speculative duplicate commit sometimes.
                if g.chance(0.3) {
                    store.put(seg.clone());
                }
                store.put(seg);
                if p == 0 {
                    expected.extend(keys.iter().map(|&k| vec![k]));
                }
            }
        }
        store.verify_complete(n_maps, n_parts).unwrap();
        let segs = store.fetch_partition(0, n_maps).unwrap();
        let merged = merge_to_recordbuf(&segs);
        let mut keys: Vec<Vec<u8>> = merged.iter().map(|(k, _)| k.to_vec()).collect();
        expected.sort();
        keys.sort();
        assert_eq!(keys, expected);
    });
}

/// Terasort invariant at the unit level: for any random data, the range
/// partitioner is monotone and concatenated partition runs cover exactly
/// the input (used by Teravalidate's cross-part boundary check).
#[test]
fn range_partition_cover_property() {
    use hpcw::terasort::RangePartitioner;
    props(60, |g: &mut Gen| {
        let samples: Vec<u64> = (0..g.usize(2..300)).map(|_| g.u64(0..1 << 48)).collect();
        let parts = g.u32(1..64);
        let p = RangePartitioner::from_samples(samples, parts).unwrap();
        let keys: Vec<u64> = (0..200).map(|_| g.u64(0..1 << 48)).collect();
        let mut per_part: Vec<Vec<u64>> = vec![Vec::new(); p.n_partitions() as usize];
        for &k in &keys {
            per_part[p.route(k) as usize].push(k);
        }
        // Concatenating sorted partitions equals sorting everything.
        let mut concat = Vec::new();
        for part in &mut per_part {
            part.sort_unstable();
            concat.extend_from_slice(part);
        }
        let mut all = keys.clone();
        all.sort_unstable();
        assert_eq!(concat, all);
    });
}
