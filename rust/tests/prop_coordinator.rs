//! Property tests over the coordinator invariants (DESIGN.md §6):
//! randomized job streams, allocation churn, shuffle delivery, and the
//! YARN resource ledger, driven by the in-repo testkit.

use hpcw::cluster::{ClusterModel, NodeId};
use hpcw::config::StackConfig;
use hpcw::lustre::{Dfs, LustreFs};
use hpcw::mapreduce::shuffle::{merge_to_recordbuf, Segment, ShuffleStore};
use hpcw::mapreduce::{
    FailurePlan, HashPartitioner, InputFormat, JobSpec, Mapper, MrEngine, OutputFormat,
    RecordBuf, Reducer, SchedMode, TaskId,
};
use hpcw::metrics::Metrics;
use hpcw::scheduler::{JobCommand, JobState, Lsf, ResourceRequest};
use hpcw::testkit::{props, Gen};
use hpcw::util::ids::{IdGen, LsfJobId};
use hpcw::util::pool::Pool;
use hpcw::util::time::Micros;
use hpcw::wrapper::DynamicCluster;
use hpcw::yarn::container::{ContainerKind, ContainerRequest, Resource};
use hpcw::yarn::rm::{AppState, ResourceManager};
use std::collections::BTreeMap;
use std::sync::Arc;

/// The scheduler never double-books nodes, never loses them, and every
/// terminal job ends with zero holdings — across arbitrary interleavings
/// of submit / dispatch / finish / kill / node-failure.
#[test]
fn scheduler_conserves_nodes_under_churn() {
    props(40, |g: &mut Gen| {
        let cfg = StackConfig::tiny();
        let cluster = ClusterModel::new(&cfg.cluster);
        let mut lsf = Lsf::new(
            cfg.scheduler.clone(),
            &cluster,
            Arc::new(IdGen::default()),
            Arc::new(Metrics::new()),
        );
        let mut live: Vec<LsfJobId> = Vec::new();
        let mut now = Micros::ZERO;
        for _ in 0..g.usize(5..60) {
            now += Micros::secs(1);
            match g.u32(0..10) {
                0..=3 => {
                    let nodes = g.u32(1..9);
                    if let Ok(id) = lsf.submit(
                        ResourceRequest::bigdata(nodes, &g.ident(5)),
                        JobCommand::plain(&["w"]),
                        now,
                    ) {
                        live.push(id);
                    }
                }
                4..=6 => {
                    lsf.dispatch_cycle(now);
                }
                7 => {
                    if !live.is_empty() {
                        let id = live[g.usize(0..live.len())];
                        if lsf.status(id).map(|j| j.state) == Some(JobState::Running) {
                            lsf.finish(id, now).unwrap();
                        }
                    }
                }
                8 => {
                    if !live.is_empty() {
                        let id = live[g.usize(0..live.len())];
                        let state = lsf.status(id).map(|j| j.state).unwrap();
                        if !state.is_terminal() {
                            lsf.kill(id, now).unwrap();
                        }
                    }
                }
                _ => {
                    let node = NodeId(g.u32(0..8));
                    let victims = lsf.node_failed(node);
                    for v in victims {
                        let _ = lsf.fail(v, now);
                    }
                }
            }
            lsf.check_invariants().expect("scheduler invariant");
        }
        // Drain: finish everything still running.
        for id in live {
            if lsf.status(id).map(|j| j.state) == Some(JobState::Running) {
                lsf.finish(id, now).unwrap();
            }
        }
        lsf.check_invariants().unwrap();
    });
}

/// The RM's per-node ledger equals the sum of outstanding containers at
/// every step of a random allocate/release/fail sequence, and app
/// completion always returns the ledger to zero.
#[test]
fn yarn_ledger_balances_under_churn() {
    props(40, |g: &mut Gen| {
        let n_nodes = g.u32(1..6);
        let mut rm = ResourceManager::new(
            StackConfig::paper().yarn.clone(),
            Arc::new(IdGen::default()),
            Arc::new(Metrics::new()),
        );
        for i in 0..n_nodes {
            rm.register_nm(NodeId(i), Micros::ZERO).unwrap();
        }
        let h = rm.submit_app("prop", "u", Micros::ZERO).unwrap();
        let mut held = Vec::new();
        for _ in 0..g.usize(1..25) {
            match g.u32(0..3) {
                0 => {
                    let got = rm
                        .allocate(
                            h.app,
                            ContainerRequest {
                                resource: Resource::new(g.u64(256..8192), 1),
                                count: g.u32(1..10),
                            },
                            ContainerKind::Map,
                            Micros::ZERO,
                        )
                        .unwrap();
                    held.extend(got);
                }
                1 => {
                    if !held.is_empty() {
                        let i = g.usize(0..held.len());
                        let c: hpcw::yarn::Container = held.swap_remove(i);
                        rm.release(h.app, c.id).unwrap();
                    }
                }
                _ => {
                    if g.chance(0.2) && rm.nm_count() > 1 {
                        let node = NodeId(g.u32(0..n_nodes));
                        let lost = rm.node_failed(node);
                        held.retain(|c| !lost.iter().any(|l| l.id == c.id));
                    }
                }
            }
            rm.check_invariants().expect("yarn ledger");
        }
        rm.finish_app(h.app, AppState::Finished, Micros::secs(1)).unwrap();
        let (_, used) = rm.cluster_resources();
        assert_eq!(used, Resource::zero());
    });
}

/// Shuffle delivery is exactly-once and merge output equals a flat sort,
/// under random segment commits including duplicate (speculative) commits.
#[test]
fn shuffle_exactly_once_and_merge_correct() {
    props(40, |g: &mut Gen| {
        let n_maps = g.u32(1..6);
        let n_parts = g.u32(1..5);
        let store = ShuffleStore::new();
        let mut expected: Vec<Vec<u8>> = Vec::new();
        for m in 0..n_maps {
            for p in 0..n_parts {
                let mut keys: Vec<u8> =
                    (0..g.usize(0..15)).map(|_| g.u32(0..40) as u8).collect();
                keys.sort_unstable();
                let seg = Segment {
                    map: m,
                    partition: p,
                    node: NodeId(m),
                    records: RecordBuf::from_pairs(
                        keys.iter().map(|&k| (vec![k], Vec::<u8>::new())),
                    ),
                };
                // Speculative duplicate commit sometimes.
                if g.chance(0.3) {
                    store.put(seg.clone());
                }
                store.put(seg);
                if p == 0 {
                    expected.extend(keys.iter().map(|&k| vec![k]));
                }
            }
        }
        store.verify_complete(n_maps, n_parts).unwrap();
        let segs = store.fetch_partition(0, n_maps).unwrap();
        let merged = merge_to_recordbuf(&segs);
        let mut keys: Vec<Vec<u8>> = merged.iter().map(|(k, _)| k.to_vec()).collect();
        expected.sort();
        keys.sort();
        assert_eq!(keys, expected);
    });
}

struct WordSplit;
impl Mapper for WordSplit {
    fn map(&self, _k: &[u8], v: &[u8], emit: &mut dyn FnMut(&[u8], &[u8])) {
        for w in v.split(|&b| b == b' ').filter(|w| !w.is_empty()) {
            emit(w, b"1");
        }
    }
}

struct CountReducer;
impl Reducer for CountReducer {
    fn reduce(
        &self,
        key: &[u8],
        values: &mut dyn Iterator<Item = &[u8]>,
        emit: &mut dyn FnMut(&[u8], &[u8]),
    ) {
        let n = values.count();
        emit(key, n.to_string().as_bytes());
    }
}

/// Counters whose totals must not depend on launch ordering.
const PARITY_COUNTERS: &[&str] = &[
    "MAP_INPUT_RECORDS",
    "MAP_OUTPUT_RECORDS",
    "MAP_OUTPUT_BYTES",
    "SHUFFLE_BYTES",
    "SHUFFLE_SEGMENTS",
    "REDUCE_INPUT_RECORDS",
    "REDUCE_OUTPUT_RECORDS",
    "REDUCE_OUTPUT_BYTES",
    "TASKS_FAILED",
    "TASKS_LAUNCHED",
];

/// Run one wordcount job on a fresh cluster in the given scheduler mode.
/// Returns `(output file name → bytes, order-independent counters)`.
#[allow(clippy::type_complexity)]
fn run_parity_job(
    mode: SchedMode,
    text: &[u8],
    reduces: u32,
    split_bytes: u64,
    failures: &[(TaskId, u32)],
) -> (BTreeMap<String, Vec<u8>>, BTreeMap<String, u64>) {
    let cfg = StackConfig::tiny();
    let fs = Arc::new(LustreFs::new(&cfg.lustre, &cfg.cluster));
    let nodes: Vec<NodeId> = (0..6).map(NodeId).collect();
    let mut dc = DynamicCluster::build(
        &cfg,
        &nodes,
        &*fs,
        Arc::new(IdGen::default()),
        Arc::new(Metrics::new()),
        "parity",
        Micros::ZERO,
    )
    .unwrap();
    let pool = Pool::new(4);
    fs.mkdirs("/lustre/scratch/par-in").unwrap();
    fs.create("/lustre/scratch/par-in/f", text).unwrap();
    let mut spec =
        JobSpec::identity("parity", "/lustre/scratch/par-in", "/lustre/scratch/par-out", reduces);
    spec.input_format = InputFormat::Lines;
    spec.output_format = OutputFormat::TextKv;
    spec.split_bytes = split_bytes;
    spec.mapper = Arc::new(WordSplit);
    spec.reducer = Arc::new(CountReducer);
    spec.partitioner = Arc::new(HashPartitioner);
    let mut plan = FailurePlan::none();
    for &(task, attempt) in failures {
        plan = plan.fail_attempt(task, attempt);
    }
    spec.failures = plan;
    // Speculation is deliberate scheduling nondeterminism (duplicate
    // attempts); the byte-parity oracle runs with it off.
    let ecfg = hpcw::config::ElasticConfig {
        speculation: hpcw::config::SpeculationMode::Off,
        ..Default::default()
    };
    let mut engine = MrEngine::new(
        &mut dc,
        fs.clone(),
        &pool,
        cfg.yarn.map_memory_mb,
        cfg.yarn.reduce_memory_mb,
    )
    .with_mode(mode)
    .with_slowstart(0.5)
    .with_elastic_cfg(ecfg);
    let outcome = engine.run(Arc::new(spec), "u", Micros::ZERO).unwrap();
    dc.rm.check_invariants().unwrap();
    let mut files = BTreeMap::new();
    for f in &outcome.output_files {
        files.insert(f.clone(), fs.read(f).unwrap());
    }
    let counters: BTreeMap<String, u64> = outcome
        .counters
        .snapshot()
        .into_iter()
        .filter(|(k, _)| PARITY_COUNTERS.contains(&k.as_str()))
        .collect();
    (files, counters)
}

/// The pipelined scheduler is a pure scheduling change: under random
/// inputs, reduce counts, split sizes and attempt-0 failure injection,
/// its reduce output files are byte-identical to the barriered path and
/// the order-independent counter totals match exactly.
#[test]
fn pipelined_matches_barriered_byte_for_byte() {
    props(8, |g: &mut Gen| {
        let n_lines = g.usize(4..40);
        let mut text = Vec::new();
        for i in 0..n_lines {
            let w1 = g.u32(0..12);
            let w2 = g.u32(0..12);
            text.extend_from_slice(format!("w{w1} w{w2} line{i}\n").as_bytes());
        }
        let reduces = g.u32(1..5);
        let split_bytes = [24u64, 48, 96][g.usize(0..3)];
        let n_maps = ((text.len() as u64 + split_bytes - 1) / split_bytes) as u32;
        // Attempt-0 failures on a few random tasks — both runs inject the
        // identical plan, so retries line up.
        let mut failures = Vec::new();
        for _ in 0..g.usize(0..3) {
            if g.chance(0.5) {
                failures.push((TaskId::map(g.u32(0..n_maps)), 0));
            } else {
                failures.push((TaskId::reduce(g.u32(0..reduces)), 0));
            }
        }
        failures.sort_by_key(|(t, a)| (t.kind, t.index, *a));
        failures.dedup();
        let (files_b, ctr_b) =
            run_parity_job(SchedMode::Barriered, &text, reduces, split_bytes, &failures);
        let (files_p, ctr_p) =
            run_parity_job(SchedMode::Pipelined, &text, reduces, split_bytes, &failures);
        assert_eq!(files_b.len(), reduces as usize);
        assert_eq!(files_b, files_p, "reduce outputs must be byte-identical");
        assert_eq!(ctr_b, ctr_p, "order-independent counters must match");
    });
}

/// Terasort invariant at the unit level: for any random data, the range
/// partitioner is monotone and concatenated partition runs cover exactly
/// the input (used by Teravalidate's cross-part boundary check).
#[test]
fn range_partition_cover_property() {
    use hpcw::terasort::RangePartitioner;
    props(60, |g: &mut Gen| {
        let samples: Vec<u64> = (0..g.usize(2..300)).map(|_| g.u64(0..1 << 48)).collect();
        let parts = g.u32(1..64);
        let p = RangePartitioner::from_samples(samples, parts).unwrap();
        let keys: Vec<u64> = (0..200).map(|_| g.u64(0..1 << 48)).collect();
        let mut per_part: Vec<Vec<u64>> = vec![Vec::new(); p.n_partitions() as usize];
        for &k in &keys {
            per_part[p.route(k) as usize].push(k);
        }
        // Concatenating sorted partitions equals sorting everything.
        let mut concat = Vec::new();
        for part in &mut per_part {
            part.sort_unstable();
            concat.extend_from_slice(part);
        }
        let mut all = keys.clone();
        all.sort_unstable();
        assert_eq!(concat, all);
    });
}
