//! A TOML subset parser for config files.
//!
//! Supported: `[table]` / `[table.sub]` headers, `key = value` with string,
//! integer, float, boolean and homogeneous-array values, `#` comments.
//! Not supported (rejected loudly): inline tables, array-of-tables,
//! multiline strings, datetimes — the stack's configs don't use them.

use crate::error::{Error, Result};
use std::collections::BTreeMap;

/// A parsed scalar / array value.
#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            TomlValue::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            TomlValue::Int(i) if *i >= 0 => Some(*i as u64),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(f) => Some(*f),
            TomlValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// A parsed document: dotted-path key → value.
/// `[lustre]` + `ost_count = 12` becomes `"lustre.ost_count"`.
#[derive(Debug, Default, Clone)]
pub struct TomlDoc {
    entries: BTreeMap<String, TomlValue>,
}

impl TomlDoc {
    pub fn parse(text: &str) -> Result<TomlDoc> {
        let mut doc = TomlDoc::default();
        let mut prefix = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                if line.starts_with("[[") {
                    return Err(Error::Codec(format!(
                        "line {}: array-of-tables not supported",
                        lineno + 1
                    )));
                }
                let name = rest.strip_suffix(']').ok_or_else(|| {
                    Error::Codec(format!("line {}: unterminated table header", lineno + 1))
                })?;
                let name = name.trim();
                if name.is_empty() || !name.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '.' || c == '-') {
                    return Err(Error::Codec(format!(
                        "line {}: bad table name '{name}'",
                        lineno + 1
                    )));
                }
                prefix = name.to_string();
                continue;
            }
            let eq = line.find('=').ok_or_else(|| {
                Error::Codec(format!("line {}: expected 'key = value'", lineno + 1))
            })?;
            let key = line[..eq].trim();
            let val = line[eq + 1..].trim();
            if key.is_empty() {
                return Err(Error::Codec(format!("line {}: empty key", lineno + 1)));
            }
            let full = if prefix.is_empty() {
                key.to_string()
            } else {
                format!("{prefix}.{key}")
            };
            let parsed = parse_value(val)
                .map_err(|e| Error::Codec(format!("line {}: {e}", lineno + 1)))?;
            doc.entries.insert(full, parsed);
        }
        Ok(doc)
    }

    pub fn get(&self, path: &str) -> Option<&TomlValue> {
        self.entries.get(path)
    }

    pub fn str(&self, path: &str) -> Option<&str> {
        self.get(path).and_then(TomlValue::as_str)
    }

    pub fn u64(&self, path: &str) -> Option<u64> {
        self.get(path).and_then(TomlValue::as_u64)
    }

    pub fn f64(&self, path: &str) -> Option<f64> {
        self.get(path).and_then(TomlValue::as_f64)
    }

    pub fn bool(&self, path: &str) -> Option<bool> {
        self.get(path).and_then(TomlValue::as_bool)
    }

    /// All keys under a table prefix (`keys_under("lustre")`).
    pub fn keys_under<'a>(&'a self, prefix: &'a str) -> impl Iterator<Item = &'a str> + 'a {
        let want = format!("{prefix}.");
        self.entries
            .keys()
            .filter(move |k| k.starts_with(&want))
            .map(|k| k.as_str())
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

/// Strip a `#` comment that is not inside a quoted string.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> std::result::Result<TomlValue, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(rest) = s.strip_prefix('"') {
        let inner = rest
            .strip_suffix('"')
            .ok_or_else(|| "unterminated string".to_string())?;
        let mut out = String::new();
        let mut chars = inner.chars();
        while let Some(c) = chars.next() {
            if c == '\\' {
                match chars.next() {
                    Some('n') => out.push('\n'),
                    Some('t') => out.push('\t'),
                    Some('"') => out.push('"'),
                    Some('\\') => out.push('\\'),
                    other => return Err(format!("bad escape \\{other:?}")),
                }
            } else if c == '"' {
                return Err("unescaped quote inside string".into());
            } else {
                out.push(c);
            }
        }
        return Ok(TomlValue::Str(out));
    }
    if s == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if s == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner
            .strip_suffix(']')
            .ok_or_else(|| "unterminated array".to_string())?;
        let mut items = Vec::new();
        for part in split_array_items(inner) {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            items.push(parse_value(part)?);
        }
        return Ok(TomlValue::Arr(items));
    }
    let cleaned = s.replace('_', "");
    if let Ok(i) = cleaned.parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = cleaned.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    Err(format!("unparseable value '{s}'"))
}

/// Split array items on commas that are not inside strings.
fn split_array_items(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut start = 0;
    let mut in_str = false;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            ',' if !in_str => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# stack config
seed = 42
name = "hpcwales"   # trailing comment

[cluster]
nodes = 128
cores_per_node = 16
mem_gb = 64.0
exclusive = true

[lustre]
ost_count = 12
ost_bw_mbps = 1_200
mount = "/lustre/scratch"
stripes = [1, 2, 4]
tags = ["a", "b,c"]
"#;

    #[test]
    fn parses_tables_and_scalars() {
        let doc = TomlDoc::parse(SAMPLE).unwrap();
        assert_eq!(doc.u64("seed"), Some(42));
        assert_eq!(doc.str("name"), Some("hpcwales"));
        assert_eq!(doc.u64("cluster.nodes"), Some(128));
        assert_eq!(doc.f64("cluster.mem_gb"), Some(64.0));
        assert_eq!(doc.bool("cluster.exclusive"), Some(true));
        assert_eq!(doc.u64("lustre.ost_bw_mbps"), Some(1200));
        assert_eq!(doc.str("lustre.mount"), Some("/lustre/scratch"));
    }

    #[test]
    fn arrays_with_commas_in_strings() {
        let doc = TomlDoc::parse(SAMPLE).unwrap();
        match doc.get("lustre.stripes").unwrap() {
            TomlValue::Arr(v) => assert_eq!(v.len(), 3),
            _ => panic!(),
        }
        match doc.get("lustre.tags").unwrap() {
            TomlValue::Arr(v) => {
                assert_eq!(v[1].as_str(), Some("b,c"));
            }
            _ => panic!(),
        }
    }

    #[test]
    fn int_coerces_to_f64_not_reverse() {
        let doc = TomlDoc::parse("x = 3\ny = 3.5").unwrap();
        assert_eq!(doc.f64("x"), Some(3.0));
        assert_eq!(doc.u64("y"), None);
    }

    #[test]
    fn keys_under_prefix() {
        let doc = TomlDoc::parse(SAMPLE).unwrap();
        let keys: Vec<_> = doc.keys_under("cluster").collect();
        assert_eq!(keys.len(), 4);
        assert!(keys.contains(&"cluster.nodes"));
    }

    #[test]
    fn rejects_unsupported_constructs() {
        assert!(TomlDoc::parse("[[jobs]]").is_err());
        assert!(TomlDoc::parse("[unclosed").is_err());
        assert!(TomlDoc::parse("just a line").is_err());
        assert!(TomlDoc::parse("k = ").is_err());
        assert!(TomlDoc::parse("k = \"open").is_err());
    }

    #[test]
    fn string_escapes() {
        let doc = TomlDoc::parse(r#"s = "a\nb\t\"c\"""#).unwrap();
        assert_eq!(doc.str("s"), Some("a\nb\t\"c\""));
    }

    #[test]
    fn hash_inside_string_not_comment() {
        let doc = TomlDoc::parse(r##"s = "a#b" # real comment"##).unwrap();
        assert_eq!(doc.str("s"), Some("a#b"));
    }
}
