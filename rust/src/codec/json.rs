//! JSON value model, recursive-descent parser, and writer.
//!
//! Used as the wire format of the SynfiniWay-style API and for the artifact
//! manifest written by `python/compile/aot.py`. Objects preserve insertion
//! order (Vec of pairs) so responses are stable for tests.

use crate::error::{Error, Result};
use std::fmt::Write as _;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Build an object from pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }

    /// Field lookup on objects.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as u64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Required-field accessors with codec errors (API handlers use these).
    pub fn req_str(&self, key: &str) -> Result<&str> {
        self.get(key)
            .and_then(Json::as_str)
            .ok_or_else(|| Error::Codec(format!("missing string field '{key}'")))
    }

    pub fn req_u64(&self, key: &str) -> Result<u64> {
        self.get(key)
            .and_then(Json::as_u64)
            .ok_or_else(|| Error::Codec(format!("missing integer field '{key}'")))
    }

    /// Compact serialization.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty serialization (2-space indent).
    pub fn pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9.0e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(pairs) => {
                out.push('{');
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !pairs.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }

    /// Parse a complete JSON document (trailing whitespace allowed).
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(Error::Codec(format!(
                "trailing garbage at byte {} of JSON document",
                p.pos
            )));
        }
        Ok(v)
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(Error::Codec(format!(
                "expected '{}' at byte {}",
                b as char,
                self.pos.saturating_sub(1)
            )))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(Error::Codec(format!("bad literal at byte {}", self.pos)))
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(Error::Codec(format!(
                "unexpected {:?} at byte {}",
                other.map(|b| b as char),
                self.pos
            ))),
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(Error::Codec("unterminated string".into())),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let d = self
                                .bump()
                                .and_then(|b| (b as char).to_digit(16))
                                .ok_or_else(|| Error::Codec("bad \\u escape".into()))?;
                            code = code * 16 + d;
                        }
                        // Surrogate pairs: read the low half if present.
                        if (0xD800..0xDC00).contains(&code) {
                            if self.bump() == Some(b'\\') && self.bump() == Some(b'u') {
                                let mut low = 0u32;
                                for _ in 0..4 {
                                    let d = self
                                        .bump()
                                        .and_then(|b| (b as char).to_digit(16))
                                        .ok_or_else(|| Error::Codec("bad \\u escape".into()))?;
                                    low = low * 16 + d;
                                }
                                code = 0x10000 + ((code - 0xD800) << 10) + (low - 0xDC00);
                            } else {
                                return Err(Error::Codec("lone high surrogate".into()));
                            }
                        }
                        s.push(
                            char::from_u32(code)
                                .ok_or_else(|| Error::Codec("invalid codepoint".into()))?,
                        );
                    }
                    other => {
                        return Err(Error::Codec(format!("bad escape {other:?}")));
                    }
                },
                Some(b) if b < 0x80 => s.push(b as char),
                Some(b) => {
                    // Multi-byte UTF-8: find the full sequence.
                    let len = if b >= 0xF0 {
                        4
                    } else if b >= 0xE0 {
                        3
                    } else {
                        2
                    };
                    let start = self.pos - 1;
                    let end = start + len;
                    if end > self.bytes.len() {
                        return Err(Error::Codec("truncated utf-8".into()));
                    }
                    let chunk = std::str::from_utf8(&self.bytes[start..end])
                        .map_err(|_| Error::Codec("invalid utf-8".into()))?;
                    s.push_str(chunk);
                    self.pos = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b) if b.is_ascii_digit() || b == b'.' || b == b'e' || b == b'E' || b == b'+' || b == b'-')
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| Error::Codec(format!("bad number '{text}'")))
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(Error::Codec("expected ',' or ']'".into())),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut pairs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(pairs));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            pairs.push((key, val));
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(pairs)),
                _ => return Err(Error::Codec("expected ',' or '}'".into())),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip_compact() {
        let v = Json::obj(vec![
            ("name", Json::str("terasort")),
            ("cores", Json::num(1800)),
            ("exclusive", Json::Bool(true)),
            ("tags", Json::Arr(vec![Json::str("yarn"), Json::str("lustre")])),
            ("note", Json::Null),
        ]);
        let text = v.to_string();
        let back = Json::parse(&text).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn parse_nested_and_spaces() {
        let v = Json::parse(r#" { "a" : [ 1 , 2.5 , { "b" : "c" } ] } "#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("c")
        );
    }

    #[test]
    fn integers_serialize_without_fraction() {
        assert_eq!(Json::num(1800).to_string(), "1800");
        assert_eq!(Json::num(1.5).to_string(), "1.5");
    }

    #[test]
    fn escapes_round_trip() {
        let v = Json::str("line1\nline2\t\"quoted\" \\ end");
        let back = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""Aé😀""#).unwrap();
        assert_eq!(v.as_str(), Some("Aé😀"));
    }

    #[test]
    fn utf8_passthrough() {
        let v = Json::parse(r#""café 北京""#).unwrap();
        assert_eq!(v.as_str(), Some("café 北京"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{} extra").is_err());
        assert!(Json::parse("nulll").is_err());
        assert!(Json::parse("'single'").is_err());
    }

    #[test]
    fn negative_and_exponent_numbers() {
        let v = Json::parse("[-3, 1e3, -2.5e-2]").unwrap();
        let a = v.as_arr().unwrap();
        assert_eq!(a[0].as_f64(), Some(-3.0));
        assert_eq!(a[1].as_f64(), Some(1000.0));
        assert_eq!(a[2].as_f64(), Some(-0.025));
    }

    #[test]
    fn req_accessors_error_kind() {
        let v = Json::obj(vec![("x", Json::num(1))]);
        assert!(v.req_str("y").is_err());
        assert_eq!(v.req_u64("x").unwrap(), 1);
    }

    #[test]
    fn pretty_is_parseable() {
        let v = Json::obj(vec![
            ("a", Json::Arr(vec![Json::num(1), Json::num(2)])),
            ("b", Json::obj(vec![("c", Json::str("d"))])),
        ]);
        let back = Json::parse(&v.pretty()).unwrap();
        assert_eq!(v, back);
        assert!(v.pretty().contains('\n'));
    }
}
