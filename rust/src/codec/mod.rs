//! Hand-rolled serialization: JSON (API wire format), a TOML subset
//! (config files) and CSV (bench output). serde is not vendored in this
//! environment, so these are small self-contained implementations.

pub mod csv;
pub mod json;
pub mod toml;
