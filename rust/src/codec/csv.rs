//! Tiny CSV writer for bench output (`bench_out/*.csv`), with RFC-4180
//! quoting. Write-only: nothing in the stack parses CSV.

use std::io::Write;
use std::path::Path;

/// Accumulates rows, writes a file atomically at the end.
pub struct CsvWriter {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl CsvWriter {
    pub fn new(header: &[&str]) -> Self {
        CsvWriter {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width {} != header width {}",
            cells.len(),
            self.header.len()
        );
        self.rows.push(cells.to_vec());
    }

    /// Convenience for numeric benches.
    pub fn rowf(&mut self, cells: &[f64]) {
        self.row(&cells.iter().map(|v| format!("{v}")).collect::<Vec<_>>());
    }

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        push_row(&mut out, &self.header);
        for r in &self.rows {
            push_row(&mut out, r);
        }
        out
    }

    /// Write to `path`, creating parent directories.
    pub fn write_file(&self, path: &Path) -> std::io::Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut f = std::fs::File::create(path)?;
        f.write_all(self.to_string().as_bytes())
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }
}

fn push_row(out: &mut String, cells: &[String]) {
    for (i, c) in cells.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        if c.contains(',') || c.contains('"') || c.contains('\n') {
            out.push('"');
            out.push_str(&c.replace('"', "\"\""));
            out.push('"');
        } else {
            out.push_str(c);
        }
    }
    out.push('\n');
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_header_and_rows() {
        let mut w = CsvWriter::new(&["cores", "seconds"]);
        w.rowf(&[16.0, 31.5]);
        w.rowf(&[32.0, 33.0]);
        let text = w.to_string();
        assert_eq!(text, "cores,seconds\n16,31.5\n32,33\n");
    }

    #[test]
    fn quotes_when_needed() {
        let mut w = CsvWriter::new(&["a"]);
        w.row(&["x,y".to_string()]);
        w.row(&["say \"hi\"".to_string()]);
        let text = w.to_string();
        assert!(text.contains("\"x,y\""));
        assert!(text.contains("\"say \"\"hi\"\"\""));
    }

    #[test]
    #[should_panic]
    fn width_mismatch_panics() {
        let mut w = CsvWriter::new(&["a", "b"]);
        w.row(&["only-one".to_string()]);
    }
}
