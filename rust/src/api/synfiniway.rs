//! SynfiniWay-style workflows: named-step DAGs submitted through the API
//! (§II: "the Fujitsu SynfiniWay framework to enable job submission via a
//! web interface and high-level API"; §III step 2: "SynfiniWay submits the
//! job into the scheduler based on the custom workflows").
//!
//! A workflow is a DAG of named steps ([`WorkflowSpec`] in `wire.rs`):
//! a step starts when every step in its `after` list is `DONE`, and every
//! ready step is submitted in the same advance pass — independent branches
//! run concurrently. Steps chain outputs to inputs by embedding
//! `${steps.<name>.output_dir}` in their payload strings; the reference is
//! substituted with the producing step's actual output directory at
//! submit time. A failed step is retried up to its `retries` budget, then
//! fails the workflow: running branches finish, unstarted steps are
//! `SKIPPED`, and the workflow reports `aborted`.

use crate::api::stack::Stack;
use crate::api::wire::{
    payload_map_strings, substitute_step_refs, StepDoc, StepSpec, StepState, WorkflowDoc,
    WorkflowSpec,
};
use crate::error::Result;
use crate::scheduler::JobState;
use crate::util::ids::LsfJobId;

/// Back-compat alias: the workflow definition is the wire spec.
pub type Workflow = WorkflowSpec;

/// Compile a multi-stage query plan to a SynfiniWay workflow: one
/// `query_stage` step per MR job, chained `s0 → s1 → …` with each step's
/// input wired to `${steps.<prev>.output_dir}` — intermediate outputs
/// flow through the DFS like any other job's, and the API's workflow
/// machinery (retries, events, status docs) applies unchanged.
pub fn query_workflow(
    name: &str,
    user: &str,
    nodes: u32,
    plan: &crate::frameworks::LogicalPlan,
) -> Result<WorkflowSpec> {
    let stages = plan.compile_stages()?;
    let steps = stages
        .iter()
        .enumerate()
        .map(|(i, stage)| {
            let mut stage = stage.clone();
            let after = if i == 0 {
                Vec::new()
            } else {
                stage.input_dir = format!("${{steps.s{}.output_dir}}", i - 1);
                vec![format!("s{}", i - 1)]
            };
            StepSpec {
                name: format!("s{i}"),
                after,
                retries: 0,
                payload: crate::api::stack::AppPayload::QueryStage { stage },
            }
        })
        .collect();
    let spec = WorkflowSpec {
        name: name.to_string(),
        user: user.to_string(),
        nodes,
        steps,
    };
    spec.validate()?;
    Ok(spec)
}

/// One observed step transition, for the server's event journal.
#[derive(Debug, Clone, PartialEq)]
pub struct StepTransition {
    pub step: String,
    pub state: StepState,
    pub job: Option<LsfJobId>,
}

/// Execution state of one step.
#[derive(Debug)]
struct StepRun {
    state: StepState,
    /// LSF job of the current (or last) attempt.
    job: Option<LsfJobId>,
    attempts: u32,
    /// The producing job's output directory, recorded on `DONE` for
    /// `${steps.<name>.output_dir}` consumers.
    output_dir: Option<String>,
}

/// Execution state of one workflow.
#[derive(Debug)]
pub struct WorkflowRun {
    pub id: u64,
    pub spec: WorkflowSpec,
    steps: Vec<StepRun>,
    aborted: bool,
    complete: bool,
}

impl WorkflowRun {
    /// `spec` must already be validated (`WorkflowSpec::from_json` does;
    /// call [`WorkflowSpec::validate`] for hand-built specs).
    pub fn new(id: u64, spec: WorkflowSpec) -> WorkflowRun {
        let steps = spec
            .steps
            .iter()
            .map(|_| StepRun {
                state: StepState::Waiting,
                job: None,
                attempts: 0,
                output_dir: None,
            })
            .collect();
        WorkflowRun {
            id,
            spec,
            steps,
            aborted: false,
            complete: false,
        }
    }

    pub fn is_terminal(&self) -> bool {
        self.complete || self.aborted
    }

    pub fn is_complete(&self) -> bool {
        self.complete
    }

    pub fn is_aborted(&self) -> bool {
        self.aborted
    }

    fn index_of(&self, name: &str) -> usize {
        self.spec
            .steps
            .iter()
            .position(|s| s.name == name)
            .expect("validated spec: step names resolve")
    }

    fn deps_done(&self, spec: &StepSpec) -> bool {
        spec.after
            .iter()
            .all(|d| self.steps[self.index_of(d)].state == StepState::Done)
    }

    /// Substitute `${steps.<name>.output_dir}` references in a payload
    /// against completed steps' recorded output dirs.
    fn resolve_payload(&self, spec: &StepSpec) -> Result<crate::api::stack::AppPayload> {
        payload_map_strings(&spec.payload, &mut |text| {
            substitute_step_refs(text, &|name| {
                self.spec
                    .steps
                    .iter()
                    .position(|s| s.name == name)
                    .and_then(|i| self.steps[i].output_dir.clone())
            })
        })
    }

    /// Advance the workflow: collect finished attempts, retry or fail,
    /// and submit every ready step. Called from the API pump with the
    /// stack lock held. Returns the step transitions that occurred, in
    /// order, for the event journal.
    pub fn advance(&mut self, stack: &mut Stack) -> Vec<StepTransition> {
        let mut transitions = Vec::new();
        if self.is_terminal() {
            return transitions;
        }

        // 1. Collect running attempts that reached a terminal LSF state.
        for i in 0..self.steps.len() {
            if self.steps[i].state != StepState::Running {
                continue;
            }
            let job = self.steps[i].job.expect("running step has a job");
            let job_state = match stack.lsf.status(job).map(|j| j.state) {
                Some(s) => s,
                None => {
                    // Job vanished (should not happen): treat as failure.
                    JobState::Exited
                }
            };
            match job_state {
                JobState::Done => {
                    let output_dir = stack
                        .job_state(job)
                        .and_then(|(_, r)| r.map(|r| r.output_dir.clone()));
                    let run = &mut self.steps[i];
                    run.state = StepState::Done;
                    run.output_dir = output_dir;
                    transitions.push(StepTransition {
                        step: self.spec.steps[i].name.clone(),
                        state: StepState::Done,
                        job: Some(job),
                    });
                }
                JobState::Killed => {
                    // An operator bkill is a decision, not a flaky attempt:
                    // never resubmit it, fail the step immediately.
                    transitions.push(self.fail_step(i));
                }
                s if s.is_terminal() => {
                    // Failed attempt: retry if budget remains.
                    if self.steps[i].attempts <= self.spec.steps[i].retries {
                        match self.submit_step(stack, i) {
                            Ok(t) => transitions.push(t),
                            Err(_) => transitions.push(self.fail_step(i)),
                        }
                    } else {
                        transitions.push(self.fail_step(i));
                    }
                }
                _ => {} // still pending/running
            }
        }

        // 2. On failure, skip everything not yet started; running branches
        //    were already collected above and simply stop mattering.
        if self.aborted {
            self.skip_waiting(&mut transitions);
            return transitions;
        }

        // 3. Submit every ready step in the same pass: independent DAG
        //    branches (e.g. the two middle steps of a diamond) go to the
        //    scheduler together and run concurrently.
        for i in 0..self.steps.len() {
            if self.steps[i].state == StepState::Waiting && self.deps_done(&self.spec.steps[i]) {
                match self.submit_step(stack, i) {
                    Ok(t) => transitions.push(t),
                    Err(_) => {
                        transitions.push(self.fail_step(i));
                        break;
                    }
                }
            }
        }
        if self.aborted {
            self.skip_waiting(&mut transitions);
        } else if self.steps.iter().all(|s| s.state == StepState::Done) {
            self.complete = true;
        }
        transitions
    }

    fn skip_waiting(&mut self, transitions: &mut Vec<StepTransition>) {
        for i in 0..self.steps.len() {
            if self.steps[i].state == StepState::Waiting {
                self.steps[i].state = StepState::Skipped;
                transitions.push(StepTransition {
                    step: self.spec.steps[i].name.clone(),
                    state: StepState::Skipped,
                    job: None,
                });
            }
        }
    }

    fn submit_step(&mut self, stack: &mut Stack, i: usize) -> Result<StepTransition> {
        let spec = &self.spec.steps[i];
        let payload = self.resolve_payload(spec)?;
        let id = stack.submit(self.spec.nodes, &self.spec.user, payload)?;
        let name = self.spec.steps[i].name.clone();
        let run = &mut self.steps[i];
        run.state = StepState::Running;
        run.job = Some(id);
        run.attempts += 1;
        Ok(StepTransition {
            step: name,
            state: StepState::Running,
            job: Some(id),
        })
    }

    fn fail_step(&mut self, i: usize) -> StepTransition {
        self.steps[i].state = StepState::Failed;
        self.aborted = true;
        StepTransition {
            step: self.spec.steps[i].name.clone(),
            state: StepState::Failed,
            job: self.steps[i].job,
        }
    }

    /// The wire status document.
    pub fn to_doc(&self) -> WorkflowDoc {
        let steps = self
            .spec
            .steps
            .iter()
            .zip(&self.steps)
            .map(|(spec, run)| StepDoc {
                name: spec.name.clone(),
                kind: spec.payload.kind().to_string(),
                state: run.state,
                attempts: run.attempts,
                job: run.job.map(|j| j.0),
                output_dir: run.output_dir.clone(),
            })
            .collect();
        WorkflowDoc {
            workflow: self.id,
            name: self.spec.name.clone(),
            complete: self.complete,
            aborted: self.aborted,
            steps,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::stack::AppPayload;
    use crate::config::StackConfig;
    use crate::lustre::Dfs as _;

    fn teragen(dir: &str) -> AppPayload {
        AppPayload::Teragen {
            rows: 400,
            maps: 2,
            dir: dir.to_string(),
        }
    }

    fn step(name: &str, after: &[&str], payload: AppPayload) -> StepSpec {
        StepSpec {
            name: name.into(),
            after: after.iter().map(|s| s.to_string()).collect(),
            retries: 0,
            payload,
        }
    }

    fn spec(steps: Vec<StepSpec>) -> WorkflowSpec {
        let s = WorkflowSpec {
            name: "wf".into(),
            user: "sid".into(),
            nodes: 4,
            steps,
        };
        s.validate().unwrap();
        s
    }

    #[test]
    fn linear_steps_run_in_order() {
        let mut stack = Stack::new(StackConfig::tiny()).unwrap();
        let wf = WorkflowSpec::linear(
            "pipeline",
            "sid",
            4,
            vec![teragen("/lustre/scratch/wf-a"), teragen("/lustre/scratch/wf-b")],
        );
        wf.validate().unwrap();
        let mut run = WorkflowRun::new(0, wf);
        let t = run.advance(&mut stack);
        assert_eq!(t.len(), 1);
        assert_eq!(t[0].state, StepState::Running);
        // Step 2 must not be submitted before step 1 completes.
        assert!(run.advance(&mut stack).is_empty());
        stack.tick(); // runs step 1
        let t = run.advance(&mut stack);
        assert_eq!(
            t.iter().map(|x| x.state).collect::<Vec<_>>(),
            vec![StepState::Done, StepState::Running]
        );
        stack.tick();
        run.advance(&mut stack);
        assert!(run.is_complete());
        assert!(stack.dfs.exists("/lustre/scratch/wf-a/_SUCCESS"));
        assert!(stack.dfs.exists("/lustre/scratch/wf-b/_SUCCESS"));
    }

    #[test]
    fn diamond_runs_middle_steps_concurrently() {
        let mut stack = Stack::new(StackConfig::tiny()).unwrap();
        let wf = spec(vec![
            step("gen", &[], teragen("/lustre/scratch/di-gen")),
            step("left", &["gen"], teragen("/lustre/scratch/di-left")),
            step("right", &["gen"], teragen("/lustre/scratch/di-right")),
            step("join", &["left", "right"], teragen("/lustre/scratch/di-join")),
        ]);
        let mut run = WorkflowRun::new(0, wf);
        run.advance(&mut stack);
        stack.tick(); // gen done
        let t = run.advance(&mut stack);
        // Both middle steps submitted in the SAME pass, before either ran.
        let running: Vec<&str> = t
            .iter()
            .filter(|x| x.state == StepState::Running)
            .map(|x| x.step.as_str())
            .collect();
        assert_eq!(running, vec!["left", "right"]);
        let doc = run.to_doc();
        let st = |n: &str| doc.steps.iter().find(|s| s.name == n).unwrap().state;
        assert_eq!(st("left"), StepState::Running);
        assert_eq!(st("right"), StepState::Running);
        assert_eq!(st("join"), StepState::Waiting);
        stack.tick(); // both middles execute this tick (4+4 nodes fit)
        run.advance(&mut stack);
        stack.tick();
        run.advance(&mut stack);
        assert!(run.is_complete());
        for d in ["di-gen", "di-left", "di-right", "di-join"] {
            assert!(stack.dfs.exists(&format!("/lustre/scratch/{d}/_SUCCESS")));
        }
    }

    #[test]
    fn output_dir_chains_into_dependent_payload() {
        let mut stack = Stack::new(StackConfig::tiny()).unwrap();
        stack.dfs.mkdirs("/lustre/scratch/chain-src").unwrap();
        stack
            .dfs
            .create(
                "/lustre/scratch/chain-src/part-0",
                b"wales,200\nwales,300\nengland,50\n",
            )
            .unwrap();
        let wf = spec(vec![
            step(
                "report",
                &[],
                AppPayload::PigScript {
                    script: "
                        recs = LOAD '/lustre/scratch/chain-src' USING ',' AS (region, amount);
                        grp  = GROUP recs BY region;
                        out  = FOREACH grp GENERATE group, SUM(amount);
                        STORE out INTO '/lustre/scratch/chain-report';"
                        .into(),
                    reduces: 1,
                },
            ),
            step(
                "rollup",
                &["report"],
                AppPayload::HiveQuery {
                    // Consumes the producing step's ACTUAL output dir via
                    // the wire reference, not a hard-coded path.
                    sql: "SELECT region, SUM(total) FROM '${steps.report.output_dir}' \
                          USING '\t' SCHEMA (region, total) GROUP BY region \
                          INTO '/lustre/scratch/chain-rollup'"
                        .into(),
                    reduces: 1,
                },
            ),
        ]);
        let mut run = WorkflowRun::new(0, wf);
        run.advance(&mut stack);
        stack.tick();
        run.advance(&mut stack);
        stack.tick();
        run.advance(&mut stack);
        assert!(run.is_complete(), "doc={:?}", run.to_doc());
        let doc = run.to_doc();
        assert_eq!(
            doc.steps[0].output_dir.as_deref(),
            Some("/lustre/scratch/chain-report")
        );
        assert!(stack.dfs.exists("/lustre/scratch/chain-rollup/_SUCCESS"));
    }

    #[test]
    fn failed_step_aborts_flow_and_skips_dependents() {
        let mut stack = Stack::new(StackConfig::tiny()).unwrap();
        let wf = spec(vec![
            step(
                "bad",
                &[],
                AppPayload::HiveQuery {
                    sql: "SELECT COUNT(a) FROM '/lustre/scratch/missing' SCHEMA (a) INTO '/lustre/scratch/wf-x'".into(),
                    reduces: 1,
                },
            ),
            step("never", &["bad"], teragen("/lustre/scratch/wf-never")),
        ]);
        let mut run = WorkflowRun::new(0, wf);
        run.advance(&mut stack);
        stack.tick(); // step 1 fails
        let t = run.advance(&mut stack);
        assert!(run.is_aborted());
        assert_eq!(
            t.iter().map(|x| x.state).collect::<Vec<_>>(),
            vec![StepState::Failed, StepState::Skipped]
        );
        assert!(!stack.dfs.exists("/lustre/scratch/wf-never"));
        let doc = run.to_doc();
        assert!(doc.aborted && !doc.complete);
        assert_eq!(doc.steps[1].state, StepState::Skipped);
    }

    #[test]
    fn retry_budget_resubmits_failed_attempts() {
        let mut stack = Stack::new(StackConfig::tiny()).unwrap();
        // The query fails while the input is missing; retries=2 gives the
        // step three attempts total.
        let mut s = step(
            "flaky",
            &[],
            AppPayload::HiveQuery {
                sql: "SELECT COUNT(a) FROM '/lustre/scratch/late' SCHEMA (a) INTO '/lustre/scratch/late-out'".into(),
                reduces: 1,
            },
        );
        s.retries = 2;
        let mut run = WorkflowRun::new(0, spec(vec![s]));
        run.advance(&mut stack);
        stack.tick(); // attempt 1 fails
        let t = run.advance(&mut stack);
        assert_eq!(t.last().unwrap().state, StepState::Running, "retried");
        // Stage the input before the retry executes: attempt 2 succeeds.
        stack.dfs.mkdirs("/lustre/scratch/late").unwrap();
        stack
            .dfs
            .create("/lustre/scratch/late/part-0", b"7\n9\n")
            .unwrap();
        stack.tick();
        run.advance(&mut stack);
        assert!(run.is_complete());
        assert_eq!(run.to_doc().steps[0].attempts, 2);
    }

    #[test]
    fn killed_step_is_not_retried() {
        let mut stack = Stack::new(StackConfig::tiny()).unwrap();
        let mut s = step("stoppable", &[], teragen("/lustre/scratch/kill-wf"));
        s.retries = 3; // a bkill must override the retry budget
        let mut run = WorkflowRun::new(0, spec(vec![s]));
        run.advance(&mut stack);
        let job = run.to_doc().steps[0].job.unwrap();
        stack.kill(crate::util::ids::LsfJobId(job)).unwrap();
        let t = run.advance(&mut stack);
        assert!(run.is_aborted());
        assert_eq!(t[0].state, StepState::Failed);
        assert_eq!(run.to_doc().steps[0].attempts, 1, "no resubmission");
    }

    #[test]
    fn query_workflow_chains_stages_through_step_refs() {
        let mut stack = Stack::new(StackConfig::tiny()).unwrap();
        stack.dfs.mkdirs("/lustre/scratch/qw-sales").unwrap();
        stack
            .dfs
            .create(
                "/lustre/scratch/qw-sales/part-0",
                b"wales,200\nwales,300\nengland,50\nwales,25\nengland,75\n",
            )
            .unwrap();
        let plan = crate::api::stack::parse_query_text(
            "hive",
            "SELECT region, SUM(amount) FROM '/lustre/scratch/qw-sales' USING ',' \
             SCHEMA (region, amount) GROUP BY region \
             ORDER BY sum_amount DESC INTO '/lustre/scratch/qw-top'",
            2,
        )
        .unwrap();
        let wf = query_workflow("top-regions", "sid", 4, &plan).unwrap();
        assert_eq!(wf.steps.len(), 2, "agg then sort");
        assert_eq!(wf.steps[1].after, vec!["s0"]);
        // The sort step's input is a reference, resolved at submit time.
        match &wf.steps[1].payload {
            crate::api::stack::AppPayload::QueryStage { stage } => {
                assert_eq!(stage.input_dir, "${steps.s0.output_dir}");
            }
            other => panic!("unexpected payload {other:?}"),
        }
        let mut run = WorkflowRun::new(0, wf);
        for _ in 0..6 {
            run.advance(&mut stack);
            stack.tick();
        }
        run.advance(&mut stack);
        assert!(run.is_complete(), "doc={:?}", run.to_doc());
        // Globally ordered output: wales (525) before england (125).
        let mut files: Vec<String> = stack
            .dfs
            .list("/lustre/scratch/qw-top")
            .into_iter()
            .filter(|p| p.contains("/part-"))
            .collect();
        files.sort();
        let mut text = String::new();
        for f in &files {
            text.push_str(&String::from_utf8(stack.dfs.read(f).unwrap()).unwrap());
        }
        let rows: Vec<&str> = text.lines().collect();
        assert_eq!(rows, vec!["wales\t525", "england\t125"]);

        // Re-running the same query as a workflow must succeed: the
        // final output is removed by the caller (Hadoop semantics), and
        // the stale `.stage0` intermediate left by the first run is
        // pre-deleted by the stage itself.
        assert!(stack.dfs.exists("/lustre/scratch/qw-top.stage0"));
        stack.dfs.delete_recursive("/lustre/scratch/qw-top").unwrap();
        let wf2 = query_workflow("top-regions-again", "sid", 4, &plan).unwrap();
        let mut rerun = WorkflowRun::new(1, wf2);
        for _ in 0..6 {
            rerun.advance(&mut stack);
            stack.tick();
        }
        rerun.advance(&mut stack);
        assert!(rerun.is_complete(), "doc={:?}", rerun.to_doc());
        assert!(stack.dfs.exists("/lustre/scratch/qw-top/_SUCCESS"));
    }

    #[test]
    fn retries_exhausted_fails_the_workflow() {
        let mut stack = Stack::new(StackConfig::tiny()).unwrap();
        let mut s = step(
            "doomed",
            &[],
            AppPayload::HiveQuery {
                sql: "SELECT COUNT(a) FROM '/lustre/scratch/never' SCHEMA (a) INTO '/lustre/scratch/never-out'".into(),
                reduces: 1,
            },
        );
        s.retries = 1;
        let mut run = WorkflowRun::new(0, spec(vec![s]));
        run.advance(&mut stack);
        stack.tick();
        run.advance(&mut stack); // retry submitted
        stack.tick();
        run.advance(&mut stack); // retry failed, budget exhausted
        assert!(run.is_aborted());
        let doc = run.to_doc();
        assert_eq!(doc.steps[0].state, StepState::Failed);
        assert_eq!(doc.steps[0].attempts, 2);
    }
}
