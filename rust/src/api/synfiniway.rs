//! SynfiniWay-style workflows: named multi-step flows submitted through
//! the API (§II: "the Fujitsu SynfiniWay framework to enable job
//! submission via a web interface and high-level API"; §III step 2:
//! "SynfiniWay submits the job into the scheduler based on the custom
//! workflows").
//!
//! A workflow is an ordered list of application payloads; step *i+1* is
//! submitted only after step *i*'s LSF job reaches a terminal state, and a
//! failed step aborts the rest — the behaviour scientific pipelines
//! (stage-in → analyse → report) rely on.

use crate::api::server::payload_from_json;
use crate::api::stack::{AppPayload, Stack};
use crate::codec::json::Json;
use crate::error::{Error, Result};
use crate::scheduler::JobState;
use crate::util::ids::LsfJobId;

/// A workflow definition.
#[derive(Debug, Clone)]
pub struct Workflow {
    pub name: String,
    pub user: String,
    /// Nodes requested for every step's LSF job.
    pub nodes: u32,
    pub steps: Vec<AppPayload>,
}

impl Workflow {
    pub fn from_json(j: &Json) -> Result<Workflow> {
        let steps_json = j
            .get("steps")
            .and_then(Json::as_arr)
            .ok_or_else(|| Error::Api("workflow needs steps[]".into()))?;
        if steps_json.is_empty() {
            return Err(Error::Api("workflow with no steps".into()));
        }
        let steps: Result<Vec<AppPayload>> = steps_json.iter().map(payload_from_json).collect();
        Ok(Workflow {
            name: j.req_str("name")?.to_string(),
            user: j.req_str("user")?.to_string(),
            nodes: j.req_u64("nodes")? as u32,
            steps: steps?,
        })
    }
}

/// Execution state of one workflow.
#[derive(Debug)]
pub struct WorkflowRun {
    pub id: u64,
    pub workflow: Workflow,
    /// LSF job per already-submitted step.
    pub jobs: Vec<LsfJobId>,
    pub aborted: bool,
}

impl WorkflowRun {
    pub fn new(id: u64, workflow: Workflow) -> WorkflowRun {
        WorkflowRun {
            id,
            workflow,
            jobs: Vec::new(),
            aborted: false,
        }
    }

    /// Advance: submit the next step if the previous one finished cleanly.
    /// Called from the API pump with the stack lock held.
    pub fn advance(&mut self, stack: &mut Stack) {
        if self.aborted || self.jobs.len() >= self.workflow.steps.len() + 1 {
            return;
        }
        // Check the last submitted step.
        if let Some(&last) = self.jobs.last() {
            match stack.lsf.status(last).map(|j| j.state) {
                Some(JobState::Done) => {}
                Some(s) if s.is_terminal() => {
                    self.aborted = true; // failed or killed → stop the flow
                    return;
                }
                _ => return, // still pending/running
            }
        }
        let next_idx = self.jobs.len();
        if next_idx >= self.workflow.steps.len() {
            return; // all done
        }
        let payload = self.workflow.steps[next_idx].clone();
        match stack.submit(self.workflow.nodes, &self.workflow.user, payload) {
            Ok(id) => self.jobs.push(id),
            Err(_) => self.aborted = true,
        }
    }

    /// Finished successfully?
    pub fn is_complete(&self, stack: &Stack) -> bool {
        !self.aborted
            && self.jobs.len() == self.workflow.steps.len()
            && self
                .jobs
                .iter()
                .all(|&j| stack.lsf.status(j).map(|x| x.state) == Some(JobState::Done))
    }

    pub fn to_json(&self, stack: &Stack) -> Json {
        let steps: Vec<Json> = self
            .workflow
            .steps
            .iter()
            .enumerate()
            .map(|(i, p)| {
                let mut fields = vec![
                    ("step", Json::num(i as f64)),
                    ("type", Json::str(p.kind())),
                ];
                if let Some(&job) = self.jobs.get(i) {
                    fields.push(("job", Json::num(job.0 as f64)));
                    if let Some(j) = stack.lsf.status(job) {
                        fields.push(("state", Json::str(j.state.lsf_name())));
                    }
                } else {
                    fields.push(("state", Json::str("WAITING")));
                }
                Json::obj(fields)
            })
            .collect();
        Json::obj(vec![
            ("workflow", Json::num(self.id as f64)),
            ("name", Json::str(&*self.workflow.name)),
            ("aborted", Json::Bool(self.aborted)),
            ("complete", Json::Bool(self.is_complete(stack))),
            ("steps", Json::Arr(steps)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::StackConfig;
    use crate::lustre::Dfs as _;

    fn teragen(dir: &str) -> AppPayload {
        AppPayload::Teragen {
            rows: 400,
            maps: 2,
            dir: dir.to_string(),
        }
    }

    #[test]
    fn steps_run_in_order() {
        let mut stack = Stack::new(StackConfig::tiny()).unwrap();
        let wf = Workflow {
            name: "pipeline".into(),
            user: "sid".into(),
            nodes: 4,
            steps: vec![
                teragen("/lustre/scratch/wf-a"),
                teragen("/lustre/scratch/wf-b"),
            ],
        };
        let mut run = WorkflowRun::new(0, wf);
        run.advance(&mut stack);
        assert_eq!(run.jobs.len(), 1);
        // Step 2 must not be submitted before step 1 completes.
        run.advance(&mut stack);
        assert_eq!(run.jobs.len(), 1);
        stack.tick(); // runs step 1
        run.advance(&mut stack);
        assert_eq!(run.jobs.len(), 2);
        stack.tick();
        assert!(run.is_complete(&stack));
        assert!(stack.dfs.exists("/lustre/scratch/wf-a/_SUCCESS"));
        assert!(stack.dfs.exists("/lustre/scratch/wf-b/_SUCCESS"));
    }

    #[test]
    fn failed_step_aborts_flow() {
        let mut stack = Stack::new(StackConfig::tiny()).unwrap();
        let wf = Workflow {
            name: "broken".into(),
            user: "sid".into(),
            nodes: 4,
            steps: vec![
                AppPayload::HiveQuery {
                    sql: "SELECT COUNT(a) FROM '/lustre/scratch/missing' SCHEMA (a) INTO '/lustre/scratch/wf-x'".into(),
                    reduces: 1,
                },
                teragen("/lustre/scratch/wf-never"),
            ],
        };
        let mut run = WorkflowRun::new(0, wf);
        run.advance(&mut stack);
        stack.tick(); // step 1 fails
        run.advance(&mut stack);
        assert!(run.aborted);
        assert_eq!(run.jobs.len(), 1);
        assert!(!stack.dfs.exists("/lustre/scratch/wf-never"));
    }

    #[test]
    fn json_round_trip() {
        let j = Json::parse(
            r#"{"name":"wf","user":"u","nodes":4,
                "steps":[{"type":"teragen","rows":10,"maps":1,"dir":"/d"}]}"#,
        )
        .unwrap();
        let wf = Workflow::from_json(&j).unwrap();
        assert_eq!(wf.steps.len(), 1);
        assert_eq!(wf.steps[0].kind(), "teragen");
        assert!(Workflow::from_json(&Json::parse(r#"{"name":"x","user":"u","nodes":1,"steps":[]}"#).unwrap()).is_err());
    }
}
