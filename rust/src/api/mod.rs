//! The access layer: the full HPC Wales submission flow (§III Fig 1).
//!
//! * [`stack`] — the in-process orchestrator: LSF → wrapper → YARN → app →
//!   teardown, the end-to-end flow of steps 3–5.
//! * [`http`] — a minimal HTTP/1.1 server on `std::net` (no tokio in the
//!   vendored environment).
//! * [`server`] — the REST surface (steps 1–2 and 6: submit / status /
//!   terminate / data access without SSH).
//! * [`synfiniway`] — workflow definitions: named multi-step flows, the
//!   SynfiniWay analog.
//! * [`client`] — the Rust client API ("APIs in multiple languages" —
//!   this is the reference implementation; the wire format is plain JSON
//!   over HTTP so other languages follow).

pub mod client;
pub mod http;
pub mod server;
pub mod stack;
pub mod synfiniway;

pub use client::ApiClient;
pub use server::ApiServer;
pub use stack::{AppPayload, AppResult, Stack};
pub use synfiniway::{Workflow, WorkflowRun};
