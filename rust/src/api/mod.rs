//! The access layer: the full HPC Wales submission flow (§III Fig 1),
//! exposed as a versioned, event-capable REST API. The endpoint-by-
//! endpoint contract lives in `docs/API.md`.
//!
//! * [`wire`] — the single source of truth for the v1 wire protocol:
//!   every request/response document as a typed struct with
//!   `to_json`/`from_json`, stable error codes, and the conformance
//!   vectors shared with the Python client.
//! * [`stack`] — the in-process orchestrator: LSF → wrapper → YARN →
//!   app → teardown, the end-to-end flow of steps 3–5.
//! * [`http`] — a minimal, hardened HTTP/1.1 server on `std::net` (no
//!   tokio in the vendored environment).
//! * [`server`] — the `/v1` REST surface (steps 1–2 and 6: submit /
//!   status / terminate / data access without SSH), long-poll waits and
//!   the monotonic event journal.
//! * [`synfiniway`] — workflow execution: named-step DAGs with retry
//!   policies and `${steps.<name>.output_dir}` chaining, the SynfiniWay
//!   analog.
//! * [`client`] — the Rust client API ("APIs in multiple languages" —
//!   the reference implementation; `python/hpcw_client/` is the Python
//!   port, pinned to the same `python/tests/vectors.json`).

pub mod client;
pub mod http;
pub mod server;
pub mod stack;
pub mod synfiniway;
pub mod wire;

pub use client::ApiClient;
pub use server::ApiServer;
pub use stack::{parse_query_text, AppPayload, AppResult, Stack};
pub use synfiniway::{query_workflow, Workflow, WorkflowRun};
pub use wire::{
    ErrorDoc, EventDoc, EventPage, JobDoc, JobsPage, ResultDoc, StepSpec, StepState,
    SubmitRequest, WorkflowDoc, WorkflowSpec,
};
