//! The REST surface: submit / status / terminate / data access (§III
//! steps 1, 2 and 6 — "the traditional means of HPC access do not become a
//! bottleneck").
//!
//! Endpoints:
//! * `POST /jobs` `{nodes, user, payload}` → `{job}`
//! * `GET /jobs` → list; `GET /jobs/{id}` → state + result
//! * `DELETE /jobs/{id}` → bkill
//! * `GET /jobs/{id}/output?path=...` → raw bytes off Lustre
//! * `POST /workflows` → SynfiniWay-style multi-step flow
//! * `GET /workflows/{id}` → per-step progress
//! * `GET /metrics` → text metrics dump
//!
//! A pump thread drives `Stack::tick` and workflow advancement; handlers
//! only mutate queue state, so requests stay fast.

use crate::api::http::{self, Request, Response};
use crate::api::stack::{AppPayload, AppResult, Stack};
use crate::api::synfiniway::{Workflow, WorkflowRun};
use crate::codec::json::Json;
use crate::error::{Error, Result};
use crate::scheduler::JobState;
use crate::util::ids::LsfJobId;
use std::net::TcpListener;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

/// Shared server state.
struct State {
    stack: Mutex<Stack>,
    workflows: Mutex<Vec<WorkflowRun>>,
}

/// The API server handle.
pub struct ApiServer {
    pub addr: String,
    stop: Arc<AtomicBool>,
    serve_thread: Option<std::thread::JoinHandle<()>>,
    pump_thread: Option<std::thread::JoinHandle<()>>,
}

impl ApiServer {
    /// Bind on an ephemeral loopback port and start serving `stack`.
    pub fn start(stack: Stack) -> Result<ApiServer> {
        let listener = TcpListener::bind("127.0.0.1:0")?;
        let addr = listener.local_addr()?.to_string();
        let state = Arc::new(State {
            stack: Mutex::new(stack),
            workflows: Mutex::new(Vec::new()),
        });
        let stop = Arc::new(AtomicBool::new(false));

        // Pump: dispatch cycles + workflow advancement.
        let pump_state = Arc::clone(&state);
        let pump_stop = Arc::clone(&stop);
        let pump_thread = std::thread::Builder::new()
            .name("hpcw-api-pump".into())
            .spawn(move || {
                while !pump_stop.load(Ordering::Relaxed) {
                    {
                        let mut stack = pump_state.stack.lock().unwrap();
                        stack.tick();
                        let mut wfs = pump_state.workflows.lock().unwrap();
                        for wf in wfs.iter_mut() {
                            wf.advance(&mut stack);
                        }
                    }
                    std::thread::sleep(std::time::Duration::from_millis(20));
                }
            })
            .map_err(|e| Error::Api(format!("spawn pump: {e}")))?;

        let handler_state = Arc::clone(&state);
        let handler: Arc<dyn Fn(Request) -> Response + Send + Sync> =
            Arc::new(move |req| route(&handler_state, req));
        let serve_stop = Arc::clone(&stop);
        let serve_thread = std::thread::Builder::new()
            .name("hpcw-api".into())
            .spawn(move || http::serve(listener, serve_stop, handler))
            .map_err(|e| Error::Api(format!("spawn server: {e}")))?;

        Ok(ApiServer {
            addr,
            stop,
            serve_thread: Some(serve_thread),
            pump_thread: Some(pump_thread),
        })
    }

    pub fn shutdown(mut self) {
        self.stop_now();
    }

    fn stop_now(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.serve_thread.take() {
            let _ = t.join();
        }
        if let Some(t) = self.pump_thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for ApiServer {
    fn drop(&mut self) {
        self.stop_now();
    }
}

fn route(state: &State, req: Request) -> Response {
    let segs = req.segments();
    let result = match (req.method.as_str(), segs.as_slice()) {
        ("POST", ["jobs"]) => post_job(state, &req),
        ("GET", ["jobs"]) => list_jobs(state),
        ("GET", ["jobs", id]) => get_job(state, id),
        ("DELETE", ["jobs", id]) => delete_job(state, id),
        ("GET", ["jobs", _id, "output"]) => get_output(state, &req),
        ("POST", ["workflows"]) => post_workflow(state, &req),
        ("GET", ["workflows", id]) => get_workflow(state, id),
        ("GET", ["metrics"]) => {
            let stack = state.stack.lock().unwrap();
            return Response {
                status: 200,
                content_type: "text/plain",
                body: stack.metrics.render().into_bytes(),
            };
        }
        _ => Err(Error::Api(format!("no route {} {}", req.method, req.path))),
    };
    match result {
        Ok(resp) => resp,
        Err(e) => {
            let status = match e {
                Error::Api(ref m) if m.starts_with("no route") => 404,
                Error::Api(ref m) if m.contains("unknown job") => 404,
                _ => 400,
            };
            Response::json(
                status,
                Json::obj(vec![
                    ("error", Json::str(e.to_string())),
                    ("kind", Json::str(e.kind())),
                ])
                .to_string(),
            )
        }
    }
}

/// Parse an [`AppPayload`] from its JSON form.
pub fn payload_from_json(j: &Json) -> Result<AppPayload> {
    match j.req_str("type")? {
        "terasort" => Ok(AppPayload::Terasort {
            rows: j.req_u64("rows")?,
            maps: j.req_u64("maps")?,
            reduces: j.req_u64("reduces")? as u32,
            use_kernel: j.get("use_kernel").and_then(Json::as_bool).unwrap_or(false),
        }),
        "teragen" => Ok(AppPayload::Teragen {
            rows: j.req_u64("rows")?,
            maps: j.req_u64("maps")?,
            dir: j.req_str("dir")?.to_string(),
        }),
        "pig" => Ok(AppPayload::PigScript {
            script: j.req_str("script")?.to_string(),
            reduces: j.req_u64("reduces")? as u32,
        }),
        "hive" => Ok(AppPayload::HiveQuery {
            sql: j.req_str("sql")?.to_string(),
            reduces: j.req_u64("reduces")? as u32,
        }),
        "rsummary" => {
            let strs = |key: &str| -> Result<Vec<String>> {
                j.get(key)
                    .and_then(Json::as_arr)
                    .map(|a| {
                        a.iter()
                            .filter_map(Json::as_str)
                            .map(str::to_string)
                            .collect()
                    })
                    .ok_or_else(|| Error::Codec(format!("missing array '{key}'")))
            };
            Ok(AppPayload::RSummary {
                input_dir: j.req_str("input_dir")?.to_string(),
                output_dir: j.req_str("output_dir")?.to_string(),
                fields: strs("fields")?,
                delimiter: j
                    .get("delimiter")
                    .and_then(Json::as_str)
                    .and_then(|s| s.chars().next())
                    .unwrap_or(','),
                columns: strs("columns")?,
            })
        }
        other => Err(Error::Api(format!("unknown payload type '{other}'"))),
    }
}

/// Serialize an [`AppResult`].
pub fn result_to_json(r: &AppResult) -> Json {
    Json::obj(vec![
        ("kind", Json::str(r.kind)),
        ("output_dir", Json::str(&*r.output_dir)),
        (
            "output_files",
            Json::Arr(r.output_files.iter().map(|f| Json::str(&**f)).collect()),
        ),
        ("records", Json::num(r.records as f64)),
        ("validated", Json::Bool(r.validated)),
        ("wall_ms", Json::num(r.wall.as_millis() as f64)),
        (
            "counters",
            Json::Obj(
                r.counters
                    .iter()
                    .map(|(k, v)| (k.clone(), Json::num(*v as f64)))
                    .collect(),
            ),
        ),
    ])
}

fn job_state_str(s: JobState) -> &'static str {
    s.lsf_name()
}

fn parse_job_id(text: &str) -> Result<LsfJobId> {
    text.parse::<u64>()
        .map(LsfJobId)
        .map_err(|_| Error::Api(format!("bad job id '{text}'")))
}

fn post_job(state: &State, req: &Request) -> Result<Response> {
    let j = Json::parse(req.body_text()?)?;
    let nodes = j.req_u64("nodes")? as u32;
    let user = j.req_str("user")?.to_string();
    let payload = payload_from_json(
        j.get("payload")
            .ok_or_else(|| Error::Api("missing payload".into()))?,
    )?;
    let mut stack = state.stack.lock().unwrap();
    let id = stack.submit(nodes, &user, payload)?;
    Ok(Response::json(
        201,
        Json::obj(vec![("job", Json::num(id.0 as f64))]).to_string(),
    ))
}

fn list_jobs(state: &State) -> Result<Response> {
    let stack = state.stack.lock().unwrap();
    let jobs: Vec<Json> = stack
        .jobs()
        .into_iter()
        .map(|(id, kind, s)| {
            Json::obj(vec![
                ("job", Json::num(id.0 as f64)),
                ("kind", Json::str(kind)),
                ("state", Json::str(job_state_str(s))),
            ])
        })
        .collect();
    Ok(Response::json(200, Json::Arr(jobs).to_string()))
}

fn get_job(state: &State, id: &str) -> Result<Response> {
    let id = parse_job_id(id)?;
    let stack = state.stack.lock().unwrap();
    let (job_state, result) = stack
        .job_state(id)
        .ok_or_else(|| Error::Api(format!("unknown job {id}")))?;
    let mut fields = vec![
        ("job", Json::num(id.0 as f64)),
        ("state", Json::str(job_state_str(job_state))),
    ];
    if let Some(r) = result {
        fields.push(("result", result_to_json(r)));
    }
    if let Some(e) = stack.job_error(id) {
        fields.push(("error", Json::str(e)));
    }
    Ok(Response::json(200, Json::obj(fields).to_string()))
}

fn delete_job(state: &State, id: &str) -> Result<Response> {
    let id = parse_job_id(id)?;
    let mut stack = state.stack.lock().unwrap();
    stack.kill(id)?;
    Ok(Response::json(
        200,
        Json::obj(vec![("killed", Json::num(id.0 as f64))]).to_string(),
    ))
}

fn get_output(state: &State, req: &Request) -> Result<Response> {
    let query = req.path.split('?').nth(1).unwrap_or("");
    let path = query
        .split('&')
        .find_map(|kv| kv.strip_prefix("path="))
        .ok_or_else(|| Error::Api("missing ?path=".into()))?;
    let stack = state.stack.lock().unwrap();
    let bytes = stack.read_output(path)?;
    Ok(Response::bytes(200, bytes))
}

fn post_workflow(state: &State, req: &Request) -> Result<Response> {
    let j = Json::parse(req.body_text()?)?;
    let wf = Workflow::from_json(&j)?;
    let mut wfs = state.workflows.lock().unwrap();
    let id = wfs.len() as u64;
    let mut run = WorkflowRun::new(id, wf);
    {
        // Kick off the first step immediately.
        let mut stack = state.stack.lock().unwrap();
        run.advance(&mut stack);
    }
    wfs.push(run);
    Ok(Response::json(
        201,
        Json::obj(vec![("workflow", Json::num(id as f64))]).to_string(),
    ))
}

fn get_workflow(state: &State, id: &str) -> Result<Response> {
    let id: usize = id
        .parse()
        .map_err(|_| Error::Api(format!("bad workflow id '{id}'")))?;
    let wfs = state.workflows.lock().unwrap();
    let wf = wfs
        .get(id)
        .ok_or_else(|| Error::Api(format!("unknown job workflow {id}")))?;
    let stack = state.stack.lock().unwrap();
    Ok(Response::json(200, wf.to_json(&stack).to_string()))
}
