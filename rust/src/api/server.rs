//! The versioned REST surface (§III steps 1, 2 and 6 — "the traditional
//! means of HPC access do not become a bottleneck").
//!
//! All routes live under `/v1` and speak the typed wire schema from
//! [`crate::api::wire`] — see `docs/API.md` for the full spec:
//!
//! * `POST /v1/jobs` `SubmitRequest` → `{job}`
//! * `GET /v1/jobs?offset=&limit=` → `JobsPage`
//! * `GET /v1/jobs/{id}[?wait_ms=N]` → `JobDoc` (long-poll until terminal)
//! * `DELETE /v1/jobs/{id}` → bkill
//! * `GET /v1/jobs/{id}/output?path=...` → raw bytes, confined to the
//!   job's output root (`bad_path` on traversal attempts)
//! * `POST /v1/workflows` `WorkflowSpec` (named-step DAG) → `{workflow}`
//! * `POST /v1/queries` `{engine,text,reduces,nodes,user[,mode]}` →
//!   `{job}` (one cluster, chained stages) or, with `mode:"workflow"`,
//!   `{workflow}` (one `query_stage` step per MR job)
//! * `GET /v1/workflows/{id}[?wait_ms=N]` → `WorkflowDoc`
//! * `POST /v1/scenarios` `ScenarioSpec` → `{scenario}` (validated, then
//!   queued; the pump runs the simulation and scores it)
//! * `GET /v1/scenarios?offset=&limit=` → `ScenariosPage` (rows omit the
//!   score)
//! * `GET /v1/scenarios/{id}[?wait_ms=N]` → `ScenarioDoc` (long-poll
//!   until scored)
//! * `GET /v1/events?since=seq[&wait_ms=N]` → `EventPage`, the monotonic
//!   journal of job/workflow/step/scenario transitions
//! * `GET /v1/metrics` → text metrics dump
//!
//! Unversioned legacy paths answer `301 Moved Permanently` with
//! `Location: /v1/...` and a `Deprecation: true` header.
//!
//! A pump thread drives `Stack::tick` and workflow advancement. It is
//! event-driven: handlers only mutate queue state and wake the pump via a
//! condvar (no fixed-interval sleep), and the pump publishes every state
//! transition to the event journal, which in turn wakes long-pollers —
//! `wait` costs O(transitions) requests instead of O(time/poll-interval).

use crate::api::http::{self, Request, Response, ServeStats};
use crate::api::stack::Stack;
use crate::api::synfiniway::WorkflowRun;
use crate::api::wire::{
    self, code, scenario_spec_from_json, ErrorDoc, EventDoc, EventPage, JobDoc, JobsPage,
    QueueDoc, ResultDoc, ScenarioDoc, ScenarioState, ScenariosPage, SubmitRequest, TenantDoc,
    WorkflowDoc, WorkflowSpec,
};
use crate::codec::json::Json;
use crate::error::Error;
use crate::metrics::Metrics;
use crate::scenario::{Runner, ScenarioSpec, ScoreDoc};
use crate::scheduler::JobState;
use crate::tenant::{AdmissionError, Tenant, TenantRegistry};
use crate::util::ids::LsfJobId;
use std::collections::BTreeMap;
use std::collections::VecDeque;
use std::net::TcpListener;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Longest server-side long-poll slice; clients re-arm for longer waits.
const MAX_WAIT_MS: u64 = 10_000;
/// Event journal retention; older events are dropped (the `next` cursor
/// lets clients detect and resync).
const EVENT_CAP: usize = 4096;
/// Pump fallback wakeup when idle (safety net only; submissions wake it).
const IDLE_TICK: Duration = Duration::from_millis(250);

/// A condvar-guarded generation counter: `notify` bumps it, `wait_past`
/// blocks until it moves past a seen value or the deadline passes.
struct Signal {
    gen: Mutex<u64>,
    cv: Condvar,
}

impl Signal {
    fn new() -> Signal {
        Signal {
            gen: Mutex::new(0),
            cv: Condvar::new(),
        }
    }

    fn notify(&self) {
        *self.gen.lock().unwrap() += 1;
        self.cv.notify_all();
    }

    /// Wait until the generation exceeds `seen` or `timeout` elapses;
    /// returns the current generation.
    fn wait_past(&self, seen: u64, timeout: Duration) -> u64 {
        let deadline = Instant::now() + timeout;
        let mut g = self.gen.lock().unwrap();
        while *g <= seen {
            let left = deadline.saturating_duration_since(Instant::now());
            if left.is_zero() {
                break;
            }
            let (guard, _) = self.cv.wait_timeout(g, left).unwrap();
            g = guard;
        }
        *g
    }
}

/// The monotonic event journal plus its change condvar.
struct EventBus {
    inner: Mutex<EventLog>,
    cv: Condvar,
    metrics: Arc<Metrics>,
}

struct EventLog {
    events: VecDeque<EventDoc>,
    next_seq: u64,
}

impl EventBus {
    fn new(metrics: Arc<Metrics>) -> EventBus {
        EventBus {
            inner: Mutex::new(EventLog {
                events: VecDeque::new(),
                next_seq: 1,
            }),
            cv: Condvar::new(),
            metrics,
        }
    }

    fn emit(&self, kind: &str, id: u64, state: String, step: Option<String>) {
        let mut log = self.inner.lock().unwrap();
        let seq = log.next_seq;
        log.next_seq += 1;
        log.events.push_back(EventDoc {
            seq,
            kind: kind.to_string(),
            id,
            state,
            step,
        });
        while log.events.len() > EVENT_CAP {
            log.events.pop_front();
        }
        drop(log);
        self.metrics.inc("api.events_emitted", 1);
        self.cv.notify_all();
    }

    /// Events with `seq > since` plus the cursor for the next call.
    fn since(&self, since: u64) -> EventPage {
        let log = self.inner.lock().unwrap();
        let events: Vec<EventDoc> = log
            .events
            .iter()
            .filter(|e| e.seq > since)
            .cloned()
            .collect();
        let next = events.last().map(|e| e.seq).unwrap_or(since);
        EventPage { events, next }
    }

    /// Highest published sequence number.
    fn seq(&self) -> u64 {
        self.inner.lock().unwrap().next_seq - 1
    }

    /// Block until any event lands past `seen` or the deadline passes.
    fn wait_change(&self, seen: u64, deadline: Instant, stop: &AtomicBool) {
        let mut log = self.inner.lock().unwrap();
        while log.next_seq - 1 <= seen && !stop.load(Ordering::Relaxed) {
            let left = deadline.saturating_duration_since(Instant::now());
            if left.is_zero() {
                return;
            }
            let (guard, _) = self.cv.wait_timeout(log, left.min(Duration::from_millis(500))).unwrap();
            log = guard;
        }
    }
}

/// One submitted scenario and its lifecycle. The index into
/// `State::scenarios` is the wire id.
struct ScenarioRun {
    spec: ScenarioSpec,
    state: ScenarioState,
    score: Option<ScoreDoc>,
    error: Option<String>,
}

impl ScenarioRun {
    fn to_doc(&self, id: u64, with_score: bool) -> ScenarioDoc {
        ScenarioDoc {
            scenario: id,
            name: self.spec.name.clone(),
            policy: self.spec.policy.clone(),
            state: self.state,
            score: if with_score { self.score.clone() } else { None },
            error: self.error.clone(),
        }
    }
}

/// Shared server state.
struct State {
    stack: Mutex<Stack>,
    workflows: Mutex<Vec<WorkflowRun>>,
    scenarios: Mutex<Vec<ScenarioRun>>,
    events: EventBus,
    /// Wakes the pump on submissions / kills.
    work: Signal,
    metrics: Arc<Metrics>,
    /// Multi-tenant front door (shared with the stack's scheduler).
    tenants: Arc<TenantRegistry>,
    /// Bounded-accept-queue counters from the HTTP worker pool.
    serve_stats: Arc<ServeStats>,
    stop: Arc<AtomicBool>,
}

/// The API server handle.
pub struct ApiServer {
    pub addr: String,
    stop: Arc<AtomicBool>,
    state: Arc<State>,
    serve_thread: Option<std::thread::JoinHandle<()>>,
    pump_thread: Option<std::thread::JoinHandle<()>>,
}

impl ApiServer {
    /// Bind on an ephemeral loopback port and start serving `stack`.
    pub fn start(stack: Stack) -> crate::error::Result<ApiServer> {
        let listener = TcpListener::bind("127.0.0.1:0")?;
        let addr = listener.local_addr()?.to_string();
        let metrics = Arc::clone(&stack.metrics);
        let tenants = Arc::clone(&stack.tenants);
        let http_workers = stack.cfg.tenant.http_workers.max(1) as usize;
        let accept_queue = stack.cfg.tenant.accept_queue.max(1) as usize;
        let serve_stats = Arc::new(ServeStats::default());
        let stop = Arc::new(AtomicBool::new(false));
        let state = Arc::new(State {
            stack: Mutex::new(stack),
            workflows: Mutex::new(Vec::new()),
            scenarios: Mutex::new(Vec::new()),
            events: EventBus::new(Arc::clone(&metrics)),
            work: Signal::new(),
            metrics,
            tenants,
            serve_stats: Arc::clone(&serve_stats),
            stop: Arc::clone(&stop),
        });

        // Pump: dispatch cycles + workflow advancement + event publishing.
        let pump_state = Arc::clone(&state);
        let pump_stop = Arc::clone(&stop);
        let pump_thread = std::thread::Builder::new()
            .name("hpcw-api-pump".into())
            .spawn(move || pump(pump_state, pump_stop))
            .map_err(|e| Error::Api(format!("spawn pump: {e}")))?;

        let handler_state = Arc::clone(&state);
        let handler: Arc<dyn Fn(Request) -> Response + Send + Sync> =
            Arc::new(move |req| route(&handler_state, req));
        let serve_stop = Arc::clone(&stop);
        let serve_thread = std::thread::Builder::new()
            .name("hpcw-api".into())
            .spawn(move || {
                http::serve_pool(
                    listener,
                    serve_stop,
                    handler,
                    http_workers,
                    accept_queue,
                    serve_stats,
                )
            })
            .map_err(|e| Error::Api(format!("spawn server: {e}")))?;

        Ok(ApiServer {
            addr,
            stop,
            state,
            serve_thread: Some(serve_thread),
            pump_thread: Some(pump_thread),
        })
    }

    pub fn shutdown(mut self) {
        self.stop_now();
    }

    fn stop_now(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        // Wake the pump and every long-poller so they observe `stop`.
        self.state.work.notify();
        self.state.events.cv.notify_all();
        if let Some(t) = self.serve_thread.take() {
            let _ = t.join();
        }
        if let Some(t) = self.pump_thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for ApiServer {
    fn drop(&mut self) {
        self.stop_now();
    }
}

/// The event-driven pump. While jobs or workflows are live it runs
/// dispatch cycles back to back (each `tick` performs real work in Real
/// mode); when everything is terminal it sleeps on the `work` condvar
/// until a handler submits or kills something.
fn pump(state: Arc<State>, stop: Arc<AtomicBool>) {
    let mut known: BTreeMap<u64, JobState> = BTreeMap::new();
    let mut work_gen = 0u64;
    while !stop.load(Ordering::Relaxed) {
        let active = {
            let mut stack = state.stack.lock().unwrap();
            stack.tick();
            let mut wfs = state.workflows.lock().unwrap();
            for wf in wfs.iter_mut() {
                let before_terminal = wf.is_terminal();
                for t in wf.advance(&mut stack) {
                    state.events.emit(
                        "step",
                        wf.id,
                        t.state.as_wire().to_string(),
                        Some(t.step),
                    );
                }
                if !before_terminal && wf.is_terminal() {
                    let state_str = if wf.is_complete() { "COMPLETE" } else { "ABORTED" };
                    state.events.emit("workflow", wf.id, state_str.to_string(), None);
                }
            }
            // Publish observed job transitions.
            for j in stack.lsf.jobs() {
                let id = j.id.0;
                if known.get(&id) != Some(&j.state) {
                    known.insert(id, j.state);
                    state
                        .events
                        .emit("job", id, wire::job_state_to_wire(j.state).to_string(), None);
                }
            }
            stack.has_active_jobs() || wfs.iter().any(|w| !w.is_terminal())
        };
        run_pending_scenarios(&state);
        if !active {
            work_gen = state.work.wait_past(work_gen, IDLE_TICK);
        }
    }
}

/// Run any pending scenarios to completion. A scenario simulates its own
/// `DynamicCluster` (bounded to 100k control ticks by spec validation),
/// so it runs synchronously here — but OUTSIDE the stack lock, so jobs
/// and long-pollers are never blocked behind a simulation. Lifecycle
/// transitions land in the event journal (kind `scenario`), which wakes
/// `GET /v1/scenarios/{id}?wait_ms=` pollers.
fn run_pending_scenarios(state: &State) {
    loop {
        let (id, spec) = {
            let mut runs = state.scenarios.lock().unwrap();
            match runs.iter().position(|r| r.state == ScenarioState::Pending) {
                None => return,
                Some(i) => {
                    runs[i].state = ScenarioState::Running;
                    (i as u64, runs[i].spec.clone())
                }
            }
        };
        state
            .events
            .emit("scenario", id, ScenarioState::Running.as_wire().to_string(), None);
        let result = Runner::run(spec);
        let final_state = {
            let mut runs = state.scenarios.lock().unwrap();
            let run = &mut runs[id as usize];
            let final_state = match result {
                Ok(score) => {
                    run.score = Some(score);
                    ScenarioState::Done
                }
                Err(e) => {
                    run.error = Some(e.to_string());
                    ScenarioState::Failed
                }
            };
            run.state = final_state;
            final_state
        };
        state
            .events
            .emit("scenario", id, final_state.as_wire().to_string(), None);
    }
}

// ---------------------------------------------------------------------------
// Routing
// ---------------------------------------------------------------------------

type HandlerResult = std::result::Result<Response, ErrorDoc>;

fn error_response(e: &ErrorDoc) -> Response {
    Response::json(e.http_status(), e.to_json().to_string())
}

fn route(state: &State, req: Request) -> Response {
    let t0 = Instant::now();
    state.metrics.inc("api.requests", 1);
    // Identity first, for EVERY route — including the legacy 301 arm, so
    // a deprecated path is never a side door around the front door.
    let tenant = match state
        .tenants
        .authenticate(req.headers.get("x-hpcw-key").map(String::as_str))
    {
        Ok(t) => t,
        Err(e) => {
            state.metrics.inc("api.requests.unauthorized", 1);
            return admission_response(&e);
        }
    };
    let segs = req.segments();
    let (endpoint, result): (&str, HandlerResult) = match (req.method.as_str(), segs.as_slice()) {
        ("POST", ["v1", "jobs"]) => ("post_job", post_job(state, &req, &tenant)),
        ("GET", ["v1", "jobs"]) => ("list_jobs", list_jobs(state, &req)),
        ("GET", ["v1", "jobs", id]) => ("get_job", get_job(state, &req, id)),
        ("DELETE", ["v1", "jobs", id]) => ("delete_job", delete_job(state, id)),
        ("GET", ["v1", "jobs", id, "output"]) => ("get_output", get_output(state, &req, id)),
        ("POST", ["v1", "workflows"]) => ("post_workflow", post_workflow(state, &req, &tenant)),
        ("POST", ["v1", "queries"]) => ("post_query", post_query(state, &req, &tenant)),
        ("GET", ["v1", "workflows", id]) => ("get_workflow", get_workflow(state, &req, id)),
        ("POST", ["v1", "scenarios"]) => ("post_scenario", post_scenario(state, &req, &tenant)),
        ("GET", ["v1", "scenarios"]) => ("list_scenarios", list_scenarios(state, &req)),
        ("GET", ["v1", "scenarios", id]) => ("get_scenario", get_scenario(state, &req, id)),
        ("GET", ["v1", "cluster"]) => ("get_cluster", get_cluster(state)),
        ("POST", ["v1", "cluster", "nodes", id, action]) => {
            ("post_node_action", post_node_action(state, id, action))
        }
        ("GET", ["v1", "events"]) => ("get_events", get_events(state, &req)),
        ("GET", ["v1", "metrics"]) => ("get_metrics", get_metrics(state)),
        ("GET", ["v1", "tenants"]) => ("get_tenants", get_tenants(state)),
        ("GET", ["v1", "queues"]) => ("get_queues", get_queues(state)),
        // Unversioned legacy paths: permanent redirect + Deprecation.
        // Submissions on them still pass full admission control first —
        // a 301 must never leak capacity past the quota/rate gate.
        (_, ["jobs", ..]) | (_, ["workflows", ..]) | (_, ["metrics"]) => {
            ("legacy", legacy_guarded(state, &req, &tenant))
        }
        _ => (
            "unrouted",
            Err(ErrorDoc::not_found(format!(
                "no route {} {}",
                req.method, req.path
            ))),
        ),
    };
    let response = match result {
        Ok(resp) => resp,
        Err(e) => error_response(&e),
    };
    state.metrics.inc(&format!("api.requests.{endpoint}"), 1);
    state.metrics.inc(
        &format!("api.latency_us.{endpoint}"),
        t0.elapsed().as_micros() as u64,
    );
    response
}

/// Map an admission rejection onto the wire error taxonomy. The breaker
/// presents as `rate_limited`: from the caller's perspective both are
/// "server-imposed rate of zero, retry later".
fn admission_error_doc(e: &AdmissionError) -> ErrorDoc {
    match e {
        AdmissionError::Unauthorized => ErrorDoc::new(
            code::UNAUTHORIZED,
            "missing or unknown X-HPCW-Key",
        ),
        AdmissionError::RateLimited { .. } => ErrorDoc::new(
            code::RATE_LIMITED,
            format!(
                "submission rate limit exceeded; retry after {}s",
                e.retry_after_s().unwrap_or(1)
            ),
        ),
        AdmissionError::CircuitOpen { .. } => ErrorDoc::new(
            code::RATE_LIMITED,
            format!(
                "circuit breaker open after repeated job failures; retry after {}s",
                e.retry_after_s().unwrap_or(1)
            ),
        ),
        AdmissionError::QuotaExceeded { detail } => {
            ErrorDoc::new(code::QUOTA_EXCEEDED, detail.clone())
        }
    }
}

/// The full rejection response, with `Retry-After` where meaningful.
fn admission_response(e: &AdmissionError) -> Response {
    let doc = admission_error_doc(e);
    let mut resp = Response::json(doc.http_status(), doc.to_json().to_string());
    if let Some(s) = e.retry_after_s() {
        resp = resp.with_header("Retry-After", &s.to_string());
    }
    resp
}

/// Legacy unversioned paths: a submission must clear the same admission
/// gate as its versioned target BEFORE being redirected — the 301 arm
/// was a side door past the rate/quota gate otherwise (the redirected
/// retry is charged its own token, like any other attempt).
fn legacy_guarded(state: &State, req: &Request, tenant: &Tenant) -> HandlerResult {
    let is_submission = req.method == "POST"
        && matches!(req.segments().as_slice(), ["jobs"] | ["workflows"]);
    if is_submission {
        let now = state.stack.lock().unwrap().now();
        if let Err(e) = state.tenants.admit_submit(&tenant.name, now) {
            return Ok(admission_response(&e));
        }
    }
    legacy_redirect(req)
}

fn legacy_redirect(req: &Request) -> HandlerResult {
    let target = format!("/v1{}", req.path);
    Ok(Response::json(
        301,
        ErrorDoc::new(
            code::DEPRECATED,
            format!("unversioned path is deprecated; use {target}"),
        )
        .to_json()
        .to_string(),
    )
    .with_header("Location", &target)
    .with_header("Deprecation", "true"))
}

fn bad_request(e: &Error) -> ErrorDoc {
    match e {
        Error::Api(m) if m.contains("unknown payload type") => {
            ErrorDoc::new(code::UNKNOWN_PAYLOAD, m.clone())
        }
        Error::Codec(m) if m.contains("byte") || m.contains("unterminated") => {
            ErrorDoc::new(code::BAD_JSON, m.clone())
        }
        _ => ErrorDoc::from(e),
    }
}

fn parse_body(req: &Request) -> std::result::Result<Json, ErrorDoc> {
    let text = req
        .body_text()
        .map_err(|_| ErrorDoc::new(code::BAD_JSON, "body is not valid UTF-8"))?;
    Json::parse(text).map_err(|e| ErrorDoc::new(code::BAD_JSON, e.to_string()))
}

fn parse_job_id(text: &str) -> std::result::Result<LsfJobId, ErrorDoc> {
    text.parse::<u64>()
        .map(LsfJobId)
        .map_err(|_| ErrorDoc::new(code::BAD_REQUEST, format!("bad job id '{text}'")))
}

fn wait_ms(req: &Request) -> u64 {
    req.query_param("wait_ms")
        .and_then(|v| v.parse().ok())
        .unwrap_or(0)
        .min(MAX_WAIT_MS)
}

/// Shared long-poll loop: re-`snapshot` until `done`, the deadline, or
/// shutdown. The event cursor is captured BEFORE each snapshot so a
/// transition landing in between re-wakes the wait instead of being lost.
fn long_poll<T>(
    state: &State,
    deadline: Instant,
    snapshot: impl Fn() -> std::result::Result<T, ErrorDoc>,
    done: impl Fn(&T) -> bool,
) -> std::result::Result<T, ErrorDoc> {
    let mut waited = false;
    loop {
        let seen = state.events.seq();
        let doc = snapshot()?;
        if done(&doc)
            || Instant::now() >= deadline
            || state.stop.load(Ordering::Relaxed)
        {
            return Ok(doc);
        }
        if !waited {
            state.metrics.inc("api.long_poll_waits", 1);
            waited = true;
        }
        state.events.wait_change(seen, deadline, &state.stop);
    }
}

// ---------------------------------------------------------------------------
// Handlers
// ---------------------------------------------------------------------------

/// The LSF user a submission is attributed to: under tenancy the
/// authenticated tenant (never the client-claimed body field — identity
/// comes from the key); otherwise the body's `user`.
fn effective_user<'a>(state: &State, tenant: &'a Tenant, claimed: &'a str) -> &'a str {
    if state.tenants.enabled() {
        tenant.name.as_str()
    } else {
        claimed
    }
}

fn post_job(state: &State, req: &Request, tenant: &Tenant) -> HandlerResult {
    let j = parse_body(req)?;
    let submit = SubmitRequest::from_json(&j).map_err(|e| bad_request(&e))?;
    let mut stack = state.stack.lock().unwrap();
    if let Err(e) = state.tenants.admit_submit(&tenant.name, stack.now()) {
        return Ok(admission_response(&e));
    }
    let user = effective_user(state, tenant, &submit.user).to_string();
    let id = stack
        .submit(submit.nodes, &user, submit.payload)
        .map_err(|e| bad_request(&e))?;
    drop(stack);
    state.work.notify();
    Ok(Response::json(
        201,
        Json::obj(vec![("job", Json::num(id.0 as f64))]).to_string(),
    ))
}

fn job_doc(stack: &Stack, id: LsfJobId, with_result: bool) -> std::result::Result<JobDoc, ErrorDoc> {
    let (job_state, result) = stack
        .job_state(id)
        .ok_or_else(|| ErrorDoc::not_found(format!("unknown job {id}")))?;
    Ok(JobDoc {
        job: id.0,
        kind: stack.job_kind(id).unwrap_or("plain").to_string(),
        state: job_state,
        result: if with_result {
            result.map(ResultDoc::from_result)
        } else {
            None
        },
        error: stack.job_error(id),
    })
}

fn list_jobs(state: &State, req: &Request) -> HandlerResult {
    let offset: u64 = req
        .query_param("offset")
        .and_then(|v| v.parse().ok())
        .unwrap_or(0);
    let limit: u64 = req
        .query_param("limit")
        .and_then(|v| v.parse().ok())
        .unwrap_or(50)
        .clamp(1, 500);
    let stack = state.stack.lock().unwrap();
    let mut ids: Vec<LsfJobId> = stack.lsf.jobs().map(|j| j.id).collect();
    ids.sort();
    let total = ids.len() as u64;
    let jobs = ids
        .into_iter()
        .skip(offset as usize)
        .take(limit as usize)
        .map(|id| job_doc(&stack, id, false))
        .collect::<std::result::Result<Vec<_>, _>>()?;
    let page = JobsPage {
        jobs,
        total,
        offset,
    };
    Ok(Response::json(200, page.to_json().to_string()))
}

fn get_job(state: &State, req: &Request, id: &str) -> HandlerResult {
    let id = parse_job_id(id)?;
    let deadline = Instant::now() + Duration::from_millis(wait_ms(req));
    let doc = long_poll(
        state,
        deadline,
        || job_doc(&state.stack.lock().unwrap(), id, true),
        JobDoc::is_terminal,
    )?;
    Ok(Response::json(200, doc.to_json().to_string()))
}

fn delete_job(state: &State, id: &str) -> HandlerResult {
    let id = parse_job_id(id)?;
    let mut stack = state.stack.lock().unwrap();
    stack.kill(id).map_err(|e| {
        let msg = e.to_string();
        if msg.contains("unknown job") {
            ErrorDoc::not_found(msg)
        } else {
            bad_request(&e)
        }
    })?;
    drop(stack);
    state.work.notify();
    Ok(Response::json(
        200,
        Json::obj(vec![("killed", Json::num(id.0 as f64))]).to_string(),
    ))
}

fn get_output(state: &State, req: &Request, id: &str) -> HandlerResult {
    let id = parse_job_id(id)?;
    let path = req
        .query_param("path")
        .ok_or_else(|| ErrorDoc::new(code::BAD_REQUEST, "missing ?path="))?;
    let stack = state.stack.lock().unwrap();
    let (job_state, result) = stack
        .job_state(id)
        .ok_or_else(|| ErrorDoc::not_found(format!("unknown job {id}")))?;
    let root = match result {
        Some(r) => r.output_dir.clone(),
        None => {
            return Err(ErrorDoc::new(
                code::NOT_READY,
                format!(
                    "job {id} has no output yet (state {})",
                    wire::job_state_to_wire(job_state)
                ),
            ))
        }
    };
    // Confine the read to the job's output root: `..` and absolute
    // escapes answer with the stable `bad_path` code.
    let full = wire::resolve_output_path(&root, &path)
        .map_err(|e| ErrorDoc::new(code::BAD_PATH, e.to_string()))?;
    let bytes = stack
        .read_output(&full)
        .map_err(|e| ErrorDoc::not_found(e.to_string()))?;
    Ok(Response::bytes(200, bytes))
}

fn post_workflow(state: &State, req: &Request, tenant: &Tenant) -> HandlerResult {
    let j = parse_body(req)?;
    let mut spec = WorkflowSpec::from_json(&j).map_err(|e| bad_request(&e))?;
    {
        let stack = state.stack.lock().unwrap();
        if let Err(e) = state.tenants.admit_submit(&tenant.name, stack.now()) {
            return Ok(admission_response(&e));
        }
    }
    spec.user = effective_user(state, tenant, &spec.user).to_string();
    let mut wfs = state.workflows.lock().unwrap();
    let id = wfs.len() as u64;
    wfs.push(WorkflowRun::new(id, spec));
    drop(wfs);
    state.work.notify();
    Ok(Response::json(
        201,
        Json::obj(vec![("workflow", Json::num(id as f64))]).to_string(),
    ))
}

/// `POST /v1/queries`: submit a Pig/Hive query text. Body:
/// `{engine, text, reduces, nodes, user[, mode][, explain]}`.
/// `explain: true` compiles the plan and answers the optimizer's stage
/// DAG (join strategy, fused ops, estimated input bytes) with 200 —
/// nothing runs and `nodes`/`user` are not required. Otherwise
/// `mode: "job"` (default) runs the stage chain on one dynamic cluster
/// and answers `{job}`; `mode: "workflow"` compiles the plan to a DAG of
/// `query_stage` steps and answers `{workflow}` — one LSF job per stage,
/// chained through `${steps.<name>.output_dir}` references.
fn post_query(state: &State, req: &Request, tenant: &Tenant) -> HandlerResult {
    let j = parse_body(req)?;
    let engine = j.req_str("engine").map_err(|e| bad_request(&e))?.to_string();
    let text = j.req_str("text").map_err(|e| bad_request(&e))?.to_string();
    let reduces = j.req_u64("reduces").map_err(|e| bad_request(&e))? as u32;
    if j.get("explain").and_then(Json::as_bool).unwrap_or(false) {
        // EXPLAIN runs nothing: no admission token is charged.
        let stack = state.stack.lock().unwrap();
        let doc = stack
            .explain_query(&engine, &text, reduces)
            .map_err(|e| bad_request(&e))?;
        return Ok(Response::json(200, doc.to_string()));
    }
    let nodes = j.req_u64("nodes").map_err(|e| bad_request(&e))? as u32;
    let claimed = j.req_str("user").map_err(|e| bad_request(&e))?.to_string();
    let user = effective_user(state, tenant, &claimed).to_string();
    {
        let stack = state.stack.lock().unwrap();
        if let Err(e) = state.tenants.admit_submit(&tenant.name, stack.now()) {
            return Ok(admission_response(&e));
        }
    }
    let mode = j.get("mode").and_then(Json::as_str).unwrap_or("job");
    match mode {
        "job" => {
            // Parse eagerly so syntax errors answer 400, not a failed job.
            crate::api::stack::parse_query_text(&engine, &text, reduces)
                .map_err(|e| bad_request(&e))?;
            let mut stack = state.stack.lock().unwrap();
            let id = stack
                .submit(
                    nodes,
                    &user,
                    crate::api::stack::AppPayload::Query {
                        engine,
                        text,
                        reduces,
                    },
                )
                .map_err(|e| bad_request(&e))?;
            drop(stack);
            state.work.notify();
            Ok(Response::json(
                201,
                Json::obj(vec![("job", Json::num(id.0 as f64))]).to_string(),
            ))
        }
        "workflow" => {
            let plan = crate::api::stack::parse_query_text(&engine, &text, reduces)
                .map_err(|e| bad_request(&e))?;
            let wf =
                crate::api::synfiniway::query_workflow(&format!("query-{engine}"), &user, nodes, &plan)
                    .map_err(|e| bad_request(&e))?;
            let mut wfs = state.workflows.lock().unwrap();
            let id = wfs.len() as u64;
            wfs.push(WorkflowRun::new(id, wf));
            drop(wfs);
            state.work.notify();
            Ok(Response::json(
                201,
                Json::obj(vec![("workflow", Json::num(id as f64))]).to_string(),
            ))
        }
        other => Err(ErrorDoc::new(
            code::BAD_REQUEST,
            format!("unknown query mode '{other}' (job|workflow)"),
        )),
    }
}

fn get_workflow(state: &State, req: &Request, id: &str) -> HandlerResult {
    let idx: usize = id
        .parse()
        .map_err(|_| ErrorDoc::new(code::BAD_REQUEST, format!("bad workflow id '{id}'")))?;
    let deadline = Instant::now() + Duration::from_millis(wait_ms(req));
    let doc = long_poll(
        state,
        deadline,
        || {
            state
                .workflows
                .lock()
                .unwrap()
                .get(idx)
                .map(|wf| wf.to_doc())
                .ok_or_else(|| ErrorDoc::not_found(format!("unknown workflow {idx}")))
        },
        WorkflowDoc::is_terminal,
    )?;
    Ok(Response::json(200, doc.to_json().to_string()))
}

/// `POST /v1/scenarios`: validate the declarative spec (the same
/// validation the runner applies — a 201 is a spec that will run) and
/// queue it for the pump. Scenario submissions clear the same admission
/// gate as job submissions.
fn post_scenario(state: &State, req: &Request, tenant: &Tenant) -> HandlerResult {
    let j = parse_body(req)?;
    let spec = scenario_spec_from_json(&j).map_err(|e| bad_request(&e))?;
    {
        let stack = state.stack.lock().unwrap();
        if let Err(e) = state.tenants.admit_submit(&tenant.name, stack.now()) {
            return Ok(admission_response(&e));
        }
    }
    let mut runs = state.scenarios.lock().unwrap();
    let id = runs.len() as u64;
    runs.push(ScenarioRun {
        spec,
        state: ScenarioState::Pending,
        score: None,
        error: None,
    });
    drop(runs);
    state
        .events
        .emit("scenario", id, ScenarioState::Pending.as_wire().to_string(), None);
    state.work.notify();
    Ok(Response::json(
        201,
        Json::obj(vec![("scenario", Json::num(id as f64))]).to_string(),
    ))
}

fn get_scenario(state: &State, req: &Request, id: &str) -> HandlerResult {
    let idx: usize = id
        .parse()
        .map_err(|_| ErrorDoc::new(code::BAD_REQUEST, format!("bad scenario id '{id}'")))?;
    let deadline = Instant::now() + Duration::from_millis(wait_ms(req));
    let doc = long_poll(
        state,
        deadline,
        || {
            state
                .scenarios
                .lock()
                .unwrap()
                .get(idx)
                .map(|r| r.to_doc(idx as u64, true))
                .ok_or_else(|| ErrorDoc::not_found(format!("unknown scenario {idx}")))
        },
        ScenarioDoc::is_terminal,
    )?;
    Ok(Response::json(200, doc.to_json().to_string()))
}

fn list_scenarios(state: &State, req: &Request) -> HandlerResult {
    let offset: u64 = req
        .query_param("offset")
        .and_then(|v| v.parse().ok())
        .unwrap_or(0);
    let limit: u64 = req
        .query_param("limit")
        .and_then(|v| v.parse().ok())
        .unwrap_or(50)
        .clamp(1, 500);
    let runs = state.scenarios.lock().unwrap();
    let total = runs.len() as u64;
    let scenarios = runs
        .iter()
        .enumerate()
        .skip(offset as usize)
        .take(limit as usize)
        .map(|(i, r)| r.to_doc(i as u64, false))
        .collect();
    let page = ScenariosPage {
        scenarios,
        total,
        offset,
    };
    Ok(Response::json(200, page.to_json().to_string()))
}

fn get_cluster(state: &State) -> HandlerResult {
    let stack = state.stack.lock().unwrap();
    Ok(Response::json(200, stack.cluster_doc().to_json().to_string()))
}

/// Node lifecycle administration: `POST /v1/cluster/nodes/{id}/{action}`
/// with `action` ∈ {`fail`, `drain`, `restore`}. The transition lands in
/// the event journal (kind `node`).
fn post_node_action(state: &State, id: &str, action: &str) -> HandlerResult {
    let node: u64 = id
        .parse()
        .map_err(|_| ErrorDoc::new(code::BAD_REQUEST, format!("bad node id '{id}'")))?;
    let mut stack = state.stack.lock().unwrap();
    let known = stack.cluster_doc().nodes.iter().any(|n| n.node == node);
    if !known {
        return Err(ErrorDoc::not_found(format!("unknown node {node}")));
    }
    let new_state = match action {
        "fail" => {
            stack.fail_node(node).map_err(|e| bad_request(&e))?;
            "DOWN"
        }
        "drain" => {
            stack.drain_node(node).map_err(|e| bad_request(&e))?;
            "DRAINED"
        }
        "restore" => {
            stack.restore_node(node).map_err(|e| bad_request(&e))?;
            "UP"
        }
        other => {
            return Err(ErrorDoc::new(
                code::BAD_REQUEST,
                format!("unknown node action '{other}' (fail|drain|restore)"),
            ))
        }
    };
    // Emit while still holding the stack lock: the journal order of node
    // events then always matches the order the transitions were applied,
    // even when two admin actions race on separate connections.
    state.events.emit("node", node, new_state.to_string(), None);
    drop(stack);
    state.work.notify();
    Ok(Response::json(
        200,
        Json::obj(vec![
            ("node", Json::num(node as f64)),
            ("state", Json::str(new_state)),
        ])
        .to_string(),
    ))
}

fn get_events(state: &State, req: &Request) -> HandlerResult {
    let since: u64 = req
        .query_param("since")
        .and_then(|v| v.parse().ok())
        .unwrap_or(0);
    let deadline = Instant::now() + Duration::from_millis(wait_ms(req));
    let page = long_poll(
        state,
        deadline,
        || Ok(state.events.since(since)),
        |page: &EventPage| !page.events.is_empty(),
    )?;
    Ok(Response::json(200, page.to_json().to_string()))
}

fn get_metrics(state: &State) -> HandlerResult {
    // Refresh the storage-tier gauges so the scrape sees current tier
    // occupancy/counters, not the values at the last job transition.
    state.stack.lock().unwrap().publish_storage_metrics();
    // Front-door health: accepted vs shed at the bounded accept queue.
    state
        .metrics
        .set_gauge("api.accepted", state.serve_stats.accepted_count() as f64);
    state
        .metrics
        .set_gauge("api.shed", state.serve_stats.shed_count() as f64);
    Ok(Response::text(200, state.metrics.render()))
}

/// `GET /v1/tenants`: identity + accounting for every known tenant.
fn get_tenants(state: &State) -> HandlerResult {
    let docs: Vec<Json> = state
        .tenants
        .tenant_snapshots()
        .into_iter()
        .map(|s| {
            TenantDoc {
                name: s.name,
                queue: s.queue,
                running_apps: s.running_apps as u64,
                containers: s.containers as u64,
                dfs_bytes: s.dfs_bytes,
                submitted: s.submitted,
                rate_limited: s.rate_limited,
                quota_rejected: s.quota_rejected,
                breaker_rejected: s.breaker_rejected,
                breaker: s.breaker.to_string(),
            }
            .to_json()
        })
        .collect();
    Ok(Response::json(
        200,
        Json::obj(vec![("tenants", Json::Arr(docs))]).to_string(),
    ))
}

/// `GET /v1/queues`: fair-share policy + live counters per queue.
fn get_queues(state: &State) -> HandlerResult {
    let docs: Vec<Json> = state
        .tenants
        .queue_snapshots()
        .into_iter()
        .map(|q| {
            QueueDoc {
                name: q.name,
                weight: q.weight as u64,
                min_pct: q.min_pct as u64,
                max_pct: q.max_pct as u64,
                running: q.running as u64,
                served: q.served,
                share_pct: q.share_pct,
                preemptions: q.preemptions,
                wait_us: q.wait_us,
            }
            .to_json()
        })
        .collect();
    Ok(Response::json(
        200,
        Json::obj(vec![("queues", Json::Arr(docs))]).to_string(),
    ))
}
