//! The Rust reference client for the HPC Wales v1 API ("The user will be
//! provided with HPC Wales APIs in multiple languages ... job submission,
//! obtaining job status and job termination"). The wire format is the
//! typed schema in [`crate::api::wire`]; `python/hpcw_client/` is the
//! mechanical port, held to the same conformance vectors.
//!
//! `wait`/`wait_workflow` are event-driven: they long-poll
//! `GET /v1/...?wait_ms=N`, so a job that completes after time T costs
//! O(state transitions) HTTP requests, not O(T / poll-interval).

use crate::api::http::request_with_headers;
use crate::api::stack::AppPayload;
use crate::api::wire::{
    scenario_spec_to_json, ClusterDoc, ErrorDoc, EventPage, JobDoc, JobsPage, QueueDoc,
    ScenarioDoc, ScenariosPage, SubmitRequest, TenantDoc, WorkflowDoc, WorkflowSpec,
};
use crate::scenario::ScenarioSpec;
use crate::codec::json::Json;
use crate::error::{Error, Result};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Longest single long-poll slice requested from the server.
const WAIT_SLICE_MS: u64 = 10_000;

/// Client handle for one API endpoint.
#[derive(Debug)]
pub struct ApiClient {
    pub addr: String,
    /// `X-HPCW-Key` credential sent with every request (multi-tenant
    /// servers resolve it to a tenant + fair-share queue).
    api_key: Option<String>,
    /// HTTP requests issued by this handle (tests assert the O(transitions)
    /// property of `wait` with it).
    requests: AtomicU64,
}

impl Clone for ApiClient {
    fn clone(&self) -> ApiClient {
        ApiClient {
            addr: self.addr.clone(),
            api_key: self.api_key.clone(),
            requests: AtomicU64::new(0),
        }
    }
}

impl ApiClient {
    pub fn new(addr: &str) -> ApiClient {
        ApiClient {
            addr: addr.to_string(),
            api_key: None,
            requests: AtomicU64::new(0),
        }
    }

    /// A client that authenticates as a tenant via `X-HPCW-Key`.
    pub fn with_key(addr: &str, key: &str) -> ApiClient {
        let mut c = ApiClient::new(addr);
        c.api_key = Some(key.to_string());
        c
    }

    /// HTTP requests issued so far by this handle.
    pub fn request_count(&self) -> u64 {
        self.requests.load(Ordering::Relaxed)
    }

    fn call(&self, method: &str, path: &str, body: Option<&[u8]>) -> Result<(u16, Vec<u8>)> {
        let (status, body, _) = self.call_throttled(method, path, body)?;
        Ok((status, body))
    }

    /// Like `call`, but also returns the server's `Retry-After` seconds
    /// when the request was shed or throttled (429).
    fn call_throttled(
        &self,
        method: &str,
        path: &str,
        body: Option<&[u8]>,
    ) -> Result<(u16, Vec<u8>, Option<u64>)> {
        self.requests.fetch_add(1, Ordering::Relaxed);
        let extra: Vec<(&str, &str)> = match self.api_key.as_deref() {
            Some(k) => vec![("X-HPCW-Key", k)],
            None => Vec::new(),
        };
        let (status, headers, body) =
            request_with_headers(&self.addr, method, path, body, &extra)?;
        let retry_after = headers
            .get("retry-after")
            .and_then(|v| v.trim().parse::<u64>().ok());
        Ok((status, body, retry_after))
    }

    /// Parse a JSON response; `4xx`/`5xx` become errors carrying the
    /// stable wire code, e.g. `api: HTTP 404 not_found: unknown job 9`.
    fn check(status: u16, body: &[u8]) -> Result<Json> {
        let text = std::str::from_utf8(body)
            .map_err(|_| Error::Api("non-utf8 response".into()))?;
        let json = Json::parse(text)?;
        if status >= 400 {
            return match ErrorDoc::from_json(&json) {
                Ok(e) => Err(Error::Api(format!(
                    "HTTP {status} {}: {}",
                    e.code, e.message
                ))),
                Err(_) => Err(Error::Api(format!("HTTP {status}: {text}"))),
            };
        }
        Ok(json)
    }

    /// Submit an application; returns the LSF job id. A 429 rejection
    /// (rate limit / quota / shed) carries the server's `Retry-After`
    /// seconds in the error message.
    pub fn submit(&self, nodes: u32, user: &str, payload: &AppPayload) -> Result<u64> {
        let body = SubmitRequest {
            nodes,
            user: user.to_string(),
            payload: payload.clone(),
        }
        .to_json()
        .to_string();
        let (status, resp, retry_after) =
            self.call_throttled("POST", "/v1/jobs", Some(body.as_bytes()))?;
        if status == 429 {
            let hint = retry_after
                .map(|s| format!(" (Retry-After: {s}s)"))
                .unwrap_or_default();
            return match Self::check(status, &resp) {
                Err(e) => Err(Error::Api(format!("{e}{hint}"))),
                Ok(_) => Err(Error::Api(format!("HTTP 429{hint}"))),
            };
        }
        let json = Self::check(status, &resp)?;
        json.req_u64("job")
    }

    /// Job status snapshot.
    pub fn status(&self, job: u64) -> Result<JobDoc> {
        let (status, resp) = self.call("GET", &format!("/v1/jobs/{job}"), None)?;
        JobDoc::from_json(&Self::check(status, &resp)?)
    }

    /// One page of the job list.
    pub fn list_jobs(&self, offset: u64, limit: u64) -> Result<JobsPage> {
        let (status, resp) = self.call(
            "GET",
            &format!("/v1/jobs?offset={offset}&limit={limit}"),
            None,
        )?;
        JobsPage::from_json(&Self::check(status, &resp)?)
    }

    /// Wait until terminal or timeout, long-polling the server.
    pub fn wait(&self, job: u64, timeout: Duration) -> Result<JobDoc> {
        let deadline = std::time::Instant::now() + timeout;
        loop {
            let left = deadline.saturating_duration_since(std::time::Instant::now());
            let slice = (left.as_millis() as u64).min(WAIT_SLICE_MS);
            let (status, resp) = self.call(
                "GET",
                &format!("/v1/jobs/{job}?wait_ms={slice}"),
                None,
            )?;
            let doc = JobDoc::from_json(&Self::check(status, &resp)?)?;
            if doc.is_terminal() {
                return Ok(doc);
            }
            if std::time::Instant::now() >= deadline {
                return Err(Error::Api(format!("timeout waiting for job {job}")));
            }
        }
    }

    /// Terminate a job.
    pub fn kill(&self, job: u64) -> Result<()> {
        let (status, resp) = self.call("DELETE", &format!("/v1/jobs/{job}"), None)?;
        Self::check(status, &resp).map(|_| ())
    }

    /// Fetch an output file's bytes (step 6: data access via the API).
    /// `path` may be absolute (must stay under the job's output root) or
    /// relative to that root.
    pub fn read_output(&self, job: u64, path: &str) -> Result<Vec<u8>> {
        let encoded: String = path
            .bytes()
            .map(|b| match b {
                b'A'..=b'Z' | b'a'..=b'z' | b'0'..=b'9' | b'/' | b'-' | b'_' | b'.' | b'~' => {
                    (b as char).to_string()
                }
                _ => format!("%{b:02x}"),
            })
            .collect();
        let (status, resp) = self.call(
            "GET",
            &format!("/v1/jobs/{job}/output?path={encoded}"),
            None,
        )?;
        if status >= 400 {
            Self::check(status, &resp)?;
            return Err(Error::Api(format!("HTTP {status} reading {path}")));
        }
        Ok(resp)
    }

    /// Submit a Pig/Hive query text (`POST /v1/queries`). With
    /// `workflow = false` the stage chain runs on one dynamic cluster
    /// and the returned id is an LSF **job**; with `workflow = true` the
    /// plan becomes a DAG of `query_stage` steps and the id is a
    /// **workflow** (one LSF job per stage).
    pub fn submit_query(
        &self,
        engine: &str,
        text: &str,
        reduces: u32,
        nodes: u32,
        user: &str,
        workflow: bool,
    ) -> Result<u64> {
        let mode = if workflow { "workflow" } else { "job" };
        let body = Json::obj(vec![
            ("engine", Json::str(engine)),
            ("text", Json::str(text)),
            ("reduces", Json::num(reduces as f64)),
            ("nodes", Json::num(nodes as f64)),
            ("user", Json::str(user)),
            ("mode", Json::str(mode)),
        ])
        .to_string();
        let (status, resp) = self.call("POST", "/v1/queries", Some(body.as_bytes()))?;
        let json = Self::check(status, &resp)?;
        json.req_u64(if workflow { "workflow" } else { "job" })
    }

    /// EXPLAIN a Pig/Hive query (`POST /v1/queries` with
    /// `explain: true`): returns the optimizer's stage DAG — per-stage
    /// join strategy, fused ops, and estimated input bytes — without
    /// running anything.
    pub fn explain_query(&self, engine: &str, text: &str, reduces: u32) -> Result<Json> {
        let body = Json::obj(vec![
            ("engine", Json::str(engine)),
            ("text", Json::str(text)),
            ("reduces", Json::num(reduces as f64)),
            ("explain", Json::Bool(true)),
        ])
        .to_string();
        let (status, resp) = self.call("POST", "/v1/queries", Some(body.as_bytes()))?;
        Self::check(status, &resp)
    }

    /// Submit a named-step DAG workflow; returns the workflow id.
    pub fn submit_workflow(&self, spec: &WorkflowSpec) -> Result<u64> {
        spec.validate()?;
        let body = spec.to_json().to_string();
        let (status, resp) = self.call("POST", "/v1/workflows", Some(body.as_bytes()))?;
        let json = Self::check(status, &resp)?;
        json.req_u64("workflow")
    }

    /// Workflow progress document.
    pub fn workflow(&self, id: u64) -> Result<WorkflowDoc> {
        let (status, resp) = self.call("GET", &format!("/v1/workflows/{id}"), None)?;
        WorkflowDoc::from_json(&Self::check(status, &resp)?)
    }

    /// Wait for a workflow to complete or abort, long-polling the server.
    pub fn wait_workflow(&self, id: u64, timeout: Duration) -> Result<WorkflowDoc> {
        let deadline = std::time::Instant::now() + timeout;
        loop {
            let left = deadline.saturating_duration_since(std::time::Instant::now());
            let slice = (left.as_millis() as u64).min(WAIT_SLICE_MS);
            let (status, resp) = self.call(
                "GET",
                &format!("/v1/workflows/{id}?wait_ms={slice}"),
                None,
            )?;
            let doc = WorkflowDoc::from_json(&Self::check(status, &resp)?)?;
            if doc.is_terminal() {
                return Ok(doc);
            }
            if std::time::Instant::now() >= deadline {
                return Err(Error::Api(format!("timeout waiting for workflow {id}")));
            }
        }
    }

    /// Events after `since` (the monotonic journal); pass `wait_ms > 0`
    /// to long-poll when the journal is drained. Returns the page; feed
    /// `page.next` back as the next `since`.
    pub fn events(&self, since: u64, wait_ms: u64) -> Result<EventPage> {
        let (status, resp) = self.call(
            "GET",
            &format!("/v1/events?since={since}&wait_ms={wait_ms}"),
            None,
        )?;
        EventPage::from_json(&Self::check(status, &resp)?)
    }

    /// Cluster snapshot: node states, lease holders, totals.
    pub fn cluster(&self) -> Result<ClusterDoc> {
        let (status, resp) = self.call("GET", "/v1/cluster", None)?;
        ClusterDoc::from_json(&Self::check(status, &resp)?)
    }

    /// Node lifecycle administration: `action` ∈ `fail` / `drain` /
    /// `restore`.
    pub fn node_action(&self, node: u64, action: &str) -> Result<()> {
        let (status, resp) =
            self.call("POST", &format!("/v1/cluster/nodes/{node}/{action}"), None)?;
        Self::check(status, &resp).map(|_| ())
    }

    /// Raw metrics dump.
    pub fn metrics(&self) -> Result<String> {
        let (status, resp) = self.call("GET", "/v1/metrics", None)?;
        if status != 200 {
            return Err(Error::Api(format!("HTTP {status}")));
        }
        String::from_utf8(resp).map_err(|_| Error::Api("non-utf8 metrics".into()))
    }

    /// Submit a scenario for simulation (`POST /v1/scenarios`); returns
    /// the scenario id. The spec is validated client-side first, so a
    /// malformed scenario fails before it costs an admission token.
    pub fn run_scenario(&self, spec: &ScenarioSpec) -> Result<u64> {
        spec.validate()?;
        let body = scenario_spec_to_json(spec).to_string();
        let (status, resp) = self.call("POST", "/v1/scenarios", Some(body.as_bytes()))?;
        let json = Self::check(status, &resp)?;
        json.req_u64("scenario")
    }

    /// Scenario status snapshot (with the score once `DONE`).
    pub fn scenario(&self, id: u64) -> Result<ScenarioDoc> {
        let (status, resp) = self.call("GET", &format!("/v1/scenarios/{id}"), None)?;
        ScenarioDoc::from_json(&Self::check(status, &resp)?)
    }

    /// Wait for a scenario to finish (or fail), long-polling the server.
    pub fn wait_scenario(&self, id: u64, timeout: Duration) -> Result<ScenarioDoc> {
        let deadline = std::time::Instant::now() + timeout;
        loop {
            let left = deadline.saturating_duration_since(std::time::Instant::now());
            let slice = (left.as_millis() as u64).min(WAIT_SLICE_MS);
            let (status, resp) = self.call(
                "GET",
                &format!("/v1/scenarios/{id}?wait_ms={slice}"),
                None,
            )?;
            let doc = ScenarioDoc::from_json(&Self::check(status, &resp)?)?;
            if doc.is_terminal() {
                return Ok(doc);
            }
            if std::time::Instant::now() >= deadline {
                return Err(Error::Api(format!("timeout waiting for scenario {id}")));
            }
        }
    }

    /// One page of the scenario list (rows omit the score; fetch one
    /// scenario for the full document).
    pub fn list_scenarios(&self, offset: u64, limit: u64) -> Result<ScenariosPage> {
        let (status, resp) = self.call(
            "GET",
            &format!("/v1/scenarios?offset={offset}&limit={limit}"),
            None,
        )?;
        ScenariosPage::from_json(&Self::check(status, &resp)?)
    }

    /// Per-tenant accounting (`GET /v1/tenants`): quota usage, admission
    /// counters and circuit-breaker state.
    pub fn tenants(&self) -> Result<Vec<TenantDoc>> {
        let (status, resp) = self.call("GET", "/v1/tenants", None)?;
        let json = Self::check(status, &resp)?;
        json.get("tenants")
            .and_then(Json::as_arr)
            .ok_or_else(|| Error::Api("missing 'tenants' array".into()))?
            .iter()
            .map(TenantDoc::from_json)
            .collect()
    }

    /// Fair-share queue accounting (`GET /v1/queues`): policy
    /// (weight / min / max) plus live share and preemption counters.
    pub fn queues(&self) -> Result<Vec<QueueDoc>> {
        let (status, resp) = self.call("GET", "/v1/queues", None)?;
        let json = Self::check(status, &resp)?;
        json.get("queues")
            .and_then(Json::as_arr)
            .ok_or_else(|| Error::Api("missing 'queues' array".into()))?
            .iter()
            .map(QueueDoc::from_json)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::server::ApiServer;
    use crate::api::stack::Stack;
    use crate::api::wire::StepState;
    use crate::config::StackConfig;
    use crate::scheduler::JobState;
    use std::time::Duration;

    fn server() -> (ApiServer, ApiClient) {
        let stack = Stack::new(StackConfig::tiny()).unwrap();
        let server = ApiServer::start(stack).unwrap();
        let client = ApiClient::new(&server.addr);
        (server, client)
    }

    #[test]
    fn submit_wait_fetch_cycle() {
        let (_server, client) = server();
        let job = client
            .submit(
                6,
                "sid",
                &AppPayload::Terasort {
                    rows: 1_000,
                    maps: 2,
                    reduces: 3,
                    use_kernel: false,
                },
            )
            .unwrap();
        let st = client.wait(job, Duration::from_secs(30)).unwrap();
        assert_eq!(st.state, JobState::Done, "error={:?}", st.error);
        assert!(st.is_terminal());
        let result = st.result.unwrap();
        assert!(result.validated);
        assert_eq!(result.records, 1000);
        assert_eq!(result.kind, "terasort");
        // Fetch one output part through the API.
        let bytes = client.read_output(job, &result.output_files[0]).unwrap();
        assert_eq!(bytes.len() % 100, 0);
        // Relative paths resolve against the output root.
        let rel = result.output_files[0]
            .strip_prefix(&format!("{}/", result.output_dir))
            .unwrap();
        assert_eq!(client.read_output(job, rel).unwrap(), bytes);
        // Metrics exposed, including the API layer's own counters.
        let m = client.metrics().unwrap();
        assert!(m.contains("lsf.dispatched"));
        assert!(m.contains("api.requests"));
    }

    #[test]
    fn status_of_unknown_job_is_not_found() {
        let (_server, client) = server();
        let err = client.status(99_999).unwrap_err();
        assert!(err.to_string().contains("not_found"), "{err}");
    }

    #[test]
    fn bad_payload_rejected_with_stable_code() {
        let (_server, client) = server();
        let (status, body) = crate::api::http::request(
            &client.addr,
            "POST",
            "/v1/jobs",
            Some(br#"{"nodes":2,"user":"u","payload":{"type":"nonsense"}}"#),
        )
        .unwrap();
        assert_eq!(status, 400);
        let doc = ErrorDoc::from_json(&Json::parse(std::str::from_utf8(&body).unwrap()).unwrap())
            .unwrap();
        assert_eq!(doc.code, "unknown_payload");
        assert!(doc.message.contains("unknown payload type"));
    }

    #[test]
    fn jobs_are_paginated() {
        let (_server, client) = server();
        for i in 0..5 {
            client
                .submit(
                    2,
                    "pager",
                    &AppPayload::Teragen {
                        rows: 50,
                        maps: 1,
                        dir: format!("/lustre/scratch/page-{i}"),
                    },
                )
                .unwrap();
        }
        let page = client.list_jobs(0, 2).unwrap();
        assert_eq!(page.total, 5);
        assert_eq!(page.jobs.len(), 2);
        assert_eq!(page.offset, 0);
        let rest = client.list_jobs(2, 500).unwrap();
        assert_eq!(rest.jobs.len(), 3);
        let first_ids: Vec<u64> = page.jobs.iter().map(|j| j.job).collect();
        let rest_ids: Vec<u64> = rest.jobs.iter().map(|j| j.job).collect();
        assert!(first_ids.iter().max().unwrap() < rest_ids.iter().min().unwrap());
    }

    #[test]
    fn dag_workflow_over_api() {
        let (_server, client) = server();
        let spec = WorkflowSpec::linear(
            "two-step",
            "sid",
            4,
            vec![
                AppPayload::Teragen {
                    rows: 300,
                    maps: 2,
                    dir: "/lustre/scratch/api-wf-a".into(),
                },
                AppPayload::Teragen {
                    rows: 300,
                    maps: 2,
                    dir: "/lustre/scratch/api-wf-b".into(),
                },
            ],
        );
        let wf = client.submit_workflow(&spec).unwrap();
        let doc = client.wait_workflow(wf, Duration::from_secs(30)).unwrap();
        assert!(doc.complete, "doc={doc:?}");
        assert_eq!(doc.steps.len(), 2);
        assert!(doc.steps.iter().all(|s| s.state == StepState::Done));
        assert!(doc.steps.iter().all(|s| s.job.is_some()));
        assert_eq!(
            doc.steps[1].output_dir.as_deref(),
            Some("/lustre/scratch/api-wf-b")
        );
    }

    #[test]
    fn events_journal_reports_transitions() {
        let (_server, client) = server();
        let job = client
            .submit(
                2,
                "ev",
                &AppPayload::Teragen {
                    rows: 100,
                    maps: 1,
                    dir: "/lustre/scratch/ev".into(),
                },
            )
            .unwrap();
        client.wait(job, Duration::from_secs(30)).unwrap();
        let page = client.events(0, 0).unwrap();
        assert!(page.next >= 1);
        let done = page
            .events
            .iter()
            .find(|e| e.kind == "job" && e.id == job && e.state == "DONE");
        assert!(done.is_some(), "events={:?}", page.events);
        // Seqs are strictly increasing.
        let seqs: Vec<u64> = page.events.iter().map(|e| e.seq).collect();
        assert!(seqs.windows(2).all(|w| w[0] < w[1]));
        // Draining from the cursor returns nothing new.
        let empty = client.events(page.next, 0).unwrap();
        assert!(empty.events.is_empty());
    }

    #[test]
    fn cluster_endpoint_reports_and_drives_node_lifecycle() {
        let (_server, client) = server();
        let doc = client.cluster().unwrap();
        assert_eq!(doc.nodes.len(), 8);
        assert_eq!(doc.up, 8);
        assert_eq!(doc.leased, 0);
        // Fail a node, drain another: the snapshot and the event journal
        // both reflect the transitions.
        client.node_action(3, "fail").unwrap();
        client.node_action(5, "drain").unwrap();
        let doc = client.cluster().unwrap();
        assert_eq!(doc.up, 6);
        assert_eq!(doc.down, 1);
        assert_eq!(doc.drained, 1);
        let down = doc.nodes.iter().find(|n| n.node == 3).unwrap();
        assert_eq!(down.state, "DOWN");
        // Restore both; journal carries the node transitions.
        client.node_action(3, "restore").unwrap();
        client.node_action(5, "restore").unwrap();
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        let mut seen_down = false;
        let mut seen_up = false;
        let mut since = 0;
        while std::time::Instant::now() < deadline && !(seen_down && seen_up) {
            let page = client.events(since, 200).unwrap();
            since = page.next;
            for e in &page.events {
                if e.kind == "node" && e.id == 3 && e.state == "DOWN" {
                    seen_down = true;
                }
                if e.kind == "node" && e.id == 3 && e.state == "UP" {
                    seen_up = true;
                }
            }
        }
        assert!(seen_down && seen_up, "node transitions must reach the journal");
        assert_eq!(client.cluster().unwrap().up, 8);
        // Unknown node and unknown action answer with stable codes.
        let err = client.node_action(99, "fail").unwrap_err();
        assert!(err.to_string().contains("not_found"), "{err}");
        let err = client.node_action(0, "explode").unwrap_err();
        assert!(err.to_string().contains("bad_request"), "{err}");
    }

    #[test]
    fn scenario_lifecycle_over_api() {
        let (_server, client) = server();
        let spec = ScenarioSpec::from_toml(include_str!(concat!(
            env!("CARGO_MANIFEST_DIR"),
            "/examples/scenarios/updown.toml"
        )))
        .unwrap();
        let id = client.run_scenario(&spec).unwrap();
        let doc = client.wait_scenario(id, Duration::from_secs(60)).unwrap();
        assert_eq!(doc.state, crate::api::wire::ScenarioState::Done, "{:?}", doc.error);
        assert_eq!(doc.name, "updown");
        assert_eq!(doc.policy, "sla_energy");
        let score = doc.score.expect("DONE carries the score");
        assert_eq!(score.policy, "sla_energy");
        assert!(score.ticks > 0);
        assert!(score.energy.energy_mj > 0);
        // List rows cover the run but omit the score.
        let page = client.list_scenarios(0, 10).unwrap();
        assert_eq!(page.total, 1);
        assert_eq!(page.scenarios[0].scenario, id);
        assert!(page.scenarios[0].score.is_none());
        // Lifecycle transitions land in the journal.
        let events = client.events(0, 0).unwrap();
        for state in ["PENDING", "RUNNING", "DONE"] {
            assert!(
                events
                    .events
                    .iter()
                    .any(|e| e.kind == "scenario" && e.id == id && e.state == state),
                "missing scenario {state} event: {:?}",
                events.events
            );
        }
        // An invalid spec answers 400 with a stable code, runs nothing.
        let mut bad = spec.clone();
        bad.policy = "psychic".into();
        let err = client.run_scenario(&bad).unwrap_err();
        assert!(err.to_string().contains("psychic"), "{err}");
        assert_eq!(client.list_scenarios(0, 10).unwrap().total, 1);
        // Unknown scenario id answers not_found.
        let err = client.scenario(99).unwrap_err();
        assert!(err.to_string().contains("not_found"), "{err}");
    }

    #[test]
    fn legacy_paths_redirect_with_deprecation() {
        let (_server, client) = server();
        let (status, headers, body) =
            crate::api::http::request_full(&client.addr, "GET", "/jobs", None).unwrap();
        assert_eq!(status, 301);
        assert_eq!(headers.get("location").map(String::as_str), Some("/v1/jobs"));
        assert_eq!(headers.get("deprecation").map(String::as_str), Some("true"));
        let doc = ErrorDoc::from_json(&Json::parse(std::str::from_utf8(&body).unwrap()).unwrap())
            .unwrap();
        assert_eq!(doc.code, "deprecated");
    }
}
