//! The Rust reference client for the HPC Wales API ("The user will be
//! provided with HPC Wales APIs in multiple languages ... job submission,
//! obtaining job status and job termination"). The wire format is plain
//! JSON over HTTP, so other-language clients are mechanical ports.

use crate::api::http::request;
use crate::api::stack::AppPayload;
use crate::codec::json::Json;
use crate::error::{Error, Result};

/// Client handle for one API endpoint.
#[derive(Debug, Clone)]
pub struct ApiClient {
    pub addr: String,
}

/// A job status snapshot.
#[derive(Debug, Clone)]
pub struct JobStatus {
    pub job: u64,
    pub state: String,
    pub result: Option<Json>,
    pub error: Option<String>,
}

impl JobStatus {
    pub fn is_terminal(&self) -> bool {
        self.state.starts_with("DONE") || self.state.starts_with("EXIT")
    }
}

fn payload_to_json(p: &AppPayload) -> Json {
    match p {
        AppPayload::Terasort {
            rows,
            maps,
            reduces,
            use_kernel,
        } => Json::obj(vec![
            ("type", Json::str("terasort")),
            ("rows", Json::num(*rows as f64)),
            ("maps", Json::num(*maps as f64)),
            ("reduces", Json::num(*reduces as f64)),
            ("use_kernel", Json::Bool(*use_kernel)),
        ]),
        AppPayload::Teragen { rows, maps, dir } => Json::obj(vec![
            ("type", Json::str("teragen")),
            ("rows", Json::num(*rows as f64)),
            ("maps", Json::num(*maps as f64)),
            ("dir", Json::str(&**dir)),
        ]),
        AppPayload::PigScript { script, reduces } => Json::obj(vec![
            ("type", Json::str("pig")),
            ("script", Json::str(&**script)),
            ("reduces", Json::num(*reduces as f64)),
        ]),
        AppPayload::HiveQuery { sql, reduces } => Json::obj(vec![
            ("type", Json::str("hive")),
            ("sql", Json::str(&**sql)),
            ("reduces", Json::num(*reduces as f64)),
        ]),
        AppPayload::RSummary {
            input_dir,
            output_dir,
            fields,
            delimiter,
            columns,
        } => Json::obj(vec![
            ("type", Json::str("rsummary")),
            ("input_dir", Json::str(&**input_dir)),
            ("output_dir", Json::str(&**output_dir)),
            (
                "fields",
                Json::Arr(fields.iter().map(|f| Json::str(&**f)).collect()),
            ),
            ("delimiter", Json::str(delimiter.to_string())),
            (
                "columns",
                Json::Arr(columns.iter().map(|c| Json::str(&**c)).collect()),
            ),
        ]),
    }
}

impl ApiClient {
    pub fn new(addr: &str) -> ApiClient {
        ApiClient {
            addr: addr.to_string(),
        }
    }

    fn check(status: u16, body: &[u8]) -> Result<Json> {
        let text = std::str::from_utf8(body)
            .map_err(|_| Error::Api("non-utf8 response".into()))?;
        let json = Json::parse(text)?;
        if status >= 400 {
            let msg = json
                .get("error")
                .and_then(Json::as_str)
                .unwrap_or("unknown error");
            return Err(Error::Api(format!("HTTP {status}: {msg}")));
        }
        Ok(json)
    }

    /// Submit an application; returns the LSF job id.
    pub fn submit(&self, nodes: u32, user: &str, payload: &AppPayload) -> Result<u64> {
        let body = Json::obj(vec![
            ("nodes", Json::num(nodes as f64)),
            ("user", Json::str(user)),
            ("payload", payload_to_json(payload)),
        ])
        .to_string();
        let (status, resp) = request(&self.addr, "POST", "/jobs", Some(body.as_bytes()))?;
        let json = Self::check(status, &resp)?;
        json.req_u64("job")
    }

    /// Job status.
    pub fn status(&self, job: u64) -> Result<JobStatus> {
        let (status, resp) = request(&self.addr, "GET", &format!("/jobs/{job}"), None)?;
        let json = Self::check(status, &resp)?;
        Ok(JobStatus {
            job,
            state: json.req_str("state")?.to_string(),
            result: json.get("result").cloned(),
            error: json.get("error").and_then(Json::as_str).map(str::to_string),
        })
    }

    /// Poll until terminal or timeout.
    pub fn wait(&self, job: u64, timeout: std::time::Duration) -> Result<JobStatus> {
        let deadline = std::time::Instant::now() + timeout;
        loop {
            let st = self.status(job)?;
            if st.is_terminal() {
                return Ok(st);
            }
            if std::time::Instant::now() > deadline {
                return Err(Error::Api(format!("timeout waiting for job {job}")));
            }
            std::thread::sleep(std::time::Duration::from_millis(25));
        }
    }

    /// Terminate a job.
    pub fn kill(&self, job: u64) -> Result<()> {
        let (status, resp) = request(&self.addr, "DELETE", &format!("/jobs/{job}"), None)?;
        Self::check(status, &resp).map(|_| ())
    }

    /// Fetch an output file's bytes (step 6: data access via the API).
    pub fn read_output(&self, job: u64, path: &str) -> Result<Vec<u8>> {
        let (status, resp) = request(
            &self.addr,
            "GET",
            &format!("/jobs/{job}/output?path={path}"),
            None,
        )?;
        if status >= 400 {
            return Err(Error::Api(format!("HTTP {status} reading {path}")));
        }
        Ok(resp)
    }

    /// Submit a workflow; returns the workflow id.
    pub fn submit_workflow(
        &self,
        name: &str,
        user: &str,
        nodes: u32,
        steps: &[AppPayload],
    ) -> Result<u64> {
        let body = Json::obj(vec![
            ("name", Json::str(name)),
            ("user", Json::str(user)),
            ("nodes", Json::num(nodes as f64)),
            (
                "steps",
                Json::Arr(steps.iter().map(payload_to_json).collect()),
            ),
        ])
        .to_string();
        let (status, resp) = request(&self.addr, "POST", "/workflows", Some(body.as_bytes()))?;
        let json = Self::check(status, &resp)?;
        json.req_u64("workflow")
    }

    /// Workflow progress document.
    pub fn workflow(&self, id: u64) -> Result<Json> {
        let (status, resp) = request(&self.addr, "GET", &format!("/workflows/{id}"), None)?;
        Self::check(status, &resp)
    }

    /// Wait for a workflow to complete (or abort).
    pub fn wait_workflow(&self, id: u64, timeout: std::time::Duration) -> Result<Json> {
        let deadline = std::time::Instant::now() + timeout;
        loop {
            let doc = self.workflow(id)?;
            let complete = doc.get("complete").and_then(Json::as_bool).unwrap_or(false);
            let aborted = doc.get("aborted").and_then(Json::as_bool).unwrap_or(false);
            if complete || aborted {
                return Ok(doc);
            }
            if std::time::Instant::now() > deadline {
                return Err(Error::Api(format!("timeout waiting for workflow {id}")));
            }
            std::thread::sleep(std::time::Duration::from_millis(25));
        }
    }

    /// Raw metrics dump.
    pub fn metrics(&self) -> Result<String> {
        let (status, resp) = request(&self.addr, "GET", "/metrics", None)?;
        if status != 200 {
            return Err(Error::Api(format!("HTTP {status}")));
        }
        String::from_utf8(resp).map_err(|_| Error::Api("non-utf8 metrics".into()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::server::ApiServer;
    use crate::api::stack::Stack;
    use crate::config::StackConfig;
    use std::time::Duration;

    fn server() -> (ApiServer, ApiClient) {
        let stack = Stack::new(StackConfig::tiny()).unwrap();
        let server = ApiServer::start(stack).unwrap();
        let client = ApiClient::new(&server.addr);
        (server, client)
    }

    #[test]
    fn submit_wait_fetch_cycle() {
        let (_server, client) = server();
        let job = client
            .submit(
                6,
                "sid",
                &AppPayload::Terasort {
                    rows: 1_000,
                    maps: 2,
                    reduces: 3,
                    use_kernel: false,
                },
            )
            .unwrap();
        let st = client.wait(job, Duration::from_secs(30)).unwrap();
        assert_eq!(st.state, "DONE", "error={:?}", st.error);
        let result = st.result.unwrap();
        assert_eq!(result.get("validated"), Some(&Json::Bool(true)));
        assert_eq!(result.get("records").and_then(Json::as_u64), Some(1000));
        // Fetch one output part through the API.
        let files = result.get("output_files").unwrap().as_arr().unwrap();
        let first = files[0].as_str().unwrap();
        let bytes = client.read_output(job, first).unwrap();
        assert_eq!(bytes.len() % 100, 0);
        // Metrics exposed.
        let m = client.metrics().unwrap();
        assert!(m.contains("lsf.dispatched"));
    }

    #[test]
    fn status_of_unknown_job_is_error() {
        let (_server, client) = server();
        let err = client.status(99_999).unwrap_err();
        assert!(err.to_string().contains("404") || err.to_string().contains("unknown"));
    }

    #[test]
    fn bad_payload_rejected() {
        let (_server, client) = server();
        let (status, body) = request(
            &client.addr,
            "POST",
            "/jobs",
            Some(br#"{"nodes":2,"user":"u","payload":{"type":"nonsense"}}"#),
        )
        .unwrap();
        assert_eq!(status, 400);
        assert!(String::from_utf8_lossy(&body).contains("unknown payload type"));
    }

    #[test]
    fn workflow_over_api() {
        let (_server, client) = server();
        let steps = vec![
            AppPayload::Teragen {
                rows: 300,
                maps: 2,
                dir: "/lustre/scratch/api-wf-a".into(),
            },
            AppPayload::Teragen {
                rows: 300,
                maps: 2,
                dir: "/lustre/scratch/api-wf-b".into(),
            },
        ];
        let wf = client
            .submit_workflow("two-step", "sid", 4, &steps)
            .unwrap();
        let doc = client.wait_workflow(wf, Duration::from_secs(30)).unwrap();
        assert_eq!(doc.get("complete"), Some(&Json::Bool(true)));
        let steps = doc.get("steps").unwrap().as_arr().unwrap();
        assert_eq!(steps.len(), 2);
        assert!(steps.iter().all(|s| s.get("state").and_then(Json::as_str) == Some("DONE")));
    }
}
