//! Single source of truth for the v1 wire protocol.
//!
//! Every JSON document that crosses the HTTP boundary — submissions, job
//! and workflow status, events, errors — is defined here as a typed
//! struct with `to_json` / `from_json`. The Rust client, the server, the
//! CLI and the Python client (`python/hpcw_client/wire.py`) all speak
//! exactly this schema; the shared conformance vectors in
//! `python/tests/vectors.json` pin the byte-level encoding for both
//! languages. See `docs/API.md` for the endpoint-by-endpoint spec.
//!
//! Design rules:
//! * one encoder/decoder per document, round-trip property-tested
//!   (`from_json(to_json(x)) == x` for every variant);
//! * stable machine-readable error codes ([`code`]) instead of matching
//!   on message text;
//! * [`JobState`] crosses the wire as an exact token (`KILLED`, not the
//!   `EXIT(kill)` display string), so clients never prefix-match.

use crate::api::stack::{AppPayload, AppResult};
use crate::codec::json::Json;
use crate::error::{Error, Result};
use crate::frameworks::expr::Schema;
use crate::frameworks::plan::{AggSpec, Aggregate, StageKind, StageSpec};
use crate::scenario::score::{EnergyScore, ScoreDoc, TierScore};
use crate::scenario::spec::{
    LoadShape, MachineClass, ScenarioSpec, SlaTier, TaskClass, REFERENCE_MIPS, TIERS,
};
use crate::scheduler::JobState;

/// The protocol version segment every route is mounted under.
pub const WIRE_VERSION: &str = "v1";

/// Stable error codes carried by [`ErrorDoc`]. Clients branch on these,
/// never on message text.
pub mod code {
    /// Malformed request: bad fields, bad ids, bad query parameters.
    pub const BAD_REQUEST: &str = "bad_request";
    /// Body is not valid JSON (or not valid UTF-8).
    pub const BAD_JSON: &str = "bad_json";
    /// Unknown job / workflow / route.
    pub const NOT_FOUND: &str = "not_found";
    /// Output path escapes the job's output root.
    pub const BAD_PATH: &str = "bad_path";
    /// `payload.type` is not a known application.
    pub const UNKNOWN_PAYLOAD: &str = "unknown_payload";
    /// Request is valid but the resource is not in a state that allows
    /// it (e.g. output fetch before the job finished).
    pub const NOT_READY: &str = "not_ready";
    /// Request body exceeds the server's size cap.
    pub const TOO_LARGE: &str = "too_large";
    /// Unversioned legacy path; follow `Location` to the `/v1` route.
    pub const DEPRECATED: &str = "deprecated";
    /// Server-side failure.
    pub const INTERNAL: &str = "internal";
    /// Missing or unknown `X-HPCW-Key` while tenancy requires one.
    pub const UNAUTHORIZED: &str = "unauthorized";
    /// Submission rate limit (token bucket) or open circuit breaker;
    /// retry after the `Retry-After` header's delay.
    pub const RATE_LIMITED: &str = "rate_limited";
    /// A per-tenant quota (running apps, containers, DFS bytes) is
    /// exhausted; free resources before retrying.
    pub const QUOTA_EXCEEDED: &str = "quota_exceeded";
}

// ---------------------------------------------------------------------------
// ErrorDoc
// ---------------------------------------------------------------------------

/// The structured error envelope: `{"error":{"code":..,"message":..}}`.
#[derive(Debug, Clone, PartialEq)]
pub struct ErrorDoc {
    pub code: String,
    pub message: String,
}

impl ErrorDoc {
    pub fn new(code: &str, message: impl Into<String>) -> ErrorDoc {
        ErrorDoc {
            code: code.to_string(),
            message: message.into(),
        }
    }

    pub fn not_found(message: impl Into<String>) -> ErrorDoc {
        ErrorDoc::new(code::NOT_FOUND, message)
    }

    /// HTTP status implied by the code.
    pub fn http_status(&self) -> u16 {
        match self.code.as_str() {
            code::NOT_FOUND => 404,
            code::NOT_READY => 409,
            code::TOO_LARGE => 413,
            code::DEPRECATED => 301,
            code::INTERNAL => 500,
            code::UNAUTHORIZED => 401,
            code::RATE_LIMITED | code::QUOTA_EXCEEDED => 429,
            _ => 400,
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![(
            "error",
            Json::obj(vec![
                ("code", Json::str(&*self.code)),
                ("message", Json::str(&*self.message)),
            ]),
        )])
    }

    pub fn from_json(j: &Json) -> Result<ErrorDoc> {
        let e = j
            .get("error")
            .ok_or_else(|| Error::Codec("missing 'error' envelope".into()))?;
        Ok(ErrorDoc {
            code: e.req_str("code")?.to_string(),
            message: e.req_str("message")?.to_string(),
        })
    }
}

impl From<&Error> for ErrorDoc {
    fn from(e: &Error) -> ErrorDoc {
        let c = match e {
            Error::Io(_) => code::INTERNAL,
            _ => code::BAD_REQUEST,
        };
        ErrorDoc::new(c, e.to_string())
    }
}

// ---------------------------------------------------------------------------
// Job state tokens
// ---------------------------------------------------------------------------

/// Exact wire token for a job state (LSF names, but `KILLED` instead of
/// the display-only `EXIT(kill)` so parsing is never prefix matching).
pub fn job_state_to_wire(s: JobState) -> &'static str {
    match s {
        JobState::Pending => "PEND",
        JobState::Running => "RUN",
        JobState::Done => "DONE",
        JobState::Exited => "EXIT",
        JobState::Killed => "KILLED",
    }
}

pub fn job_state_from_wire(s: &str) -> Result<JobState> {
    match s {
        "PEND" => Ok(JobState::Pending),
        "RUN" => Ok(JobState::Running),
        "DONE" => Ok(JobState::Done),
        "EXIT" => Ok(JobState::Exited),
        "KILLED" => Ok(JobState::Killed),
        other => Err(Error::Codec(format!("unknown job state '{other}'"))),
    }
}

// ---------------------------------------------------------------------------
// AppPayload — the one and only JSON mapping
// ---------------------------------------------------------------------------

/// Serialize a payload. This is the single copy in the codebase: client,
/// server, CLI and tests all call here (the old duplicated
/// `client::payload_to_json` / `server::payload_from_json` pair is gone).
pub fn payload_to_json(p: &AppPayload) -> Json {
    match p {
        AppPayload::Terasort {
            rows,
            maps,
            reduces,
            use_kernel,
        } => Json::obj(vec![
            ("type", Json::str("terasort")),
            ("rows", Json::num(*rows as f64)),
            ("maps", Json::num(*maps as f64)),
            ("reduces", Json::num(*reduces as f64)),
            ("use_kernel", Json::Bool(*use_kernel)),
        ]),
        AppPayload::Teragen { rows, maps, dir } => Json::obj(vec![
            ("type", Json::str("teragen")),
            ("rows", Json::num(*rows as f64)),
            ("maps", Json::num(*maps as f64)),
            ("dir", Json::str(&**dir)),
        ]),
        AppPayload::PigScript { script, reduces } => Json::obj(vec![
            ("type", Json::str("pig")),
            ("script", Json::str(&**script)),
            ("reduces", Json::num(*reduces as f64)),
        ]),
        AppPayload::HiveQuery { sql, reduces } => Json::obj(vec![
            ("type", Json::str("hive")),
            ("sql", Json::str(&**sql)),
            ("reduces", Json::num(*reduces as f64)),
        ]),
        AppPayload::Query {
            engine,
            text,
            reduces,
        } => Json::obj(vec![
            ("type", Json::str("query")),
            ("engine", Json::str(&**engine)),
            ("text", Json::str(&**text)),
            ("reduces", Json::num(*reduces as f64)),
        ]),
        AppPayload::QueryStage { stage } => Json::obj(vec![
            ("type", Json::str("query_stage")),
            ("stage", stage_to_json(stage)),
        ]),
        AppPayload::RSummary {
            input_dir,
            output_dir,
            fields,
            delimiter,
            columns,
        } => Json::obj(vec![
            ("type", Json::str("rsummary")),
            ("input_dir", Json::str(&**input_dir)),
            ("output_dir", Json::str(&**output_dir)),
            (
                "fields",
                Json::Arr(fields.iter().map(|f| Json::str(&**f)).collect()),
            ),
            ("delimiter", Json::str(delimiter.to_string())),
            (
                "columns",
                Json::Arr(columns.iter().map(|c| Json::str(&**c)).collect()),
            ),
        ]),
    }
}

/// Parse a payload; unknown `type` yields [`code::UNKNOWN_PAYLOAD`]-worthy
/// errors at the API layer.
pub fn payload_from_json(j: &Json) -> Result<AppPayload> {
    match j.req_str("type")? {
        "terasort" => Ok(AppPayload::Terasort {
            rows: j.req_u64("rows")?,
            maps: j.req_u64("maps")?,
            reduces: j.req_u64("reduces")? as u32,
            use_kernel: j.get("use_kernel").and_then(Json::as_bool).unwrap_or(false),
        }),
        "teragen" => Ok(AppPayload::Teragen {
            rows: j.req_u64("rows")?,
            maps: j.req_u64("maps")?,
            dir: j.req_str("dir")?.to_string(),
        }),
        "pig" => Ok(AppPayload::PigScript {
            script: j.req_str("script")?.to_string(),
            reduces: j.req_u64("reduces")? as u32,
        }),
        "hive" => Ok(AppPayload::HiveQuery {
            sql: j.req_str("sql")?.to_string(),
            reduces: j.req_u64("reduces")? as u32,
        }),
        "query" => Ok(AppPayload::Query {
            engine: j.req_str("engine")?.to_string(),
            text: j.req_str("text")?.to_string(),
            reduces: j.req_u64("reduces")? as u32,
        }),
        "query_stage" => Ok(AppPayload::QueryStage {
            stage: stage_from_json(
                j.get("stage")
                    .ok_or_else(|| Error::Codec("missing 'stage'".into()))?,
            )?,
        }),
        "rsummary" => {
            let strs = |key: &str| -> Result<Vec<String>> {
                j.get(key)
                    .and_then(Json::as_arr)
                    .map(|a| {
                        a.iter()
                            .filter_map(Json::as_str)
                            .map(str::to_string)
                            .collect()
                    })
                    .ok_or_else(|| Error::Codec(format!("missing array '{key}'")))
            };
            Ok(AppPayload::RSummary {
                input_dir: j.req_str("input_dir")?.to_string(),
                output_dir: j.req_str("output_dir")?.to_string(),
                fields: strs("fields")?,
                delimiter: j
                    .get("delimiter")
                    .and_then(Json::as_str)
                    .and_then(|s| s.chars().next())
                    .unwrap_or(','),
                columns: strs("columns")?,
            })
        }
        other => Err(Error::Api(format!("unknown payload type '{other}'"))),
    }
}

fn str_arr(items: &[String]) -> Json {
    Json::Arr(items.iter().map(|s| Json::str(&**s)).collect())
}

fn req_str_arr(j: &Json, key: &str) -> Result<Vec<String>> {
    j.get(key)
        .and_then(Json::as_arr)
        .map(|a| {
            a.iter()
                .filter_map(Json::as_str)
                .map(str::to_string)
                .collect()
        })
        .ok_or_else(|| Error::Codec(format!("missing array '{key}'")))
}

fn opt_str(j: &Json, key: &str) -> Option<String> {
    j.get(key).and_then(Json::as_str).map(str::to_string)
}

/// Serialize one compiled query stage. Field presence rules (mirrored
/// byte-for-byte by `python/hpcw_client/wire.py`): the right-side block
/// appears only for `join` stages; `filter`/`left_filter`/`right_filter`
/// (the pushed-down join predicates)/`group_by`/`sort_by`/`limit` only
/// when set; `project`/`aggregates` only when non-empty; `desc` only
/// when true.
pub fn stage_to_json(s: &StageSpec) -> Json {
    let mut fields = vec![
        ("kind", Json::str(s.kind.as_wire())),
        ("input_dir", Json::str(&*s.input_dir)),
        ("input_fields", str_arr(&s.input_schema.fields)),
        ("input_delim", Json::str(s.input_schema.delimiter.to_string())),
        ("output_dir", Json::str(&*s.output_dir)),
        ("reduces", Json::num(s.n_reduces as f64)),
    ];
    if s.intermediate {
        fields.push(("intermediate", Json::Bool(true)));
    }
    if let (Some(rd), Some(rs)) = (&s.right_dir, &s.right_schema) {
        fields.push(("right_dir", Json::str(&**rd)));
        fields.push(("right_fields", str_arr(&rs.fields)));
        fields.push(("right_delim", Json::str(rs.delimiter.to_string())));
    }
    if let Some(k) = &s.left_key {
        fields.push(("left_key", Json::str(&**k)));
    }
    if let Some(k) = &s.right_key {
        fields.push(("right_key", Json::str(&**k)));
    }
    if !s.combined_fields.is_empty() {
        fields.push(("combined_fields", str_arr(&s.combined_fields)));
    }
    if let Some(f) = &s.filter {
        fields.push(("filter", Json::str(&**f)));
    }
    if let Some(f) = &s.left_filter {
        fields.push(("left_filter", Json::str(&**f)));
    }
    if let Some(f) = &s.right_filter {
        fields.push(("right_filter", Json::str(&**f)));
    }
    if !s.project.is_empty() {
        fields.push(("project", str_arr(&s.project)));
    }
    if let Some(g) = &s.group_by {
        fields.push(("group_by", Json::str(&**g)));
    }
    if !s.aggregates.is_empty() {
        fields.push((
            "aggregates",
            Json::Arr(
                s.aggregates
                    .iter()
                    .map(|a| {
                        Json::obj(vec![
                            ("fn", Json::str(a.agg.name())),
                            ("expr", Json::str(&*a.expr)),
                        ])
                    })
                    .collect(),
            ),
        ));
    }
    if let Some(k) = &s.sort_by {
        fields.push(("sort_by", Json::str(&**k)));
    }
    if s.desc {
        fields.push(("desc", Json::Bool(true)));
    }
    if let Some(l) = s.limit {
        fields.push(("limit", Json::num(l as f64)));
    }
    Json::obj(fields)
}

/// Parse a stage document (inverse of [`stage_to_json`]).
pub fn stage_from_json(j: &Json) -> Result<StageSpec> {
    let delim_of = |key: &str| -> char {
        j.get(key)
            .and_then(Json::as_str)
            .and_then(|s| s.chars().next())
            .unwrap_or('\t')
    };
    let right_dir = opt_str(j, "right_dir");
    let right_schema = if right_dir.is_some() {
        Some(Schema {
            fields: req_str_arr(j, "right_fields")?,
            delimiter: delim_of("right_delim"),
        })
    } else {
        None
    };
    let aggregates = match j.get("aggregates").and_then(Json::as_arr) {
        Some(items) => items
            .iter()
            .map(|a| {
                let name = a.req_str("fn")?;
                Ok(AggSpec {
                    agg: Aggregate::parse(name).ok_or_else(|| {
                        Error::Codec(format!("unknown aggregate '{name}'"))
                    })?,
                    expr: a.req_str("expr")?.to_string(),
                })
            })
            .collect::<Result<Vec<_>>>()?,
        None => Vec::new(),
    };
    Ok(StageSpec {
        kind: StageKind::from_wire(j.req_str("kind")?)?,
        input_dir: j.req_str("input_dir")?.to_string(),
        input_schema: Schema {
            fields: req_str_arr(j, "input_fields")?,
            delimiter: delim_of("input_delim"),
        },
        right_dir,
        right_schema,
        left_key: opt_str(j, "left_key"),
        right_key: opt_str(j, "right_key"),
        combined_fields: match j.get("combined_fields") {
            Some(_) => req_str_arr(j, "combined_fields")?,
            None => Vec::new(),
        },
        filter: opt_str(j, "filter"),
        left_filter: opt_str(j, "left_filter"),
        right_filter: opt_str(j, "right_filter"),
        project: match j.get("project") {
            Some(_) => req_str_arr(j, "project")?,
            None => Vec::new(),
        },
        group_by: opt_str(j, "group_by"),
        aggregates,
        sort_by: opt_str(j, "sort_by"),
        desc: j.get("desc").and_then(Json::as_bool).unwrap_or(false),
        limit: j.get("limit").and_then(Json::as_u64),
        output_dir: j.req_str("output_dir")?.to_string(),
        n_reduces: j.req_u64("reduces")? as u32,
        intermediate: j.get("intermediate").and_then(Json::as_bool).unwrap_or(false),
    })
}

/// Apply `f` to every free-form string field of a payload — the fields
/// that may carry `${steps.<name>.output_dir}` references (workflow
/// output→input chaining).
pub fn payload_map_strings(
    p: &AppPayload,
    f: &mut dyn FnMut(&str) -> Result<String>,
) -> Result<AppPayload> {
    Ok(match p {
        AppPayload::Terasort { .. } => p.clone(),
        AppPayload::Teragen { rows, maps, dir } => AppPayload::Teragen {
            rows: *rows,
            maps: *maps,
            dir: f(dir)?,
        },
        AppPayload::PigScript { script, reduces } => AppPayload::PigScript {
            script: f(script)?,
            reduces: *reduces,
        },
        AppPayload::HiveQuery { sql, reduces } => AppPayload::HiveQuery {
            sql: f(sql)?,
            reduces: *reduces,
        },
        AppPayload::Query {
            engine,
            text,
            reduces,
        } => AppPayload::Query {
            engine: engine.clone(),
            text: f(text)?,
            reduces: *reduces,
        },
        AppPayload::QueryStage { stage } => {
            let mut s = stage.clone();
            s.input_dir = f(&s.input_dir)?;
            if let Some(rd) = &s.right_dir {
                s.right_dir = Some(f(rd)?);
            }
            s.output_dir = f(&s.output_dir)?;
            AppPayload::QueryStage { stage: s }
        }
        AppPayload::RSummary {
            input_dir,
            output_dir,
            fields,
            delimiter,
            columns,
        } => AppPayload::RSummary {
            input_dir: f(input_dir)?,
            output_dir: f(output_dir)?,
            fields: fields.clone(),
            delimiter: *delimiter,
            columns: columns.clone(),
        },
    })
}

/// Step names referenced as `${steps.<name>.output_dir}` in one string.
pub fn step_refs(s: &str) -> Result<Vec<String>> {
    let mut out = Vec::new();
    let mut rest = s;
    while let Some(start) = rest.find("${") {
        let tail = &rest[start + 2..];
        let end = tail
            .find('}')
            .ok_or_else(|| Error::Api(format!("unterminated ${{...}} reference in '{s}'")))?;
        let inner = &tail[..end];
        let name = inner
            .strip_prefix("steps.")
            .and_then(|x| x.strip_suffix(".output_dir"))
            .ok_or_else(|| {
                Error::Api(format!(
                    "bad reference '${{{inner}}}': only ${{steps.<name>.output_dir}} is supported"
                ))
            })?;
        out.push(name.to_string());
        rest = &tail[end + 1..];
    }
    Ok(out)
}

/// Replace every `${steps.<name>.output_dir}` with `lookup(name)`.
pub fn substitute_step_refs(
    s: &str,
    lookup: &dyn Fn(&str) -> Option<String>,
) -> Result<String> {
    let mut out = String::with_capacity(s.len());
    let mut rest = s;
    while let Some(start) = rest.find("${") {
        out.push_str(&rest[..start]);
        let tail = &rest[start + 2..];
        let end = tail
            .find('}')
            .ok_or_else(|| Error::Api(format!("unterminated ${{...}} reference in '{s}'")))?;
        let inner = &tail[..end];
        let name = inner
            .strip_prefix("steps.")
            .and_then(|x| x.strip_suffix(".output_dir"))
            .ok_or_else(|| {
                Error::Api(format!(
                    "bad reference '${{{inner}}}': only ${{steps.<name>.output_dir}} is supported"
                ))
            })?;
        let val = lookup(name).ok_or_else(|| {
            Error::Api(format!("step '{name}' has no output_dir yet (bad dependency?)"))
        })?;
        out.push_str(&val);
        rest = &tail[end + 1..];
    }
    out.push_str(rest);
    Ok(out)
}

// ---------------------------------------------------------------------------
// Output-path containment (GET /v1/jobs/{id}/output?path=...)
// ---------------------------------------------------------------------------

/// Normalize an absolute path: collapse `//` and `.` segments, resolve
/// `..` textually, and reject any `..` that climbs past the filesystem
/// root. Returns the canonical `/a/b/c` form.
fn normalize_abs(p: &str) -> Result<String> {
    if !p.starts_with('/') {
        return Err(Error::Api(format!("path '{p}' is not absolute")));
    }
    let mut segs: Vec<&str> = Vec::new();
    for seg in p.split('/') {
        match seg {
            "" | "." => {}
            ".." => {
                if segs.pop().is_none() {
                    return Err(Error::Api(format!("path '{p}' escapes the filesystem root")));
                }
            }
            s => segs.push(s),
        }
    }
    Ok(format!("/{}", segs.join("/")))
}

/// Resolve a client-supplied output path against a job's output root.
/// Relative paths are joined to the root; absolute paths must stay under
/// it. Any escape (`..`, absolute path outside the root) is an error the
/// API layer reports as [`code::BAD_PATH`].
pub fn resolve_output_path(root: &str, path: &str) -> Result<String> {
    let root = normalize_abs(root)?;
    let joined = if path.starts_with('/') {
        path.to_string()
    } else {
        format!("{root}/{path}")
    };
    let full = normalize_abs(&joined)?;
    if full == root || full.starts_with(&format!("{root}/")) {
        Ok(full)
    } else {
        Err(Error::Api(format!(
            "path '{path}' escapes the job output root '{root}'"
        )))
    }
}

// ---------------------------------------------------------------------------
// SubmitRequest / ResultDoc / JobDoc / JobsPage
// ---------------------------------------------------------------------------

/// `POST /v1/jobs` body.
#[derive(Debug, Clone, PartialEq)]
pub struct SubmitRequest {
    pub nodes: u32,
    pub user: String,
    pub payload: AppPayload,
}

impl SubmitRequest {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("nodes", Json::num(self.nodes as f64)),
            ("user", Json::str(&*self.user)),
            ("payload", payload_to_json(&self.payload)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<SubmitRequest> {
        Ok(SubmitRequest {
            nodes: j.req_u64("nodes")? as u32,
            user: j.req_str("user")?.to_string(),
            payload: payload_from_json(
                j.get("payload")
                    .ok_or_else(|| Error::Codec("missing 'payload'".into()))?,
            )?,
        })
    }
}

/// A finished application's result document.
#[derive(Debug, Clone, PartialEq)]
pub struct ResultDoc {
    pub kind: String,
    pub output_dir: String,
    pub output_files: Vec<String>,
    pub records: u64,
    pub validated: bool,
    pub wall_ms: u64,
    pub counters: Vec<(String, u64)>,
}

impl ResultDoc {
    pub fn from_result(r: &AppResult) -> ResultDoc {
        ResultDoc {
            kind: r.kind.to_string(),
            output_dir: r.output_dir.clone(),
            output_files: r.output_files.clone(),
            records: r.records,
            validated: r.validated,
            wall_ms: r.wall.as_millis() as u64,
            counters: r.counters.clone(),
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("kind", Json::str(&*self.kind)),
            ("output_dir", Json::str(&*self.output_dir)),
            (
                "output_files",
                Json::Arr(self.output_files.iter().map(|f| Json::str(&**f)).collect()),
            ),
            ("records", Json::num(self.records as f64)),
            ("validated", Json::Bool(self.validated)),
            ("wall_ms", Json::num(self.wall_ms as f64)),
            (
                "counters",
                Json::Obj(
                    self.counters
                        .iter()
                        .map(|(k, v)| (k.clone(), Json::num(*v as f64)))
                        .collect(),
                ),
            ),
        ])
    }

    pub fn from_json(j: &Json) -> Result<ResultDoc> {
        let files = j
            .get("output_files")
            .and_then(Json::as_arr)
            .ok_or_else(|| Error::Codec("missing array 'output_files'".into()))?
            .iter()
            .filter_map(Json::as_str)
            .map(str::to_string)
            .collect();
        let counters = match j.get("counters") {
            Some(Json::Obj(pairs)) => pairs
                .iter()
                .filter_map(|(k, v)| v.as_u64().map(|n| (k.clone(), n)))
                .collect(),
            _ => Vec::new(),
        };
        Ok(ResultDoc {
            kind: j.req_str("kind")?.to_string(),
            output_dir: j.req_str("output_dir")?.to_string(),
            output_files: files,
            records: j.req_u64("records")?,
            validated: j.get("validated").and_then(Json::as_bool).unwrap_or(false),
            wall_ms: j.req_u64("wall_ms")?,
            counters,
        })
    }
}

/// `GET /v1/jobs/{id}` response (and one row of `GET /v1/jobs`).
#[derive(Debug, Clone, PartialEq)]
pub struct JobDoc {
    pub job: u64,
    pub kind: String,
    pub state: JobState,
    /// Present once the job is `DONE` (omitted in list rows).
    pub result: Option<ResultDoc>,
    /// Present once the job failed.
    pub error: Option<String>,
}

impl JobDoc {
    pub fn is_terminal(&self) -> bool {
        self.state.is_terminal()
    }

    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("job", Json::num(self.job as f64)),
            ("kind", Json::str(&*self.kind)),
            ("state", Json::str(job_state_to_wire(self.state))),
        ];
        if let Some(r) = &self.result {
            fields.push(("result", r.to_json()));
        }
        if let Some(e) = &self.error {
            fields.push(("error", Json::str(&**e)));
        }
        Json::obj(fields)
    }

    pub fn from_json(j: &Json) -> Result<JobDoc> {
        Ok(JobDoc {
            job: j.req_u64("job")?,
            kind: j.req_str("kind")?.to_string(),
            state: job_state_from_wire(j.req_str("state")?)?,
            result: match j.get("result") {
                Some(r) => Some(ResultDoc::from_json(r)?),
                None => None,
            },
            error: j.get("error").and_then(Json::as_str).map(str::to_string),
        })
    }
}

/// `GET /v1/jobs?offset=N&limit=N` response.
#[derive(Debug, Clone, PartialEq)]
pub struct JobsPage {
    pub jobs: Vec<JobDoc>,
    pub total: u64,
    pub offset: u64,
}

impl JobsPage {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            (
                "jobs",
                Json::Arr(self.jobs.iter().map(JobDoc::to_json).collect()),
            ),
            ("total", Json::num(self.total as f64)),
            ("offset", Json::num(self.offset as f64)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<JobsPage> {
        let jobs = j
            .get("jobs")
            .and_then(Json::as_arr)
            .ok_or_else(|| Error::Codec("missing array 'jobs'".into()))?
            .iter()
            .map(JobDoc::from_json)
            .collect::<Result<Vec<_>>>()?;
        Ok(JobsPage {
            jobs,
            total: j.req_u64("total")?,
            offset: j.req_u64("offset")?,
        })
    }
}

// ---------------------------------------------------------------------------
// Workflows: spec (submit) and doc (status)
// ---------------------------------------------------------------------------

/// One named step of a workflow DAG.
#[derive(Debug, Clone, PartialEq)]
pub struct StepSpec {
    pub name: String,
    /// Names of steps that must be `DONE` before this one starts.
    pub after: Vec<String>,
    /// Re-submission attempts allowed after a failure (0 = fail fast).
    pub retries: u32,
    pub payload: AppPayload,
}

/// `POST /v1/workflows` body: a named-step DAG.
#[derive(Debug, Clone, PartialEq)]
pub struct WorkflowSpec {
    pub name: String,
    pub user: String,
    /// Nodes requested for every step's LSF job.
    pub nodes: u32,
    pub steps: Vec<StepSpec>,
}

impl WorkflowSpec {
    /// A linear chain (the pre-DAG workflow shape): `stepN` after
    /// `stepN-1`, no retries.
    pub fn linear(name: &str, user: &str, nodes: u32, payloads: Vec<AppPayload>) -> WorkflowSpec {
        let steps = payloads
            .into_iter()
            .enumerate()
            .map(|(i, payload)| StepSpec {
                name: format!("step{i}"),
                after: if i == 0 {
                    Vec::new()
                } else {
                    vec![format!("step{}", i - 1)]
                },
                retries: 0,
                payload,
            })
            .collect();
        WorkflowSpec {
            name: name.to_string(),
            user: user.to_string(),
            nodes,
            steps,
        }
    }

    /// Structural validation: non-empty, unique well-formed names, known
    /// acyclic dependencies, and `${steps.<name>.output_dir}` references
    /// only to declared dependencies.
    pub fn validate(&self) -> Result<()> {
        if self.steps.is_empty() {
            return Err(Error::Api("workflow with no steps".into()));
        }
        let mut names = std::collections::BTreeSet::new();
        for s in &self.steps {
            if s.name.is_empty()
                || !s
                    .name
                    .chars()
                    .all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '-')
            {
                return Err(Error::Api(format!(
                    "bad step name '{}': use [A-Za-z0-9_-]+",
                    s.name
                )));
            }
            if !names.insert(s.name.as_str()) {
                return Err(Error::Api(format!("duplicate step name '{}'", s.name)));
            }
        }
        for s in &self.steps {
            let mut deps = std::collections::BTreeSet::new();
            for d in &s.after {
                if d == &s.name {
                    return Err(Error::Api(format!("step '{}' depends on itself", s.name)));
                }
                if !names.contains(d.as_str()) {
                    return Err(Error::Api(format!(
                        "step '{}' depends on unknown step '{d}'",
                        s.name
                    )));
                }
                if !deps.insert(d.as_str()) {
                    return Err(Error::Api(format!(
                        "step '{}' lists dependency '{d}' twice",
                        s.name
                    )));
                }
            }
            // Output references must point at declared dependencies, so a
            // referenced output_dir is always available at submit time.
            let mut refs = Vec::new();
            payload_map_strings(&s.payload, &mut |text| {
                refs.extend(step_refs(text)?);
                Ok(text.to_string())
            })?;
            for r in refs {
                if !s.after.iter().any(|d| d == &r) {
                    return Err(Error::Api(format!(
                        "step '{}' references ${{steps.{r}.output_dir}} but does not list '{r}' in after[]",
                        s.name
                    )));
                }
            }
        }
        // Kahn's algorithm: every step must be reachable from the roots.
        let mut indeg: std::collections::BTreeMap<&str, usize> = self
            .steps
            .iter()
            .map(|s| (s.name.as_str(), s.after.len()))
            .collect();
        let mut ready: Vec<&str> = indeg
            .iter()
            .filter(|(_, &d)| d == 0)
            .map(|(&n, _)| n)
            .collect();
        let mut seen = 0usize;
        while let Some(n) = ready.pop() {
            seen += 1;
            for s in &self.steps {
                if s.after.iter().any(|d| d == n) {
                    let e = indeg.get_mut(s.name.as_str()).unwrap();
                    *e -= 1;
                    if *e == 0 {
                        ready.push(s.name.as_str());
                    }
                }
            }
        }
        if seen != self.steps.len() {
            return Err(Error::Api(format!(
                "workflow '{}' has a dependency cycle",
                self.name
            )));
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        let steps = self
            .steps
            .iter()
            .map(|s| {
                Json::obj(vec![
                    ("name", Json::str(&*s.name)),
                    (
                        "after",
                        Json::Arr(s.after.iter().map(|a| Json::str(&**a)).collect()),
                    ),
                    ("retries", Json::num(s.retries as f64)),
                    ("payload", payload_to_json(&s.payload)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("name", Json::str(&*self.name)),
            ("user", Json::str(&*self.user)),
            ("nodes", Json::num(self.nodes as f64)),
            ("steps", Json::Arr(steps)),
        ])
    }

    /// Parse and validate. `after` and `retries` are optional per step.
    pub fn from_json(j: &Json) -> Result<WorkflowSpec> {
        let steps_json = j
            .get("steps")
            .and_then(Json::as_arr)
            .ok_or_else(|| Error::Api("workflow needs steps[]".into()))?;
        let steps = steps_json
            .iter()
            .map(|s| {
                let after = match s.get("after") {
                    Some(Json::Arr(items)) => items
                        .iter()
                        .filter_map(Json::as_str)
                        .map(str::to_string)
                        .collect(),
                    _ => Vec::new(),
                };
                Ok(StepSpec {
                    name: s.req_str("name")?.to_string(),
                    after,
                    retries: s.get("retries").and_then(Json::as_u64).unwrap_or(0) as u32,
                    payload: payload_from_json(
                        s.get("payload")
                            .ok_or_else(|| Error::Codec("step missing 'payload'".into()))?,
                    )?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let spec = WorkflowSpec {
            name: j.req_str("name")?.to_string(),
            user: j.req_str("user")?.to_string(),
            nodes: j.req_u64("nodes")? as u32,
            steps,
        };
        spec.validate()?;
        Ok(spec)
    }
}

/// Execution state of one workflow step.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StepState {
    /// Dependencies not yet satisfied.
    Waiting,
    /// Submitted to LSF (possibly a retry attempt).
    Running,
    Done,
    /// Failed after exhausting retries.
    Failed,
    /// Never ran: an upstream step failed.
    Skipped,
}

impl StepState {
    pub fn as_wire(self) -> &'static str {
        match self {
            StepState::Waiting => "WAITING",
            StepState::Running => "RUNNING",
            StepState::Done => "DONE",
            StepState::Failed => "FAILED",
            StepState::Skipped => "SKIPPED",
        }
    }

    pub fn from_wire(s: &str) -> Result<StepState> {
        match s {
            "WAITING" => Ok(StepState::Waiting),
            "RUNNING" => Ok(StepState::Running),
            "DONE" => Ok(StepState::Done),
            "FAILED" => Ok(StepState::Failed),
            "SKIPPED" => Ok(StepState::Skipped),
            other => Err(Error::Codec(format!("unknown step state '{other}'"))),
        }
    }

    pub fn is_terminal(self) -> bool {
        matches!(self, StepState::Done | StepState::Failed | StepState::Skipped)
    }
}

/// Per-step progress row inside [`WorkflowDoc`].
#[derive(Debug, Clone, PartialEq)]
pub struct StepDoc {
    pub name: String,
    pub kind: String,
    pub state: StepState,
    pub attempts: u32,
    pub job: Option<u64>,
    pub output_dir: Option<String>,
}

impl StepDoc {
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("name", Json::str(&*self.name)),
            ("kind", Json::str(&*self.kind)),
            ("state", Json::str(self.state.as_wire())),
            ("attempts", Json::num(self.attempts as f64)),
        ];
        if let Some(job) = self.job {
            fields.push(("job", Json::num(job as f64)));
        }
        if let Some(d) = &self.output_dir {
            fields.push(("output_dir", Json::str(&**d)));
        }
        Json::obj(fields)
    }

    pub fn from_json(j: &Json) -> Result<StepDoc> {
        Ok(StepDoc {
            name: j.req_str("name")?.to_string(),
            kind: j.req_str("kind")?.to_string(),
            state: StepState::from_wire(j.req_str("state")?)?,
            attempts: j.req_u64("attempts")? as u32,
            job: j.get("job").and_then(Json::as_u64),
            output_dir: j
                .get("output_dir")
                .and_then(Json::as_str)
                .map(str::to_string),
        })
    }
}

/// `GET /v1/workflows/{id}` response.
#[derive(Debug, Clone, PartialEq)]
pub struct WorkflowDoc {
    pub workflow: u64,
    pub name: String,
    pub complete: bool,
    pub aborted: bool,
    pub steps: Vec<StepDoc>,
}

impl WorkflowDoc {
    pub fn is_terminal(&self) -> bool {
        self.complete || self.aborted
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("workflow", Json::num(self.workflow as f64)),
            ("name", Json::str(&*self.name)),
            ("complete", Json::Bool(self.complete)),
            ("aborted", Json::Bool(self.aborted)),
            (
                "steps",
                Json::Arr(self.steps.iter().map(StepDoc::to_json).collect()),
            ),
        ])
    }

    pub fn from_json(j: &Json) -> Result<WorkflowDoc> {
        let steps = j
            .get("steps")
            .and_then(Json::as_arr)
            .ok_or_else(|| Error::Codec("missing array 'steps'".into()))?
            .iter()
            .map(StepDoc::from_json)
            .collect::<Result<Vec<_>>>()?;
        Ok(WorkflowDoc {
            workflow: j.req_u64("workflow")?,
            name: j.req_str("name")?.to_string(),
            complete: j.get("complete").and_then(Json::as_bool).unwrap_or(false),
            aborted: j.get("aborted").and_then(Json::as_bool).unwrap_or(false),
            steps,
        })
    }
}

// ---------------------------------------------------------------------------
// Cluster (GET /v1/cluster)
// ---------------------------------------------------------------------------

/// One machine-model node as reported by `GET /v1/cluster`.
#[derive(Debug, Clone, PartialEq)]
pub struct NodeDoc {
    pub node: u64,
    pub hostname: String,
    /// `UP`, `DRAINED` or `DOWN`.
    pub state: String,
    pub cores: u64,
    pub mem_mb: u64,
    /// CloudSim-style per-core speed tier; `REFERENCE_MIPS` (1000) on a
    /// homogeneous pool. Feeds the adaptive scheduler
    /// (`docs/SCHEDULING.md`).
    pub mips: u64,
    /// LSF job currently leasing this node, if any.
    pub job: Option<u64>,
    /// Milliseconds left on the lease's wall limit (absent when the lease
    /// has no wall limit or the node is free).
    pub lease_remaining_ms: Option<u64>,
}

impl NodeDoc {
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("node", Json::num(self.node as f64)),
            ("hostname", Json::str(&*self.hostname)),
            ("state", Json::str(&*self.state)),
            ("cores", Json::num(self.cores as f64)),
            ("mem_mb", Json::num(self.mem_mb as f64)),
            ("mips", Json::num(self.mips as f64)),
        ];
        if let Some(j) = self.job {
            fields.push(("job", Json::num(j as f64)));
        }
        if let Some(ms) = self.lease_remaining_ms {
            fields.push(("lease_remaining_ms", Json::num(ms as f64)));
        }
        Json::obj(fields)
    }

    pub fn from_json(j: &Json) -> Result<NodeDoc> {
        Ok(NodeDoc {
            node: j.req_u64("node")?,
            hostname: j.req_str("hostname")?.to_string(),
            state: j.req_str("state")?.to_string(),
            cores: j.req_u64("cores")?,
            mem_mb: j.req_u64("mem_mb")?,
            // Absent in pre-PR-10 payloads: reference speed.
            mips: j.get("mips").and_then(Json::as_u64).unwrap_or(REFERENCE_MIPS),
            job: j.get("job").and_then(Json::as_u64),
            lease_remaining_ms: j.get("lease_remaining_ms").and_then(Json::as_u64),
        })
    }
}

/// Two-level storage tier snapshot on `GET /v1/cluster` (and, as gauges,
/// `GET /v1/metrics`). Present only when the stack's DFS tiers its
/// storage (`HPCW_MEM_BUDGET` / `lustre.mem_budget_bytes`).
#[derive(Debug, Clone, PartialEq)]
pub struct TierDoc {
    /// Burst-tier budget in bytes; 0 = unbounded (pure burst, no backing
    /// traffic — the doc still appears so clients can see the mode).
    pub mem_budget_bytes: u64,
    /// Bytes currently resident in the burst tier.
    pub resident_bytes: u64,
    /// Bytes currently held by the backing tier (evicted + written back).
    pub backing_bytes: u64,
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    pub promotions: u64,
    pub writeback_bytes: u64,
    pub spill_bytes: u64,
    /// Modeled seconds of backing-tier I/O (priced by the backend's
    /// `FsModel`).
    pub simulated_io_s: f64,
}

impl TierDoc {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("mem_budget_bytes", Json::num(self.mem_budget_bytes as f64)),
            ("resident_bytes", Json::num(self.resident_bytes as f64)),
            ("backing_bytes", Json::num(self.backing_bytes as f64)),
            ("hits", Json::num(self.hits as f64)),
            ("misses", Json::num(self.misses as f64)),
            ("evictions", Json::num(self.evictions as f64)),
            ("promotions", Json::num(self.promotions as f64)),
            ("writeback_bytes", Json::num(self.writeback_bytes as f64)),
            ("spill_bytes", Json::num(self.spill_bytes as f64)),
            ("simulated_io_s", Json::num(self.simulated_io_s)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<TierDoc> {
        Ok(TierDoc {
            mem_budget_bytes: j.req_u64("mem_budget_bytes")?,
            resident_bytes: j.req_u64("resident_bytes")?,
            backing_bytes: j.req_u64("backing_bytes")?,
            hits: j.req_u64("hits")?,
            misses: j.req_u64("misses")?,
            evictions: j.req_u64("evictions")?,
            promotions: j.req_u64("promotions")?,
            writeback_bytes: j.req_u64("writeback_bytes")?,
            spill_bytes: j.req_u64("spill_bytes")?,
            simulated_io_s: j
                .get("simulated_io_s")
                .and_then(Json::as_f64)
                .ok_or_else(|| Error::Codec("missing number 'simulated_io_s'".into()))?,
        })
    }
}

/// `GET /v1/cluster` response: node states + lease info + totals.
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterDoc {
    pub nodes: Vec<NodeDoc>,
    pub up: u64,
    pub drained: u64,
    pub down: u64,
    /// Nodes currently leased to running jobs.
    pub leased: u64,
    /// Storage-tier snapshot; absent for single-tier backends.
    pub tier: Option<TierDoc>,
}

impl ClusterDoc {
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            (
                "nodes",
                Json::Arr(self.nodes.iter().map(NodeDoc::to_json).collect()),
            ),
            ("up", Json::num(self.up as f64)),
            ("drained", Json::num(self.drained as f64)),
            ("down", Json::num(self.down as f64)),
            ("leased", Json::num(self.leased as f64)),
        ];
        if let Some(t) = &self.tier {
            fields.push(("tier", t.to_json()));
        }
        Json::obj(fields)
    }

    pub fn from_json(j: &Json) -> Result<ClusterDoc> {
        let nodes = j
            .get("nodes")
            .and_then(Json::as_arr)
            .ok_or_else(|| Error::Codec("missing array 'nodes'".into()))?
            .iter()
            .map(NodeDoc::from_json)
            .collect::<Result<Vec<_>>>()?;
        Ok(ClusterDoc {
            nodes,
            up: j.req_u64("up")?,
            drained: j.req_u64("drained")?,
            down: j.req_u64("down")?,
            leased: j.req_u64("leased")?,
            tier: j.get("tier").map(TierDoc::from_json).transpose()?,
        })
    }
}

// ---------------------------------------------------------------------------
// Tenancy introspection
// ---------------------------------------------------------------------------

/// One tenant's identity + live accounting on `GET /v1/tenants`.
///
/// All counts are integers (shares as whole percent) so the canonical
/// encoding is float-format-free and byte-identical across languages.
#[derive(Debug, Clone, PartialEq)]
pub struct TenantDoc {
    pub name: String,
    /// Hierarchical fair-share queue the tenant's jobs dispatch from.
    pub queue: String,
    /// Apps submitted and not yet terminal.
    pub running_apps: u64,
    /// Containers (node leases) currently held.
    pub containers: u64,
    /// Cumulative DFS bytes written by completed jobs.
    pub dfs_bytes: u64,
    pub submitted: u64,
    pub rate_limited: u64,
    pub quota_rejected: u64,
    pub breaker_rejected: u64,
    /// Circuit-breaker state: `closed`, `open` or `half_open`.
    pub breaker: String,
}

impl TenantDoc {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(&*self.name)),
            ("queue", Json::str(&*self.queue)),
            ("running_apps", Json::num(self.running_apps as f64)),
            ("containers", Json::num(self.containers as f64)),
            ("dfs_bytes", Json::num(self.dfs_bytes as f64)),
            ("submitted", Json::num(self.submitted as f64)),
            ("rate_limited", Json::num(self.rate_limited as f64)),
            ("quota_rejected", Json::num(self.quota_rejected as f64)),
            ("breaker_rejected", Json::num(self.breaker_rejected as f64)),
            ("breaker", Json::str(&*self.breaker)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<TenantDoc> {
        Ok(TenantDoc {
            name: j.req_str("name")?.to_string(),
            queue: j.req_str("queue")?.to_string(),
            running_apps: j.req_u64("running_apps")?,
            containers: j.req_u64("containers")?,
            dfs_bytes: j.req_u64("dfs_bytes")?,
            submitted: j.req_u64("submitted")?,
            rate_limited: j.req_u64("rate_limited")?,
            quota_rejected: j.req_u64("quota_rejected")?,
            breaker_rejected: j.req_u64("breaker_rejected")?,
            breaker: j.req_str("breaker")?.to_string(),
        })
    }
}

/// One fair-share queue's policy + live accounting on `GET /v1/queues`.
#[derive(Debug, Clone, PartialEq)]
pub struct QueueDoc {
    /// Dot-path under `root`, e.g. `root.research.alice`.
    pub name: String,
    pub weight: u64,
    /// Min-guarantee floor, percent of total slots.
    pub min_pct: u64,
    /// Max-share cap, percent of total slots.
    pub max_pct: u64,
    /// Jobs currently running out of this queue.
    pub running: u64,
    /// Jobs served over the queue's lifetime (the deficit counter).
    pub served: u64,
    /// Observed share of total service, whole percent.
    pub share_pct: u64,
    /// Containers preempted from this queue's apps.
    pub preemptions: u64,
    /// Total microseconds this queue's jobs waited before dispatch.
    pub wait_us: u64,
}

impl QueueDoc {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(&*self.name)),
            ("weight", Json::num(self.weight as f64)),
            ("min_pct", Json::num(self.min_pct as f64)),
            ("max_pct", Json::num(self.max_pct as f64)),
            ("running", Json::num(self.running as f64)),
            ("served", Json::num(self.served as f64)),
            ("share_pct", Json::num(self.share_pct as f64)),
            ("preemptions", Json::num(self.preemptions as f64)),
            ("wait_us", Json::num(self.wait_us as f64)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<QueueDoc> {
        Ok(QueueDoc {
            name: j.req_str("name")?.to_string(),
            weight: j.req_u64("weight")?,
            min_pct: j.req_u64("min_pct")?,
            max_pct: j.req_u64("max_pct")?,
            running: j.req_u64("running")?,
            served: j.req_u64("served")?,
            share_pct: j.req_u64("share_pct")?,
            preemptions: j.req_u64("preemptions")?,
            wait_us: j.req_u64("wait_us")?,
        })
    }
}

// ---------------------------------------------------------------------------
// Events
// ---------------------------------------------------------------------------

/// One entry of the monotonic event journal (`GET /v1/events?since=seq`):
/// a job, workflow or step state transition observed by the pump.
#[derive(Debug, Clone, PartialEq)]
pub struct EventDoc {
    /// Monotonic sequence number, 1-based, never reused.
    pub seq: u64,
    /// `"job"`, `"workflow"` or `"step"`.
    pub kind: String,
    /// Job id for job events; workflow id for workflow/step events.
    pub id: u64,
    /// Wire state token ([`job_state_to_wire`] / [`StepState::as_wire`],
    /// or `COMPLETE`/`ABORTED` for workflow events).
    pub state: String,
    /// Step name, present on step events.
    pub step: Option<String>,
}

impl EventDoc {
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("seq", Json::num(self.seq as f64)),
            ("kind", Json::str(&*self.kind)),
            ("id", Json::num(self.id as f64)),
            ("state", Json::str(&*self.state)),
        ];
        if let Some(s) = &self.step {
            fields.push(("step", Json::str(&**s)));
        }
        Json::obj(fields)
    }

    pub fn from_json(j: &Json) -> Result<EventDoc> {
        Ok(EventDoc {
            seq: j.req_u64("seq")?,
            kind: j.req_str("kind")?.to_string(),
            id: j.req_u64("id")?,
            state: j.req_str("state")?.to_string(),
            step: j.get("step").and_then(Json::as_str).map(str::to_string),
        })
    }
}

/// `GET /v1/events` response: events after `since`, plus the cursor to
/// pass as the next `since`.
#[derive(Debug, Clone, PartialEq)]
pub struct EventPage {
    pub events: Vec<EventDoc>,
    pub next: u64,
}

impl EventPage {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            (
                "events",
                Json::Arr(self.events.iter().map(EventDoc::to_json).collect()),
            ),
            ("next", Json::num(self.next as f64)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<EventPage> {
        let events = j
            .get("events")
            .and_then(Json::as_arr)
            .ok_or_else(|| Error::Codec("missing array 'events'".into()))?
            .iter()
            .map(EventDoc::from_json)
            .collect::<Result<Vec<_>>>()?;
        Ok(EventPage {
            events,
            next: j.req_u64("next")?,
        })
    }
}

// ---------------------------------------------------------------------------
// Scenarios
// ---------------------------------------------------------------------------

/// Lifecycle of a submitted scenario (`POST /v1/scenarios`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScenarioState {
    Pending,
    Running,
    Done,
    Failed,
}

impl ScenarioState {
    pub fn as_wire(self) -> &'static str {
        match self {
            ScenarioState::Pending => "PENDING",
            ScenarioState::Running => "RUNNING",
            ScenarioState::Done => "DONE",
            ScenarioState::Failed => "FAILED",
        }
    }

    pub fn from_wire(s: &str) -> Result<ScenarioState> {
        match s {
            "PENDING" => Ok(ScenarioState::Pending),
            "RUNNING" => Ok(ScenarioState::Running),
            "DONE" => Ok(ScenarioState::Done),
            "FAILED" => Ok(ScenarioState::Failed),
            other => Err(Error::Codec(format!("unknown scenario state '{other}'"))),
        }
    }

    pub fn is_terminal(self) -> bool {
        matches!(self, ScenarioState::Done | ScenarioState::Failed)
    }
}

fn tiers_to_json(tiers: &[SlaTier]) -> Json {
    Json::Arr(tiers.iter().map(|t| Json::str(t.name())).collect())
}

fn machine_class_to_json(c: &MachineClass) -> Json {
    let mut fields = vec![
        ("name", Json::str(&*c.name)),
        ("count", Json::num(c.count as f64)),
        ("cores", Json::num(c.cores as f64)),
        ("mem_mb", Json::num(c.mem_mb as f64)),
        ("mips", Json::num(c.mips as f64)),
        ("active_w", Json::num(c.active_w as f64)),
        ("idle_w", Json::num(c.idle_w as f64)),
        ("sleep_w", Json::num(c.sleep_w as f64)),
        ("wake_ms", Json::num(c.wake_ms as f64)),
    ];
    if !c.tiers.is_empty() {
        fields.push(("tiers", tiers_to_json(&c.tiers)));
    }
    Json::obj(fields)
}

fn machine_class_from_json(j: &Json) -> Result<MachineClass> {
    let tiers = match j.get("tiers") {
        None => Vec::new(),
        Some(v) => v
            .as_arr()
            .ok_or_else(|| Error::Codec("machine class: tiers must be an array".into()))?
            .iter()
            .map(|t| {
                t.as_str()
                    .ok_or_else(|| Error::Codec("machine class: tiers must be strings".into()))
                    .and_then(SlaTier::from_name)
            })
            .collect::<Result<Vec<_>>>()?,
    };
    Ok(MachineClass {
        name: j.req_str("name")?.to_string(),
        count: j.req_u64("count")? as u32,
        cores: j.req_u64("cores")? as u32,
        mem_mb: j.req_u64("mem_mb")?,
        mips: j.get("mips").and_then(Json::as_u64).unwrap_or(REFERENCE_MIPS),
        active_w: j.get("active_w").and_then(Json::as_u64).unwrap_or(200),
        idle_w: j.get("idle_w").and_then(Json::as_u64).unwrap_or(100),
        sleep_w: j.get("sleep_w").and_then(Json::as_u64).unwrap_or(10),
        wake_ms: j.get("wake_ms").and_then(Json::as_u64).unwrap_or(0),
        tiers,
    })
}

fn task_class_to_json(t: &TaskClass) -> Json {
    let mut fields = vec![
        ("name", Json::str(&*t.name)),
        ("tier", Json::str(t.tier.name())),
        ("start_ms", Json::num(t.start_ms as f64)),
        ("end_ms", Json::num(t.end_ms as f64)),
        ("inter_arrival_ms", Json::num(t.inter_arrival_ms as f64)),
        ("runtime_ms", Json::num(t.runtime_ms as f64)),
        ("mem_mb", Json::num(t.mem_mb as f64)),
        ("shape", Json::str(t.shape.name())),
    ];
    if let LoadShape::Diurnal { period_ms, duty_pct } = t.shape {
        fields.push(("period_ms", Json::num(period_ms as f64)));
        fields.push(("duty_pct", Json::num(duty_pct as f64)));
    }
    fields.push(("seed", Json::num(t.seed as f64)));
    Json::obj(fields)
}

fn task_class_from_json(j: &Json, duration_ms: u64) -> Result<TaskClass> {
    let shape = match j.get("shape").and_then(Json::as_str).unwrap_or("steady") {
        "steady" => LoadShape::Steady,
        "diurnal" => LoadShape::Diurnal {
            period_ms: j.req_u64("period_ms")?,
            duty_pct: j.req_u64("duty_pct")?,
        },
        other => {
            return Err(Error::Codec(format!(
                "task class: unknown shape '{other}' (steady|diurnal)"
            )))
        }
    };
    Ok(TaskClass {
        name: j.req_str("name")?.to_string(),
        tier: SlaTier::from_name(j.req_str("tier")?)?,
        start_ms: j.get("start_ms").and_then(Json::as_u64).unwrap_or(0),
        end_ms: j.get("end_ms").and_then(Json::as_u64).unwrap_or(duration_ms),
        inter_arrival_ms: j.req_u64("inter_arrival_ms")?,
        runtime_ms: j.req_u64("runtime_ms")?,
        mem_mb: j.get("mem_mb").and_then(Json::as_u64).unwrap_or(1024),
        shape,
        seed: j.get("seed").and_then(Json::as_u64).unwrap_or(0),
    })
}

/// Canonical JSON form of a [`ScenarioSpec`] (`POST /v1/scenarios` body).
/// Field presence mirrors the TOML form: `tiers` appears only when the
/// class restricts its tiers, `period_ms`/`duty_pct` only on diurnal
/// shapes; everything else is always present.
pub fn scenario_spec_to_json(s: &ScenarioSpec) -> Json {
    Json::obj(vec![
        ("name", Json::str(&*s.name)),
        ("duration_ms", Json::num(s.duration_ms as f64)),
        ("tick_ms", Json::num(s.tick_ms as f64)),
        ("seed", Json::num(s.seed as f64)),
        ("policy", Json::str(&*s.policy)),
        ("warm_spares", Json::num(s.warm_spares as f64)),
        (
            "batch_backlog_per_node",
            Json::num(s.batch_backlog_per_node as f64),
        ),
        ("nodes_min", Json::num(s.nodes_min as f64)),
        ("nodes_max", Json::num(s.nodes_max as f64)),
        ("queue_delay_ms", Json::num(s.queue_delay_ms as f64)),
        (
            "machine_classes",
            Json::Arr(s.machine_classes.iter().map(machine_class_to_json).collect()),
        ),
        (
            "task_classes",
            Json::Arr(s.task_classes.iter().map(task_class_to_json).collect()),
        ),
    ])
}

/// Decode and validate a scenario spec. Optional fields default exactly
/// as in the TOML form, then [`ScenarioSpec::validate`] runs, so a spec
/// accepted here is a spec the runner will accept.
pub fn scenario_spec_from_json(j: &Json) -> Result<ScenarioSpec> {
    let duration_ms = j.req_u64("duration_ms")?;
    let machine_classes = j
        .get("machine_classes")
        .and_then(Json::as_arr)
        .ok_or_else(|| Error::Codec("missing array 'machine_classes'".into()))?
        .iter()
        .map(machine_class_from_json)
        .collect::<Result<Vec<_>>>()?;
    let task_classes = j
        .get("task_classes")
        .and_then(Json::as_arr)
        .ok_or_else(|| Error::Codec("missing array 'task_classes'".into()))?
        .iter()
        .map(|t| task_class_from_json(t, duration_ms))
        .collect::<Result<Vec<_>>>()?;
    let spec = ScenarioSpec {
        name: j.req_str("name")?.to_string(),
        duration_ms,
        tick_ms: j.get("tick_ms").and_then(Json::as_u64).unwrap_or(1_000),
        seed: j.get("seed").and_then(Json::as_u64).unwrap_or(0),
        policy: j
            .get("policy")
            .and_then(Json::as_str)
            .unwrap_or("grow_on_backlog")
            .to_string(),
        warm_spares: j.get("warm_spares").and_then(Json::as_u64).unwrap_or(1) as u32,
        batch_backlog_per_node: j
            .get("batch_backlog_per_node")
            .and_then(Json::as_u64)
            .unwrap_or(4) as u32,
        nodes_min: j.req_u64("nodes_min")? as u32,
        nodes_max: j.req_u64("nodes_max")? as u32,
        queue_delay_ms: j.get("queue_delay_ms").and_then(Json::as_u64).unwrap_or(500),
        machine_classes,
        task_classes,
    };
    spec.validate().map_err(|e| Error::Codec(e.to_string()))?;
    Ok(spec)
}

/// Canonical JSON form of a [`ScoreDoc`]: per-tier violation accounting
/// in [`TIERS`] order, the energy integral, and provisioning counters.
/// All integers — byte-stable across languages.
pub fn score_doc_to_json(s: &ScoreDoc) -> Json {
    let tiers = TIERS
        .iter()
        .zip(s.tiers.iter())
        .map(|(tier, t)| {
            Json::obj(vec![
                ("tier", Json::str(tier.name())),
                ("tasks", Json::num(t.tasks as f64)),
                ("violations", Json::num(t.violations as f64)),
            ])
        })
        .collect();
    let energy = Json::obj(vec![
        ("node_ms", Json::num(s.energy.node_ms as f64)),
        ("busy_core_ms", Json::num(s.energy.busy_core_ms as f64)),
        ("idle_node_ms", Json::num(s.energy.idle_node_ms as f64)),
        ("wakeups", Json::num(s.energy.wakeups as f64)),
        ("wake_ms", Json::num(s.energy.wake_ms as f64)),
        ("energy_mj", Json::num(s.energy.energy_mj as f64)),
    ]);
    Json::obj(vec![
        ("scenario", Json::str(&*s.scenario)),
        ("policy", Json::str(&*s.policy)),
        ("duration_ms", Json::num(s.duration_ms as f64)),
        ("ticks", Json::num(s.ticks as f64)),
        ("tiers", Json::Arr(tiers)),
        ("energy", energy),
        ("peak_nodes", Json::num(s.peak_nodes as f64)),
        ("grants", Json::num(s.grants as f64)),
        ("drains", Json::num(s.drains as f64)),
    ])
}

pub fn score_doc_from_json(j: &Json) -> Result<ScoreDoc> {
    let tier_arr = j
        .get("tiers")
        .and_then(Json::as_arr)
        .ok_or_else(|| Error::Codec("missing array 'tiers'".into()))?;
    if tier_arr.len() != TIERS.len() {
        return Err(Error::Codec(format!(
            "score: expected {} tier entries, got {}",
            TIERS.len(),
            tier_arr.len()
        )));
    }
    let mut tiers = [TierScore::default(); 4];
    for (slot, (tier, t)) in TIERS.iter().zip(tier_arr.iter()).enumerate() {
        if t.req_str("tier")? != tier.name() {
            return Err(Error::Codec(format!(
                "score: tier entry {slot} must be '{}'",
                tier.name()
            )));
        }
        tiers[slot] = TierScore {
            tasks: t.req_u64("tasks")?,
            violations: t.req_u64("violations")?,
        };
    }
    let e = j
        .get("energy")
        .ok_or_else(|| Error::Codec("missing object 'energy'".into()))?;
    Ok(ScoreDoc {
        scenario: j.req_str("scenario")?.to_string(),
        policy: j.req_str("policy")?.to_string(),
        duration_ms: j.req_u64("duration_ms")?,
        ticks: j.req_u64("ticks")?,
        tiers,
        energy: EnergyScore {
            node_ms: e.req_u64("node_ms")?,
            busy_core_ms: e.req_u64("busy_core_ms")?,
            idle_node_ms: e.req_u64("idle_node_ms")?,
            wakeups: e.req_u64("wakeups")?,
            wake_ms: e.req_u64("wake_ms")?,
            energy_mj: e.req_u64("energy_mj")?,
        },
        peak_nodes: j.req_u64("peak_nodes")? as u32,
        grants: j.req_u64("grants")?,
        drains: j.req_u64("drains")?,
    })
}

/// `GET /v1/scenarios/{id}` response. `score` appears once the run is
/// `DONE`; `error` once it is `FAILED`.
#[derive(Debug, Clone, PartialEq)]
pub struct ScenarioDoc {
    pub scenario: u64,
    pub name: String,
    pub policy: String,
    pub state: ScenarioState,
    pub score: Option<ScoreDoc>,
    pub error: Option<String>,
}

impl ScenarioDoc {
    pub fn is_terminal(&self) -> bool {
        self.state.is_terminal()
    }

    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("scenario", Json::num(self.scenario as f64)),
            ("name", Json::str(&*self.name)),
            ("policy", Json::str(&*self.policy)),
            ("state", Json::str(self.state.as_wire())),
        ];
        if let Some(s) = &self.score {
            fields.push(("score", score_doc_to_json(s)));
        }
        if let Some(e) = &self.error {
            fields.push(("error", Json::str(&**e)));
        }
        Json::obj(fields)
    }

    pub fn from_json(j: &Json) -> Result<ScenarioDoc> {
        Ok(ScenarioDoc {
            scenario: j.req_u64("scenario")?,
            name: j.req_str("name")?.to_string(),
            policy: j.req_str("policy")?.to_string(),
            state: ScenarioState::from_wire(j.req_str("state")?)?,
            score: j.get("score").map(score_doc_from_json).transpose()?,
            error: j.get("error").and_then(Json::as_str).map(str::to_string),
        })
    }
}

/// `GET /v1/scenarios` response. List rows omit `score` (fetch one
/// scenario for the full document), so pages stay small.
#[derive(Debug, Clone, PartialEq)]
pub struct ScenariosPage {
    pub scenarios: Vec<ScenarioDoc>,
    pub total: u64,
    pub offset: u64,
}

impl ScenariosPage {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            (
                "scenarios",
                Json::Arr(self.scenarios.iter().map(ScenarioDoc::to_json).collect()),
            ),
            ("total", Json::num(self.total as f64)),
            ("offset", Json::num(self.offset as f64)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<ScenariosPage> {
        let scenarios = j
            .get("scenarios")
            .and_then(Json::as_arr)
            .ok_or_else(|| Error::Codec("missing array 'scenarios'".into()))?
            .iter()
            .map(ScenarioDoc::from_json)
            .collect::<Result<Vec<_>>>()?;
        Ok(ScenariosPage {
            scenarios,
            total: j.req_u64("total")?,
            offset: j.req_u64("offset")?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::{props, Gen};

    fn arb_path(g: &mut Gen) -> String {
        format!("/lustre/scratch/{}", g.ident(8))
    }

    fn arb_stage(g: &mut Gen) -> StageSpec {
        let kind = g.pick(&[StageKind::Join, StageKind::Agg, StageKind::Select, StageKind::Sort]);
        let join = kind == StageKind::Join;
        let input_fields = g.vec(1..4, |g| g.ident(6));
        let right_fields = g.vec(1..3, |g| g.ident(6));
        StageSpec {
            kind,
            input_dir: arb_path(g),
            input_schema: Schema {
                fields: input_fields.clone(),
                delimiter: g.pick(&[',', ';', '\t']),
            },
            right_dir: join.then(|| arb_path(g)),
            right_schema: join.then(|| Schema {
                fields: right_fields.clone(),
                delimiter: g.pick(&[',', '\t']),
            }),
            left_key: join.then(|| input_fields[0].clone()),
            right_key: join.then(|| right_fields[0].clone()),
            combined_fields: if join {
                input_fields.iter().chain(&right_fields).cloned().collect()
            } else {
                Vec::new()
            },
            filter: g.chance(0.5).then(|| format!("{} > 1", input_fields[0])),
            left_filter: (join && g.chance(0.5)).then(|| format!("{} > 2", input_fields[0])),
            right_filter: (join && g.chance(0.5)).then(|| format!("{} > 3", right_fields[0])),
            project: if kind == StageKind::Select {
                vec![input_fields[0].clone()]
            } else {
                Vec::new()
            },
            group_by: (kind == StageKind::Agg && g.chance(0.7))
                .then(|| input_fields[0].clone()),
            aggregates: if kind == StageKind::Agg {
                g.vec(1..3, |g| AggSpec {
                    agg: g.pick(&[
                        Aggregate::Count,
                        Aggregate::Sum,
                        Aggregate::Avg,
                        Aggregate::Min,
                        Aggregate::Max,
                    ]),
                    expr: input_fields[0].clone(),
                })
            } else {
                Vec::new()
            },
            sort_by: (kind == StageKind::Sort).then(|| input_fields[0].clone()),
            desc: kind == StageKind::Sort && g.chance(0.5),
            limit: (kind == StageKind::Sort && g.chance(0.5)).then(|| g.u64(1..100)),
            output_dir: arb_path(g),
            n_reduces: if kind == StageKind::Select {
                0
            } else {
                g.u32(1..16)
            },
            intermediate: g.chance(0.4),
        }
    }

    fn arb_payload(g: &mut Gen) -> AppPayload {
        match g.u32(0..7) {
            0 => AppPayload::Terasort {
                rows: g.u64(1..1_000_000),
                maps: g.u64(1..64),
                reduces: g.u32(1..32),
                use_kernel: g.chance(0.5),
            },
            1 => AppPayload::Teragen {
                rows: g.u64(1..1_000_000),
                maps: g.u64(1..64),
                dir: arb_path(g),
            },
            2 => AppPayload::PigScript {
                script: format!("recs = LOAD '{}' AS (a);\nSTORE recs INTO '{}';", arb_path(g), arb_path(g)),
                reduces: g.u32(1..32),
            },
            3 => AppPayload::HiveQuery {
                sql: format!("SELECT COUNT(a) FROM '{}' SCHEMA (a) INTO '{}'", arb_path(g), arb_path(g)),
                reduces: g.u32(1..32),
            },
            4 => AppPayload::Query {
                engine: g.pick(&["pig", "hive"]).to_string(),
                text: format!(
                    "SELECT COUNT(a) FROM '{}' SCHEMA (a) ORDER BY a INTO '{}'",
                    arb_path(g),
                    arb_path(g)
                ),
                reduces: g.u32(1..32),
            },
            5 => AppPayload::QueryStage {
                stage: arb_stage(g),
            },
            _ => AppPayload::RSummary {
                input_dir: arb_path(g),
                output_dir: arb_path(g),
                fields: g.vec(1..4, |g| g.ident(6)),
                delimiter: g.pick(&[',', ';', '\t', '|']),
                columns: g.vec(1..3, |g| g.ident(6)),
            },
        }
    }

    fn arb_state(g: &mut Gen) -> JobState {
        g.pick(&[
            JobState::Pending,
            JobState::Running,
            JobState::Done,
            JobState::Exited,
            JobState::Killed,
        ])
    }

    fn arb_result(g: &mut Gen) -> ResultDoc {
        ResultDoc {
            kind: g.pick(&["terasort", "teragen", "pig", "hive", "rsummary"]).to_string(),
            output_dir: arb_path(g),
            output_files: g.vec(0..4, arb_path),
            records: g.u64(0..1_000_000),
            validated: g.chance(0.5),
            wall_ms: g.u64(0..100_000),
            counters: g.vec(0..4, |g| (g.ident(8), g.u64(0..1_000))),
        }
    }

    /// The acceptance property: every payload variant survives the wire.
    #[test]
    fn prop_payload_round_trip() {
        props(300, |g| {
            let p = arb_payload(g);
            let text = payload_to_json(&p).to_string();
            let back = payload_from_json(&Json::parse(&text).unwrap()).unwrap();
            assert_eq!(p, back);
        });
    }

    #[test]
    fn prop_submit_request_round_trip() {
        props(200, |g| {
            let r = SubmitRequest {
                nodes: g.u32(1..128),
                user: g.ident(8),
                payload: arb_payload(g),
            };
            let back =
                SubmitRequest::from_json(&Json::parse(&r.to_json().to_string()).unwrap()).unwrap();
            assert_eq!(r, back);
        });
    }

    #[test]
    fn prop_job_doc_round_trip() {
        props(200, |g| {
            let d = JobDoc {
                job: g.u64(1..10_000),
                kind: g.pick(&["terasort", "pig", "hive"]).to_string(),
                state: arb_state(g),
                result: if g.chance(0.5) { Some(arb_result(g)) } else { None },
                error: if g.chance(0.3) { Some(g.ident(12)) } else { None },
            };
            let back = JobDoc::from_json(&Json::parse(&d.to_json().to_string()).unwrap()).unwrap();
            assert_eq!(d, back);
        });
    }

    #[test]
    fn prop_jobs_page_round_trip() {
        props(100, |g| {
            let page = JobsPage {
                jobs: g.vec(0..5, |g| JobDoc {
                    job: g.u64(1..10_000),
                    kind: "teragen".to_string(),
                    state: arb_state(g),
                    result: None,
                    error: None,
                }),
                total: g.u64(0..10_000),
                offset: g.u64(0..10_000),
            };
            let back =
                JobsPage::from_json(&Json::parse(&page.to_json().to_string()).unwrap()).unwrap();
            assert_eq!(page, back);
        });
    }

    #[test]
    fn prop_workflow_spec_round_trip() {
        props(150, |g| {
            let n = g.usize(1..5);
            let steps: Vec<StepSpec> = (0..n)
                .map(|i| {
                    let after = (0..i).filter(|_| g.chance(0.5)).map(|d| format!("s{d}")).collect();
                    StepSpec {
                        name: format!("s{i}"),
                        after,
                        retries: g.u32(0..3),
                        payload: arb_payload(g),
                    }
                })
                .collect();
            let spec = WorkflowSpec {
                name: g.ident(8),
                user: g.ident(6),
                nodes: g.u32(1..32),
                steps,
            };
            spec.validate().unwrap();
            let back =
                WorkflowSpec::from_json(&Json::parse(&spec.to_json().to_string()).unwrap()).unwrap();
            assert_eq!(spec, back);
        });
    }

    #[test]
    fn prop_workflow_doc_round_trip() {
        props(150, |g| {
            let doc = WorkflowDoc {
                workflow: g.u64(0..1_000),
                name: g.ident(8),
                complete: g.chance(0.5),
                aborted: g.chance(0.3),
                steps: g.vec(1..4, |g| StepDoc {
                    name: g.ident(6),
                    kind: "pig".to_string(),
                    state: g.pick(&[
                        StepState::Waiting,
                        StepState::Running,
                        StepState::Done,
                        StepState::Failed,
                        StepState::Skipped,
                    ]),
                    attempts: g.u32(0..4),
                    job: if g.chance(0.5) { Some(g.u64(1..1_000)) } else { None },
                    output_dir: if g.chance(0.5) { Some(arb_path(g)) } else { None },
                }),
            };
            let back =
                WorkflowDoc::from_json(&Json::parse(&doc.to_json().to_string()).unwrap()).unwrap();
            assert_eq!(doc, back);
        });
    }

    #[test]
    fn prop_event_docs_round_trip() {
        props(150, |g| {
            let page = EventPage {
                events: g.vec(0..6, |g| EventDoc {
                    seq: g.u64(1..100_000),
                    kind: g.pick(&["job", "workflow", "step"]).to_string(),
                    id: g.u64(0..10_000),
                    state: g.pick(&["PEND", "RUN", "DONE", "EXIT", "COMPLETE"]).to_string(),
                    step: if g.chance(0.4) { Some(g.ident(6)) } else { None },
                }),
                next: g.u64(0..100_000),
            };
            let back =
                EventPage::from_json(&Json::parse(&page.to_json().to_string()).unwrap()).unwrap();
            assert_eq!(page, back);
        });
    }

    #[test]
    fn prop_cluster_doc_round_trip() {
        props(150, |g| {
            let doc = ClusterDoc {
                nodes: g.vec(0..6, |g| NodeDoc {
                    node: g.u64(0..256),
                    hostname: format!("sbd{:04}", g.u64(0..256)),
                    state: g.pick(&["UP", "DRAINED", "DOWN"]).to_string(),
                    cores: g.u64(1..64),
                    mem_mb: g.u64(1024..65_536),
                    mips: g.u64(1..4_000),
                    job: if g.chance(0.5) { Some(g.u64(1..1_000)) } else { None },
                    lease_remaining_ms: if g.chance(0.4) {
                        Some(g.u64(0..10_000_000))
                    } else {
                        None
                    },
                }),
                up: g.u64(0..256),
                drained: g.u64(0..16),
                down: g.u64(0..16),
                leased: g.u64(0..256),
                tier: if g.chance(0.5) {
                    Some(TierDoc {
                        mem_budget_bytes: g.u64(0..1 << 30),
                        resident_bytes: g.u64(0..1 << 30),
                        backing_bytes: g.u64(0..1 << 30),
                        hits: g.u64(0..100_000),
                        misses: g.u64(0..100_000),
                        evictions: g.u64(0..100_000),
                        promotions: g.u64(0..100_000),
                        writeback_bytes: g.u64(0..1 << 40),
                        spill_bytes: g.u64(0..1 << 40),
                        // Dyadic fraction: exact across the JSON text form.
                        simulated_io_s: g.u64(0..1 << 20) as f64 / 8.0,
                    })
                } else {
                    None
                },
            };
            let back =
                ClusterDoc::from_json(&Json::parse(&doc.to_json().to_string()).unwrap()).unwrap();
            assert_eq!(doc, back);
        });
    }

    #[test]
    fn error_doc_round_trip_and_statuses() {
        let e = ErrorDoc::new(code::BAD_PATH, "escapes root");
        let back = ErrorDoc::from_json(&Json::parse(&e.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(e, back);
        assert_eq!(e.http_status(), 400);
        assert_eq!(ErrorDoc::not_found("x").http_status(), 404);
        assert_eq!(ErrorDoc::new(code::NOT_READY, "x").http_status(), 409);
        assert_eq!(ErrorDoc::new(code::INTERNAL, "x").http_status(), 500);
        assert_eq!(ErrorDoc::new(code::DEPRECATED, "x").http_status(), 301);
        assert_eq!(ErrorDoc::new(code::UNAUTHORIZED, "x").http_status(), 401);
        assert_eq!(ErrorDoc::new(code::RATE_LIMITED, "x").http_status(), 429);
        assert_eq!(ErrorDoc::new(code::QUOTA_EXCEEDED, "x").http_status(), 429);
    }

    #[test]
    fn tenant_doc_round_trip() {
        props(60, |g| {
            let doc = TenantDoc {
                name: g.ident(8),
                queue: format!("root.{}", g.ident(6)),
                running_apps: g.u64(0..1_000),
                containers: g.u64(0..10_000),
                dfs_bytes: g.u64(0..1 << 40),
                submitted: g.u64(0..1_000_000),
                rate_limited: g.u64(0..1_000),
                quota_rejected: g.u64(0..1_000),
                breaker_rejected: g.u64(0..1_000),
                breaker: g.pick(&["closed", "open", "half_open"]).to_string(),
            };
            let back =
                TenantDoc::from_json(&Json::parse(&doc.to_json().to_string()).unwrap()).unwrap();
            assert_eq!(doc, back);
        });
    }

    #[test]
    fn queue_doc_round_trip() {
        props(60, |g| {
            let doc = QueueDoc {
                name: format!("root.{}.{}", g.ident(5), g.ident(5)),
                weight: g.u64(1..100),
                min_pct: g.u64(0..50),
                max_pct: g.u64(50..101),
                running: g.u64(0..1_000),
                served: g.u64(0..1_000_000),
                share_pct: g.u64(0..101),
                preemptions: g.u64(0..10_000),
                wait_us: g.u64(0..1 << 40),
            };
            let back =
                QueueDoc::from_json(&Json::parse(&doc.to_json().to_string()).unwrap()).unwrap();
            assert_eq!(doc, back);
        });
    }

    #[test]
    fn job_states_cross_the_wire_exactly() {
        for s in [
            JobState::Pending,
            JobState::Running,
            JobState::Done,
            JobState::Exited,
            JobState::Killed,
        ] {
            assert_eq!(job_state_from_wire(job_state_to_wire(s)).unwrap(), s);
        }
        // KILLED is a real token, not the EXIT(kill) display hack.
        assert_eq!(job_state_to_wire(JobState::Killed), "KILLED");
        assert!(job_state_from_wire("EXIT(kill)").is_err());
        assert!(job_state_from_wire("DONEish").is_err());
    }

    #[test]
    fn unknown_payload_type_rejected() {
        let j = Json::parse(r#"{"type":"nonsense"}"#).unwrap();
        assert!(payload_from_json(&j).unwrap_err().to_string().contains("unknown payload type"));
    }

    #[test]
    fn step_ref_scan_and_substitution() {
        let refs = step_refs("LOAD '${steps.gen.output_dir}' INTO '${steps.stage.output_dir}'")
            .unwrap();
        assert_eq!(refs, vec!["gen", "stage"]);
        assert!(step_refs("${steps.x.wall_ms}").is_err());
        assert!(step_refs("${steps.x.output_dir").is_err());

        let out = substitute_step_refs("FROM '${steps.gen.output_dir}/part-0'", &|n| {
            (n == "gen").then(|| "/lustre/out".to_string())
        })
        .unwrap();
        assert_eq!(out, "FROM '/lustre/out/part-0'");
        assert!(substitute_step_refs("${steps.missing.output_dir}", &|_| None).is_err());
        // No references: unchanged.
        assert_eq!(substitute_step_refs("plain", &|_| None).unwrap(), "plain");
    }

    #[test]
    fn workflow_validation_rejects_bad_dags() {
        let step = |name: &str, after: &[&str]| StepSpec {
            name: name.into(),
            after: after.iter().map(|s| s.to_string()).collect(),
            retries: 0,
            payload: AppPayload::Teragen { rows: 1, maps: 1, dir: "/d".into() },
        };
        let wf = |steps: Vec<StepSpec>| WorkflowSpec {
            name: "wf".into(),
            user: "u".into(),
            nodes: 2,
            steps,
        };
        assert!(wf(vec![]).validate().is_err());
        assert!(wf(vec![step("a", &[]), step("a", &[])]).validate().is_err());
        assert!(wf(vec![step("a", &["ghost"])]).validate().is_err());
        assert!(wf(vec![step("a", &["a"])]).validate().is_err());
        assert!(wf(vec![step("bad name", &[])]).validate().is_err());
        // Cycle a→b→a.
        assert!(wf(vec![step("a", &["b"]), step("b", &["a"])]).validate().is_err());
        // Reference to a step not in after[].
        let mut s = step("b", &[]);
        s.payload = AppPayload::HiveQuery {
            sql: "SELECT COUNT(a) FROM '${steps.a.output_dir}' SCHEMA (a) INTO '/o'".into(),
            reduces: 1,
        };
        assert!(wf(vec![step("a", &[]), s.clone()]).validate().is_err());
        s.after = vec!["a".into()];
        wf(vec![step("a", &[]), s]).validate().unwrap();
        // Diamond is fine.
        wf(vec![
            step("a", &[]),
            step("b", &["a"]),
            step("c", &["a"]),
            step("d", &["b", "c"]),
        ])
        .validate()
        .unwrap();
    }

    #[test]
    fn output_path_containment() {
        let root = "/lustre/data/lsf-7/tera-out";
        // Absolute path inside the root.
        assert_eq!(
            resolve_output_path(root, "/lustre/data/lsf-7/tera-out/part-0").unwrap(),
            "/lustre/data/lsf-7/tera-out/part-0"
        );
        // Relative path joins the root.
        assert_eq!(
            resolve_output_path(root, "part-1").unwrap(),
            "/lustre/data/lsf-7/tera-out/part-1"
        );
        // Dot segments collapse but stay inside.
        assert_eq!(
            resolve_output_path(root, "./sub/../part-2").unwrap(),
            "/lustre/data/lsf-7/tera-out/part-2"
        );
        // `..` escapes are rejected.
        assert!(resolve_output_path(root, "..").is_err());
        assert!(resolve_output_path(root, "../other-job/part-0").is_err());
        assert!(resolve_output_path(root, "a/../../../../etc/passwd").is_err());
        // Absolute escapes are rejected.
        assert!(resolve_output_path(root, "/etc/passwd").is_err());
        assert!(resolve_output_path(root, "/lustre/data/lsf-7/tera-outish/x").is_err());
        assert!(resolve_output_path(root, "/lustre/data/lsf-7/tera-out/../x").is_err());
    }

    fn arb_machine_class(g: &mut Gen, i: usize, serve_all: bool) -> MachineClass {
        MachineClass {
            name: format!("mc{i}"),
            count: g.u32(1..8),
            cores: g.u32(1..8),
            mem_mb: g.u64(1024..32_768),
            mips: g.u64(400..2_400),
            active_w: g.u64(100..400),
            idle_w: g.u64(20..100),
            sleep_w: g.u64(1..20),
            wake_ms: g.u64(0..10_000),
            tiers: if serve_all || g.chance(0.5) {
                Vec::new()
            } else {
                vec![SlaTier::Batch]
            },
        }
    }

    fn arb_task_class(g: &mut Gen, i: usize, duration_ms: u64) -> TaskClass {
        let start_ms = g.u64(0..duration_ms / 2 + 1);
        TaskClass {
            name: format!("tc{i}"),
            tier: g.pick(&[SlaTier::Sla0, SlaTier::Sla1, SlaTier::Sla2, SlaTier::Batch]),
            start_ms,
            end_ms: start_ms + g.u64(1..duration_ms + 1),
            inter_arrival_ms: g.u64(1..5_000),
            runtime_ms: g.u64(1..20_000),
            mem_mb: g.u64(128..8_192),
            shape: if g.chance(0.4) {
                LoadShape::Diurnal {
                    period_ms: g.u64(1..duration_ms + 1),
                    duty_pct: g.u64(1..101),
                }
            } else {
                LoadShape::Steady
            },
            seed: g.u64(0..1_000),
        }
    }

    fn arb_scenario_spec(g: &mut Gen) -> ScenarioSpec {
        let duration_ms = g.u64(1_000..200_000);
        // First class serves every tier so any generated task class
        // passes the "some class serves this tier" validation.
        let machine_classes: Vec<MachineClass> = (0..g.usize(1..4))
            .map(|i| arb_machine_class(g, i, i == 0))
            .collect();
        let total: u32 = machine_classes.iter().map(|c| c.count).sum();
        let nodes_min = g.u32(1..total + 1);
        let spec = ScenarioSpec {
            name: g.ident(8),
            duration_ms,
            tick_ms: g.u64(duration_ms / 50_000 + 1..5_000),
            seed: g.u64(0..1_000),
            policy: g.pick(&["grow_on_backlog", "sla_energy"]).to_string(),
            warm_spares: g.u32(0..8),
            batch_backlog_per_node: g.u32(1..16),
            nodes_min,
            nodes_max: g.u32(nodes_min..total + 8),
            queue_delay_ms: g.u64(0..10_000),
            machine_classes,
            task_classes: (0..g.usize(1..4))
                .map(|i| arb_task_class(g, i, duration_ms))
                .collect(),
        };
        spec.validate().unwrap();
        spec
    }

    fn arb_score_doc(g: &mut Gen) -> ScoreDoc {
        let mut tiers = [TierScore::default(); 4];
        for t in tiers.iter_mut() {
            t.tasks = g.u64(0..100_000);
            t.violations = g.u64(0..t.tasks + 1);
        }
        ScoreDoc {
            scenario: g.ident(8),
            policy: g.pick(&["grow_on_backlog", "sla_energy"]).to_string(),
            duration_ms: g.u64(1..1 << 30),
            ticks: g.u64(1..100_000),
            tiers,
            energy: EnergyScore {
                node_ms: g.u64(0..1 << 40),
                busy_core_ms: g.u64(0..1 << 40),
                idle_node_ms: g.u64(0..1 << 40),
                wakeups: g.u64(0..10_000),
                wake_ms: g.u64(0..1 << 30),
                energy_mj: g.u64(0..1 << 45),
            },
            peak_nodes: g.u32(0..10_000),
            grants: g.u64(0..100_000),
            drains: g.u64(0..100_000),
        }
    }

    /// The scenario acceptance property: any valid spec survives the
    /// wire byte-for-byte, including tier restrictions and load shapes.
    #[test]
    fn prop_scenario_spec_round_trip() {
        props(200, |g| {
            let spec = arb_scenario_spec(g);
            let back =
                scenario_spec_from_json(&Json::parse(&scenario_spec_to_json(&spec).to_string()).unwrap())
                    .unwrap();
            assert_eq!(spec, back);
        });
    }

    #[test]
    fn prop_score_doc_round_trip() {
        props(200, |g| {
            let score = arb_score_doc(g);
            let back =
                score_doc_from_json(&Json::parse(&score_doc_to_json(&score).to_string()).unwrap())
                    .unwrap();
            assert_eq!(score, back);
        });
    }

    #[test]
    fn prop_scenario_doc_round_trip() {
        props(150, |g| {
            let state = g.pick(&[
                ScenarioState::Pending,
                ScenarioState::Running,
                ScenarioState::Done,
                ScenarioState::Failed,
            ]);
            let doc = ScenarioDoc {
                scenario: g.u64(1..10_000),
                name: g.ident(8),
                policy: g.pick(&["grow_on_backlog", "sla_energy"]).to_string(),
                state,
                score: (state == ScenarioState::Done).then(|| arb_score_doc(g)),
                error: (state == ScenarioState::Failed).then(|| g.ident(12)),
            };
            let back =
                ScenarioDoc::from_json(&Json::parse(&doc.to_json().to_string()).unwrap()).unwrap();
            assert_eq!(doc, back);
        });
    }

    #[test]
    fn prop_scenarios_page_round_trip() {
        props(100, |g| {
            let page = ScenariosPage {
                scenarios: g.vec(0..5, |g| ScenarioDoc {
                    scenario: g.u64(1..10_000),
                    name: g.ident(6),
                    policy: "sla_energy".to_string(),
                    state: g.pick(&[
                        ScenarioState::Pending,
                        ScenarioState::Running,
                        ScenarioState::Done,
                        ScenarioState::Failed,
                    ]),
                    score: None,
                    error: None,
                }),
                total: g.u64(0..10_000),
                offset: g.u64(0..10_000),
            };
            let back =
                ScenariosPage::from_json(&Json::parse(&page.to_json().to_string()).unwrap())
                    .unwrap();
            assert_eq!(page, back);
        });
    }

    #[test]
    fn scenario_states_cross_the_wire_exactly() {
        for s in [
            ScenarioState::Pending,
            ScenarioState::Running,
            ScenarioState::Done,
            ScenarioState::Failed,
        ] {
            assert_eq!(ScenarioState::from_wire(s.as_wire()).unwrap(), s);
        }
        assert!(ScenarioState::from_wire("DONEish").is_err());
        assert!(!ScenarioState::Running.is_terminal());
        assert!(ScenarioState::Failed.is_terminal());
    }

    /// The TOML and JSON forms describe the same spec: parsing the
    /// shipped example TOML and round-tripping it through the wire form
    /// yields an identical `ScenarioSpec`.
    #[test]
    fn scenario_toml_and_json_forms_agree() {
        for text in [
            include_str!(concat!(
                env!("CARGO_MANIFEST_DIR"),
                "/examples/scenarios/spike.toml"
            )),
            include_str!(concat!(
                env!("CARGO_MANIFEST_DIR"),
                "/examples/scenarios/updown.toml"
            )),
        ] {
            let spec = ScenarioSpec::from_toml(text).unwrap();
            let back = scenario_spec_from_json(
                &Json::parse(&scenario_spec_to_json(&spec).to_string()).unwrap(),
            )
            .unwrap();
            assert_eq!(spec, back);
        }
    }

    #[test]
    fn scenario_spec_from_json_rejects_invalid_specs() {
        props(20, |g| {
            let spec = arb_scenario_spec(g);
            let mut j = scenario_spec_to_json(&spec);
            // Valid as emitted.
            scenario_spec_from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
            // Unknown policy is rejected by the embedded validate().
            if let Json::Obj(fields) = &mut j {
                for (k, v) in fields.iter_mut() {
                    if k == "policy" {
                        *v = Json::str("psychic");
                    }
                }
            }
            assert!(scenario_spec_from_json(&Json::parse(&j.to_string()).unwrap()).is_err());
        });
    }

    /// The Python conformance suite replays the same vectors
    /// (`python/tests/vectors.json`): every `doc` must re-serialize to the
    /// byte-identical `canon` string in both languages.
    #[test]
    fn conformance_vectors_are_canonical() {
        let text = include_str!(concat!(
            env!("CARGO_MANIFEST_DIR"),
            "/python/tests/vectors.json"
        ));
        let vectors = Json::parse(text).unwrap();
        let cases = vectors.get("payloads").unwrap().as_arr().unwrap();
        assert!(cases.len() >= 5, "one vector per payload variant");
        for case in cases {
            let doc = case.get("doc").unwrap();
            let canon = case.get("canon").unwrap().as_str().unwrap();
            let typed = payload_from_json(doc).unwrap();
            assert_eq!(payload_to_json(&typed).to_string(), canon);
        }
        let wf = vectors.get("workflow").unwrap();
        let typed = WorkflowSpec::from_json(wf.get("doc").unwrap()).unwrap();
        assert_eq!(typed.to_json().to_string(), wf.get("canon").unwrap().as_str().unwrap());
        let err = vectors.get("error").unwrap();
        let typed = ErrorDoc::from_json(err.get("doc").unwrap()).unwrap();
        assert_eq!(typed.to_json().to_string(), err.get("canon").unwrap().as_str().unwrap());
        let tenant = vectors.get("tenant").unwrap();
        let typed = TenantDoc::from_json(tenant.get("doc").unwrap()).unwrap();
        assert_eq!(
            typed.to_json().to_string(),
            tenant.get("canon").unwrap().as_str().unwrap()
        );
        let queue = vectors.get("queue").unwrap();
        let typed = QueueDoc::from_json(queue.get("doc").unwrap()).unwrap();
        assert_eq!(
            typed.to_json().to_string(),
            queue.get("canon").unwrap().as_str().unwrap()
        );
        let errs = vectors.get("admission_errors").unwrap().as_arr().unwrap();
        assert!(errs.len() >= 2, "rate_limited + quota_exceeded vectors");
        for case in errs {
            let doc = case.get("doc").unwrap();
            let canon = case.get("canon").unwrap().as_str().unwrap();
            let typed = ErrorDoc::from_json(doc).unwrap();
            assert_eq!(typed.to_json().to_string(), canon);
            assert_eq!(typed.http_status(), 429);
        }
        let spec = vectors.get("scenario_spec").unwrap();
        let typed = scenario_spec_from_json(spec.get("doc").unwrap()).unwrap();
        assert_eq!(
            scenario_spec_to_json(&typed).to_string(),
            spec.get("canon").unwrap().as_str().unwrap()
        );
        let score = vectors.get("score").unwrap();
        let typed = score_doc_from_json(score.get("doc").unwrap()).unwrap();
        assert_eq!(
            score_doc_to_json(&typed).to_string(),
            score.get("canon").unwrap().as_str().unwrap()
        );
        let scen = vectors.get("scenario").unwrap();
        let typed = ScenarioDoc::from_json(scen.get("doc").unwrap()).unwrap();
        assert_eq!(
            typed.to_json().to_string(),
            scen.get("canon").unwrap().as_str().unwrap()
        );
        let cluster = vectors.get("cluster").unwrap();
        let typed = ClusterDoc::from_json(cluster.get("doc").unwrap()).unwrap();
        assert_eq!(
            typed.to_json().to_string(),
            cluster.get("canon").unwrap().as_str().unwrap()
        );
        // The vector's second node omits `mips`: pre-heterogeneity
        // payloads decode to the reference speed in both languages.
        assert_eq!(typed.nodes[1].mips, REFERENCE_MIPS);
        assert_eq!(typed.nodes[0].mips, 250);
    }
}
