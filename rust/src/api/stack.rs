//! The in-process orchestrator: one object owning the whole HPC Wales
//! stack, driving the paper's execution flow (§III):
//!
//! submit (step 3/2) → LSF dispatch (step 4a) → wrapper builds the YARN
//! cluster (step 4b) → application runs on it (step 4c) → teardown →
//! outputs + logs accessible (steps 5/6).
//!
//! `tick()` runs one LSF dispatch cycle and executes every dispatched job
//! to completion — Real mode is synchronous by design (the data fits in
//! memory; determinism makes the tests honest). The HTTP API wraps this in
//! a background pump thread.

use crate::api::wire::{ClusterDoc, NodeDoc, TierDoc};
use crate::cluster::{ClusterModel, NodeId, NodeState};
use crate::config::StackConfig;
use crate::error::{Error, Result};
use crate::frameworks::{hive, pig, rhadoop, LogicalPlan};
use crate::frameworks::expr::Schema;
use crate::lustre::{Dfs, LustreFs};
use crate::mapreduce::MrEngine;
use crate::metrics::Metrics;
use crate::scheduler::{JobCommand, JobState, Lsf, ResourceRequest};
use crate::terasort::{
    self, summarize_dir, teravalidate, TeragenSpec, TerasortJob,
};
use crate::util::ids::{IdGen, LsfJobId};
use crate::util::pool::Pool;
use crate::util::time::Micros;
use crate::wrapper::DynamicCluster;
use std::collections::BTreeMap;
use std::sync::Arc;

/// What a submitted job runs inside its dynamic cluster.
#[derive(Debug, Clone, PartialEq)]
pub enum AppPayload {
    /// Full Terasort pipeline: teragen `rows`, sort into `reduces`
    /// partitions, teravalidate. `use_kernel` switches the map path to the
    /// AOT Pallas kernel via PJRT.
    Terasort {
        rows: u64,
        maps: u64,
        reduces: u32,
        use_kernel: bool,
    },
    /// Teragen only.
    Teragen { rows: u64, maps: u64, dir: String },
    /// A Pig-like script (paths inside the script).
    PigScript { script: String, reduces: u32 },
    /// A Hive-like query.
    HiveQuery { sql: String, reduces: u32 },
    /// A multi-stage query (`engine` = `"pig"` or `"hive"`): the plan's
    /// stage chain (join → aggregate → sort) runs back to back on ONE
    /// dynamic cluster — the pilot-job shape (chained MR jobs on the same
    /// pilot-managed resources).
    Query {
        engine: String,
        text: String,
        reduces: u32,
    },
    /// One compiled stage of a query plan — the unit a compiled-query
    /// workflow submits per step (`synfiniway::query_workflow`).
    QueryStage {
        stage: crate::frameworks::plan::StageSpec,
    },
    /// RHadoop summary statistics over a delimited dataset.
    RSummary {
        input_dir: String,
        output_dir: String,
        fields: Vec<String>,
        delimiter: char,
        columns: Vec<String>,
    },
}

impl AppPayload {
    pub fn kind(&self) -> &'static str {
        match self {
            AppPayload::Terasort { .. } => "terasort",
            AppPayload::Teragen { .. } => "teragen",
            AppPayload::PigScript { .. } => "pig",
            AppPayload::HiveQuery { .. } => "hive",
            AppPayload::Query { .. } => "query",
            AppPayload::QueryStage { .. } => "query_stage",
            AppPayload::RSummary { .. } => "rsummary",
        }
    }
}

/// Result of a completed application.
#[derive(Debug, Clone)]
pub struct AppResult {
    pub kind: &'static str,
    pub output_dir: String,
    pub output_files: Vec<String>,
    pub records: u64,
    pub validated: bool,
    pub counters: Vec<(String, u64)>,
    pub wall: std::time::Duration,
}

struct Entry {
    payload: AppPayload,
    user: String,
    result: Option<Result<AppResult>>,
}

/// The orchestrator.
pub struct Stack {
    pub cfg: StackConfig,
    pub cluster: ClusterModel,
    pub lsf: Lsf,
    pub dfs: Arc<LustreFs>,
    pub ids: Arc<IdGen>,
    pub metrics: Arc<Metrics>,
    /// Multi-tenant front door: identity, fair share, quotas, breaker.
    /// Inert (admits everything) unless `cfg.tenant` configures keys.
    pub tenants: Arc<crate::tenant::TenantRegistry>,
    pool: Pool,
    entries: BTreeMap<LsfJobId, Entry>,
    now: Micros,
}

impl Stack {
    pub fn new(cfg: StackConfig) -> Result<Stack> {
        cfg.validate()?;
        let mut cluster = ClusterModel::new(&cfg.cluster);
        // Heterogeneous pools: apply per-node MIPS overrides so
        // `GET /v1/cluster` reports the speed tier the scheduler sees.
        cluster.set_node_mips(&cfg.elastic.node_mips);
        let ids = Arc::new(IdGen::default());
        let metrics = Arc::new(Metrics::new());
        let tenants = Arc::new(crate::tenant::TenantRegistry::new(
            &cfg.tenant,
            Arc::clone(&metrics),
        ));
        let mut lsf = Lsf::new(
            cfg.scheduler.clone(),
            &cluster,
            Arc::clone(&ids),
            Arc::clone(&metrics),
        );
        lsf.set_tenants(Arc::clone(&tenants));
        let dfs = Arc::new(LustreFs::new(&cfg.lustre, &cfg.cluster));
        let workers = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4)
            .min(8);
        Ok(Stack {
            cfg,
            cluster,
            lsf,
            dfs,
            ids,
            metrics,
            tenants,
            pool: Pool::new(workers),
            entries: BTreeMap::new(),
            now: Micros::ZERO,
        })
    }

    /// Submit an application to the bigdata queue (`bsub` analog).
    ///
    /// Under tenancy every accepted submission — direct or a workflow
    /// step — books the submitting tenant's `running_apps` here, so the
    /// accounting stays symmetric with the `on_terminal` release in
    /// `tick` no matter which path submitted.
    pub fn submit(&mut self, nodes: u32, user: &str, payload: AppPayload) -> Result<LsfJobId> {
        let id = self.lsf.submit(
            ResourceRequest::bigdata(nodes, user),
            JobCommand::wrapper(payload.kind()),
            self.now,
        )?;
        self.entries.insert(
            id,
            Entry {
                payload,
                user: user.to_string(),
                result: None,
            },
        );
        if self.tenants.enabled() {
            self.tenants.on_submitted(user, self.now);
        }
        Ok(id)
    }

    /// One scheduler cycle: dispatch pending jobs and run each dispatched
    /// job to completion. Returns the ids that finished this tick.
    pub fn tick(&mut self) -> Vec<LsfJobId> {
        self.now += Micros::ms(self.cfg.scheduler.cycle_ms);
        let dispatches = self.lsf.dispatch_cycle(self.now);
        let mut finished = Vec::new();
        for d in dispatches {
            let outcome = self.run_dispatched(d.job, &d.nodes);
            let ok = outcome.is_ok();
            if let Some(e) = self.entries.get_mut(&d.job) {
                e.result = Some(outcome);
            }
            if ok {
                let _ = self.lsf.finish(d.job, self.now);
            } else {
                let _ = self.lsf.fail(d.job, self.now);
            }
            if self.tenants.enabled() {
                if let Some(user) = self.job_user(d.job).map(str::to_string) {
                    let bytes = if ok { self.output_bytes(d.job) } else { 0 };
                    self.tenants
                        .on_terminal(&user, ok, d.nodes.len() as u32, bytes, self.now);
                    // Stamp the tenant's queue accounting into the job's
                    // counters, next to the engine's own — the per-job view
                    // of the fair-share ledger.
                    if ok {
                        let snap = self.tenants.queue_of(&user).and_then(|q| {
                            self.tenants
                                .queue_snapshots()
                                .into_iter()
                                .find(|s| s.name == q)
                        });
                        if let Some(snap) = snap {
                            if let Some(Ok(r)) =
                                self.entries.get_mut(&d.job).and_then(|e| e.result.as_mut())
                            {
                                use crate::mapreduce::counters as mrc;
                                r.counters.push((mrc::QUEUE_SHARE.to_string(), snap.share_pct));
                                r.counters
                                    .push((mrc::PREEMPTIONS.to_string(), snap.preemptions));
                                r.counters
                                    .push((mrc::QUEUE_WAIT_US.to_string(), snap.wait_us));
                            }
                        }
                    }
                }
            }
            finished.push(d.job);
        }
        finished
    }

    /// Run ticks until `id` reaches a terminal state (or `max_ticks`).
    pub fn run_to_completion(&mut self, id: LsfJobId, max_ticks: u32) -> Result<&AppResult> {
        for _ in 0..max_ticks {
            if self
                .lsf
                .status(id)
                .map(|j| j.state.is_terminal())
                .unwrap_or(false)
            {
                break;
            }
            self.tick();
        }
        match self.entries.get(&id).and_then(|e| e.result.as_ref()) {
            Some(Ok(r)) => Ok(r),
            Some(Err(e)) => Err(Error::Api(format!("job {id} failed: {e}"))),
            None => Err(Error::Api(format!("job {id} did not complete"))),
        }
    }

    /// Status for the API: LSF state + result summary if done.
    pub fn job_state(&self, id: LsfJobId) -> Option<(JobState, Option<&AppResult>)> {
        let job = self.lsf.status(id)?;
        let result = self
            .entries
            .get(&id)
            .and_then(|e| e.result.as_ref())
            .and_then(|r| r.as_ref().ok());
        Some((job.state, result))
    }

    pub fn job_error(&self, id: LsfJobId) -> Option<String> {
        match self.entries.get(&id).and_then(|e| e.result.as_ref()) {
            Some(Err(e)) => Some(e.to_string()),
            _ => None,
        }
    }

    /// Payload kind of a submitted job (`None` for plain LSF jobs).
    pub fn job_kind(&self, id: LsfJobId) -> Option<&'static str> {
        self.entries.get(&id).map(|e| e.payload.kind())
    }

    /// Submitting user (= tenant name under tenancy) of a job.
    pub fn job_user(&self, id: LsfJobId) -> Option<&str> {
        self.entries.get(&id).map(|e| e.user.as_str())
    }

    /// The stack's logical clock (advances one `cycle_ms` per tick).
    pub fn now(&self) -> Micros {
        self.now
    }

    /// Bytes a finished job left under its output dir (0 when it produced
    /// nothing) — the figure charged against the tenant's DFS quota.
    pub fn output_bytes(&self, id: LsfJobId) -> u64 {
        self.entries
            .get(&id)
            .and_then(|e| e.result.as_ref())
            .and_then(|r| r.as_ref().ok())
            .map(|r| crate::lustre::dir_bytes(&*self.dfs, &r.output_dir))
            .unwrap_or(0)
    }

    /// Any job not yet in a terminal state? The API pump keeps ticking
    /// while this holds and sleeps on its condvar otherwise.
    pub fn has_active_jobs(&self) -> bool {
        self.lsf.jobs().any(|j| !j.state.is_terminal())
    }

    /// `bkill` passthrough. A killed job releases its tenant's
    /// running-app slot; a kill is not a failure, so the breaker's
    /// consecutive-failure streak is not fed (in the synchronous stack
    /// only pending jobs are ever observable here, so no containers are
    /// held at this point).
    pub fn kill(&mut self, id: LsfJobId) -> Result<()> {
        self.lsf.kill(id, self.now)?;
        if self.tenants.enabled() {
            if let Some(user) = self.job_user(id).map(str::to_string) {
                self.tenants.on_terminal(&user, true, 0, 0, self.now);
            }
        }
        Ok(())
    }

    /// Read a result file (API step 6: data access without SSH).
    pub fn read_output(&self, path: &str) -> Result<Vec<u8>> {
        self.dfs.read(path)
    }

    /// Machine-model + lease view for `GET /v1/cluster`: per-node state,
    /// the LSF job currently leasing each node, and remaining walltime.
    pub fn cluster_doc(&self) -> ClusterDoc {
        // One pass over the job table up front: node → leasing job.
        let mut holders: BTreeMap<NodeId, &crate::scheduler::LsfJob> = BTreeMap::new();
        for j in self.lsf.jobs().filter(|j| j.state == JobState::Running) {
            for &n in &j.nodes {
                holders.insert(n, j);
            }
        }
        let mut nodes = Vec::with_capacity(self.cluster.len());
        let mut up = 0u64;
        let mut drained = 0u64;
        let mut down = 0u64;
        let mut leased = 0u64;
        for n in self.cluster.nodes() {
            let state = match n.state {
                NodeState::Up => {
                    up += 1;
                    "UP"
                }
                NodeState::Drained => {
                    drained += 1;
                    "DRAINED"
                }
                NodeState::Down => {
                    down += 1;
                    "DOWN"
                }
            };
            let holder = holders.get(&n.id).copied();
            let lease_remaining_ms = holder.and_then(|j| {
                let limit = j.req.wall_limit?;
                let started = j.started_at?;
                Some((started + limit).saturating_sub(self.now).0 / 1_000)
            });
            if holder.is_some() {
                leased += 1;
            }
            nodes.push(NodeDoc {
                node: n.id.0 as u64,
                hostname: n.hostname(),
                state: state.to_string(),
                cores: n.cores as u64,
                mem_mb: n.mem_mb,
                mips: n.mips,
                job: holder.map(|j| j.id.0),
                lease_remaining_ms,
            });
        }
        ClusterDoc {
            nodes,
            up,
            drained,
            down,
            leased,
            tier: self.tier_doc(),
        }
    }

    /// Storage-tier snapshot for the wire. Always present so the
    /// `GET /v1/cluster` schema is stable across configurations: a stack
    /// whose DFS does not tier its storage (no `HPCW_MEM_BUDGET` /
    /// `lustre.mem_budget_bytes`) reports an all-zero doc rather than
    /// omitting the field. (`ClusterDoc::tier` stays optional on the
    /// wire so clients tolerate older servers.)
    fn tier_doc(&self) -> Option<TierDoc> {
        let s = match self.dfs.tier_stats() {
            Some(s) => s,
            None => return Some(TierDoc {
                mem_budget_bytes: 0,
                resident_bytes: 0,
                backing_bytes: 0,
                hits: 0,
                misses: 0,
                evictions: 0,
                promotions: 0,
                writeback_bytes: 0,
                spill_bytes: 0,
                simulated_io_s: 0.0,
            }),
        };
        Some(TierDoc {
            mem_budget_bytes: s.mem_budget.unwrap_or(0),
            resident_bytes: s.resident_bytes,
            backing_bytes: s.backing_bytes,
            hits: s.tier_hits,
            misses: s.tier_misses,
            evictions: s.tier_evictions,
            promotions: s.tier_promotions,
            writeback_bytes: s.writeback_bytes,
            spill_bytes: s.spill_bytes,
            simulated_io_s: s.simulated_io_s,
        })
    }

    /// Push the tier counters into the metrics registry as gauges so
    /// `GET /v1/metrics` exposes storage health alongside job metrics.
    /// No-op on single-tier backends.
    pub fn publish_storage_metrics(&self) {
        let Some(s) = self.dfs.tier_stats() else { return };
        let m = &self.metrics;
        m.set_gauge("storage_mem_budget_bytes", s.mem_budget.unwrap_or(0) as f64);
        m.set_gauge("storage_resident_bytes", s.resident_bytes as f64);
        m.set_gauge("storage_backing_bytes", s.backing_bytes as f64);
        m.set_gauge("storage_tier_hits", s.tier_hits as f64);
        m.set_gauge("storage_tier_misses", s.tier_misses as f64);
        m.set_gauge("storage_tier_evictions", s.tier_evictions as f64);
        m.set_gauge("storage_tier_promotions", s.tier_promotions as f64);
        m.set_gauge("storage_writeback_bytes", s.writeback_bytes as f64);
        m.set_gauge("storage_spill_bytes", s.spill_bytes as f64);
        m.set_gauge("storage_simulated_io_s", s.simulated_io_s);
    }

    /// Crash a node: it leaves the machine model and the LSF pool; any
    /// running job holding it is failed (its allocation died).
    pub fn fail_node(&mut self, node: u64) -> Result<Vec<LsfJobId>> {
        let id = NodeId(node as u32);
        self.cluster.fail_node(id)?;
        let victims = self.lsf.node_failed(id);
        for &v in &victims {
            if let Some(e) = self.entries.get_mut(&v) {
                e.result = Some(Err(Error::Api(format!("job {v} lost node {id}"))));
            }
            let _ = self.lsf.fail(v, self.now);
        }
        self.metrics.event(self.now, "cluster", &format!("node {id} failed"));
        Ok(victims)
    }

    /// Administratively drain a node (maintenance): no new allocations.
    pub fn drain_node(&mut self, node: u64) -> Result<()> {
        let id = NodeId(node as u32);
        self.cluster.drain_node(id)?;
        self.lsf.drain_node(id);
        self.metrics.event(self.now, "cluster", &format!("node {id} drained"));
        Ok(())
    }

    /// Restore a failed or drained node into service.
    pub fn restore_node(&mut self, node: u64) -> Result<()> {
        let id = NodeId(node as u32);
        self.cluster.restore_node(id)?;
        self.lsf.restore_node(id);
        self.metrics.event(self.now, "cluster", &format!("node {id} restored"));
        Ok(())
    }

    pub fn jobs(&self) -> Vec<(LsfJobId, &'static str, JobState)> {
        self.lsf
            .jobs()
            .map(|j| {
                let kind = self
                    .entries
                    .get(&j.id)
                    .map(|e| e.payload.kind())
                    .unwrap_or("plain");
                (j.id, kind, j.state)
            })
            .collect()
    }

    fn run_dispatched(&mut self, id: LsfJobId, nodes: &[NodeId]) -> Result<AppResult> {
        let entry = self
            .entries
            .get(&id)
            .ok_or_else(|| Error::Api(format!("no payload for job {id}")))?;
        let payload = entry.payload.clone();
        let user = entry.user.clone();
        let tag = format!("lsf-{id}");
        let mut dc = DynamicCluster::build(
            &self.cfg,
            nodes,
            &*self.dfs,
            Arc::clone(&self.ids),
            Arc::clone(&self.metrics),
            &tag,
            self.now,
        )?;
        let run = self.run_payload(&mut dc, &payload, &user, &tag);
        // Teardown happens regardless of app success; its failure only
        // masks an app success (a dirty cluster is a wrapper bug).
        let teardown = dc.teardown(&*self.dfs, self.now);
        let result = run?;
        teardown?;
        dc.verify_clean(&*self.dfs)?;
        Ok(result)
    }

    fn run_payload(
        &self,
        dc: &mut DynamicCluster,
        payload: &AppPayload,
        user: &str,
        tag: &str,
    ) -> Result<AppResult> {
        let t0 = std::time::Instant::now();
        let mount = self.cfg.lustre.mount.trim_end_matches('/');
        let mut engine = MrEngine::new(
            dc,
            self.dfs.clone() as Arc<dyn Dfs>,
            &self.pool,
            self.cfg.yarn.map_memory_mb,
            self.cfg.yarn.reduce_memory_mb,
        );
        match payload {
            AppPayload::Terasort {
                rows,
                maps,
                reduces,
                use_kernel,
            } => {
                // Data lives OUTSIDE the wrapper staging root: outputs must
                // survive teardown (§III step 5).
                let in_dir = format!("{mount}/data/{tag}/tera-in");
                let out_dir = format!("{mount}/data/{tag}/tera-out");
                let gen = TeragenSpec {
                    rows: *rows,
                    maps: *maps,
                    output_dir: in_dir.clone(),
                    seed: self.cfg.seed,
                };
                terasort::run_teragen(&mut engine, &gen, self.now)?;
                let input = summarize_dir(&*self.dfs, &in_dir)?;
                let ts = TerasortJob {
                    split_bytes: 4 * 1024 * 1024,
                    ..TerasortJob::new(&in_dir, &out_dir, *reduces)
                };
                let outcome = if *use_kernel {
                    let samples = terasort::sample_input(&*self.dfs, &in_dir, 1000)?;
                    let part =
                        terasort::RangePartitioner::from_samples(samples, *reduces)?;
                    let client = crate::runtime::shared_client()?;
                    let bp = crate::runtime::KernelBlockProcessor::new(client, part)?;
                    terasort::run_terasort_with_processor(
                        &mut engine,
                        &ts,
                        Arc::new(bp),
                        self.now,
                    )?
                } else {
                    terasort::run_terasort(&mut engine, &ts, None, self.now)?
                };
                let validated = teravalidate(&*self.dfs, &out_dir, input)?;
                Ok(AppResult {
                    kind: "terasort",
                    output_dir: out_dir,
                    output_files: outcome.output_files,
                    records: validated.records,
                    validated: true,
                    counters: outcome.counters.snapshot(),
                    wall: t0.elapsed(),
                })
            }
            AppPayload::Teragen { rows, maps, dir } => {
                let gen = TeragenSpec {
                    rows: *rows,
                    maps: *maps,
                    output_dir: dir.clone(),
                    seed: self.cfg.seed,
                };
                let outcome = terasort::run_teragen(&mut engine, &gen, self.now)?;
                Ok(AppResult {
                    kind: "teragen",
                    output_dir: dir.clone(),
                    output_files: outcome.output_files,
                    records: *rows,
                    validated: false,
                    counters: outcome.counters.snapshot(),
                    wall: t0.elapsed(),
                })
            }
            AppPayload::PigScript { script, reduces } => {
                let plan = pig::parse_script(script, *reduces)?;
                self.run_query_plan(&mut engine, "pig", &plan, user, t0)
            }
            AppPayload::HiveQuery { sql, reduces } => {
                let plan = hive::parse_query(sql, *reduces)?;
                self.run_query_plan(&mut engine, "hive", &plan, user, t0)
            }
            AppPayload::Query {
                engine: qe,
                text,
                reduces,
            } => {
                let plan = parse_query_text(qe, text, *reduces)?;
                self.run_query_plan(&mut engine, "query", &plan, user, t0)
            }
            AppPayload::QueryStage { stage } => {
                let (outcome, records) = self.run_stage(&mut engine, stage, user)?;
                Ok(AppResult {
                    kind: "query_stage",
                    output_dir: stage.output_dir.clone(),
                    output_files: outcome.output_files,
                    records,
                    validated: false,
                    counters: outcome.counters.snapshot(),
                    wall: t0.elapsed(),
                })
            }
            AppPayload::RSummary {
                input_dir,
                output_dir,
                fields,
                delimiter,
                columns,
            } => {
                let schema = Schema::new(
                    &fields.iter().map(String::as_str).collect::<Vec<_>>(),
                    *delimiter,
                );
                let cols: Vec<&str> = columns.iter().map(String::as_str).collect();
                let spec = rhadoop::summary_job(input_dir, output_dir, schema, &cols)?;
                let outcome = engine.run(Arc::new(spec), user, self.now)?;
                Ok(AppResult {
                    kind: "rsummary",
                    output_dir: output_dir.clone(),
                    output_files: outcome.output_files,
                    records: outcome.counters.get("REDUCE_OUTPUT_RECORDS"),
                    validated: false,
                    counters: outcome.counters.snapshot(),
                    wall: t0.elapsed(),
                })
            }
        }
    }

    /// Run ONE compiled query stage: pre-delete a stale intermediate
    /// output (guarded — see `StageSpec::cleanable_intermediate`),
    /// compile (sort stages sample their input here, after the producer
    /// ran), execute, and return the outcome plus its output-record
    /// count. Shared by the `query_stage` payload and the chained
    /// `query` runner so their semantics cannot drift.
    fn run_stage(
        &self,
        engine: &mut MrEngine<'_>,
        stage: &crate::frameworks::StageSpec,
        user: &str,
    ) -> Result<(crate::mapreduce::MrOutcome, u64)> {
        if stage.cleanable_intermediate() && self.dfs.exists(&stage.output_dir) {
            self.dfs.delete_recursive(&stage.output_dir)?;
        }
        let spec = stage.compile(&*self.dfs)?;
        let map_only = spec.n_reduces == 0;
        let outcome = engine.run(Arc::new(spec), user, self.now)?;
        let records = outcome.counters.get(if map_only {
            "MAP_OUTPUT_RECORDS"
        } else {
            "REDUCE_OUTPUT_RECORDS"
        });
        Ok((outcome, records))
    }

    /// Compile a query and report the optimizer's plan WITHOUT running
    /// it — the EXPLAIN path. Each stage carries its wire-canonical
    /// spec plus the join strategy the cost rule would pick right now,
    /// the logical ops fused into it, and estimated input bytes from
    /// DFS size metadata.
    pub fn explain_query(
        &self,
        engine: &str,
        text: &str,
        reduces: u32,
    ) -> Result<crate::codec::json::Json> {
        use crate::codec::json::Json;
        let plan = parse_query_text(engine, text, reduces)?;
        let (stages, stats) = plan.optimized_stages()?;
        let stage_docs = stages
            .iter()
            .enumerate()
            .map(|(i, s)| {
                let (strategy, bytes) = s.explain_strategy(&*self.dfs);
                Json::obj(vec![
                    ("stage", Json::num(i as f64)),
                    ("strategy", Json::str(strategy)),
                    ("est_input_bytes", Json::num(bytes as f64)),
                    (
                        "ops",
                        Json::Arr(s.fused_ops().into_iter().map(Json::str).collect()),
                    ),
                    ("spec", crate::api::wire::stage_to_json(s)),
                ])
            })
            .collect();
        Ok(Json::obj(vec![
            ("engine", Json::str(engine)),
            ("reduces", Json::num(reduces as f64)),
            ("naive_stages", Json::num(stats.naive_stages as f64)),
            ("stages_fused", Json::num(stats.stages_fused as f64)),
            (
                "predicate_pushdowns",
                Json::num(stats.predicate_pushdowns as f64),
            ),
            ("stages", Json::Arr(stage_docs)),
        ]))
    }

    /// Run a compiled query plan as chained MR jobs on one dynamic
    /// cluster: stage `i` reads stage `i-1`'s output through the DFS;
    /// intermediates are deleted after success. The result carries the
    /// final stage's output plus merged (`NAME`) and per-stage
    /// (`s{i}.NAME`) counters.
    fn run_query_plan(
        &self,
        engine: &mut MrEngine<'_>,
        kind: &'static str,
        plan: &LogicalPlan,
        user: &str,
        t0: std::time::Instant,
    ) -> Result<AppResult> {
        let (stages, pstats) = plan.optimized_stages()?;
        let mut merged: BTreeMap<String, u64> = BTreeMap::new();
        // Planner counters: what the optimizer did, next to what the
        // engine measured.
        merged.insert(
            crate::mapreduce::counters::STAGES_FUSED.to_string(),
            pstats.stages_fused,
        );
        merged.insert(
            crate::mapreduce::counters::PREDICATE_PUSHDOWNS.to_string(),
            pstats.predicate_pushdowns,
        );
        let mut per_stage: Vec<(String, u64)> = Vec::new();
        let mut last: Option<(crate::mapreduce::MrOutcome, u64)> = None;
        for (i, stage) in stages.iter().enumerate() {
            let (outcome, records) = self.run_stage(engine, stage, user)?;
            for (name, v) in outcome.counters.snapshot() {
                *merged.entry(name.clone()).or_insert(0) += v;
                per_stage.push((format!("s{i}.{name}"), v));
            }
            last = Some((outcome, records));
        }
        let (outcome, records) =
            last.ok_or_else(|| Error::Api("query compiled to zero stages".into()))?;
        for stage in &stages[..stages.len() - 1] {
            let _ = self.dfs.delete_recursive(&stage.output_dir);
        }
        let mut counters: Vec<(String, u64)> = merged.into_iter().collect();
        counters.extend(per_stage);
        Ok(AppResult {
            kind,
            output_dir: plan.output_dir.clone(),
            output_files: outcome.output_files,
            records,
            validated: false,
            counters,
            wall: t0.elapsed(),
        })
    }
}

/// Parse `engine` + query text into a validated plan (`"pig"` scripts or
/// `"hive"` SQL).
pub fn parse_query_text(engine: &str, text: &str, reduces: u32) -> Result<LogicalPlan> {
    match engine {
        "pig" => pig::parse_script(text, reduces),
        "hive" => hive::parse_query(text, reduces),
        other => Err(Error::Api(format!(
            "unknown query engine '{other}' (pig|hive)"
        ))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stack() -> Stack {
        Stack::new(StackConfig::tiny()).unwrap()
    }

    #[test]
    fn terasort_payload_end_to_end() {
        let mut s = stack();
        let id = s
            .submit(
                6,
                "sid",
                AppPayload::Terasort {
                    rows: 3_000,
                    maps: 3,
                    reduces: 4,
                    use_kernel: false,
                },
            )
            .unwrap();
        let result = s.run_to_completion(id, 10).unwrap().clone();
        assert!(result.validated);
        assert_eq!(result.records, 3_000);
        assert_eq!(result.output_files.len(), 4);
        assert_eq!(s.lsf.status(id).unwrap().state, JobState::Done);
        // Cluster is fully released.
        assert_eq!(s.lsf.free_nodes(), 8);
        s.lsf.check_invariants().unwrap();
    }

    #[test]
    fn pig_payload_runs_on_stack() {
        let mut s = stack();
        // Stage input data on Lustre first (step: data staging).
        s.dfs.mkdirs("/lustre/scratch/sales").unwrap();
        s.dfs
            .create(
                "/lustre/scratch/sales/part-0",
                b"wales,widget,150\nwales,sprocket,80\nengland,widget,300\nwales,widget,200\n",
            )
            .unwrap();
        let script = "
            recs = LOAD '/lustre/scratch/sales' USING ',' AS (region, product, amount);
            big  = FILTER recs BY amount > 100;
            grp  = GROUP big BY region;
            out  = FOREACH grp GENERATE group, SUM(amount), COUNT(amount);
            STORE out INTO '/lustre/scratch/sales-report';
        ";
        let id = s
            .submit(
                4,
                "ana",
                AppPayload::PigScript {
                    script: script.into(),
                    reduces: 2,
                },
            )
            .unwrap();
        let result = s.run_to_completion(id, 10).unwrap().clone();
        let mut text = String::new();
        for f in &result.output_files {
            text.push_str(&String::from_utf8(s.read_output(f).unwrap()).unwrap());
        }
        let lines = crate::frameworks::plan::sorted_result_lines(&text);
        assert_eq!(lines, vec!["england\t300\t1", "wales\t350\t2"]);
    }

    #[test]
    fn failed_payload_marks_job_exited() {
        let mut s = stack();
        // Hive query over a missing input dir fails inside the cluster.
        let id = s
            .submit(
                4,
                "bob",
                AppPayload::HiveQuery {
                    sql: "SELECT COUNT(a) FROM '/lustre/scratch/nope' SCHEMA (a) INTO '/lustre/scratch/x'"
                        .into(),
                    reduces: 1,
                },
            )
            .unwrap();
        s.tick();
        assert_eq!(s.lsf.status(id).unwrap().state, JobState::Exited);
        assert!(s.job_error(id).unwrap().contains("no input files"));
        // Nodes released even on failure.
        assert_eq!(s.lsf.free_nodes(), 8);
    }

    #[test]
    fn cluster_doc_reports_states_and_counts() {
        let mut s = stack();
        let doc = s.cluster_doc();
        assert_eq!(doc.nodes.len(), 8);
        assert_eq!(doc.up, 8);
        assert_eq!(doc.leased, 0);
        assert!(doc.nodes.iter().all(|n| n.state == "UP" && n.job.is_none()));
        s.drain_node(2).unwrap();
        s.fail_node(5).unwrap();
        let doc = s.cluster_doc();
        assert_eq!(doc.up, 6);
        assert_eq!(doc.drained, 1);
        assert_eq!(doc.down, 1);
        assert_eq!(doc.nodes[2].state, "DRAINED");
        assert_eq!(doc.nodes[5].state, "DOWN");
    }

    #[test]
    fn cluster_doc_surfaces_node_mips() {
        // Homogeneous default: every node reports the reference speed.
        let s = stack();
        let doc = s.cluster_doc();
        assert!(doc
            .nodes
            .iter()
            .all(|n| n.mips == crate::scenario::REFERENCE_MIPS));

        // A heterogeneous profile flows config -> ClusterModel -> wire.
        let mut cfg = StackConfig::tiny();
        cfg.elastic.node_mips = vec![(0, 250), (3, 2_000)];
        let s = Stack::new(cfg).unwrap();
        let doc = s.cluster_doc();
        assert_eq!(doc.nodes[0].mips, 250);
        assert_eq!(doc.nodes[3].mips, 2_000);
        assert_eq!(doc.nodes[1].mips, crate::scenario::REFERENCE_MIPS);
        // And survives the canonical wire round trip.
        let back = ClusterDoc::from_json(
            &crate::codec::json::Json::parse(&doc.to_json().to_string()).unwrap(),
        )
        .unwrap();
        assert_eq!(back.nodes[0].mips, 250);
        assert_eq!(back.nodes[3].mips, 2_000);
    }

    #[test]
    fn cluster_doc_tier_shape_is_stable_across_configs() {
        // Untiered stack (no HPCW_MEM_BUDGET / lustre.mem_budget_bytes —
        // the suite runs without the env var, as the tiered-store tests
        // already assume): the tier doc is still present, all zeroes, so
        // the GET /v1/cluster schema has one shape across configs.
        let mut s = stack();
        let doc = s.cluster_doc();
        let tier = doc.tier.clone().expect("tier doc present without a budget");
        assert_eq!(tier.mem_budget_bytes, 0);
        assert_eq!(tier.resident_bytes, 0);
        assert_eq!(tier.backing_bytes, 0);
        assert_eq!(tier.hits + tier.misses + tier.evictions, 0);
        assert_eq!(tier.simulated_io_s, 0.0);
        // The zeroed shape survives the wire.
        let back = ClusterDoc::from_json(
            &crate::codec::json::Json::parse(&doc.to_json().to_string()).unwrap(),
        )
        .unwrap();
        assert_eq!(back.tier.unwrap(), tier);

        // Tiered stack: the same field carries the real stats.
        let mut cfg = StackConfig::tiny();
        cfg.lustre.mem_budget_bytes = 1 << 20;
        let mut s = Stack::new(cfg).unwrap();
        let id = s
            .submit(
                2,
                "tier",
                AppPayload::Teragen {
                    rows: 200,
                    maps: 1,
                    dir: "/lustre/scratch/tier-shape".into(),
                },
            )
            .unwrap();
        s.run_to_completion(id, 10).unwrap();
        let doc = s.cluster_doc();
        let tier = doc.tier.expect("tier doc present with a budget");
        assert_eq!(tier.mem_budget_bytes, 1 << 20);
        assert!(
            tier.resident_bytes + tier.backing_bytes > 0,
            "a completed teragen leaves bytes in the store: {tier:?}"
        );
    }

    #[test]
    fn failed_node_shrinks_pool_until_restored() {
        let mut s = stack();
        s.fail_node(7).unwrap();
        assert_eq!(s.lsf.free_nodes(), 7);
        // A full-cluster request now exceeds capacity at dispatch time but
        // an 7-node job still runs.
        let id = s
            .submit(
                7,
                "u",
                AppPayload::Teragen {
                    rows: 200,
                    maps: 2,
                    dir: "/lustre/scratch/fn-g".into(),
                },
            )
            .unwrap();
        s.run_to_completion(id, 10).unwrap();
        assert_eq!(s.lsf.status(id).unwrap().state, JobState::Done);
        s.restore_node(7).unwrap();
        assert_eq!(s.lsf.free_nodes(), 8);
        assert_eq!(s.cluster_doc().up, 8);
        s.lsf.check_invariants().unwrap();
    }

    #[test]
    fn queueing_two_big_jobs_serialize() {
        let mut s = stack();
        let mk = || AppPayload::Teragen {
            rows: 500,
            maps: 2,
            dir: String::new(),
        };
        let a = s
            .submit(8, "u1", {
                let mut p = mk();
                if let AppPayload::Teragen { dir, .. } = &mut p {
                    *dir = "/lustre/scratch/g1".into();
                }
                p
            })
            .unwrap();
        let b = s
            .submit(8, "u2", {
                let mut p = mk();
                if let AppPayload::Teragen { dir, .. } = &mut p {
                    *dir = "/lustre/scratch/g2".into();
                }
                p
            })
            .unwrap();
        let first = s.tick();
        assert_eq!(first, vec![a]);
        assert_eq!(s.lsf.status(b).unwrap().state, JobState::Pending);
        let second = s.tick();
        assert_eq!(second, vec![b]);
        assert!(s.dfs.exists("/lustre/scratch/g1/_SUCCESS"));
        assert!(s.dfs.exists("/lustre/scratch/g2/_SUCCESS"));
    }
}
