//! Minimal HTTP/1.1 server and client primitives on `std::net`.
//!
//! Enough protocol for a JSON REST API: request line, headers,
//! Content-Length bodies, keep-alive off (Connection: close). Not a
//! general web server — the SynfiniWay analog only needs request/response.

use crate::error::{Error, Result};
use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

/// A parsed request.
#[derive(Debug)]
pub struct Request {
    pub method: String,
    pub path: String,
    pub headers: BTreeMap<String, String>,
    pub body: Vec<u8>,
}

impl Request {
    /// Path segments, e.g. `/jobs/42` → `["jobs", "42"]`.
    pub fn segments(&self) -> Vec<&str> {
        self.path
            .split('?')
            .next()
            .unwrap_or("")
            .split('/')
            .filter(|s| !s.is_empty())
            .collect()
    }

    pub fn body_text(&self) -> Result<&str> {
        std::str::from_utf8(&self.body).map_err(|_| Error::Api("non-utf8 body".into()))
    }
}

/// A response under construction.
#[derive(Debug)]
pub struct Response {
    pub status: u16,
    pub content_type: &'static str,
    pub body: Vec<u8>,
}

impl Response {
    pub fn json(status: u16, body: String) -> Response {
        Response {
            status,
            content_type: "application/json",
            body: body.into_bytes(),
        }
    }

    pub fn bytes(status: u16, body: Vec<u8>) -> Response {
        Response {
            status,
            content_type: "application/octet-stream",
            body,
        }
    }

    fn status_text(&self) -> &'static str {
        match self.status {
            200 => "OK",
            201 => "Created",
            400 => "Bad Request",
            404 => "Not Found",
            405 => "Method Not Allowed",
            409 => "Conflict",
            _ => "Internal Server Error",
        }
    }

    fn write_to(&self, stream: &mut TcpStream) -> std::io::Result<()> {
        let head = format!(
            "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
            self.status,
            self.status_text(),
            self.content_type,
            self.body.len()
        );
        stream.write_all(head.as_bytes())?;
        stream.write_all(&self.body)
    }
}

/// Read one request from a stream.
pub fn read_request(stream: &mut TcpStream) -> Result<Request> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    let mut parts = line.split_whitespace();
    let method = parts
        .next()
        .ok_or_else(|| Error::Api("empty request line".into()))?
        .to_string();
    let path = parts
        .next()
        .ok_or_else(|| Error::Api("missing path".into()))?
        .to_string();

    let mut headers = BTreeMap::new();
    loop {
        let mut h = String::new();
        reader.read_line(&mut h)?;
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            headers.insert(k.trim().to_ascii_lowercase(), v.trim().to_string());
        }
    }
    let len: usize = headers
        .get("content-length")
        .and_then(|v| v.parse().ok())
        .unwrap_or(0);
    let mut body = vec![0u8; len];
    if len > 0 {
        reader.read_exact(&mut body)?;
    }
    Ok(Request {
        method,
        path,
        headers,
        body,
    })
}

/// Serve until `stop` flips; each connection handled on its own thread.
pub fn serve(
    listener: TcpListener,
    stop: Arc<AtomicBool>,
    handler: Arc<dyn Fn(Request) -> Response + Send + Sync>,
) {
    listener
        .set_nonblocking(true)
        .expect("nonblocking listener");
    while !stop.load(Ordering::Relaxed) {
        match listener.accept() {
            Ok((mut stream, _)) => {
                let handler = Arc::clone(&handler);
                std::thread::spawn(move || {
                    stream.set_nonblocking(false).ok();
                    let response = match read_request(&mut stream) {
                        Ok(req) => handler(req),
                        Err(e) => Response::json(
                            400,
                            format!("{{\"error\":\"{}\"}}", e.to_string().replace('"', "'")),
                        ),
                    };
                    let _ = response.write_to(&mut stream);
                });
            }
            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(std::time::Duration::from_millis(5));
            }
            Err(_) => break,
        }
    }
}

/// Blocking client request; returns (status, body).
pub fn request(
    addr: &str,
    method: &str,
    path: &str,
    body: Option<&[u8]>,
) -> Result<(u16, Vec<u8>)> {
    let mut stream = TcpStream::connect(addr)
        .map_err(|e| Error::Api(format!("connect {addr}: {e}")))?;
    let body = body.unwrap_or(&[]);
    let head = format!(
        "{method} {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body)?;

    let mut reader = BufReader::new(stream);
    let mut status_line = String::new();
    reader.read_line(&mut status_line)?;
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| Error::Api(format!("bad status line '{status_line}'")))?;
    let mut len = 0usize;
    loop {
        let mut h = String::new();
        reader.read_line(&mut h)?;
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            if k.trim().eq_ignore_ascii_case("content-length") {
                len = v.trim().parse().unwrap_or(0);
            }
        }
    }
    let mut body = vec![0u8; len];
    reader.read_exact(&mut body)?;
    Ok((status, body))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip_over_loopback() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let handler: Arc<dyn Fn(Request) -> Response + Send + Sync> =
            Arc::new(|req: Request| {
                assert_eq!(req.method, "POST");
                assert_eq!(req.segments(), vec!["echo", "x"]);
                Response::json(200, String::from_utf8(req.body).unwrap())
            });
        let server = std::thread::spawn(move || serve(listener, stop2, handler));

        let (status, body) = request(&addr, "POST", "/echo/x", Some(b"{\"a\":1}")).unwrap();
        assert_eq!(status, 200);
        assert_eq!(body, b"{\"a\":1}");

        stop.store(true, Ordering::Relaxed);
        server.join().unwrap();
    }

    #[test]
    fn segments_ignore_query() {
        let r = Request {
            method: "GET".into(),
            path: "/jobs/7/output?path=/x".into(),
            headers: BTreeMap::new(),
            body: vec![],
        };
        assert_eq!(r.segments(), vec!["jobs", "7", "output"]);
    }
}
