//! Minimal HTTP/1.1 server and client primitives on `std::net`.
//!
//! Enough protocol for a JSON REST API: request line, headers,
//! Content-Length bodies, keep-alive off (Connection: close). Not a
//! general web server — the SynfiniWay analog only needs request/response.
//!
//! The reader is hardened against adversarial input: request lines and
//! header lines are length-bounded, header count is capped, bodies are
//! capped, and every violation produces a clean parse error (which the
//! server answers with a structured 4xx envelope) instead of unbounded
//! allocation or a hung thread.

use crate::error::{Error, Result};
use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Longest accepted request line or header line, bytes.
pub const MAX_LINE_BYTES: usize = 8 * 1024;
/// Most headers accepted on one request.
pub const MAX_HEADERS: usize = 64;
/// Largest accepted request body, bytes.
pub const MAX_BODY_BYTES: usize = 64 * 1024 * 1024;
/// Per-connection socket timeouts: a client that stalls mid-request or
/// stops reading the response cannot pin a handler thread forever.
const READ_TIMEOUT: Duration = Duration::from_secs(30);
const WRITE_TIMEOUT: Duration = Duration::from_secs(30);

/// A parsed request.
#[derive(Debug)]
pub struct Request {
    pub method: String,
    pub path: String,
    pub headers: BTreeMap<String, String>,
    pub body: Vec<u8>,
}

impl Request {
    /// Path segments, e.g. `/v1/jobs/42` → `["v1", "jobs", "42"]`.
    pub fn segments(&self) -> Vec<&str> {
        self.path
            .split('?')
            .next()
            .unwrap_or("")
            .split('/')
            .filter(|s| !s.is_empty())
            .collect()
    }

    /// Path without the query string.
    pub fn route(&self) -> &str {
        self.path.split('?').next().unwrap_or("")
    }

    /// A query parameter, `%XX`-decoded. `/x?a=1&b=two` → `query_param("b") == Some("two")`.
    pub fn query_param(&self, name: &str) -> Option<String> {
        let query = self.path.split('?').nth(1)?;
        query.split('&').find_map(|kv| {
            let (k, v) = kv.split_once('=')?;
            (k == name).then(|| percent_decode(v))
        })
    }

    pub fn body_text(&self) -> Result<&str> {
        std::str::from_utf8(&self.body).map_err(|_| Error::Api("non-utf8 body".into()))
    }
}

/// Decode `%XX` escapes and `+` (space); malformed escapes pass through.
pub fn percent_decode(s: &str) -> String {
    let bytes = s.as_bytes();
    let mut out = Vec::with_capacity(bytes.len());
    let mut i = 0;
    while i < bytes.len() {
        match bytes[i] {
            b'%' if i + 2 < bytes.len() => {
                let hex = |b: u8| (b as char).to_digit(16);
                match (hex(bytes[i + 1]), hex(bytes[i + 2])) {
                    (Some(h), Some(l)) => {
                        out.push((h * 16 + l) as u8);
                        i += 3;
                    }
                    _ => {
                        out.push(b'%');
                        i += 1;
                    }
                }
            }
            b'+' => {
                out.push(b' ');
                i += 1;
            }
            b => {
                out.push(b);
                i += 1;
            }
        }
    }
    String::from_utf8_lossy(&out).into_owned()
}

/// A response under construction.
#[derive(Debug)]
pub struct Response {
    pub status: u16,
    pub content_type: &'static str,
    /// Extra headers (`Location`, `Deprecation`, ...).
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl Response {
    pub fn json(status: u16, body: String) -> Response {
        Response {
            status,
            content_type: "application/json",
            headers: Vec::new(),
            body: body.into_bytes(),
        }
    }

    pub fn bytes(status: u16, body: Vec<u8>) -> Response {
        Response {
            status,
            content_type: "application/octet-stream",
            headers: Vec::new(),
            body,
        }
    }

    pub fn text(status: u16, body: String) -> Response {
        Response {
            status,
            content_type: "text/plain",
            headers: Vec::new(),
            body: body.into_bytes(),
        }
    }

    pub fn with_header(mut self, name: &str, value: &str) -> Response {
        self.headers.push((name.to_string(), value.to_string()));
        self
    }

    fn status_text(&self) -> &'static str {
        match self.status {
            200 => "OK",
            201 => "Created",
            301 => "Moved Permanently",
            400 => "Bad Request",
            401 => "Unauthorized",
            404 => "Not Found",
            405 => "Method Not Allowed",
            409 => "Conflict",
            413 => "Payload Too Large",
            429 => "Too Many Requests",
            503 => "Service Unavailable",
            _ => "Internal Server Error",
        }
    }

    fn write_to(&self, stream: &mut TcpStream) -> std::io::Result<()> {
        let mut head = format!(
            "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: close\r\n",
            self.status,
            self.status_text(),
            self.content_type,
            self.body.len()
        );
        for (k, v) in &self.headers {
            head.push_str(k);
            head.push_str(": ");
            head.push_str(v);
            head.push_str("\r\n");
        }
        head.push_str("\r\n");
        stream.write_all(head.as_bytes())?;
        stream.write_all(&self.body)
    }
}

/// Read one `\n`-terminated line, at most `MAX_LINE_BYTES` long. A closed
/// connection before any byte yields an "empty request" error; a line with
/// no terminator within the bound is "line too long" / "truncated".
fn read_line_bounded(reader: &mut impl BufRead, what: &str) -> Result<String> {
    let mut buf = Vec::new();
    let mut limited = reader.take(MAX_LINE_BYTES as u64 + 1);
    limited.read_until(b'\n', &mut buf)?;
    if buf.len() > MAX_LINE_BYTES {
        return Err(Error::Api(format!("{what} line exceeds {MAX_LINE_BYTES} bytes")));
    }
    if !buf.ends_with(b"\n") && !buf.is_empty() {
        return Err(Error::Api(format!("truncated {what} line")));
    }
    String::from_utf8(buf).map_err(|_| Error::Api(format!("non-utf8 {what} line")))
}

/// Read one request from a stream, enforcing the protocol bounds.
pub fn read_request(stream: &mut TcpStream) -> Result<Request> {
    stream.set_read_timeout(Some(READ_TIMEOUT)).ok();
    stream.set_write_timeout(Some(WRITE_TIMEOUT)).ok();
    let mut reader = BufReader::new(stream.try_clone()?);
    let line = read_line_bounded(&mut reader, "request")?;
    if line.trim().is_empty() {
        return Err(Error::Api("empty request line".into()));
    }
    let mut parts = line.split_whitespace();
    let method = parts
        .next()
        .ok_or_else(|| Error::Api("empty request line".into()))?
        .to_string();
    let path = parts
        .next()
        .ok_or_else(|| Error::Api("missing path".into()))?
        .to_string();

    let mut headers = BTreeMap::new();
    loop {
        let h = read_line_bounded(&mut reader, "header")?;
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        if headers.len() >= MAX_HEADERS {
            return Err(Error::Api(format!("more than {MAX_HEADERS} headers")));
        }
        if let Some((k, v)) = h.split_once(':') {
            headers.insert(k.trim().to_ascii_lowercase(), v.trim().to_string());
        }
    }
    let len: usize = headers
        .get("content-length")
        .and_then(|v| v.parse().ok())
        .unwrap_or(0);
    if len > MAX_BODY_BYTES {
        return Err(Error::Api(format!(
            "body of {len} bytes exceeds the {MAX_BODY_BYTES}-byte cap"
        )));
    }
    let mut body = vec![0u8; len];
    if len > 0 {
        reader.read_exact(&mut body)?;
    }
    Ok(Request {
        method,
        path,
        headers,
        body,
    })
}

/// The structured 4xx envelope for requests that never reached a handler.
/// Mirrors `wire::ErrorDoc` (kept literal here: the HTTP layer stays
/// schema-agnostic).
fn parse_error_response(e: &Error) -> Response {
    let msg = e.to_string().replace('\\', "\\\\").replace('"', "'");
    let (status, code) = if msg.contains("exceeds the") {
        (413, "too_large")
    } else {
        (400, "bad_request")
    };
    Response::json(
        status,
        format!("{{\"error\":{{\"code\":\"{code}\",\"message\":\"{msg}\"}}}}"),
    )
}

/// Serve until `stop` flips; each connection handled on its own thread.
pub fn serve(
    listener: TcpListener,
    stop: Arc<AtomicBool>,
    handler: Arc<dyn Fn(Request) -> Response + Send + Sync>,
) {
    listener
        .set_nonblocking(true)
        .expect("nonblocking listener");
    while !stop.load(Ordering::Relaxed) {
        match listener.accept() {
            Ok((mut stream, _)) => {
                let handler = Arc::clone(&handler);
                std::thread::spawn(move || {
                    stream.set_nonblocking(false).ok();
                    let response = match read_request(&mut stream) {
                        Ok(req) => handler(req),
                        Err(e) => parse_error_response(&e),
                    };
                    let _ = response.write_to(&mut stream);
                });
            }
            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(std::time::Duration::from_millis(5));
            }
            Err(_) => break,
        }
    }
}

/// Counters published by `serve_pool`'s bounded front door.
#[derive(Debug, Default)]
pub struct ServeStats {
    /// Connections shed with 429 because the accept queue was full.
    pub shed: std::sync::atomic::AtomicU64,
    /// Connections accepted into the work queue.
    pub accepted: std::sync::atomic::AtomicU64,
}

impl ServeStats {
    pub fn shed_count(&self) -> u64 {
        self.shed.load(Ordering::Relaxed)
    }

    pub fn accepted_count(&self) -> u64 {
        self.accepted.load(Ordering::Relaxed)
    }
}

/// The overload response, written *before* any request parse: when the
/// accept queue is full the server must not spend reader-thread time on
/// the very load it is shedding.
fn shed_response() -> Response {
    Response::json(
        429,
        "{\"error\":{\"code\":\"rate_limited\",\"message\":\"server overloaded; accept queue full\"}}"
            .to_string(),
    )
    .with_header("Retry-After", "1")
}

/// Serve with a bounded front door: a fixed pool of `workers` handler
/// threads drains a work queue of at most `queue_depth` accepted
/// connections. When the queue is full, new connections are answered 429
/// + `Retry-After` immediately — before the request is even read — so an
/// overloaded server stays responsive instead of accumulating threads
/// (the failure mode of one-thread-per-connection `serve`). Returns when
/// `stop` flips and all workers have drained.
pub fn serve_pool(
    listener: TcpListener,
    stop: Arc<AtomicBool>,
    handler: Arc<dyn Fn(Request) -> Response + Send + Sync>,
    workers: usize,
    queue_depth: usize,
    stats: Arc<ServeStats>,
) {
    use std::collections::VecDeque;
    use std::sync::{Condvar, Mutex};

    let workers = workers.max(1);
    let queue_depth = queue_depth.max(1);
    let work: Arc<(Mutex<VecDeque<TcpStream>>, Condvar)> =
        Arc::new((Mutex::new(VecDeque::new()), Condvar::new()));

    let mut pool = Vec::with_capacity(workers);
    for _ in 0..workers {
        let work = Arc::clone(&work);
        let stop = Arc::clone(&stop);
        let handler = Arc::clone(&handler);
        pool.push(std::thread::spawn(move || loop {
            let mut stream = {
                let (lock, cvar) = &*work;
                let mut q = lock.lock().unwrap();
                loop {
                    if let Some(s) = q.pop_front() {
                        break s;
                    }
                    if stop.load(Ordering::Relaxed) {
                        return;
                    }
                    // Timed wait so a flipped `stop` is observed even if
                    // the accept loop died before notifying.
                    let (guard, _) = cvar
                        .wait_timeout(q, Duration::from_millis(50))
                        .unwrap();
                    q = guard;
                }
            };
            stream.set_nonblocking(false).ok();
            let response = match read_request(&mut stream) {
                Ok(req) => handler(req),
                Err(e) => parse_error_response(&e),
            };
            let _ = response.write_to(&mut stream);
        }));
    }

    listener
        .set_nonblocking(true)
        .expect("nonblocking listener");
    while !stop.load(Ordering::Relaxed) {
        match listener.accept() {
            Ok((mut stream, _)) => {
                let (lock, cvar) = &*work;
                let mut q = lock.lock().unwrap();
                if q.len() >= queue_depth {
                    drop(q);
                    stats.shed.fetch_add(1, Ordering::Relaxed);
                    stream.set_nonblocking(false).ok();
                    stream.set_write_timeout(Some(WRITE_TIMEOUT)).ok();
                    let _ = shed_response().write_to(&mut stream);
                } else {
                    q.push_back(stream);
                    stats.accepted.fetch_add(1, Ordering::Relaxed);
                    drop(q);
                    cvar.notify_one();
                }
            }
            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(std::time::Duration::from_millis(5));
            }
            Err(_) => break,
        }
    }
    work.1.notify_all();
    for t in pool {
        let _ = t.join();
    }
}

/// Blocking client request; returns (status, body).
pub fn request(
    addr: &str,
    method: &str,
    path: &str,
    body: Option<&[u8]>,
) -> Result<(u16, Vec<u8>)> {
    let (status, _headers, body) = request_full(addr, method, path, body)?;
    Ok((status, body))
}

/// Blocking client request; returns (status, headers, body). Header names
/// are lower-cased.
pub fn request_full(
    addr: &str,
    method: &str,
    path: &str,
    body: Option<&[u8]>,
) -> Result<(u16, BTreeMap<String, String>, Vec<u8>)> {
    request_with_headers(addr, method, path, body, &[])
}

/// `request_full` plus caller-supplied request headers (e.g. the
/// `X-HPCW-Key` tenant credential).
pub fn request_with_headers(
    addr: &str,
    method: &str,
    path: &str,
    body: Option<&[u8]>,
    extra_headers: &[(&str, &str)],
) -> Result<(u16, BTreeMap<String, String>, Vec<u8>)> {
    let mut stream = TcpStream::connect(addr)
        .map_err(|e| Error::Api(format!("connect {addr}: {e}")))?;
    let body = body.unwrap_or(&[]);
    let mut head = format!(
        "{method} {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Length: {}\r\nConnection: close\r\n",
        body.len()
    );
    for (k, v) in extra_headers {
        head.push_str(k);
        head.push_str(": ");
        head.push_str(v);
        head.push_str("\r\n");
    }
    head.push_str("\r\n");
    stream.write_all(head.as_bytes())?;
    stream.write_all(body)?;

    let mut reader = BufReader::new(stream);
    let mut status_line = String::new();
    reader.read_line(&mut status_line)?;
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| Error::Api(format!("bad status line '{status_line}'")))?;
    let mut headers = BTreeMap::new();
    let mut len = 0usize;
    loop {
        let mut h = String::new();
        reader.read_line(&mut h)?;
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            let k = k.trim().to_ascii_lowercase();
            let v = v.trim().to_string();
            if k == "content-length" {
                len = v.parse().unwrap_or(0);
            }
            headers.insert(k, v);
        }
    }
    let mut body = vec![0u8; len];
    reader.read_exact(&mut body)?;
    Ok((status, headers, body))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn echo_server() -> (String, Arc<AtomicBool>, std::thread::JoinHandle<()>) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let handler: Arc<dyn Fn(Request) -> Response + Send + Sync> =
            Arc::new(|req: Request| Response::json(200, String::from_utf8_lossy(&req.body).into_owned()));
        let server = std::thread::spawn(move || serve(listener, stop2, handler));
        (addr, stop, server)
    }

    #[test]
    fn round_trip_over_loopback() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let handler: Arc<dyn Fn(Request) -> Response + Send + Sync> =
            Arc::new(|req: Request| {
                assert_eq!(req.method, "POST");
                assert_eq!(req.segments(), vec!["echo", "x"]);
                Response::json(200, String::from_utf8(req.body).unwrap())
            });
        let server = std::thread::spawn(move || serve(listener, stop2, handler));

        let (status, body) = request(&addr, "POST", "/echo/x", Some(b"{\"a\":1}")).unwrap();
        assert_eq!(status, 200);
        assert_eq!(body, b"{\"a\":1}");

        stop.store(true, Ordering::Relaxed);
        server.join().unwrap();
    }

    #[test]
    fn segments_ignore_query() {
        let r = Request {
            method: "GET".into(),
            path: "/jobs/7/output?path=/x".into(),
            headers: BTreeMap::new(),
            body: vec![],
        };
        assert_eq!(r.segments(), vec!["jobs", "7", "output"]);
        assert_eq!(r.route(), "/jobs/7/output");
        assert_eq!(r.query_param("path").as_deref(), Some("/x"));
        assert_eq!(r.query_param("nope"), None);
    }

    #[test]
    fn query_params_percent_decode() {
        let r = Request {
            method: "GET".into(),
            path: "/jobs/7/output?path=%2Flustre%2Fa%20b&x=1+2".into(),
            headers: BTreeMap::new(),
            body: vec![],
        };
        assert_eq!(r.query_param("path").as_deref(), Some("/lustre/a b"));
        assert_eq!(r.query_param("x").as_deref(), Some("1 2"));
        assert_eq!(percent_decode("100%"), "100%");
        assert_eq!(percent_decode("%zz"), "%zz");
    }

    #[test]
    fn extra_headers_are_written() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let handler: Arc<dyn Fn(Request) -> Response + Send + Sync> = Arc::new(|_req| {
            Response::json(301, "{}".into())
                .with_header("Location", "/v1/jobs")
                .with_header("Deprecation", "true")
        });
        let server = std::thread::spawn(move || serve(listener, stop2, handler));
        let (status, headers, _body) = request_full(&addr, "GET", "/jobs", None).unwrap();
        assert_eq!(status, 301);
        assert_eq!(headers.get("location").map(String::as_str), Some("/v1/jobs"));
        assert_eq!(headers.get("deprecation").map(String::as_str), Some("true"));
        stop.store(true, Ordering::Relaxed);
        server.join().unwrap();
    }

    #[test]
    fn truncated_request_line_answered_cleanly() {
        let (addr, stop, server) = echo_server();
        // A client that sends half a request line and hangs up.
        {
            let mut s = TcpStream::connect(&addr).unwrap();
            s.write_all(b"GET /half").unwrap();
        } // dropped: connection closed with no newline
        // The server must still serve the next client.
        let (status, body) = request(&addr, "POST", "/x", Some(b"ok")).unwrap();
        assert_eq!(status, 200);
        assert_eq!(body, b"ok");
        stop.store(true, Ordering::Relaxed);
        server.join().unwrap();
    }

    #[test]
    fn oversized_request_line_rejected() {
        let (addr, stop, server) = echo_server();
        let mut s = TcpStream::connect(&addr).unwrap();
        let huge = format!("GET /{} HTTP/1.1\r\n\r\n", "a".repeat(MAX_LINE_BYTES * 2));
        s.write_all(huge.as_bytes()).unwrap();
        let mut reader = BufReader::new(s);
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("400"), "got {line}");
        stop.store(true, Ordering::Relaxed);
        server.join().unwrap();
    }

    #[test]
    fn too_many_headers_rejected() {
        let (addr, stop, server) = echo_server();
        let mut s = TcpStream::connect(&addr).unwrap();
        let mut req = String::from("GET /x HTTP/1.1\r\n");
        for i in 0..(MAX_HEADERS + 8) {
            req.push_str(&format!("X-Flood-{i}: v\r\n"));
        }
        req.push_str("\r\n");
        s.write_all(req.as_bytes()).unwrap();
        let mut reader = BufReader::new(s);
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("400"), "got {line}");
        stop.store(true, Ordering::Relaxed);
        server.join().unwrap();
    }

    #[test]
    fn oversized_body_rejected_without_allocation() {
        let (addr, stop, server) = echo_server();
        let mut s = TcpStream::connect(&addr).unwrap();
        // Claim a 1 GiB body; never send it. The server must refuse from
        // the header alone (413), not allocate-and-wait.
        let req = format!(
            "POST /x HTTP/1.1\r\nContent-Length: {}\r\n\r\n",
            1u64 << 30
        );
        s.write_all(req.as_bytes()).unwrap();
        let mut reader = BufReader::new(s);
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("413"), "got {line}");
        stop.store(true, Ordering::Relaxed);
        server.join().unwrap();
    }

    #[test]
    fn pool_serves_and_sheds_when_saturated() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let stats = Arc::new(ServeStats::default());
        let stats2 = Arc::clone(&stats);
        // One worker that blocks on a gate: the first request parks it,
        // so the queue (depth 1) fills deterministically.
        let gate = Arc::new(AtomicBool::new(false));
        let gate2 = Arc::clone(&gate);
        let entered = Arc::new(std::sync::atomic::AtomicU64::new(0));
        let entered2 = Arc::clone(&entered);
        let handler: Arc<dyn Fn(Request) -> Response + Send + Sync> =
            Arc::new(move |req: Request| {
                if req.route() == "/slow" {
                    entered2.fetch_add(1, Ordering::Relaxed);
                    while !gate2.load(Ordering::Relaxed) {
                        std::thread::sleep(Duration::from_millis(2));
                    }
                }
                Response::json(200, "{}".into())
            });
        let server =
            std::thread::spawn(move || serve_pool(listener, stop2, handler, 1, 1, stats2));

        // Park the single worker, then fill the single queue slot. Each
        // uses a raw socket kept open so the connection stays queued.
        let park = |path: &str| {
            let mut s = TcpStream::connect(&addr).unwrap();
            s.write_all(
                format!("GET {path} HTTP/1.1\r\nContent-Length: 0\r\n\r\n").as_bytes(),
            )
            .unwrap();
            s
        };
        let wait_for = |cond: &dyn Fn() -> bool, what: &str| {
            let deadline = std::time::Instant::now() + Duration::from_secs(5);
            while !cond() {
                assert!(std::time::Instant::now() < deadline, "timeout: {what}");
                std::thread::sleep(Duration::from_millis(5));
            }
        };
        let s1 = park("/slow");
        // The worker must have *popped* s1 (handler entered) before s2 is
        // sent, or s2 could race into a full queue and be shed.
        wait_for(&|| entered.load(Ordering::Relaxed) >= 1, "worker parked");
        let s2 = park("/slow");
        wait_for(&|| stats.accepted_count() >= 2, "s2 queued");

        // The third connection must be shed 429 before any parse.
        let (status, headers, body) = request_full(&addr, "GET", "/fast", None).unwrap();
        assert_eq!(status, 429);
        assert_eq!(headers.get("retry-after").map(String::as_str), Some("1"));
        assert!(String::from_utf8_lossy(&body).contains("rate_limited"));
        assert_eq!(stats.shed_count(), 1);

        // Release the gate: the queued requests complete normally.
        gate.store(true, Ordering::Relaxed);
        for s in [s1, s2] {
            let mut line = String::new();
            BufReader::new(s).read_line(&mut line).unwrap();
            assert!(line.contains("200"), "queued request served, got {line}");
        }
        // And the pool serves new traffic again.
        let (status, _) = request(&addr, "GET", "/fast", None).unwrap();
        assert_eq!(status, 200);

        stop.store(true, Ordering::Relaxed);
        server.join().unwrap();
    }

    #[test]
    fn client_extra_headers_reach_the_server() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let handler: Arc<dyn Fn(Request) -> Response + Send + Sync> =
            Arc::new(|req: Request| {
                let key = req.headers.get("x-hpcw-key").cloned().unwrap_or_default();
                Response::json(200, format!("{{\"key\":\"{key}\"}}"))
            });
        let server = std::thread::spawn(move || serve(listener, stop2, handler));
        let (status, _headers, body) =
            request_with_headers(&addr, "GET", "/x", None, &[("X-HPCW-Key", "k-alice")])
                .unwrap();
        assert_eq!(status, 200);
        assert!(String::from_utf8_lossy(&body).contains("k-alice"));
        stop.store(true, Ordering::Relaxed);
        server.join().unwrap();
    }

    #[test]
    fn non_utf8_body_is_reportable() {
        let r = Request {
            method: "POST".into(),
            path: "/x".into(),
            headers: BTreeMap::new(),
            body: vec![0xff, 0xfe, 0x00],
        };
        assert!(r.body_text().is_err());
    }
}
