//! Metrics: counters, gauges, histograms and timelines.
//!
//! Every daemon and engine in the stack reports through a [`Metrics`]
//! registry; benches and the API surface render them. Histograms use
//! power-of-two-ish buckets (HDR-lite) which is plenty for latency
//! distributions at simulation fidelity.

use crate::util::time::Micros;
use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::sync::Mutex;

/// A fixed-bucket latency/size histogram. Buckets are `[2^k, 2^(k+1))` in
/// the recorded unit.
#[derive(Debug, Clone)]
pub struct Histogram {
    counts: Vec<u64>,
    total: u64,
    sum: u128,
    min: u64,
    max: u64,
}

impl Histogram {
    pub fn new() -> Self {
        Histogram {
            counts: vec![0; 64],
            total: 0,
            sum: 0,
            min: u64::MAX,
            max: 0,
        }
    }

    pub fn record(&mut self, v: u64) {
        let bucket = 64 - v.leading_zeros() as usize; // 0 → bucket 0
        let bucket = bucket.min(self.counts.len() - 1);
        self.counts[bucket] += 1;
        self.total += 1;
        self.sum += v as u128;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum as f64 / self.total as f64
        }
    }

    pub fn min(&self) -> u64 {
        if self.total == 0 {
            0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> u64 {
        self.max
    }

    /// Approximate quantile from bucket boundaries (upper bound of the
    /// containing bucket).
    pub fn quantile(&self, q: f64) -> u64 {
        if self.total == 0 {
            return 0;
        }
        let target = (q.clamp(0.0, 1.0) * self.total as f64).ceil() as u64;
        let mut seen = 0;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= target.max(1) {
                return if i == 0 { 1 } else { 1u64 << i };
            }
        }
        self.max
    }
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

/// One timeline event: `(at, component, label)`. The wrapper and the MR
/// engine emit these so tests can assert ordering ("RM up before NMs").
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TimelineEvent {
    pub at: Micros,
    pub component: String,
    pub label: String,
}

/// Thread-safe metrics registry.
#[derive(Debug, Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

#[derive(Debug, Default)]
struct Inner {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
    histograms: BTreeMap<String, Histogram>,
    timeline: Vec<TimelineEvent>,
}

impl Metrics {
    pub fn new() -> Self {
        Metrics::default()
    }

    pub fn inc(&self, name: &str, by: u64) {
        let mut g = self.inner.lock().unwrap();
        *g.counters.entry(name.to_string()).or_insert(0) += by;
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.inner.lock().unwrap().counters.get(name).copied().unwrap_or(0)
    }

    pub fn set_gauge(&self, name: &str, v: f64) {
        self.inner.lock().unwrap().gauges.insert(name.to_string(), v);
    }

    pub fn gauge(&self, name: &str) -> Option<f64> {
        self.inner.lock().unwrap().gauges.get(name).copied()
    }

    pub fn observe(&self, name: &str, v: u64) {
        let mut g = self.inner.lock().unwrap();
        g.histograms.entry(name.to_string()).or_default().record(v);
    }

    pub fn histogram(&self, name: &str) -> Option<Histogram> {
        self.inner.lock().unwrap().histograms.get(name).cloned()
    }

    pub fn event(&self, at: Micros, component: &str, label: &str) {
        self.inner.lock().unwrap().timeline.push(TimelineEvent {
            at,
            component: component.to_string(),
            label: label.to_string(),
        });
    }

    pub fn timeline(&self) -> Vec<TimelineEvent> {
        let mut t = self.inner.lock().unwrap().timeline.clone();
        t.sort_by_key(|e| e.at);
        t
    }

    /// Find the first timeline event whose label contains `needle`.
    pub fn find_event(&self, needle: &str) -> Option<TimelineEvent> {
        self.timeline().into_iter().find(|e| e.label.contains(needle))
    }

    /// Render a flat text report (CLI `hpcw metrics`).
    pub fn render(&self) -> String {
        let g = self.inner.lock().unwrap();
        let mut out = String::new();
        for (k, v) in &g.counters {
            let _ = writeln!(out, "counter {k} = {v}");
        }
        for (k, v) in &g.gauges {
            let _ = writeln!(out, "gauge   {k} = {v}");
        }
        for (k, h) in &g.histograms {
            let _ = writeln!(
                out,
                "hist    {k}: n={} mean={:.1} p50={} p99={} max={}",
                h.count(),
                h.mean(),
                h.quantile(0.5),
                h.quantile(0.99),
                h.max()
            );
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.inc("maps.completed", 3);
        m.inc("maps.completed", 4);
        assert_eq!(m.counter("maps.completed"), 7);
        assert_eq!(m.counter("missing"), 0);
    }

    #[test]
    fn histogram_stats() {
        let mut h = Histogram::new();
        for v in [1u64, 2, 4, 8, 1000] {
            h.record(v);
        }
        assert_eq!(h.count(), 5);
        assert_eq!(h.min(), 1);
        assert_eq!(h.max(), 1000);
        assert!((h.mean() - 203.0).abs() < 1.0);
        assert!(h.quantile(0.5) <= 8);
        assert!(h.quantile(1.0) >= 1000 || h.quantile(1.0) == 1024);
    }

    #[test]
    fn timeline_sorted_by_time() {
        let m = Metrics::new();
        m.event(Micros::secs(5), "rm", "started");
        m.event(Micros::secs(1), "lsf", "dispatched");
        let t = m.timeline();
        assert_eq!(t[0].component, "lsf");
        assert_eq!(t[1].component, "rm");
        assert!(m.find_event("started").is_some());
        assert!(m.find_event("nope").is_none());
    }

    #[test]
    fn render_contains_everything() {
        let m = Metrics::new();
        m.inc("a", 1);
        m.set_gauge("b", 2.5);
        m.observe("c", 10);
        let r = m.render();
        assert!(r.contains("counter a = 1"));
        assert!(r.contains("gauge   b = 2.5"));
        assert!(r.contains("hist    c:"));
    }
}
