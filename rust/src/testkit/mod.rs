//! A small property-based testing kit (proptest is not vendored in this
//! environment). Seeded generators + bounded shrinking, enough for the
//! coordinator invariants DESIGN.md §6 calls for.
//!
//! ```no_run
//! use hpcw::testkit::{props, Gen};
//! props(64, |g| {
//!     let xs = g.vec(0..100, |g| g.u64(0..1000));
//!     let mut sorted = xs.clone();
//!     sorted.sort_unstable();
//!     assert!(sorted.windows(2).all(|w| w[0] <= w[1]));
//! });
//! ```

use crate::util::rng::Rng;
use std::ops::Range;
use std::panic::{catch_unwind, AssertUnwindSafe};

/// Test-case generator handle passed to property closures.
pub struct Gen {
    rng: Rng,
    /// Trace of raw draws, kept so failures can be replayed/reported.
    draws: Vec<u64>,
}

impl Gen {
    fn new(seed: u64) -> Self {
        Gen {
            rng: Rng::new(seed),
            draws: Vec::new(),
        }
    }

    fn draw(&mut self, v: u64) -> u64 {
        self.draws.push(v);
        v
    }

    /// Uniform u64 in range.
    pub fn u64(&mut self, r: Range<u64>) -> u64 {
        assert!(r.end > r.start);
        let v = self.rng.range(r.start, r.end);
        self.draw(v)
    }

    /// Uniform usize in range.
    pub fn usize(&mut self, r: Range<usize>) -> usize {
        self.u64(r.start as u64..r.end as u64) as usize
    }

    /// Uniform u32.
    pub fn u32(&mut self, r: Range<u32>) -> u32 {
        self.u64(r.start as u64..r.end as u64) as u32
    }

    /// f64 in [0,1).
    pub fn unit_f64(&mut self) -> f64 {
        let v = self.rng.f64();
        self.draw((v * 1e9) as u64);
        v
    }

    /// Coin flip with probability `p` of true.
    pub fn chance(&mut self, p: f64) -> bool {
        self.unit_f64() < p
    }

    /// A vector with length drawn from `len`, elements from `f`.
    pub fn vec<T>(&mut self, len: Range<usize>, mut f: impl FnMut(&mut Gen) -> T) -> Vec<T> {
        let n = self.usize(len);
        (0..n).map(|_| f(self)).collect()
    }

    /// Pick one of the provided values.
    pub fn pick<T: Clone>(&mut self, options: &[T]) -> T {
        let i = self.usize(0..options.len());
        options[i].clone()
    }

    /// ASCII identifier of bounded length (queue names, users).
    pub fn ident(&mut self, max_len: usize) -> String {
        let n = self.usize(1..max_len.max(2));
        (0..n)
            .map(|_| (b'a' + self.u32(0..26) as u8) as char)
            .collect()
    }

    /// Underlying RNG access for bulk data.
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

/// Run `prop` for `cases` seeds. On failure, re-runs nearby "smaller" seeds
/// to report the smallest failing case it can find, then panics with the
/// failing seed so the case can be replayed with [`replay`].
pub fn props(cases: u64, prop: impl Fn(&mut Gen) + std::panic::RefUnwindSafe) {
    let base = std::env::var("HPCW_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEEu64);
    for i in 0..cases {
        let seed = base.wrapping_add(i);
        let result = catch_unwind(AssertUnwindSafe(|| {
            let mut g = Gen::new(seed);
            prop(&mut g);
        }));
        if let Err(e) = result {
            // Shrink-lite: deterministically retry with truncating seeds to
            // find a failure with fewer draws; report the best one.
            let mut best_seed = seed;
            let mut best_draws = {
                let mut g = Gen::new(seed);
                let _ = catch_unwind(AssertUnwindSafe(|| prop(&mut g)));
                g.draws.len()
            };
            for shrink in 0..64u64 {
                let s = seed ^ (1u64 << (shrink % 48));
                let mut g = Gen::new(s);
                if catch_unwind(AssertUnwindSafe(|| prop(&mut g))).is_err()
                    && g.draws.len() < best_draws
                {
                    best_seed = s;
                    best_draws = g.draws.len();
                }
            }
            let msg = if let Some(s) = e.downcast_ref::<String>() {
                s.clone()
            } else if let Some(s) = e.downcast_ref::<&str>() {
                s.to_string()
            } else {
                "property failed".to_string()
            };
            panic!(
                "property failed (seed {best_seed}, {best_draws} draws; replay with \
                 HPCW_PROP_SEED={best_seed}): {msg}"
            );
        }
    }
}

/// Replay a single failing seed.
pub fn replay(seed: u64, prop: impl Fn(&mut Gen)) {
    let mut g = Gen::new(seed);
    prop(&mut g);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generators_respect_ranges() {
        props(32, |g| {
            let v = g.u64(10..20);
            assert!((10..20).contains(&v));
            let xs = g.vec(0..5, |g| g.u32(0..3));
            assert!(xs.len() < 5);
            assert!(xs.iter().all(|&x| x < 3));
            let id = g.ident(8);
            assert!(!id.is_empty() && id.len() < 8);
        });
    }

    #[test]
    fn same_seed_same_case() {
        let mut a = Gen::new(99);
        let mut b = Gen::new(99);
        assert_eq!(a.u64(0..1000), b.u64(0..1000));
        assert_eq!(a.ident(10), b.ident(10));
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failures_report_seed() {
        props(8, |g| {
            let v = g.u64(0..100);
            assert!(v < 1, "deliberately failing for v={v}");
        });
    }

    #[test]
    fn pick_and_chance() {
        props(16, |g| {
            let x = g.pick(&[1, 2, 3]);
            assert!((1..=3).contains(&x));
            let _ = g.chance(0.5);
        });
    }
}
