//! The Terasort record format (O'Malley, "TeraByte Sort on Apache Hadoop"
//! [9]): 100-byte records, 10-byte key.
//!
//! Teragen's official generator derives each record deterministically from
//! its row id, so any subset of rows can be generated independently by any
//! map task — we keep that property (key bytes come from a SplitMix64
//! stream seeded by `seed ^ row`), which is what makes re-run map attempts
//! byte-identical and Teravalidate's checksum comparison meaningful.

use crate::util::bytes::Crc32;
use crate::util::rng::splitmix64;

/// Total record length.
pub const RECORD_LEN: usize = 100;
/// Key prefix length.
pub const KEY_LEN: usize = 10;
/// Value length.
pub const VALUE_LEN: usize = RECORD_LEN - KEY_LEN;

/// Generate the 10-byte key of row `row` under `seed`.
pub fn key_for_row(seed: u64, row: u64) -> [u8; KEY_LEN] {
    let mut state = seed ^ row.wrapping_mul(0xD1B5_4A32_D192_ED03);
    let a = splitmix64(&mut state);
    let b = splitmix64(&mut state);
    let mut key = [0u8; KEY_LEN];
    key[..8].copy_from_slice(&a.to_be_bytes());
    key[8..].copy_from_slice(&b.to_be_bytes()[..2]);
    key
}

/// Generate the 90-byte value of row `row`: the row id in ASCII (matching
/// teragen's human-inspectable layout) plus a deterministic filler.
pub fn value_for_row(row: u64) -> [u8; VALUE_LEN] {
    let mut v = [b'.'; VALUE_LEN];
    let id = format!("{row:020}");
    v[..20].copy_from_slice(id.as_bytes());
    // Filler pattern: repeating A-Z block keyed by the row (teragen uses a
    // similar alphabetic filler).
    let c = b'A' + (row % 26) as u8;
    for x in v[20..].iter_mut() {
        *x = c;
    }
    v
}

/// Full record for a row.
pub fn record_for_row(seed: u64, row: u64) -> [u8; RECORD_LEN] {
    let mut rec = [0u8; RECORD_LEN];
    rec[..KEY_LEN].copy_from_slice(&key_for_row(seed, row));
    rec[KEY_LEN..].copy_from_slice(&value_for_row(row));
    rec
}

/// First 8 bytes of a key as a big-endian u64 — the prefix the range
/// partitioner, the flat-record sort and the Pallas kernel operate on.
/// Keys shorter than 8 bytes are zero-padded on the right, which can only
/// *equate* two keys the byte order distinguishes, never invert them —
/// callers that need a total order resolve equal prefixes on the full key.
#[inline]
pub fn key_prefix_u64(key: &[u8]) -> u64 {
    if key.len() >= 8 {
        u64::from_be_bytes(key[..8].try_into().unwrap())
    } else {
        let mut buf = [0u8; 8];
        buf[..key.len()].copy_from_slice(key);
        u64::from_be_bytes(buf)
    }
}

/// Split a 100-byte record into its `(key, value)` slices — the flat-path
/// view of the fixed 10/90 layout.
#[inline]
pub fn split_record(record: &[u8]) -> (&[u8], &[u8]) {
    debug_assert_eq!(record.len(), RECORD_LEN);
    record.split_at(KEY_LEN)
}

/// Checksum of one record, accumulated Teravalidate-style: CRC32 widened
/// to u64 and wrapping-summed over all records (order independent).
pub fn record_checksum(record: &[u8]) -> u64 {
    Crc32::of(record) as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_are_deterministic_in_row() {
        assert_eq!(record_for_row(42, 7), record_for_row(42, 7));
        assert_ne!(record_for_row(42, 7), record_for_row(42, 8));
        assert_ne!(record_for_row(42, 7), record_for_row(43, 7));
    }

    #[test]
    fn sizes_are_official() {
        let r = record_for_row(1, 1);
        assert_eq!(r.len(), 100);
        assert_eq!(key_for_row(1, 1).len(), 10);
        assert_eq!(value_for_row(1).len(), 90);
    }

    #[test]
    fn value_carries_row_id() {
        let v = value_for_row(12345);
        assert_eq!(&v[..20], b"00000000000000012345");
    }

    #[test]
    fn key_prefix_preserves_order() {
        // Byte-order comparison of keys == numeric comparison of prefixes
        // whenever prefixes differ (big-endian).
        let a = key_for_row(9, 100);
        let b = key_for_row(9, 200);
        let cmp_bytes = a.cmp(&b);
        let cmp_prefix = key_prefix_u64(&a).cmp(&key_prefix_u64(&b));
        if key_prefix_u64(&a) != key_prefix_u64(&b) {
            assert_eq!(cmp_bytes, cmp_prefix);
        }
    }

    #[test]
    fn keys_are_spread() {
        // Rough uniformity: bucket the top byte of 10k keys.
        let mut buckets = [0u32; 16];
        for row in 0..10_000u64 {
            let k = key_for_row(5, row);
            buckets[(k[0] >> 4) as usize] += 1;
        }
        for (i, &b) in buckets.iter().enumerate() {
            assert!((400..900).contains(&b), "bucket {i} = {b}");
        }
    }
}
