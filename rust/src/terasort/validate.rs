//! Teravalidate: prove the output of Terasort is a permutation of the
//! input and globally sorted.
//!
//! Checks, per the Hadoop validator:
//! 1. within every part file, keys are non-decreasing;
//! 2. across part files (in name order), the first key of part *i+1* is
//!    `>=` the last key of part *i*;
//! 3. record count matches;
//! 4. an order-independent checksum (wrapping sum of per-record CRC32s)
//!    matches the input's.

use crate::error::{Error, Result};
use crate::lustre::Dfs;
use crate::terasort::format::{record_checksum, KEY_LEN, RECORD_LEN};

/// Aggregate of one directory scan.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DirSummary {
    pub records: u64,
    pub checksum: u64,
}

/// Scan a Terasort data directory (input or output): count + checksum.
pub fn summarize_dir(dfs: &dyn Dfs, dir: &str) -> Result<DirSummary> {
    let mut records = 0u64;
    let mut checksum = 0u64;
    for f in part_files(dfs, dir)? {
        let buf = dfs.read(&f)?;
        if buf.len() % RECORD_LEN != 0 {
            return Err(Error::MapReduce(format!("{f}: not record aligned")));
        }
        for rec in buf.chunks_exact(RECORD_LEN) {
            records += 1;
            checksum = checksum.wrapping_add(record_checksum(rec));
        }
    }
    Ok(DirSummary { records, checksum })
}

/// Full validation of `output_dir` against the input's summary.
pub fn teravalidate(dfs: &dyn Dfs, output_dir: &str, input: DirSummary) -> Result<DirSummary> {
    let files = part_files(dfs, output_dir)?;
    let mut records = 0u64;
    let mut checksum = 0u64;
    let mut prev_last: Option<Vec<u8>> = None;
    for f in &files {
        let buf = dfs.read(f)?;
        if buf.len() % RECORD_LEN != 0 {
            return Err(Error::MapReduce(format!("{f}: not record aligned")));
        }
        let mut prev: Option<&[u8]> = None;
        for rec in buf.chunks_exact(RECORD_LEN) {
            let key = &rec[..KEY_LEN];
            if let Some(p) = prev {
                if p > key {
                    return Err(Error::MapReduce(format!("{f}: keys out of order")));
                }
            }
            // Cross-file boundary: first key of this file vs last of prev.
            if prev.is_none() {
                if let Some(pl) = &prev_last {
                    if pl.as_slice() > key {
                        return Err(Error::MapReduce(format!(
                            "{f}: first key below previous part's last key"
                        )));
                    }
                }
            }
            prev = Some(key);
            records += 1;
            checksum = checksum.wrapping_add(record_checksum(rec));
        }
        if let Some(p) = prev {
            prev_last = Some(p.to_vec());
        }
    }
    if records != input.records {
        return Err(Error::MapReduce(format!(
            "record count {} != input {}",
            records, input.records
        )));
    }
    if checksum != input.checksum {
        return Err(Error::MapReduce(format!(
            "checksum {checksum:#x} != input {:#x}",
            input.checksum
        )));
    }
    Ok(DirSummary { records, checksum })
}

fn part_files(dfs: &dyn Dfs, dir: &str) -> Result<Vec<String>> {
    let mut files: Vec<String> = dfs
        .list(dir)
        .into_iter()
        .filter(|p| p.contains("/part-"))
        .collect();
    files.sort();
    if files.is_empty() {
        return Err(Error::MapReduce(format!("no parts under {dir}")));
    }
    Ok(files)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::StackConfig;
    use crate::lustre::LustreFs;
    use crate::terasort::format::record_for_row;

    fn fs() -> LustreFs {
        let c = StackConfig::paper();
        LustreFs::new(&c.lustre, &c.cluster)
    }

    fn write_parts(fs: &LustreFs, dir: &str, rows_per_part: &[Vec<u64>], sort: bool) -> DirSummary {
        fs.mkdirs(dir).unwrap();
        let mut records = 0;
        let mut checksum = 0u64;
        for (i, rows) in rows_per_part.iter().enumerate() {
            let mut recs: Vec<[u8; 100]> = rows.iter().map(|&r| record_for_row(1, r)).collect();
            if sort {
                recs.sort();
            }
            let mut buf = Vec::new();
            for r in &recs {
                records += 1;
                checksum = checksum.wrapping_add(record_checksum(r));
                buf.extend_from_slice(r);
            }
            fs.create(&format!("{dir}/part-r-{i:05}"), &buf).unwrap();
        }
        DirSummary { records, checksum }
    }

    #[test]
    fn valid_sorted_output_passes() {
        let fs = fs();
        // Craft two parts whose key ranges don't overlap: route by key.
        let all: Vec<u64> = (0..200).collect();
        let mut keyed: Vec<(Vec<u8>, u64)> = all
            .iter()
            .map(|&r| (record_for_row(1, r)[..10].to_vec(), r))
            .collect();
        keyed.sort();
        let lo: Vec<u64> = keyed[..100].iter().map(|(_, r)| *r).collect();
        let hi: Vec<u64> = keyed[100..].iter().map(|(_, r)| *r).collect();
        let summary = write_parts(&fs, "/lustre/scratch/tv-ok", &[lo, hi], true);
        let out = teravalidate(&fs, "/lustre/scratch/tv-ok", summary).unwrap();
        assert_eq!(out.records, 200);
    }

    #[test]
    fn unsorted_part_fails() {
        let fs = fs();
        let summary = write_parts(&fs, "/lustre/scratch/tv-bad", &[vec![5, 3, 9]], false);
        let err = teravalidate(&fs, "/lustre/scratch/tv-bad", summary);
        // Either sorted-within fails, or if rows happen sorted the test is
        // vacuous — force the known-unsorted case:
        if err.is_ok() {
            // keys of rows 5,3,9 happened to be ordered; craft a reversal.
            let fs2 = self::fs();
            let r0 = record_for_row(1, 0);
            let r1 = record_for_row(1, 1);
            let (big, small) = if r0[..10] > r1[..10] { (r0, r1) } else { (r1, r0) };
            fs2.mkdirs("/lustre/scratch/tv-bad2").unwrap();
            let mut buf = Vec::new();
            buf.extend_from_slice(&big);
            buf.extend_from_slice(&small);
            fs2.create("/lustre/scratch/tv-bad2/part-r-00000", &buf).unwrap();
            let s = DirSummary {
                records: 2,
                checksum: record_checksum(&big).wrapping_add(record_checksum(&small)),
            };
            assert!(teravalidate(&fs2, "/lustre/scratch/tv-bad2", s).is_err());
        }
    }

    #[test]
    fn cross_part_boundary_violation_fails() {
        let fs = fs();
        let r0 = record_for_row(1, 0);
        let r1 = record_for_row(1, 1);
        let (big, small) = if r0[..10] > r1[..10] { (r0, r1) } else { (r1, r0) };
        fs.mkdirs("/lustre/scratch/tv-x").unwrap();
        fs.create("/lustre/scratch/tv-x/part-r-00000", &big).unwrap();
        fs.create("/lustre/scratch/tv-x/part-r-00001", &small).unwrap();
        let s = DirSummary {
            records: 2,
            checksum: record_checksum(&big).wrapping_add(record_checksum(&small)),
        };
        assert!(teravalidate(&fs, "/lustre/scratch/tv-x", s).is_err());
    }

    #[test]
    fn count_and_checksum_mismatches_fail() {
        let fs = fs();
        let summary = write_parts(&fs, "/lustre/scratch/tv-c", &[vec![1, 2, 3]], true);
        let short = DirSummary {
            records: summary.records + 1,
            checksum: summary.checksum,
        };
        assert!(teravalidate(&fs, "/lustre/scratch/tv-c", short).is_err());
        let wrong = DirSummary {
            records: summary.records,
            checksum: summary.checksum ^ 1,
        };
        assert!(teravalidate(&fs, "/lustre/scratch/tv-c", wrong).is_err());
    }

    #[test]
    fn summarize_matches_write() {
        let fs = fs();
        let summary = write_parts(&fs, "/lustre/scratch/tv-s", &[vec![7, 8], vec![9]], true);
        let scanned = summarize_dir(&fs, "/lustre/scratch/tv-s").unwrap();
        assert_eq!(scanned, summary);
    }
}
