//! Total-order range partitioning for Terasort.
//!
//! Hadoop's TeraSort samples the input, computes `R-1` splitter keys, and
//! routes each record to the partition whose range contains its key — that
//! is what makes concatenated reduce outputs globally sorted. We partition
//! on the 8-byte big-endian key prefix (ties below the prefix resolution
//! land in the same partition, preserving correctness).
//!
//! Two interchangeable implementations of the routing hot-spot exist:
//! this pure-Rust binary search, and the AOT-compiled Pallas kernel loaded
//! through [`crate::runtime`] (see `python/compile/kernels/partition.py`).
//! They are parity-tested against each other.

use crate::error::{Error, Result};
use crate::lustre::Dfs;
use crate::mapreduce::Partitioner;
use crate::terasort::format::{key_prefix_u64, KEY_LEN, RECORD_LEN};

/// Range partitioner over u64 key prefixes.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RangePartitioner {
    /// `n_partitions - 1` sorted boundaries; partition i takes keys in
    /// `[splitters[i-1], splitters[i])`.
    pub splitters: Vec<u64>,
}

impl RangePartitioner {
    /// Build from sampled key prefixes: sort and take R-1 quantiles.
    pub fn from_samples(mut samples: Vec<u64>, n_partitions: u32) -> Result<RangePartitioner> {
        if n_partitions == 0 {
            return Err(Error::MapReduce("0 partitions".into()));
        }
        if samples.is_empty() && n_partitions > 1 {
            return Err(Error::MapReduce("no samples for the partitioner".into()));
        }
        samples.sort_unstable();
        let r = n_partitions as usize;
        let mut splitters = Vec::with_capacity(r - 1);
        for i in 1..r {
            let idx = i * samples.len() / r;
            splitters.push(samples[idx.min(samples.len() - 1)]);
        }
        splitters.dedup();
        Ok(RangePartitioner { splitters })
    }

    /// Number of partitions this router produces.
    pub fn n_partitions(&self) -> u32 {
        self.splitters.len() as u32 + 1
    }

    /// Route one prefix: index of the first splitter greater than the key
    /// (upper-bound binary search).
    #[inline]
    pub fn route(&self, prefix: u64) -> u32 {
        self.splitters.partition_point(|&s| s <= prefix) as u32
    }

    /// Route an arbitrary byte key by its 8-byte big-endian prefix — the
    /// entry point the query engine's ORDER BY stage shares with
    /// Terasort (samples and keys are both reduced to prefixes, so ties
    /// below prefix resolution land in the same partition and the
    /// in-partition sort finishes the order).
    #[inline]
    pub fn route_key(&self, key: &[u8]) -> u32 {
        self.route(key_prefix_u64(key))
    }

    /// Sequential router for a key stream already sorted by prefix:
    /// amortizes the per-key binary search to O(n + splitters) for a whole
    /// sorted run (the block-processor hot path).
    pub fn router(&self) -> MonotoneRouter<'_> {
        MonotoneRouter {
            splitters: &self.splitters,
            next: 0,
        }
    }
}

/// Cursor over the splitter array; feed it non-decreasing prefixes.
#[derive(Debug)]
pub struct MonotoneRouter<'a> {
    splitters: &'a [u64],
    next: usize,
}

impl MonotoneRouter<'_> {
    /// Partition of `prefix`. Equivalent to [`RangePartitioner::route`]
    /// when prefixes arrive in non-decreasing order.
    #[inline]
    pub fn route(&mut self, prefix: u64) -> u32 {
        while self.next < self.splitters.len() && self.splitters[self.next] <= prefix {
            self.next += 1;
        }
        self.next as u32
    }
}

impl Partitioner for RangePartitioner {
    fn partition(&self, key: &[u8], n_reduces: u32) -> u32 {
        self.route_key(key).min(n_reduces.saturating_sub(1))
    }
}

/// Sample key prefixes from a Terasort input directory: reads up to
/// `per_file` records from the head of each input part (Hadoop's sampler
/// reads from a handful of splits; input keys are uniform so head-sampling
/// is unbiased here by construction).
pub fn sample_input(dfs: &dyn Dfs, input_dir: &str, per_file: u64) -> Result<Vec<u64>> {
    let mut samples = Vec::new();
    let mut files: Vec<String> = dfs
        .list(input_dir)
        .into_iter()
        .filter(|p| p.contains("/part-"))
        .collect();
    files.sort();
    if files.is_empty() {
        return Err(Error::MapReduce(format!("no parts under {input_dir}")));
    }
    for f in &files {
        let take = per_file * RECORD_LEN as u64;
        let buf = dfs.read_range(f, 0, take)?;
        for rec in buf.chunks_exact(RECORD_LEN) {
            samples.push(key_prefix_u64(&rec[..KEY_LEN]));
        }
    }
    Ok(samples)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::props;

    #[test]
    fn route_respects_ranges() {
        let p = RangePartitioner {
            splitters: vec![100, 200, 300],
        };
        assert_eq!(p.n_partitions(), 4);
        assert_eq!(p.route(0), 0);
        assert_eq!(p.route(99), 0);
        assert_eq!(p.route(100), 1); // boundary goes right
        assert_eq!(p.route(250), 2);
        assert_eq!(p.route(300), 3);
        assert_eq!(p.route(u64::MAX), 3);
    }

    #[test]
    fn from_samples_balances() {
        // Uniform samples → roughly equal-width ranges.
        let samples: Vec<u64> = (0..10_000).map(|i| i * 1000).collect();
        let p = RangePartitioner::from_samples(samples, 10).unwrap();
        assert_eq!(p.n_partitions(), 10);
        // Route a fresh uniform stream; counts should be near 1/10 each.
        let mut counts = vec![0u32; 10];
        for i in 0..10_000u64 {
            counts[p.route(i * 999 + 7) as usize] += 1;
        }
        for (i, &c) in counts.iter().enumerate() {
            assert!((700..1300).contains(&c), "partition {i}: {c}");
        }
    }

    #[test]
    fn routing_is_monotone_property() {
        props(40, |g| {
            let samples: Vec<u64> = (0..g.usize(10..200)).map(|_| g.u64(0..1_000_000)).collect();
            let parts = g.u32(1..32);
            let p = RangePartitioner::from_samples(samples, parts).unwrap();
            let mut a = g.u64(0..1_000_000);
            let mut b = g.u64(0..1_000_000);
            if a > b {
                std::mem::swap(&mut a, &mut b);
            }
            assert!(p.route(a) <= p.route(b), "monotone routing");
            assert!(p.route(b) < p.n_partitions());
        });
    }

    #[test]
    fn monotone_router_matches_binary_search() {
        props(40, |g| {
            let samples: Vec<u64> = (0..g.usize(2..200)).map(|_| g.u64(0..1 << 40)).collect();
            let parts = g.u32(1..32);
            let p = RangePartitioner::from_samples(samples, parts).unwrap();
            let mut keys: Vec<u64> = (0..200).map(|_| g.u64(0..1 << 40)).collect();
            keys.sort_unstable();
            let mut router = p.router();
            for &k in &keys {
                assert_eq!(router.route(k), p.route(k), "key {k}");
            }
        });
    }

    #[test]
    fn degenerate_cases() {
        // Single partition needs no samples.
        let p = RangePartitioner::from_samples(vec![], 1).unwrap();
        assert_eq!(p.route(123), 0);
        // All-equal samples dedup to fewer partitions but stay valid.
        let p = RangePartitioner::from_samples(vec![5; 100], 4).unwrap();
        assert!(p.n_partitions() <= 2);
        assert!(RangePartitioner::from_samples(vec![], 4).is_err());
        assert!(RangePartitioner::from_samples(vec![1], 0).is_err());
    }
}
