//! Terasort: the paper's benchmark (§VI–VII). "Terasort provides the
//! opportunity to analyze the behavior of the cluster when subjected to
//! sorting one Terabyte of data... divided into three stages, (i) Teragen,
//! (ii) Terasort and (iii) Teravalidate."
//!
//! Real mode runs all three stages through the live MR engine on a live
//! wrapper-built cluster; Sim mode regenerates Figs 4 and 5 through the
//! same phase structure at 1 TB scale.

pub mod format;
pub mod partition;
pub mod validate;

pub use format::{key_for_row, key_prefix_u64, record_for_row, KEY_LEN, RECORD_LEN, VALUE_LEN};
pub use partition::{sample_input, RangePartitioner};
pub use validate::{summarize_dir, teravalidate, DirSummary};

use crate::error::Result;
use crate::mapreduce::{InputFormat, JobSpec, Mapper, MrEngine, MrOutcome, OutputFormat};
use crate::util::time::Micros;
use std::sync::Arc;

/// Teragen parameters.
#[derive(Debug, Clone)]
pub struct TeragenSpec {
    pub rows: u64,
    pub maps: u64,
    pub output_dir: String,
    /// Deterministic data seed (keys derive from `seed ^ row`).
    pub seed: u64,
}

/// The Teragen mapper: synthesizes the official record for each row id.
pub struct TeragenMapper {
    pub seed: u64,
}

impl Mapper for TeragenMapper {
    fn map(&self, key: &[u8], _value: &[u8], emit: &mut dyn FnMut(&[u8], &[u8])) {
        let row = u64::from_be_bytes(key.try_into().expect("row id key"));
        let rec = record_for_row(self.seed, row);
        let (k, v) = format::split_record(&rec);
        emit(k, v);
    }
}

/// Run Teragen (map-only job) on a live engine.
pub fn run_teragen(
    engine: &mut MrEngine<'_>,
    spec: &TeragenSpec,
    now: Micros,
) -> Result<MrOutcome> {
    let mut job = JobSpec::identity("teragen", "", &spec.output_dir, 0);
    job.input_format = InputFormat::RowRange;
    job.output_format = OutputFormat::TeraRecords;
    job.synthetic_rows = Some((spec.rows, spec.maps));
    job.mapper = Arc::new(TeragenMapper { seed: spec.seed });
    engine.run(Arc::new(job), "hpcw", now)
}

/// Terasort parameters.
#[derive(Debug, Clone)]
pub struct TerasortJob {
    pub input_dir: String,
    pub output_dir: String,
    pub reduces: u32,
    /// Samples per input part for the range partitioner.
    pub samples_per_file: u64,
    pub split_bytes: u64,
}

impl TerasortJob {
    pub fn new(input_dir: &str, output_dir: &str, reduces: u32) -> TerasortJob {
        TerasortJob {
            input_dir: input_dir.to_string(),
            output_dir: output_dir.to_string(),
            reduces,
            samples_per_file: 1000,
            split_bytes: 64 * 1024 * 1024,
        }
    }
}

/// Run Terasort with a partitioner built by sampling the input.
/// `partitioner` may be injected (e.g. the PJRT kernel path); when `None`
/// the pure-Rust [`RangePartitioner`] is sampled here.
pub fn run_terasort(
    engine: &mut MrEngine<'_>,
    ts: &TerasortJob,
    partitioner: Option<Arc<dyn crate::mapreduce::Partitioner>>,
    now: Micros,
) -> Result<MrOutcome> {
    let partitioner = match partitioner {
        Some(p) => p,
        None => {
            let samples = sample_input(&*engine.dfs, &ts.input_dir, ts.samples_per_file)?;
            Arc::new(RangePartitioner::from_samples(samples, ts.reduces)?)
                as Arc<dyn crate::mapreduce::Partitioner>
        }
    };
    let mut job = JobSpec::identity("terasort", &ts.input_dir, &ts.output_dir, ts.reduces);
    job.input_format = InputFormat::TeraRecords;
    job.output_format = OutputFormat::TeraRecords;
    job.split_bytes = ts.split_bytes;
    job.partitioner = partitioner;
    engine.run(Arc::new(job), "hpcw", now)
}

/// Run Terasort through a whole-block map path (the PJRT Pallas kernel or
/// the pure-Rust block processor) — the paper's hot path, accelerated.
pub fn run_terasort_with_processor(
    engine: &mut MrEngine<'_>,
    ts: &TerasortJob,
    processor: Arc<dyn crate::mapreduce::BlockProcessor>,
    now: Micros,
) -> Result<MrOutcome> {
    let mut job = JobSpec::identity("terasort", &ts.input_dir, &ts.output_dir, ts.reduces);
    job.input_format = InputFormat::TeraRecords;
    job.output_format = OutputFormat::TeraRecords;
    job.split_bytes = ts.split_bytes;
    job.block_processor = Some(processor);
    engine.run(Arc::new(job), "hpcw", now)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::NodeId;
    use crate::config::StackConfig;
    use crate::lustre::{Dfs as _, LustreFs};
    use crate::metrics::Metrics;
    use crate::util::ids::IdGen;
    use crate::util::pool::Pool;
    use crate::wrapper::DynamicCluster;

    fn stack() -> (StackConfig, Arc<LustreFs>, DynamicCluster, Pool) {
        let cfg = StackConfig::tiny();
        let fs = Arc::new(LustreFs::new(&cfg.lustre, &cfg.cluster));
        let nodes: Vec<NodeId> = (0..6).map(NodeId).collect();
        let dc = DynamicCluster::build(
            &cfg,
            &nodes,
            &*fs,
            Arc::new(IdGen::default()),
            Arc::new(Metrics::new()),
            "ts-test",
            Micros::ZERO,
        )
        .unwrap();
        (cfg, fs, dc, Pool::new(4))
    }

    /// The miniature end-to-end: teragen → terasort → teravalidate.
    #[test]
    fn terasort_pipeline_validates() {
        let (cfg, fs, mut dc, pool) = stack();
        let gen = TeragenSpec {
            rows: 5_000,
            maps: 4,
            output_dir: "/lustre/scratch/tera-in".into(),
            seed: 42,
        };
        {
            let mut engine = MrEngine::new(
                &mut dc,
                fs.clone(),
                &pool,
                cfg.yarn.map_memory_mb,
                cfg.yarn.reduce_memory_mb,
            );
            let out = run_teragen(&mut engine, &gen, Micros::ZERO).unwrap();
            assert_eq!(out.maps, 4);
            assert_eq!(out.reduces, 0);
        }
        let input = summarize_dir(&*fs, "/lustre/scratch/tera-in").unwrap();
        assert_eq!(input.records, 5_000);

        {
            let mut engine = MrEngine::new(
                &mut dc,
                fs.clone(),
                &pool,
                cfg.yarn.map_memory_mb,
                cfg.yarn.reduce_memory_mb,
            );
            let ts = TerasortJob {
                split_bytes: 50_000, // force multiple maps
                ..TerasortJob::new("/lustre/scratch/tera-in", "/lustre/scratch/tera-out", 5)
            };
            let out = run_terasort(&mut engine, &ts, None, Micros::secs(60)).unwrap();
            assert!(out.maps > 1);
            assert_eq!(out.reduces, 5);
        }

        let validated = teravalidate(&*fs, "/lustre/scratch/tera-out", input).unwrap();
        assert_eq!(validated.records, 5_000);
        // Both stages recorded in history.
        assert_eq!(dc.jhs.count(), 2);
    }

    #[test]
    fn teragen_bytes_match_rows() {
        let (cfg, fs, mut dc, pool) = stack();
        let gen = TeragenSpec {
            rows: 1_234,
            maps: 3,
            output_dir: "/lustre/scratch/tg".into(),
            seed: 7,
        };
        let mut engine = MrEngine::new(
            &mut dc,
            fs.clone(),
            &pool,
            cfg.yarn.map_memory_mb,
            cfg.yarn.reduce_memory_mb,
        );
        run_teragen(&mut engine, &gen, Micros::ZERO).unwrap();
        let total: u64 = fs
            .list("/lustre/scratch/tg")
            .iter()
            .filter(|p| p.contains("/part-"))
            .map(|p| fs.size(p).unwrap())
            .sum();
        assert_eq!(total, 1_234 * RECORD_LEN as u64);
    }

    #[test]
    fn terasort_with_failure_injection_still_validates() {
        use crate::mapreduce::{FailurePlan, TaskId};
        let (cfg, fs, mut dc, pool) = stack();
        let gen = TeragenSpec {
            rows: 2_000,
            maps: 2,
            output_dir: "/lustre/scratch/tf-in".into(),
            seed: 3,
        };
        {
            let mut engine = MrEngine::new(
                &mut dc,
                fs.clone(),
                &pool,
                cfg.yarn.map_memory_mb,
                cfg.yarn.reduce_memory_mb,
            );
            run_teragen(&mut engine, &gen, Micros::ZERO).unwrap();
        }
        let input = summarize_dir(&*fs, "/lustre/scratch/tf-in").unwrap();
        {
            let samples = sample_input(&*fs, "/lustre/scratch/tf-in", 500).unwrap();
            let part = RangePartitioner::from_samples(samples, 3).unwrap();
            let mut job = JobSpec::identity(
                "terasort",
                "/lustre/scratch/tf-in",
                "/lustre/scratch/tf-out",
                3,
            );
            job.split_bytes = 60_000;
            job.partitioner = Arc::new(part);
            job.failures = FailurePlan::none()
                .fail_attempt(TaskId::map(1), 0)
                .fail_attempt(TaskId::reduce(0), 0);
            let mut engine = MrEngine::new(
                &mut dc,
                fs.clone(),
                &pool,
                cfg.yarn.map_memory_mb,
                cfg.yarn.reduce_memory_mb,
            );
            engine.run(Arc::new(job), "hpcw", Micros::ZERO).unwrap();
        }
        teravalidate(&*fs, "/lustre/scratch/tf-out", input).unwrap();
    }
}
