//! The HPC Wales wrapper — the paper's contribution (§III step 4, §V).
//!
//! "The dynamic cluster configuration then kicks in, driven by a custom
//! wrapper script that performs the Hadoop cluster creation: daemon
//! initiation, directory structure creation and the environment setup. The
//! user application is then submitted into this cluster. ... This
//! infrastructure is torn down after the job completes."
//!
//! Layout (§V, Fig 2): the Resource Manager starts on the **first** node of
//! the LSF allocation, the Job History Server on the **second**, and every
//! remaining node becomes a slave running a NodeManager.
//!
//! Two faces of the same logic:
//! * [`DynamicCluster`] (this file) — Real mode: actually constructs the
//!   RM / NM / JHS state machines, creates the directory trees, hands the
//!   caller a live cluster, and tears it down afterwards, verifying the
//!   environment is returned clean.
//! * [`sim::simulate_wrapper`] — Sim mode: the calibrated timing model of
//!   the identical sequence of steps, which regenerates Fig 3.

pub mod env;
pub mod sim;

pub use env::ClusterEnv;
pub use sim::{simulate_wrapper, WrapperPhases};

use crate::cluster::NodeId;
use crate::config::StackConfig;
use crate::error::{Error, Result};
use crate::lustre::Dfs;
use crate::metrics::Metrics;
use crate::util::ids::IdGen;
use crate::util::time::Micros;
use crate::yarn::{JobHistoryServer, NodeManager, ResourceManager};
use std::collections::BTreeMap;
use std::sync::Arc;

/// A live dynamically-constructed YARN cluster inside an LSF allocation.
pub struct DynamicCluster {
    pub rm: ResourceManager,
    pub jhs: JobHistoryServer,
    pub nms: BTreeMap<NodeId, NodeManager>,
    pub rm_node: NodeId,
    pub jhs_node: NodeId,
    pub slaves: Vec<NodeId>,
    pub env: ClusterEnv,
    metrics: Arc<Metrics>,
    torn_down: bool,
}

impl DynamicCluster {
    /// Build the cluster on an LSF allocation (wrapper step 4).
    ///
    /// `nodes` is the allocation in LSF order; needs at least 3 nodes
    /// (RM, JHS, ≥1 slave). `job_tag` isolates this job's staging area.
    pub fn build(
        cfg: &StackConfig,
        nodes: &[NodeId],
        dfs: &dyn Dfs,
        ids: Arc<IdGen>,
        metrics: Arc<Metrics>,
        job_tag: &str,
        now: Micros,
    ) -> Result<DynamicCluster> {
        if nodes.len() < 3 {
            return Err(Error::Wrapper(format!(
                "allocation of {} nodes: need >= 3 (RM, JHS, >=1 slave)",
                nodes.len()
            )));
        }
        let rm_node = nodes[0];
        let jhs_node = nodes[1];
        let slaves: Vec<NodeId> = nodes[2..].to_vec();

        // 1. Environment setup + staging directories on Lustre.
        let env = ClusterEnv::new(cfg, job_tag, rm_node, jhs_node);
        env.create_shared_dirs(dfs)?;
        metrics.event(now, "wrapper", "staging dirs created");

        // 2. Resource Manager on the first node.
        let mut rm = ResourceManager::new(cfg.yarn.clone(), ids, Arc::clone(&metrics));
        rm.set_rack_width(cfg.elastic.rack_width);
        // Heterogeneous node profiles (HPCW_NODE_MIPS / scenario machine
        // classes) go into the RM registry up front: the registry outlives
        // node churn, so slaves admitted mid-job (elastic grow) resolve
        // their MIPS tier too.
        for &(id, mips) in &cfg.elastic.node_mips {
            rm.set_node_mips(NodeId(id), mips);
        }
        if cfg.tenant.enabled() {
            // Multi-tenant front door is on: arbitrate cross-app asks by
            // dominant resource fairness and let over-share apps lose
            // their youngest containers to starved ones.
            rm.set_queue_policy(Box::new(crate::yarn::rm::DrfPolicy));
            rm.set_preemption(cfg.tenant.preemption);
        }
        metrics.event(now, "wrapper", &format!("RM started on {rm_node}"));

        // 3. Job History Server on the second node.
        let mut jhs = JobHistoryServer::new(&env.history_done_dir);
        jhs.start(dfs)?;
        metrics.event(now, "wrapper", &format!("JHS started on {jhs_node}"));

        // 4. Slaves: local dirs, NM daemon, registration with the RM.
        let mut nms = BTreeMap::new();
        for &s in &slaves {
            let mut nm = NodeManager::new(s);
            nm.setup_dirs()
                .map_err(|e| Error::Wrapper(format!("dir setup on {s}: {e}")))?;
            nm.start(now)
                .map_err(|e| Error::Wrapper(format!("NM start on {s}: {e}")))?;
            rm.register_nm(s, now)
                .map_err(|e| Error::Wrapper(format!("NM register {s}: {e}")))?;
            nms.insert(s, nm);
        }
        metrics.event(now, "wrapper", &format!("{} NMs up", slaves.len()));
        metrics.inc("wrapper.clusters_built", 1);

        Ok(DynamicCluster {
            rm,
            jhs,
            nms,
            rm_node,
            jhs_node,
            slaves,
            env,
            metrics,
            torn_down: false,
        })
    }

    /// Abort half-way through a failed build: release whatever exists.
    /// (Build is transactional from the caller's perspective: on error the
    /// LSF job exits and the allocation is released; staging dirs are
    /// removed here.)
    pub fn abort_build(cfg: &StackConfig, dfs: &dyn Dfs, job_tag: &str) -> Result<()> {
        let env = ClusterEnv::new(cfg, job_tag, NodeId(0), NodeId(0));
        let _ = dfs.delete_recursive(&env.staging_root);
        Ok(())
    }

    /// Number of slave nodes.
    pub fn slave_count(&self) -> usize {
        self.slaves.len()
    }

    /// Admit a new slave mid-job (elastic grow): create its local dirs,
    /// start the NM daemon and register it with the live RM — the same
    /// three wrapper steps the initial build performs per slave.
    pub fn admit_node(&mut self, node: NodeId, now: Micros) -> Result<()> {
        if self.nms.contains_key(&node) {
            return Err(Error::Wrapper(format!("node {node} already admitted")));
        }
        let mut nm = NodeManager::new(node);
        nm.setup_dirs()
            .map_err(|e| Error::Wrapper(format!("dir setup on {node}: {e}")))?;
        nm.start(now)
            .map_err(|e| Error::Wrapper(format!("NM start on {node}: {e}")))?;
        self.rm
            .register_nm(node, now)
            .map_err(|e| Error::Wrapper(format!("NM register {node}: {e}")))?;
        self.nms.insert(node, nm);
        self.slaves.push(node);
        self.metrics.inc("wrapper.nodes_joined", 1);
        self.metrics.event(now, "wrapper", &format!("node {node} joined"));
        Ok(())
    }

    /// Gracefully decommission a slave (elastic shrink / lease expiry):
    /// refuses while the RM still tracks containers there, then stops the
    /// NM, cleans its workspace and removes it from the cluster.
    pub fn decommission_node(&mut self, node: NodeId, now: Micros) -> Result<()> {
        self.rm.decommission_nm(node)?;
        if let Some(mut nm) = self.nms.remove(&node) {
            nm.stop_and_clean()
                .map_err(|e| Error::Wrapper(format!("NM {node} drain: {e}")))?;
        }
        self.slaves.retain(|&s| s != node);
        self.metrics.inc("wrapper.nodes_drained", 1);
        self.metrics.event(now, "wrapper", &format!("node {node} drained"));
        Ok(())
    }

    /// Crash a slave: the NM vanishes without cleanup (node is gone), the
    /// RM drops it and reports the containers lost with it.
    pub fn fail_node(&mut self, node: NodeId, now: Micros) -> Vec<crate::yarn::Container> {
        let lost = self.rm.node_failed(node);
        self.nms.remove(&node);
        self.slaves.retain(|&s| s != node);
        self.metrics.inc("wrapper.nodes_failed", 1);
        self.metrics.event(now, "wrapper", &format!("node {node} failed"));
        lost
    }

    /// Heartbeat every live NM and expire the rest: nodes silent for more
    /// than `timeout` become failures. `partitioned` nodes skip their
    /// heartbeat (fault injection: the node is alive but unreachable).
    pub fn heartbeat_and_expire(
        &mut self,
        now: Micros,
        timeout: Micros,
        partitioned: &std::collections::BTreeSet<NodeId>,
    ) -> Vec<(NodeId, Vec<crate::yarn::Container>)> {
        for (&node, nm) in self.nms.iter() {
            if nm.is_running() && !partitioned.contains(&node) {
                let _ = self.rm.nm_heartbeat(node, now);
            }
        }
        let expired = self.rm.expire_nms(now, timeout);
        for (node, _) in &expired {
            self.nms.remove(node);
            self.slaves.retain(|s| s != node);
            self.metrics.inc("wrapper.nodes_failed", 1);
            self.metrics
                .event(now, "wrapper", &format!("node {node} expired (missed heartbeats)"));
        }
        expired
    }

    /// Total container capacity in (mem, vcores) terms.
    pub fn capacity(&self) -> crate::yarn::Resource {
        self.rm.cluster_resources().0
    }

    /// Tear the cluster down (wrapper step after app completion):
    /// stop NMs (refusing if containers still run), clean node-local
    /// workspaces, shut the RM down, stop the JHS, remove staging — but
    /// keep the history done-dir (it outlives the cluster; §V).
    pub fn teardown(&mut self, dfs: &dyn Dfs, now: Micros) -> Result<()> {
        if self.torn_down {
            return Err(Error::Wrapper("cluster already torn down".into()));
        }
        for (id, nm) in self.nms.iter_mut() {
            nm.stop_and_clean()
                .map_err(|e| Error::Wrapper(format!("NM {id} teardown: {e}")))?;
        }
        self.rm
            .shutdown()
            .map_err(|e| Error::Wrapper(format!("RM shutdown: {e}")))?;
        self.jhs.stop();
        dfs.delete_recursive(&self.env.staging_root)?;
        self.torn_down = true;
        self.metrics.event(now, "wrapper", "cluster torn down");
        self.metrics.inc("wrapper.clusters_torn_down", 1);
        Ok(())
    }

    /// Post-teardown cleanliness check, used by tests: no staging left, no
    /// NM running, no NM-local files.
    pub fn verify_clean(&self, dfs: &dyn Dfs) -> Result<()> {
        if !self.torn_down {
            return Err(Error::Wrapper("not torn down".into()));
        }
        if dfs.exists(&self.env.staging_root) {
            return Err(Error::Wrapper(format!(
                "staging '{}' survived teardown",
                self.env.staging_root
            )));
        }
        for (id, nm) in &self.nms {
            if nm.is_running() {
                return Err(Error::Wrapper(format!("NM {id} still running")));
            }
            if nm.local_fs.exists("/tmp/hpcw") {
                return Err(Error::Wrapper(format!("NM {id} workspace not cleaned")));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::StackConfig;
    use crate::lustre::LustreFs;

    fn setup() -> (StackConfig, LustreFs, Arc<IdGen>, Arc<Metrics>) {
        let cfg = StackConfig::tiny();
        let fs = LustreFs::new(&cfg.lustre, &cfg.cluster);
        (cfg, fs, Arc::new(IdGen::default()), Arc::new(Metrics::new()))
    }

    fn nodes(n: u32) -> Vec<NodeId> {
        (0..n).map(NodeId).collect()
    }

    #[test]
    fn build_assigns_roles_per_paper() {
        let (cfg, fs, ids, m) = setup();
        let dc =
            DynamicCluster::build(&cfg, &nodes(8), &fs, ids, m, "job1", Micros::ZERO).unwrap();
        // First two nodes: RM + JHS; the other six are slaves (§V).
        assert_eq!(dc.rm_node, NodeId(0));
        assert_eq!(dc.jhs_node, NodeId(1));
        assert_eq!(dc.slave_count(), 6);
        assert_eq!(dc.rm.nm_count(), 6);
        assert!(dc.jhs.is_running());
        // Slaves have their local dirs.
        for nm in dc.nms.values() {
            assert!(nm.local_fs.exists("/tmp/hpcw/yarn/nm-local"));
        }
    }

    #[test]
    fn build_requires_three_nodes() {
        let (cfg, fs, ids, m) = setup();
        assert!(DynamicCluster::build(&cfg, &nodes(2), &fs, ids, m, "j", Micros::ZERO).is_err());
    }

    #[test]
    fn teardown_leaves_no_residue_but_keeps_history() {
        let (cfg, fs, ids, m) = setup();
        let mut dc =
            DynamicCluster::build(&cfg, &nodes(4), &fs, ids, m, "job2", Micros::ZERO).unwrap();
        let staging = dc.env.staging_root.clone();
        let done = dc.env.history_done_dir.clone();
        assert!(fs.exists(&staging));
        dc.teardown(&fs, Micros::secs(100)).unwrap();
        dc.verify_clean(&fs).unwrap();
        assert!(!fs.exists(&staging));
        assert!(fs.exists(&done)); // history outlives the cluster
        // Double teardown is an error.
        assert!(dc.teardown(&fs, Micros::secs(101)).is_err());
    }

    #[test]
    fn teardown_refuses_while_app_running() {
        let (cfg, fs, ids, m) = setup();
        let mut dc =
            DynamicCluster::build(&cfg, &nodes(4), &fs, ids, m, "job3", Micros::ZERO).unwrap();
        let _h = dc.rm.submit_app("t", "u", Micros::ZERO).unwrap();
        // RM still tracks the AM container → shutdown must refuse.
        assert!(dc.teardown(&fs, Micros::secs(5)).is_err());
    }

    #[test]
    fn two_jobs_do_not_collide_in_staging() {
        let (cfg, fs, ids, m) = setup();
        let dc1 = DynamicCluster::build(
            &cfg,
            &nodes(4),
            &fs,
            Arc::clone(&ids),
            Arc::clone(&m),
            "jobA",
            Micros::ZERO,
        )
        .unwrap();
        let dc2 =
            DynamicCluster::build(&cfg, &nodes(4), &fs, ids, m, "jobB", Micros::ZERO).unwrap();
        assert_ne!(dc1.env.staging_root, dc2.env.staging_root);
    }
}
