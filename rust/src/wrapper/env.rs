//! The environment the wrapper exports into the dynamic cluster: Hadoop
//! configuration values and the Lustre directory layout.
//!
//! §III "Data Movement": operational directories on node-local DAS (see
//! [`crate::yarn::nm::LOCAL_DIRS`]); "Hadoop Staging, Input and Output" on
//! Lustre. §V: "This configuration is exported into the cluster
//! environment and the daemons are triggered."

use crate::cluster::NodeId;
use crate::config::StackConfig;
use crate::error::Result;
use crate::lustre::Dfs;

/// Resolved per-job environment.
#[derive(Debug, Clone)]
pub struct ClusterEnv {
    /// Per-job staging root on Lustre, removed at teardown.
    pub staging_root: String,
    /// Job input directory (user-provided data lands here).
    pub input_dir: String,
    /// Job output directory.
    pub output_dir: String,
    /// MR intermediate/staging area.
    pub mr_staging_dir: String,
    /// Job-history done-dir — deliberately *outside* the staging root so it
    /// survives teardown.
    pub history_done_dir: String,
    /// Exported variables (the `hadoop-env.sh` analog); kept as explicit
    /// pairs so tests and the API can show the user exactly what a job saw.
    pub exports: Vec<(String, String)>,
}

impl ClusterEnv {
    pub fn new(cfg: &StackConfig, job_tag: &str, rm_node: NodeId, jhs_node: NodeId) -> ClusterEnv {
        let mount = cfg.lustre.mount.trim_end_matches('/');
        let staging_root = format!("{mount}/hpcw-jobs/{job_tag}");
        let env = ClusterEnv {
            input_dir: format!("{staging_root}/input"),
            output_dir: format!("{staging_root}/output"),
            mr_staging_dir: format!("{staging_root}/staging"),
            history_done_dir: format!("{mount}/hpcw-history/done"),
            exports: vec![
                ("HADOOP_HOME".into(), "/app/hadoop/2.5.1".into()),
                ("YARN_RESOURCEMANAGER_HOST".into(), rm_node.to_string()),
                ("MAPRED_HISTORYSERVER_HOST".into(), jhs_node.to_string()),
                (
                    "YARN_NM_RESOURCE_MB".into(),
                    cfg.yarn.nm_resource_mb.to_string(),
                ),
                (
                    "YARN_MIN_ALLOC_MB".into(),
                    cfg.yarn.min_alloc_mb.to_string(),
                ),
                (
                    "MAPREDUCE_MAP_MEMORY_MB".into(),
                    cfg.yarn.map_memory_mb.to_string(),
                ),
                (
                    "MAPREDUCE_MAP_JAVA_OPTS".into(),
                    format!("-Xmx{}m", cfg.yarn.map_java_heap_mb),
                ),
                ("HPCW_LUSTRE_MOUNT".into(), mount.to_string()),
            ],
            staging_root,
        };
        env
    }

    /// Create the shared (Lustre) directories.
    pub fn create_shared_dirs(&self, dfs: &dyn Dfs) -> Result<()> {
        dfs.mkdirs(&self.staging_root)?;
        dfs.mkdirs(&self.input_dir)?;
        dfs.mkdirs(&self.output_dir)?;
        dfs.mkdirs(&self.mr_staging_dir)?;
        dfs.mkdirs(&self.history_done_dir)?;
        Ok(())
    }

    /// Lookup of an exported variable.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.exports
            .iter()
            .find(|(k, _)| k == key)
            .map(|(_, v)| v.as_str())
    }

    /// Number of shared metadata objects this env creates (Sim mode feeds
    /// this into the MDS model).
    pub fn shared_dir_count(&self) -> u32 {
        5
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::StackConfig;
    use crate::lustre::LustreFs;

    #[test]
    fn paths_rooted_in_lustre_mount() {
        let cfg = StackConfig::paper();
        let env = ClusterEnv::new(&cfg, "job42", NodeId(0), NodeId(1));
        assert!(env.staging_root.starts_with("/lustre/scratch/"));
        assert!(env.input_dir.contains("job42"));
        assert!(!env.history_done_dir.contains("job42")); // survives teardown
    }

    #[test]
    fn exports_reflect_paper_table() {
        let cfg = StackConfig::paper();
        let env = ClusterEnv::new(&cfg, "j", NodeId(3), NodeId(4));
        assert_eq!(env.get("YARN_NM_RESOURCE_MB"), Some("53248"));
        assert_eq!(env.get("MAPREDUCE_MAP_JAVA_OPTS"), Some("-Xmx3072m"));
        assert_eq!(env.get("YARN_RESOURCEMANAGER_HOST"), Some("n0003"));
        assert_eq!(env.get("NOPE"), None);
    }

    #[test]
    fn create_shared_dirs_makes_all() {
        let cfg = StackConfig::paper();
        let fs = LustreFs::new(&cfg.lustre, &cfg.cluster);
        let env = ClusterEnv::new(&cfg, "j", NodeId(0), NodeId(1));
        env.create_shared_dirs(&fs).unwrap();
        for d in [
            &env.staging_root,
            &env.input_dir,
            &env.output_dir,
            &env.mr_staging_dir,
            &env.history_done_dir,
        ] {
            assert!(fs.exists(d), "{d} missing");
        }
    }
}
