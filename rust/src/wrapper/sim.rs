//! Sim-mode wrapper timing model — regenerates **Fig 3** ("Wrapper
//! Behaviour": cores allocated vs time to create + tear down the cluster,
//! with no application run in between).
//!
//! The modelled sequence is exactly [`super::DynamicCluster::build`]'s:
//!
//! 1. script + environment export;
//! 2. staging-directory creation on Lustre (MDS drain; grows ~linearly in
//!    node count but at 15k ops/s stays sub-second);
//! 3. RM on node 1, JHS on node 2 (ssh'd serially, JVMs boot in parallel);
//! 4. NodeManagers on the remaining nodes through a pdsh-style sliding
//!    window (`calibration.ssh_fanout` concurrent sessions), each session =
//!    ssh setup + local mkdir + NM JVM boot (log-normal jitter), then
//!    registration with the RM;
//! 5. teardown mirrors it: NM stop window, RM/JHS stop, staging removal.
//!
//! The shape this produces — near-flat with a mild log-ish rise from the
//! max of per-node jitter and the fan-out window — is the published
//! "wrapper adds little overhead" behaviour.

use crate::config::StackConfig;
use crate::simx::queueing::MD1;
use crate::util::rng::Rng;
use std::collections::BinaryHeap;

/// Timing breakdown of one simulated wrapper run.
#[derive(Debug, Clone)]
pub struct WrapperPhases {
    pub nodes: u32,
    pub cores: u32,
    pub env_setup_s: f64,
    pub shared_dirs_s: f64,
    pub daemons_s: f64,
    pub nm_phase_s: f64,
    pub create_s: f64,
    pub teardown_s: f64,
}

impl WrapperPhases {
    pub fn total_s(&self) -> f64 {
        self.create_s + self.teardown_s
    }
}

/// Makespan of `durations` items run through a sliding window of `width`
/// concurrent slots (pdsh semantics), items issued in order.
pub fn sliding_window_makespan(durations: &[f64], width: usize) -> f64 {
    assert!(width >= 1);
    // Min-heap of slot free times (stored negated in a max-heap).
    let mut heap: BinaryHeap<std::cmp::Reverse<u64>> = BinaryHeap::new();
    const SCALE: f64 = 1e6;
    let mut makespan = 0.0f64;
    for &d in durations {
        let start = if heap.len() < width {
            0.0
        } else {
            let std::cmp::Reverse(t) = heap.pop().unwrap();
            t as f64 / SCALE
        };
        let finish = start + d;
        makespan = makespan.max(finish);
        heap.push(std::cmp::Reverse((finish * SCALE) as u64));
    }
    makespan
}

/// Simulate one wrapper create+teardown for an allocation of `nodes`.
pub fn simulate_wrapper(cfg: &StackConfig, nodes: u32, seed: u64) -> WrapperPhases {
    assert!(nodes >= 3, "wrapper needs RM + JHS + >=1 slave");
    let cal = &cfg.calibration;
    let mut rng = Rng::new(cfg.seed ^ seed.wrapping_mul(0x9E3779B97F4A7C15)).fork(nodes as u64);
    let slaves = nodes - 2;

    // Log-normal with mean `mean_s`: ln-mu = ln(mean) - sigma^2/2.
    fn lognorm(rng: &mut Rng, sigma: f64, mean_s: f64) -> f64 {
        let mu = mean_s.ln() - sigma * sigma / 2.0;
        rng.lognormal(mu, sigma)
    }
    let sig = cal.daemon_jitter_sigma;

    // Phase 1: script startup, module loads, config generation, env export.
    let env_setup_s = 1.0 + rng.f64() * 0.2;

    // Phase 2: shared dirs on Lustre. 5 job dirs + one staging subdir per
    // node (the NM deposit dirs), drained by the MDS.
    let mds = MD1::new(cfg.lustre.mds_ops_per_sec);
    let shared_dirs_s = mds.drain_time(5 + nodes as u64);

    // Phase 3: RM (node 1) and JHS (node 2). Serial ssh, parallel boot.
    let rm_up = cal.ssh_setup_s + lognorm(&mut rng, sig, cal.rm_start_s);
    let jhs_up = 2.0 * cal.ssh_setup_s + lognorm(&mut rng, sig, cal.jhs_start_s);
    let daemons_s = rm_up.max(jhs_up);

    // Phase 4: NM fan-out. Per node: ssh + local mkdirs + NM boot.
    let local_mkdir_s = cal.dirs_per_node as f64 * 0.002;
    let durations: Vec<f64> = (0..slaves)
        .map(|_| cal.ssh_setup_s + local_mkdir_s + lognorm(&mut rng, sig, cal.nm_start_s))
        .collect();
    let nm_boot = sliding_window_makespan(&durations, cal.ssh_fanout as usize);
    // Registration needs the RM up; the fan-out starts as soon as the RM/JHS
    // ssh commands return (daemon boot is backgrounded).
    let nm_phase_s = nm_boot.max(daemons_s) + cal.nm_register_s;

    let create_s = env_setup_s + shared_dirs_s + nm_phase_s;

    // Teardown: NM stop window, RM + JHS stop, staging removal.
    let stop_durations: Vec<f64> = (0..slaves)
        .map(|_| cal.ssh_setup_s + lognorm(&mut rng, sig, cal.daemon_stop_s))
        .collect();
    let nm_stop = sliding_window_makespan(&stop_durations, cal.ssh_fanout as usize);
    let rm_jhs_stop = 2.0 * cal.ssh_setup_s + lognorm(&mut rng, sig, cal.daemon_stop_s);
    // Staging removal: ~1 dir per node plus job dirs and logs.
    let unlink_s = mds.drain_time(nodes as u64 + 20);
    let teardown_s = nm_stop + rm_jhs_stop + unlink_s;

    WrapperPhases {
        nodes,
        cores: nodes * cfg.cluster.cores_per_node,
        env_setup_s,
        shared_dirs_s,
        daemons_s,
        nm_phase_s,
        create_s,
        teardown_s,
    }
}

/// The Fig 3 sweep: create+teardown times across allocation sizes.
/// Returns `(cores, create_s, teardown_s, total_s)` rows.
pub fn fig3_sweep(cfg: &StackConfig, node_counts: &[u32], reps: u32) -> Vec<(u32, f64, f64, f64)> {
    node_counts
        .iter()
        .map(|&n| {
            let mut create = 0.0;
            let mut teardown = 0.0;
            for r in 0..reps.max(1) {
                let p = simulate_wrapper(cfg, n, r as u64);
                create += p.create_s;
                teardown += p.teardown_s;
            }
            let reps = reps.max(1) as f64;
            let (c, t) = (create / reps, teardown / reps);
            (n * cfg.cluster.cores_per_node, c, t, c + t)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::StackConfig;

    #[test]
    fn sliding_window_basics() {
        // 4 items of 1 s through width 2 → 2 s.
        assert!((sliding_window_makespan(&[1.0; 4], 2) - 2.0).abs() < 1e-9);
        // Width >= n → max item.
        assert!((sliding_window_makespan(&[1.0, 3.0, 2.0], 10) - 3.0).abs() < 1e-9);
        // Width 1 → sum.
        assert!((sliding_window_makespan(&[1.0, 2.0, 3.0], 1) - 6.0).abs() < 1e-6);
        assert_eq!(sliding_window_makespan(&[], 4), 0.0);
    }

    #[test]
    fn wrapper_time_dominated_by_daemons_not_dirs() {
        let cfg = StackConfig::paper();
        let p = simulate_wrapper(&cfg, 16, 0);
        assert!(p.shared_dirs_s < 1.0, "MDS dirs {0}", p.shared_dirs_s);
        assert!(p.nm_phase_s > p.shared_dirs_s);
        assert!(p.create_s > p.teardown_s, "stop is faster than start");
    }

    #[test]
    fn fig3_shape_near_flat_with_mild_growth() {
        let cfg = StackConfig::paper();
        let rows = fig3_sweep(&cfg, &[4, 16, 64, 128], 3);
        let t4 = rows[0].3;
        let t128 = rows[3].3;
        // Little overhead: under 2 minutes even at 2,048 cores...
        assert!(t128 < 120.0, "t128={t128}");
        // ...and growth from 64 to 2,048 cores is well under 3×.
        assert!(t128 / t4 < 3.0, "t4={t4} t128={t128}");
        // But it is monotone-ish: more nodes is not faster.
        assert!(t128 > t4 * 0.9);
        // Cores column uses the paper's 16-core nodes.
        assert_eq!(rows[0].0, 64);
        assert_eq!(rows[3].0, 2048);
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = StackConfig::paper();
        let a = simulate_wrapper(&cfg, 32, 7);
        let b = simulate_wrapper(&cfg, 32, 7);
        assert_eq!(a.create_s, b.create_s);
        let c = simulate_wrapper(&cfg, 32, 8);
        assert_ne!(a.create_s, c.create_s);
    }

    #[test]
    #[should_panic(expected = "needs RM")]
    fn too_few_nodes_panics() {
        let cfg = StackConfig::paper();
        simulate_wrapper(&cfg, 2, 0);
    }
}
