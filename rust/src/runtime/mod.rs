//! The PJRT runtime: load the AOT-lowered JAX/Pallas artifacts
//! (`artifacts/*.hlo.txt`) and execute them from the Rust hot path.
//!
//! * [`artifacts`] — manifest parsing + artifact discovery.
//! * [`pjrt`] — the dedicated PJRT server thread (xla-crate types are not
//!   `Send`) with compile-once caching and a cloneable client handle.
//! * [`kernels`] — typed wrappers and the [`crate::mapreduce::BlockProcessor`]
//!   implementations (pure-Rust reference vs Pallas kernel), parity-tested.

pub mod artifacts;
pub mod kernels;
pub mod pjrt;

pub use artifacts::{ArtifactManifest, TensorSpec};
pub use kernels::{KernelBlockProcessor, RustBlockProcessor};
pub use pjrt::{shared_client, KernelClient, KernelServer, Tensor};
