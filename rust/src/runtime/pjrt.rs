//! The PJRT execution server.
//!
//! The `xla` crate's client/executable types wrap raw C++ pointers and are
//! not `Send`, but map tasks run on a thread pool. The server owns the
//! PJRT CPU client and all compiled executables on one dedicated thread;
//! callers talk to it through a cloneable [`KernelClient`] channel handle.
//! Executables are compiled once per entry name and cached for the life of
//! the server — compilation happens at startup (or first use), never on
//! the per-record hot path.

use crate::error::{Error, Result};
use crate::runtime::artifacts::ArtifactManifest;
#[cfg(feature = "xla")]
use std::collections::BTreeMap;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};

/// A typed input tensor crossing the channel.
#[derive(Debug, Clone)]
pub enum Tensor {
    U64(Vec<u64>),
    I32(Vec<i32>),
}

impl Tensor {
    pub fn as_u64(&self) -> Result<&[u64]> {
        match self {
            Tensor::U64(v) => Ok(v),
            _ => Err(Error::Runtime("expected u64 tensor".into())),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            Tensor::I32(v) => Ok(v),
            _ => Err(Error::Runtime("expected i32 tensor".into())),
        }
    }

    #[cfg(feature = "xla")]
    fn to_literal(&self) -> xla::Literal {
        match self {
            Tensor::U64(v) => xla::Literal::vec1(v),
            Tensor::I32(v) => xla::Literal::vec1(v),
        }
    }
}

// Without the `xla` feature the stub server never reads `entry`/`inputs`;
// the request shape stays identical so clients are feature-agnostic.
#[cfg_attr(not(feature = "xla"), allow(dead_code))]
enum Request {
    Exec {
        entry: String,
        inputs: Vec<Tensor>,
        reply: Sender<Result<Vec<Tensor>>>,
    },
    /// Pre-compile an entry (warmup).
    Compile {
        entry: String,
        reply: Sender<Result<()>>,
    },
    Shutdown,
}

/// Cloneable handle to the PJRT server thread.
#[derive(Clone)]
pub struct KernelClient {
    tx: Sender<Request>,
    manifest: Arc<ArtifactManifest>,
}

// The Sender is Send+Sync via the Mutex pattern below; Request contains
// only owned data.
pub struct KernelServer {
    tx: Sender<Request>,
    manifest: Arc<ArtifactManifest>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl KernelServer {
    /// Start the server for an artifact manifest.
    pub fn start(manifest: ArtifactManifest) -> Result<KernelServer> {
        let manifest = Arc::new(manifest);
        let (tx, rx) = channel::<Request>();
        let m2 = Arc::clone(&manifest);
        let handle = std::thread::Builder::new()
            .name("hpcw-pjrt".into())
            .spawn(move || server_loop(rx, m2))
            .map_err(|e| Error::Runtime(format!("spawn pjrt server: {e}")))?;
        Ok(KernelServer {
            tx,
            manifest,
            handle: Some(handle),
        })
    }

    /// Start from the default artifacts dir.
    pub fn start_default() -> Result<KernelServer> {
        let dir = crate::runtime::artifacts::default_dir();
        KernelServer::start(ArtifactManifest::load(&dir)?)
    }

    pub fn client(&self) -> KernelClient {
        KernelClient {
            tx: self.tx.clone(),
            manifest: Arc::clone(&self.manifest),
        }
    }
}

impl Drop for KernelServer {
    fn drop(&mut self) {
        let _ = self.tx.send(Request::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl KernelClient {
    pub fn manifest(&self) -> &ArtifactManifest {
        &self.manifest
    }

    /// Execute an entry with typed tensors; blocks for the result.
    pub fn execute(&self, entry: &str, inputs: Vec<Tensor>) -> Result<Vec<Tensor>> {
        let spec = self.manifest.entry(entry)?;
        if spec.inputs.len() != inputs.len() {
            return Err(Error::Runtime(format!(
                "{entry}: expected {} inputs, got {}",
                spec.inputs.len(),
                inputs.len()
            )));
        }
        for (i, (s, t)) in spec.inputs.iter().zip(&inputs).enumerate() {
            let len = match t {
                Tensor::U64(v) => v.len() as u64,
                Tensor::I32(v) => v.len() as u64,
            };
            if len != s.elements() {
                return Err(Error::Runtime(format!(
                    "{entry}: input {i} has {len} elements, expected {}",
                    s.elements()
                )));
            }
        }
        let (reply, rrx): (Sender<Result<Vec<Tensor>>>, Receiver<_>) = channel();
        self.tx
            .send(Request::Exec {
                entry: entry.to_string(),
                inputs,
                reply,
            })
            .map_err(|_| Error::Runtime("pjrt server gone".into()))?;
        rrx.recv()
            .map_err(|_| Error::Runtime("pjrt server dropped reply".into()))?
    }

    /// Warm the compile cache for an entry.
    pub fn precompile(&self, entry: &str) -> Result<()> {
        let (reply, rrx) = channel();
        self.tx
            .send(Request::Compile {
                entry: entry.to_string(),
                reply,
            })
            .map_err(|_| Error::Runtime("pjrt server gone".into()))?;
        rrx.recv()
            .map_err(|_| Error::Runtime("pjrt server dropped reply".into()))?
    }
}

/// Stub backend: the crate was built without the `xla` feature, so every
/// request gets a clean Runtime error. Kernel tests skip on this error the
/// same way they skip when artifacts are not built.
#[cfg(not(feature = "xla"))]
fn server_loop(rx: Receiver<Request>, _manifest: Arc<ArtifactManifest>) {
    while let Ok(req) = rx.recv() {
        let err = || Error::Runtime("PJRT unavailable: built without the `xla` feature".into());
        match req {
            Request::Exec { reply, .. } => {
                let _ = reply.send(Err(err()));
            }
            Request::Compile { reply, .. } => {
                let _ = reply.send(Err(err()));
            }
            Request::Shutdown => break,
        }
    }
}

#[cfg(feature = "xla")]
fn server_loop(rx: Receiver<Request>, manifest: Arc<ArtifactManifest>) {
    let client = match xla::PjRtClient::cpu() {
        Ok(c) => c,
        Err(e) => {
            // Answer every request with the startup error.
            while let Ok(req) = rx.recv() {
                match req {
                    Request::Exec { reply, .. } => {
                        let _ = reply.send(Err(Error::Runtime(format!("PJRT init failed: {e}"))));
                    }
                    Request::Compile { reply, .. } => {
                        let _ = reply.send(Err(Error::Runtime(format!("PJRT init failed: {e}"))));
                    }
                    Request::Shutdown => break,
                }
            }
            return;
        }
    };
    let mut cache: BTreeMap<String, xla::PjRtLoadedExecutable> = BTreeMap::new();

    let compile =
        |cache: &mut BTreeMap<String, xla::PjRtLoadedExecutable>, entry: &str| -> Result<()> {
            if cache.contains_key(entry) {
                return Ok(());
            }
            let spec = manifest.entry(entry)?;
            let path = spec
                .file
                .to_str()
                .ok_or_else(|| Error::Runtime("bad artifact path".into()))?
                .to_string();
            let proto = xla::HloModuleProto::from_text_file(&path)?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp)?;
            cache.insert(entry.to_string(), exe);
            Ok(())
        };

    while let Ok(req) = rx.recv() {
        match req {
            Request::Shutdown => break,
            Request::Compile { entry, reply } => {
                let _ = reply.send(compile(&mut cache, &entry));
            }
            Request::Exec {
                entry,
                inputs,
                reply,
            } => {
                let result = (|| -> Result<Vec<Tensor>> {
                    compile(&mut cache, &entry)?;
                    let exe = cache.get(&entry).unwrap();
                    let lits: Vec<xla::Literal> =
                        inputs.iter().map(Tensor::to_literal).collect();
                    let out = exe.execute::<xla::Literal>(&lits)?;
                    let result = out[0][0].to_literal_sync()?;
                    // aot.py lowers with return_tuple=True.
                    let parts = result.to_tuple()?;
                    let spec = manifest.entry(&entry)?;
                    if parts.len() != spec.outputs.len() {
                        return Err(Error::Runtime(format!(
                            "{entry}: got {} outputs, manifest says {}",
                            parts.len(),
                            spec.outputs.len()
                        )));
                    }
                    let mut tensors = Vec::with_capacity(parts.len());
                    for (lit, ospec) in parts.into_iter().zip(&spec.outputs) {
                        if ospec.is_u64() {
                            tensors.push(Tensor::U64(lit.to_vec::<u64>()?));
                        } else if ospec.is_i32() {
                            tensors.push(Tensor::I32(lit.to_vec::<i32>()?));
                        } else {
                            return Err(Error::Runtime(format!(
                                "{entry}: unsupported output dtype {}",
                                ospec.dtype
                            )));
                        }
                    }
                    Ok(tensors)
                })();
                let _ = reply.send(result);
            }
        }
    }
}

/// Shared lazily-started server (one per process). Returns a client, or a
/// clean error if artifacts are not built / PJRT unavailable.
pub fn shared_client() -> Result<KernelClient> {
    static SERVER: Mutex<Option<KernelServer>> = Mutex::new(None);
    let mut guard = SERVER.lock().unwrap();
    if guard.is_none() {
        *guard = Some(KernelServer::start_default()?);
    }
    Ok(guard.as_ref().unwrap().client())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifacts::default_dir;

    fn client() -> Option<KernelClient> {
        if !default_dir().join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        shared_client().ok()
    }

    #[test]
    fn partition_kernel_executes() {
        let Some(c) = client() else { return };
        let n = 4096usize;
        let keys: Vec<u64> = (0..n as u64).map(|i| i * 1_000_003).collect();
        let mut splitters = vec![u64::MAX; 127];
        splitters[0] = 1_000_000_000;
        splitters[1] = 3_000_000_000;
        splitters.sort_unstable();
        let out = c
            .execute(
                "partition_b4096_s127",
                vec![Tensor::U64(keys.clone()), Tensor::U64(splitters.clone())],
            )
            .unwrap();
        let part = out[0].as_i32().unwrap();
        let counts = out[1].as_i32().unwrap();
        assert_eq!(part.len(), n);
        assert_eq!(counts.iter().map(|&c| c as i64).sum::<i64>(), n as i64);
        // Spot-check against the Rust router semantics.
        for (i, &k) in keys.iter().enumerate().step_by(517) {
            let expect = splitters.iter().filter(|&&s| s <= k).count() as i32;
            assert_eq!(part[i], expect, "key {k}");
        }
    }

    #[test]
    fn input_shape_mismatch_rejected() {
        let Some(c) = client() else { return };
        let err = c
            .execute("partition_b4096_s127", vec![Tensor::U64(vec![1, 2, 3])])
            .unwrap_err();
        assert!(err.to_string().contains("expected 2 inputs"));
        let err2 = c
            .execute(
                "partition_b4096_s127",
                vec![Tensor::U64(vec![1, 2, 3]), Tensor::U64(vec![0; 127])],
            )
            .unwrap_err();
        assert!(err2.to_string().contains("elements"));
    }

    #[test]
    fn unknown_entry_rejected() {
        let Some(c) = client() else { return };
        assert!(c.execute("nope", vec![]).is_err());
    }
}
