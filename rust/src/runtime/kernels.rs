//! Typed kernel wrappers + the map-path integration seam.
//!
//! [`BlockProcessor`] is the hook the MR engine's map task calls to
//! sort+partition a whole emitted block at once. Two implementations:
//!
//! * [`RustBlockProcessor`] — pure Rust over the flat `RecordBuf` arena
//!   (prefix-decorated index sort + monotone routing scan);
//! * [`KernelBlockProcessor`] — the AOT Pallas `mapphase` artifact through
//!   PJRT: kernel sorts/partitions the 8-byte key prefixes, Rust applies
//!   the permutation to the arena indices and resolves the rare
//!   prefix-tie runs by a local full-key fix-up pass.
//!
//! Both must produce byte-identical segments; `parity` tests enforce it.

use crate::error::{Error, Result};
use crate::mapreduce::recordbuf::resolve_prefix_ties;
use crate::mapreduce::{BlockProcessor, RecordBuf};
use crate::runtime::pjrt::{KernelClient, Tensor};
use crate::terasort::format::key_prefix_u64;
use crate::terasort::RangePartitioner;

/// Pure-Rust reference path over the flat [`RecordBuf`] arena: one
/// prefix-decorated index sort, then a single monotone routing scan that
/// copies each record once into its partition buffer.
pub struct RustBlockProcessor {
    pub partitioner: RangePartitioner,
}

impl BlockProcessor for RustBlockProcessor {
    fn process(&self, mut records: RecordBuf, n_reduces: u32) -> Result<Vec<RecordBuf>> {
        let mut out: Vec<RecordBuf> = (0..n_reduces).map(|_| RecordBuf::new()).collect();
        if n_reduces == 0 {
            return Ok(out);
        }
        records.sort_by_key();
        let mut router = self.partitioner.router();
        for i in 0..records.len() {
            let (k, v) = records.get(i);
            let p = router.route(key_prefix_u64(k)).min(n_reduces - 1) as usize;
            out[p].push(k, v);
        }
        Ok(out)
    }

    fn name(&self) -> &'static str {
        "rust"
    }
}

/// PJRT kernel path: fused `mapphase` artifact.
pub struct KernelBlockProcessor {
    client: KernelClient,
    /// Splitters padded to the artifact's 127 slots with u64::MAX.
    splitters_padded: Vec<u64>,
    partitioner: RangePartitioner,
    /// Available mapphase block geometries, ascending.
    blocks: Vec<(u64, String)>,
    /// Multi-block artifact (one PJRT call sorting several 8192-blocks),
    /// if shipped: `(total_n, run_len, entry)`.
    multi: Option<(u64, u64, String)>,
}

/// Number of splitter slots the shipped artifacts use.
pub const SPLITTER_SLOTS: usize = 127;

impl KernelBlockProcessor {
    pub fn new(client: KernelClient, partitioner: RangePartitioner) -> Result<Self> {
        if partitioner.splitters.len() > SPLITTER_SLOTS {
            return Err(Error::Runtime(format!(
                "kernel supports up to {} splitters, got {}",
                SPLITTER_SLOTS,
                partitioner.splitters.len()
            )));
        }
        if partitioner.splitters.iter().any(|&s| s == u64::MAX) {
            return Err(Error::Runtime(
                "u64::MAX splitter collides with the pad sentinel".into(),
            ));
        }
        let mut splitters_padded = partitioner.splitters.clone();
        splitters_padded.resize(SPLITTER_SLOTS, u64::MAX);
        let mut blocks = client.manifest().block_sizes("mapphase");
        // Separate the multi-block artifact (mapphase_multi_b8192_g4) from
        // the single-block geometries.
        let mut multi = None;
        blocks.retain(|(_, name)| {
            if let Some(rest) = name.strip_prefix("mapphase_multi_b") {
                let mut it = rest.split("_g");
                if let (Some(b), Some(g)) = (it.next(), it.next()) {
                    if let (Ok(b), Ok(g)) = (b.parse::<u64>(), g.parse::<u64>()) {
                        multi = Some((b * g, b, name.clone()));
                    }
                }
                false
            } else {
                true
            }
        });
        if blocks.is_empty() {
            return Err(Error::Runtime("no mapphase artifacts in manifest".into()));
        }
        Ok(KernelBlockProcessor {
            client,
            splitters_padded,
            partitioner,
            blocks,
            multi,
        })
    }

    /// Pick the smallest artifact block >= n (or the largest available).
    fn pick_block(&self, n: usize) -> (u64, &str) {
        for (b, name) in &self.blocks {
            if *b as usize >= n {
                return (*b, name);
            }
        }
        let (b, name) = self.blocks.last().unwrap();
        (*b, name)
    }

    /// Run the fused kernel over up to one block of prefixes; returns one
    /// or more sorted runs of globally-indexed positions (several runs
    /// when the multi-block artifact handled the chunk).
    fn sorted_runs(&self, prefixes: &[u64]) -> Result<Vec<Vec<u32>>> {
        let n = prefixes.len();
        // Prefer the multi-block artifact when the chunk outgrows the
        // largest single block (perf pass: one PJRT call, G runs).
        if let Some((total, run_len, entry)) = &self.multi {
            let single_max = self.blocks.last().unwrap().0 as usize;
            if n > single_max && n <= *total as usize {
                let total = *total as usize;
                let run_len = *run_len as usize;
                let mut padded = prefixes.to_vec();
                padded.resize(total, u64::MAX);
                let out = self.client.execute(
                    entry,
                    vec![
                        Tensor::U64(padded),
                        Tensor::U64(self.splitters_padded.clone()),
                    ],
                )?;
                let perm = out[1].as_i32()?;
                let mut runs = Vec::new();
                let mut kept = 0usize;
                for (w, window) in perm.chunks(run_len).enumerate() {
                    let base = (w * run_len) as u32;
                    let mut run = Vec::new();
                    for &p in window {
                        let global = base + p as u32;
                        if (global as usize) < n {
                            run.push(global);
                        }
                    }
                    kept += run.len();
                    if !run.is_empty() {
                        runs.push(run);
                    }
                }
                if kept != n {
                    return Err(Error::Runtime(format!(
                        "multi-block perm lost entries: {kept} of {n}"
                    )));
                }
                return Ok(runs);
            }
        }
        let (block, entry) = self.pick_block(n);
        let block = block as usize;
        debug_assert!(n <= block);
        let mut padded = prefixes.to_vec();
        padded.resize(block, u64::MAX);
        let out = self.client.execute(
            entry,
            vec![
                Tensor::U64(padded),
                Tensor::U64(self.splitters_padded.clone()),
            ],
        )?;
        let perm = out[1].as_i32()?;
        // Padding keys are u64::MAX with indices >= n; the stable network
        // sinks them to the tail *after* any real MAX-prefix keys — but a
        // real key CAN be MAX, so filter by index rather than position.
        let mut order = Vec::with_capacity(n);
        for &p in perm {
            if (p as usize) < n {
                order.push(p as u32);
            }
        }
        if order.len() != n {
            return Err(Error::Runtime(format!(
                "kernel perm lost entries: {} of {n}",
                order.len()
            )));
        }
        Ok(vec![order])
    }
}

impl BlockProcessor for KernelBlockProcessor {
    fn process(&self, records: RecordBuf, n_reduces: u32) -> Result<Vec<RecordBuf>> {
        // `.max(1)`: a corrupt manifest advertising a zero-sized block must
        // not stall the chunking loop below (`base` would never advance).
        let chunk_cap = self
            .multi
            .as_ref()
            .map(|(t, _, _)| *t as usize)
            .unwrap_or_else(|| self.blocks.last().unwrap().0 as usize)
            .max(1);
        let mut out: Vec<RecordBuf> = (0..n_reduces).map(|_| RecordBuf::new()).collect();
        if n_reduces == 0 {
            return Ok(out);
        }

        // Process in kernel-sized chunks of the arena; each chunk may come
        // back as several sorted runs (multi-block artifact). Multi-run
        // outputs get one per-partition sort pass at the end. The kernel
        // only ever sees the u64 prefixes — record payloads stay in the
        // arena and are copied exactly once, into their partition buffer.
        let n = records.len();
        let mut total_runs = 0usize;
        let mut base = 0usize;
        while base < n {
            let len = chunk_cap.min(n - base);
            let prefixes: Vec<u64> = (base..base + len)
                .map(|i| key_prefix_u64(records.key(i)))
                .collect();
            let runs = self.sorted_runs(&prefixes)?;
            total_runs += runs.len();
            for mut order in runs {
                // `order` holds chunk-local indices sorted by prefix.
                // Fix-up: resolve ties on the full 10-byte key within
                // equal-prefix runs (position stays the final tiebreak, so
                // equal full keys keep emission order) — the same shared
                // pass RecordBuf::sort_by_key uses, keeping both paths
                // byte-identical.
                resolve_prefix_ties(
                    &mut order,
                    |i| prefixes[i as usize],
                    |i| records.key(base + i as usize),
                );

                // Route the sorted run (partitioning is monotone: one scan;
                // prefixes were already extracted for the kernel call).
                let mut router = self.partitioner.router();
                for &ci in &order {
                    let gi = base + ci as usize;
                    let (k, v) = records.get(gi);
                    let p = router.route(prefixes[ci as usize]).min(n_reduces - 1) as usize;
                    out[p].push(k, v);
                }
            }
            base += len;
        }

        if total_runs > 1 {
            // Per-partition contributions from different runs are each
            // sorted but interleaved; restore order with one stable index
            // sort per partition (partitions are small relative to the
            // block).
            for part in &mut out {
                part.sort_by_key();
            }
        }
        Ok(out)
    }

    fn name(&self) -> &'static str {
        "pallas-pjrt"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifacts::default_dir;
    use crate::runtime::pjrt::shared_client;
    use crate::terasort::format::record_for_row;
    use crate::util::rng::Rng;

    fn partitioner(n: u32, seed: u64) -> RangePartitioner {
        let mut rng = Rng::new(seed);
        let samples: Vec<u64> = (0..4000).map(|_| rng.next_u64()).collect();
        RangePartitioner::from_samples(samples, n).unwrap()
    }

    fn records(n: usize, seed: u64) -> RecordBuf {
        let mut rb = RecordBuf::with_capacity(n, n * 100);
        for i in 0..n {
            rb.push_record(&record_for_row(seed, i as u64), 10);
        }
        rb
    }

    #[test]
    fn rust_processor_outputs_sorted_partitions() {
        let p = RustBlockProcessor {
            partitioner: partitioner(8, 1),
        };
        let out = p.process(records(5000, 42), 8).unwrap();
        assert_eq!(out.len(), 8);
        let total: usize = out.iter().map(RecordBuf::len).sum();
        assert_eq!(total, 5000);
        for part in &out {
            assert!(part.is_sorted_by_key());
        }
    }

    #[test]
    fn rust_processor_matches_legacy_pairs_model() {
        // Parity with the pre-flat-path implementation: stable full sort of
        // owned pairs, then per-record binary-search routing.
        let part = partitioner(8, 3);
        let n_reduces = 8u32;
        let mut pairs: Vec<(Vec<u8>, Vec<u8>)> = (0..3000)
            .map(|i| {
                let rec = record_for_row(11, i as u64);
                (rec[..10].to_vec(), rec[10..].to_vec())
            })
            .collect();
        let p = RustBlockProcessor {
            partitioner: part.clone(),
        };
        let out = p.process(records(3000, 11), n_reduces).unwrap();
        pairs.sort_by(|a, b| a.0.cmp(&b.0));
        let mut legacy: Vec<Vec<(Vec<u8>, Vec<u8>)>> =
            (0..n_reduces).map(|_| Vec::new()).collect();
        for (k, v) in pairs {
            let route = part.route(key_prefix_u64(&k)).min(n_reduces - 1) as usize;
            legacy[route].push((k, v));
        }
        for (flat, old) in out.iter().zip(&legacy) {
            assert_eq!(&flat.to_pairs(), old);
        }
    }

    #[test]
    fn kernel_parity_with_rust_path() {
        if !default_dir().join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let client = shared_client().unwrap();
        let part = partitioner(16, 2);
        let kernel = KernelBlockProcessor::new(client, part.clone()).unwrap();
        let rust = RustBlockProcessor { partitioner: part };
        for &n in &[100usize, 2048, 3000, 9000] {
            let a = kernel.process(records(n, 7), 16).unwrap();
            let b = rust.process(records(n, 7), 16).unwrap();
            assert_eq!(a, b, "parity failed at n={n}");
        }
    }

    #[test]
    fn kernel_rejects_max_sentinel_splitter() {
        if !default_dir().join("manifest.json").exists() {
            return;
        }
        let client = shared_client().unwrap();
        let bad = RangePartitioner {
            splitters: vec![5, u64::MAX],
        };
        assert!(KernelBlockProcessor::new(client, bad).is_err());
    }
}
