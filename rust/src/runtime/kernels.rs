//! Typed kernel wrappers + the map-path integration seam.
//!
//! [`BlockProcessor`] is the hook the MR engine's map task calls to
//! sort+partition a whole emitted block at once. Two implementations:
//!
//! * [`RustBlockProcessor`] — pure Rust (sort_by + binary-search routing);
//! * [`KernelBlockProcessor`] — the AOT Pallas `mapphase` artifact through
//!   PJRT: kernel sorts/partitions the 8-byte key prefixes, Rust applies
//!   the permutation to the full 100-byte records and resolves the rare
//!   prefix-tie runs by a local full-key fix-up pass.
//!
//! Both must produce byte-identical segments; `parity` tests enforce it.

use crate::error::{Error, Result};
use crate::mapreduce::BlockProcessor;
use crate::runtime::pjrt::{KernelClient, Tensor};
use crate::terasort::format::key_prefix_u64;
use crate::terasort::RangePartitioner;

/// Pure-Rust reference path.
pub struct RustBlockProcessor {
    pub partitioner: RangePartitioner,
}

impl BlockProcessor for RustBlockProcessor {
    fn process(
        &self,
        mut pairs: Vec<(Vec<u8>, Vec<u8>)>,
        n_reduces: u32,
    ) -> Result<Vec<Vec<(Vec<u8>, Vec<u8>)>>> {
        pairs.sort_by(|a, b| a.0.cmp(&b.0));
        let mut out: Vec<Vec<(Vec<u8>, Vec<u8>)>> = (0..n_reduces).map(|_| Vec::new()).collect();
        for (k, v) in pairs {
            let p = self
                .partitioner
                .route(key_prefix_u64(&k))
                .min(n_reduces.saturating_sub(1)) as usize;
            out[p].push((k, v));
        }
        Ok(out)
    }

    fn name(&self) -> &'static str {
        "rust"
    }
}

/// PJRT kernel path: fused `mapphase` artifact.
pub struct KernelBlockProcessor {
    client: KernelClient,
    /// Splitters padded to the artifact's 127 slots with u64::MAX.
    splitters_padded: Vec<u64>,
    partitioner: RangePartitioner,
    /// Available mapphase block geometries, ascending.
    blocks: Vec<(u64, String)>,
    /// Multi-block artifact (one PJRT call sorting several 8192-blocks),
    /// if shipped: `(total_n, run_len, entry)`.
    multi: Option<(u64, u64, String)>,
}

/// Number of splitter slots the shipped artifacts use.
pub const SPLITTER_SLOTS: usize = 127;

impl KernelBlockProcessor {
    pub fn new(client: KernelClient, partitioner: RangePartitioner) -> Result<Self> {
        if partitioner.splitters.len() > SPLITTER_SLOTS {
            return Err(Error::Runtime(format!(
                "kernel supports up to {} splitters, got {}",
                SPLITTER_SLOTS,
                partitioner.splitters.len()
            )));
        }
        if partitioner.splitters.iter().any(|&s| s == u64::MAX) {
            return Err(Error::Runtime(
                "u64::MAX splitter collides with the pad sentinel".into(),
            ));
        }
        let mut splitters_padded = partitioner.splitters.clone();
        splitters_padded.resize(SPLITTER_SLOTS, u64::MAX);
        let mut blocks = client.manifest().block_sizes("mapphase");
        // Separate the multi-block artifact (mapphase_multi_b8192_g4) from
        // the single-block geometries.
        let mut multi = None;
        blocks.retain(|(_, name)| {
            if let Some(rest) = name.strip_prefix("mapphase_multi_b") {
                let mut it = rest.split("_g");
                if let (Some(b), Some(g)) = (it.next(), it.next()) {
                    if let (Ok(b), Ok(g)) = (b.parse::<u64>(), g.parse::<u64>()) {
                        multi = Some((b * g, b, name.clone()));
                    }
                }
                false
            } else {
                true
            }
        });
        if blocks.is_empty() {
            return Err(Error::Runtime("no mapphase artifacts in manifest".into()));
        }
        Ok(KernelBlockProcessor {
            client,
            splitters_padded,
            partitioner,
            blocks,
            multi,
        })
    }

    /// Pick the smallest artifact block >= n (or the largest available).
    fn pick_block(&self, n: usize) -> (u64, &str) {
        for (b, name) in &self.blocks {
            if *b as usize >= n {
                return (*b, name);
            }
        }
        let (b, name) = self.blocks.last().unwrap();
        (*b, name)
    }

    /// Run the fused kernel over up to one block of prefixes; returns one
    /// or more sorted runs of globally-indexed positions (several runs
    /// when the multi-block artifact handled the chunk).
    fn sorted_runs(&self, prefixes: &[u64]) -> Result<Vec<Vec<u32>>> {
        let n = prefixes.len();
        // Prefer the multi-block artifact when the chunk outgrows the
        // largest single block (perf pass: one PJRT call, G runs).
        if let Some((total, run_len, entry)) = &self.multi {
            let single_max = self.blocks.last().unwrap().0 as usize;
            if n > single_max && n <= *total as usize {
                let total = *total as usize;
                let run_len = *run_len as usize;
                let mut padded = prefixes.to_vec();
                padded.resize(total, u64::MAX);
                let out = self.client.execute(
                    entry,
                    vec![
                        Tensor::U64(padded),
                        Tensor::U64(self.splitters_padded.clone()),
                    ],
                )?;
                let perm = out[1].as_i32()?;
                let mut runs = Vec::new();
                let mut kept = 0usize;
                for (w, window) in perm.chunks(run_len).enumerate() {
                    let base = (w * run_len) as u32;
                    let mut run = Vec::new();
                    for &p in window {
                        let global = base + p as u32;
                        if (global as usize) < n {
                            run.push(global);
                        }
                    }
                    kept += run.len();
                    if !run.is_empty() {
                        runs.push(run);
                    }
                }
                if kept != n {
                    return Err(Error::Runtime(format!(
                        "multi-block perm lost entries: {kept} of {n}"
                    )));
                }
                return Ok(runs);
            }
        }
        let (block, entry) = self.pick_block(n);
        let block = block as usize;
        debug_assert!(n <= block);
        let mut padded = prefixes.to_vec();
        padded.resize(block, u64::MAX);
        let out = self.client.execute(
            entry,
            vec![
                Tensor::U64(padded),
                Tensor::U64(self.splitters_padded.clone()),
            ],
        )?;
        let perm = out[1].as_i32()?;
        // Padding keys are u64::MAX with indices >= n; the stable network
        // sinks them to the tail *after* any real MAX-prefix keys — but a
        // real key CAN be MAX, so filter by index rather than position.
        let mut order = Vec::with_capacity(n);
        for &p in perm {
            if (p as usize) < n {
                order.push(p as u32);
            }
        }
        if order.len() != n {
            return Err(Error::Runtime(format!(
                "kernel perm lost entries: {} of {n}",
                order.len()
            )));
        }
        Ok(vec![order])
    }
}

impl BlockProcessor for KernelBlockProcessor {
    fn process(
        &self,
        pairs: Vec<(Vec<u8>, Vec<u8>)>,
        n_reduces: u32,
    ) -> Result<Vec<Vec<(Vec<u8>, Vec<u8>)>>> {
        let chunk_cap = self
            .multi
            .as_ref()
            .map(|(t, _, _)| *t as usize)
            .unwrap_or_else(|| self.blocks.last().unwrap().0 as usize);
        let mut out: Vec<Vec<(Vec<u8>, Vec<u8>)>> = (0..n_reduces).map(|_| Vec::new()).collect();

        // Process in kernel-sized chunks; each chunk may come back as
        // several sorted runs (multi-block artifact). Multi-run outputs get
        // one per-partition merge pass at the end.
        let mut chunks: Vec<Vec<(Vec<u8>, Vec<u8>)>> = Vec::new();
        let mut current = Vec::new();
        for p in pairs {
            current.push(p);
            if current.len() == chunk_cap {
                chunks.push(std::mem::take(&mut current));
            }
        }
        if !current.is_empty() {
            chunks.push(current);
        }

        let mut total_runs = 0usize;
        for chunk in chunks {
            let prefixes: Vec<u64> = chunk.iter().map(|(k, _)| key_prefix_u64(k)).collect();
            let runs = self.sorted_runs(&prefixes)?;
            total_runs += runs.len();
            let mut taken: Vec<Option<(Vec<u8>, Vec<u8>)>> =
                chunk.into_iter().map(Some).collect();
            for order in runs {
                // Apply the permutation to full records.
                let mut sorted: Vec<(Vec<u8>, Vec<u8>)> = Vec::with_capacity(order.len());
                for &i in &order {
                    sorted.push(taken[i as usize].take().expect("perm is a permutation"));
                }

                // Fix-up: the kernel sorted by the 8-byte prefix; resolve
                // ties on the full 10-byte key within equal-prefix runs.
                let mut i = 0;
                while i < sorted.len() {
                    let mut j = i + 1;
                    let pi = key_prefix_u64(&sorted[i].0);
                    while j < sorted.len() && key_prefix_u64(&sorted[j].0) == pi {
                        j += 1;
                    }
                    if j - i > 1 {
                        sorted[i..j].sort_by(|a, b| a.0.cmp(&b.0));
                    }
                    i = j;
                }

                // Route the sorted run (partitioning is monotone: one scan).
                for (k, v) in sorted {
                    let p = self
                        .partitioner
                        .route(key_prefix_u64(&k))
                        .min(n_reduces.saturating_sub(1)) as usize;
                    out[p].push((k, v));
                }
            }
        }

        if total_runs > 1 {
            // Per-partition contributions from different runs are each
            // sorted but interleaved; restore order with one merge-ish
            // sort pass (partitions are small relative to the block).
            for part in &mut out {
                part.sort_by(|a, b| a.0.cmp(&b.0));
            }
        }
        Ok(out)
    }

    fn name(&self) -> &'static str {
        "pallas-pjrt"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifacts::default_dir;
    use crate::runtime::pjrt::shared_client;
    use crate::terasort::format::record_for_row;
    use crate::util::rng::Rng;

    fn partitioner(n: u32, seed: u64) -> RangePartitioner {
        let mut rng = Rng::new(seed);
        let samples: Vec<u64> = (0..4000).map(|_| rng.next_u64()).collect();
        RangePartitioner::from_samples(samples, n).unwrap()
    }

    fn pairs(n: usize, seed: u64) -> Vec<(Vec<u8>, Vec<u8>)> {
        (0..n)
            .map(|i| {
                let rec = record_for_row(seed, i as u64);
                (rec[..10].to_vec(), rec[10..].to_vec())
            })
            .collect()
    }

    #[test]
    fn rust_processor_outputs_sorted_partitions() {
        let p = RustBlockProcessor {
            partitioner: partitioner(8, 1),
        };
        let out = p.process(pairs(5000, 42), 8).unwrap();
        assert_eq!(out.len(), 8);
        let total: usize = out.iter().map(Vec::len).sum();
        assert_eq!(total, 5000);
        for part in &out {
            assert!(part.windows(2).all(|w| w[0].0 <= w[1].0));
        }
    }

    #[test]
    fn kernel_parity_with_rust_path() {
        if !default_dir().join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let client = shared_client().unwrap();
        let part = partitioner(16, 2);
        let kernel = KernelBlockProcessor::new(client, part.clone()).unwrap();
        let rust = RustBlockProcessor { partitioner: part };
        for &n in &[100usize, 2048, 3000, 9000] {
            let a = kernel.process(pairs(n, 7), 16).unwrap();
            let b = rust.process(pairs(n, 7), 16).unwrap();
            assert_eq!(a, b, "parity failed at n={n}");
        }
    }

    #[test]
    fn kernel_rejects_max_sentinel_splitter() {
        if !default_dir().join("manifest.json").exists() {
            return;
        }
        let client = shared_client().unwrap();
        let bad = RangePartitioner {
            splitters: vec![5, u64::MAX],
        };
        assert!(KernelBlockProcessor::new(client, bad).is_err());
    }
}
