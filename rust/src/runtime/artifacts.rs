//! Artifact manifest: locate and describe the AOT-lowered HLO modules
//! produced by `python/compile/aot.py` (`make artifacts`).

use crate::codec::json::Json;
use crate::error::{Error, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Tensor spec of one kernel input/output.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    pub name: String,
    /// "uint64" / "u64" / "int32" / "s32" (aot.py emits numpy-style for
    /// inputs and short names for outputs; both are accepted).
    pub dtype: String,
    pub shape: Vec<u64>,
}

impl TensorSpec {
    pub fn elements(&self) -> u64 {
        self.shape.iter().product()
    }

    pub fn is_u64(&self) -> bool {
        matches!(self.dtype.as_str(), "uint64" | "u64")
    }

    pub fn is_i32(&self) -> bool {
        matches!(self.dtype.as_str(), "int32" | "s32")
    }
}

/// One AOT entry point.
#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct ArtifactManifest {
    pub dir: PathBuf,
    pub entries: BTreeMap<String, ArtifactEntry>,
}

/// Locate the artifacts directory: `HPCW_ARTIFACTS` env var, else
/// `./artifacts`, else `<crate root>/artifacts`.
pub fn default_dir() -> PathBuf {
    if let Ok(d) = std::env::var("HPCW_ARTIFACTS") {
        return PathBuf::from(d);
    }
    let cwd = PathBuf::from("artifacts");
    if cwd.join("manifest.json").exists() {
        return cwd;
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

impl ArtifactManifest {
    pub fn load(dir: &Path) -> Result<ArtifactManifest> {
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path).map_err(|e| {
            Error::Runtime(format!(
                "read {} failed ({e}) — run `make artifacts` first",
                manifest_path.display()
            ))
        })?;
        let json = Json::parse(&text)?;
        if json.get("format").and_then(Json::as_str) != Some("hlo-text") {
            return Err(Error::Runtime("manifest: unknown format".into()));
        }
        let mut entries = BTreeMap::new();
        let Some(Json::Obj(list)) = json.get("entries") else {
            return Err(Error::Runtime("manifest: missing entries".into()));
        };
        for (name, e) in list {
            let file = dir.join(e.req_str("file")?);
            if !file.exists() {
                return Err(Error::Runtime(format!(
                    "artifact {} missing file {}",
                    name,
                    file.display()
                )));
            }
            let parse_specs = |key: &str| -> Result<Vec<TensorSpec>> {
                let mut out = Vec::new();
                if let Some(arr) = e.get(key).and_then(Json::as_arr) {
                    for (i, t) in arr.iter().enumerate() {
                        out.push(TensorSpec {
                            name: t
                                .get("name")
                                .and_then(Json::as_str)
                                .unwrap_or(&format!("{key}{i}"))
                                .to_string(),
                            dtype: t.req_str("dtype")?.to_string(),
                            shape: t
                                .get("shape")
                                .and_then(Json::as_arr)
                                .map(|s| s.iter().filter_map(Json::as_u64).collect())
                                .unwrap_or_default(),
                        });
                    }
                }
                Ok(out)
            };
            entries.insert(
                name.clone(),
                ArtifactEntry {
                    name: name.clone(),
                    file,
                    inputs: parse_specs("inputs")?,
                    outputs: parse_specs("outputs")?,
                },
            );
        }
        Ok(ArtifactManifest {
            dir: dir.to_path_buf(),
            entries,
        })
    }

    pub fn entry(&self, name: &str) -> Result<&ArtifactEntry> {
        self.entries
            .get(name)
            .ok_or_else(|| Error::Runtime(format!("no artifact entry '{name}'")))
    }

    /// Entries named `prefix_b<N>...`, sorted by N — used to pick the
    /// smallest block geometry that fits a batch.
    pub fn block_sizes(&self, prefix: &str) -> Vec<(u64, String)> {
        let mut out: Vec<(u64, String)> = self
            .entries
            .keys()
            .filter(|k| k.starts_with(prefix))
            .filter_map(|k| {
                let rest = &k[prefix.len()..];
                let b = rest
                    .strip_prefix("_b")?
                    .split('_')
                    .next()?
                    .parse::<u64>()
                    .ok()?;
                Some((b, k.clone()))
            })
            .collect();
        out.sort();
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest() -> Option<ArtifactManifest> {
        let dir = default_dir();
        ArtifactManifest::load(&dir).ok()
    }

    #[test]
    fn manifest_loads_when_built() {
        let Some(m) = manifest() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        assert!(m.entries.len() >= 5);
        let e = m.entry("partition_b4096_s127").unwrap();
        assert!(e.inputs[0].is_u64());
        assert_eq!(e.inputs[0].shape, vec![4096]);
        assert_eq!(e.outputs[1].name, "counts");
        assert!(e.outputs[1].is_i32());
    }

    #[test]
    fn block_size_listing() {
        let Some(m) = manifest() else {
            return;
        };
        let parts = m.block_sizes("partition");
        assert_eq!(parts.len(), 2);
        assert!(parts[0].0 < parts[1].0);
        let maps = m.block_sizes("mapphase");
        assert_eq!(maps.first().map(|e| e.0), Some(2048));
    }

    #[test]
    fn missing_dir_is_a_clean_error() {
        let err = ArtifactManifest::load(Path::new("/nonexistent-xyz")).unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }
}
