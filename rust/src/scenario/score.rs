//! Scenario scoring: per-SLA-tier violation rates against energy spent.
//!
//! Everything is an integer (basis points for rates, watt-milliseconds —
//! i.e. millijoules — for energy) so the wire encoding is float-free and
//! byte-stable across languages.

use crate::scenario::spec::TIERS;

/// Violation accounting for one SLA tier.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct TierScore {
    /// Tasks the scenario emitted into this tier.
    pub tasks: u64,
    /// Tasks that missed their deadline (or, for batch, never finished).
    pub violations: u64,
}

impl TierScore {
    /// Violation rate in basis points (0..=10000).
    pub fn violation_bp(&self) -> u64 {
        if self.tasks == 0 {
            0
        } else {
            self.violations * 10_000 / self.tasks
        }
    }
}

/// Energy/provisioning accounting integrated over the timeline.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct EnergyScore {
    /// Node-milliseconds of admitted (active or idle) capacity.
    pub node_ms: u64,
    /// Core-milliseconds actually running tasks.
    pub busy_core_ms: u64,
    /// Node-milliseconds admitted but running nothing (warm waste).
    pub idle_node_ms: u64,
    /// Sleep→active transitions (each charged `wake_ms` at active power).
    pub wakeups: u64,
    /// Node-milliseconds spent waking (unusable but at active power).
    pub wake_ms: u64,
    /// Total energy in millijoules (watts × milliseconds) across active,
    /// idle, waking and sleeping nodes.
    pub energy_mj: u64,
}

/// The scored outcome of one scenario run.
#[derive(Debug, Default, Clone, PartialEq, Eq)]
pub struct ScoreDoc {
    pub scenario: String,
    pub policy: String,
    pub duration_ms: u64,
    pub ticks: u64,
    /// Indexed like [`TIERS`]: sla0, sla1, sla2, batch.
    pub tiers: [TierScore; 4],
    pub energy: EnergyScore,
    /// Most NodeManagers alive at any tick.
    pub peak_nodes: u32,
    /// Nodes granted by the batch scheduler over the run.
    pub grants: u64,
    /// Nodes drained back to the batch scheduler over the run.
    pub drains: u64,
}

impl ScoreDoc {
    /// SLA0 violation rate in basis points — the headline number the
    /// bench gate compares across policies.
    pub fn sla0_violation_bp(&self) -> u64 {
        self.tiers[0].violation_bp()
    }

    /// One-line human summary (CLI output).
    pub fn summary(&self) -> String {
        let mut parts: Vec<String> = Vec::new();
        for (tier, score) in TIERS.iter().zip(self.tiers.iter()) {
            parts.push(format!(
                "{}={}bp({}/{})",
                tier.name(),
                score.violation_bp(),
                score.violations,
                score.tasks
            ));
        }
        format!(
            "{} [{}]: {} energy={}J idle={}s wakeups={} peak={}",
            self.scenario,
            self.policy,
            parts.join(" "),
            self.energy.energy_mj / 1_000,
            self.energy.idle_node_ms / 1_000,
            self.energy.wakeups,
            self.peak_nodes
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn violation_rate_in_basis_points() {
        let t = TierScore {
            tasks: 400,
            violations: 3,
        };
        assert_eq!(t.violation_bp(), 75);
        assert_eq!(TierScore::default().violation_bp(), 0);
    }

    #[test]
    fn summary_reports_all_tiers() {
        let mut s = ScoreDoc {
            scenario: "spike".into(),
            policy: "sla_energy".into(),
            ..ScoreDoc::default()
        };
        s.tiers[0] = TierScore {
            tasks: 100,
            violations: 1,
        };
        let line = s.summary();
        assert!(line.contains("sla0=100bp(1/100)"), "{line}");
        assert!(line.contains("batch=0bp(0/0)"), "{line}");
    }
}
