//! Scenario harness: declarative workloads scored against autoscaling
//! policies.
//!
//! The paper's elasticity story ("scales seamlessly from a few cores to
//! thousands of cores") is exercised here as a cloudsim-style what-if
//! harness: a [`ScenarioSpec`] declares machine classes (cores, MIPS
//! tier, power/sleep states, wake-up cost), task classes (arrival
//! process, runtime, memory, SLA tier) and load shapes (spikes, sparse
//! windows, diurnal cycles); the [`Runner`] drives the real
//! [`crate::cluster::ClusterManager`] + [`crate::wrapper::DynamicCluster`]
//! stack through the timeline under a selectable
//! [`crate::cluster::ScalePolicy`]; the [`ScoreDoc`] reports per-tier
//! SLA violation rates against energy spent. Specs parse from TOML
//! (`examples/scenarios/`) or arrive as JSON via `POST /v1/scenarios`;
//! see `docs/SCENARIOS.md`.

pub mod runner;
pub mod score;
pub mod spec;

pub use runner::Runner;
pub use score::{EnergyScore, ScoreDoc, TierScore};
pub use spec::{
    LoadShape, MachineClass, ScenarioSpec, SlaTier, TaskClass, REFERENCE_MIPS, TIERS,
};
