//! The declarative scenario spec: machine classes, task classes and load
//! shapes, cloudsim-style (machine classes carry power/sleep states and a
//! per-class MIPS tier; task classes carry an arrival process, an expected
//! runtime and an SLA tier).
//!
//! A spec parses from TOML (named sub-tables, `[machine_class.<name>]` /
//! `[task_class.<name>]` — the in-tree TOML subset has no array-of-tables)
//! or from the JSON wire form in [`crate::api::wire`]. Every field is an
//! integer so both encodings are float-format-free and canonical.

use crate::codec::toml::TomlDoc;
use crate::error::{Error, Result};
use std::collections::BTreeSet;

/// SLA tiers, strictest first. `Batch` carries no deadline.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum SlaTier {
    Sla0,
    Sla1,
    Sla2,
    Batch,
}

/// All tiers, strictest first (canonical report order).
pub const TIERS: [SlaTier; 4] = [SlaTier::Sla0, SlaTier::Sla1, SlaTier::Sla2, SlaTier::Batch];

impl SlaTier {
    pub fn name(&self) -> &'static str {
        match self {
            SlaTier::Sla0 => "sla0",
            SlaTier::Sla1 => "sla1",
            SlaTier::Sla2 => "sla2",
            SlaTier::Batch => "batch",
        }
    }

    pub fn from_name(s: &str) -> Result<SlaTier> {
        match s {
            "sla0" => Ok(SlaTier::Sla0),
            "sla1" => Ok(SlaTier::Sla1),
            "sla2" => Ok(SlaTier::Sla2),
            "batch" => Ok(SlaTier::Batch),
            other => Err(Error::Config(format!(
                "unknown SLA tier '{other}' (sla0|sla1|sla2|batch)"
            ))),
        }
    }

    /// Completion deadline as a percentage of the task's nominal runtime
    /// (cloudsim's SLA0 ≤ 1.2×, SLA1 ≤ 1.5×, SLA2 ≤ 2.0×); `None` for
    /// batch — it only has to finish.
    pub fn deadline_factor_pct(&self) -> Option<u64> {
        match self {
            SlaTier::Sla0 => Some(120),
            SlaTier::Sla1 => Some(150),
            SlaTier::Sla2 => Some(200),
            SlaTier::Batch => None,
        }
    }

    pub fn index(&self) -> usize {
        match self {
            SlaTier::Sla0 => 0,
            SlaTier::Sla1 => 1,
            SlaTier::Sla2 => 2,
            SlaTier::Batch => 3,
        }
    }
}

/// Arrival-window modulation of a task class.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LoadShape {
    /// Arrivals throughout `[start_ms, end_ms)`. Spikes are steady
    /// classes with a narrow window and a small inter-arrival.
    Steady,
    /// On/off cycling: arrivals only while
    /// `(t - start_ms) % period_ms < period_ms * duty_pct / 100`.
    Diurnal { period_ms: u64, duty_pct: u64 },
}

impl LoadShape {
    pub fn name(&self) -> &'static str {
        match self {
            LoadShape::Steady => "steady",
            LoadShape::Diurnal { .. } => "diurnal",
        }
    }

    /// Is the class emitting arrivals at `t` (ms since scenario start)?
    pub fn open_at(&self, t: u64, start_ms: u64) -> bool {
        match *self {
            LoadShape::Steady => true,
            LoadShape::Diurnal { period_ms, duty_pct } => {
                (t - start_ms) % period_ms < period_ms * duty_pct / 100
            }
        }
    }
}

/// One machine class: a homogeneous slice of the node pool with its own
/// speed tier and power model (cloudsim machine classes: cores, MIPS,
/// S-states and a wake-up cost).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MachineClass {
    pub name: String,
    pub count: u32,
    pub cores: u32,
    pub mem_mb: u64,
    /// Per-core speed; nominal task runtimes assume [`REFERENCE_MIPS`].
    pub mips: u64,
    /// Power draw (watts) with at least one task running.
    pub active_w: u64,
    /// Power draw while admitted but idle (warm capacity cost).
    pub idle_w: u64,
    /// Power draw while released to the batch pool (deep sleep).
    pub sleep_w: u64,
    /// Sleep→active latency; a freshly granted node accepts no tasks
    /// until the wake completes (charged at `active_w`).
    pub wake_ms: u64,
    /// Tiers this class may serve; empty = all four.
    pub tiers: Vec<SlaTier>,
}

/// The MIPS tier nominal task runtimes are quoted at; a class with
/// `mips = 2000` halves them, `mips = 500` doubles them.
pub const REFERENCE_MIPS: u64 = 1000;

impl MachineClass {
    pub fn serves(&self, tier: SlaTier) -> bool {
        self.tiers.is_empty() || self.tiers.contains(&tier)
    }

    /// Does this class serve nothing but batch work? (Preferred
    /// power-down victim for the SLA/energy policy.)
    pub fn batch_only(&self) -> bool {
        !self.tiers.is_empty() && self.tiers.iter().all(|t| *t == SlaTier::Batch)
    }

    /// Actual runtime of a nominal `runtime_ms` task on this class.
    pub fn scaled_runtime_ms(&self, runtime_ms: u64) -> u64 {
        (runtime_ms * REFERENCE_MIPS / self.mips.max(1)).max(1)
    }
}

/// One task class: an arrival process emitting identical tasks into one
/// SLA tier (cloudsim task classes: start/end, inter-arrival, expected
/// runtime, memory, SLA type, seed).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TaskClass {
    pub name: String,
    pub tier: SlaTier,
    pub start_ms: u64,
    pub end_ms: u64,
    pub inter_arrival_ms: u64,
    /// Nominal runtime at [`REFERENCE_MIPS`]; the deadline is
    /// `arrival + deadline_factor × runtime_ms` regardless of which
    /// class the task lands on.
    pub runtime_ms: u64,
    pub mem_mb: u64,
    pub shape: LoadShape,
    /// Per-class stream for runtime jitter (forked off the spec seed).
    pub seed: u64,
}

/// A complete scenario: the cluster shape, the autoscaling policy under
/// test and the workload timeline.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ScenarioSpec {
    pub name: String,
    pub duration_ms: u64,
    /// Control-cycle period; arrivals/completions/energy integrate at
    /// this resolution.
    pub tick_ms: u64,
    pub seed: u64,
    /// `grow_on_backlog` or `sla_energy`.
    pub policy: String,
    pub warm_spares: u32,
    pub batch_backlog_per_node: u32,
    pub nodes_min: u32,
    pub nodes_max: u32,
    /// Simulated batch-queue grant delay (PBS/SLURM queue wait).
    pub queue_delay_ms: u64,
    pub machine_classes: Vec<MachineClass>,
    pub task_classes: Vec<TaskClass>,
}

impl ScenarioSpec {
    /// Parse the TOML form (`[machine_class.<name>]` sub-tables; see
    /// `docs/SCENARIOS.md` and `examples/scenarios/`).
    pub fn from_toml(text: &str) -> Result<ScenarioSpec> {
        let doc = TomlDoc::parse(text)?;
        let req_u64 = |key: &str| -> Result<u64> {
            doc.u64(key)
                .ok_or_else(|| Error::Config(format!("scenario: missing '{key}'")))
        };
        let mut spec = ScenarioSpec {
            name: doc
                .str("name")
                .ok_or_else(|| Error::Config("scenario: missing 'name'".into()))?
                .to_string(),
            duration_ms: req_u64("duration_ms")?,
            tick_ms: doc.u64("tick_ms").unwrap_or(1_000),
            seed: doc.u64("seed").unwrap_or(0),
            policy: doc.str("policy").unwrap_or("grow_on_backlog").to_string(),
            warm_spares: doc.u64("warm_spares").unwrap_or(1) as u32,
            batch_backlog_per_node: doc.u64("batch_backlog_per_node").unwrap_or(4) as u32,
            nodes_min: req_u64("nodes_min")? as u32,
            nodes_max: req_u64("nodes_max")? as u32,
            queue_delay_ms: doc.u64("queue_delay_ms").unwrap_or(500),
            machine_classes: Vec::new(),
            task_classes: Vec::new(),
        };
        for name in table_names(&doc, "machine_class") {
            let k = |f: &str| format!("machine_class.{name}.{f}");
            let req = |f: &str| -> Result<u64> {
                doc.u64(&k(f))
                    .ok_or_else(|| Error::Config(format!("machine_class.{name}: missing '{f}'")))
            };
            let tiers = match doc.get(&k("tiers")) {
                None => Vec::new(),
                Some(v) => match v {
                    crate::codec::toml::TomlValue::Arr(items) => items
                        .iter()
                        .map(|t| {
                            t.as_str()
                                .ok_or_else(|| {
                                    Error::Config(format!(
                                        "machine_class.{name}: tiers must be strings"
                                    ))
                                })
                                .and_then(SlaTier::from_name)
                        })
                        .collect::<Result<Vec<_>>>()?,
                    _ => {
                        return Err(Error::Config(format!(
                            "machine_class.{name}: tiers must be an array"
                        )))
                    }
                },
            };
            spec.machine_classes.push(MachineClass {
                name: name.clone(),
                count: req("count")? as u32,
                cores: req("cores")? as u32,
                mem_mb: req("mem_mb")?,
                mips: doc.u64(&k("mips")).unwrap_or(REFERENCE_MIPS),
                active_w: doc.u64(&k("active_w")).unwrap_or(200),
                idle_w: doc.u64(&k("idle_w")).unwrap_or(100),
                sleep_w: doc.u64(&k("sleep_w")).unwrap_or(10),
                wake_ms: doc.u64(&k("wake_ms")).unwrap_or(0),
                tiers,
            });
        }
        for name in table_names(&doc, "task_class") {
            let k = |f: &str| format!("task_class.{name}.{f}");
            let req = |f: &str| -> Result<u64> {
                doc.u64(&k(f))
                    .ok_or_else(|| Error::Config(format!("task_class.{name}: missing '{f}'")))
            };
            let tier = SlaTier::from_name(
                doc.str(&k("tier"))
                    .ok_or_else(|| Error::Config(format!("task_class.{name}: missing 'tier'")))?,
            )?;
            let shape = match doc.str(&k("shape")).unwrap_or("steady") {
                "steady" => LoadShape::Steady,
                "diurnal" => LoadShape::Diurnal {
                    period_ms: req("period_ms")?,
                    duty_pct: req("duty_pct")?,
                },
                other => {
                    return Err(Error::Config(format!(
                        "task_class.{name}: unknown shape '{other}' (steady|diurnal)"
                    )))
                }
            };
            spec.task_classes.push(TaskClass {
                name: name.clone(),
                tier,
                start_ms: doc.u64(&k("start_ms")).unwrap_or(0),
                end_ms: doc.u64(&k("end_ms")).unwrap_or(spec.duration_ms),
                inter_arrival_ms: req("inter_arrival_ms")?,
                runtime_ms: req("runtime_ms")?,
                mem_mb: doc.u64(&k("mem_mb")).unwrap_or(1024),
                shape,
                seed: doc.u64(&k("seed")).unwrap_or(0),
            });
        }
        spec.validate()?;
        Ok(spec)
    }

    /// Total nodes across machine classes.
    pub fn total_nodes(&self) -> u32 {
        self.machine_classes.iter().map(|c| c.count).sum()
    }

    pub fn validate(&self) -> Result<()> {
        if self.name.is_empty() {
            return Err(Error::Config("scenario: name must be non-empty".into()));
        }
        if self.duration_ms == 0 || self.tick_ms == 0 {
            return Err(Error::Config(
                "scenario: duration_ms and tick_ms must be > 0".into(),
            ));
        }
        if self.duration_ms / self.tick_ms > 100_000 {
            return Err(Error::Config(
                "scenario: more than 100000 ticks (shrink duration or grow tick_ms)".into(),
            ));
        }
        if !matches!(self.policy.as_str(), "grow_on_backlog" | "sla_energy") {
            return Err(Error::Config(format!(
                "scenario: unknown policy '{}' (grow_on_backlog | sla_energy)",
                self.policy
            )));
        }
        if self.machine_classes.is_empty() {
            return Err(Error::Config("scenario: no machine classes".into()));
        }
        if self.task_classes.is_empty() {
            return Err(Error::Config("scenario: no task classes".into()));
        }
        let mut names = BTreeSet::new();
        for c in &self.machine_classes {
            if !names.insert(&c.name) {
                return Err(Error::Config(format!("duplicate machine class '{}'", c.name)));
            }
            if c.count == 0 || c.cores == 0 || c.mips == 0 {
                return Err(Error::Config(format!(
                    "machine_class.{}: count, cores and mips must be > 0",
                    c.name
                )));
            }
        }
        let mut names = BTreeSet::new();
        for t in &self.task_classes {
            if !names.insert(&t.name) {
                return Err(Error::Config(format!("duplicate task class '{}'", t.name)));
            }
            if t.inter_arrival_ms == 0 || t.runtime_ms == 0 {
                return Err(Error::Config(format!(
                    "task_class.{}: inter_arrival_ms and runtime_ms must be > 0",
                    t.name
                )));
            }
            if t.end_ms <= t.start_ms {
                return Err(Error::Config(format!(
                    "task_class.{}: end_ms must exceed start_ms",
                    t.name
                )));
            }
            if let LoadShape::Diurnal { period_ms, duty_pct } = t.shape {
                if period_ms == 0 || duty_pct == 0 || duty_pct > 100 {
                    return Err(Error::Config(format!(
                        "task_class.{}: diurnal needs period_ms > 0 and duty_pct in 1..=100",
                        t.name
                    )));
                }
            }
            if !self.machine_classes.iter().any(|c| c.serves(t.tier)) {
                return Err(Error::Config(format!(
                    "task_class.{}: no machine class serves tier {}",
                    t.name,
                    t.tier.name()
                )));
            }
        }
        if self.nodes_min == 0 {
            return Err(Error::Config(
                "scenario: nodes_min must be >= 1 (the RM needs a slave)".into(),
            ));
        }
        if self.nodes_min > self.nodes_max {
            return Err(Error::Config(format!(
                "scenario: nodes_min ({}) exceeds nodes_max ({})",
                self.nodes_min, self.nodes_max
            )));
        }
        if self.total_nodes() < self.nodes_min {
            return Err(Error::Config(format!(
                "scenario: machine classes provide {} nodes, below nodes_min {}",
                self.total_nodes(),
                self.nodes_min
            )));
        }
        Ok(())
    }
}

/// Distinct sub-table names under `prefix` (sorted: TomlDoc's entry map
/// is a BTreeMap, so scenario parsing is order-stable).
fn table_names(doc: &TomlDoc, prefix: &str) -> Vec<String> {
    let mut out: Vec<String> = Vec::new();
    for key in doc.keys_under(prefix) {
        let rest = &key[prefix.len() + 1..];
        if let Some((name, _)) = rest.split_once('.') {
            if out.last().map(String::as_str) != Some(name) {
                out.push(name.to_string());
            }
        }
    }
    out.dedup();
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    pub(crate) const SPIKE_TOML: &str = r#"
name = "spike"
duration_ms = 120000
tick_ms = 1000
seed = 7
policy = "sla_energy"
warm_spares = 1
nodes_min = 2
nodes_max = 8
queue_delay_ms = 2000

[machine_class.fast]
count = 6
cores = 4
mem_mb = 8192
mips = 1500
active_w = 220
idle_w = 90
sleep_w = 8
wake_ms = 3000

[machine_class.bulk]
count = 2
cores = 8
mem_mb = 16384
mips = 800
tiers = ["batch"]

[task_class.web]
tier = "sla0"
start_ms = 30000
end_ms = 60000
inter_arrival_ms = 500
runtime_ms = 2000
mem_mb = 1024

[task_class.night]
tier = "batch"
inter_arrival_ms = 4000
runtime_ms = 8000
shape = "diurnal"
period_ms = 60000
duty_pct = 50
"#;

    #[test]
    fn toml_round_trip_fields() {
        let spec = ScenarioSpec::from_toml(SPIKE_TOML).unwrap();
        assert_eq!(spec.name, "spike");
        assert_eq!(spec.policy, "sla_energy");
        assert_eq!(spec.machine_classes.len(), 2);
        let bulk = &spec.machine_classes[0]; // BTreeMap order: bulk < fast
        assert_eq!(bulk.name, "bulk");
        assert!(bulk.batch_only());
        assert!(!bulk.serves(SlaTier::Sla0));
        let fast = &spec.machine_classes[1];
        assert_eq!(fast.wake_ms, 3000);
        assert!(fast.serves(SlaTier::Sla0));
        assert!(!fast.batch_only());
        assert_eq!(spec.task_classes.len(), 2);
        let night = &spec.task_classes[0];
        assert_eq!(night.tier, SlaTier::Batch);
        assert_eq!(
            night.shape,
            LoadShape::Diurnal {
                period_ms: 60000,
                duty_pct: 50
            }
        );
        // end_ms defaults to the scenario duration.
        assert_eq!(night.end_ms, 120000);
        assert_eq!(spec.total_nodes(), 8);
    }

    #[test]
    fn runtime_scales_with_mips() {
        let spec = ScenarioSpec::from_toml(SPIKE_TOML).unwrap();
        let fast = &spec.machine_classes[1];
        assert_eq!(fast.scaled_runtime_ms(3000), 2000); // 1500 MIPS
        let bulk = &spec.machine_classes[0];
        assert_eq!(bulk.scaled_runtime_ms(3000), 3750); // 800 MIPS
    }

    #[test]
    fn diurnal_shape_gates_arrivals() {
        let d = LoadShape::Diurnal {
            period_ms: 100,
            duty_pct: 30,
        };
        assert!(d.open_at(0, 0));
        assert!(d.open_at(29, 0));
        assert!(!d.open_at(30, 0));
        assert!(!d.open_at(99, 0));
        assert!(d.open_at(100, 0));
        // Phase is relative to the class window start.
        assert!(d.open_at(50, 50));
    }

    #[test]
    fn deadlines_tighten_with_tier() {
        assert_eq!(SlaTier::Sla0.deadline_factor_pct(), Some(120));
        assert_eq!(SlaTier::Sla1.deadline_factor_pct(), Some(150));
        assert_eq!(SlaTier::Sla2.deadline_factor_pct(), Some(200));
        assert_eq!(SlaTier::Batch.deadline_factor_pct(), None);
    }

    #[test]
    fn validation_rejects_bad_specs() {
        // Unknown policy.
        let bad = SPIKE_TOML.replace("sla_energy", "psychic");
        assert!(ScenarioSpec::from_toml(&bad).is_err());
        // SLA0 work with no class able to serve it.
        let mut orphan = ScenarioSpec::from_toml(SPIKE_TOML).unwrap();
        orphan.machine_classes[1].tiers = vec![SlaTier::Batch];
        assert!(orphan.validate().is_err());
        // Pool smaller than the floor.
        let bad = SPIKE_TOML.replace("nodes_min = 2", "nodes_min = 20");
        assert!(ScenarioSpec::from_toml(&bad).is_err());
        // Unknown tier name.
        let bad = SPIKE_TOML.replace("tier = \"sla0\"", "tier = \"gold\"");
        assert!(ScenarioSpec::from_toml(&bad).is_err());
    }
}
