//! The scenario runner: drives a live [`DynamicCluster`] through the
//! declarative workload timeline and scores the run.
//!
//! The runner is a discrete-time simulation at `tick_ms` resolution.
//! Each tick it (1) completes finished tasks, (2) emits arrivals from
//! every task class whose window is open, (3) finishes node wake-ups,
//! (4) places queued tasks strictest tier first, (5) hands the per-tier
//! backlog and occupancy to [`ClusterManager::tick_with`] so the
//! configured [`ScalePolicy`] can grow or power down the cluster, and
//! (6) integrates the power model (active / idle / waking / sleeping
//! watts per machine class) into the [`ScoreDoc`].
//!
//! Node identity layout: node 0 is the RM, node 1 the JHS (fixed
//! overhead, excluded from scoring); machine classes occupy contiguous
//! id ranges from 2, SLA-capable classes first so the batch scheduler's
//! FIFO pool grants general-purpose nodes before batch-only ones and
//! the initial `nodes_min` slaves can serve every tier. The initial
//! slaves are *not* leased from the allocator, so no policy can drain
//! them — the `nodes_min` floor is structural.
//!
//! Determinism: all randomness comes from per-class streams forked off
//! `spec.seed`, iteration is over `BTreeMap`s, and placement is
//! tick-quantized — the same spec always produces byte-identical
//! [`ScoreDoc`]s, which is what lets CI gate on scored baselines.

use crate::cluster::batch::{GrowOnBacklogPolicy, SlaEnergyPolicy, TierBacklog};
use crate::cluster::{ClusterManager, NodeId};
use crate::config::{ElasticConfig, StackConfig};
use crate::error::{Error, Result};
use crate::lustre::LustreFs;
use crate::metrics::Metrics;
use crate::scenario::score::ScoreDoc;
use crate::scenario::spec::{ScenarioSpec, SlaTier, TIERS};
use crate::util::ids::IdGen;
use crate::util::rng::Rng;
use crate::util::time::Micros;
use crate::wrapper::DynamicCluster;
use std::collections::{BTreeMap, BTreeSet, VecDeque};
use std::sync::Arc;

/// Power/availability state of one scenario node.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum PowerState {
    /// Released to the batch pool (deep sleep, `sleep_w`).
    Sleeping,
    /// Granted and admitted, but inside the class wake-up latency:
    /// draws `active_w`, accepts no tasks.
    Waking { until: u64 },
    /// Admitted and able to run tasks.
    Ready,
}

#[derive(Debug)]
struct SimNode {
    /// Index into `spec.machine_classes`.
    class: usize,
    state: PowerState,
    used_cores: u32,
    used_mem: u64,
}

/// A queued task instance.
#[derive(Debug)]
struct SimTask {
    /// Absolute completion deadline; `None` for batch.
    deadline: Option<u64>,
    /// Jittered nominal runtime at `REFERENCE_MIPS`.
    runtime_ms: u64,
    mem_mb: u64,
}

#[derive(Debug)]
struct RunningTask {
    node: NodeId,
    end: u64,
    mem_mb: u64,
    tier: SlaTier,
}

/// Arrival cursor of one task class.
#[derive(Debug)]
struct ArrivalCursor {
    class: usize,
    next: u64,
    rng: Rng,
}

/// Drives one [`ScenarioSpec`] to completion. [`Runner::run`] is the
/// one-shot entry point; [`Runner::step`] exposes single ticks so tests
/// can assert per-tick invariants (e.g. the `nodes_min` floor).
pub struct Runner {
    spec: ScenarioSpec,
    #[allow(dead_code)]
    fs: LustreFs,
    dc: DynamicCluster,
    cm: ClusterManager,
    nodes: BTreeMap<NodeId, SimNode>,
    queues: [VecDeque<SimTask>; 4],
    running: Vec<RunningTask>,
    cursors: Vec<ArrivalCursor>,
    /// How far ahead of an SLA0 window the runner reports it open:
    /// the full provisioning latency (queue delay + worst wake-up).
    anticipate_ms: u64,
    now_ms: u64,
    score: ScoreDoc,
}

impl Runner {
    pub fn new(spec: ScenarioSpec) -> Result<Runner> {
        spec.validate()?;

        // Node layout: RM 0, JHS 1, then contiguous class ranges with
        // SLA-capable classes first.
        let mut order: Vec<usize> = (0..spec.machine_classes.len())
            .filter(|&i| !spec.machine_classes[i].batch_only())
            .collect();
        order.extend((0..spec.machine_classes.len()).filter(|&i| spec.machine_classes[i].batch_only()));
        let mut nodes = BTreeMap::new();
        let mut batch_only = BTreeSet::new();
        let mut all_ids = Vec::new();
        // Per-node MIPS profile derived from the class layout: feeds the
        // adaptive scheduler's estimator/placement bias through the
        // elastic config (`docs/SCHEDULING.md`). Reference-speed nodes
        // are left implicit.
        let mut node_mips: Vec<(u32, u64)> = Vec::new();
        let mut next_id = 2u32;
        for &ci in &order {
            let c = &spec.machine_classes[ci];
            for _ in 0..c.count {
                let id = NodeId(next_id);
                next_id += 1;
                nodes.insert(
                    id,
                    SimNode {
                        class: ci,
                        state: PowerState::Sleeping,
                        used_cores: 0,
                        used_mem: 0,
                    },
                );
                if c.batch_only() {
                    batch_only.insert(id);
                }
                if c.mips != crate::scenario::REFERENCE_MIPS {
                    node_mips.push((id.0, c.mips));
                }
                all_ids.push(id);
            }
        }

        // The first `nodes_min` ids are the pilot's seed allocation:
        // admitted at t=0, never leased, so never drainable. The rest
        // form the batch scheduler's free pool.
        let initial: Vec<NodeId> = all_ids[..spec.nodes_min as usize].to_vec();
        let pool: Vec<NodeId> = all_ids[spec.nodes_min as usize..].to_vec();
        for id in &initial {
            nodes.get_mut(id).unwrap().state = PowerState::Ready;
        }

        let ecfg = ElasticConfig {
            nodes_min: spec.nodes_min,
            nodes_max: spec.nodes_max,
            queue_delay_ms: spec.queue_delay_ms,
            // Leases must outlive the run: power-down is the policy's
            // decision here, never a walltime side effect.
            lease_walltime_s: spec.duration_ms / 1_000 + 3_600,
            nm_timeout_ms: spec.duration_ms + 60_000,
            scale_policy: spec.policy.clone(),
            warm_spares: spec.warm_spares,
            batch_backlog_per_node: spec.batch_backlog_per_node,
            node_mips: node_mips.clone(),
            ..ElasticConfig::default()
        };
        ecfg.validate()?;

        let mut stack = StackConfig::tiny();
        // The same heterogeneous profile reaches the live cluster's RM
        // (and any MapReduce job run against it) via the stack config.
        stack.elastic.node_mips = node_mips;
        let fs = LustreFs::new(&stack.lustre, &stack.cluster);
        let mut build_nodes = vec![NodeId(0), NodeId(1)];
        build_nodes.extend(initial.iter().copied());
        let dc = DynamicCluster::build(
            &stack,
            &build_nodes,
            &fs,
            Arc::new(IdGen::default()),
            Arc::new(Metrics::new()),
            &format!("scenario-{}", spec.name),
            Micros::ZERO,
        )
        .map_err(|e| Error::Config(format!("scenario cluster build: {e}")))?;

        let mut cm = ClusterManager::new(ecfg, pool);
        match spec.policy.as_str() {
            "sla_energy" => cm.set_policy(Box::new(SlaEnergyPolicy {
                warm_spares: spec.warm_spares,
                batch_backlog_per_node: spec.batch_backlog_per_node,
                batch_only,
            })),
            _ => cm.set_policy(Box::new(GrowOnBacklogPolicy)),
        }

        let cursors = spec
            .task_classes
            .iter()
            .enumerate()
            .map(|(i, t)| ArrivalCursor {
                class: i,
                next: t.start_ms,
                rng: Rng::new(spec.seed.wrapping_add(t.seed)).fork(i as u64 + 1),
            })
            .collect();

        let anticipate_ms = spec.queue_delay_ms
            + spec
                .machine_classes
                .iter()
                .filter(|c| c.serves(SlaTier::Sla0))
                .map(|c| c.wake_ms)
                .max()
                .unwrap_or(0);

        let score = ScoreDoc {
            scenario: spec.name.clone(),
            policy: spec.policy.clone(),
            duration_ms: spec.duration_ms,
            peak_nodes: spec.nodes_min,
            ..ScoreDoc::default()
        };

        Ok(Runner {
            spec,
            fs,
            dc,
            cm,
            nodes,
            queues: Default::default(),
            running: Vec::new(),
            cursors,
            anticipate_ms,
            now_ms: 0,
            score,
        })
    }

    /// Logical time of the *next* tick to execute.
    pub fn now_ms(&self) -> u64 {
        self.now_ms
    }

    /// Live NodeManagers (per-tick invariant hooks for tests).
    pub fn nm_count(&self) -> u32 {
        self.dc.rm.nm_count() as u32
    }

    pub fn nodes_min(&self) -> u32 {
        self.spec.nodes_min
    }

    /// Is any SLA0 arrival window open (or opening within the
    /// provisioning latency) at `t`?
    fn sla0_window_open(&self, t: u64) -> bool {
        self.spec.task_classes.iter().any(|c| {
            c.tier == SlaTier::Sla0 && c.start_ms <= t + self.anticipate_ms && t < c.end_ms
        })
    }

    /// Lowest-power candidate for a batch task, fastest for SLA work;
    /// ties break to the lowest node id (BTreeMap order keeps this
    /// deterministic).
    fn pick_node(&self, tier: SlaTier, mem_mb: u64) -> Option<NodeId> {
        let mut best: Option<(NodeId, u64)> = None;
        for (&id, n) in &self.nodes {
            if n.state != PowerState::Ready {
                continue;
            }
            let cls = &self.spec.machine_classes[n.class];
            if !cls.serves(tier) || n.used_cores >= cls.cores || n.used_mem + mem_mb > cls.mem_mb
            {
                continue;
            }
            let better = match best {
                None => true,
                Some((_, bm)) => {
                    if tier == SlaTier::Batch {
                        cls.mips < bm
                    } else {
                        cls.mips > bm
                    }
                }
            };
            if better {
                best = Some((id, cls.mips));
            }
        }
        best.map(|(id, _)| id)
    }

    /// Execute one tick. Returns `false` once the timeline is over.
    pub fn step(&mut self) -> Result<bool> {
        let t = self.now_ms;
        if t >= self.spec.duration_ms {
            return Ok(false);
        }
        let tick = self.spec.tick_ms;

        // 1. Completions free their cores and memory.
        let mut still = Vec::with_capacity(self.running.len());
        for rt in self.running.drain(..) {
            if rt.end <= t {
                let n = self.nodes.get_mut(&rt.node).unwrap();
                n.used_cores -= 1;
                n.used_mem -= rt.mem_mb;
            } else {
                still.push(rt);
            }
        }
        self.running = still;

        // 2. Arrivals due by now. The cursor advances through closed
        // shape phases too (a diurnal off-phase suppresses arrivals, it
        // does not defer them).
        for c in self.cursors.iter_mut() {
            let tc = &self.spec.task_classes[c.class];
            while c.next <= t && c.next < tc.end_ms {
                if tc.shape.open_at(c.next, tc.start_ms) {
                    let jitter = c.rng.range(90, 111); // percent
                    let deadline = tc
                        .tier
                        .deadline_factor_pct()
                        .map(|f| c.next + tc.runtime_ms * f / 100);
                    self.queues[tc.tier.index()].push_back(SimTask {
                        deadline,
                        runtime_ms: (tc.runtime_ms * jitter / 100).max(1),
                        mem_mb: tc.mem_mb,
                    });
                    self.score.tiers[tc.tier.index()].tasks += 1;
                }
                c.next += tc.inter_arrival_ms;
            }
        }

        // 3. Wake-ups complete.
        for n in self.nodes.values_mut() {
            if let PowerState::Waking { until } = n.state {
                if until <= t {
                    n.state = PowerState::Ready;
                }
            }
        }

        // 4. Placement, strictest tier first, FIFO within a tier. A
        // task's violation is decided at placement time: quantized
        // start plus scaled runtime against the arrival deadline.
        for tier in TIERS {
            let qi = tier.index();
            while let Some(front) = self.queues[qi].front() {
                let Some(node) = self.pick_node(tier, front.mem_mb) else {
                    break;
                };
                let task = self.queues[qi].pop_front().unwrap();
                let cls = &self.spec.machine_classes[self.nodes[&node].class];
                let end = t + cls.scaled_runtime_ms(task.runtime_ms);
                if let Some(d) = task.deadline {
                    if end > d {
                        self.score.tiers[qi].violations += 1;
                    }
                }
                let n = self.nodes.get_mut(&node).unwrap();
                n.used_cores += 1;
                n.used_mem += task.mem_mb;
                self.running.push(RunningTask {
                    node,
                    end,
                    mem_mb: task.mem_mb,
                    tier,
                });
            }
        }

        // 5. The elastic control cycle sees post-placement backlog,
        // occupancy and the anticipated SLA0 window.
        let backlog = TierBacklog {
            sla0: self.queues[0].len() as u32,
            sla1: self.queues[1].len() as u32,
            sla2: self.queues[2].len() as u32,
            batch: self.queues[3].len() as u32,
        };
        let window = self.sla0_window_open(t);
        let mut waking = 0u32;
        let busy: BTreeSet<NodeId> = self
            .nodes
            .iter()
            .filter(|(_, n)| {
                if matches!(n.state, PowerState::Waking { .. }) {
                    waking += 1;
                    true
                } else {
                    n.used_cores > 0
                }
            })
            .map(|(&id, _)| id)
            .collect();
        let delta = self
            .cm
            .tick_with(&mut self.dc, backlog, window, waking, &busy, Micros::ms(t))?;
        for node in &delta.joined {
            let n = self.nodes.get_mut(node).unwrap();
            let cls = &self.spec.machine_classes[n.class];
            n.state = if cls.wake_ms > 0 {
                self.score.energy.wakeups += 1;
                PowerState::Waking {
                    until: t + cls.wake_ms,
                }
            } else {
                PowerState::Ready
            };
            self.score.grants += 1;
        }
        for node in &delta.drained {
            self.nodes.get_mut(node).unwrap().state = PowerState::Sleeping;
            self.score.drains += 1;
        }
        debug_assert!(delta.failed.is_empty(), "scenario nodes never miss heartbeats");

        // 6. Integrate the power model over [t, t + tick).
        let mut admitted = 0u32;
        for n in self.nodes.values() {
            let cls = &self.spec.machine_classes[n.class];
            let w = match n.state {
                PowerState::Sleeping => cls.sleep_w,
                PowerState::Waking { .. } => {
                    self.score.energy.wake_ms += tick;
                    cls.active_w
                }
                PowerState::Ready => {
                    if n.used_cores > 0 {
                        cls.active_w
                    } else {
                        self.score.energy.idle_node_ms += tick;
                        cls.idle_w
                    }
                }
            };
            if n.state != PowerState::Sleeping {
                admitted += 1;
                self.score.energy.node_ms += tick;
            }
            self.score.energy.busy_core_ms += n.used_cores as u64 * tick;
            self.score.energy.energy_mj += w * tick;
        }
        self.score.peak_nodes = self.score.peak_nodes.max(admitted);
        self.score.ticks += 1;
        self.now_ms = t + tick;
        Ok(true)
    }

    /// Close the books: tasks still queued past their deadline (or batch
    /// work that never finished) are violations.
    pub fn finish(mut self) -> ScoreDoc {
        let dur = self.spec.duration_ms;
        for (qi, q) in self.queues.iter().enumerate() {
            for task in q {
                match task.deadline {
                    Some(d) if d < dur => self.score.tiers[qi].violations += 1,
                    None => self.score.tiers[qi].violations += 1,
                    _ => {}
                }
            }
        }
        for rt in &self.running {
            if rt.tier == SlaTier::Batch && rt.end > dur {
                self.score.tiers[SlaTier::Batch.index()].violations += 1;
            }
        }
        self.score
    }

    /// Run a spec end to end and score it.
    pub fn run(spec: ScenarioSpec) -> Result<ScoreDoc> {
        let mut r = Runner::new(spec)?;
        while r.step()? {}
        Ok(r.finish())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SPIKE: &str = include_str!("../../../examples/scenarios/spike.toml");
    const UPDOWN: &str = include_str!("../../../examples/scenarios/updown.toml");

    fn with_policy(toml: &str, policy: &str) -> ScenarioSpec {
        let mut spec = ScenarioSpec::from_toml(toml).unwrap();
        spec.policy = policy.to_string();
        spec
    }

    #[test]
    fn runs_are_deterministic() {
        let a = Runner::run(with_policy(SPIKE, "sla_energy")).unwrap();
        let b = Runner::run(with_policy(SPIKE, "sla_energy")).unwrap();
        assert_eq!(a, b, "same spec, same score, bit for bit");
        assert!(a.tiers[0].tasks > 0, "the spike emitted SLA0 work");
    }

    #[test]
    fn sla_policy_beats_backlog_policy_on_spike() {
        let sla = Runner::run(with_policy(SPIKE, "sla_energy")).unwrap();
        let legacy = Runner::run(with_policy(SPIKE, "grow_on_backlog")).unwrap();
        assert_eq!(sla.tiers[0].tasks, legacy.tiers[0].tasks);
        assert!(
            sla.sla0_violation_bp() < legacy.sla0_violation_bp(),
            "warm capacity must absorb the spike: sla={} legacy={}",
            sla.summary(),
            legacy.summary()
        );
        assert!(
            sla.energy.energy_mj <= legacy.energy.energy_mj,
            "and at no extra energy: sla={} legacy={}",
            sla.energy.energy_mj,
            legacy.energy.energy_mj
        );
        assert!(legacy.sla0_violation_bp() > 0, "the spike must hurt the legacy policy");
    }

    #[test]
    fn updown_cycle_finishes_batch_cheaper_under_sla_policy() {
        let sla = Runner::run(with_policy(UPDOWN, "sla_energy")).unwrap();
        let legacy = Runner::run(with_policy(UPDOWN, "grow_on_backlog")).unwrap();
        // Batch work has no deadline but must finish inside the run.
        assert_eq!(sla.tiers[3].violations, 0, "{}", sla.summary());
        assert_eq!(legacy.tiers[3].violations, 0, "{}", legacy.summary());
        assert!(
            sla.energy.energy_mj <= legacy.energy.energy_mj,
            "queue-tolerant batch scaling must not cost more energy: sla={} legacy={}",
            sla.energy.energy_mj,
            legacy.energy.energy_mj
        );
        assert!(sla.drains > 0, "the diurnal trough powers nodes down");
    }

    #[test]
    fn class_mips_profile_reaches_the_rm_registry() {
        // updown's `bulk` class runs below reference speed; every node's
        // class MIPS must be resolvable through the live RM, including
        // pool nodes that have not joined yet.
        let r = Runner::new(with_policy(UPDOWN, "sla_energy")).unwrap();
        let mut hetero = 0u32;
        for (&id, n) in &r.nodes {
            let cls = &r.spec.machine_classes[n.class];
            assert_eq!(r.dc.rm.node_mips(id), cls.mips.max(1));
            if cls.mips != crate::scenario::REFERENCE_MIPS {
                hetero += 1;
            }
        }
        assert!(hetero > 0, "updown declares a sub-reference class");
    }

    #[test]
    fn nodes_min_floor_never_violated_during_power_down() {
        for policy in ["sla_energy", "grow_on_backlog"] {
            let mut r = Runner::new(with_policy(UPDOWN, policy)).unwrap();
            while r.step().unwrap() {
                assert!(
                    r.nm_count() >= r.nodes_min(),
                    "{policy}: floor broken at t={}ms: {} < {}",
                    r.now_ms(),
                    r.nm_count(),
                    r.nodes_min()
                );
            }
        }
    }

    #[test]
    fn wake_up_latency_is_charged_before_sla0_tasks_land() {
        // Reactive growth pays queue delay + wake-up before new capacity
        // can serve the spike; with wake_ms = 0 the same policy only
        // pays the queue delay. The gap must show up as violations.
        let slow = Runner::run(with_policy(SPIKE, "grow_on_backlog")).unwrap();
        let mut spec = with_policy(SPIKE, "grow_on_backlog");
        for c in &mut spec.machine_classes {
            c.wake_ms = 0;
        }
        let instant = Runner::run(spec).unwrap();
        assert!(slow.energy.wakeups > 0 && slow.energy.wake_ms > 0);
        assert_eq!(instant.energy.wakeups, 0);
        assert!(
            slow.tiers[0].violations > instant.tiers[0].violations,
            "wake latency must cost deadlines: slow={} instant={}",
            slow.summary(),
            instant.summary()
        );
    }

    #[test]
    fn score_accounts_every_emitted_task() {
        let spec = ScenarioSpec::from_toml(UPDOWN).unwrap();
        // Emission is tick-quantized: an arrival lands when the first
        // tick at-or-after it runs, so arrivals after the last tick
        // (duration - tick) are never emitted.
        let last_tick = spec.duration_ms - spec.tick_ms;
        let expected: u64 = spec
            .task_classes
            .iter()
            .map(|c| {
                let mut n = 0u64;
                let mut t = c.start_ms;
                while t < c.end_ms && t <= last_tick {
                    if c.shape.open_at(t, c.start_ms) {
                        n += 1;
                    }
                    t += c.inter_arrival_ms;
                }
                n
            })
            .sum();
        let score = Runner::run(spec).unwrap();
        let emitted: u64 = score.tiers.iter().map(|t| t.tasks).sum();
        assert_eq!(emitted, expected);
        for tier in &score.tiers {
            assert!(tier.violations <= tier.tasks);
        }
    }
}
