//! Crate-wide error type.
//!
//! Domain layers attach their own context; everything converges on
//! [`Error`] so the CLI / API boundary can render a single error shape.
//! Display/Error are hand-implemented — the offline build has no
//! `thiserror`.

use std::fmt;

/// Unified error type for the hpcw stack.
#[derive(Debug)]
pub enum Error {
    /// Configuration file or value problems.
    Config(String),

    /// JSON / TOML / CSV encoding-decoding problems.
    Codec(String),

    /// LSF-like scheduler errors (unknown queue, bad resource request, ...).
    Sched(String),

    /// YARN daemon / container protocol errors.
    Yarn(String),

    /// Dynamic-cluster wrapper errors (daemon start failure, dirty teardown).
    Wrapper(String),

    /// Distributed-filesystem errors (Lustre / HDFS-like / DAS).
    Dfs(String),

    /// MapReduce engine errors.
    MapReduce(String),

    /// Framework frontend errors (Pig / Hive / RHadoop / Mongo parsing or planning).
    Framework(String),

    /// SynfiniWay-style API errors.
    Api(String),

    /// PJRT runtime errors (artifact missing, compile or execute failure).
    Runtime(String),

    /// Underlying OS I/O.
    Io(std::io::Error),

    /// Errors bubbled from the `xla` crate (feature-gated backend).
    Xla(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Config(m) => write!(f, "config: {m}"),
            Error::Codec(m) => write!(f, "codec: {m}"),
            Error::Sched(m) => write!(f, "scheduler: {m}"),
            Error::Yarn(m) => write!(f, "yarn: {m}"),
            Error::Wrapper(m) => write!(f, "wrapper: {m}"),
            Error::Dfs(m) => write!(f, "dfs: {m}"),
            Error::MapReduce(m) => write!(f, "mapreduce: {m}"),
            Error::Framework(m) => write!(f, "framework: {m}"),
            Error::Api(m) => write!(f, "api: {m}"),
            Error::Runtime(m) => write!(f, "runtime: {m}"),
            Error::Io(e) => write!(f, "io: {e}"),
            Error::Xla(m) => write!(f, "xla: {m}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

#[cfg(feature = "xla")]
impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

impl Error {
    /// Short machine-readable kind tag, used by the API layer.
    pub fn kind(&self) -> &'static str {
        match self {
            Error::Config(_) => "config",
            Error::Codec(_) => "codec",
            Error::Sched(_) => "scheduler",
            Error::Yarn(_) => "yarn",
            Error::Wrapper(_) => "wrapper",
            Error::Dfs(_) => "dfs",
            Error::MapReduce(_) => "mapreduce",
            Error::Framework(_) => "framework",
            Error::Api(_) => "api",
            Error::Runtime(_) => "runtime",
            Error::Io(_) => "io",
            Error::Xla(_) => "xla",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kinds_are_stable() {
        assert_eq!(Error::Sched("x".into()).kind(), "scheduler");
        assert_eq!(Error::Yarn("x".into()).kind(), "yarn");
        assert_eq!(Error::Wrapper("x".into()).kind(), "wrapper");
    }

    #[test]
    fn display_includes_context() {
        let e = Error::Wrapper("node 3 NM failed to start".into());
        assert!(e.to_string().contains("node 3"));
    }
}
