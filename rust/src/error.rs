//! Crate-wide error type.
//!
//! Domain layers attach their own context; everything converges on
//! [`Error`] so the CLI / API boundary can render a single error shape.

use thiserror::Error;

/// Unified error type for the hpcw stack.
#[derive(Debug, Error)]
pub enum Error {
    /// Configuration file or value problems.
    #[error("config: {0}")]
    Config(String),

    /// JSON / TOML / CSV encoding-decoding problems.
    #[error("codec: {0}")]
    Codec(String),

    /// LSF-like scheduler errors (unknown queue, bad resource request, ...).
    #[error("scheduler: {0}")]
    Sched(String),

    /// YARN daemon / container protocol errors.
    #[error("yarn: {0}")]
    Yarn(String),

    /// Dynamic-cluster wrapper errors (daemon start failure, dirty teardown).
    #[error("wrapper: {0}")]
    Wrapper(String),

    /// Distributed-filesystem errors (Lustre / HDFS-like / DAS).
    #[error("dfs: {0}")]
    Dfs(String),

    /// MapReduce engine errors.
    #[error("mapreduce: {0}")]
    MapReduce(String),

    /// Framework frontend errors (Pig / Hive / RHadoop / Mongo parsing or planning).
    #[error("framework: {0}")]
    Framework(String),

    /// SynfiniWay-style API errors.
    #[error("api: {0}")]
    Api(String),

    /// PJRT runtime errors (artifact missing, compile or execute failure).
    #[error("runtime: {0}")]
    Runtime(String),

    /// Underlying OS I/O.
    #[error("io: {0}")]
    Io(#[from] std::io::Error),

    /// Errors bubbled from the `xla` crate.
    #[error("xla: {0}")]
    Xla(String),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

impl Error {
    /// Short machine-readable kind tag, used by the API layer.
    pub fn kind(&self) -> &'static str {
        match self {
            Error::Config(_) => "config",
            Error::Codec(_) => "codec",
            Error::Sched(_) => "scheduler",
            Error::Yarn(_) => "yarn",
            Error::Wrapper(_) => "wrapper",
            Error::Dfs(_) => "dfs",
            Error::MapReduce(_) => "mapreduce",
            Error::Framework(_) => "framework",
            Error::Api(_) => "api",
            Error::Runtime(_) => "runtime",
            Error::Io(_) => "io",
            Error::Xla(_) => "xla",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kinds_are_stable() {
        assert_eq!(Error::Sched("x".into()).kind(), "scheduler");
        assert_eq!(Error::Yarn("x".into()).kind(), "yarn");
        assert_eq!(Error::Wrapper("x".into()).kind(), "wrapper");
    }

    #[test]
    fn display_includes_context() {
        let e = Error::Wrapper("node 3 NM failed to start".into());
        assert!(e.to_string().contains("node 3"));
    }
}
