//! The `hpcw` command-line interface (the leader entrypoint).
//!
//! Subcommands:
//! * `hpcw figures [--reps N] [--jobs N]` — regenerate every paper figure
//!   and ablation (Sim data plane), CSVs in `bench_out/`.
//! * `hpcw terasort --rows N [--nodes N] [--maps N] [--reduces N]
//!   [--kernel]` — run the real pipeline end to end and validate.
//! * `hpcw pig --file SCRIPT [--reduces N]` — run a Pig-like script.
//! * `hpcw hive --sql QUERY [--reduces N]` — run a Hive-like query.
//! * `hpcw query --sql QUERY | --file SCRIPT [--engine pig|hive]` — run a
//!   multi-stage query (JOIN / ORDER BY / LIMIT) as chained MR jobs;
//!   `--explain` prints the optimizer's stage plan instead of running.
//! * `hpcw wrapper --nodes N` — simulate one wrapper create/teardown and
//!   print the phase timeline (Fig 3's single point).
//! * `hpcw serve [--config FILE]` — start the SynfiniWay-style v1 API
//!   server and block.
//! * `hpcw jobs --addr HOST:PORT [--offset N] [--limit N]` — page through
//!   a running server's job list over the v1 wire protocol.
//! * `hpcw events --addr HOST:PORT [--since SEQ] [--wait-ms N]` — tail a
//!   running server's event journal.
//! * `hpcw scenario run --file SPEC.toml [--policy P] [--json]
//!   [--addr HOST:PORT]` — run a declarative autoscaling scenario
//!   (in-process, or through a server's `/v1/scenarios`) and print the
//!   score.
//! * `hpcw scenario get --addr HOST:PORT --id N` — fetch a submitted
//!   scenario's state and score.

pub mod args;

use crate::api::{ApiClient, ApiServer, AppPayload, Stack};
use crate::api::wire::{job_state_to_wire, score_doc_to_json};
use crate::bench;
use crate::config::StackConfig;
use crate::error::{Error, Result};
use crate::scenario::{Runner, ScenarioSpec, ScoreDoc};
use crate::wrapper::sim::simulate_wrapper;
use args::Args;

/// Entry point used by `main.rs`. Returns the process exit code.
pub fn run(argv: Vec<String>) -> i32 {
    match dispatch(argv) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("hpcw: error: {e}");
            1
        }
    }
}

fn load_config(args: &Args) -> Result<StackConfig> {
    let mut cfg = match args.opt("config") {
        Some(path) => StackConfig::from_file(std::path::Path::new(&path))?,
        None => {
            if args.flag("tiny") {
                StackConfig::tiny()
            } else {
                StackConfig::paper()
            }
        }
    };
    // Env wins over file for the multi-tenant front door (HPCW_TENANTS,
    // HPCW_ANON_QUEUE, HPCW_SUBMIT_RATE, ... — see docs/TENANCY.md).
    cfg.tenant.apply_env()?;
    Ok(cfg)
}

fn dispatch(argv: Vec<String>) -> Result<()> {
    // `hpcw scenario <run|get>` carries a sub-subcommand; strip the
    // leading "scenario" so the one-positional argv parser sees run/get.
    if argv.first().map(String::as_str) == Some("scenario") {
        let args = Args::parse(argv[1..].to_vec())?;
        return match args.command.as_deref() {
            Some("run") => cmd_scenario_run(&args),
            Some("get") => cmd_scenario_get(&args),
            _ => Err(Error::Api(format!("scenario needs run|get\n{USAGE}"))),
        };
    }
    let args = Args::parse(argv)?;
    match args.command.as_deref() {
        Some("figures") => cmd_figures(&args),
        Some("terasort") => cmd_terasort(&args),
        Some("pig") => cmd_pig(&args),
        Some("hive") => cmd_hive(&args),
        Some("query") => cmd_query(&args),
        Some("wrapper") => cmd_wrapper(&args),
        Some("serve") => cmd_serve(&args),
        Some("jobs") => cmd_jobs(&args),
        Some("events") => cmd_events(&args),
        Some("tenants") => cmd_tenants(&args),
        Some("queues") => cmd_queues(&args),
        Some(other) => Err(Error::Api(format!("unknown subcommand '{other}'\n{USAGE}"))),
        None => {
            println!("{USAGE}");
            Ok(())
        }
    }
}

const USAGE: &str = "usage: hpcw <figures|terasort|pig|hive|query|wrapper|serve|jobs|events|tenants|queues|scenario> [options]
  figures   [--reps N] [--jobs N]           regenerate paper figures (sim)
  terasort  --rows N [--nodes N] [--maps N] [--reduces N] [--kernel] [--tiny]
  pig       --file SCRIPT [--reduces N] [--tiny]
  hive      --sql QUERY [--reduces N] [--tiny]
  query     --sql QUERY | --file SCRIPT [--engine pig|hive] [--reduces N]
            [--explain] [--tiny]
            multi-stage queries: JOIN / ORDER BY / LIMIT compile to chained MR jobs
  wrapper   --nodes N                       one simulated create/teardown
  serve     [--config FILE] [--tiny]        start the v1 API server
  jobs      --addr HOST:PORT [--offset N] [--limit N]   list a server's jobs
  events    --addr HOST:PORT [--since SEQ] [--wait-ms N] tail the event journal
  tenants   --addr HOST:PORT [--key KEY]   per-tenant quota/limiter/breaker state
  queues    --addr HOST:PORT [--key KEY]   fair-share queue shares + wait times
  scenario  run --file SPEC.toml [--policy P] [--json] [--addr HOST:PORT]
            run a declarative autoscaling scenario (see docs/SCENARIOS.md);
            in-process by default, via POST /v1/scenarios with --addr
  scenario  get --addr HOST:PORT --id N    fetch a scenario's state + score
  (jobs/events/tenants/queues/scenario accept --key KEY to authenticate as a tenant)";

fn cmd_figures(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let reps = args.num("reps").unwrap_or(5) as u32;
    let jobs = args.num("jobs").unwrap_or(120) as u32;
    bench::fig3(&cfg, reps);
    bench::fig4(&cfg);
    bench::fig5(&cfg);
    bench::ablation_fs(&cfg);
    bench::ablation_transport(&cfg);
    bench::ablation_sched(&cfg, jobs);
    println!("\nall figures regenerated into bench_out/");
    Ok(())
}

fn cmd_terasort(args: &Args) -> Result<()> {
    let mut cfg = StackConfig::tiny();
    let nodes = args.num("nodes").unwrap_or(8) as u32;
    cfg.cluster.nodes = nodes.max(3);
    let rows = args
        .num("rows")
        .ok_or_else(|| Error::Api("terasort needs --rows".into()))?;
    let payload = AppPayload::Terasort {
        rows,
        maps: args.num("maps").unwrap_or(4),
        reduces: args.num("reduces").unwrap_or(4) as u32,
        use_kernel: args.flag("kernel"),
    };
    let mut stack = Stack::new(cfg)?;
    let id = stack.submit(nodes, &whoami(), payload)?;
    println!("submitted LSF job {id}");
    let result = stack.run_to_completion(id, 50)?;
    println!(
        "validated={} records={} wall={:.2}s output={}",
        result.validated,
        result.records,
        result.wall.as_secs_f64(),
        result.output_dir
    );
    Ok(())
}

fn cmd_pig(args: &Args) -> Result<()> {
    let path = args
        .opt("file")
        .ok_or_else(|| Error::Api("pig needs --file".into()))?;
    let script = std::fs::read_to_string(&path)
        .map_err(|e| Error::Api(format!("read {path}: {e}")))?;
    run_query(
        args,
        AppPayload::PigScript {
            script,
            reduces: args.num("reduces").unwrap_or(2) as u32,
        },
    )
}

fn cmd_hive(args: &Args) -> Result<()> {
    let sql = args
        .opt("sql")
        .ok_or_else(|| Error::Api("hive needs --sql".into()))?;
    run_query(
        args,
        AppPayload::HiveQuery {
            sql,
            reduces: args.num("reduces").unwrap_or(2) as u32,
        },
    )
}

/// `hpcw query` — the multi-stage engine: `--sql` (Hive, default) or
/// `--file` (Pig script, default) with `--engine` to override.
fn cmd_query(args: &Args) -> Result<()> {
    let (default_engine, text) = if let Some(sql) = args.opt("sql") {
        ("hive", sql)
    } else if let Some(path) = args.opt("file") {
        let text = std::fs::read_to_string(&path)
            .map_err(|e| Error::Api(format!("read {path}: {e}")))?;
        ("pig", text)
    } else {
        return Err(Error::Api("query needs --sql or --file".into()));
    };
    let engine = args.opt("engine").unwrap_or_else(|| default_engine.into());
    let reduces = args.num("reduces").unwrap_or(2) as u32;
    if args.flag("explain") {
        let cfg = load_config(args)?;
        let stack = Stack::new(cfg)?;
        let doc = stack.explain_query(&engine, &text, reduces)?;
        println!("{}", doc.pretty());
        return Ok(());
    }
    run_query(
        args,
        AppPayload::Query {
            engine,
            text,
            reduces,
        },
    )
}

fn run_query(args: &Args, payload: AppPayload) -> Result<()> {
    let cfg = load_config(args)?;
    let mut stack = Stack::new(cfg)?;
    let nodes = args.num("nodes").unwrap_or(4) as u32;
    let id = stack.submit(nodes, &whoami(), payload)?;
    let result = stack.run_to_completion(id, 50)?.clone();
    println!("job {id} done; {} output files:", result.output_files.len());
    for f in &result.output_files {
        let text = String::from_utf8_lossy(&stack.read_output(f)?).to_string();
        print!("{text}");
    }
    Ok(())
}

fn cmd_wrapper(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let nodes = args.num("nodes").unwrap_or(16) as u32;
    let p = simulate_wrapper(&cfg, nodes.max(3), 0);
    println!("wrapper timing for {} nodes ({} cores):", p.nodes, p.cores);
    println!("  env setup      {:>8.2}s", p.env_setup_s);
    println!("  lustre dirs    {:>8.2}s", p.shared_dirs_s);
    println!("  RM + JHS up    {:>8.2}s", p.daemons_s);
    println!("  NM fan-out     {:>8.2}s", p.nm_phase_s);
    println!("  create total   {:>8.2}s", p.create_s);
    println!("  teardown       {:>8.2}s", p.teardown_s);
    println!("  TOTAL          {:>8.2}s", p.total_s());
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let stack = Stack::new(cfg)?;
    let server = ApiServer::start(stack)?;
    println!("hpcw API serving on http://{} (Ctrl-C to stop)", server.addr);
    println!("  v1 routes: POST/GET /v1/jobs, GET /v1/jobs/{{id}}?wait_ms=N,");
    println!("             GET /v1/jobs/{{id}}/output?path=, POST/GET /v1/workflows,");
    println!("             GET /v1/events?since=seq, GET /v1/metrics  (see docs/API.md)");
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn client_for(args: &Args) -> Result<ApiClient> {
    let addr = args
        .opt("addr")
        .ok_or_else(|| Error::Api("needs --addr HOST:PORT of a running `hpcw serve`".into()))?;
    Ok(match args.opt("key") {
        Some(key) => ApiClient::with_key(&addr, &key),
        None => ApiClient::new(&addr),
    })
}

fn cmd_jobs(args: &Args) -> Result<()> {
    let client = client_for(args)?;
    let page = client.list_jobs(args.num("offset").unwrap_or(0), args.num("limit").unwrap_or(50))?;
    println!(
        "{} jobs total, showing {} from offset {}",
        page.total,
        page.jobs.len(),
        page.offset
    );
    for j in &page.jobs {
        println!("  job {:>6}  {:<10} {}", j.job, j.kind, job_state_to_wire(j.state));
    }
    Ok(())
}

fn cmd_events(args: &Args) -> Result<()> {
    let client = client_for(args)?;
    let page = client.events(
        args.num("since").unwrap_or(0),
        args.num("wait-ms").unwrap_or(0),
    )?;
    for e in &page.events {
        match &e.step {
            Some(step) => println!("{:>6}  {:<9} {:<6} {step}: {}", e.seq, e.kind, e.id, e.state),
            None => println!("{:>6}  {:<9} {:<6} {}", e.seq, e.kind, e.id, e.state),
        }
    }
    println!("next cursor: {}", page.next);
    Ok(())
}

fn cmd_tenants(args: &Args) -> Result<()> {
    let client = client_for(args)?;
    let tenants = client.tenants()?;
    if tenants.is_empty() {
        println!("tenancy disabled (no [tenants] keys configured)");
        return Ok(());
    }
    println!(
        "{:<12} {:<24} {:>4} {:>6} {:>10} {:>6} {:>5} {:>5} {:>5}  breaker",
        "tenant", "queue", "apps", "ctrs", "dfs_bytes", "subm", "rate", "quota", "brk"
    );
    for t in &tenants {
        println!(
            "{:<12} {:<24} {:>4} {:>6} {:>10} {:>6} {:>5} {:>5} {:>5}  {}",
            t.name,
            t.queue,
            t.running_apps,
            t.containers,
            t.dfs_bytes,
            t.submitted,
            t.rate_limited,
            t.quota_rejected,
            t.breaker_rejected,
            t.breaker
        );
    }
    Ok(())
}

fn cmd_queues(args: &Args) -> Result<()> {
    let client = client_for(args)?;
    let queues = client.queues()?;
    if queues.is_empty() {
        println!("tenancy disabled (no [tenants] keys configured)");
        return Ok(());
    }
    println!(
        "{:<24} {:>6} {:>7} {:>7} {:>7} {:>7} {:>9} {:>7} {:>12}",
        "queue", "weight", "min%", "max%", "running", "served", "share%", "preempt", "wait_us"
    );
    for q in &queues {
        println!(
            "{:<24} {:>6} {:>7} {:>7} {:>7} {:>7} {:>9} {:>7} {:>12}",
            q.name,
            q.weight,
            q.min_pct,
            q.max_pct,
            q.running,
            q.served,
            q.share_pct,
            q.preemptions,
            q.wait_us
        );
    }
    Ok(())
}

/// `hpcw scenario run`: parse the declarative TOML spec and run it
/// in-process (the CI path — no server needed), or, with `--addr`,
/// submit it to a running server over `POST /v1/scenarios` and wait for
/// the score. `--policy` overrides the spec's autoscaling policy so one
/// spec file drives an A/B comparison; `--json` prints the canonical
/// wire-form score (machine-readable) instead of the one-line summary.
fn cmd_scenario_run(args: &Args) -> Result<()> {
    let path = args
        .opt("file")
        .ok_or_else(|| Error::Api("scenario run needs --file SPEC.toml".into()))?;
    let text = std::fs::read_to_string(&path)
        .map_err(|e| Error::Api(format!("read {path}: {e}")))?;
    let mut spec = ScenarioSpec::from_toml(&text)?;
    if let Some(p) = args.opt("policy") {
        spec.policy = p;
        spec.validate()?;
    }
    if args.opt("addr").is_some() {
        let client = client_for(args)?;
        let id = client.run_scenario(&spec)?;
        eprintln!("submitted scenario {id}");
        let doc = client.wait_scenario(id, std::time::Duration::from_secs(600))?;
        return match doc.score {
            Some(score) => {
                print_score(&score, args.flag("json"));
                Ok(())
            }
            None => Err(Error::Api(format!(
                "scenario {id} failed: {}",
                doc.error.unwrap_or_default()
            ))),
        };
    }
    let score = Runner::run(spec)?;
    print_score(&score, args.flag("json"));
    Ok(())
}

fn print_score(score: &ScoreDoc, json: bool) {
    if json {
        println!("{}", score_doc_to_json(score).to_string());
    } else {
        println!("{}", score.summary());
    }
}

fn cmd_scenario_get(args: &Args) -> Result<()> {
    let client = client_for(args)?;
    let id = args
        .num("id")
        .ok_or_else(|| Error::Api("scenario get needs --id N".into()))?;
    let doc = client.scenario(id)?;
    println!(
        "scenario {} '{}' [{}] {}",
        doc.scenario,
        doc.name,
        doc.policy,
        doc.state.as_wire()
    );
    if let Some(score) = &doc.score {
        println!("{}", score.summary());
    }
    if let Some(err) = &doc.error {
        println!("error: {err}");
    }
    Ok(())
}

fn whoami() -> String {
    std::env::var("USER").unwrap_or_else(|_| "hpcw".into())
}
