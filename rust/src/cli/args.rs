//! Tiny argv parser (clap is not vendored): one optional subcommand,
//! `--flag` booleans, `--key value` options.

use crate::error::{Error, Result};
use std::collections::BTreeMap;

/// Parsed arguments.
#[derive(Debug, Default)]
pub struct Args {
    pub command: Option<String>,
    flags: Vec<String>,
    opts: BTreeMap<String, String>,
}

impl Args {
    /// Parse `argv` (excluding the program name).
    pub fn parse(argv: Vec<String>) -> Result<Args> {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if name.is_empty() {
                    return Err(Error::Api("bare '--' not supported".into()));
                }
                // `--key value` when the next token is not another flag;
                // otherwise a boolean flag.
                match it.peek() {
                    Some(v) if !v.starts_with("--") => {
                        let v = it.next().unwrap();
                        out.opts.insert(name.to_string(), v);
                    }
                    _ => out.flags.push(name.to_string()),
                }
            } else if out.command.is_none() {
                out.command = Some(a);
            } else {
                return Err(Error::Api(format!("unexpected positional '{a}'")));
            }
        }
        Ok(out)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt(&self, name: &str) -> Option<String> {
        self.opts.get(name).cloned()
    }

    pub fn num(&self, name: &str) -> Option<u64> {
        self.opts.get(name).and_then(|v| v.parse().ok())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(str::to_string).collect()
    }

    #[test]
    fn subcommand_flags_and_options() {
        let a = Args::parse(argv("terasort --rows 1000 --kernel --reduces 4")).unwrap();
        assert_eq!(a.command.as_deref(), Some("terasort"));
        assert_eq!(a.num("rows"), Some(1000));
        assert_eq!(a.num("reduces"), Some(4));
        assert!(a.flag("kernel"));
        assert!(!a.flag("verbose"));
        assert_eq!(a.num("missing"), None);
    }

    #[test]
    fn trailing_flag_is_boolean() {
        let a = Args::parse(argv("serve --tiny")).unwrap();
        assert!(a.flag("tiny"));
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = Args::parse(argv("x --kernel --rows 5")).unwrap();
        assert!(a.flag("kernel"));
        assert_eq!(a.num("rows"), Some(5));
    }

    #[test]
    fn double_positional_rejected() {
        assert!(Args::parse(argv("a b")).is_err());
    }
}
