//! The Sim-mode MapReduce cost model: the same phase structure as
//! [`super::real`], with the data plane replaced by the calibrated models
//! (`FsModel` for storage, `Interconnect` for the shuffle fabric,
//! `CalibrationConfig` for per-task software costs).
//!
//! This is what regenerates Fig 4 (Teragen) and Fig 5 (Terasort) at the
//! paper's 1 TB / 2,048-core scale. Every rate used here is either taken
//! from the hardware table (§VI) or carries a provenance note in
//! [`crate::config::calibration`].

use crate::cluster::interconnect::Transport;
use crate::config::StackConfig;
use crate::lustre::FsModel;

/// Workload description for one simulated MR job.
#[derive(Debug, Clone)]
pub struct MrWorkload {
    /// Nodes in the LSF allocation (the first two run RM/JHS, per §V).
    pub alloc_nodes: u32,
    /// Bytes read by the map phase from the Dfs (0 for Teragen).
    pub input_bytes: f64,
    /// Bytes crossing the shuffle (0 for map-only jobs).
    pub shuffle_bytes: f64,
    /// Bytes written to the Dfs by the final phase.
    pub output_bytes: f64,
    pub n_maps: u32,
    pub n_reduces: u32,
    /// Shuffle transport (ABL-RPC swaps this).
    pub transport: Transport,
    /// Per-record map compute cost multiplier (1.0 = Terasort's identity
    /// map; frameworks with heavier mappers raise it).
    pub map_cost_factor: f64,
}

impl MrWorkload {
    /// The paper's standard shape: mappers/reducers proportional to cores.
    pub fn terasort_shape(cfg: &StackConfig, alloc_nodes: u32, bytes: f64) -> MrWorkload {
        let slots = map_slots(cfg, alloc_nodes);
        MrWorkload {
            alloc_nodes,
            input_bytes: bytes,
            shuffle_bytes: bytes,
            output_bytes: bytes,
            n_maps: slots,
            n_reduces: (slots / 2).max(1),
            transport: Transport::HadoopRpc,
            map_cost_factor: 1.0,
        }
    }

    /// Teragen: map-only, mappers fill every slot (§VII: "the number of
    /// mappers and reducers are proportional to the allocated cores").
    pub fn teragen_shape(cfg: &StackConfig, alloc_nodes: u32, bytes: f64) -> MrWorkload {
        let slots = map_slots(cfg, alloc_nodes);
        MrWorkload {
            alloc_nodes,
            input_bytes: 0.0,
            shuffle_bytes: 0.0,
            output_bytes: bytes,
            n_maps: slots,
            n_reduces: 0,
            transport: Transport::HadoopRpc,
            map_cost_factor: 0.7, // row synthesis is cheaper than parse+sort
        }
    }
}

/// Concurrent map containers an allocation can host (slaves × per-node).
pub fn map_slots(cfg: &StackConfig, alloc_nodes: u32) -> u32 {
    let slaves = alloc_nodes.saturating_sub(2).max(1);
    slaves * cfg.yarn.containers_per_node(cfg.yarn.map_memory_mb) as u32
}

/// Phase timing breakdown of one simulated job.
#[derive(Debug, Clone)]
pub struct MrSimReport {
    pub map_s: f64,
    pub shuffle_s: f64,
    pub reduce_s: f64,
    pub total_s: f64,
    pub map_waves: u32,
    pub reduce_waves: u32,
    /// Which resource bound the longest phase: "map-io", "map-cpu",
    /// "shuffle-net", "shuffle-disk", "reduce-io", "reduce-cpu".
    pub bottleneck: &'static str,
}

/// Simulate one MR job against a storage model.
pub fn simulate_mr(cfg: &StackConfig, fs: &FsModel, w: &MrWorkload) -> MrSimReport {
    let cal = &cfg.calibration;
    let cpu = cfg.cluster.cpu.speed_factor();
    let slots = map_slots(cfg, w.alloc_nodes).max(1);
    let slaves = w.alloc_nodes.saturating_sub(2).max(1);

    let straggler_tax = if cfg.yarn.speculative_execution {
        // Speculation re-runs the tail; residual tax is small.
        1.0 + cal.straggler_frac * (cal.straggler_slowdown - 1.0)
    } else {
        // An unspeculated wave waits for its slowest member.
        cal.straggler_slowdown
            .min(1.0 + cal.straggler_frac * slots as f64 * (cal.straggler_slowdown - 1.0))
    };

    // ---------------- map phase ----------------
    let n_maps = w.n_maps.max(1);
    let map_waves = n_maps.div_ceil(slots);
    let per_map_in = w.input_bytes / n_maps as f64;
    let per_map_out = if w.n_reduces == 0 {
        w.output_bytes / n_maps as f64
    } else {
        w.shuffle_bytes / n_maps as f64
    };

    let mut map_s = 0.0;
    let mut map_bound = "map-cpu";
    let mut remaining = n_maps;
    while remaining > 0 {
        let k = remaining.min(slots);
        // Input: shared read through the Dfs (remote for Lustre).
        let read_rate = fs.contended_read_bps(k).max(1.0);
        let per_task_read = per_map_in
            / (read_rate / k as f64)
                .min(cal.hadoop_stream_read_mbps * 1e6)
                .max(1.0);
        // Compute: parse + partition + sort at the calibrated per-core rate.
        let comp_rate = cal.map_compute_mbps_per_core * 1e6 * cpu / w.map_cost_factor;
        let per_task_comp = per_map_in.max(per_map_out) / comp_rate;
        // Output: map-only jobs write to the Dfs; shuffled jobs spill to DAS.
        let per_task_write = if w.n_reduces == 0 {
            let write_rate = fs.contended_write_bps(k).max(1.0);
            per_map_out
                / (write_rate / k as f64)
                    .min(cal.hadoop_stream_write_mbps * 1e6)
                    .max(1.0)
        } else {
            // Spill to node-local DAS shared by concurrent tasks on the node.
            let tasks_per_node = (k as f64 / slaves as f64).max(1.0);
            per_map_out * cal.spill_factor / (cfg.cluster.das_bw_mbps * 1e6 / tasks_per_node)
        };
        let io = per_task_read + per_task_write;
        let task_s = io.max(per_task_comp) * straggler_tax;
        if io > per_task_comp {
            map_bound = "map-io";
        }
        map_s += cal.container_launch_s + cal.wave_latency_s + task_s;
        remaining -= k;
    }

    // ---------------- shuffle ----------------
    let (shuffle_s, shuffle_bound) = if w.shuffle_bytes > 0.0 && w.n_reduces > 0 {
        let streams = (w.n_reduces as u64 * n_maps as u64).min(10_000) as f64;
        let per_stream = match w.transport {
            Transport::HadoopRpc => cal.hadoop_rpc_stream_mbps * 1e6,
            Transport::Native => cal.native_stream_mbps * 1e6,
        };
        // Aggregate limits: per-stream software ceiling × concurrent
        // fetchers (Hadoop runs ~5 fetchers per reduce), fabric bisection,
        // and the DAS spindles serving the map-side segments.
        let fetchers = (w.n_reduces as f64 * 5.0).min(streams);
        let net = cfg.cluster.ib_gbps * 1e9 / 8.0 * slaves as f64 * 0.75;
        let das = slaves as f64 * cfg.cluster.das_bw_mbps * 1e6;
        let rate = (fetchers * per_stream).min(net).min(das).max(1.0);
        let fetch_overhead =
            cal.shuffle_fetch_overhead_s * (n_maps as f64) / (w.n_reduces as f64 * 5.0).max(1.0);
        (
            w.shuffle_bytes / rate + fetch_overhead,
            if (fetchers * per_stream) < net.min(das) {
                "shuffle-net"
            } else {
                "shuffle-disk"
            },
        )
    } else {
        (0.0, "map-cpu")
    };

    // ---------------- reduce phase ----------------
    let mut reduce_s = 0.0;
    let mut reduce_waves = 0;
    let mut reduce_bound = "reduce-io";
    if w.n_reduces > 0 {
        let reduce_slots =
            (slaves * cfg.yarn.containers_per_node(cfg.yarn.reduce_memory_mb) as u32).max(1);
        let n_red = w.n_reduces;
        reduce_waves = n_red.div_ceil(reduce_slots);
        let per_red_out = w.output_bytes / n_red as f64;
        let mut remaining = n_red;
        while remaining > 0 {
            let k = remaining.min(reduce_slots);
            let write_rate = fs.contended_write_bps(k).max(1.0);
            let per_task_write = per_red_out
                / (write_rate / k as f64)
                    .min(cal.hadoop_stream_write_mbps * 1e6)
                    .max(1.0);
            let comp_rate = cal.reduce_compute_mbps_per_core * 1e6 * cpu;
            let per_task_comp = per_red_out / comp_rate;
            let task_s = per_task_write.max(per_task_comp) * straggler_tax;
            if per_task_comp > per_task_write {
                reduce_bound = "reduce-cpu";
            }
            reduce_s += cal.container_launch_s + cal.wave_latency_s + task_s;
            remaining -= k;
        }
    }

    let total_s = map_s + shuffle_s + reduce_s;
    let bottleneck = if map_s >= shuffle_s && map_s >= reduce_s {
        map_bound
    } else if shuffle_s >= reduce_s {
        shuffle_bound
    } else {
        reduce_bound
    };
    MrSimReport {
        map_s,
        shuffle_s,
        reduce_s,
        total_s,
        map_waves,
        reduce_waves,
        bottleneck,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::StackConfig;
    use crate::lustre::{Dfs, HdfsLikeFs, LustreFs};

    fn lustre_model(cfg: &StackConfig, nodes: u32) -> FsModel {
        LustreFs::new(&cfg.lustre, &cfg.cluster).model(nodes)
    }

    const TB: f64 = 1e12;

    #[test]
    fn teragen_fig4_shape_optimum_near_1800_cores() {
        let cfg = StackConfig::paper();
        let mut rows = Vec::new();
        for &nodes in &[8u32, 16, 32, 56, 88, 113, 120, 128] {
            let w = MrWorkload::teragen_shape(&cfg, nodes, TB);
            let fs = lustre_model(&cfg, nodes);
            let r = simulate_mr(&cfg, &fs, &w);
            rows.push((nodes * 16, r.total_s));
        }
        // Strictly improving up to the ~1,800-core row...
        for win in rows.windows(2) {
            if win[1].0 <= 1800 {
                assert!(
                    win[1].1 < win[0].1,
                    "teragen should improve {} -> {} cores: {} vs {}",
                    win[0].0,
                    win[1].0,
                    win[0].1,
                    win[1].1
                );
            }
        }
        // ...and the optimum is near 1,800, not at the 2,048-core end.
        let best = rows.iter().min_by(|a, b| a.1.total_cmp(&b.1)).unwrap();
        assert!(
            (1500..2040).contains(&best.0),
            "optimum at {} cores (rows: {rows:?})",
            best.0
        );
        let last = rows.last().unwrap();
        assert!(last.1 > best.1, "2,048 cores worse than the optimum");
    }

    #[test]
    fn terasort_fig5_shape_diminishing_returns() {
        let cfg = StackConfig::paper();
        let mut rows = Vec::new();
        for &nodes in &[8u32, 16, 32, 64, 128] {
            let w = MrWorkload::terasort_shape(&cfg, nodes, TB);
            let fs = lustre_model(&cfg, nodes);
            let r = simulate_mr(&cfg, &fs, &w);
            rows.push((nodes as f64 * 16.0, r.total_s));
        }
        // Monotone improvement with diminishing returns.
        for w in rows.windows(2) {
            assert!(w[1].1 < w[0].1, "terasort scales: {rows:?}");
        }
        let speedup_low = rows[0].1 / rows[1].1;
        let speedup_high = rows[3].1 / rows[4].1;
        assert!(
            speedup_low > speedup_high,
            "early doubling helps more than late: {speedup_low} vs {speedup_high}"
        );
        // "Reasonable scalability": sublinear in the slave count (6 slaves
        // at 8 nodes vs 126 at 128 nodes = 21x more data-plane capacity,
        // but less than 21x speedup). Per-core speedup can exceed 16x only
        // because 2 of 8 nodes are daemon-taxed at the small end.
        let overall = rows[0].1 / rows[4].1;
        assert!(overall > 3.0 && overall < 21.0, "overall speedup {overall}");
    }

    #[test]
    fn terasort_goes_io_bound_at_scale() {
        let cfg = StackConfig::paper();
        let w = MrWorkload::terasort_shape(&cfg, 128, TB);
        let fs = lustre_model(&cfg, 128);
        let r = simulate_mr(&cfg, &fs, &w);
        assert!(
            r.bottleneck.contains("io") || r.bottleneck.contains("disk"),
            "paper SVII: I/O bottleneck at scale, got {}",
            r.bottleneck
        );
    }

    #[test]
    fn hdfs_ablation_beats_lustre_on_io_but_cannot_hold_terabyte() {
        let cfg = StackConfig::paper();
        let hdfs = HdfsLikeFs::new(&cfg.cluster);
        let w = MrWorkload::terasort_shape(&cfg, 64, TB);
        let m = hdfs.model(64);
        // Capacity: input+output x3 replication does NOT fit on 8 nodes
        // (8 x 414 GB = 3.3 TB < 6 TB) - the paper's SIII objection.
        assert!(!hdfs.model(8).fits(2.0 * TB));
        assert!(m.fits(2.0 * TB));
        // Performance on big allocations is comparable (Fadika et al. [11]):
        // within ~2.5x either way.
        let lustre = lustre_model(&cfg, 64);
        let t_hdfs = simulate_mr(&cfg, &m, &w).total_s;
        let t_lustre = simulate_mr(&cfg, &lustre, &w).total_s;
        let ratio = t_hdfs / t_lustre;
        assert!((0.4..2.5).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn native_transport_shrinks_shuffle() {
        let cfg = StackConfig::paper();
        let fs = lustre_model(&cfg, 64);
        let mut w = MrWorkload::terasort_shape(&cfg, 64, TB);
        // Lu et al.'s gap is per-stream: make the stream count the binding
        // constraint (few reducers), as in their measurement setup.
        w.n_reduces = 4;
        let rpc = simulate_mr(&cfg, &fs, &w);
        w.transport = Transport::Native;
        let native = simulate_mr(&cfg, &fs, &w);
        assert!(
            native.shuffle_s < rpc.shuffle_s / 3.0,
            "native {} vs rpc {}",
            native.shuffle_s,
            rpc.shuffle_s
        );
    }

    #[test]
    fn more_waves_cost_more_overhead() {
        let cfg = StackConfig::paper();
        let fs = lustre_model(&cfg, 16);
        let slots = map_slots(&cfg, 16);
        let mut w = MrWorkload::terasort_shape(&cfg, 16, 1e10);
        w.n_maps = slots; // one wave
        let one = simulate_mr(&cfg, &fs, &w);
        w.n_maps = slots * 4; // four waves, same bytes
        let four = simulate_mr(&cfg, &fs, &w);
        assert_eq!(one.map_waves, 1);
        assert_eq!(four.map_waves, 4);
        assert!(four.map_s > one.map_s);
    }
}
