//! The MapReduce engine that runs on the dynamic YARN cluster.
//!
//! Two executors share one job model:
//!
//! * [`real`] — executes actual bytes: splits are read from the [`Dfs`],
//!   map tasks run on a thread pool inside YARN containers granted by the
//!   live [`ResourceManager`], map output is partitioned + sorted + spilled
//!   into the [`shuffle::ShuffleStore`], reducers merge and write committed
//!   output back to the Dfs. Teravalidate passes on this path.
//! * [`sim`] — the calibrated cost model of the same phase structure,
//!   used at paper scale (1 TB × 2,048 cores) for Figs 4 and 5.
//!
//! The user-facing API ([`Mapper`], [`Reducer`], [`Partitioner`],
//! [`JobSpec`]) is deliberately Hadoop-shaped: the frameworks layer (Pig /
//! Hive / RHadoop) compiles down to these.

pub mod counters;
pub mod real;
pub mod recordbuf;
pub mod shuffle;
pub mod sim;
pub mod split;
pub mod task;

pub use counters::Counters;
pub use real::{
    ElasticAction, ElasticEvent, ElasticPlan, MrEngine, MrOutcome, PhaseTimings, SchedMode,
};
pub use recordbuf::RecordBuf;
pub use sim::{simulate_mr, MrSimReport, MrWorkload};

pub use split::{assign_locality, InputFormat, InputSplit};
pub use task::{FailurePlan, TaskId, TaskKind};

use std::sync::Arc;

/// Map function over byte-oriented records.
///
/// `emit` borrows its slices: the engine copies them straight into the
/// flat [`RecordBuf`] arena, so a mapper emission performs no heap
/// allocation of its own.
pub trait Mapper: Send + Sync {
    /// Emit zero or more (key, value) pairs for one input record.
    fn map(&self, key: &[u8], value: &[u8], emit: &mut dyn FnMut(&[u8], &[u8]));
}

/// Reduce function: all values for one key, in one partition.
pub trait Reducer: Send + Sync {
    fn reduce(
        &self,
        key: &[u8],
        values: &mut dyn Iterator<Item = &[u8]>,
        emit: &mut dyn FnMut(&[u8], &[u8]),
    );
}

/// Key → partition routing.
pub trait Partitioner: Send + Sync {
    fn partition(&self, key: &[u8], n_reduces: u32) -> u32;
}

/// Whole-block map-side sort + partition (the Terasort hot path).
///
/// When a [`JobSpec`] carries one, the map task hands its entire emitted
/// block to `process` instead of routing record-by-record through the
/// [`Partitioner`]; the implementations live in [`crate::runtime::kernels`]
/// (pure-Rust reference and the AOT Pallas kernel via PJRT) and are
/// parity-tested against each other.
pub trait BlockProcessor: Send + Sync {
    /// Returns exactly `n_reduces` buffers — `records` routed per
    /// partition, each buffer sorted by key.
    fn process(&self, records: RecordBuf, n_reduces: u32) -> crate::error::Result<Vec<RecordBuf>>;

    /// Implementation name, surfaced in job counters.
    fn name(&self) -> &'static str;
}

/// Identity mapper (Terasort's map phase).
pub struct IdentityMapper;

impl Mapper for IdentityMapper {
    fn map(&self, key: &[u8], value: &[u8], emit: &mut dyn FnMut(&[u8], &[u8])) {
        emit(key, value);
    }
}

/// Identity reducer (Terasort's reduce phase): emits pairs unchanged.
pub struct IdentityReducer;

impl Reducer for IdentityReducer {
    fn reduce(
        &self,
        key: &[u8],
        values: &mut dyn Iterator<Item = &[u8]>,
        emit: &mut dyn FnMut(&[u8], &[u8]),
    ) {
        for v in values {
            emit(key, v);
        }
    }
}

/// Hash partitioner (Hadoop's default): FNV-1a over the key.
pub struct HashPartitioner;

impl Partitioner for HashPartitioner {
    fn partition(&self, key: &[u8], n_reduces: u32) -> u32 {
        (crate::util::bytes::fnv1a(key) % n_reduces.max(1) as u64) as u32
    }
}

/// How reduce output is serialized.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OutputFormat {
    /// Raw concatenated 100-byte records (Terasort output).
    TeraRecords,
    /// `key \t value \n` text.
    TextKv,
    /// Values only, newline-separated (key is a routing artifact).
    TextValue,
}

impl OutputFormat {
    /// Serialize one record into `out`.
    #[inline]
    pub fn write_record(&self, out: &mut Vec<u8>, key: &[u8], value: &[u8]) {
        match self {
            OutputFormat::TeraRecords => {
                out.extend_from_slice(key);
                out.extend_from_slice(value);
            }
            OutputFormat::TextKv => {
                out.extend_from_slice(key);
                out.push(b'\t');
                out.extend_from_slice(value);
                out.push(b'\n');
            }
            OutputFormat::TextValue => {
                out.extend_from_slice(value);
                out.push(b'\n');
            }
        }
    }
}

/// One input directory of a multi-input job, mapped with its own mapper
/// (Hadoop's `MultipleInputs` — the repartition join's tagged map sides).
pub struct TaggedInput {
    pub dir: String,
    pub mapper: Arc<dyn Mapper>,
}

/// Receiver for a broadcast input (Hadoop's DistributedCache shape): the
/// engine reads the directory once per job run and hands the concatenated
/// bytes to `load` before any map task is scheduled. Map attempts —
/// including retries, speculative twins, and re-executions after node
/// loss — then share the loaded state, so broadcast data survives
/// map re-execution by construction.
pub trait BroadcastSink: Send + Sync {
    fn load(&self, data: &[u8]) -> crate::error::Result<()>;
}

/// One broadcast side-input: a Dfs directory whose full contents (all
/// non-underscore part files, in name order) are shipped to `sink`
/// at job start — the broadcast-hash join's small side.
pub struct BroadcastInput {
    pub dir: String,
    pub sink: Arc<dyn BroadcastSink>,
}

/// A MapReduce job description.
pub struct JobSpec {
    pub name: String,
    /// Input directory on the Dfs (unused for synthetic-row jobs and
    /// when `tagged_inputs` is non-empty).
    pub input_dir: String,
    /// Final output directory (must not exist — Hadoop semantics).
    pub output_dir: String,
    pub n_reduces: u32,
    pub input_format: InputFormat,
    pub output_format: OutputFormat,
    /// Target split size in bytes.
    pub split_bytes: u64,
    /// For `InputFormat::RowRange` jobs (Teragen): `(total_rows, n_maps)`.
    pub synthetic_rows: Option<(u64, u64)>,
    pub mapper: Arc<dyn Mapper>,
    /// Multi-input jobs: when non-empty, splits are planned over every
    /// entry and each split runs its own entry's mapper (`mapper` and
    /// `input_dir` are ignored for split planning).
    pub tagged_inputs: Vec<TaggedInput>,
    /// Broadcast side-inputs, loaded once per run before map scheduling
    /// (each directory's bytes go to its [`BroadcastSink`]; the volume is
    /// surfaced as the `BROADCAST_BYTES` counter).
    pub broadcast_inputs: Vec<BroadcastInput>,
    pub reducer: Arc<dyn Reducer>,
    /// Optional map-side combiner, run over each sorted spill run before
    /// the segment is committed to the shuffle (Hadoop contract: it must
    /// emit records under the keys it was given, and be associative —
    /// combined and uncombined runs must reduce identically). Disabled
    /// globally by `HPCW_COMBINER=0`.
    pub combiner: Option<Arc<dyn Reducer>>,
    pub partitioner: Arc<dyn Partitioner>,
    /// Cap on records each reduce task serializes (ORDER BY ... LIMIT
    /// with a single reduce). Counted per attempt, so retries and
    /// speculative twins stay correct.
    pub reduce_limit: Option<u64>,
    /// Fault-injection schedule (tests).
    pub failures: FailurePlan,
    /// Optional whole-block map path (Terasort kernel acceleration).
    pub block_processor: Option<Arc<dyn BlockProcessor>>,
}

impl JobSpec {
    /// An identity job skeleton; callers override what they need.
    pub fn identity(name: &str, input_dir: &str, output_dir: &str, n_reduces: u32) -> JobSpec {
        JobSpec {
            name: name.to_string(),
            input_dir: input_dir.to_string(),
            output_dir: output_dir.to_string(),
            n_reduces,
            input_format: InputFormat::TeraRecords,
            output_format: OutputFormat::TeraRecords,
            split_bytes: 64 * 1024 * 1024,
            synthetic_rows: None,
            mapper: Arc::new(IdentityMapper),
            tagged_inputs: Vec::new(),
            broadcast_inputs: Vec::new(),
            reducer: Arc::new(IdentityReducer),
            combiner: None,
            partitioner: Arc::new(HashPartitioner),
            reduce_limit: None,
            failures: FailurePlan::none(),
            block_processor: None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hash_partitioner_in_range_and_spread() {
        let p = HashPartitioner;
        let mut seen = std::collections::BTreeSet::new();
        for i in 0..1000u32 {
            let k = i.to_be_bytes();
            let part = p.partition(&k, 16);
            assert!(part < 16);
            seen.insert(part);
        }
        assert_eq!(seen.len(), 16, "all partitions hit");
        // Deterministic.
        assert_eq!(p.partition(b"abc", 7), p.partition(b"abc", 7));
    }

    #[test]
    fn identity_mapper_round_trips() {
        let m = IdentityMapper;
        let mut out = Vec::new();
        m.map(b"k", b"v", &mut |k, v| out.push((k.to_vec(), v.to_vec())));
        assert_eq!(out, vec![(b"k".to_vec(), b"v".to_vec())]);
    }

    #[test]
    fn identity_reducer_emits_all_values() {
        let r = IdentityReducer;
        let vals: Vec<&[u8]> = vec![b"1", b"2", b"3"];
        let mut out = Vec::new();
        r.reduce(b"k", &mut vals.into_iter(), &mut |_, v| out.push(v.to_vec()));
        assert_eq!(out.len(), 3);
    }

    #[test]
    fn output_format_serialization() {
        let mut tera = Vec::new();
        OutputFormat::TeraRecords.write_record(&mut tera, b"kk", b"vv");
        assert_eq!(tera, b"kkvv");
        let mut kv = Vec::new();
        OutputFormat::TextKv.write_record(&mut kv, b"k", b"v");
        assert_eq!(kv, b"k\tv\n");
        let mut val = Vec::new();
        OutputFormat::TextValue.write_record(&mut val, b"k", b"v");
        assert_eq!(val, b"v\n");
    }
}
