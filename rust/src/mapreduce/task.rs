//! Task identity, attempts and fault-injection plans.

use std::collections::BTreeSet;
use std::fmt;
use std::sync::Mutex;

/// Map or reduce.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum TaskKind {
    Map,
    Reduce,
}

/// Task id within a job (`task_m_000017` style).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub struct TaskId {
    pub kind: TaskKind,
    pub index: u32,
}

impl fmt::Display for TaskId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let k = match self.kind {
            TaskKind::Map => 'm',
            TaskKind::Reduce => 'r',
        };
        write!(f, "task_{k}_{:06}", self.index)
    }
}

impl TaskId {
    pub fn map(index: u32) -> TaskId {
        TaskId {
            kind: TaskKind::Map,
            index,
        }
    }

    pub fn reduce(index: u32) -> TaskId {
        TaskId {
            kind: TaskKind::Reduce,
            index,
        }
    }
}

/// Hadoop's retry budget.
pub const MAX_ATTEMPTS: u32 = 4;

/// Fault-injection plan: `(task, attempt)` pairs that must fail, plus
/// `(task, attempt)` pairs that must *dawdle* (straggler injection for the
/// speculative-execution tests). Interior mutability so the engine can
/// consume injections from worker threads.
#[derive(Debug, Default)]
pub struct FailurePlan {
    fail: Mutex<BTreeSet<(TaskId, u32)>>,
    delay: Mutex<std::collections::BTreeMap<(TaskId, u32), u64>>,
}

impl FailurePlan {
    pub fn none() -> FailurePlan {
        FailurePlan::default()
    }

    /// Schedule attempt `attempt` of `task` to fail.
    pub fn fail_attempt(self, task: TaskId, attempt: u32) -> FailurePlan {
        self.fail.lock().unwrap().insert((task, attempt));
        self
    }

    /// Schedule attempt `attempt` of `task` to sleep `ms` before doing any
    /// work — a straggler for speculation to race.
    pub fn delay_attempt(self, task: TaskId, attempt: u32, ms: u64) -> FailurePlan {
        self.delay.lock().unwrap().insert((task, attempt), ms);
        self
    }

    /// Should this attempt fail? (Consumes the injection.)
    pub fn should_fail(&self, task: TaskId, attempt: u32) -> bool {
        self.fail.lock().unwrap().remove(&(task, attempt))
    }

    /// Straggler delay for this attempt in ms, if any. (Consumes the
    /// injection.)
    pub fn delay_for(&self, task: TaskId, attempt: u32) -> Option<u64> {
        self.delay.lock().unwrap().remove(&(task, attempt))
    }

    pub fn pending(&self) -> usize {
        self.fail.lock().unwrap().len() + self.delay.lock().unwrap().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_format() {
        assert_eq!(TaskId::map(17).to_string(), "task_m_000017");
        assert_eq!(TaskId::reduce(3).to_string(), "task_r_000003");
    }

    #[test]
    fn failure_plan_consumes_injections() {
        let plan = FailurePlan::none()
            .fail_attempt(TaskId::map(0), 0)
            .fail_attempt(TaskId::map(1), 0);
        assert_eq!(plan.pending(), 2);
        assert!(plan.should_fail(TaskId::map(0), 0));
        assert!(!plan.should_fail(TaskId::map(0), 0), "consumed");
        assert!(!plan.should_fail(TaskId::map(0), 1));
        assert_eq!(plan.pending(), 1);
    }

    #[test]
    fn delay_plan_consumes_injections() {
        let plan = FailurePlan::none().delay_attempt(TaskId::reduce(2), 0, 40);
        assert_eq!(plan.pending(), 1);
        assert_eq!(plan.delay_for(TaskId::reduce(2), 0), Some(40));
        assert_eq!(plan.delay_for(TaskId::reduce(2), 0), None, "consumed");
        assert_eq!(plan.pending(), 0);
    }
}
