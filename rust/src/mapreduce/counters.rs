//! MapReduce job counters (the Hadoop counter groups the JHS reports).

use std::collections::BTreeMap;
use std::sync::Mutex;

/// Thread-safe counter set, merged across task attempts.
#[derive(Debug, Default)]
pub struct Counters {
    inner: Mutex<BTreeMap<&'static str, u64>>,
}

/// Canonical counter names (subset of Hadoop's).
pub const MAP_INPUT_RECORDS: &str = "MAP_INPUT_RECORDS";
pub const MAP_OUTPUT_RECORDS: &str = "MAP_OUTPUT_RECORDS";
pub const MAP_OUTPUT_BYTES: &str = "MAP_OUTPUT_BYTES";
pub const MAP_SPILLS: &str = "MAP_SPILLS";
pub const SHUFFLE_BYTES: &str = "SHUFFLE_BYTES";
pub const SHUFFLE_SEGMENTS: &str = "SHUFFLE_SEGMENTS";
pub const REDUCE_INPUT_RECORDS: &str = "REDUCE_INPUT_RECORDS";
pub const REDUCE_OUTPUT_RECORDS: &str = "REDUCE_OUTPUT_RECORDS";
pub const REDUCE_OUTPUT_BYTES: &str = "REDUCE_OUTPUT_BYTES";
pub const TASKS_LAUNCHED: &str = "TASKS_LAUNCHED";
pub const TASKS_FAILED: &str = "TASKS_FAILED";
pub const TASKS_SPECULATED: &str = "TASKS_SPECULATED";
/// 1 once the first reduce container launches (slow-start marker).
pub const FIRST_REDUCE_LAUNCHED: &str = "FIRST_REDUCE_LAUNCHED";
/// Maps committed at the moment the first reduce launched. Under reduce
/// slow-start this is < total maps — the observable overlap signal.
pub const MAPS_AT_FIRST_REDUCE: &str = "MAPS_AT_FIRST_REDUCE";
/// Allocate rounds the scheduler retried because the RM granted zero
/// containers with nothing in flight (backoff path).
pub const GRANT_ZERO_RETRIES: &str = "GRANT_ZERO_RETRIES";
/// Containers granted over the job's lifetime (every grant is a release +
/// re-grant of freed capacity once the first wave is out).
pub const CONTAINERS_GRANTED: &str = "CONTAINERS_GRANTED";
/// Shuffle segments a reduce fetched before the job's last map committed
/// (slow-start fetch overlap).
pub const SHUFFLE_SEGMENTS_PREFETCHED: &str = "SHUFFLE_SEGMENTS_PREFETCHED";
/// Map containers granted on one of the split's preferred nodes.
pub const LOCAL_MAPS: &str = "LOCAL_MAPS";
/// Map containers granted in a preferred node's rack (but not on it).
pub const RACK_MAPS: &str = "RACK_MAPS";
/// Map containers granted with no locality match (or no preference).
pub const OTHER_MAPS: &str = "OTHER_MAPS";
/// Speculative duplicate attempts that committed before the original.
pub const SPECULATIVE_WINS: &str = "SPECULATIVE_WINS";
/// NodeManagers that joined the live cluster mid-job (elastic grow).
pub const NODES_JOINED: &str = "NODES_JOINED";
/// NodeManagers drained and returned to the batch scheduler mid-job.
pub const NODES_DRAINED: &str = "NODES_DRAINED";
/// NodeManagers lost mid-job (crash or missed-heartbeat expiry).
pub const NODES_FAILED: &str = "NODES_FAILED";
/// Committed map outputs invalidated by a node loss and re-executed.
pub const MAPS_INVALIDATED: &str = "MAPS_INVALIDATED";
/// Records fed into the map-side combiner (sorted spill runs).
pub const COMBINE_INPUT_RECORDS: &str = "COMBINE_INPUT_RECORDS";
/// Records the combiner emitted (what the shuffle actually carries).
pub const COMBINE_OUTPUT_RECORDS: &str = "COMBINE_OUTPUT_RECORDS";
/// Bytes shipped through the broadcast side-channel (DistributedCache
/// shape) before map scheduling — the broadcast-hash join's small side.
pub const BROADCAST_BYTES: &str = "BROADCAST_BYTES";
/// Logical-plan stages eliminated by map-stage fusion (planner counter,
/// stamped by the query layer rather than the engine).
pub const STAGES_FUSED: &str = "STAGES_FUSED";
/// Filter conjuncts the planner pushed below a join (planner counter).
pub const PREDICATE_PUSHDOWNS: &str = "PREDICATE_PUSHDOWNS";
/// DFS reads served from the in-memory burst tier (two-level storage).
pub const TIER_HITS: &str = "TIER_HITS";
/// DFS reads that missed the burst tier and faulted in from backing.
pub const TIER_MISSES: &str = "TIER_MISSES";
/// Burst-tier extents evicted to the backing tier under memory pressure.
pub const TIER_EVICTIONS: &str = "TIER_EVICTIONS";
/// Files promoted back into the burst tier on read-through.
pub const TIER_PROMOTIONS: &str = "TIER_PROMOTIONS";
/// Shuffle-segment bytes spilled to the backing tier.
pub const SPILL_BYTES: &str = "SPILL_BYTES";
/// File bytes persisted to the backing tier (write-behind + eviction).
pub const WRITEBACK_BYTES: &str = "WRITEBACK_BYTES";
/// Fair-share service share (percent) of the submitting tenant's queue at
/// the moment the job completed (multi-tenant scheduling).
pub const QUEUE_SHARE: &str = "QUEUE_SHARE";
/// Containers preempted from the tenant's queue over its lifetime.
pub const PREEMPTIONS: &str = "PREEMPTIONS";
/// Cumulative dispatch wait (µs) charged to the tenant's queue.
pub const QUEUE_WAIT_US: &str = "QUEUE_WAIT_US";
/// Attempt durations folded into the online per-(node, shape) runtime
/// estimator (adaptive scheduling).
pub const ESTIMATOR_UPDATES: &str = "ESTIMATOR_UPDATES";
/// Speculative duplicates triggered by the estimator's predicted-p95
/// threshold (as opposed to the static global multiplier).
pub const PREDICTED_P95_SPECULATIONS: &str = "PREDICTED_P95_SPECULATIONS";
/// Any-tier placements the fast-node bias steered onto a faster node
/// while a strictly slower candidate also had room.
pub const FAST_NODE_PLACEMENTS: &str = "FAST_NODE_PLACEMENTS";

impl Counters {
    pub fn new() -> Self {
        Counters::default()
    }

    pub fn add(&self, name: &'static str, by: u64) {
        *self.inner.lock().unwrap().entry(name).or_insert(0) += by;
    }

    /// Batched update: one lock acquisition for a whole task's counters.
    /// Tasks accumulate in local `u64`s and flush once here instead of
    /// taking the lock per record.
    pub fn add_many(&self, entries: &[(&'static str, u64)]) {
        let mut g = self.inner.lock().unwrap();
        for &(name, by) in entries {
            *g.entry(name).or_insert(0) += by;
        }
    }

    pub fn get(&self, name: &str) -> u64 {
        self.inner.lock().unwrap().get(name).copied().unwrap_or(0)
    }

    /// Snapshot for the history report.
    pub fn snapshot(&self) -> Vec<(String, u64)> {
        self.inner
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.to_string(), *v))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_and_get() {
        let c = Counters::new();
        c.add(MAP_INPUT_RECORDS, 10);
        c.add(MAP_INPUT_RECORDS, 5);
        assert_eq!(c.get(MAP_INPUT_RECORDS), 15);
        assert_eq!(c.get("missing"), 0);
    }

    #[test]
    fn add_many_batches() {
        let c = Counters::new();
        c.add(MAP_SPILLS, 1);
        c.add_many(&[(MAP_SPILLS, 2), (SHUFFLE_BYTES, 100)]);
        assert_eq!(c.get(MAP_SPILLS), 3);
        assert_eq!(c.get(SHUFFLE_BYTES), 100);
    }

    #[test]
    fn snapshot_sorted_by_name() {
        let c = Counters::new();
        c.add(SHUFFLE_BYTES, 1);
        c.add(MAP_SPILLS, 2);
        let snap = c.snapshot();
        assert_eq!(snap.len(), 2);
        assert!(snap[0].0 < snap[1].0);
    }
}
