//! Input splits and record formats.
//!
//! Three formats cover the stack:
//! * [`InputFormat::TeraRecords`] — fixed 100-byte Terasort records
//!   (10-byte key + 90-byte value), split on record boundaries;
//! * [`InputFormat::Lines`] — newline-delimited text (key = byte offset,
//!   value = line), splits aligned to line boundaries at read time;
//! * [`InputFormat::RowRange`] — synthetic splits with no backing file:
//!   Teragen's input ("generate rows [start, start+count)").

use crate::cluster::NodeId;
use crate::error::{Error, Result};
use crate::lustre::Dfs;
use crate::terasort::format::{split_record, RECORD_LEN};

/// Record format of a job's input.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InputFormat {
    TeraRecords,
    Lines,
    /// Synthetic: `InputSplit.offset` = first row id, `.len` = row count.
    RowRange,
}

/// One input split, processed by one map task.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct InputSplit {
    /// Backing file ("" for RowRange).
    pub path: String,
    /// Byte offset (or first row id for RowRange).
    pub offset: u64,
    /// Byte length (or row count for RowRange).
    pub len: u64,
    /// Nodes the scheduler should prefer for this split's map task, in
    /// order (derived from DFS shard residency by [`assign_locality`];
    /// empty = no preference, e.g. synthetic RowRange splits).
    pub preferred: Vec<NodeId>,
    /// Which `JobSpec::tagged_inputs` entry produced this split (0 for
    /// single-input jobs): the map task runs that entry's mapper.
    pub source: u32,
}

/// Plan splits over all files under `input_dir`.
///
/// TeraRecords splits are record-aligned; Lines splits are byte ranges that
/// the reader later aligns to line boundaries (Hadoop semantics: a split
/// owns every line that *starts* inside it).
pub fn plan_splits(
    dfs: &dyn Dfs,
    input_dir: &str,
    format: InputFormat,
    split_bytes: u64,
) -> Result<Vec<InputSplit>> {
    if format == InputFormat::RowRange {
        return Err(Error::MapReduce(
            "RowRange splits are synthesized by the job, not planned from files".into(),
        ));
    }
    let split_bytes = split_bytes.max(1);
    let mut out = Vec::new();
    let files = crate::lustre::visible_files(dfs, input_dir);
    if files.is_empty() {
        return Err(Error::MapReduce(format!("no input files in {input_dir}")));
    }
    for f in files {
        let size = dfs.size(&f)?;
        if size == 0 {
            continue;
        }
        let step = match format {
            InputFormat::TeraRecords => {
                if size % RECORD_LEN as u64 != 0 {
                    return Err(Error::MapReduce(format!(
                        "{f}: size {size} not a multiple of the {RECORD_LEN}-byte record"
                    )));
                }
                // Round the split down to a whole number of records.
                (split_bytes / RECORD_LEN as u64).max(1) * RECORD_LEN as u64
            }
            InputFormat::Lines => split_bytes,
            InputFormat::RowRange => unreachable!(),
        };
        let mut off = 0;
        while off < size {
            let len = step.min(size - off);
            out.push(InputSplit {
                path: f.clone(),
                offset: off,
                len,
                preferred: Vec::new(),
                source: 0,
            });
            off += len;
        }
    }
    Ok(out)
}

/// Synthesize RowRange splits for a generator job (Teragen).
pub fn row_range_splits(total_rows: u64, n_maps: u64) -> Vec<InputSplit> {
    let n_maps = n_maps.max(1).min(total_rows.max(1));
    let base = total_rows / n_maps;
    let extra = total_rows % n_maps;
    let mut out = Vec::with_capacity(n_maps as usize);
    let mut start = 0;
    for i in 0..n_maps {
        let count = base + if i < extra { 1 } else { 0 };
        out.push(InputSplit {
            path: String::new(),
            offset: start,
            len: count,
            preferred: Vec::new(),
            source: 0,
        });
        start += count;
    }
    out
}

/// Attach preferred nodes to each split from DFS shard residency: the
/// shard a split's file lives in is mapped onto the slave list, and the
/// next `replicas - 1` slaves back it up (the HDFS-replica analogue). The
/// RM's placement then honours node-local > rack-local > any. Splits with
/// no backing file (RowRange) and backends without residency information
/// keep an empty preference.
pub fn assign_locality(
    splits: &mut [InputSplit],
    dfs: &dyn Dfs,
    nodes: &[NodeId],
    replicas: u32,
) {
    if nodes.is_empty() || replicas == 0 {
        return;
    }
    for s in splits {
        if s.path.is_empty() {
            continue;
        }
        let Some(shard) = dfs.shard_of(&s.path) else {
            continue;
        };
        let anchor = (shard as usize) % nodes.len();
        let fanout = (replicas as usize).min(nodes.len());
        s.preferred = (0..fanout).map(|i| nodes[(anchor + i) % nodes.len()]).collect();
    }
}

/// Iterate the records of a split, calling `f(key, value)`.
pub fn read_records(
    dfs: &dyn Dfs,
    split: &InputSplit,
    format: InputFormat,
    f: &mut dyn FnMut(&[u8], &[u8]),
) -> Result<u64> {
    match format {
        InputFormat::TeraRecords => {
            // Zero-copy: slice the shared file extent in place instead of
            // copying the split's byte range out of the store.
            let file = dfs.open(&split.path)?;
            let start = (split.offset as usize).min(file.len());
            let end = ((split.offset + split.len) as usize).min(file.len());
            let buf = &file[start..end];
            if buf.len() % RECORD_LEN != 0 {
                return Err(Error::MapReduce(format!(
                    "split of {} not record aligned",
                    split.path
                )));
            }
            let mut n = 0;
            for rec in buf.chunks_exact(RECORD_LEN) {
                let (k, v) = split_record(rec);
                f(k, v);
                n += 1;
            }
            Ok(n)
        }
        InputFormat::Lines => {
            // A split owns lines that *start* within [offset, offset+len).
            // Slice a bit past the end of the shared extent to finish the
            // last line (no copy).
            let file = dfs.open(&split.path)?;
            let file_size = file.len() as u64;
            let read_to = (split.offset + split.len + 1024 * 1024).min(file_size);
            let start = (split.offset as usize).min(file.len());
            let buf = &file[start..(read_to as usize).max(start)];
            let mut pos = 0usize;
            // Skip the partial first line unless we start at 0 (it belongs
            // to the previous split).
            if split.offset > 0 {
                match buf.iter().position(|&b| b == b'\n') {
                    Some(i) => pos = i + 1,
                    None => return Ok(0),
                }
            }
            let mut n = 0;
            while pos < buf.len() {
                let abs = split.offset + pos as u64;
                if abs >= split.offset + split.len {
                    break; // line starts in the next split
                }
                let end = buf[pos..]
                    .iter()
                    .position(|&b| b == b'\n')
                    .map(|i| pos + i)
                    .unwrap_or(buf.len());
                let key = abs.to_be_bytes();
                f(&key, &buf[pos..end]);
                n += 1;
                pos = end + 1;
            }
            Ok(n)
        }
        InputFormat::RowRange => Err(Error::MapReduce(
            "RowRange records are synthesized by the mapper".into(),
        )),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::StackConfig;
    use crate::lustre::LustreFs;

    fn fs() -> LustreFs {
        let c = StackConfig::paper();
        LustreFs::new(&c.lustre, &c.cluster)
    }

    #[test]
    fn tera_splits_are_record_aligned() {
        let fs = fs();
        fs.mkdirs("/lustre/scratch/in").unwrap();
        fs.create("/lustre/scratch/in/part-0", &vec![0u8; 100 * 1000]).unwrap();
        let splits =
            plan_splits(&fs, "/lustre/scratch/in", InputFormat::TeraRecords, 30_000).unwrap();
        // 100,000 bytes in steps of 30,000 rounded to 100 → 300 recs/split.
        assert_eq!(splits.len(), 4);
        for s in &splits {
            assert_eq!(s.offset % 100, 0);
        }
        let total: u64 = splits.iter().map(|s| s.len).sum();
        assert_eq!(total, 100 * 1000);
    }

    #[test]
    fn tera_split_rejects_misaligned_file() {
        let fs = fs();
        fs.mkdirs("/lustre/scratch/bad").unwrap();
        fs.create("/lustre/scratch/bad/f", &[0u8; 150]).unwrap();
        assert!(plan_splits(&fs, "/lustre/scratch/bad", InputFormat::TeraRecords, 100).is_err());
    }

    #[test]
    fn hidden_files_skipped_and_empty_dir_errors() {
        let fs = fs();
        fs.mkdirs("/lustre/scratch/only-hidden").unwrap();
        fs.create("/lustre/scratch/only-hidden/_SUCCESS", b"").unwrap();
        assert!(
            plan_splits(&fs, "/lustre/scratch/only-hidden", InputFormat::TeraRecords, 100)
                .is_err()
        );
    }

    #[test]
    fn line_records_assigned_to_owning_split() {
        let fs = fs();
        fs.mkdirs("/lustre/scratch/txt").unwrap();
        let text = b"alpha\nbeta\ngamma\ndelta\n";
        fs.create("/lustre/scratch/txt/f", text).unwrap();
        let splits = plan_splits(&fs, "/lustre/scratch/txt", InputFormat::Lines, 8).unwrap();
        let mut all = Vec::new();
        for s in &splits {
            read_records(&fs, s, InputFormat::Lines, &mut |_, v| {
                all.push(String::from_utf8(v.to_vec()).unwrap());
            })
            .unwrap();
        }
        assert_eq!(all, vec!["alpha", "beta", "gamma", "delta"]);
    }

    #[test]
    fn tera_records_read_back() {
        let fs = fs();
        fs.mkdirs("/lustre/scratch/t2").unwrap();
        let mut data = Vec::new();
        for i in 0..5u8 {
            let mut rec = vec![i; 10];
            rec.extend_from_slice(&[0xAA; 90]);
            data.extend_from_slice(&rec);
        }
        fs.create("/lustre/scratch/t2/f", &data).unwrap();
        let splits = plan_splits(&fs, "/lustre/scratch/t2", InputFormat::TeraRecords, 200).unwrap();
        let mut keys = Vec::new();
        for s in &splits {
            read_records(&fs, s, InputFormat::TeraRecords, &mut |k, v| {
                assert_eq!(v.len(), 90);
                keys.push(k[0]);
            })
            .unwrap();
        }
        assert_eq!(keys, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn locality_assignment_is_deterministic_and_fans_out() {
        let fs = fs();
        fs.mkdirs("/lustre/scratch/loc").unwrap();
        for i in 0..4 {
            fs.create(&format!("/lustre/scratch/loc/part-{i}"), &vec![0u8; 300]).unwrap();
        }
        let nodes: Vec<NodeId> = (2..8).map(NodeId).collect();
        let mut a = plan_splits(&fs, "/lustre/scratch/loc", InputFormat::TeraRecords, 300).unwrap();
        let mut b = a.clone();
        assign_locality(&mut a, &fs, &nodes, 2);
        assign_locality(&mut b, &fs, &nodes, 2);
        assert_eq!(a, b, "residency-derived placement is deterministic");
        for s in &a {
            assert_eq!(s.preferred.len(), 2);
            assert_ne!(s.preferred[0], s.preferred[1]);
            assert!(s.preferred.iter().all(|n| nodes.contains(n)));
            // Splits of the same file share the same residency.
            let twin = a.iter().find(|t| t.path == s.path).unwrap();
            assert_eq!(twin.preferred, s.preferred);
        }
    }

    #[test]
    fn locality_skips_synthetic_splits() {
        let fs = fs();
        let mut splits = row_range_splits(10, 3);
        assign_locality(&mut splits, &fs, &[NodeId(0), NodeId(1)], 2);
        assert!(splits.iter().all(|s| s.preferred.is_empty()));
    }

    #[test]
    fn row_range_split_counts() {
        let splits = row_range_splits(10, 3);
        assert_eq!(splits.len(), 3);
        let counts: Vec<u64> = splits.iter().map(|s| s.len).collect();
        assert_eq!(counts, vec![4, 3, 3]);
        let starts: Vec<u64> = splits.iter().map(|s| s.offset).collect();
        assert_eq!(starts, vec![0, 4, 7]);
        // More maps than rows clamps.
        assert_eq!(row_range_splits(2, 100).len(), 2);
    }
}
