//! `RecordBuf` — the flat-record arena the MapReduce data path runs on.
//!
//! The legacy path moved every record as an owned `(Vec<u8>, Vec<u8>)`
//! pair: two heap allocations per record at map emit, pointer-chasing
//! comparisons in every sort, and a deep clone wherever a segment crossed
//! a boundary. `RecordBuf` stores all record payloads in one contiguous
//! byte buffer plus a compact `(offset, key_len, val_len)` index entry per
//! record, so:
//!
//! * map emit is an `extend_from_slice` into the arena (zero mallocs on
//!   the per-record path once the buffers are warm);
//! * sorting permutes 16-byte index entries decorated with a `u64`
//!   big-endian key prefix — the Terasort 10/90 fast path sorts on the
//!   prefix with `sort_unstable` and only touches full keys to resolve
//!   the (rare) prefix ties;
//! * shuffle segments share the arena behind an `Arc` — fetching a
//!   partition never copies record bytes.
//!
//! The prefix ordering is correct for arbitrary keys, not just Terasort's:
//! the zero-padded 8-byte big-endian prefix can never *invert* the
//! lexicographic byte order of two keys, only equate them, and equal
//! prefixes fall back to a full-key comparison.

use crate::terasort::format::key_prefix_u64;
use std::fmt;

/// Index entry: one record inside the arena.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct RecordMeta {
    offset: u64,
    key_len: u32,
    val_len: u32,
}

impl RecordMeta {
    #[inline]
    fn end(&self) -> usize {
        self.offset as usize + self.key_len as usize + self.val_len as usize
    }
}

/// Contiguous record storage + per-record index. Records keep their push
/// order until [`RecordBuf::sort_by_key`] permutes the index.
#[derive(Clone, Default)]
pub struct RecordBuf {
    data: Vec<u8>,
    index: Vec<RecordMeta>,
}

impl RecordBuf {
    pub fn new() -> RecordBuf {
        RecordBuf::default()
    }

    /// Pre-size for `records` entries totalling `bytes` of payload.
    pub fn with_capacity(records: usize, bytes: usize) -> RecordBuf {
        RecordBuf {
            data: Vec::with_capacity(bytes),
            index: Vec::with_capacity(records),
        }
    }

    /// Append one record (copies the payload into the arena).
    #[inline]
    pub fn push(&mut self, key: &[u8], value: &[u8]) {
        let offset = self.data.len() as u64;
        self.data.extend_from_slice(key);
        self.data.extend_from_slice(value);
        self.index.push(RecordMeta {
            offset,
            key_len: key.len() as u32,
            val_len: value.len() as u32,
        });
    }

    /// Fixed-width fast path: append a whole record (key followed by value
    /// in one contiguous slice) with a single copy — the Terasort read
    /// path pushes 100-byte records with `key_len = 10`.
    #[inline]
    pub fn push_record(&mut self, record: &[u8], key_len: usize) {
        debug_assert!(key_len <= record.len());
        let offset = self.data.len() as u64;
        self.data.extend_from_slice(record);
        self.index.push(RecordMeta {
            offset,
            key_len: key_len as u32,
            val_len: (record.len() - key_len) as u32,
        });
    }

    /// Copy record `i` of `src` into this buffer.
    #[inline]
    pub fn push_from(&mut self, src: &RecordBuf, i: usize) {
        let m = src.index[i];
        let offset = self.data.len() as u64;
        self.data
            .extend_from_slice(&src.data[m.offset as usize..m.end()]);
        self.index.push(RecordMeta { offset, ..m });
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.index.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.index.is_empty()
    }

    /// Total payload bytes held (keys + values).
    #[inline]
    pub fn payload_bytes(&self) -> u64 {
        self.data.len() as u64
    }

    #[inline]
    pub fn key(&self, i: usize) -> &[u8] {
        let m = self.index[i];
        &self.data[m.offset as usize..m.offset as usize + m.key_len as usize]
    }

    #[inline]
    pub fn value(&self, i: usize) -> &[u8] {
        let m = self.index[i];
        let start = m.offset as usize + m.key_len as usize;
        &self.data[start..start + m.val_len as usize]
    }

    /// `(key, value)` of record `i`.
    #[inline]
    pub fn get(&self, i: usize) -> (&[u8], &[u8]) {
        let m = self.index[i];
        let ks = m.offset as usize;
        let vs = ks + m.key_len as usize;
        (&self.data[ks..vs], &self.data[vs..vs + m.val_len as usize])
    }

    /// Iterate `(key, value)` in index order.
    pub fn iter(&self) -> impl Iterator<Item = (&[u8], &[u8])> + '_ {
        (0..self.len()).map(move |i| self.get(i))
    }

    /// Stable sort by key, permuting only the index. Decorates each entry
    /// with its `u64` key prefix, sorts the `(prefix, position)` pairs with
    /// `sort_unstable` (total order — equal prefixes break on the original
    /// position, so the result is stable), then resolves equal-prefix runs
    /// on the full key via [`resolve_prefix_ties`]. Allocates O(records)
    /// index words, never touches payload bytes.
    pub fn sort_by_key(&mut self) {
        fn key_at<'b>(data: &'b [u8], m: &RecordMeta) -> &'b [u8] {
            &data[m.offset as usize..m.offset as usize + m.key_len as usize]
        }
        if self.index.len() <= 1 {
            return;
        }
        let data = self.data.as_slice();
        let index = &self.index;
        let prefixes: Vec<u64> = index
            .iter()
            .map(|m| key_prefix_u64(key_at(data, m)))
            .collect();
        let mut decorated: Vec<(u64, u32)> = prefixes
            .iter()
            .enumerate()
            .map(|(i, &p)| (p, i as u32))
            .collect();
        decorated.sort_unstable();
        let mut order: Vec<u32> = decorated.iter().map(|&(_, i)| i).collect();
        resolve_prefix_ties(
            &mut order,
            |i| prefixes[i as usize],
            |i| key_at(data, &index[i as usize]),
        );
        let new_index: Vec<RecordMeta> =
            order.iter().map(|&i| index[i as usize]).collect();
        self.index = new_index;
    }

    /// Are the records in non-decreasing key order?
    pub fn is_sorted_by_key(&self) -> bool {
        (1..self.len()).all(|i| self.key(i - 1) <= self.key(i))
    }

    /// Build from owned pairs (tests and migration shims).
    pub fn from_pairs<I, K, V>(pairs: I) -> RecordBuf
    where
        I: IntoIterator<Item = (K, V)>,
        K: AsRef<[u8]>,
        V: AsRef<[u8]>,
    {
        let mut out = RecordBuf::new();
        for (k, v) in pairs {
            out.push(k.as_ref(), v.as_ref());
        }
        out
    }

    /// Materialize as owned pairs (tests and migration shims).
    pub fn to_pairs(&self) -> Vec<(Vec<u8>, Vec<u8>)> {
        self.iter()
            .map(|(k, v)| (k.to_vec(), v.to_vec()))
            .collect()
    }
}

/// Given `order` already sorted by `prefix`, re-sort every equal-prefix
/// run by the full key, with the order value itself as the final tiebreak
/// — restoring stable full-key order after a prefix-only sort. Shared by
/// [`RecordBuf::sort_by_key`] and the kernel block processor, whose
/// byte-identical parity depends on both using the same tie rules.
pub(crate) fn resolve_prefix_ties<'a>(
    order: &mut [u32],
    prefix: impl Fn(u32) -> u64,
    key: impl Fn(u32) -> &'a [u8],
) {
    let mut i = 0;
    while i < order.len() {
        let pi = prefix(order[i]);
        let mut j = i + 1;
        while j < order.len() && prefix(order[j]) == pi {
            j += 1;
        }
        if j - i > 1 {
            order[i..j].sort_unstable_by(|&a, &b| key(a).cmp(key(b)).then(a.cmp(&b)));
        }
        i = j;
    }
}

/// Run a combiner over the equal-key runs of a **sorted** buffer,
/// returning the combined records (spill-time combining: the map task
/// calls this per partition before committing the segment). The combiner
/// must emit records under the key of the run it is reducing, so the
/// result stays sorted; `ShuffleStore::put`'s debug assertion enforces
/// it.
pub fn combine_sorted(records: &RecordBuf, combiner: &dyn crate::mapreduce::Reducer) -> RecordBuf {
    debug_assert!(records.is_sorted_by_key());
    let n = records.len();
    let mut out = RecordBuf::with_capacity(n.min(1024), 0);
    let mut i = 0;
    while i < n {
        let key = records.key(i);
        let mut j = i + 1;
        while j < n && records.key(j) == key {
            j += 1;
        }
        let mut values = (i..j).map(|r| records.value(r));
        combiner.reduce(key, &mut values, &mut |k, v| out.push(k, v));
        i = j;
    }
    out
}

/// A batch of delimited text rows with a precomputed per-field index,
/// layered on [`RecordBuf`]: line payloads live in one arena and each row
/// carries `arity + 1` cut points, so consumers (projection, aggregation,
/// the broadcast hash-table build) slice only the columns an expression
/// references instead of re-splitting every row per record.
///
/// Field `i` of a row spans `cuts[i] .. cuts[i+1] - 1` within the line
/// (the `-1` skips the delimiter). Rows shorter than `arity` index the
/// missing fields as empty; extra trailing fields are ignored — matching
/// the query layer's pad/truncate row contract.
#[derive(Clone, Default)]
pub struct ColumnBatch {
    lines: RecordBuf,
    cuts: Vec<u32>,
    arity: usize,
    delimiter: u8,
}

impl ColumnBatch {
    pub fn new(arity: usize, delimiter: u8) -> ColumnBatch {
        ColumnBatch {
            lines: RecordBuf::new(),
            cuts: Vec::new(),
            arity,
            delimiter,
        }
    }

    #[inline]
    pub fn arity(&self) -> usize {
        self.arity
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.lines.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.lines.is_empty()
    }

    /// Append one row, scanning its delimiters once.
    pub fn push_line(&mut self, line: &[u8]) {
        self.lines.push(b"", line);
        let sentinel = line.len() as u32 + 1;
        self.cuts.push(0);
        let mut have = 1;
        for (pos, &b) in line.iter().enumerate() {
            if b == self.delimiter {
                self.cuts.push(pos as u32 + 1);
                have += 1;
                if have == self.arity + 1 {
                    break;
                }
            }
        }
        while have < self.arity + 1 {
            self.cuts.push(sentinel);
            have += 1;
        }
    }

    /// The raw line bytes of row `row`.
    #[inline]
    pub fn line(&self, row: usize) -> &[u8] {
        self.lines.value(row)
    }

    /// Field `col` of row `row` without re-splitting the line; empty for
    /// columns past the row's end or past the batch arity.
    #[inline]
    pub fn field(&self, row: usize, col: usize) -> &[u8] {
        if col >= self.arity {
            return b"";
        }
        let line = self.lines.value(row);
        let c = &self.cuts[row * (self.arity + 1)..];
        let len = line.len();
        let start = (c[col] as usize).min(len);
        let end = (c[col + 1] as usize).saturating_sub(1).clamp(start, len);
        &line[start..end]
    }

    /// Number of fields actually present in row `row`'s line, capped at
    /// the batch arity — what `line.split(delim).count()` would report
    /// for short rows (padding cuts carry the out-of-range sentinel and
    /// don't count; real cut offsets never exceed the line length).
    #[inline]
    pub fn fields_in(&self, row: usize) -> usize {
        let len = self.line(row).len() as u32;
        let c = &self.cuts[row * (self.arity + 1)..(row + 1) * (self.arity + 1)];
        self.arity.min(c[1..].iter().filter(|&&x| x <= len).count() + 1)
    }

    /// Total line payload bytes held.
    #[inline]
    pub fn payload_bytes(&self) -> u64 {
        self.lines.payload_bytes()
    }

    pub fn clear(&mut self) {
        self.lines = RecordBuf::new();
        self.cuts.clear();
    }
}

impl fmt::Debug for ColumnBatch {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "ColumnBatch({} rows x {} cols, {} bytes)",
            self.rows(),
            self.arity,
            self.payload_bytes()
        )
    }
}

/// Logical equality: same records in the same order, regardless of arena
/// layout (a sorted buffer equals a freshly-pushed sorted copy).
impl PartialEq for RecordBuf {
    fn eq(&self, other: &RecordBuf) -> bool {
        self.len() == other.len() && (0..self.len()).all(|i| self.get(i) == other.get(i))
    }
}

impl Eq for RecordBuf {}

impl fmt::Debug for RecordBuf {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "RecordBuf({} records, {} bytes)",
            self.len(),
            self.payload_bytes()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::props;

    #[test]
    fn push_and_get_round_trip() {
        let mut rb = RecordBuf::new();
        rb.push(b"alpha", b"1");
        rb.push(b"", b"empty-key");
        rb.push_record(b"key-and-value", 3);
        assert_eq!(rb.len(), 3);
        assert_eq!(rb.get(0), (&b"alpha"[..], &b"1"[..]));
        assert_eq!(rb.get(1), (&b""[..], &b"empty-key"[..]));
        assert_eq!(rb.get(2), (&b"key"[..], &b"-and-value"[..]));
        assert_eq!(rb.payload_bytes(), 6 + 9 + 13);
    }

    #[test]
    fn prefix_never_inverts_byte_order() {
        let cases: &[(&[u8], &[u8])] = &[
            (b"a", b"ab"),
            (b"a\x00", b"a"),
            (b"a\x01", b"a"),
            (b"same-key!", b"same-key!"),
            (b"", b"x"),
            (b"\xff\xff\xff\xff\xff\xff\xff\xff\x01", b"\xff\xff\xff\xff\xff\xff\xff\xff"),
        ];
        for &(a, b) in cases {
            let (pa, pb) = (key_prefix_u64(a), key_prefix_u64(b));
            if pa != pb {
                assert_eq!(pa.cmp(&pb), a.cmp(b), "{a:?} vs {b:?}");
            }
        }
    }

    #[test]
    fn sort_matches_legacy_pairs_sort() {
        props(60, |g| {
            let n = g.usize(0..120);
            let mut pairs: Vec<(Vec<u8>, Vec<u8>)> = Vec::new();
            let mut rb = RecordBuf::new();
            for seq in 0..n {
                // Short keys from a small alphabet force duplicates (and
                // prefix ties); the value records the emission order so
                // stability is observable.
                let klen = g.usize(0..12);
                let key: Vec<u8> = (0..klen).map(|_| g.u32(0..4) as u8).collect();
                let val = format!("seq-{seq}").into_bytes();
                rb.push(&key, &val);
                pairs.push((key, val));
            }
            rb.sort_by_key();
            pairs.sort_by(|a, b| a.0.cmp(&b.0)); // legacy path: stable Vec sort
            assert_eq!(rb.to_pairs(), pairs);
            assert!(rb.is_sorted_by_key());
        });
    }

    #[test]
    fn sort_fixed_width_terasort_records() {
        use crate::terasort::format::record_for_row;
        let mut rb = RecordBuf::new();
        let mut pairs = Vec::new();
        for row in 0..500u64 {
            let rec = record_for_row(7, row);
            rb.push_record(&rec, 10);
            pairs.push((rec[..10].to_vec(), rec[10..].to_vec()));
        }
        rb.sort_by_key();
        pairs.sort_by(|a, b| a.0.cmp(&b.0));
        assert_eq!(rb.to_pairs(), pairs);
    }

    #[test]
    fn from_pairs_round_trips() {
        let pairs = vec![
            (b"k1".to_vec(), b"v1".to_vec()),
            (b"k0".to_vec(), b"v0".to_vec()),
        ];
        let rb = RecordBuf::from_pairs(pairs.clone());
        assert_eq!(rb.to_pairs(), pairs);
    }

    #[test]
    fn logical_equality_ignores_layout() {
        let mut a = RecordBuf::new();
        a.push(b"b", b"2");
        a.push(b"a", b"1");
        a.sort_by_key(); // permuted index, original arena layout
        let mut b = RecordBuf::new();
        b.push(b"a", b"1");
        b.push(b"b", b"2"); // contiguous sorted layout
        assert_eq!(a, b);
    }

    #[test]
    fn combine_sorted_folds_equal_key_runs() {
        struct CountCombiner;
        impl crate::mapreduce::Reducer for CountCombiner {
            fn reduce(
                &self,
                key: &[u8],
                values: &mut dyn Iterator<Item = &[u8]>,
                emit: &mut dyn FnMut(&[u8], &[u8]),
            ) {
                let n = values.count();
                emit(key, n.to_string().as_bytes());
            }
        }
        let mut rb = RecordBuf::new();
        rb.push(b"a", b"x");
        rb.push(b"a", b"y");
        rb.push(b"b", b"z");
        rb.push(b"c", b"w");
        rb.push(b"c", b"v");
        assert!(rb.is_sorted_by_key());
        let out = combine_sorted(&rb, &CountCombiner);
        assert_eq!(
            out.to_pairs(),
            vec![
                (b"a".to_vec(), b"2".to_vec()),
                (b"b".to_vec(), b"1".to_vec()),
                (b"c".to_vec(), b"2".to_vec()),
            ]
        );
        assert!(out.is_sorted_by_key());
        // Empty input combines to empty output.
        assert_eq!(combine_sorted(&RecordBuf::new(), &CountCombiner).len(), 0);
    }

    #[test]
    fn column_batch_slices_match_split_semantics() {
        let mut cb = ColumnBatch::new(3, b',');
        cb.push_line(b"wales,widget,120");
        cb.push_line(b"a,,b"); // empty middle field
        cb.push_line(b"short"); // fewer fields than arity -> empty pads
        cb.push_line(b""); // empty line
        cb.push_line(b"x,y,z,extra,extra2"); // extra fields ignored
        assert_eq!(cb.rows(), 5);
        assert_eq!(cb.arity(), 3);
        assert_eq!(cb.field(0, 0), b"wales");
        assert_eq!(cb.field(0, 1), b"widget");
        assert_eq!(cb.field(0, 2), b"120");
        assert_eq!(cb.field(1, 1), b"");
        assert_eq!(cb.field(1, 2), b"b");
        assert_eq!(cb.field(2, 0), b"short");
        assert_eq!(cb.field(2, 1), b"");
        assert_eq!(cb.field(2, 2), b"");
        assert_eq!(cb.field(3, 0), b"");
        assert_eq!(cb.field(4, 2), b"z");
        assert_eq!(cb.field(0, 7), b"", "past arity is empty");
        assert_eq!(cb.line(0), b"wales,widget,120");
        cb.clear();
        assert!(cb.is_empty());
    }

    #[test]
    fn column_batch_field_counts_match_split() {
        let mut cb = ColumnBatch::new(3, b',');
        for line in ["a,b,c", "a,b", "a,", "a", "", "a,b,c,d,e", ",,", ",,,"] {
            cb.push_line(line.as_bytes());
        }
        let want: Vec<usize> = ["a,b,c", "a,b", "a,", "a", "", "a,b,c,d,e", ",,", ",,,"]
            .iter()
            .map(|l| l.split(',').count().min(3))
            .collect();
        let got: Vec<usize> = (0..cb.rows()).map(|r| cb.fields_in(r)).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn column_batch_parity_with_split_property() {
        props(40, |g| {
            let arity = g.usize(1..6);
            let mut cb = ColumnBatch::new(arity, b',');
            let mut expected: Vec<Vec<String>> = Vec::new();
            for _ in 0..g.usize(0..30) {
                let n_fields = g.usize(0..8);
                let fields: Vec<String> = (0..n_fields).map(|_| g.ident(6)).collect();
                let line = fields.join(",");
                cb.push_line(line.as_bytes());
                // Reference: split, truncate to arity, pad with "".
                let mut split: Vec<String> = if line.is_empty() && n_fields == 0 {
                    vec![String::new()]
                } else {
                    line.split(',').map(str::to_string).collect()
                };
                split.truncate(arity);
                while split.len() < arity {
                    split.push(String::new());
                }
                expected.push(split);
            }
            for (row, fields) in expected.iter().enumerate() {
                for (col, want) in fields.iter().enumerate() {
                    assert_eq!(
                        cb.field(row, col),
                        want.as_bytes(),
                        "row {row} col {col}"
                    );
                }
            }
        });
    }

    #[test]
    fn push_from_copies_one_record() {
        let mut src = RecordBuf::new();
        src.push(b"k0", b"v0");
        src.push(b"k1", b"v1");
        let mut dst = RecordBuf::new();
        dst.push_from(&src, 1);
        assert_eq!(dst.to_pairs(), vec![(b"k1".to_vec(), b"v1".to_vec())]);
    }
}
