//! The Real-mode MapReduce executor: actual bytes through the live YARN
//! cluster built by the wrapper.
//!
//! Since PR 2 the default execution is **event-driven** (see
//! [`SchedMode::Pipelined`]): the AM-side scheduler loop grants containers
//! for pending tasks, submits each task attempt to the worker pool with a
//! completion channel ([`crate::util::pool::Pool::submit_with`]), and on
//! every completion releases that container back to the RM and immediately
//! re-grants freed capacity to the next pending task — no wave barrier, so
//! one straggler no longer idles the whole wave. Reduce tasks launch under
//! Hadoop-style **slow-start**: once `HPCW_SLOWSTART` (default 0.8) of the
//! maps have committed, reduces are granted containers and begin fetching
//! already-committed shuffle segments ([`ShuffleStore::try_fetch`])
//! concurrently with the remaining maps. A zero-container grant with
//! nothing in flight retries with bounded backoff instead of failing the
//! job.
//!
//! The pre-PR-2 lock-step wave execution survives as
//! [`SchedMode::Barriered`] — the measured baseline for
//! `benches/fig5_terasort.rs` and the parity oracle for
//! `rust/tests/prop_coordinator.rs`.
//!
//! Failed attempts (fault injection, panics) retry up to
//! [`task::MAX_ATTEMPTS`]; a node failure mid-job invalidates its shuffle
//! segments and re-runs exactly the affected maps.

use crate::error::{Error, Result};
use crate::lustre::Dfs;
use crate::mapreduce::counters::{self, Counters};
use crate::mapreduce::recordbuf::RecordBuf;
use crate::mapreduce::shuffle::{merge_segments, Segment, ShuffleStore};
use crate::mapreduce::split::{plan_splits, read_records, row_range_splits, InputFormat, InputSplit};
use crate::mapreduce::task::{TaskId, MAX_ATTEMPTS};
use crate::mapreduce::JobSpec;
use crate::util::ids::AppId;
use crate::util::pool::Pool;
use crate::util::time::Micros;
use crate::wrapper::DynamicCluster;
use crate::yarn::container::{Container, ContainerKind, ContainerRequest, Resource};
use crate::yarn::jobhistory::AppReport;
use crate::yarn::rm::AppState;
use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// How the engine schedules task attempts onto containers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedMode {
    /// Event-driven overlap scheduler (default): per-completion container
    /// release/re-grant, reduce slow-start, zero-grant backoff.
    Pipelined,
    /// Legacy lock-step waves (benchmark baseline / parity oracle).
    Barriered,
}

/// Default reduce slow-start fraction (Hadoop's
/// `mapreduce.job.reduce.slowstart.completedmaps` lore value).
pub const DEFAULT_SLOWSTART: f64 = 0.8;

/// Bounded retries when the RM grants zero containers with nothing in
/// flight (capacity may free up between scheduler cycles on a busy
/// cluster).
const MAX_GRANT_RETRIES: u32 = 6;
const GRANT_BACKOFF_START: Duration = Duration::from_micros(500);

/// Reduce slow-start poll interval while waiting for map segments.
const FETCH_POLL: Duration = Duration::from_micros(300);

/// Wall-clock phase marks of one job, seconds since submission. In
/// pipelined mode `first_reduce_launch_s < last_map_commit_s` is the
/// map/reduce overlap window; in barriered mode the overlap is zero by
/// construction.
#[derive(Debug, Clone, Copy, Default)]
pub struct PhaseTimings {
    pub first_map_launch_s: f64,
    pub last_map_commit_s: f64,
    /// 0.0 for map-only jobs.
    pub first_reduce_launch_s: f64,
    pub last_reduce_commit_s: f64,
    pub total_s: f64,
}

impl PhaseTimings {
    /// Seconds during which reduces were launched while maps were still
    /// committing.
    pub fn overlap_s(&self) -> f64 {
        if self.first_reduce_launch_s <= 0.0 {
            return 0.0;
        }
        (self.last_map_commit_s - self.first_reduce_launch_s).max(0.0)
    }
}

/// Result of a completed job.
#[derive(Debug)]
pub struct MrOutcome {
    pub app: AppId,
    pub maps: u32,
    pub reduces: u32,
    pub counters: Arc<Counters>,
    pub output_files: Vec<String>,
    pub wall: std::time::Duration,
    pub phases: PhaseTimings,
}

fn env_sched_mode() -> SchedMode {
    match std::env::var("HPCW_SCHED").as_deref() {
        Ok("barriered") | Ok("waves") => SchedMode::Barriered,
        _ => SchedMode::Pipelined,
    }
}

fn env_slowstart() -> f64 {
    std::env::var("HPCW_SLOWSTART")
        .ok()
        .and_then(|s| s.parse::<f64>().ok())
        .map(|f| f.clamp(0.0, 1.0))
        .unwrap_or(DEFAULT_SLOWSTART)
}

/// The Real-mode engine. Holds the live cluster and the worker pool.
pub struct MrEngine<'a> {
    pub cluster: &'a mut DynamicCluster,
    pub dfs: Arc<dyn Dfs>,
    pub pool: &'a Pool,
    pub map_memory_mb: u64,
    pub reduce_memory_mb: u64,
    /// Scheduling mode (`HPCW_SCHED=barriered` flips the default).
    pub mode: SchedMode,
    /// Reduce slow-start fraction in `[0, 1]` (`HPCW_SLOWSTART`).
    pub slowstart: f64,
}

impl<'a> MrEngine<'a> {
    pub fn new(
        cluster: &'a mut DynamicCluster,
        dfs: Arc<dyn Dfs>,
        pool: &'a Pool,
        map_memory_mb: u64,
        reduce_memory_mb: u64,
    ) -> Self {
        MrEngine {
            cluster,
            dfs,
            pool,
            map_memory_mb,
            reduce_memory_mb,
            mode: env_sched_mode(),
            slowstart: env_slowstart(),
        }
    }

    pub fn with_mode(mut self, mode: SchedMode) -> Self {
        self.mode = mode;
        self
    }

    pub fn with_slowstart(mut self, frac: f64) -> Self {
        self.slowstart = frac.clamp(0.0, 1.0);
        self
    }

    /// Run a job to completion. `now` is the logical submission time used
    /// for YARN bookkeeping; wall time is measured for the outcome.
    pub fn run(&mut self, spec: Arc<JobSpec>, user: &str, now: Micros) -> Result<MrOutcome> {
        let t0 = Instant::now();
        if self.dfs.exists(&spec.output_dir) {
            return Err(Error::MapReduce(format!(
                "output dir '{}' already exists",
                spec.output_dir
            )));
        }
        let splits: Vec<InputSplit> = match spec.input_format {
            InputFormat::RowRange => {
                let (rows, maps) = spec.synthetic_rows.ok_or_else(|| {
                    Error::MapReduce("RowRange job without synthetic_rows".into())
                })?;
                row_range_splits(rows, maps)
            }
            fmt => plan_splits(&*self.dfs, &spec.input_dir, fmt, spec.split_bytes)?,
        };
        // Shared once: task attempts, retries and re-grants borrow the same
        // allocation instead of cloning split metadata per attempt.
        let splits: Arc<[InputSplit]> = splits.into();
        let n_maps = splits.len() as u32;
        let n_reduces = spec.n_reduces; // 0 = map-only job (Teragen)

        // Output scaffolding.
        self.dfs.mkdirs(&spec.output_dir)?;
        let tmp_root = format!("{}/_temporary", spec.output_dir);
        self.dfs.mkdirs(&tmp_root)?;

        let handle = self.cluster.rm.submit_app(&spec.name, user, now)?;
        let counters = Arc::new(Counters::new());
        let shuffle = Arc::new(ShuffleStore::new());

        let mut phases = PhaseTimings::default();
        let exec = match self.mode {
            SchedMode::Pipelined => self.run_pipelined(
                &spec, &handle.app, &splits, &shuffle, &counters, &tmp_root, now, t0,
                &mut phases,
            ),
            SchedMode::Barriered => self.run_barriered(
                &spec, &handle.app, &splits, &shuffle, &counters, &tmp_root, now, t0,
                &mut phases,
            ),
        };
        if let Err(e) = exec {
            self.fail_app(&spec, handle.app, user, &counters, now)?;
            return Err(e);
        }

        // Commit: _SUCCESS marker, drop _temporary.
        self.dfs.delete_recursive(&tmp_root)?;
        self.dfs.create(&format!("{}/_SUCCESS", spec.output_dir), b"")?;

        self.cluster
            .rm
            .finish_app(handle.app, AppState::Finished, now)?;
        self.cluster.jhs.record(
            AppReport {
                app: handle.app,
                name: spec.name.clone(),
                user: user.to_string(),
                state: AppState::Finished,
                submitted_at: now,
                finished_at: now + Micros::from_secs_f64(t0.elapsed().as_secs_f64()),
                counters: counters.snapshot(),
            },
            &*self.dfs,
        )?;

        let output_files = self
            .dfs
            .list(&spec.output_dir)
            .into_iter()
            .filter(|p| p.contains("/part-"))
            .collect();
        phases.total_s = t0.elapsed().as_secs_f64();
        Ok(MrOutcome {
            app: handle.app,
            maps: n_maps,
            reduces: n_reduces,
            counters,
            output_files,
            wall: t0.elapsed(),
            phases,
        })
    }

    fn fail_app(
        &mut self,
        spec: &JobSpec,
        app: AppId,
        user: &str,
        counters: &Arc<Counters>,
        now: Micros,
    ) -> Result<()> {
        self.cluster.rm.finish_app(app, AppState::Failed, now)?;
        self.cluster.jhs.record(
            AppReport {
                app,
                name: spec.name.clone(),
                user: user.to_string(),
                state: AppState::Failed,
                submitted_at: now,
                finished_at: now,
                counters: counters.snapshot(),
            },
            &*self.dfs,
        )?;
        Ok(())
    }

    /// Complete a container on its NM and release it back to the RM — the
    /// per-task-completion release that replaces `finish_wave`.
    fn finish_container(&mut self, app: &AppId, c: &Container, ok: bool) -> Result<()> {
        if let Some(nm) = self.cluster.nms.get_mut(&c.node) {
            nm.complete(c.id, ok)?;
        }
        self.cluster.rm.release(*app, c.id)?;
        Ok(())
    }

    /// Allocate up to `want` containers of `mem_mb` and launch them on
    /// their NMs. May grant fewer (including zero) — YARN semantics; the
    /// caller re-requests as capacity frees.
    fn grant(
        &mut self,
        app: &AppId,
        want: u32,
        mem_mb: u64,
        kind: ContainerKind,
        now: Micros,
    ) -> Result<Vec<Container>> {
        let got = self.cluster.rm.allocate(
            *app,
            ContainerRequest {
                resource: Resource::new(mem_mb, 1),
                count: want,
            },
            kind,
            now,
        )?;
        for c in &got {
            if let Some(nm) = self.cluster.nms.get_mut(&c.node) {
                nm.launch(c.id)?;
            }
        }
        Ok(got)
    }

    // ------------------------------------------------------------------
    // Pipelined (event-driven) scheduler
    // ------------------------------------------------------------------

    #[allow(clippy::too_many_arguments)]
    fn run_pipelined(
        &mut self,
        spec: &Arc<JobSpec>,
        app: &AppId,
        splits: &Arc<[InputSplit]>,
        shuffle: &Arc<ShuffleStore>,
        counters: &Arc<Counters>,
        tmp_root: &str,
        now: Micros,
        t0: Instant,
        phases: &mut PhaseTimings,
    ) -> Result<()> {
        let mut running: BTreeMap<u64, InFlight> = BTreeMap::new();
        let (tx, rx): (TaskTx, TaskRx) = channel();
        let cancel = Arc::new(AtomicBool::new(false));
        let result = self.pipelined_loop(
            spec, app, splits, shuffle, counters, tmp_root, now, t0, phases, &tx, &rx,
            &cancel, &mut running,
        );
        if result.is_err() {
            // Whatever failed, leave the shared pool clean: flag in-flight
            // slow-start reduces to stop waiting and drain every running
            // task so its container is released (fail_app sweeps any
            // release this misses).
            self.drain_failed(app, &rx, &mut running, &cancel);
        }
        result
    }

    #[allow(clippy::too_many_arguments)]
    fn pipelined_loop(
        &mut self,
        spec: &Arc<JobSpec>,
        app: &AppId,
        splits: &Arc<[InputSplit]>,
        shuffle: &Arc<ShuffleStore>,
        counters: &Arc<Counters>,
        tmp_root: &str,
        now: Micros,
        t0: Instant,
        phases: &mut PhaseTimings,
        tx: &TaskTx,
        rx: &TaskRx,
        cancel: &Arc<AtomicBool>,
        running: &mut BTreeMap<u64, InFlight>,
    ) -> Result<()> {
        let n_maps = splits.len() as u32;
        let n_reduces = spec.n_reduces;
        let map_only = n_reduces == 0;
        // Reduces become eligible once this many maps committed.
        let slowstart_target = ((self.slowstart * n_maps as f64).ceil() as u32).min(n_maps);

        let mut pending_maps: VecDeque<(u32, u32)> =
            (0..n_maps).map(|i| (i, 0)).collect();
        let mut pending_reduces: VecDeque<(u32, u32)> = if map_only {
            VecDeque::new()
        } else {
            (0..n_reduces).map(|r| (r, 0)).collect()
        };
        let mut next_token = 0u64;
        let mut maps_committed = 0u32;
        let mut reduces_done = 0u32;
        let mut maps_running = 0u32;
        let mut reduces_running = 0u32;
        let mut first_map_launched = false;
        let mut first_reduce_launched = false;
        let mut zero_tries = 0u32;
        let mut backoff = GRANT_BACKOFF_START;

        loop {
            // --- launch: grant containers for every eligible pending task.
            let mut launched = 0u32;
            while !pending_maps.is_empty() {
                let got = self.grant(
                    app,
                    pending_maps.len() as u32,
                    self.map_memory_mb,
                    ContainerKind::Map,
                    now,
                )?;
                if got.is_empty() {
                    break;
                }
                counters.add(counters::CONTAINERS_GRANTED, got.len() as u64);
                for c in got {
                    let (idx, attempt) = pending_maps.pop_front().unwrap();
                    if !first_map_launched {
                        first_map_launched = true;
                        phases.first_map_launch_s = t0.elapsed().as_secs_f64();
                    }
                    let token = next_token;
                    next_token += 1;
                    let task = TaskRef::Map { idx, attempt };
                    running.insert(token, InFlight { container: c, task });
                    maps_running += 1;
                    launched += 1;
                    self.pool.submit_with(
                        token,
                        MapTaskArgs {
                            idx,
                            attempt,
                            node: c.node,
                            splits: Arc::clone(splits),
                            spec: Arc::clone(spec),
                            shuffle: Arc::clone(shuffle),
                            counters: Arc::clone(counters),
                            dfs: Arc::clone(&self.dfs),
                        },
                        run_map_task,
                        tx.clone(),
                    );
                }
            }
            if !map_only && maps_committed >= slowstart_target {
                // While maps are still outstanding, cap in-flight reduces
                // below the pool width so slow-start fetch-waits can never
                // starve the remaining maps of worker threads.
                // (With a 1-wide pool that cap is zero: there is no spare
                // worker, so reduces wait for the maps to drain.)
                let maps_outstanding = !pending_maps.is_empty() || maps_running > 0;
                let cap = if maps_outstanding {
                    self.pool.size().saturating_sub(1) as u32
                } else {
                    u32::MAX
                };
                while !pending_reduces.is_empty() && reduces_running < cap {
                    let want = (pending_reduces.len() as u32).min(cap - reduces_running);
                    let got = self.grant(
                        app,
                        want,
                        self.reduce_memory_mb,
                        ContainerKind::Reduce,
                        now,
                    )?;
                    if got.is_empty() {
                        break;
                    }
                    counters.add(counters::CONTAINERS_GRANTED, got.len() as u64);
                    for c in got {
                        let (r, attempt) = pending_reduces.pop_front().unwrap();
                        if !first_reduce_launched {
                            first_reduce_launched = true;
                            phases.first_reduce_launch_s = t0.elapsed().as_secs_f64();
                            counters.add(counters::FIRST_REDUCE_LAUNCHED, 1);
                            counters.add(counters::MAPS_AT_FIRST_REDUCE, maps_committed as u64);
                        }
                        let token = next_token;
                        next_token += 1;
                        let task = TaskRef::Reduce { r, attempt };
                        running.insert(token, InFlight { container: c, task });
                        reduces_running += 1;
                        launched += 1;
                        self.pool.submit_with(
                            token,
                            ReduceTaskArgs {
                                r,
                                attempt,
                                n_maps,
                                spec: Arc::clone(spec),
                                shuffle: Arc::clone(shuffle),
                                counters: Arc::clone(counters),
                                dfs: Arc::clone(&self.dfs),
                                tmp_root: tmp_root.to_string(),
                                cancel: Some(Arc::clone(cancel)),
                            },
                            run_reduce_task,
                            tx.clone(),
                        );
                    }
                }
            }

            if running.is_empty() {
                if pending_maps.is_empty() && pending_reduces.is_empty() {
                    break; // job complete
                }
                // Nothing in flight and the RM granted zero containers:
                // bounded retry with backoff (capacity can free between
                // scheduler cycles) instead of failing the job outright.
                debug_assert_eq!(launched, 0);
                zero_tries += 1;
                counters.add(counters::GRANT_ZERO_RETRIES, 1);
                if zero_tries > MAX_GRANT_RETRIES {
                    return Err(Error::MapReduce(format!(
                        "RM granted zero containers over {MAX_GRANT_RETRIES} \
                         backoff retries — cluster cannot host a single task"
                    )));
                }
                std::thread::sleep(backoff);
                backoff = backoff.saturating_mul(2);
                continue;
            }
            zero_tries = 0;
            backoff = GRANT_BACKOFF_START;

            // --- wait for exactly one completion, then release + re-grant.
            let (token, result) = rx
                .recv()
                .map_err(|_| Error::MapReduce("scheduler channel closed".into()))?;
            let inflight = running
                .remove(&token)
                .ok_or_else(|| Error::MapReduce(format!("unknown task token {token}")))?;
            let ok = matches!(result, Some(Ok(())));
            self.finish_container(app, &inflight.container, ok)?;
            match inflight.task {
                TaskRef::Map { idx, attempt } => {
                    maps_running -= 1;
                    if ok {
                        maps_committed += 1;
                        phases.last_map_commit_s = t0.elapsed().as_secs_f64();
                    } else {
                        counters.add(counters::TASKS_FAILED, 1);
                        let next = attempt + 1;
                        if next >= MAX_ATTEMPTS {
                            // The caller drains in-flight tasks on error.
                            return Err(Error::MapReduce(format!(
                                "map {idx} failed {MAX_ATTEMPTS} attempts"
                            )));
                        }
                        pending_maps.push_back((idx, next));
                    }
                }
                TaskRef::Reduce { r, attempt } => {
                    reduces_running -= 1;
                    if ok {
                        reduces_done += 1;
                        phases.last_reduce_commit_s = t0.elapsed().as_secs_f64();
                    } else {
                        counters.add(counters::TASKS_FAILED, 1);
                        let next = attempt + 1;
                        if next >= MAX_ATTEMPTS {
                            // The caller drains in-flight tasks on error.
                            return Err(Error::MapReduce(format!(
                                "reduce {r} failed {MAX_ATTEMPTS} attempts"
                            )));
                        }
                        pending_reduces.push_back((r, next));
                    }
                }
            }
        }
        debug_assert_eq!(maps_committed, n_maps);
        debug_assert!(map_only || reduces_done == n_reduces);
        Ok(())
    }

    /// Job failure mid-flight: flag running slow-start reduces to bail out
    /// of their fetch wait, then drain every in-flight task so the shared
    /// pool is clean for the next job. Best-effort on the YARN side — a
    /// container whose release fails here is swept up by `fail_app`'s
    /// `finish_app`.
    fn drain_failed(
        &mut self,
        app: &AppId,
        rx: &TaskRx,
        running: &mut BTreeMap<u64, InFlight>,
        cancel: &Arc<AtomicBool>,
    ) {
        cancel.store(true, Ordering::SeqCst);
        while !running.is_empty() {
            match rx.recv() {
                Ok((token, result)) => {
                    if let Some(inflight) = running.remove(&token) {
                        let ok = matches!(result, Some(Ok(())));
                        let _ = self.finish_container(app, &inflight.container, ok);
                    }
                }
                Err(_) => break, // channel closed: nothing left to drain
            }
        }
    }

    // ------------------------------------------------------------------
    // Barriered baseline (pre-PR-2 wave execution)
    // ------------------------------------------------------------------

    #[allow(clippy::too_many_arguments)]
    fn run_barriered(
        &mut self,
        spec: &Arc<JobSpec>,
        app: &AppId,
        splits: &Arc<[InputSplit]>,
        shuffle: &Arc<ShuffleStore>,
        counters: &Arc<Counters>,
        tmp_root: &str,
        now: Micros,
        t0: Instant,
        phases: &mut PhaseTimings,
    ) -> Result<()> {
        let n_maps = splits.len() as u32;
        let n_reduces = spec.n_reduces;
        phases.first_map_launch_s = t0.elapsed().as_secs_f64();
        self.run_maps_barriered(spec, app, splits, shuffle, counters, now)?;
        phases.last_map_commit_s = t0.elapsed().as_secs_f64();
        if n_reduces > 0 {
            shuffle.verify_complete(n_maps, n_reduces)?;
            phases.first_reduce_launch_s = t0.elapsed().as_secs_f64();
            counters.add(counters::FIRST_REDUCE_LAUNCHED, 1);
            counters.add(counters::MAPS_AT_FIRST_REDUCE, n_maps as u64);
            self.run_reduces_barriered(
                spec, app, n_maps, n_reduces, shuffle, counters, tmp_root, now,
            )?;
            phases.last_reduce_commit_s = t0.elapsed().as_secs_f64();
        }
        Ok(())
    }

    /// Grant a wave of containers for `want` tasks of `mem_mb`. Zero-grant
    /// retries with bounded backoff before giving up.
    fn grant_wave(
        &mut self,
        app: &AppId,
        want: u32,
        mem_mb: u64,
        kind: ContainerKind,
        counters: &Arc<Counters>,
        now: Micros,
    ) -> Result<Vec<Container>> {
        let mut backoff = GRANT_BACKOFF_START;
        for attempt in 0..=MAX_GRANT_RETRIES {
            let got = self.grant(app, want, mem_mb, kind, now)?;
            if !got.is_empty() {
                counters.add(counters::CONTAINERS_GRANTED, got.len() as u64);
                return Ok(got);
            }
            counters.add(counters::GRANT_ZERO_RETRIES, 1);
            if attempt < MAX_GRANT_RETRIES {
                std::thread::sleep(backoff);
                backoff = backoff.saturating_mul(2);
            }
        }
        Err(Error::MapReduce(format!(
            "RM granted zero containers over {MAX_GRANT_RETRIES} backoff \
             retries — cluster cannot host a single task"
        )))
    }

    fn finish_wave(&mut self, app: &AppId, wave: &[(Container, bool)]) -> Result<()> {
        for (c, ok) in wave {
            self.finish_container(app, c, *ok)?;
        }
        Ok(())
    }

    fn run_maps_barriered(
        &mut self,
        spec: &Arc<JobSpec>,
        app: &AppId,
        splits: &Arc<[InputSplit]>,
        shuffle: &Arc<ShuffleStore>,
        counters: &Arc<Counters>,
        now: Micros,
    ) -> Result<()> {
        // (task index, attempt) work queue.
        let mut todo: Vec<(u32, u32)> = (0..splits.len() as u32).map(|i| (i, 0)).collect();
        while !todo.is_empty() {
            let wave_n = todo.len() as u32;
            let granted =
                self.grant_wave(app, wave_n, self.map_memory_mb, ContainerKind::Map, counters, now)?;
            let batch: Vec<((u32, u32), Container)> =
                todo.drain(..granted.len().min(todo.len())).zip(granted).collect();

            let results = self.pool.try_map(
                batch
                    .iter()
                    .map(|((idx, attempt), c)| MapTaskArgs {
                        idx: *idx,
                        attempt: *attempt,
                        node: c.node,
                        splits: Arc::clone(splits),
                        spec: Arc::clone(spec),
                        shuffle: Arc::clone(shuffle),
                        counters: Arc::clone(counters),
                        dfs: Arc::clone(&self.dfs),
                    })
                    .collect(),
                run_map_task,
            );

            let mut wave_done = Vec::new();
            for (((idx, attempt), container), result) in batch.into_iter().zip(results) {
                let ok = matches!(result, Some(Ok(())));
                wave_done.push((container, ok));
                if !ok {
                    counters.add(counters::TASKS_FAILED, 1);
                    let next = attempt + 1;
                    if next >= MAX_ATTEMPTS {
                        self.finish_wave(app, &wave_done)?;
                        return Err(Error::MapReduce(format!(
                            "map {idx} failed {MAX_ATTEMPTS} attempts"
                        )));
                    }
                    todo.push((idx, next));
                }
            }
            self.finish_wave(app, &wave_done)?;
        }
        Ok(())
    }

    #[allow(clippy::too_many_arguments)]
    fn run_reduces_barriered(
        &mut self,
        spec: &Arc<JobSpec>,
        app: &AppId,
        n_maps: u32,
        n_reduces: u32,
        shuffle: &Arc<ShuffleStore>,
        counters: &Arc<Counters>,
        tmp_root: &str,
        now: Micros,
    ) -> Result<()> {
        let mut todo: Vec<(u32, u32)> = (0..n_reduces).map(|r| (r, 0)).collect();
        while !todo.is_empty() {
            let wave_n = todo.len() as u32;
            let granted = self.grant_wave(
                app, wave_n, self.reduce_memory_mb, ContainerKind::Reduce, counters, now,
            )?;
            let batch: Vec<((u32, u32), Container)> =
                todo.drain(..granted.len().min(todo.len())).zip(granted).collect();

            let results = self.pool.try_map(
                batch
                    .iter()
                    .map(|((r, attempt), _)| ReduceTaskArgs {
                        r: *r,
                        attempt: *attempt,
                        n_maps,
                        spec: Arc::clone(spec),
                        shuffle: Arc::clone(shuffle),
                        counters: Arc::clone(counters),
                        dfs: Arc::clone(&self.dfs),
                        tmp_root: tmp_root.to_string(),
                        cancel: None,
                    })
                    .collect(),
                run_reduce_task,
            );

            let mut wave_done = Vec::new();
            for (((r, attempt), container), result) in batch.into_iter().zip(results) {
                let ok = matches!(result, Some(Ok(())));
                wave_done.push((container, ok));
                if !ok {
                    counters.add(counters::TASKS_FAILED, 1);
                    let next = attempt + 1;
                    if next >= MAX_ATTEMPTS {
                        self.finish_wave(app, &wave_done)?;
                        return Err(Error::MapReduce(format!(
                            "reduce {r} failed {MAX_ATTEMPTS} attempts"
                        )));
                    }
                    todo.push((r, next));
                }
            }
            self.finish_wave(app, &wave_done)?;
        }
        Ok(())
    }
}

/// What one in-flight container is working on.
enum TaskRef {
    Map { idx: u32, attempt: u32 },
    Reduce { r: u32, attempt: u32 },
}

struct InFlight {
    container: Container,
    task: TaskRef,
}

type TaskTx = Sender<(u64, Option<Result<()>>)>;
type TaskRx = Receiver<(u64, Option<Result<()>>)>;

/// Arguments of one map task attempt.
struct MapTaskArgs {
    idx: u32,
    attempt: u32,
    node: crate::cluster::NodeId,
    splits: Arc<[InputSplit]>,
    spec: Arc<JobSpec>,
    shuffle: Arc<ShuffleStore>,
    counters: Arc<Counters>,
    dfs: Arc<dyn Dfs>,
}

/// One map task attempt (runs on a pool worker).
///
/// Records flow through the flat [`RecordBuf`] arena: emissions are copied
/// straight into per-partition buffers (no per-record heap allocation),
/// counters accumulate in task-local `u64`s and flush once at the end of
/// the task, and spilled segments hand their arenas to the shuffle store
/// without further copying.
fn run_map_task(args: MapTaskArgs) -> Result<()> {
    let MapTaskArgs { idx, attempt, node, splits, spec, shuffle, counters, dfs } = args;
    let split = &splits[idx as usize];
    counters.add(counters::TASKS_LAUNCHED, 1);
    if spec.failures.should_fail(TaskId::map(idx), attempt) {
        return Err(Error::MapReduce(format!(
            "injected failure: map {idx} attempt {attempt}"
        )));
    }

    let map_only = spec.n_reduces == 0;
    let n_buckets = spec.n_reduces.max(1);
    let block_path = spec.block_processor.is_some() && !map_only;
    // One bucket when the whole block is processed at once (map-only
    // serialization order, or the BlockProcessor's input block).
    let n_emit_buckets = if map_only || block_path { 1 } else { n_buckets };
    let mut buckets: Vec<RecordBuf> = (0..n_emit_buckets).map(|_| RecordBuf::new()).collect();
    // Task-local counter accumulation (flushed once below).
    let mut in_records = 0u64;
    let mut out_records = 0u64;
    let mut out_bytes = 0u64;
    {
        let mapper = &spec.mapper;
        let partitioner = &spec.partitioner;
        let mut emit = |k: &[u8], v: &[u8]| {
            let p = if n_emit_buckets == 1 {
                0
            } else {
                partitioner.partition(k, n_buckets).min(n_buckets - 1)
            };
            out_bytes += (k.len() + v.len()) as u64;
            out_records += 1;
            buckets[p as usize].push(k, v);
        };
        match spec.input_format {
            InputFormat::RowRange => {
                for row in split.offset..split.offset + split.len {
                    mapper.map(&row.to_be_bytes(), &[], &mut emit);
                    in_records += 1;
                }
            }
            fmt => {
                in_records += read_records(&*dfs, split, fmt, &mut |k, v| {
                    mapper.map(k, v, &mut emit)
                })?;
            }
        }
    }
    let mut flush = vec![(counters::MAP_INPUT_RECORDS, in_records)];
    if out_records > 0 {
        flush.push((counters::MAP_OUTPUT_BYTES, out_bytes));
        flush.push((counters::MAP_OUTPUT_RECORDS, out_records));
    }
    counters.add_many(&flush);

    if map_only {
        // Map-only jobs (Teragen) write their emissions straight to the
        // output directory in emission order via the commit protocol.
        let records = buckets.into_iter().next().unwrap();
        let mut out = Vec::with_capacity(records.payload_bytes() as usize);
        for (k, v) in records.iter() {
            spec.output_format.write_record(&mut out, k, v);
        }
        let attempt_dir = format!("{}/_temporary/attempt_m_{idx:05}_{attempt}", spec.output_dir);
        dfs.mkdirs(&attempt_dir)?;
        let attempt_file = format!("{attempt_dir}/part-m-{idx:05}");
        dfs.create(&attempt_file, &out)?;
        dfs.rename(
            &attempt_file,
            &format!("{}/part-m-{idx:05}", spec.output_dir),
        )?;
        return Ok(());
    }

    if block_path {
        // Whole-block map path: the BlockProcessor sorts + routes the
        // entire emitted block at once (Terasort kernel acceleration).
        let bp = spec.block_processor.as_ref().unwrap();
        let block = buckets.into_iter().next().unwrap();
        let parts = bp.process(block, n_buckets)?;
        if parts.len() != n_buckets as usize {
            return Err(Error::MapReduce(format!(
                "block processor '{}' returned {} partitions, expected {n_buckets}",
                bp.name(),
                parts.len()
            )));
        }
        for (p, records) in parts.into_iter().enumerate() {
            shuffle.put(Segment {
                map: idx,
                partition: p as u32,
                node,
                records,
            });
        }
        counters.add_many(&[
            (counters::MAP_SPILLS, n_buckets as u64),
            (counters::SHUFFLE_SEGMENTS, n_buckets as u64),
        ]);
        return Ok(());
    }

    // Map-side sort + spill (one segment per partition). The sort permutes
    // index entries decorated with u64 key prefixes — payload bytes never
    // move. All partitions are sorted BEFORE the first commit: slow-start
    // reduces see map output per cell (`try_fetch`), so the commit must be
    // all-or-nothing per attempt — a sort panic on a later bucket must not
    // leave this attempt's earlier segments visible.
    let mut segments = Vec::with_capacity(n_buckets as usize);
    for (p, mut records) in buckets.into_iter().enumerate() {
        records.sort_by_key();
        segments.push(Segment {
            map: idx,
            partition: p as u32,
            node,
            records,
        });
    }
    for seg in segments {
        shuffle.put(seg);
    }
    counters.add_many(&[
        (counters::MAP_SPILLS, n_buckets as u64),
        (counters::SHUFFLE_SEGMENTS, n_buckets as u64),
    ]);
    Ok(())
}

/// Arguments of one reduce task attempt. `cancel: Some(_)` puts the fetch
/// phase in slow-start mode: poll [`ShuffleStore::try_fetch`] per map cell
/// until the partition's column is complete (bailing out if the scheduler
/// cancels the job); `None` is the barriered baseline's all-at-once fetch.
struct ReduceTaskArgs {
    r: u32,
    attempt: u32,
    n_maps: u32,
    spec: Arc<JobSpec>,
    shuffle: Arc<ShuffleStore>,
    counters: Arc<Counters>,
    dfs: Arc<dyn Dfs>,
    tmp_root: String,
    cancel: Option<Arc<AtomicBool>>,
}

/// One reduce task attempt.
///
/// The shuffle hands back `Arc`-shared segments (no copies); the k-way
/// merge yields `(segment, record)` indices; grouping and reduction read
/// keys and values as borrowed slices straight out of the segment arenas.
fn run_reduce_task(args: ReduceTaskArgs) -> Result<()> {
    let ReduceTaskArgs { r, attempt, n_maps, spec, shuffle, counters, dfs, tmp_root, cancel } =
        args;
    counters.add(counters::TASKS_LAUNCHED, 1);
    if spec.failures.should_fail(TaskId::reduce(r), attempt) {
        return Err(Error::MapReduce(format!(
            "injected failure: reduce {r} attempt {attempt}"
        )));
    }

    let segments: Vec<Arc<Segment>> = match cancel {
        // Slow-start: fetch each map's segment the moment it commits,
        // concurrently with the remaining maps.
        Some(cancel) => {
            let mut slots: Vec<Option<Arc<Segment>>> = (0..n_maps).map(|_| None).collect();
            let mut missing = n_maps as usize;
            let mut prefetched = 0u64;
            while missing > 0 {
                if cancel.load(Ordering::Relaxed) {
                    return Err(Error::MapReduce(format!(
                        "reduce {r} cancelled: job failed while waiting for map output"
                    )));
                }
                for (m, slot) in slots.iter_mut().enumerate() {
                    if slot.is_none() {
                        if let Some(s) = shuffle.try_fetch(m as u32, r) {
                            *slot = Some(s);
                            missing -= 1;
                        }
                    }
                }
                if missing > 0 {
                    // Still waiting on uncommitted maps: everything fetched
                    // so far arrived ahead of the last map commit.
                    prefetched = (n_maps as usize - missing) as u64;
                    std::thread::sleep(FETCH_POLL);
                }
            }
            if prefetched > 0 {
                counters.add(counters::SHUFFLE_SEGMENTS_PREFETCHED, prefetched);
            }
            slots.into_iter().map(|s| s.unwrap()).collect()
        }
        None => shuffle.fetch_partition(r, n_maps)?,
    };
    let shuffle_bytes = segments.iter().map(|s| s.bytes()).sum::<u64>();
    let order = merge_segments(&segments);
    counters.add_many(&[
        (counters::SHUFFLE_BYTES, shuffle_bytes),
        (counters::REDUCE_INPUT_RECORDS, order.len() as u64),
    ]);

    // Group by key, reduce, serialize. Keys and values are borrowed from
    // the shared segments for the whole pass.
    let mut out = Vec::new();
    let mut out_records = 0u64;
    {
        let mut emit = |k: &[u8], v: &[u8]| {
            out_records += 1;
            spec.output_format.write_record(&mut out, k, v);
        };
        let mut i = 0usize;
        while i < order.len() {
            let (s0, r0) = order[i];
            let key = segments[s0 as usize].records.key(r0 as usize);
            let mut j = i + 1;
            while j < order.len() {
                let (s1, r1) = order[j];
                if segments[s1 as usize].records.key(r1 as usize) != key {
                    break;
                }
                j += 1;
            }
            let mut values = order[i..j]
                .iter()
                .map(|&(s, rec)| segments[s as usize].records.value(rec as usize));
            spec.reducer.reduce(key, &mut values, &mut emit);
            i = j;
        }
    }
    counters.add_many(&[
        (counters::REDUCE_OUTPUT_RECORDS, out_records),
        (counters::REDUCE_OUTPUT_BYTES, out.len() as u64),
    ]);

    // Commit protocol: write the attempt file, then rename into place.
    let attempt_dir = format!("{tmp_root}/attempt_r_{r:05}_{attempt}");
    dfs.mkdirs(&attempt_dir)?;
    let attempt_file = format!("{attempt_dir}/part-r-{r:05}");
    dfs.create(&attempt_file, &out)?;
    let final_file = format!("{}/part-r-{r:05}", spec.output_dir);
    dfs.rename(&attempt_file, &final_file)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::NodeId;
    use crate::config::StackConfig;
    use crate::lustre::LustreFs;
    use crate::mapreduce::{FailurePlan, HashPartitioner, Mapper, OutputFormat, Reducer};
    use crate::mapreduce::task::TaskId;
    use crate::metrics::Metrics;
    use crate::util::ids::IdGen;

    struct WordSplit;
    impl Mapper for WordSplit {
        fn map(&self, _k: &[u8], v: &[u8], emit: &mut dyn FnMut(&[u8], &[u8])) {
            for w in v.split(|&b| b == b' ').filter(|w| !w.is_empty()) {
                emit(w, b"1");
            }
        }
    }

    struct CountReducer;
    impl Reducer for CountReducer {
        fn reduce(
            &self,
            key: &[u8],
            values: &mut dyn Iterator<Item = &[u8]>,
            emit: &mut dyn FnMut(&[u8], &[u8]),
        ) {
            let n = values.count();
            emit(key, n.to_string().as_bytes());
        }
    }

    fn stack() -> (StackConfig, Arc<LustreFs>, DynamicCluster, Pool) {
        let cfg = StackConfig::tiny();
        let fs = Arc::new(LustreFs::new(&cfg.lustre, &cfg.cluster));
        let nodes: Vec<NodeId> = (0..6).map(NodeId).collect();
        let dc = DynamicCluster::build(
            &cfg,
            &nodes,
            &*fs,
            Arc::new(IdGen::default()),
            Arc::new(Metrics::new()),
            "mr-test",
            Micros::ZERO,
        )
        .unwrap();
        (cfg, fs, dc, Pool::new(4))
    }

    fn wordcount_spec(input: &str, output: &str) -> JobSpec {
        let mut spec = JobSpec::identity("wordcount", input, output, 3);
        spec.input_format = InputFormat::Lines;
        spec.output_format = OutputFormat::TextKv;
        spec.split_bytes = 32;
        spec.mapper = Arc::new(WordSplit);
        spec.reducer = Arc::new(CountReducer);
        spec.partitioner = Arc::new(HashPartitioner);
        spec
    }

    #[test]
    fn wordcount_end_to_end() {
        let (cfg, fs, mut dc, pool) = stack();
        fs.mkdirs("/lustre/scratch/wc-in").unwrap();
        fs.create(
            "/lustre/scratch/wc-in/f1",
            b"the quick brown fox\nthe lazy dog\nthe end",
        )
        .unwrap();
        let spec = Arc::new(wordcount_spec("/lustre/scratch/wc-in", "/lustre/scratch/wc-out"));
        let mut engine = MrEngine::new(
            &mut dc,
            fs.clone(),
            &pool,
            cfg.yarn.map_memory_mb,
            cfg.yarn.reduce_memory_mb,
        );
        let outcome = engine.run(Arc::clone(&spec), "alice", Micros::ZERO).unwrap();
        assert!(outcome.maps >= 2, "small splits → multiple maps");
        assert_eq!(outcome.reduces, 3);

        // Collect all output lines and check counts.
        let mut text = String::new();
        for f in &outcome.output_files {
            text.push_str(&String::from_utf8(fs.read(f).unwrap()).unwrap());
        }
        let mut the_count = None;
        for line in text.lines() {
            let (w, n) = line.split_once('\t').unwrap();
            if w == "the" {
                the_count = Some(n.to_string());
            }
        }
        assert_eq!(the_count.as_deref(), Some("3"));
        assert!(fs.exists("/lustre/scratch/wc-out/_SUCCESS"));
        assert!(!fs.exists("/lustre/scratch/wc-out/_temporary"));
        // History recorded.
        assert_eq!(dc.jhs.count(), 1);
        dc.rm.check_invariants().unwrap();
    }

    #[test]
    fn existing_output_dir_rejected() {
        let (cfg, fs, mut dc, pool) = stack();
        fs.mkdirs("/lustre/scratch/exists").unwrap();
        fs.mkdirs("/lustre/scratch/in2").unwrap();
        fs.create("/lustre/scratch/in2/f", b"x").unwrap();
        let spec = Arc::new(wordcount_spec("/lustre/scratch/in2", "/lustre/scratch/exists"));
        let mut engine =
            MrEngine::new(&mut dc, fs, &pool, cfg.yarn.map_memory_mb, cfg.yarn.reduce_memory_mb);
        assert!(engine.run(spec, "u", Micros::ZERO).is_err());
    }

    #[test]
    fn injected_map_failure_retries_and_succeeds() {
        let (cfg, fs, mut dc, pool) = stack();
        fs.mkdirs("/lustre/scratch/in3").unwrap();
        fs.create("/lustre/scratch/in3/f", b"a b c d e f").unwrap();
        let mut spec = wordcount_spec("/lustre/scratch/in3", "/lustre/scratch/out3");
        spec.split_bytes = 1024;
        spec.failures = FailurePlan::none().fail_attempt(TaskId::map(0), 0);
        let spec = Arc::new(spec);
        let mut engine = MrEngine::new(
            &mut dc,
            fs.clone(),
            &pool,
            cfg.yarn.map_memory_mb,
            cfg.yarn.reduce_memory_mb,
        );
        let outcome = engine.run(spec, "u", Micros::ZERO).unwrap();
        assert_eq!(outcome.counters.get(counters::TASKS_FAILED), 1);
        assert!(fs.exists("/lustre/scratch/out3/_SUCCESS"));
        dc.rm.check_invariants().unwrap();
        // NM logs include the failed container's log.
        let pool_panics = pool.panic_count();
        assert_eq!(pool_panics, 0, "failures are Results, not panics");
    }

    #[test]
    fn repeated_failures_fail_the_job() {
        let (cfg, fs, mut dc, pool) = stack();
        fs.mkdirs("/lustre/scratch/in4").unwrap();
        fs.create("/lustre/scratch/in4/f", b"words here").unwrap();
        let mut spec = wordcount_spec("/lustre/scratch/in4", "/lustre/scratch/out4");
        spec.split_bytes = 1024;
        let mut failures = FailurePlan::none();
        for a in 0..MAX_ATTEMPTS {
            failures = failures.fail_attempt(TaskId::map(0), a);
        }
        spec.failures = failures;
        let spec = Arc::new(spec);
        let mut engine =
            MrEngine::new(&mut dc, fs, &pool, cfg.yarn.map_memory_mb, cfg.yarn.reduce_memory_mb);
        let err = engine.run(spec, "u", Micros::ZERO).unwrap_err();
        assert!(err.to_string().contains("failed 4 attempts"), "{err}");
        // App recorded as failed; resources all released.
        dc.rm.check_invariants().unwrap();
        let (_, used) = dc.rm.cluster_resources();
        assert_eq!(used.mem_mb, 0);
    }

    #[test]
    fn reduce_failure_retries() {
        let (cfg, fs, mut dc, pool) = stack();
        fs.mkdirs("/lustre/scratch/in5").unwrap();
        fs.create("/lustre/scratch/in5/f", b"k1 k2 k1").unwrap();
        let mut spec = wordcount_spec("/lustre/scratch/in5", "/lustre/scratch/out5");
        spec.split_bytes = 1024;
        spec.failures = FailurePlan::none().fail_attempt(TaskId::reduce(1), 0);
        let spec = Arc::new(spec);
        let mut engine = MrEngine::new(
            &mut dc,
            fs.clone(),
            &pool,
            cfg.yarn.map_memory_mb,
            cfg.yarn.reduce_memory_mb,
        );
        let outcome = engine.run(spec, "u", Micros::ZERO).unwrap();
        assert_eq!(outcome.counters.get(counters::TASKS_FAILED), 1);
        assert!(fs.exists("/lustre/scratch/out5/_SUCCESS"));
    }

    #[test]
    fn barriered_mode_still_works() {
        let (cfg, fs, mut dc, pool) = stack();
        fs.mkdirs("/lustre/scratch/in-b").unwrap();
        fs.create("/lustre/scratch/in-b/f", b"x y x z y x").unwrap();
        let spec = Arc::new(wordcount_spec("/lustre/scratch/in-b", "/lustre/scratch/out-b"));
        let mut engine = MrEngine::new(
            &mut dc,
            fs.clone(),
            &pool,
            cfg.yarn.map_memory_mb,
            cfg.yarn.reduce_memory_mb,
        )
        .with_mode(SchedMode::Barriered);
        let outcome = engine.run(spec, "u", Micros::ZERO).unwrap();
        assert!(fs.exists("/lustre/scratch/out-b/_SUCCESS"));
        // Barriered reduces see the full map count at launch — no overlap.
        assert_eq!(
            outcome.counters.get(counters::MAPS_AT_FIRST_REDUCE),
            outcome.maps as u64
        );
        assert_eq!(outcome.phases.overlap_s(), 0.0);
        dc.rm.check_invariants().unwrap();
    }

    /// The slow-start acceptance: with more maps than the pool is wide,
    /// the first reduce launches before the last map commits, observable
    /// through the counters and the phase marks.
    #[test]
    fn slowstart_launches_reduces_before_last_map_commit() {
        let (cfg, fs, mut dc, pool) = stack();
        fs.mkdirs("/lustre/scratch/ss-in").unwrap();
        // 20 splits of one line each (split_bytes = 32 over ~640 bytes).
        let mut text = Vec::new();
        for i in 0..20 {
            text.extend_from_slice(format!("alpha bravo w{i:02} charlie del\n").as_bytes());
        }
        fs.create("/lustre/scratch/ss-in/f", &text).unwrap();
        let spec = Arc::new(wordcount_spec("/lustre/scratch/ss-in", "/lustre/scratch/ss-out"));
        let mut engine = MrEngine::new(
            &mut dc,
            fs.clone(),
            &pool,
            cfg.yarn.map_memory_mb,
            cfg.yarn.reduce_memory_mb,
        )
        .with_slowstart(0.8);
        let outcome = engine.run(spec, "u", Micros::ZERO).unwrap();
        let n_maps = outcome.maps as u64;
        assert!(n_maps > pool.size() as u64, "need maps > pool width");
        assert_eq!(outcome.counters.get(counters::FIRST_REDUCE_LAUNCHED), 1);
        let at_first = outcome.counters.get(counters::MAPS_AT_FIRST_REDUCE);
        assert!(
            at_first >= (0.8 * n_maps as f64).ceil() as u64 && at_first < n_maps,
            "first reduce launched at {at_first} of {n_maps} maps"
        );
        assert!(
            outcome.phases.first_reduce_launch_s < outcome.phases.last_map_commit_s,
            "reduce launch must precede last map commit: {:?}",
            outcome.phases
        );
        // Every grant is accounted; one container per task attempt, and
        // every one of them ran to completion on some NM.
        assert_eq!(
            outcome.counters.get(counters::CONTAINERS_GRANTED),
            outcome.counters.get(counters::TASKS_LAUNCHED)
        );
        let completed: usize = dc.nms.values().map(|nm| nm.completed_containers()).sum();
        assert_eq!(
            completed as u64,
            outcome.counters.get(counters::TASKS_LAUNCHED),
            "per-completion container recycling completes one NM container per attempt"
        );
        // Release/re-grant churn: total grants exceed the concurrent
        // high-water mark once containers are recycled.
        let (granted_total, peak) = dc.rm.app_grant_stats(outcome.app).unwrap();
        assert_eq!(granted_total, outcome.counters.get(counters::TASKS_LAUNCHED) + 1);
        assert!(peak as u64 <= granted_total);
        dc.rm.check_invariants().unwrap();
    }

    /// Zero-grant is a bounded-backoff retry, not an instant hard error —
    /// and after the retries it is still a clean failure with all
    /// resources released.
    #[test]
    fn zero_grant_backs_off_then_fails_cleanly() {
        let (cfg, fs, mut dc, pool) = stack();
        fs.mkdirs("/lustre/scratch/zg-in").unwrap();
        fs.create("/lustre/scratch/zg-in/f", b"a b").unwrap();
        let spec = Arc::new(wordcount_spec("/lustre/scratch/zg-in", "/lustre/scratch/zg-out"));
        // Map containers larger than any NM can host → RM grants zero.
        let mut engine = MrEngine::new(
            &mut dc,
            fs.clone(),
            &pool,
            cfg.yarn.nm_resource_mb * 4,
            cfg.yarn.reduce_memory_mb,
        );
        let err = engine.run(spec, "u", Micros::ZERO).unwrap_err();
        assert!(err.to_string().contains("backoff retries"), "{err}");
        dc.rm.check_invariants().unwrap();
        let (_, used) = dc.rm.cluster_resources();
        assert_eq!(used.mem_mb, 0, "failed job must release everything");
    }

    /// A failing job with slow-start reduces in flight must cancel them
    /// (not leave pool workers polling forever) and release containers.
    #[test]
    fn map_exhaustion_cancels_inflight_reduces() {
        let (cfg, fs, mut dc, pool) = stack();
        fs.mkdirs("/lustre/scratch/cx-in").unwrap();
        let mut text = Vec::new();
        for i in 0..10 {
            text.extend_from_slice(format!("word{i} again maybe here yes\n").as_bytes());
        }
        fs.create("/lustre/scratch/cx-in/f", &text).unwrap();
        let mut spec = wordcount_spec("/lustre/scratch/cx-in", "/lustre/scratch/cx-out");
        // Map 5 fails every attempt; with slow-start 0.1 reduces launch
        // early and then must be cancelled when the job dies.
        let mut failures = FailurePlan::none();
        for a in 0..MAX_ATTEMPTS {
            failures = failures.fail_attempt(TaskId::map(5), a);
        }
        spec.failures = failures;
        let spec = Arc::new(spec);
        let mut engine = MrEngine::new(
            &mut dc,
            fs.clone(),
            &pool,
            cfg.yarn.map_memory_mb,
            cfg.yarn.reduce_memory_mb,
        )
        .with_slowstart(0.1);
        let err = engine.run(spec, "u", Micros::ZERO).unwrap_err();
        assert!(err.to_string().contains("failed 4 attempts"), "{err}");
        dc.rm.check_invariants().unwrap();
        let (_, used) = dc.rm.cluster_resources();
        assert_eq!(used.mem_mb, 0);
        // The pool is healthy for the next job: run one to completion.
        let spec2 = Arc::new(wordcount_spec("/lustre/scratch/cx-in", "/lustre/scratch/cx-out2"));
        let mut engine2 = MrEngine::new(
            &mut dc,
            fs.clone(),
            &pool,
            cfg.yarn.map_memory_mb,
            cfg.yarn.reduce_memory_mb,
        );
        engine2.run(spec2, "u", Micros::ZERO).unwrap();
        assert!(fs.exists("/lustre/scratch/cx-out2/_SUCCESS"));
    }
}
