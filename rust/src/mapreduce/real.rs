//! The Real-mode MapReduce executor: actual bytes through the live YARN
//! cluster built by the wrapper.
//!
//! Execution follows Hadoop 2.5's wave structure: the MR ApplicationMaster
//! heartbeats the RM for map containers, runs the granted wave on the
//! node's thread pool, commits sorted spill segments into the shuffle
//! store, then repeats for reduces, which merge their segments and commit
//! output files via the rename protocol (`_temporary/attempt` → `part-r`).
//! Failed attempts (fault injection, panics) retry up to
//! [`task::MAX_ATTEMPTS`]; a node failure mid-job invalidates its shuffle
//! segments and re-runs exactly the affected maps.

use crate::error::{Error, Result};
use crate::lustre::Dfs;
use crate::mapreduce::counters::{self, Counters};
use crate::mapreduce::recordbuf::RecordBuf;
use crate::mapreduce::shuffle::{merge_segments, Segment, ShuffleStore};
use crate::mapreduce::split::{plan_splits, read_records, row_range_splits, InputFormat, InputSplit};
use crate::mapreduce::task::{TaskId, MAX_ATTEMPTS};
use crate::mapreduce::JobSpec;
use crate::util::ids::AppId;
use crate::util::pool::Pool;
use crate::util::time::Micros;
use crate::wrapper::DynamicCluster;
use crate::yarn::container::{Container, ContainerKind, ContainerRequest, Resource};
use crate::yarn::jobhistory::AppReport;
use crate::yarn::rm::AppState;
use std::sync::Arc;

/// Result of a completed job.
#[derive(Debug)]
pub struct MrOutcome {
    pub app: AppId,
    pub maps: u32,
    pub reduces: u32,
    pub counters: Arc<Counters>,
    pub output_files: Vec<String>,
    pub wall: std::time::Duration,
}

/// The Real-mode engine. Holds the live cluster and the worker pool.
pub struct MrEngine<'a> {
    pub cluster: &'a mut DynamicCluster,
    pub dfs: Arc<dyn Dfs>,
    pub pool: &'a Pool,
    pub map_memory_mb: u64,
    pub reduce_memory_mb: u64,
}

impl<'a> MrEngine<'a> {
    pub fn new(
        cluster: &'a mut DynamicCluster,
        dfs: Arc<dyn Dfs>,
        pool: &'a Pool,
        map_memory_mb: u64,
        reduce_memory_mb: u64,
    ) -> Self {
        MrEngine {
            cluster,
            dfs,
            pool,
            map_memory_mb,
            reduce_memory_mb,
        }
    }

    /// Run a job to completion. `now` is the logical submission time used
    /// for YARN bookkeeping; wall time is measured for the outcome.
    pub fn run(&mut self, spec: Arc<JobSpec>, user: &str, now: Micros) -> Result<MrOutcome> {
        let t0 = std::time::Instant::now();
        if self.dfs.exists(&spec.output_dir) {
            return Err(Error::MapReduce(format!(
                "output dir '{}' already exists",
                spec.output_dir
            )));
        }
        let splits: Vec<InputSplit> = match spec.input_format {
            InputFormat::RowRange => {
                let (rows, maps) = spec.synthetic_rows.ok_or_else(|| {
                    Error::MapReduce("RowRange job without synthetic_rows".into())
                })?;
                row_range_splits(rows, maps)
            }
            fmt => plan_splits(&*self.dfs, &spec.input_dir, fmt, spec.split_bytes)?,
        };
        let n_maps = splits.len() as u32;
        let n_reduces = spec.n_reduces; // 0 = map-only job (Teragen)

        // Output scaffolding.
        self.dfs.mkdirs(&spec.output_dir)?;
        let tmp_root = format!("{}/_temporary", spec.output_dir);
        self.dfs.mkdirs(&tmp_root)?;

        let handle = self.cluster.rm.submit_app(&spec.name, user, now)?;
        let counters = Arc::new(Counters::new());
        let shuffle = Arc::new(ShuffleStore::new());

        let map_only = spec.n_reduces == 0;
        let map_result = self.run_maps(&spec, &handle.app, &splits, &shuffle, &counters, now);
        if let Err(e) = map_result {
            self.fail_app(&spec, handle.app, user, &counters, now)?;
            return Err(e);
        }

        if !map_only {
            shuffle.verify_complete(n_maps, n_reduces)?;
            let reduce_result = self.run_reduces(
                &spec, &handle.app, n_maps, n_reduces, &shuffle, &counters, &tmp_root, now,
            );
            if let Err(e) = reduce_result {
                self.fail_app(&spec, handle.app, user, &counters, now)?;
                return Err(e);
            }
        }

        // Commit: _SUCCESS marker, drop _temporary.
        self.dfs.delete_recursive(&tmp_root)?;
        self.dfs.create(&format!("{}/_SUCCESS", spec.output_dir), b"")?;

        self.cluster
            .rm
            .finish_app(handle.app, AppState::Finished, now)?;
        self.cluster.jhs.record(
            AppReport {
                app: handle.app,
                name: spec.name.clone(),
                user: user.to_string(),
                state: AppState::Finished,
                submitted_at: now,
                finished_at: now + Micros::from_secs_f64(t0.elapsed().as_secs_f64()),
                counters: counters.snapshot(),
            },
            &*self.dfs,
        )?;

        let output_files = self
            .dfs
            .list(&spec.output_dir)
            .into_iter()
            .filter(|p| p.contains("/part-"))
            .collect();
        Ok(MrOutcome {
            app: handle.app,
            maps: n_maps,
            reduces: n_reduces,
            counters,
            output_files,
            wall: t0.elapsed(),
        })
    }

    fn fail_app(
        &mut self,
        spec: &JobSpec,
        app: AppId,
        user: &str,
        counters: &Arc<Counters>,
        now: Micros,
    ) -> Result<()> {
        self.cluster.rm.finish_app(app, AppState::Failed, now)?;
        self.cluster.jhs.record(
            AppReport {
                app,
                name: spec.name.clone(),
                user: user.to_string(),
                state: AppState::Failed,
                submitted_at: now,
                finished_at: now,
                counters: counters.snapshot(),
            },
            &*self.dfs,
        )?;
        Ok(())
    }

    /// Grant a wave of containers for `want` tasks of `mem_mb`.
    fn grant_wave(
        &mut self,
        app: &AppId,
        want: u32,
        mem_mb: u64,
        kind: ContainerKind,
        now: Micros,
    ) -> Result<Vec<Container>> {
        let got = self.cluster.rm.allocate(
            *app,
            ContainerRequest {
                resource: Resource::new(mem_mb, 1),
                count: want,
            },
            kind,
            now,
        )?;
        if got.is_empty() {
            return Err(Error::MapReduce(
                "RM granted zero containers — cluster too small for one task".into(),
            ));
        }
        for c in &got {
            if let Some(nm) = self.cluster.nms.get_mut(&c.node) {
                nm.launch(c.id)?;
            }
        }
        Ok(got)
    }

    fn finish_wave(&mut self, app: &AppId, wave: &[(Container, bool)]) -> Result<()> {
        for (c, ok) in wave {
            if let Some(nm) = self.cluster.nms.get_mut(&c.node) {
                nm.complete(c.id, *ok)?;
            }
            self.cluster.rm.release(*app, c.id)?;
        }
        Ok(())
    }

    fn run_maps(
        &mut self,
        spec: &Arc<JobSpec>,
        app: &AppId,
        splits: &[InputSplit],
        shuffle: &Arc<ShuffleStore>,
        counters: &Arc<Counters>,
        now: Micros,
    ) -> Result<()> {
        // (task index, attempt) work queue.
        let mut todo: Vec<(u32, u32)> = (0..splits.len() as u32).map(|i| (i, 0)).collect();
        while !todo.is_empty() {
            let wave_n = todo.len() as u32;
            let granted =
                self.grant_wave(app, wave_n, self.map_memory_mb, ContainerKind::Map, now)?;
            let batch: Vec<((u32, u32), Container)> =
                todo.drain(..granted.len().min(todo.len())).zip(granted).collect();

            let results = self.pool.try_map(
                batch
                    .iter()
                    .map(|((idx, attempt), c)| {
                        (
                            *idx,
                            *attempt,
                            c.node,
                            splits[*idx as usize].clone(),
                            Arc::clone(spec),
                            Arc::clone(shuffle),
                            Arc::clone(counters),
                            Arc::clone(&self.dfs),
                        )
                    })
                    .collect(),
                run_map_task,
            );

            let mut wave_done = Vec::new();
            for (((idx, attempt), container), result) in batch.into_iter().zip(results) {
                let ok = matches!(result, Some(Ok(())));
                wave_done.push((container, ok));
                if !ok {
                    counters.add(counters::TASKS_FAILED, 1);
                    let next = attempt + 1;
                    if next >= MAX_ATTEMPTS {
                        self.finish_wave(app, &wave_done)?;
                        return Err(Error::MapReduce(format!(
                            "map {idx} failed {MAX_ATTEMPTS} attempts"
                        )));
                    }
                    todo.push((idx, next));
                }
            }
            self.finish_wave(app, &wave_done)?;
        }
        Ok(())
    }

    #[allow(clippy::too_many_arguments)]
    fn run_reduces(
        &mut self,
        spec: &Arc<JobSpec>,
        app: &AppId,
        n_maps: u32,
        n_reduces: u32,
        shuffle: &Arc<ShuffleStore>,
        counters: &Arc<Counters>,
        tmp_root: &str,
        now: Micros,
    ) -> Result<()> {
        let mut todo: Vec<(u32, u32)> = (0..n_reduces).map(|r| (r, 0)).collect();
        while !todo.is_empty() {
            let wave_n = todo.len() as u32;
            let granted =
                self.grant_wave(app, wave_n, self.reduce_memory_mb, ContainerKind::Reduce, now)?;
            let batch: Vec<((u32, u32), Container)> =
                todo.drain(..granted.len().min(todo.len())).zip(granted).collect();

            let results = self.pool.try_map(
                batch
                    .iter()
                    .map(|((r, attempt), _)| {
                        (
                            *r,
                            *attempt,
                            n_maps,
                            Arc::clone(spec),
                            Arc::clone(shuffle),
                            Arc::clone(counters),
                            Arc::clone(&self.dfs),
                            tmp_root.to_string(),
                        )
                    })
                    .collect(),
                run_reduce_task,
            );

            let mut wave_done = Vec::new();
            for (((r, attempt), container), result) in batch.into_iter().zip(results) {
                let ok = matches!(result, Some(Ok(())));
                wave_done.push((container, ok));
                if !ok {
                    counters.add(counters::TASKS_FAILED, 1);
                    let next = attempt + 1;
                    if next >= MAX_ATTEMPTS {
                        self.finish_wave(app, &wave_done)?;
                        return Err(Error::MapReduce(format!(
                            "reduce {r} failed {MAX_ATTEMPTS} attempts"
                        )));
                    }
                    todo.push((r, next));
                }
            }
            self.finish_wave(app, &wave_done)?;
        }
        Ok(())
    }
}

type MapTaskArgs = (
    u32,
    u32,
    crate::cluster::NodeId,
    InputSplit,
    Arc<JobSpec>,
    Arc<ShuffleStore>,
    Arc<Counters>,
    Arc<dyn Dfs>,
);

/// One map task attempt (runs on a pool worker).
///
/// Records flow through the flat [`RecordBuf`] arena: emissions are copied
/// straight into per-partition buffers (no per-record heap allocation),
/// counters accumulate in task-local `u64`s and flush once at the end of
/// the task, and spilled segments hand their arenas to the shuffle store
/// without further copying.
fn run_map_task(args: MapTaskArgs) -> Result<()> {
    let (idx, attempt, node, split, spec, shuffle, counters, dfs) = args;
    counters.add(counters::TASKS_LAUNCHED, 1);
    if spec.failures.should_fail(TaskId::map(idx), attempt) {
        return Err(Error::MapReduce(format!(
            "injected failure: map {idx} attempt {attempt}"
        )));
    }

    let map_only = spec.n_reduces == 0;
    let n_buckets = spec.n_reduces.max(1);
    let block_path = spec.block_processor.is_some() && !map_only;
    // One bucket when the whole block is processed at once (map-only
    // serialization order, or the BlockProcessor's input block).
    let n_emit_buckets = if map_only || block_path { 1 } else { n_buckets };
    let mut buckets: Vec<RecordBuf> = (0..n_emit_buckets).map(|_| RecordBuf::new()).collect();
    // Task-local counter accumulation (flushed once below).
    let mut in_records = 0u64;
    let mut out_records = 0u64;
    let mut out_bytes = 0u64;
    {
        let mapper = &spec.mapper;
        let partitioner = &spec.partitioner;
        let mut emit = |k: &[u8], v: &[u8]| {
            let p = if n_emit_buckets == 1 {
                0
            } else {
                partitioner.partition(k, n_buckets).min(n_buckets - 1)
            };
            out_bytes += (k.len() + v.len()) as u64;
            out_records += 1;
            buckets[p as usize].push(k, v);
        };
        match spec.input_format {
            InputFormat::RowRange => {
                for row in split.offset..split.offset + split.len {
                    mapper.map(&row.to_be_bytes(), &[], &mut emit);
                    in_records += 1;
                }
            }
            fmt => {
                in_records += read_records(&*dfs, &split, fmt, &mut |k, v| {
                    mapper.map(k, v, &mut emit)
                })?;
            }
        }
    }
    let mut flush = vec![(counters::MAP_INPUT_RECORDS, in_records)];
    if out_records > 0 {
        flush.push((counters::MAP_OUTPUT_BYTES, out_bytes));
        flush.push((counters::MAP_OUTPUT_RECORDS, out_records));
    }
    counters.add_many(&flush);

    if map_only {
        // Map-only jobs (Teragen) write their emissions straight to the
        // output directory in emission order via the commit protocol.
        let records = buckets.into_iter().next().unwrap();
        let mut out = Vec::with_capacity(records.payload_bytes() as usize);
        for (k, v) in records.iter() {
            spec.output_format.write_record(&mut out, k, v);
        }
        let attempt_dir = format!("{}/_temporary/attempt_m_{idx:05}_{attempt}", spec.output_dir);
        dfs.mkdirs(&attempt_dir)?;
        let attempt_file = format!("{attempt_dir}/part-m-{idx:05}");
        dfs.create(&attempt_file, &out)?;
        dfs.rename(
            &attempt_file,
            &format!("{}/part-m-{idx:05}", spec.output_dir),
        )?;
        return Ok(());
    }

    if block_path {
        // Whole-block map path: the BlockProcessor sorts + routes the
        // entire emitted block at once (Terasort kernel acceleration).
        let bp = spec.block_processor.as_ref().unwrap();
        let block = buckets.into_iter().next().unwrap();
        let parts = bp.process(block, n_buckets)?;
        if parts.len() != n_buckets as usize {
            return Err(Error::MapReduce(format!(
                "block processor '{}' returned {} partitions, expected {n_buckets}",
                bp.name(),
                parts.len()
            )));
        }
        for (p, records) in parts.into_iter().enumerate() {
            shuffle.put(Segment {
                map: idx,
                partition: p as u32,
                node,
                records,
            });
        }
        counters.add_many(&[
            (counters::MAP_SPILLS, n_buckets as u64),
            (counters::SHUFFLE_SEGMENTS, n_buckets as u64),
        ]);
        return Ok(());
    }

    // Map-side sort + spill (one segment per partition). The sort permutes
    // index entries decorated with u64 key prefixes — payload bytes never
    // move.
    for (p, mut records) in buckets.into_iter().enumerate() {
        records.sort_by_key();
        shuffle.put(Segment {
            map: idx,
            partition: p as u32,
            node,
            records,
        });
    }
    counters.add_many(&[
        (counters::MAP_SPILLS, n_buckets as u64),
        (counters::SHUFFLE_SEGMENTS, n_buckets as u64),
    ]);
    Ok(())
}

type ReduceTaskArgs = (
    u32,
    u32,
    u32,
    Arc<JobSpec>,
    Arc<ShuffleStore>,
    Arc<Counters>,
    Arc<dyn Dfs>,
    String,
);

/// One reduce task attempt.
///
/// The shuffle hands back `Arc`-shared segments (no copies); the k-way
/// merge yields `(segment, record)` indices; grouping and reduction read
/// keys and values as borrowed slices straight out of the segment arenas.
fn run_reduce_task(args: ReduceTaskArgs) -> Result<()> {
    let (r, attempt, n_maps, spec, shuffle, counters, dfs, tmp_root) = args;
    counters.add(counters::TASKS_LAUNCHED, 1);
    if spec.failures.should_fail(TaskId::reduce(r), attempt) {
        return Err(Error::MapReduce(format!(
            "injected failure: reduce {r} attempt {attempt}"
        )));
    }

    let segments = shuffle.fetch_partition(r, n_maps)?;
    let shuffle_bytes = segments.iter().map(|s| s.bytes()).sum::<u64>();
    let order = merge_segments(&segments);
    counters.add_many(&[
        (counters::SHUFFLE_BYTES, shuffle_bytes),
        (counters::REDUCE_INPUT_RECORDS, order.len() as u64),
    ]);

    // Group by key, reduce, serialize. Keys and values are borrowed from
    // the shared segments for the whole pass.
    let mut out = Vec::new();
    let mut out_records = 0u64;
    {
        let mut emit = |k: &[u8], v: &[u8]| {
            out_records += 1;
            spec.output_format.write_record(&mut out, k, v);
        };
        let mut i = 0usize;
        while i < order.len() {
            let (s0, r0) = order[i];
            let key = segments[s0 as usize].records.key(r0 as usize);
            let mut j = i + 1;
            while j < order.len() {
                let (s1, r1) = order[j];
                if segments[s1 as usize].records.key(r1 as usize) != key {
                    break;
                }
                j += 1;
            }
            let mut values = order[i..j]
                .iter()
                .map(|&(s, rec)| segments[s as usize].records.value(rec as usize));
            spec.reducer.reduce(key, &mut values, &mut emit);
            i = j;
        }
    }
    counters.add_many(&[
        (counters::REDUCE_OUTPUT_RECORDS, out_records),
        (counters::REDUCE_OUTPUT_BYTES, out.len() as u64),
    ]);

    // Commit protocol: write the attempt file, then rename into place.
    let attempt_dir = format!("{tmp_root}/attempt_r_{r:05}_{attempt}");
    dfs.mkdirs(&attempt_dir)?;
    let attempt_file = format!("{attempt_dir}/part-r-{r:05}");
    dfs.create(&attempt_file, &out)?;
    let final_file = format!("{}/part-r-{r:05}", spec.output_dir);
    dfs.rename(&attempt_file, &final_file)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::NodeId;
    use crate::config::StackConfig;
    use crate::lustre::LustreFs;
    use crate::mapreduce::{FailurePlan, HashPartitioner, Mapper, OutputFormat, Reducer};
    use crate::mapreduce::task::TaskId;
    use crate::metrics::Metrics;
    use crate::util::ids::IdGen;

    struct WordSplit;
    impl Mapper for WordSplit {
        fn map(&self, _k: &[u8], v: &[u8], emit: &mut dyn FnMut(&[u8], &[u8])) {
            for w in v.split(|&b| b == b' ').filter(|w| !w.is_empty()) {
                emit(w, b"1");
            }
        }
    }

    struct CountReducer;
    impl Reducer for CountReducer {
        fn reduce(
            &self,
            key: &[u8],
            values: &mut dyn Iterator<Item = &[u8]>,
            emit: &mut dyn FnMut(&[u8], &[u8]),
        ) {
            let n = values.count();
            emit(key, n.to_string().as_bytes());
        }
    }

    fn stack() -> (StackConfig, Arc<LustreFs>, DynamicCluster, Pool) {
        let cfg = StackConfig::tiny();
        let fs = Arc::new(LustreFs::new(&cfg.lustre, &cfg.cluster));
        let nodes: Vec<NodeId> = (0..6).map(NodeId).collect();
        let dc = DynamicCluster::build(
            &cfg,
            &nodes,
            &*fs,
            Arc::new(IdGen::default()),
            Arc::new(Metrics::new()),
            "mr-test",
            Micros::ZERO,
        )
        .unwrap();
        (cfg, fs, dc, Pool::new(4))
    }

    fn wordcount_spec(input: &str, output: &str) -> JobSpec {
        let mut spec = JobSpec::identity("wordcount", input, output, 3);
        spec.input_format = InputFormat::Lines;
        spec.output_format = OutputFormat::TextKv;
        spec.split_bytes = 32;
        spec.mapper = Arc::new(WordSplit);
        spec.reducer = Arc::new(CountReducer);
        spec.partitioner = Arc::new(HashPartitioner);
        spec
    }

    #[test]
    fn wordcount_end_to_end() {
        let (cfg, fs, mut dc, pool) = stack();
        fs.mkdirs("/lustre/scratch/wc-in").unwrap();
        fs.create(
            "/lustre/scratch/wc-in/f1",
            b"the quick brown fox\nthe lazy dog\nthe end",
        )
        .unwrap();
        let spec = Arc::new(wordcount_spec("/lustre/scratch/wc-in", "/lustre/scratch/wc-out"));
        let mut engine = MrEngine::new(
            &mut dc,
            fs.clone(),
            &pool,
            cfg.yarn.map_memory_mb,
            cfg.yarn.reduce_memory_mb,
        );
        let outcome = engine.run(Arc::clone(&spec), "alice", Micros::ZERO).unwrap();
        assert!(outcome.maps >= 2, "small splits → multiple maps");
        assert_eq!(outcome.reduces, 3);

        // Collect all output lines and check counts.
        let mut text = String::new();
        for f in &outcome.output_files {
            text.push_str(&String::from_utf8(fs.read(f).unwrap()).unwrap());
        }
        let mut the_count = None;
        for line in text.lines() {
            let (w, n) = line.split_once('\t').unwrap();
            if w == "the" {
                the_count = Some(n.to_string());
            }
        }
        assert_eq!(the_count.as_deref(), Some("3"));
        assert!(fs.exists("/lustre/scratch/wc-out/_SUCCESS"));
        assert!(!fs.exists("/lustre/scratch/wc-out/_temporary"));
        // History recorded.
        assert_eq!(dc.jhs.count(), 1);
        dc.rm.check_invariants().unwrap();
    }

    #[test]
    fn existing_output_dir_rejected() {
        let (cfg, fs, mut dc, pool) = stack();
        fs.mkdirs("/lustre/scratch/exists").unwrap();
        fs.mkdirs("/lustre/scratch/in2").unwrap();
        fs.create("/lustre/scratch/in2/f", b"x").unwrap();
        let spec = Arc::new(wordcount_spec("/lustre/scratch/in2", "/lustre/scratch/exists"));
        let mut engine =
            MrEngine::new(&mut dc, fs, &pool, cfg.yarn.map_memory_mb, cfg.yarn.reduce_memory_mb);
        assert!(engine.run(spec, "u", Micros::ZERO).is_err());
    }

    #[test]
    fn injected_map_failure_retries_and_succeeds() {
        let (cfg, fs, mut dc, pool) = stack();
        fs.mkdirs("/lustre/scratch/in3").unwrap();
        fs.create("/lustre/scratch/in3/f", b"a b c d e f").unwrap();
        let mut spec = wordcount_spec("/lustre/scratch/in3", "/lustre/scratch/out3");
        spec.split_bytes = 1024;
        spec.failures = FailurePlan::none().fail_attempt(TaskId::map(0), 0);
        let spec = Arc::new(spec);
        let mut engine = MrEngine::new(
            &mut dc,
            fs.clone(),
            &pool,
            cfg.yarn.map_memory_mb,
            cfg.yarn.reduce_memory_mb,
        );
        let outcome = engine.run(spec, "u", Micros::ZERO).unwrap();
        assert_eq!(outcome.counters.get(counters::TASKS_FAILED), 1);
        assert!(fs.exists("/lustre/scratch/out3/_SUCCESS"));
        dc.rm.check_invariants().unwrap();
        // NM logs include the failed container's log.
        let pool_panics = pool.panic_count();
        assert_eq!(pool_panics, 0, "failures are Results, not panics");
    }

    #[test]
    fn repeated_failures_fail_the_job() {
        let (cfg, fs, mut dc, pool) = stack();
        fs.mkdirs("/lustre/scratch/in4").unwrap();
        fs.create("/lustre/scratch/in4/f", b"words here").unwrap();
        let mut spec = wordcount_spec("/lustre/scratch/in4", "/lustre/scratch/out4");
        spec.split_bytes = 1024;
        let mut failures = FailurePlan::none();
        for a in 0..MAX_ATTEMPTS {
            failures = failures.fail_attempt(TaskId::map(0), a);
        }
        spec.failures = failures;
        let spec = Arc::new(spec);
        let mut engine =
            MrEngine::new(&mut dc, fs, &pool, cfg.yarn.map_memory_mb, cfg.yarn.reduce_memory_mb);
        let err = engine.run(spec, "u", Micros::ZERO).unwrap_err();
        assert!(err.to_string().contains("failed 4 attempts"), "{err}");
        // App recorded as failed; resources all released.
        dc.rm.check_invariants().unwrap();
        let (_, used) = dc.rm.cluster_resources();
        assert_eq!(used.mem_mb, 0);
    }

    #[test]
    fn reduce_failure_retries() {
        let (cfg, fs, mut dc, pool) = stack();
        fs.mkdirs("/lustre/scratch/in5").unwrap();
        fs.create("/lustre/scratch/in5/f", b"k1 k2 k1").unwrap();
        let mut spec = wordcount_spec("/lustre/scratch/in5", "/lustre/scratch/out5");
        spec.split_bytes = 1024;
        spec.failures = FailurePlan::none().fail_attempt(TaskId::reduce(1), 0);
        let spec = Arc::new(spec);
        let mut engine = MrEngine::new(
            &mut dc,
            fs.clone(),
            &pool,
            cfg.yarn.map_memory_mb,
            cfg.yarn.reduce_memory_mb,
        );
        let outcome = engine.run(spec, "u", Micros::ZERO).unwrap();
        assert_eq!(outcome.counters.get(counters::TASKS_FAILED), 1);
        assert!(fs.exists("/lustre/scratch/out5/_SUCCESS"));
    }
}
