//! The Real-mode MapReduce executor: actual bytes through the live YARN
//! cluster built by the wrapper.
//!
//! Since PR 2 the default execution is **event-driven** (see
//! [`SchedMode::Pipelined`]): the AM-side scheduler loop grants containers
//! for pending tasks, submits each task attempt to the worker pool with a
//! completion channel ([`crate::util::pool::Pool::submit_with`]), and on
//! every completion releases that container back to the RM and immediately
//! re-grants freed capacity to the next pending task — no wave barrier, so
//! one straggler no longer idles the whole wave. Reduce tasks launch under
//! Hadoop-style **slow-start**: once `HPCW_SLOWSTART` (default 0.8) of the
//! maps have committed, reduces are granted containers and begin fetching
//! already-committed shuffle segments ([`ShuffleStore::try_fetch`])
//! concurrently with the remaining maps. A zero-container grant with
//! nothing in flight retries with bounded backoff instead of failing the
//! job.
//!
//! The pre-PR-2 lock-step wave execution survives as
//! [`SchedMode::Barriered`] — the measured baseline for
//! `benches/fig5_terasort.rs` and the parity oracle for
//! `rust/tests/prop_coordinator.rs`.
//!
//! Failed attempts (fault injection, panics) retry up to
//! [`task::MAX_ATTEMPTS`]; a node failure mid-job invalidates its shuffle
//! segments and re-runs exactly the affected maps.

use crate::cluster::{ClusterManager, NodeId};
use crate::config::ElasticConfig;
use crate::error::{Error, Result};
use crate::lustre::Dfs;
use crate::mapreduce::counters::{self, Counters};
use crate::mapreduce::recordbuf::RecordBuf;
use crate::mapreduce::shuffle::{merge_segments, Segment, ShuffleStore};
use crate::mapreduce::split::{
    assign_locality, plan_splits, read_records, row_range_splits, InputFormat, InputSplit,
};
use crate::mapreduce::task::{TaskId, MAX_ATTEMPTS};
use crate::mapreduce::JobSpec;
use crate::scheduler::{RuntimeEstimator, TaskShape};
use crate::util::ids::AppId;
use crate::util::pool::Pool;
use crate::util::time::Micros;
use crate::wrapper::DynamicCluster;
use crate::yarn::container::{Container, ContainerKind, ContainerRequest, Resource};
use crate::yarn::jobhistory::AppReport;
use crate::yarn::rm::{AppState, LocalityTier};
use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// How the engine schedules task attempts onto containers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedMode {
    /// Event-driven overlap scheduler (default): per-completion container
    /// release/re-grant, reduce slow-start, zero-grant backoff.
    Pipelined,
    /// Legacy lock-step waves (benchmark baseline / parity oracle).
    Barriered,
}

/// Default reduce slow-start fraction (Hadoop's
/// `mapreduce.job.reduce.slowstart.completedmaps` lore value).
pub const DEFAULT_SLOWSTART: f64 = 0.8;

/// Bounded retries when the RM grants zero containers with nothing in
/// flight (capacity may free up between scheduler cycles on a busy
/// cluster).
const MAX_GRANT_RETRIES: u32 = 6;
const GRANT_BACKOFF_START: Duration = Duration::from_micros(500);

/// Reduce slow-start poll interval while waiting for map segments.
const FETCH_POLL: Duration = Duration::from_micros(300);

/// Wall-clock phase marks of one job, seconds since submission. In
/// pipelined mode `first_reduce_launch_s < last_map_commit_s` is the
/// map/reduce overlap window; in barriered mode the overlap is zero by
/// construction.
#[derive(Debug, Clone, Copy, Default)]
pub struct PhaseTimings {
    pub first_map_launch_s: f64,
    pub last_map_commit_s: f64,
    /// 0.0 for map-only jobs.
    pub first_reduce_launch_s: f64,
    pub last_reduce_commit_s: f64,
    pub total_s: f64,
}

impl PhaseTimings {
    /// Seconds during which reduces were launched while maps were still
    /// committing.
    pub fn overlap_s(&self) -> f64 {
        if self.first_reduce_launch_s <= 0.0 {
            return 0.0;
        }
        (self.last_map_commit_s - self.first_reduce_launch_s).max(0.0)
    }
}

/// Result of a completed job.
#[derive(Debug)]
pub struct MrOutcome {
    pub app: AppId,
    pub maps: u32,
    pub reduces: u32,
    pub counters: Arc<Counters>,
    pub output_files: Vec<String>,
    pub wall: std::time::Duration,
    pub phases: PhaseTimings,
}

fn env_sched_mode() -> SchedMode {
    match std::env::var("HPCW_SCHED").as_deref() {
        Ok("barriered") | Ok("waves") => SchedMode::Barriered,
        _ => SchedMode::Pipelined,
    }
}

fn env_slowstart() -> f64 {
    std::env::var("HPCW_SLOWSTART")
        .ok()
        .and_then(|s| s.parse::<f64>().ok())
        .map(|f| f.clamp(0.0, 1.0))
        .unwrap_or(DEFAULT_SLOWSTART)
}

/// A scripted elastic/chaos event: once `after_maps_committed` maps have
/// committed, `action` runs against the live cluster. Deterministic
/// fault/growth injection for tests and the elastic bench.
#[derive(Debug, Clone)]
pub struct ElasticEvent {
    pub after_maps_committed: u32,
    pub action: ElasticAction,
}

/// What a scripted elastic event does.
#[derive(Debug, Clone)]
pub enum ElasticAction {
    /// Crash the nth current slave (NM vanishes, containers lost).
    FailNthSlave(usize),
    /// Crash the node holding map `m`'s committed shuffle output —
    /// deterministic "lose exactly this map's segments" injection. Fires
    /// once map `m` has committed (requires a shuffling job).
    FailMapHost(u32),
    /// The nth slave stops heartbeating; RM liveness expiry will declare
    /// it failed after `nm_timeout_ms`. Requires a cluster manager.
    PartitionNthSlave(usize),
    /// Request `n` more nodes from the batch allocator. Requires a
    /// cluster manager.
    Grow(u32),
    /// Gracefully drain the nth slave (retries until it is idle).
    DrainNthSlave(usize),
}

/// A deterministic schedule of [`ElasticEvent`]s for one job.
#[derive(Debug, Clone, Default)]
pub struct ElasticPlan {
    pub events: Vec<ElasticEvent>,
}

impl ElasticPlan {
    pub fn new() -> ElasticPlan {
        ElasticPlan::default()
    }

    pub fn at_maps(mut self, after_maps_committed: u32, action: ElasticAction) -> ElasticPlan {
        self.events.push(ElasticEvent {
            after_maps_committed,
            action,
        });
        self
    }
}

/// The Real-mode engine. Holds the live cluster and the worker pool.
pub struct MrEngine<'a> {
    pub cluster: &'a mut DynamicCluster,
    pub dfs: Arc<dyn Dfs>,
    pub pool: &'a Pool,
    pub map_memory_mb: u64,
    pub reduce_memory_mb: u64,
    /// Scheduling mode (`HPCW_SCHED=barriered` flips the default).
    pub mode: SchedMode,
    /// Reduce slow-start fraction in `[0, 1]` (`HPCW_SLOWSTART`).
    pub slowstart: f64,
    /// Elastic knobs: speculation, locality fan-out, liveness timeout
    /// (`HPCW_SPECULATION`, `HPCW_NM_TIMEOUT`, … applied from the
    /// environment).
    pub elastic_cfg: ElasticConfig,
    /// Batch-allocator-backed elasticity: when present, the scheduler
    /// loop runs a cluster-manager tick per cycle — NM heartbeats +
    /// liveness expiry, lease expiry drains, grow-on-backlog.
    pub cluster_mgr: Option<ClusterManager>,
    /// Scripted elastic/chaos events for this engine's next job.
    pub plan: ElasticPlan,
}

impl<'a> MrEngine<'a> {
    pub fn new(
        cluster: &'a mut DynamicCluster,
        dfs: Arc<dyn Dfs>,
        pool: &'a Pool,
        map_memory_mb: u64,
        reduce_memory_mb: u64,
    ) -> Self {
        let mut elastic_cfg = ElasticConfig::default();
        elastic_cfg.apply_env();
        MrEngine {
            cluster,
            dfs,
            pool,
            map_memory_mb,
            reduce_memory_mb,
            mode: env_sched_mode(),
            slowstart: env_slowstart(),
            elastic_cfg,
            cluster_mgr: None,
            plan: ElasticPlan::default(),
        }
    }

    pub fn with_mode(mut self, mode: SchedMode) -> Self {
        self.mode = mode;
        self
    }

    pub fn with_slowstart(mut self, frac: f64) -> Self {
        self.slowstart = frac.clamp(0.0, 1.0);
        self
    }

    pub fn with_elastic_cfg(mut self, cfg: ElasticConfig) -> Self {
        self.elastic_cfg = cfg;
        self
    }

    pub fn with_cluster_manager(mut self, cm: ClusterManager) -> Self {
        self.cluster_mgr = Some(cm);
        self
    }

    pub fn with_plan(mut self, plan: ElasticPlan) -> Self {
        self.plan = plan;
        self
    }

    /// Run a job to completion. `now` is the logical submission time used
    /// for YARN bookkeeping; wall time is measured for the outcome.
    pub fn run(&mut self, spec: Arc<JobSpec>, user: &str, now: Micros) -> Result<MrOutcome> {
        let t0 = Instant::now();
        // Install heterogeneous node profiles (`HPCW_NODE_MIPS` / scenario
        // machine classes) into the RM's registry before any placement
        // decision. The registry outlives node churn, so nodes joining
        // mid-job pick their profile up too.
        for &(id, mips) in &self.elastic_cfg.node_mips {
            self.cluster.rm.set_node_mips(NodeId(id), mips);
        }
        if self.dfs.exists(&spec.output_dir) {
            return Err(Error::MapReduce(format!(
                "output dir '{}' already exists",
                spec.output_dir
            )));
        }
        let mut splits: Vec<InputSplit> = match spec.input_format {
            InputFormat::RowRange => {
                let (rows, maps) = spec.synthetic_rows.ok_or_else(|| {
                    Error::MapReduce("RowRange job without synthetic_rows".into())
                })?;
                row_range_splits(rows, maps)
            }
            fmt if !spec.tagged_inputs.is_empty() => {
                // Multi-input job (repartition join): plan each tagged
                // directory and stamp its splits with the source index so
                // the map task runs the matching mapper.
                let mut all = Vec::new();
                for (i, ti) in spec.tagged_inputs.iter().enumerate() {
                    let mut part = plan_splits(&*self.dfs, &ti.dir, fmt, spec.split_bytes)?;
                    for s in &mut part {
                        s.source = i as u32;
                    }
                    all.extend(part);
                }
                all
            }
            fmt => plan_splits(&*self.dfs, &spec.input_dir, fmt, spec.split_bytes)?,
        };
        // Locality: each split's preferred nodes come from its file's DFS
        // shard residency, mapped over the current slave set.
        assign_locality(
            &mut splits,
            &*self.dfs,
            &self.cluster.slaves,
            self.elastic_cfg.locality_replicas,
        );
        // Shared once: task attempts, retries and re-grants borrow the same
        // allocation instead of cloning split metadata per attempt.
        let splits: Arc<[InputSplit]> = splits.into();
        let n_maps = splits.len() as u32;
        let n_reduces = spec.n_reduces; // 0 = map-only job (Teragen)

        // Output scaffolding.
        self.dfs.mkdirs(&spec.output_dir)?;
        let tmp_root = format!("{}/_temporary", spec.output_dir);
        self.dfs.mkdirs(&tmp_root)?;

        let handle = self.cluster.rm.submit_app(&spec.name, user, now)?;
        let counters = Arc::new(Counters::new());
        // Tier counters are per-job deltas against the backend's cumulative
        // stats, so back-to-back jobs each report their own tier traffic.
        let tier0 = self.dfs.tier_stats();
        // Tiered backends hand the shuffle a spill sink + budget; others
        // keep it all-in-RAM.
        let shuffle = Arc::new(ShuffleStore::for_dfs(&*self.dfs));

        // Broadcast side-inputs (DistributedCache shape): loaded exactly
        // once per run, before any map container is granted, so every map
        // attempt — retries, speculative twins, node-loss re-executions —
        // shares the same loaded state.
        if let Err(e) = self.load_broadcasts(&spec, &counters) {
            self.fail_app(&spec, handle.app, user, &counters, now)?;
            return Err(e);
        }

        let mut phases = PhaseTimings::default();
        let exec = match self.mode {
            SchedMode::Pipelined => self.run_pipelined(
                &spec, &handle.app, &splits, &shuffle, &counters, &tmp_root, now, t0,
                &mut phases,
            ),
            SchedMode::Barriered => self.run_barriered(
                &spec, &handle.app, &splits, &shuffle, &counters, &tmp_root, now, t0,
                &mut phases,
            ),
        };
        if let Err(e) = exec {
            self.fail_app(&spec, handle.app, user, &counters, now)?;
            return Err(e);
        }

        // Commit: _SUCCESS marker, drop _temporary.
        self.dfs.delete_recursive(&tmp_root)?;
        self.dfs.create(&format!("{}/_SUCCESS", spec.output_dir), b"")?;

        // Flush this job's two-level-storage traffic into the counter
        // groups (shuffle spill flows through the backend's sink, so the
        // tier delta already includes SPILL_BYTES).
        if let (Some(a), Some(b)) = (tier0, self.dfs.tier_stats()) {
            counters.add_many(&[
                (counters::TIER_HITS, b.tier_hits.saturating_sub(a.tier_hits)),
                (counters::TIER_MISSES, b.tier_misses.saturating_sub(a.tier_misses)),
                (
                    counters::TIER_EVICTIONS,
                    b.tier_evictions.saturating_sub(a.tier_evictions),
                ),
                (
                    counters::TIER_PROMOTIONS,
                    b.tier_promotions.saturating_sub(a.tier_promotions),
                ),
                (counters::SPILL_BYTES, b.spill_bytes.saturating_sub(a.spill_bytes)),
                (
                    counters::WRITEBACK_BYTES,
                    b.writeback_bytes.saturating_sub(a.writeback_bytes),
                ),
            ]);
        }

        self.cluster
            .rm
            .finish_app(handle.app, AppState::Finished, now)?;
        self.cluster.jhs.record(
            AppReport {
                app: handle.app,
                name: spec.name.clone(),
                user: user.to_string(),
                state: AppState::Finished,
                submitted_at: now,
                finished_at: now + Micros::from_secs_f64(t0.elapsed().as_secs_f64()),
                counters: counters.snapshot(),
            },
            &*self.dfs,
        )?;

        let output_files = self
            .dfs
            .list(&spec.output_dir)
            .into_iter()
            .filter(|p| p.contains("/part-"))
            .collect();
        phases.total_s = t0.elapsed().as_secs_f64();
        Ok(MrOutcome {
            app: handle.app,
            maps: n_maps,
            reduces: n_reduces,
            counters,
            output_files,
            wall: t0.elapsed(),
            phases,
        })
    }

    /// Ship each broadcast input to its sink: concatenate the directory's
    /// non-underscore part files in name order and call `load` once.
    fn load_broadcasts(&self, spec: &JobSpec, counters: &Counters) -> Result<()> {
        if spec.broadcast_inputs.is_empty() {
            return Ok(());
        }
        let mut total = 0u64;
        for b in &spec.broadcast_inputs {
            let files = crate::lustre::visible_files(&*self.dfs, &b.dir);
            let mut data = Vec::new();
            for f in &files {
                let len = self.dfs.size(f)?;
                data.extend_from_slice(&self.dfs.read_range(f, 0, len)?);
                // Part files may lack a trailing newline; without one the
                // next file's first line would merge into this file's last.
                if data.last().is_some_and(|&b| b != b'\n') {
                    data.push(b'\n');
                }
            }
            total += data.len() as u64;
            b.sink.load(&data)?;
        }
        counters.add(counters::BROADCAST_BYTES, total);
        Ok(())
    }

    fn fail_app(
        &mut self,
        spec: &JobSpec,
        app: AppId,
        user: &str,
        counters: &Arc<Counters>,
        now: Micros,
    ) -> Result<()> {
        self.cluster.rm.finish_app(app, AppState::Failed, now)?;
        self.cluster.jhs.record(
            AppReport {
                app,
                name: spec.name.clone(),
                user: user.to_string(),
                state: AppState::Failed,
                submitted_at: now,
                finished_at: now,
                counters: counters.snapshot(),
            },
            &*self.dfs,
        )?;
        Ok(())
    }

    /// Complete a container on its NM and release it back to the RM — the
    /// per-task-completion release that replaces `finish_wave`.
    fn finish_container(&mut self, app: &AppId, c: &Container, ok: bool) -> Result<()> {
        if let Some(nm) = self.cluster.nms.get_mut(&c.node) {
            nm.complete(c.id, ok)?;
        }
        self.cluster.rm.release(*app, c.id)?;
        Ok(())
    }

    /// Allocate up to `want` containers of `mem_mb` and launch them on
    /// their NMs. May grant fewer (including zero) — YARN semantics; the
    /// caller re-requests as capacity frees.
    fn grant(
        &mut self,
        app: &AppId,
        want: u32,
        mem_mb: u64,
        kind: ContainerKind,
        now: Micros,
    ) -> Result<Vec<Container>> {
        let got = self.cluster.rm.allocate(
            *app,
            ContainerRequest {
                resource: Resource::new(mem_mb, 1),
                count: want,
            },
            kind,
            now,
        )?;
        for c in &got {
            if let Some(nm) = self.cluster.nms.get_mut(&c.node) {
                nm.launch(c.id)?;
            }
        }
        Ok(got)
    }

    // ------------------------------------------------------------------
    // Pipelined (event-driven) scheduler
    // ------------------------------------------------------------------

    #[allow(clippy::too_many_arguments)]
    fn run_pipelined(
        &mut self,
        spec: &Arc<JobSpec>,
        app: &AppId,
        splits: &Arc<[InputSplit]>,
        shuffle: &Arc<ShuffleStore>,
        counters: &Arc<Counters>,
        tmp_root: &str,
        now: Micros,
        t0: Instant,
        phases: &mut PhaseTimings,
    ) -> Result<()> {
        let (tx, rx): (TaskTx, TaskRx) = channel();
        let cancel = Arc::new(AtomicBool::new(false));
        let mut st = PipeState::new(splits.len() as u32, spec.n_reduces, self.plan.events.len());
        let result = self.pipelined_loop(
            spec, app, splits, shuffle, counters, tmp_root, now, t0, phases, &tx, &rx,
            &cancel, &mut st,
        );
        if result.is_err() {
            // Whatever failed, leave the shared pool clean: flag in-flight
            // slow-start reduces to stop waiting and drain every running
            // task so its container is released (fail_app sweeps any
            // release this misses).
            self.drain_failed(app, &rx, &mut st.running, &cancel);
        }
        result
    }

    #[allow(clippy::too_many_arguments)]
    fn pipelined_loop(
        &mut self,
        spec: &Arc<JobSpec>,
        app: &AppId,
        splits: &Arc<[InputSplit]>,
        shuffle: &Arc<ShuffleStore>,
        counters: &Arc<Counters>,
        tmp_root: &str,
        now: Micros,
        t0: Instant,
        phases: &mut PhaseTimings,
        tx: &TaskTx,
        rx: &TaskRx,
        cancel: &Arc<AtomicBool>,
        st: &mut PipeState,
    ) -> Result<()> {
        let n_maps = splits.len() as u32;
        let n_reduces = spec.n_reduces;
        let map_only = n_reduces == 0;
        // Reduces become eligible once this many maps committed.
        let slowstart_target = ((self.slowstart * n_maps as f64).ceil() as u32).min(n_maps);

        let mut first_map_launched = false;
        let mut first_reduce_launched = false;
        let mut zero_tries = 0u32;
        let mut backoff = GRANT_BACKOFF_START;
        // Start of the current nothing-running-waiting-for-grants stretch.
        let mut grow_wait_since: Option<Instant> = None;
        let has_elastic = self.cluster_mgr.is_some() || !self.plan.events.is_empty();
        // Straggler detection and elastic control both need the loop to
        // wake without a completion. Elastic control wants a fine slice;
        // speculation alone needs to wake no faster than half its own
        // straggler floor, so the default (non-elastic) path keeps its
        // event-driven shape to within a couple of wakes per floor.
        let wait_slice = if has_elastic {
            Some(ELASTIC_TICK)
        } else if self.elastic_cfg.speculation.enabled() {
            Some(Duration::from_millis(
                (self.elastic_cfg.speculation_floor_ms / 2).max(1),
            ))
        } else {
            None
        };
        // Online per-(node, shape) runtime estimator: every committed
        // attempt folds its duration in; adaptive speculation and the
        // fast-node placement bias read it back (docs/SCHEDULING.md).
        let mut estimator = RuntimeEstimator::new();

        loop {
            // --- elastic control plane: scripted chaos/growth events, NM
            // heartbeats + liveness expiry, lease management, autoscale.
            if has_elastic {
                let lnow = now + Micros::from_secs_f64(t0.elapsed().as_secs_f64());
                self.elastic_step(st, shuffle, counters, lnow)?;
            }

            // --- straggler detection: duplicate slow attempts once a
            // phase majority has committed and capacity is otherwise idle.
            if self.elastic_cfg.speculation.enabled() {
                maybe_speculate(st, &self.elastic_cfg, &estimator, counters);
            }

            // --- launch maps: one locality-aware grant per pending task
            // (node-local > rack-local > any against the split's preferred
            // nodes).
            let mut launched = 0u32;
            while let Some(&(idx, attempt, speculative)) = st.pending_maps.front() {
                if st.maps.done[idx as usize] {
                    // A queued speculative duplicate whose original already
                    // committed: drop it instead of re-running the task.
                    // (Maps pop from the front only, so a head check is
                    // enough — no full-queue sweep on the hot path.)
                    st.pending_maps.pop_front();
                    st.maps.live[idx as usize] -= 1;
                    continue;
                }
                let prefs: &[NodeId] = &splits[idx as usize].preferred;
                // A speculative duplicate must not land on a node already
                // running an attempt of this task — the straggler's host
                // is the likely culprit (Hadoop excludes it too).
                let avoid: Vec<NodeId> = if speculative {
                    st.running
                        .values()
                        .filter(|f| {
                            !f.orphaned
                                && matches!(f.task,
                                    TaskRef::Map { idx: j, .. } if j == idx)
                        })
                        .map(|f| f.container.node)
                        .collect()
                } else {
                    Vec::new()
                };
                // Fast-node bias (adaptive mode only): speculative rescues
                // always prefer speed; regular maps do once the estimator's
                // warm map baseline says the shape is long enough for the
                // placement to matter (≥ the straggler floor). Locality
                // tiers still win — the bias only settles any-tier ties.
                let prefer_fast = self.elastic_cfg.speculation.is_adaptive()
                    && (speculative
                        || estimator.shape_mean_s(TaskShape::Map).is_some_and(|m| {
                            m * 1000.0 >= self.elastic_cfg.speculation_floor_ms as f64
                        }));
                let got = self.cluster.rm.allocate_one_biased(
                    *app,
                    Resource::new(self.map_memory_mb, 1),
                    ContainerKind::Map,
                    prefs,
                    &avoid,
                    now,
                    prefer_fast,
                )?;
                let Some((c, tier, fast_biased)) = got else { break };
                if let Some(nm) = self.cluster.nms.get_mut(&c.node) {
                    nm.launch(c.id)?;
                }
                st.pending_maps.pop_front();
                counters.add(counters::CONTAINERS_GRANTED, 1);
                let tier_counter = match tier {
                    LocalityTier::NodeLocal => counters::LOCAL_MAPS,
                    LocalityTier::RackLocal => counters::RACK_MAPS,
                    LocalityTier::Any => counters::OTHER_MAPS,
                };
                counters.add(tier_counter, 1);
                if fast_biased {
                    counters.add(counters::FAST_NODE_PLACEMENTS, 1);
                }
                if !first_map_launched {
                    first_map_launched = true;
                    phases.first_map_launch_s = t0.elapsed().as_secs_f64();
                }
                let token = st.next_token;
                st.next_token += 1;
                st.running.insert(
                    token,
                    InFlight {
                        container: c,
                        task: TaskRef::Map { idx, attempt },
                        started: Instant::now(),
                        speculative,
                        orphaned: false,
                    },
                );
                st.maps_running += 1;
                launched += 1;
                self.pool.submit_with(
                    token,
                    MapTaskArgs {
                        idx,
                        attempt,
                        node: c.node,
                        mips: self.cluster.rm.node_mips(c.node),
                        splits: Arc::clone(splits),
                        spec: Arc::clone(spec),
                        shuffle: Arc::clone(shuffle),
                        counters: Arc::clone(counters),
                        dfs: Arc::clone(&self.dfs),
                    },
                    run_map_task,
                    tx.clone(),
                );
            }
            if !map_only && st.maps_committed >= slowstart_target {
                // While maps are still outstanding, cap in-flight reduces
                // below the pool width so slow-start fetch-waits can never
                // starve the remaining maps of worker threads.
                // (With a 1-wide pool that cap is zero: there is no spare
                // worker, so reduces wait for the maps to drain.)
                let maps_outstanding = !st.pending_maps.is_empty() || st.maps_running > 0;
                let cap = if maps_outstanding {
                    self.pool.size().saturating_sub(1) as u32
                } else {
                    u32::MAX
                };
                // The batched grant below pops entries from arbitrary
                // queue positions, so stale speculative duplicates of
                // committed reduces are swept first (the queue is at most
                // n_reduces long — cheap).
                if !st.pending_reduces.is_empty() {
                    let rt = &mut st.reduces;
                    st.pending_reduces.retain(|&(r, _, _)| {
                        let keep = !rt.done[r as usize];
                        if !keep {
                            rt.live[r as usize] -= 1;
                        }
                        keep
                    });
                }
                while !st.pending_reduces.is_empty() && st.reduces_running < cap {
                    let &(r, attempt, speculative) = st.pending_reduces.front().unwrap();
                    // A speculative duplicate must not land beside the
                    // attempt it races.
                    let avoid: Vec<NodeId> = if speculative {
                        st.running
                            .values()
                            .filter(|f| {
                                !f.orphaned
                                    && matches!(f.task,
                                        TaskRef::Reduce { r: j, .. } if j == r)
                            })
                            .map(|f| f.container.node)
                            .collect()
                    } else {
                        Vec::new()
                    };
                    // Reduces carry no locality preference, so placement is
                    // always the any tier — exactly where the fast bias
                    // matters most: the whole fetch+merge+write runs on
                    // whichever node wins. The warm map baseline stands in
                    // while the reduce cells are still cold (first wave).
                    let prefer_fast = self.elastic_cfg.speculation.is_adaptive()
                        && (speculative
                            || estimator
                                .shape_mean_s(TaskShape::Reduce)
                                .or_else(|| estimator.shape_mean_s(TaskShape::Map))
                                .is_some_and(|m| {
                                    m * 1000.0
                                        >= self.elastic_cfg.speculation_floor_ms as f64
                                }));
                    let got = self.cluster.rm.allocate_one_biased(
                        *app,
                        Resource::new(self.reduce_memory_mb, 1),
                        ContainerKind::Reduce,
                        &[],
                        &avoid,
                        now,
                        prefer_fast,
                    )?;
                    let Some((c, _tier, fast_biased)) = got else { break };
                    if let Some(nm) = self.cluster.nms.get_mut(&c.node) {
                        nm.launch(c.id)?;
                    }
                    st.pending_reduces.pop_front();
                    counters.add(counters::CONTAINERS_GRANTED, 1);
                    if fast_biased {
                        counters.add(counters::FAST_NODE_PLACEMENTS, 1);
                    }
                    if !first_reduce_launched {
                        first_reduce_launched = true;
                        phases.first_reduce_launch_s = t0.elapsed().as_secs_f64();
                        counters.add(counters::FIRST_REDUCE_LAUNCHED, 1);
                        counters.add(counters::MAPS_AT_FIRST_REDUCE, st.maps_committed as u64);
                    }
                    let token = st.next_token;
                    st.next_token += 1;
                    st.running.insert(
                        token,
                        InFlight {
                            container: c,
                            task: TaskRef::Reduce { r, attempt },
                            started: Instant::now(),
                            speculative,
                            orphaned: false,
                        },
                    );
                    st.reduces_running += 1;
                    launched += 1;
                    self.pool.submit_with(
                        token,
                        ReduceTaskArgs {
                            r,
                            attempt,
                            n_maps,
                            mips: self.cluster.rm.node_mips(c.node),
                            spec: Arc::clone(spec),
                            shuffle: Arc::clone(shuffle),
                            counters: Arc::clone(counters),
                            dfs: Arc::clone(&self.dfs),
                            tmp_root: tmp_root.to_string(),
                            cancel: Some(Arc::clone(cancel)),
                        },
                        run_reduce_task,
                        tx.clone(),
                    );
                }
            }

            if st.running.is_empty() {
                if st.pending_maps.is_empty() && st.pending_reduces.is_empty() {
                    break; // job complete
                }
                debug_assert_eq!(launched, 0);
                // Capacity known to be on its way (queued batch grants or
                // a below-floor cluster being replenished): keep ticking
                // the control plane without consuming the hard-retry
                // budget, bounded by a wall-clock stall limit. The retry
                // counter ticks once per grow-wait stretch, not per sleep.
                let growing = self.cluster_mgr.as_ref().is_some_and(|cm| {
                    cm.alloc.queued_nodes() > 0 || cm.alloc.free_count() > 0
                });
                if growing {
                    if grow_wait_since.is_none() {
                        counters.add(counters::GRANT_ZERO_RETRIES, 1);
                    }
                    let since = *grow_wait_since.get_or_insert_with(Instant::now);
                    if since.elapsed() > GROW_STALL_LIMIT {
                        return Err(Error::MapReduce(
                            "cluster grow stalled: batch grants never arrived".into(),
                        ));
                    }
                    std::thread::sleep(Duration::from_millis(1));
                    continue;
                }
                // Nothing in flight and the RM granted zero containers:
                // bounded retry with backoff (capacity can free between
                // scheduler cycles) instead of failing the job outright.
                counters.add(counters::GRANT_ZERO_RETRIES, 1);
                zero_tries += 1;
                if zero_tries > MAX_GRANT_RETRIES {
                    return Err(Error::MapReduce(format!(
                        "RM granted zero containers over {MAX_GRANT_RETRIES} \
                         backoff retries — cluster cannot host a single task"
                    )));
                }
                std::thread::sleep(backoff);
                backoff = backoff.saturating_mul(2);
                continue;
            }
            zero_tries = 0;
            backoff = GRANT_BACKOFF_START;
            grow_wait_since = None;

            // --- wait for a completion, then release + re-grant. With an
            // elastic control plane (or speculation) the wait is sliced so
            // heartbeats, expiry, admissions and straggler scans stay
            // timely even when completions are sparse.
            let (token, result) = if let Some(slice) = wait_slice {
                match rx.recv_timeout(slice) {
                    Ok(v) => v,
                    Err(std::sync::mpsc::RecvTimeoutError::Timeout) => continue,
                    Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
                        return Err(Error::MapReduce("scheduler channel closed".into()))
                    }
                }
            } else {
                rx.recv()
                    .map_err(|_| Error::MapReduce("scheduler channel closed".into()))?
            };
            let inflight = match st.running.remove(&token) {
                Some(inflight) => inflight,
                None => {
                    if st.detached.remove(&token) {
                        continue; // killed speculation loser finally returned
                    }
                    return Err(Error::MapReduce(format!("unknown task token {token}")));
                }
            };
            let ok = matches!(result, Some(Ok(())));
            if inflight.orphaned {
                // The attempt's node died mid-flight: its container is
                // already gone from the RM, its commit (if any) was fenced
                // out of the shuffle, and its task was rescheduled when
                // the node failed. Discard the zombie result.
                match inflight.task {
                    TaskRef::Map { .. } => st.maps_running -= 1,
                    TaskRef::Reduce { .. } => st.reduces_running -= 1,
                }
                continue;
            }
            self.finish_container(app, &inflight.container, ok)?;
            match inflight.task {
                TaskRef::Map { idx, .. } => {
                    st.maps_running -= 1;
                    let i = idx as usize;
                    st.maps.live[i] -= 1;
                    if ok {
                        if !st.maps.done[i] {
                            st.maps.done[i] = true;
                            st.maps_committed += 1;
                            let dur_s = inflight.started.elapsed().as_secs_f64();
                            st.maps.durations_s.push(dur_s);
                            estimator.observe(inflight.container.node, TaskShape::Map, dur_s);
                            counters.add(counters::ESTIMATOR_UPDATES, 1);
                            phases.last_map_commit_s = t0.elapsed().as_secs_f64();
                            if inflight.speculative {
                                counters.add(counters::SPECULATIVE_WINS, 1);
                            }
                            // First commit wins: kill any still-running
                            // twin of this task — release its container
                            // now and stop waiting on its result.
                            self.kill_twins(app, st, inflight.task);
                        }
                        // else: a speculative twin lost the race — first
                        // commit won, this container just gets released.
                    } else if !st.maps.done[i] {
                        counters.add(counters::TASKS_FAILED, 1);
                        st.maps.failures[i] += 1;
                        if st.maps.failures[i] >= MAX_ATTEMPTS {
                            // The caller drains in-flight tasks on error.
                            return Err(Error::MapReduce(format!(
                                "map {idx} failed {MAX_ATTEMPTS} attempts"
                            )));
                        }
                        if st.maps.live[i] == 0 {
                            st.push_map(idx, false);
                        }
                    }
                }
                TaskRef::Reduce { r, .. } => {
                    st.reduces_running -= 1;
                    let i = r as usize;
                    st.reduces.live[i] -= 1;
                    if ok {
                        if !st.reduces.done[i] {
                            st.reduces.done[i] = true;
                            st.reduces_done += 1;
                            let dur_s = inflight.started.elapsed().as_secs_f64();
                            st.reduces.durations_s.push(dur_s);
                            estimator.observe(
                                inflight.container.node,
                                TaskShape::Reduce,
                                dur_s,
                            );
                            counters.add(counters::ESTIMATOR_UPDATES, 1);
                            phases.last_reduce_commit_s = t0.elapsed().as_secs_f64();
                            if inflight.speculative {
                                counters.add(counters::SPECULATIVE_WINS, 1);
                            }
                            self.kill_twins(app, st, inflight.task);
                        }
                    } else if !st.reduces.done[i] {
                        counters.add(counters::TASKS_FAILED, 1);
                        st.reduces.failures[i] += 1;
                        if st.reduces.failures[i] >= MAX_ATTEMPTS {
                            // The caller drains in-flight tasks on error.
                            return Err(Error::MapReduce(format!(
                                "reduce {r} failed {MAX_ATTEMPTS} attempts"
                            )));
                        }
                        if st.reduces.live[i] == 0 {
                            st.push_reduce(r, false);
                        }
                    }
                }
            }
        }
        debug_assert_eq!(st.maps_committed, n_maps);
        debug_assert!(map_only || st.reduces_done == n_reduces);
        Ok(())
    }

    /// First-commit-wins cleanup: every still-running non-orphaned
    /// attempt of the committed task is killed — its container released
    /// now, its token detached so the scheduler stops waiting on it and
    /// discards its late pool result.
    fn kill_twins(&mut self, app: &AppId, st: &mut PipeState, task: TaskRef) {
        let twins: Vec<u64> = st
            .running
            .iter()
            .filter(|(_, f)| !f.orphaned && f.task.same_task(task))
            .map(|(&t, _)| t)
            .collect();
        for t in twins {
            let loser = st.running.remove(&t).unwrap();
            let _ = self.finish_container(app, &loser.container, false);
            match loser.task {
                TaskRef::Map { idx, .. } => {
                    st.maps_running -= 1;
                    st.maps.live[idx as usize] -= 1;
                }
                TaskRef::Reduce { r, .. } => {
                    st.reduces_running -= 1;
                    st.reduces.live[r as usize] -= 1;
                }
            }
            st.detached.insert(t);
        }
    }

    /// One elastic control-plane step: fire due scripted events, then run
    /// a cluster-manager tick (heartbeats → expiry, lease drains,
    /// grow-on-backlog, admissions).
    fn elastic_step(
        &mut self,
        st: &mut PipeState,
        shuffle: &Arc<ShuffleStore>,
        counters: &Arc<Counters>,
        lnow: Micros,
    ) -> Result<()> {
        for i in 0..self.plan.events.len() {
            if st.fired[i] || st.maps_committed < self.plan.events[i].after_maps_committed {
                continue;
            }
            let action = self.plan.events[i].action.clone();
            let done = match action {
                ElasticAction::FailNthSlave(n) => {
                    if let Some(&node) = self.cluster.slaves.get(n) {
                        if let Some(cm) = self.cluster_mgr.as_mut() {
                            cm.fail(self.cluster, node, lnow);
                        } else {
                            self.cluster.fail_node(node, lnow);
                        }
                        apply_node_loss(node, st, shuffle, counters);
                    }
                    true
                }
                ElasticAction::FailMapHost(m) => {
                    if !st.maps.done.get(m as usize).copied().unwrap_or(false) {
                        false // not committed yet; retry on a later step
                    } else {
                        if let Some(seg) = shuffle.try_fetch(m, 0) {
                            let node = seg.node;
                            if self.cluster.rm.has_nm(node) {
                                if let Some(cm) = self.cluster_mgr.as_mut() {
                                    cm.fail(self.cluster, node, lnow);
                                } else {
                                    self.cluster.fail_node(node, lnow);
                                }
                                apply_node_loss(node, st, shuffle, counters);
                            }
                        }
                        true
                    }
                }
                ElasticAction::PartitionNthSlave(n) => {
                    if let Some(&node) = self.cluster.slaves.get(n) {
                        if let Some(cm) = self.cluster_mgr.as_mut() {
                            cm.partition(node);
                        }
                    }
                    true
                }
                ElasticAction::Grow(k) => {
                    if let Some(cm) = self.cluster_mgr.as_mut() {
                        cm.request_grow(self.cluster, k, lnow);
                    }
                    true
                }
                ElasticAction::DrainNthSlave(n) => match self.cluster.slaves.get(n).copied() {
                    Some(node) => {
                        let drained = match self.cluster_mgr.as_mut() {
                            Some(cm) => cm.drain(self.cluster, node, lnow).is_ok(),
                            None => self.cluster.decommission_node(node, lnow).is_ok(),
                        };
                        if drained {
                            counters.add(counters::NODES_DRAINED, 1);
                        }
                        drained // busy node: retry on a later step
                    }
                    None => true,
                },
            };
            if done {
                st.fired[i] = true;
            }
        }
        if let Some(cm) = self.cluster_mgr.as_mut() {
            let backlog = (st.pending_maps.len() + st.pending_reduces.len()) as u32;
            let delta = cm.tick(self.cluster, backlog, lnow)?;
            if !delta.joined.is_empty() {
                counters.add(counters::NODES_JOINED, delta.joined.len() as u64);
            }
            if !delta.drained.is_empty() {
                counters.add(counters::NODES_DRAINED, delta.drained.len() as u64);
            }
            for (node, _lost) in delta.failed {
                apply_node_loss(node, st, shuffle, counters);
            }
        }
        Ok(())
    }

    /// Job failure mid-flight: flag running slow-start reduces to bail out
    /// of their fetch wait, then drain every in-flight task so the shared
    /// pool is clean for the next job. Best-effort on the YARN side — a
    /// container whose release fails here is swept up by `fail_app`'s
    /// `finish_app`.
    fn drain_failed(
        &mut self,
        app: &AppId,
        rx: &TaskRx,
        running: &mut BTreeMap<u64, InFlight>,
        cancel: &Arc<AtomicBool>,
    ) {
        cancel.store(true, Ordering::SeqCst);
        while !running.is_empty() {
            match rx.recv() {
                Ok((token, result)) => {
                    if let Some(inflight) = running.remove(&token) {
                        let ok = matches!(result, Some(Ok(())));
                        let _ = self.finish_container(app, &inflight.container, ok);
                    }
                }
                Err(_) => break, // channel closed: nothing left to drain
            }
        }
    }

    // ------------------------------------------------------------------
    // Barriered baseline (pre-PR-2 wave execution)
    // ------------------------------------------------------------------

    #[allow(clippy::too_many_arguments)]
    fn run_barriered(
        &mut self,
        spec: &Arc<JobSpec>,
        app: &AppId,
        splits: &Arc<[InputSplit]>,
        shuffle: &Arc<ShuffleStore>,
        counters: &Arc<Counters>,
        tmp_root: &str,
        now: Micros,
        t0: Instant,
        phases: &mut PhaseTimings,
    ) -> Result<()> {
        let n_maps = splits.len() as u32;
        let n_reduces = spec.n_reduces;
        phases.first_map_launch_s = t0.elapsed().as_secs_f64();
        self.run_maps_barriered(spec, app, splits, shuffle, counters, now)?;
        phases.last_map_commit_s = t0.elapsed().as_secs_f64();
        if n_reduces > 0 {
            shuffle.verify_complete(n_maps, n_reduces)?;
            phases.first_reduce_launch_s = t0.elapsed().as_secs_f64();
            counters.add(counters::FIRST_REDUCE_LAUNCHED, 1);
            counters.add(counters::MAPS_AT_FIRST_REDUCE, n_maps as u64);
            self.run_reduces_barriered(
                spec, app, n_maps, n_reduces, shuffle, counters, tmp_root, now,
            )?;
            phases.last_reduce_commit_s = t0.elapsed().as_secs_f64();
        }
        Ok(())
    }

    /// Grant a wave of containers for `want` tasks of `mem_mb`. Zero-grant
    /// retries with bounded backoff before giving up.
    fn grant_wave(
        &mut self,
        app: &AppId,
        want: u32,
        mem_mb: u64,
        kind: ContainerKind,
        counters: &Arc<Counters>,
        now: Micros,
    ) -> Result<Vec<Container>> {
        let mut backoff = GRANT_BACKOFF_START;
        for attempt in 0..=MAX_GRANT_RETRIES {
            let got = self.grant(app, want, mem_mb, kind, now)?;
            if !got.is_empty() {
                counters.add(counters::CONTAINERS_GRANTED, got.len() as u64);
                return Ok(got);
            }
            counters.add(counters::GRANT_ZERO_RETRIES, 1);
            if attempt < MAX_GRANT_RETRIES {
                std::thread::sleep(backoff);
                backoff = backoff.saturating_mul(2);
            }
        }
        Err(Error::MapReduce(format!(
            "RM granted zero containers over {MAX_GRANT_RETRIES} backoff \
             retries — cluster cannot host a single task"
        )))
    }

    fn finish_wave(&mut self, app: &AppId, wave: &[(Container, bool)]) -> Result<()> {
        for (c, ok) in wave {
            self.finish_container(app, c, *ok)?;
        }
        Ok(())
    }

    fn run_maps_barriered(
        &mut self,
        spec: &Arc<JobSpec>,
        app: &AppId,
        splits: &Arc<[InputSplit]>,
        shuffle: &Arc<ShuffleStore>,
        counters: &Arc<Counters>,
        now: Micros,
    ) -> Result<()> {
        // (task index, attempt) work queue.
        let mut todo: Vec<(u32, u32)> = (0..splits.len() as u32).map(|i| (i, 0)).collect();
        while !todo.is_empty() {
            let wave_n = todo.len() as u32;
            let granted =
                self.grant_wave(app, wave_n, self.map_memory_mb, ContainerKind::Map, counters, now)?;
            let batch: Vec<((u32, u32), Container)> =
                todo.drain(..granted.len().min(todo.len())).zip(granted).collect();

            let results = self.pool.try_map(
                batch
                    .iter()
                    .map(|((idx, attempt), c)| MapTaskArgs {
                        idx: *idx,
                        attempt: *attempt,
                        node: c.node,
                        mips: self.cluster.rm.node_mips(c.node),
                        splits: Arc::clone(splits),
                        spec: Arc::clone(spec),
                        shuffle: Arc::clone(shuffle),
                        counters: Arc::clone(counters),
                        dfs: Arc::clone(&self.dfs),
                    })
                    .collect(),
                run_map_task,
            );

            let mut wave_done = Vec::new();
            for (((idx, attempt), container), result) in batch.into_iter().zip(results) {
                let ok = matches!(result, Some(Ok(())));
                wave_done.push((container, ok));
                if !ok {
                    counters.add(counters::TASKS_FAILED, 1);
                    let next = attempt + 1;
                    if next >= MAX_ATTEMPTS {
                        self.finish_wave(app, &wave_done)?;
                        return Err(Error::MapReduce(format!(
                            "map {idx} failed {MAX_ATTEMPTS} attempts"
                        )));
                    }
                    todo.push((idx, next));
                }
            }
            self.finish_wave(app, &wave_done)?;
        }
        Ok(())
    }

    #[allow(clippy::too_many_arguments)]
    fn run_reduces_barriered(
        &mut self,
        spec: &Arc<JobSpec>,
        app: &AppId,
        n_maps: u32,
        n_reduces: u32,
        shuffle: &Arc<ShuffleStore>,
        counters: &Arc<Counters>,
        tmp_root: &str,
        now: Micros,
    ) -> Result<()> {
        let mut todo: Vec<(u32, u32)> = (0..n_reduces).map(|r| (r, 0)).collect();
        while !todo.is_empty() {
            let wave_n = todo.len() as u32;
            let granted = self.grant_wave(
                app, wave_n, self.reduce_memory_mb, ContainerKind::Reduce, counters, now,
            )?;
            let batch: Vec<((u32, u32), Container)> =
                todo.drain(..granted.len().min(todo.len())).zip(granted).collect();

            let results = self.pool.try_map(
                batch
                    .iter()
                    .map(|((r, attempt), c)| ReduceTaskArgs {
                        r: *r,
                        attempt: *attempt,
                        n_maps,
                        mips: self.cluster.rm.node_mips(c.node),
                        spec: Arc::clone(spec),
                        shuffle: Arc::clone(shuffle),
                        counters: Arc::clone(counters),
                        dfs: Arc::clone(&self.dfs),
                        tmp_root: tmp_root.to_string(),
                        cancel: None,
                    })
                    .collect(),
                run_reduce_task,
            );

            let mut wave_done = Vec::new();
            for (((r, attempt), container), result) in batch.into_iter().zip(results) {
                let ok = matches!(result, Some(Ok(())));
                wave_done.push((container, ok));
                if !ok {
                    counters.add(counters::TASKS_FAILED, 1);
                    let next = attempt + 1;
                    if next >= MAX_ATTEMPTS {
                        self.finish_wave(app, &wave_done)?;
                        return Err(Error::MapReduce(format!(
                            "reduce {r} failed {MAX_ATTEMPTS} attempts"
                        )));
                    }
                    todo.push((r, next));
                }
            }
            self.finish_wave(app, &wave_done)?;
        }
        Ok(())
    }
}

/// Control-plane slice of the completion wait when elasticity is on:
/// heartbeats/expiry/admissions run at least this often.
const ELASTIC_TICK: Duration = Duration::from_millis(2);

/// Hard wall-clock cap on waiting for queued batch grants with nothing
/// running (a stuck allocator must fail the job, not hang it).
const GROW_STALL_LIMIT: Duration = Duration::from_secs(30);

/// What one in-flight container is working on.
#[derive(Debug, Clone, Copy)]
enum TaskRef {
    Map { idx: u32, attempt: u32 },
    Reduce { r: u32, attempt: u32 },
}

impl TaskRef {
    /// Same task (phase + index), regardless of attempt.
    fn same_task(self, other: TaskRef) -> bool {
        match (self, other) {
            (TaskRef::Map { idx: a, .. }, TaskRef::Map { idx: b, .. }) => a == b,
            (TaskRef::Reduce { r: a, .. }, TaskRef::Reduce { r: b, .. }) => a == b,
            _ => false,
        }
    }
}

struct InFlight {
    container: Container,
    task: TaskRef,
    started: Instant,
    /// This attempt is a straggler's speculative duplicate.
    speculative: bool,
    /// This attempt's node died; its result is a zombie to discard.
    orphaned: bool,
}

/// Per-phase task bookkeeping for the pipelined scheduler.
struct TaskTable {
    /// Committed (invalidation flips this back to false).
    done: Vec<bool>,
    /// Genuine attempt failures (node losses do not count — Hadoop's
    /// killed-vs-failed distinction).
    failures: Vec<u32>,
    /// Next attempt id (monotonic; keeps attempt dirs/logs unique across
    /// retries, speculation and post-failure re-runs).
    next_attempt: Vec<u32>,
    /// Pending + running non-orphaned attempts per task.
    live: Vec<u32>,
    /// Durations of committed attempts (straggler baseline).
    durations_s: Vec<f64>,
}

impl TaskTable {
    fn new(n: u32) -> TaskTable {
        TaskTable {
            done: vec![false; n as usize],
            failures: vec![0; n as usize],
            next_attempt: vec![0; n as usize],
            live: vec![0; n as usize],
            durations_s: Vec::new(),
        }
    }

    fn mean_duration_s(&self) -> Option<f64> {
        if self.durations_s.is_empty() {
            return None;
        }
        Some(self.durations_s.iter().sum::<f64>() / self.durations_s.len() as f64)
    }
}

/// Mutable scheduling state of one pipelined job.
struct PipeState {
    /// `(task index, attempt id, speculative)` queues.
    pending_maps: VecDeque<(u32, u32, bool)>,
    pending_reduces: VecDeque<(u32, u32, bool)>,
    running: BTreeMap<u64, InFlight>,
    maps: TaskTable,
    reduces: TaskTable,
    maps_committed: u32,
    reduces_done: u32,
    maps_running: u32,
    reduces_running: u32,
    next_token: u64,
    /// Scripted elastic events already executed.
    fired: Vec<bool>,
    /// Tokens of killed speculation losers: their containers are already
    /// released and the scheduler no longer waits on them; their late
    /// pool results are discarded on arrival.
    detached: std::collections::BTreeSet<u64>,
}

impl PipeState {
    fn new(n_maps: u32, n_reduces: u32, plan_events: usize) -> PipeState {
        let mut st = PipeState {
            pending_maps: VecDeque::with_capacity(n_maps as usize),
            pending_reduces: VecDeque::with_capacity(n_reduces as usize),
            running: BTreeMap::new(),
            maps: TaskTable::new(n_maps),
            reduces: TaskTable::new(n_reduces),
            maps_committed: 0,
            reduces_done: 0,
            maps_running: 0,
            reduces_running: 0,
            next_token: 0,
            fired: vec![false; plan_events],
            detached: std::collections::BTreeSet::new(),
        };
        for i in 0..n_maps {
            st.push_map(i, false);
        }
        for r in 0..n_reduces {
            st.push_reduce(r, false);
        }
        st
    }

    fn push_map(&mut self, idx: u32, speculative: bool) {
        let a = self.maps.next_attempt[idx as usize];
        self.maps.next_attempt[idx as usize] += 1;
        self.maps.live[idx as usize] += 1;
        self.pending_maps.push_back((idx, a, speculative));
    }

    fn push_reduce(&mut self, r: u32, speculative: bool) {
        let a = self.reduces.next_attempt[r as usize];
        self.reduces.next_attempt[r as usize] += 1;
        self.reduces.live[r as usize] += 1;
        self.pending_reduces.push_back((r, a, speculative));
    }
}

/// A node died: fence its shuffle output, reschedule the committed maps
/// it hosted, orphan its in-flight attempts and reschedule their tasks.
/// Committed reduces are untouched — their output lives on the shared
/// filesystem (the paper's Lustre argument), exactly Hadoop's behaviour.
fn apply_node_loss(
    node: NodeId,
    st: &mut PipeState,
    shuffle: &ShuffleStore,
    counters: &Counters,
) {
    counters.add(counters::NODES_FAILED, 1);
    // Fence + drop the dead node's map output; these maps must re-run.
    let lost_maps = shuffle.invalidate_node(node);

    // Orphan in-flight attempts that were running on the dead node.
    let victims: Vec<(u64, TaskRef)> = st
        .running
        .iter()
        .filter(|(_, inf)| inf.container.node == node && !inf.orphaned)
        .map(|(&t, inf)| (t, inf.task))
        .collect();
    let mut hit_maps: Vec<u32> = Vec::new();
    let mut hit_reduces: Vec<u32> = Vec::new();
    for (token, task) in victims {
        st.running.get_mut(&token).unwrap().orphaned = true;
        match task {
            TaskRef::Map { idx, .. } => {
                st.maps.live[idx as usize] -= 1;
                hit_maps.push(idx);
            }
            TaskRef::Reduce { r, .. } => {
                st.reduces.live[r as usize] -= 1;
                hit_reduces.push(r);
            }
        }
    }

    // Committed output lost → not done any more; count the invalidation.
    for &m in &lost_maps {
        let i = m as usize;
        if st.maps.done[i] {
            st.maps.done[i] = false;
            st.maps_committed -= 1;
            counters.add(counters::MAPS_INVALIDATED, 1);
        }
    }

    // Re-execute every affected task that has no other live attempt.
    let affected: std::collections::BTreeSet<u32> =
        lost_maps.into_iter().chain(hit_maps).collect();
    for m in affected {
        if !st.maps.done[m as usize] && st.maps.live[m as usize] == 0 {
            st.push_map(m, false);
        }
    }
    for r in hit_reduces {
        if !st.reduces.done[r as usize] && st.reduces.live[r as usize] == 0 {
            st.push_reduce(r, false);
        }
    }
}

/// Straggler scan: once a phase has a duration baseline (≥ 3 commits and
/// a committed majority) and no other work is pending, any sole running
/// attempt over its threshold gets a speculative duplicate. In static
/// mode the threshold is the global `factor × mean` (and the absolute
/// floor). In adaptive mode each attempt is judged against the predicted
/// p95 of its *own* (node, shape) estimator cell — a fast node's
/// straggler fires early instead of hiding under a mean inflated by slow
/// nodes — falling back to the static rule while the cell is cold.
/// First commit wins; the loser's container is simply released on
/// completion.
fn maybe_speculate(
    st: &mut PipeState,
    cfg: &ElasticConfig,
    estimator: &RuntimeEstimator,
    counters: &Counters,
) {
    let floor_s = cfg.speculation_floor_ms as f64 / 1000.0;
    let adaptive = cfg.speculation.is_adaptive();
    // (task index, triggered by the per-cell p95 prediction)
    let mut spec_maps: Vec<(u32, bool)> = Vec::new();
    let mut spec_reduces: Vec<(u32, bool)> = Vec::new();
    let n_maps = st.maps.done.len() as u32;
    let n_reduces = st.reduces.done.len() as u32;
    let m_mean = st.maps.mean_duration_s();
    let r_mean = st.reduces.mean_duration_s();
    for inf in st.running.values() {
        if inf.orphaned || inf.speculative {
            continue;
        }
        let elapsed = inf.started.elapsed().as_secs_f64();
        let node = inf.container.node;
        match inf.task {
            TaskRef::Map { idx, .. } => {
                if !st.pending_maps.is_empty()
                    || st.maps_committed < 3
                    || st.maps_committed * 2 < n_maps
                {
                    continue;
                }
                let i = idx as usize;
                if st.maps.done[i] || st.maps.live[i] != 1 {
                    continue;
                }
                let cell = if adaptive {
                    estimator.predicted_p95(node, TaskShape::Map)
                } else {
                    None
                };
                let (threshold, predicted) = match cell {
                    Some(p95) => (p95.max(floor_s), true),
                    None => {
                        let Some(mean) = m_mean else { continue };
                        ((cfg.speculation_factor * mean).max(floor_s), false)
                    }
                };
                if elapsed > threshold {
                    spec_maps.push((idx, predicted));
                }
            }
            TaskRef::Reduce { r, .. } => {
                if !st.pending_reduces.is_empty()
                    || st.reduces_done < 3
                    || st.reduces_done * 2 < n_reduces
                {
                    continue;
                }
                let i = r as usize;
                if st.reduces.done[i] || st.reduces.live[i] != 1 {
                    continue;
                }
                let cell = if adaptive {
                    estimator.predicted_p95(node, TaskShape::Reduce)
                } else {
                    None
                };
                let (threshold, predicted) = match cell {
                    Some(p95) => (p95.max(floor_s), true),
                    None => {
                        let Some(mean) = r_mean else { continue };
                        ((cfg.speculation_factor * mean).max(floor_s), false)
                    }
                };
                if elapsed > threshold {
                    spec_reduces.push((r, predicted));
                }
            }
        }
    }
    for (idx, predicted) in spec_maps {
        st.push_map(idx, true);
        counters.add(counters::TASKS_SPECULATED, 1);
        if predicted {
            counters.add(counters::PREDICTED_P95_SPECULATIONS, 1);
        }
    }
    for (r, predicted) in spec_reduces {
        st.push_reduce(r, true);
        counters.add(counters::TASKS_SPECULATED, 1);
        if predicted {
            counters.add(counters::PREDICTED_P95_SPECULATIONS, 1);
        }
    }
}

type TaskTx = Sender<(u64, Option<Result<()>>)>;
type TaskRx = Receiver<(u64, Option<Result<()>>)>;

/// Arguments of one map task attempt.
struct MapTaskArgs {
    idx: u32,
    attempt: u32,
    node: crate::cluster::NodeId,
    /// The host node's MIPS tier (heterogeneity wall-clock model).
    mips: u64,
    splits: Arc<[InputSplit]>,
    spec: Arc<JobSpec>,
    shuffle: Arc<ShuffleStore>,
    counters: Arc<Counters>,
    dfs: Arc<dyn Dfs>,
}

/// One map task attempt (runs on a pool worker).
///
/// Records flow through the flat [`RecordBuf`] arena: emissions are copied
/// straight into per-partition buffers (no per-record heap allocation),
/// counters accumulate in task-local `u64`s and flush once at the end of
/// the task, and spilled segments hand their arenas to the shuffle store
/// without further copying.
fn run_map_task(args: MapTaskArgs) -> Result<()> {
    let MapTaskArgs { idx, attempt, node, mips, splits, spec, shuffle, counters, dfs } = args;
    let t_work = Instant::now();
    let split = &splits[idx as usize];
    counters.add(counters::TASKS_LAUNCHED, 1);
    if spec.failures.should_fail(TaskId::map(idx), attempt) {
        return Err(Error::MapReduce(format!(
            "injected failure: map {idx} attempt {attempt}"
        )));
    }
    if let Some(ms) = spec.failures.delay_for(TaskId::map(idx), attempt) {
        // Injected straggler: dawdle before doing any work so the
        // speculation scan has something to race.
        std::thread::sleep(Duration::from_millis(ms));
    }

    let map_only = spec.n_reduces == 0;
    let n_buckets = spec.n_reduces.max(1);
    let block_path = spec.block_processor.is_some() && !map_only;
    // One bucket when the whole block is processed at once (map-only
    // serialization order, or the BlockProcessor's input block).
    let n_emit_buckets = if map_only || block_path { 1 } else { n_buckets };
    let mut buckets: Vec<RecordBuf> = (0..n_emit_buckets).map(|_| RecordBuf::new()).collect();
    // Task-local counter accumulation (flushed once below).
    let mut in_records = 0u64;
    let mut out_records = 0u64;
    let mut out_bytes = 0u64;
    {
        // Multi-input jobs route each split to its tagged input's mapper.
        let mapper = match spec.tagged_inputs.get(split.source as usize) {
            Some(ti) => &ti.mapper,
            None => &spec.mapper,
        };
        let partitioner = &spec.partitioner;
        let mut emit = |k: &[u8], v: &[u8]| {
            let p = if n_emit_buckets == 1 {
                0
            } else {
                partitioner.partition(k, n_buckets).min(n_buckets - 1)
            };
            out_bytes += (k.len() + v.len()) as u64;
            out_records += 1;
            buckets[p as usize].push(k, v);
        };
        match spec.input_format {
            InputFormat::RowRange => {
                for row in split.offset..split.offset + split.len {
                    mapper.map(&row.to_be_bytes(), &[], &mut emit);
                    in_records += 1;
                }
            }
            fmt => {
                in_records += read_records(&*dfs, split, fmt, &mut |k, v| {
                    mapper.map(k, v, &mut emit)
                })?;
            }
        }
    }
    let mut flush = vec![(counters::MAP_INPUT_RECORDS, in_records)];
    if out_records > 0 {
        flush.push((counters::MAP_OUTPUT_BYTES, out_bytes));
        flush.push((counters::MAP_OUTPUT_RECORDS, out_records));
    }
    counters.add_many(&flush);

    if map_only {
        // Map-only jobs (Teragen) write their emissions straight to the
        // output directory in emission order via the commit protocol.
        let records = buckets.into_iter().next().unwrap();
        let mut out = Vec::with_capacity(records.payload_bytes() as usize);
        for (k, v) in records.iter() {
            spec.output_format.write_record(&mut out, k, v);
        }
        let attempt_dir = format!("{}/_temporary/attempt_m_{idx:05}_{attempt}", spec.output_dir);
        dfs.mkdirs(&attempt_dir)?;
        let attempt_file = format!("{attempt_dir}/part-m-{idx:05}");
        let final_file = format!("{}/part-m-{idx:05}", spec.output_dir);
        dfs.create(&attempt_file, &out)?;
        stretch_for_mips(t_work, mips);
        commit_rename(&*dfs, &attempt_file, &final_file)?;
        return Ok(());
    }

    if block_path {
        // Whole-block map path: the BlockProcessor sorts + routes the
        // entire emitted block at once (Terasort kernel acceleration).
        let bp = spec.block_processor.as_ref().unwrap();
        let block = buckets.into_iter().next().unwrap();
        let parts = bp.process(block, n_buckets)?;
        if parts.len() != n_buckets as usize {
            return Err(Error::MapReduce(format!(
                "block processor '{}' returned {} partitions, expected {n_buckets}",
                bp.name(),
                parts.len()
            )));
        }
        // Pad before the segments become visible: a slow node's output
        // commits late, which is what speculation races against.
        stretch_for_mips(t_work, mips);
        for (p, records) in parts.into_iter().enumerate() {
            shuffle.put(Segment {
                map: idx,
                partition: p as u32,
                node,
                records,
            });
        }
        counters.add_many(&[
            (counters::MAP_SPILLS, n_buckets as u64),
            (counters::SHUFFLE_SEGMENTS, n_buckets as u64),
        ]);
        return Ok(());
    }

    // Map-side sort + spill (one segment per partition). The sort permutes
    // index entries decorated with u64 key prefixes — payload bytes never
    // move. All partitions are sorted BEFORE the first commit: slow-start
    // reduces see map output per cell (`try_fetch`), so the commit must be
    // all-or-nothing per attempt — a sort panic on a later bucket must not
    // leave this attempt's earlier segments visible.
    //
    // With a combiner (aggregating query plans), each sorted run is folded
    // per key before the segment commits: the shuffle then carries one
    // partial per (map, key) instead of one record per input row.
    let combiner = spec.combiner.as_deref().filter(|_| combiner_enabled());
    let mut combine_in = 0u64;
    let mut combine_out = 0u64;
    let mut segments = Vec::with_capacity(n_buckets as usize);
    for (p, mut records) in buckets.into_iter().enumerate() {
        records.sort_by_key();
        if let Some(c) = combiner {
            let combined = crate::mapreduce::recordbuf::combine_sorted(&records, c);
            combine_in += records.len() as u64;
            combine_out += combined.len() as u64;
            records = combined;
        }
        segments.push(Segment {
            map: idx,
            partition: p as u32,
            node,
            records,
        });
    }
    // As above: pad before the all-or-nothing segment commit.
    stretch_for_mips(t_work, mips);
    for seg in segments {
        shuffle.put(seg);
    }
    let mut flush = vec![
        (counters::MAP_SPILLS, n_buckets as u64),
        (counters::SHUFFLE_SEGMENTS, n_buckets as u64),
    ];
    if combiner.is_some() {
        flush.push((counters::COMBINE_INPUT_RECORDS, combine_in));
        flush.push((counters::COMBINE_OUTPUT_RECORDS, combine_out));
    }
    counters.add_many(&flush);
    Ok(())
}

/// The `HPCW_COMBINER` knob: on by default, `0`/`off`/`false` disables
/// map-side combining globally (bench baselines, parity debugging).
fn combiner_enabled() -> bool {
    !matches!(
        std::env::var("HPCW_COMBINER").as_deref(),
        Ok("0") | Ok("off") | Ok("false")
    )
}

/// Arguments of one reduce task attempt. `cancel: Some(_)` puts the fetch
/// phase in slow-start mode: poll [`ShuffleStore::try_fetch`] per map cell
/// until the partition's column is complete (bailing out if the scheduler
/// cancels the job); `None` is the barriered baseline's all-at-once fetch.
struct ReduceTaskArgs {
    r: u32,
    attempt: u32,
    n_maps: u32,
    /// The host node's MIPS tier (heterogeneity wall-clock model).
    mips: u64,
    spec: Arc<JobSpec>,
    shuffle: Arc<ShuffleStore>,
    counters: Arc<Counters>,
    dfs: Arc<dyn Dfs>,
    tmp_root: String,
    cancel: Option<Arc<AtomicBool>>,
}

/// One reduce task attempt.
///
/// The shuffle hands back `Arc`-shared segments (no copies); the k-way
/// merge yields `(segment, record)` indices; grouping and reduction read
/// keys and values as borrowed slices straight out of the segment arenas.
fn run_reduce_task(args: ReduceTaskArgs) -> Result<()> {
    let ReduceTaskArgs {
        r,
        attempt,
        n_maps,
        mips,
        spec,
        shuffle,
        counters,
        dfs,
        tmp_root,
        cancel,
    } = args;
    counters.add(counters::TASKS_LAUNCHED, 1);
    if spec.failures.should_fail(TaskId::reduce(r), attempt) {
        return Err(Error::MapReduce(format!(
            "injected failure: reduce {r} attempt {attempt}"
        )));
    }
    if let Some(ms) = spec.failures.delay_for(TaskId::reduce(r), attempt) {
        std::thread::sleep(Duration::from_millis(ms));
    }

    let segments: Vec<Arc<Segment>> = match cancel {
        // Slow-start: fetch each map's segment the moment it commits,
        // concurrently with the remaining maps.
        Some(cancel) => {
            let mut slots: Vec<Option<Arc<Segment>>> = (0..n_maps).map(|_| None).collect();
            let mut missing = n_maps as usize;
            let mut prefetched = 0u64;
            while missing > 0 {
                if cancel.load(Ordering::Relaxed) {
                    return Err(Error::MapReduce(format!(
                        "reduce {r} cancelled: job failed while waiting for map output"
                    )));
                }
                for (m, slot) in slots.iter_mut().enumerate() {
                    if slot.is_none() {
                        if let Some(s) = shuffle.try_fetch(m as u32, r) {
                            *slot = Some(s);
                            missing -= 1;
                        }
                    }
                }
                if missing > 0 {
                    // Still waiting on uncommitted maps: everything fetched
                    // so far arrived ahead of the last map commit.
                    prefetched = (n_maps as usize - missing) as u64;
                    std::thread::sleep(FETCH_POLL);
                }
            }
            if prefetched > 0 {
                counters.add(counters::SHUFFLE_SEGMENTS_PREFETCHED, prefetched);
            }
            slots.into_iter().map(|s| s.unwrap()).collect()
        }
        None => shuffle.fetch_partition(r, n_maps)?,
    };
    // The heterogeneity clock starts after the fetch: waiting on other
    // nodes' maps is not this node's work.
    let t_work = Instant::now();
    let shuffle_bytes = segments.iter().map(|s| s.bytes()).sum::<u64>();
    let order = merge_segments(&segments);
    counters.add_many(&[
        (counters::SHUFFLE_BYTES, shuffle_bytes),
        (counters::REDUCE_INPUT_RECORDS, order.len() as u64),
    ]);

    // Group by key, reduce, serialize. Keys and values are borrowed from
    // the shared segments for the whole pass. `reduce_limit` (ORDER BY
    // ... LIMIT) caps the records serialized per attempt — counted
    // task-locally, so retries and speculative twins each start from
    // zero and stay correct.
    let mut out = Vec::new();
    let mut out_records = 0u64;
    {
        let mut i = 0usize;
        while i < order.len() {
            if spec.reduce_limit.is_some_and(|l| out_records >= l) {
                break;
            }
            let (s0, r0) = order[i];
            let key = segments[s0 as usize].records.key(r0 as usize);
            let mut j = i + 1;
            while j < order.len() {
                let (s1, r1) = order[j];
                if segments[s1 as usize].records.key(r1 as usize) != key {
                    break;
                }
                j += 1;
            }
            let mut values = order[i..j]
                .iter()
                .map(|&(s, rec)| segments[s as usize].records.value(rec as usize));
            let mut emit = |k: &[u8], v: &[u8]| {
                if spec.reduce_limit.is_some_and(|l| out_records >= l) {
                    return;
                }
                out_records += 1;
                spec.output_format.write_record(&mut out, k, v);
            };
            spec.reducer.reduce(key, &mut values, &mut emit);
            i = j;
        }
    }
    counters.add_many(&[
        (counters::REDUCE_OUTPUT_RECORDS, out_records),
        (counters::REDUCE_OUTPUT_BYTES, out.len() as u64),
    ]);

    // Commit protocol: write the attempt file, then rename into place.
    // The heterogeneity pad lands before the rename so a slow node's
    // commit is what arrives late.
    let attempt_dir = format!("{tmp_root}/attempt_r_{r:05}_{attempt}");
    dfs.mkdirs(&attempt_dir)?;
    let attempt_file = format!("{attempt_dir}/part-r-{r:05}");
    dfs.create(&attempt_file, &out)?;
    stretch_for_mips(t_work, mips);
    let final_file = format!("{}/part-r-{r:05}", spec.output_dir);
    commit_rename(&*dfs, &attempt_file, &final_file)?;
    Ok(())
}

/// Heterogeneity wall-clock model (CloudSim MIPS tiers): work on a node
/// slower than the reference tier takes proportionally longer. The real
/// computation runs at native speed and the speed deficit is padded with
/// sleep afterwards, so output bytes are identical under any MIPS layout
/// — only the timeline changes. Capped so a mis-profiled node cannot
/// hang a test run.
fn stretch_for_mips(started: Instant, mips: u64) {
    let mips = mips.max(1);
    if mips >= crate::scenario::REFERENCE_MIPS {
        return;
    }
    let factor = crate::scenario::REFERENCE_MIPS as f64 / mips as f64 - 1.0;
    let pad = (started.elapsed().as_secs_f64() * factor).min(10.0);
    if pad > 0.0 {
        std::thread::sleep(Duration::from_secs_f64(pad));
    }
}

/// First-commit-wins rename: when a speculative twin (or a re-run racing
/// a zombie) already renamed its identical output into place, this
/// attempt's commit is a clean no-op instead of a clobber error.
fn commit_rename(dfs: &dyn Dfs, attempt_file: &str, final_file: &str) -> Result<()> {
    if dfs.exists(final_file) {
        return Ok(());
    }
    match dfs.rename(attempt_file, final_file) {
        Ok(()) => Ok(()),
        Err(_) if dfs.exists(final_file) => Ok(()),
        Err(e) => Err(e),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::NodeId;
    use crate::config::StackConfig;
    use crate::lustre::LustreFs;
    use crate::mapreduce::{FailurePlan, HashPartitioner, Mapper, OutputFormat, Reducer};
    use crate::mapreduce::task::TaskId;
    use crate::metrics::Metrics;
    use crate::util::ids::IdGen;

    struct WordSplit;
    impl Mapper for WordSplit {
        fn map(&self, _k: &[u8], v: &[u8], emit: &mut dyn FnMut(&[u8], &[u8])) {
            for w in v.split(|&b| b == b' ').filter(|w| !w.is_empty()) {
                emit(w, b"1");
            }
        }
    }

    struct CountReducer;
    impl Reducer for CountReducer {
        fn reduce(
            &self,
            key: &[u8],
            values: &mut dyn Iterator<Item = &[u8]>,
            emit: &mut dyn FnMut(&[u8], &[u8]),
        ) {
            let n = values.count();
            emit(key, n.to_string().as_bytes());
        }
    }

    fn stack() -> (StackConfig, Arc<LustreFs>, DynamicCluster, Pool) {
        let cfg = StackConfig::tiny();
        let fs = Arc::new(LustreFs::new(&cfg.lustre, &cfg.cluster));
        let nodes: Vec<NodeId> = (0..6).map(NodeId).collect();
        let dc = DynamicCluster::build(
            &cfg,
            &nodes,
            &*fs,
            Arc::new(IdGen::default()),
            Arc::new(Metrics::new()),
            "mr-test",
            Micros::ZERO,
        )
        .unwrap();
        (cfg, fs, dc, Pool::new(4))
    }

    fn wordcount_spec(input: &str, output: &str) -> JobSpec {
        let mut spec = JobSpec::identity("wordcount", input, output, 3);
        spec.input_format = InputFormat::Lines;
        spec.output_format = OutputFormat::TextKv;
        spec.split_bytes = 32;
        spec.mapper = Arc::new(WordSplit);
        spec.reducer = Arc::new(CountReducer);
        spec.partitioner = Arc::new(HashPartitioner);
        spec
    }

    #[test]
    fn wordcount_end_to_end() {
        let (cfg, fs, mut dc, pool) = stack();
        fs.mkdirs("/lustre/scratch/wc-in").unwrap();
        fs.create(
            "/lustre/scratch/wc-in/f1",
            b"the quick brown fox\nthe lazy dog\nthe end",
        )
        .unwrap();
        let spec = Arc::new(wordcount_spec("/lustre/scratch/wc-in", "/lustre/scratch/wc-out"));
        let mut engine = MrEngine::new(
            &mut dc,
            fs.clone(),
            &pool,
            cfg.yarn.map_memory_mb,
            cfg.yarn.reduce_memory_mb,
        );
        let outcome = engine.run(Arc::clone(&spec), "alice", Micros::ZERO).unwrap();
        assert!(outcome.maps >= 2, "small splits → multiple maps");
        assert_eq!(outcome.reduces, 3);

        // Collect all output lines and check counts.
        let mut text = String::new();
        for f in &outcome.output_files {
            text.push_str(&String::from_utf8(fs.read(f).unwrap()).unwrap());
        }
        let mut the_count = None;
        for line in text.lines() {
            let (w, n) = line.split_once('\t').unwrap();
            if w == "the" {
                the_count = Some(n.to_string());
            }
        }
        assert_eq!(the_count.as_deref(), Some("3"));
        assert!(fs.exists("/lustre/scratch/wc-out/_SUCCESS"));
        assert!(!fs.exists("/lustre/scratch/wc-out/_temporary"));
        // History recorded.
        assert_eq!(dc.jhs.count(), 1);
        dc.rm.check_invariants().unwrap();
    }

    #[test]
    fn existing_output_dir_rejected() {
        let (cfg, fs, mut dc, pool) = stack();
        fs.mkdirs("/lustre/scratch/exists").unwrap();
        fs.mkdirs("/lustre/scratch/in2").unwrap();
        fs.create("/lustre/scratch/in2/f", b"x").unwrap();
        let spec = Arc::new(wordcount_spec("/lustre/scratch/in2", "/lustre/scratch/exists"));
        let mut engine =
            MrEngine::new(&mut dc, fs, &pool, cfg.yarn.map_memory_mb, cfg.yarn.reduce_memory_mb);
        assert!(engine.run(spec, "u", Micros::ZERO).is_err());
    }

    #[test]
    fn injected_map_failure_retries_and_succeeds() {
        let (cfg, fs, mut dc, pool) = stack();
        fs.mkdirs("/lustre/scratch/in3").unwrap();
        fs.create("/lustre/scratch/in3/f", b"a b c d e f").unwrap();
        let mut spec = wordcount_spec("/lustre/scratch/in3", "/lustre/scratch/out3");
        spec.split_bytes = 1024;
        spec.failures = FailurePlan::none().fail_attempt(TaskId::map(0), 0);
        let spec = Arc::new(spec);
        let mut engine = MrEngine::new(
            &mut dc,
            fs.clone(),
            &pool,
            cfg.yarn.map_memory_mb,
            cfg.yarn.reduce_memory_mb,
        );
        let outcome = engine.run(spec, "u", Micros::ZERO).unwrap();
        assert_eq!(outcome.counters.get(counters::TASKS_FAILED), 1);
        assert!(fs.exists("/lustre/scratch/out3/_SUCCESS"));
        dc.rm.check_invariants().unwrap();
        // NM logs include the failed container's log.
        let pool_panics = pool.panic_count();
        assert_eq!(pool_panics, 0, "failures are Results, not panics");
    }

    #[test]
    fn repeated_failures_fail_the_job() {
        let (cfg, fs, mut dc, pool) = stack();
        fs.mkdirs("/lustre/scratch/in4").unwrap();
        fs.create("/lustre/scratch/in4/f", b"words here").unwrap();
        let mut spec = wordcount_spec("/lustre/scratch/in4", "/lustre/scratch/out4");
        spec.split_bytes = 1024;
        let mut failures = FailurePlan::none();
        for a in 0..MAX_ATTEMPTS {
            failures = failures.fail_attempt(TaskId::map(0), a);
        }
        spec.failures = failures;
        let spec = Arc::new(spec);
        let mut engine =
            MrEngine::new(&mut dc, fs, &pool, cfg.yarn.map_memory_mb, cfg.yarn.reduce_memory_mb);
        let err = engine.run(spec, "u", Micros::ZERO).unwrap_err();
        assert!(err.to_string().contains("failed 4 attempts"), "{err}");
        // App recorded as failed; resources all released.
        dc.rm.check_invariants().unwrap();
        let (_, used) = dc.rm.cluster_resources();
        assert_eq!(used.mem_mb, 0);
    }

    #[test]
    fn reduce_failure_retries() {
        let (cfg, fs, mut dc, pool) = stack();
        fs.mkdirs("/lustre/scratch/in5").unwrap();
        fs.create("/lustre/scratch/in5/f", b"k1 k2 k1").unwrap();
        let mut spec = wordcount_spec("/lustre/scratch/in5", "/lustre/scratch/out5");
        spec.split_bytes = 1024;
        spec.failures = FailurePlan::none().fail_attempt(TaskId::reduce(1), 0);
        let spec = Arc::new(spec);
        let mut engine = MrEngine::new(
            &mut dc,
            fs.clone(),
            &pool,
            cfg.yarn.map_memory_mb,
            cfg.yarn.reduce_memory_mb,
        );
        let outcome = engine.run(spec, "u", Micros::ZERO).unwrap();
        assert_eq!(outcome.counters.get(counters::TASKS_FAILED), 1);
        assert!(fs.exists("/lustre/scratch/out5/_SUCCESS"));
    }

    #[test]
    fn barriered_mode_still_works() {
        let (cfg, fs, mut dc, pool) = stack();
        fs.mkdirs("/lustre/scratch/in-b").unwrap();
        fs.create("/lustre/scratch/in-b/f", b"x y x z y x").unwrap();
        let spec = Arc::new(wordcount_spec("/lustre/scratch/in-b", "/lustre/scratch/out-b"));
        let mut engine = MrEngine::new(
            &mut dc,
            fs.clone(),
            &pool,
            cfg.yarn.map_memory_mb,
            cfg.yarn.reduce_memory_mb,
        )
        .with_mode(SchedMode::Barriered);
        let outcome = engine.run(spec, "u", Micros::ZERO).unwrap();
        assert!(fs.exists("/lustre/scratch/out-b/_SUCCESS"));
        // Barriered reduces see the full map count at launch — no overlap.
        assert_eq!(
            outcome.counters.get(counters::MAPS_AT_FIRST_REDUCE),
            outcome.maps as u64
        );
        assert_eq!(outcome.phases.overlap_s(), 0.0);
        dc.rm.check_invariants().unwrap();
    }

    /// The slow-start acceptance: with more maps than the pool is wide,
    /// the first reduce launches before the last map commits, observable
    /// through the counters and the phase marks.
    #[test]
    fn slowstart_launches_reduces_before_last_map_commit() {
        let (cfg, fs, mut dc, pool) = stack();
        fs.mkdirs("/lustre/scratch/ss-in").unwrap();
        // 20 splits of one line each (split_bytes = 32 over ~640 bytes).
        let mut text = Vec::new();
        for i in 0..20 {
            text.extend_from_slice(format!("alpha bravo w{i:02} charlie del\n").as_bytes());
        }
        fs.create("/lustre/scratch/ss-in/f", &text).unwrap();
        let spec = Arc::new(wordcount_spec("/lustre/scratch/ss-in", "/lustre/scratch/ss-out"));
        let mut engine = MrEngine::new(
            &mut dc,
            fs.clone(),
            &pool,
            cfg.yarn.map_memory_mb,
            cfg.yarn.reduce_memory_mb,
        )
        .with_slowstart(0.8);
        let outcome = engine.run(spec, "u", Micros::ZERO).unwrap();
        let n_maps = outcome.maps as u64;
        assert!(n_maps > pool.size() as u64, "need maps > pool width");
        assert_eq!(outcome.counters.get(counters::FIRST_REDUCE_LAUNCHED), 1);
        let at_first = outcome.counters.get(counters::MAPS_AT_FIRST_REDUCE);
        assert!(
            at_first >= (0.8 * n_maps as f64).ceil() as u64 && at_first < n_maps,
            "first reduce launched at {at_first} of {n_maps} maps"
        );
        assert!(
            outcome.phases.first_reduce_launch_s < outcome.phases.last_map_commit_s,
            "reduce launch must precede last map commit: {:?}",
            outcome.phases
        );
        // Every grant is accounted; one container per task attempt, and
        // every one of them ran to completion on some NM.
        assert_eq!(
            outcome.counters.get(counters::CONTAINERS_GRANTED),
            outcome.counters.get(counters::TASKS_LAUNCHED)
        );
        let completed: usize = dc.nms.values().map(|nm| nm.completed_containers()).sum();
        assert_eq!(
            completed as u64,
            outcome.counters.get(counters::TASKS_LAUNCHED),
            "per-completion container recycling completes one NM container per attempt"
        );
        // Release/re-grant churn: total grants exceed the concurrent
        // high-water mark once containers are recycled.
        let (granted_total, peak) = dc.rm.app_grant_stats(outcome.app).unwrap();
        assert_eq!(granted_total, outcome.counters.get(counters::TASKS_LAUNCHED) + 1);
        assert!(peak as u64 <= granted_total);
        dc.rm.check_invariants().unwrap();
    }

    /// Zero-grant is a bounded-backoff retry, not an instant hard error —
    /// and after the retries it is still a clean failure with all
    /// resources released.
    #[test]
    fn zero_grant_backs_off_then_fails_cleanly() {
        let (cfg, fs, mut dc, pool) = stack();
        fs.mkdirs("/lustre/scratch/zg-in").unwrap();
        fs.create("/lustre/scratch/zg-in/f", b"a b").unwrap();
        let spec = Arc::new(wordcount_spec("/lustre/scratch/zg-in", "/lustre/scratch/zg-out"));
        // Map containers larger than any NM can host → RM grants zero.
        let mut engine = MrEngine::new(
            &mut dc,
            fs.clone(),
            &pool,
            cfg.yarn.nm_resource_mb * 4,
            cfg.yarn.reduce_memory_mb,
        );
        let err = engine.run(spec, "u", Micros::ZERO).unwrap_err();
        assert!(err.to_string().contains("backoff retries"), "{err}");
        dc.rm.check_invariants().unwrap();
        let (_, used) = dc.rm.cluster_resources();
        assert_eq!(used.mem_mb, 0, "failed job must release everything");
    }

    /// Tentpole: lose the node holding a committed map's shuffle output
    /// mid-job. The engine must fence + invalidate the lost segments,
    /// re-execute exactly the affected maps, and still produce correct,
    /// complete output — with the loss visible in the counters.
    #[test]
    fn node_loss_invalidates_and_reexecutes_lost_maps() {
        let (cfg, fs, mut dc, pool) = stack();
        fs.mkdirs("/lustre/scratch/nl-in").unwrap();
        let mut text = Vec::new();
        for i in 0..12 {
            text.extend_from_slice(format!("tok{i:02} common words here\n").as_bytes());
        }
        fs.create("/lustre/scratch/nl-in/f", &text).unwrap();
        let spec = Arc::new(wordcount_spec("/lustre/scratch/nl-in", "/lustre/scratch/nl-out"));
        // Once map 0 commits, crash whichever node holds its segments.
        let plan = ElasticPlan::new().at_maps(1, ElasticAction::FailMapHost(0));
        let mut engine = MrEngine::new(
            &mut dc,
            fs.clone(),
            &pool,
            cfg.yarn.map_memory_mb,
            cfg.yarn.reduce_memory_mb,
        )
        .with_plan(plan);
        let outcome = engine.run(Arc::clone(&spec), "u", Micros::ZERO).unwrap();
        assert_eq!(outcome.counters.get(counters::NODES_FAILED), 1);
        assert!(
            outcome.counters.get(counters::MAPS_INVALIDATED) >= 1,
            "map 0's committed output was on the crashed node"
        );
        assert!(fs.exists("/lustre/scratch/nl-out/_SUCCESS"));
        // Output is complete and correct despite the loss.
        let mut all = String::new();
        for f in &outcome.output_files {
            all.push_str(&String::from_utf8(fs.read(f).unwrap()).unwrap());
        }
        let common = all
            .lines()
            .find_map(|l| l.strip_prefix("common\t"))
            .expect("'common' key present");
        assert_eq!(common, "12");
        dc.rm.check_invariants().unwrap();
        let (_, used) = dc.rm.cluster_resources();
        assert_eq!(used.mem_mb, 0);
    }

    /// Speculative execution: an injected straggler gets a duplicate
    /// attempt once the rest of the phase commits; the duplicate wins and
    /// the job finishes long before the straggler's delay elapses alone.
    #[test]
    fn straggler_gets_speculative_duplicate_that_wins() {
        let (cfg, fs, mut dc, pool) = stack();
        fs.mkdirs("/lustre/scratch/sp-in").unwrap();
        let mut text = Vec::new();
        for i in 0..8 {
            text.extend_from_slice(format!("alpha beta w{i} gamma delta\n").as_bytes());
        }
        fs.create("/lustre/scratch/sp-in/f", &text).unwrap();
        let mut spec = wordcount_spec("/lustre/scratch/sp-in", "/lustre/scratch/sp-out");
        // Map 0's first attempt dawdles 2s; the speculative twin (a later
        // attempt, not covered by the delay injection) runs at full speed.
        spec.failures = FailurePlan::none().delay_attempt(TaskId::map(0), 0, 2_000);
        let spec = Arc::new(spec);
        let ecfg = crate::config::ElasticConfig {
            speculation: crate::config::SpeculationMode::Static,
            speculation_factor: 2.0,
            speculation_floor_ms: 20,
            ..Default::default()
        };
        let mut engine = MrEngine::new(
            &mut dc,
            fs.clone(),
            &pool,
            cfg.yarn.map_memory_mb,
            cfg.yarn.reduce_memory_mb,
        )
        .with_elastic_cfg(ecfg);
        let t0 = std::time::Instant::now();
        let outcome = engine.run(Arc::clone(&spec), "u", Micros::ZERO).unwrap();
        assert!(outcome.counters.get(counters::TASKS_SPECULATED) >= 1);
        assert_eq!(outcome.counters.get(counters::SPECULATIVE_WINS), 1);
        assert!(
            t0.elapsed() < Duration::from_millis(1_500),
            "speculation must beat the 2s straggler; took {:?}",
            t0.elapsed()
        );
        assert!(fs.exists("/lustre/scratch/sp-out/_SUCCESS"));
        // Output is still correct (first commit won; twins are identical).
        let mut all = String::new();
        for f in &outcome.output_files {
            all.push_str(&String::from_utf8(fs.read(f).unwrap()).unwrap());
        }
        let alpha = all.lines().find_map(|l| l.strip_prefix("alpha\t")).unwrap();
        assert_eq!(alpha, "8");
        dc.rm.check_invariants().unwrap();
    }

    /// Adaptive speculation: the same straggler rescue works when the
    /// threshold comes from the per-(node, shape) estimator — a cold cell
    /// falls back to the static global-mean rule, so the rescue fires
    /// either way — and every commit feeds the estimator.
    #[test]
    fn adaptive_speculation_rescues_straggler() {
        let (cfg, fs, mut dc, pool) = stack();
        fs.mkdirs("/lustre/scratch/ad-in").unwrap();
        let mut text = Vec::new();
        for i in 0..8 {
            text.extend_from_slice(format!("alpha beta w{i} gamma delta\n").as_bytes());
        }
        fs.create("/lustre/scratch/ad-in/f", &text).unwrap();
        let mut spec = wordcount_spec("/lustre/scratch/ad-in", "/lustre/scratch/ad-out");
        spec.failures = FailurePlan::none().delay_attempt(TaskId::map(0), 0, 2_000);
        let spec = Arc::new(spec);
        let ecfg = crate::config::ElasticConfig {
            speculation: crate::config::SpeculationMode::Adaptive,
            speculation_factor: 2.0,
            speculation_floor_ms: 20,
            ..Default::default()
        };
        let mut engine = MrEngine::new(
            &mut dc,
            fs.clone(),
            &pool,
            cfg.yarn.map_memory_mb,
            cfg.yarn.reduce_memory_mb,
        )
        .with_elastic_cfg(ecfg);
        let t0 = std::time::Instant::now();
        let outcome = engine.run(Arc::clone(&spec), "u", Micros::ZERO).unwrap();
        assert!(outcome.counters.get(counters::TASKS_SPECULATED) >= 1);
        // Each map and reduce commits exactly once → one estimator fold
        // per commit.
        assert_eq!(
            outcome.counters.get(counters::ESTIMATOR_UPDATES),
            (outcome.maps + outcome.reduces) as u64
        );
        assert!(
            t0.elapsed() < Duration::from_millis(1_500),
            "adaptive speculation must beat the 2s straggler; took {:?}",
            t0.elapsed()
        );
        let mut all = String::new();
        for f in &outcome.output_files {
            all.push_str(&String::from_utf8(fs.read(f).unwrap()).unwrap());
        }
        let alpha = all.lines().find_map(|l| l.strip_prefix("alpha\t")).unwrap();
        assert_eq!(alpha, "8");
        dc.rm.check_invariants().unwrap();
    }

    /// A heterogeneous MIPS profile changes only the timeline: the tiered
    /// run's output bytes are identical to the homogeneous run's, and the
    /// profile sticks in the RM registry for later jobs.
    #[test]
    fn hetero_profile_is_output_invariant() {
        let (cfg, fs, mut dc, pool) = stack();
        fs.mkdirs("/lustre/scratch/ht-in").unwrap();
        for i in 0..4 {
            fs.create(
                &format!("/lustre/scratch/ht-in/part-{i}"),
                format!("word{i} again maybe\n").as_bytes(),
            )
            .unwrap();
        }
        let read_all = |dir: &str| {
            let mut names: Vec<String> = fs
                .list(dir)
                .into_iter()
                .filter(|p| p.contains("/part-"))
                .collect();
            names.sort();
            let mut all = Vec::new();
            for n in names {
                all.extend(fs.read(&n).unwrap());
            }
            all
        };
        let profiles: [(&str, Vec<(u32, u64)>); 2] = [
            ("flat", Vec::new()),
            ("tiered", vec![(0, 250), (1, 250), (2, 2000)]),
        ];
        let mut outs = Vec::new();
        for (label, profile) in profiles {
            let mut spec = wordcount_spec(
                "/lustre/scratch/ht-in",
                &format!("/lustre/scratch/ht-out-{label}"),
            );
            spec.split_bytes = 1024; // one map per file
            let ecfg = crate::config::ElasticConfig {
                speculation: crate::config::SpeculationMode::Adaptive,
                node_mips: profile,
                ..Default::default()
            };
            let mut engine = MrEngine::new(
                &mut dc,
                fs.clone(),
                &pool,
                cfg.yarn.map_memory_mb,
                cfg.yarn.reduce_memory_mb,
            )
            .with_elastic_cfg(ecfg);
            engine.run(Arc::new(spec), "u", Micros::ZERO).unwrap();
            outs.push(read_all(&format!("/lustre/scratch/ht-out-{label}")));
        }
        assert_eq!(outs[0], outs[1], "MIPS tiers must not change output bytes");
        // The second run installed the profile into the RM registry.
        assert_eq!(dc.rm.node_mips(NodeId(0)), 250);
        assert_eq!(dc.rm.node_mips(NodeId(2)), 2000);
        assert_eq!(dc.rm.node_mips(NodeId(3)), crate::scenario::REFERENCE_MIPS);
        dc.rm.check_invariants().unwrap();
    }

    /// Locality-aware placement: with free capacity on the preferred
    /// nodes, every map with a residency hint places node-local, and the
    /// tier counters account for every map launched.
    #[test]
    fn locality_counters_account_for_every_map() {
        let (cfg, fs, mut dc, pool) = stack();
        fs.mkdirs("/lustre/scratch/lc-in").unwrap();
        for i in 0..4 {
            fs.create(
                &format!("/lustre/scratch/lc-in/part-{i}"),
                format!("word{i} again maybe\n").as_bytes(),
            )
            .unwrap();
        }
        let mut spec = wordcount_spec("/lustre/scratch/lc-in", "/lustre/scratch/lc-out");
        spec.split_bytes = 1024; // one map per file
        let spec = Arc::new(spec);
        let mut engine = MrEngine::new(
            &mut dc,
            fs.clone(),
            &pool,
            cfg.yarn.map_memory_mb,
            cfg.yarn.reduce_memory_mb,
        );
        let outcome = engine.run(spec, "u", Micros::ZERO).unwrap();
        let local = outcome.counters.get(counters::LOCAL_MAPS);
        let rack = outcome.counters.get(counters::RACK_MAPS);
        let other = outcome.counters.get(counters::OTHER_MAPS);
        // Every map attempt got exactly one tiered grant.
        assert!(local + rack + other >= outcome.maps as u64);
        // A fresh cluster always has room on the first map's anchor node,
        // and with residency hints nothing should degrade past rack tier.
        assert!(local >= 1, "local={local} rack={rack} other={other}");
        assert_eq!(other, 0, "local={local} rack={rack} other={other}");
        dc.rm.check_invariants().unwrap();
    }

    /// Map-side combining: a sum job run with and without the combiner
    /// produces byte-identical output while the combined run ships
    /// strictly fewer shuffle bytes.
    #[test]
    fn combiner_cuts_shuffle_bytes_with_identical_output() {
        struct SumReducer;
        impl Reducer for SumReducer {
            fn reduce(
                &self,
                key: &[u8],
                values: &mut dyn Iterator<Item = &[u8]>,
                emit: &mut dyn FnMut(&[u8], &[u8]),
            ) {
                let total: u64 = values
                    .filter_map(|v| std::str::from_utf8(v).ok())
                    .filter_map(|s| s.parse::<u64>().ok())
                    .sum();
                emit(key, total.to_string().as_bytes());
            }
        }
        let (cfg, fs, mut dc, pool) = stack();
        fs.mkdirs("/lustre/scratch/cb-in").unwrap();
        let mut text = Vec::new();
        for i in 0..200 {
            text.extend_from_slice(format!("word{} again again\n", i % 5).as_bytes());
        }
        fs.create("/lustre/scratch/cb-in/f", &text).unwrap();
        let read_all = |dir: &str| {
            let mut names: Vec<String> = fs
                .list(dir)
                .into_iter()
                .filter(|p| p.contains("/part-"))
                .collect();
            names.sort();
            let mut all = Vec::new();
            for n in names {
                all.extend(fs.read(&n).unwrap());
            }
            all
        };
        let mut outcomes = Vec::new();
        for (label, with_combiner) in [("off", false), ("on", true)] {
            let mut spec = wordcount_spec(
                "/lustre/scratch/cb-in",
                &format!("/lustre/scratch/cb-out-{label}"),
            );
            spec.split_bytes = 256; // several maps -> several spill runs
            spec.reducer = Arc::new(SumReducer);
            if with_combiner {
                spec.combiner = Some(Arc::new(SumReducer));
            }
            let mut engine = MrEngine::new(
                &mut dc,
                fs.clone(),
                &pool,
                cfg.yarn.map_memory_mb,
                cfg.yarn.reduce_memory_mb,
            );
            let outcome = engine.run(Arc::new(spec), "u", Micros::ZERO).unwrap();
            outcomes.push(outcome);
        }
        let (off, on) = (&outcomes[0], &outcomes[1]);
        assert_eq!(
            read_all("/lustre/scratch/cb-out-off"),
            read_all("/lustre/scratch/cb-out-on"),
            "combiner must not change the result"
        );
        let sb_off = off.counters.get(counters::SHUFFLE_BYTES);
        let sb_on = on.counters.get(counters::SHUFFLE_BYTES);
        assert!(
            sb_on < sb_off,
            "combiner must cut shuffle bytes: on={sb_on} off={sb_off}"
        );
        assert!(on.counters.get(counters::COMBINE_INPUT_RECORDS) > 0);
        assert!(
            on.counters.get(counters::COMBINE_OUTPUT_RECORDS)
                < on.counters.get(counters::COMBINE_INPUT_RECORDS)
        );
        assert_eq!(off.counters.get(counters::COMBINE_INPUT_RECORDS), 0);
    }

    /// Multi-input jobs: every tagged input's splits run that input's
    /// mapper, and the reduce sees both streams.
    #[test]
    fn tagged_inputs_route_to_their_mappers() {
        struct TagMapper(u8);
        impl Mapper for TagMapper {
            fn map(&self, _k: &[u8], v: &[u8], emit: &mut dyn FnMut(&[u8], &[u8])) {
                for w in v.split(|&b| b == b' ').filter(|w| !w.is_empty()) {
                    emit(w, &[self.0]);
                }
            }
        }
        struct ConcatReducer;
        impl Reducer for ConcatReducer {
            fn reduce(
                &self,
                key: &[u8],
                values: &mut dyn Iterator<Item = &[u8]>,
                emit: &mut dyn FnMut(&[u8], &[u8]),
            ) {
                let mut tags: Vec<u8> = values.map(|v| v[0]).collect();
                tags.sort_unstable();
                emit(key, &tags);
            }
        }
        let (cfg, fs, mut dc, pool) = stack();
        fs.mkdirs("/lustre/scratch/ti-a").unwrap();
        fs.mkdirs("/lustre/scratch/ti-b").unwrap();
        fs.create("/lustre/scratch/ti-a/f", b"both left").unwrap();
        fs.create("/lustre/scratch/ti-b/f", b"both right").unwrap();
        let mut spec = JobSpec::identity("tagged", "", "/lustre/scratch/ti-out", 2);
        spec.input_format = InputFormat::Lines;
        spec.output_format = OutputFormat::TextKv;
        spec.split_bytes = 1024;
        spec.tagged_inputs = vec![
            crate::mapreduce::TaggedInput {
                dir: "/lustre/scratch/ti-a".into(),
                mapper: Arc::new(TagMapper(b'A')),
            },
            crate::mapreduce::TaggedInput {
                dir: "/lustre/scratch/ti-b".into(),
                mapper: Arc::new(TagMapper(b'B')),
            },
        ];
        spec.reducer = Arc::new(ConcatReducer);
        let mut engine = MrEngine::new(
            &mut dc,
            fs.clone(),
            &pool,
            cfg.yarn.map_memory_mb,
            cfg.yarn.reduce_memory_mb,
        );
        let outcome = engine.run(Arc::new(spec), "u", Micros::ZERO).unwrap();
        let mut text = String::new();
        for f in &outcome.output_files {
            text.push_str(&String::from_utf8(fs.read(f).unwrap()).unwrap());
        }
        let mut rows: Vec<&str> = text.lines().collect();
        rows.sort_unstable();
        assert_eq!(rows, vec!["both\tAB", "left\tA", "right\tB"]);
    }

    /// `reduce_limit` caps serialized output per reduce attempt.
    #[test]
    fn reduce_limit_truncates_output() {
        let (cfg, fs, mut dc, pool) = stack();
        fs.mkdirs("/lustre/scratch/rl-in").unwrap();
        fs.create("/lustre/scratch/rl-in/f", b"a b c d e f g h").unwrap();
        let mut spec = wordcount_spec("/lustre/scratch/rl-in", "/lustre/scratch/rl-out");
        spec.split_bytes = 1024;
        spec.n_reduces = 1;
        spec.reduce_limit = Some(3);
        let mut engine = MrEngine::new(
            &mut dc,
            fs.clone(),
            &pool,
            cfg.yarn.map_memory_mb,
            cfg.yarn.reduce_memory_mb,
        );
        let outcome = engine.run(Arc::new(spec), "u", Micros::ZERO).unwrap();
        assert_eq!(outcome.counters.get(counters::REDUCE_OUTPUT_RECORDS), 3);
        let text = String::from_utf8(fs.read(&outcome.output_files[0]).unwrap()).unwrap();
        assert_eq!(text.lines().count(), 3);
    }

    /// A failing job with slow-start reduces in flight must cancel them
    /// (not leave pool workers polling forever) and release containers.
    #[test]
    fn map_exhaustion_cancels_inflight_reduces() {
        let (cfg, fs, mut dc, pool) = stack();
        fs.mkdirs("/lustre/scratch/cx-in").unwrap();
        let mut text = Vec::new();
        for i in 0..10 {
            text.extend_from_slice(format!("word{i} again maybe here yes\n").as_bytes());
        }
        fs.create("/lustre/scratch/cx-in/f", &text).unwrap();
        let mut spec = wordcount_spec("/lustre/scratch/cx-in", "/lustre/scratch/cx-out");
        // Map 5 fails every attempt; with slow-start 0.1 reduces launch
        // early and then must be cancelled when the job dies.
        let mut failures = FailurePlan::none();
        for a in 0..MAX_ATTEMPTS {
            failures = failures.fail_attempt(TaskId::map(5), a);
        }
        spec.failures = failures;
        let spec = Arc::new(spec);
        let mut engine = MrEngine::new(
            &mut dc,
            fs.clone(),
            &pool,
            cfg.yarn.map_memory_mb,
            cfg.yarn.reduce_memory_mb,
        )
        .with_slowstart(0.1);
        let err = engine.run(spec, "u", Micros::ZERO).unwrap_err();
        assert!(err.to_string().contains("failed 4 attempts"), "{err}");
        dc.rm.check_invariants().unwrap();
        let (_, used) = dc.rm.cluster_resources();
        assert_eq!(used.mem_mb, 0);
        // The pool is healthy for the next job: run one to completion.
        let spec2 = Arc::new(wordcount_spec("/lustre/scratch/cx-in", "/lustre/scratch/cx-out2"));
        let mut engine2 = MrEngine::new(
            &mut dc,
            fs.clone(),
            &pool,
            cfg.yarn.map_memory_mb,
            cfg.yarn.reduce_memory_mb,
        );
        engine2.run(spec2, "u", Micros::ZERO).unwrap();
        assert!(fs.exists("/lustre/scratch/cx-out2/_SUCCESS"));
    }
}
