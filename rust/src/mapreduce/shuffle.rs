//! The shuffle store: map-output segments keyed by `(map, partition)`.
//!
//! Stands in for the NM-local spill directories + the HTTP shuffle
//! handlers. Segments record the node that produced them so a node failure
//! invalidates exactly the segments Hadoop would lose (map re-execution),
//! and the exactly-once delivery invariant can be property-tested.
//!
//! Perf shape (the Terasort hot path):
//!
//! * the store is **partition-sharded** (`shard = partition % N`), so
//!   concurrent map spills and reduce fetches of different partitions
//!   never contend on one global lock;
//! * segments are stored behind `Arc` and [`ShuffleStore::fetch_partition`]
//!   hands out shared views — no record bytes are copied at fetch time;
//! * [`merge_segments`] is a cursor-based k-way merge over borrowed key
//!   slices: it allocates O(segments) heap entries plus the output index,
//!   never cloning keys or values.

use crate::cluster::NodeId;
use crate::error::{Error, Result};
use crate::mapreduce::recordbuf::RecordBuf;
use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::collections::{BTreeMap, BTreeSet};
use std::sync::{Arc, Mutex};

/// One spilled map-output segment (already sorted by key).
#[derive(Debug, Clone)]
pub struct Segment {
    pub map: u32,
    pub partition: u32,
    pub node: NodeId,
    /// Flat record storage, sorted by key.
    pub records: RecordBuf,
}

impl Segment {
    pub fn bytes(&self) -> u64 {
        self.records.payload_bytes()
    }
}

/// Default shard count; override with [`ShuffleStore::with_shards`] or the
/// `HPCW_SHUFFLE_SHARDS` environment variable.
pub const DEFAULT_SHUFFLE_SHARDS: usize = 16;

type Shard = Mutex<BTreeMap<(u32, u32), Arc<Segment>>>;

/// Thread-safe, partition-sharded shuffle store for one job.
#[derive(Debug)]
pub struct ShuffleStore {
    shards: Vec<Shard>,
    /// Nodes whose segments are fenced out: a node that failed mid-job
    /// stays banned for the life of the store, so an in-flight zombie
    /// attempt on the dead node can never overwrite a re-executed map's
    /// committed segment (the batch allocator never re-mints a failed
    /// node id).
    banned: Mutex<BTreeSet<NodeId>>,
}

impl Default for ShuffleStore {
    fn default() -> Self {
        ShuffleStore::new()
    }
}

impl ShuffleStore {
    /// Store with the default shard count (`HPCW_SHUFFLE_SHARDS` overrides).
    pub fn new() -> Self {
        let n = std::env::var("HPCW_SHUFFLE_SHARDS")
            .ok()
            .and_then(|s| s.parse::<usize>().ok())
            .unwrap_or(DEFAULT_SHUFFLE_SHARDS);
        ShuffleStore::with_shards(n)
    }

    /// Store with an explicit shard count (`n >= 1`).
    pub fn with_shards(n: usize) -> Self {
        ShuffleStore {
            shards: (0..n.max(1)).map(|_| Mutex::new(BTreeMap::new())).collect(),
            banned: Mutex::new(BTreeSet::new()),
        }
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    #[inline]
    fn shard_for(&self, partition: u32) -> &Shard {
        &self.shards[partition as usize % self.shards.len()]
    }

    /// Commit a map attempt's segment. Re-commits (speculative duplicate or
    /// re-run after failure) replace the previous segment — Hadoop's
    /// commit-wins-once semantics. A segment from a banned (failed) node
    /// is dropped on the floor; returns whether the segment was stored.
    pub fn put(&self, seg: Segment) -> bool {
        debug_assert!(seg.records.is_sorted_by_key(), "segment must be sorted");
        if self.banned.lock().unwrap().contains(&seg.node) {
            return false;
        }
        let mut g = self.shard_for(seg.partition).lock().unwrap();
        g.insert((seg.map, seg.partition), Arc::new(seg));
        true
    }

    /// Fetch all segments for one reduce partition, map order. Returns
    /// `Arc`-shared views of the committed segments — no per-record copies.
    pub fn fetch_partition(&self, partition: u32, n_maps: u32) -> Result<Vec<Arc<Segment>>> {
        let g = self.shard_for(partition).lock().unwrap();
        let mut out = Vec::with_capacity(n_maps as usize);
        for m in 0..n_maps {
            match g.get(&(m, partition)) {
                Some(s) => out.push(Arc::clone(s)),
                None => {
                    return Err(Error::MapReduce(format!(
                        "shuffle: missing segment map={m} partition={partition}"
                    )))
                }
            }
        }
        Ok(out)
    }

    /// Non-blocking per-cell visibility: the segment for `(map, partition)`
    /// if that map has committed, else `None`. Reduce slow-start polls this
    /// to fetch already-committed segments while the remaining maps are
    /// still running. Map tasks commit all their partitions together after
    /// the last sort (see `run_map_task`), so a visible cell always comes
    /// from an attempt that produced its full partition set.
    pub fn try_fetch(&self, map: u32, partition: u32) -> Option<Arc<Segment>> {
        let g = self.shard_for(partition).lock().unwrap();
        g.get(&(map, partition)).map(Arc::clone)
    }

    /// Drop every segment produced on a failed node; returns the map ids
    /// whose output was lost (they must re-run). The node is also banned:
    /// any commit from it arriving after this call is discarded, so a
    /// zombie attempt racing the invalidation cannot resurrect lost (or
    /// overwrite re-executed) segments.
    pub fn invalidate_node(&self, node: NodeId) -> Vec<u32> {
        self.banned.lock().unwrap().insert(node);
        let mut maps = Vec::new();
        for shard in &self.shards {
            let mut g = shard.lock().unwrap();
            let lost: Vec<(u32, u32)> = g
                .iter()
                .filter(|(_, s)| s.node == node)
                .map(|(&k, _)| k)
                .collect();
            for k in lost {
                maps.push(k.0);
                g.remove(&k);
            }
        }
        maps.sort_unstable();
        maps.dedup();
        maps
    }

    /// Total bytes held.
    pub fn total_bytes(&self) -> u64 {
        self.shards
            .iter()
            .map(|s| {
                s.lock()
                    .unwrap()
                    .values()
                    .map(|seg| seg.bytes())
                    .sum::<u64>()
            })
            .sum()
    }

    pub fn segment_count(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().len()).sum()
    }

    /// Exactly-once check: every (map, partition) cell present exactly once
    /// for the full matrix.
    pub fn verify_complete(&self, n_maps: u32, n_partitions: u32) -> Result<()> {
        let have = self.segment_count();
        if have != (n_maps as usize) * (n_partitions as usize) {
            return Err(Error::MapReduce(format!(
                "shuffle matrix {n_maps}×{n_partitions} has {have} cells"
            )));
        }
        Ok(())
    }
}

/// One merge cursor head: the current key of a segment. Ordered by
/// `(key, segment index)` so equal keys pop in map order — Hadoop's merge
/// stability guarantee.
struct Head<'a> {
    key: &'a [u8],
    seg: u32,
    rec: u32,
}

impl PartialEq for Head<'_> {
    fn eq(&self, other: &Self) -> bool {
        self.key == other.key && self.seg == other.seg
    }
}

impl Eq for Head<'_> {}

impl PartialOrd for Head<'_> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Head<'_> {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.key.cmp(other.key).then(self.seg.cmp(&other.seg))
    }
}

/// Cursor-based k-way merge of sorted segments: returns the merged order
/// as `(segment index, record index)` pairs, stable across segments in map
/// order for equal keys. Allocates the O(segments) heap and the output
/// index — no key or value bytes are cloned; callers read records through
/// the returned indices.
pub fn merge_segments(segments: &[Arc<Segment>]) -> Vec<(u32, u32)> {
    let total: usize = segments.iter().map(|s| s.records.len()).sum();
    let mut out = Vec::with_capacity(total);
    if segments.len() == 1 {
        out.extend((0..segments[0].records.len() as u32).map(|r| (0u32, r)));
        return out;
    }
    let mut heap: BinaryHeap<Reverse<Head<'_>>> = BinaryHeap::with_capacity(segments.len());
    for (si, s) in segments.iter().enumerate() {
        if !s.records.is_empty() {
            heap.push(Reverse(Head {
                key: s.records.key(0),
                seg: si as u32,
                rec: 0,
            }));
        }
    }
    while let Some(Reverse(h)) = heap.pop() {
        out.push((h.seg, h.rec));
        let next = h.rec as usize + 1;
        let s = &segments[h.seg as usize];
        if next < s.records.len() {
            heap.push(Reverse(Head {
                key: s.records.key(next),
                seg: h.seg,
                rec: next as u32,
            }));
        }
    }
    out
}

/// Materialize a merge into one `RecordBuf` (tests and tools; the reduce
/// path iterates [`merge_segments`]' index order without copying).
pub fn merge_to_recordbuf(segments: &[Arc<Segment>]) -> RecordBuf {
    let order = merge_segments(segments);
    let bytes: usize = segments.iter().map(|s| s.records.payload_bytes() as usize).sum();
    let mut out = RecordBuf::with_capacity(order.len(), bytes);
    for (s, r) in order {
        out.push_from(&segments[s as usize].records, r as usize);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::props;

    fn seg(map: u32, part: u32, keys: &[u8]) -> Segment {
        Segment {
            map,
            partition: part,
            node: NodeId(map),
            records: RecordBuf::from_pairs(keys.iter().map(|&k| (vec![k], vec![k, k]))),
        }
    }

    #[test]
    fn put_fetch_round_trip() {
        let st = ShuffleStore::new();
        st.put(seg(0, 0, &[1, 3]));
        st.put(seg(1, 0, &[2]));
        let got = st.fetch_partition(0, 2).unwrap();
        assert_eq!(got.len(), 2);
        assert!(st.fetch_partition(1, 2).is_err(), "missing partition 1");
    }

    #[test]
    fn fetch_shares_segments_without_copying() {
        // Zero-copy contract: two fetches of the same partition return the
        // same `Arc` allocation — the store never deep-clones a segment.
        let st = ShuffleStore::new();
        st.put(seg(0, 0, &[1, 2, 3]));
        let a = st.fetch_partition(0, 1).unwrap().remove(0);
        let b = st.fetch_partition(0, 1).unwrap().remove(0);
        assert!(Arc::ptr_eq(&a, &b), "fetch must hand out shared segments");
        // Store + two fetched handles.
        assert_eq!(Arc::strong_count(&a), 3);
    }

    #[test]
    fn try_fetch_sees_partial_commits() {
        // Per-map-commit visibility: cells appear one map at a time, and
        // the handed-out view shares the stored segment.
        let st = ShuffleStore::new();
        assert!(st.try_fetch(0, 0).is_none());
        st.put(seg(1, 0, &[2]));
        assert!(st.try_fetch(0, 0).is_none(), "map 0 not committed yet");
        let got = st.try_fetch(1, 0).unwrap();
        assert_eq!(got.records.key(0), &[2]);
        let again = st.try_fetch(1, 0).unwrap();
        assert!(Arc::ptr_eq(&got, &again));
        // fetch_partition still refuses the incomplete matrix.
        assert!(st.fetch_partition(0, 3).is_err());
        st.put(seg(0, 0, &[1]));
        st.put(seg(2, 0, &[3]));
        assert!((0..3).all(|m| st.try_fetch(m, 0).is_some()));
        assert_eq!(st.fetch_partition(0, 3).unwrap().len(), 3);
    }

    #[test]
    fn recommit_replaces() {
        let st = ShuffleStore::new();
        st.put(seg(0, 0, &[1]));
        st.put(seg(0, 0, &[9])); // speculative duplicate wins once
        let got = st.fetch_partition(0, 1).unwrap();
        assert_eq!(got[0].records.key(0), &[9]);
        assert_eq!(st.segment_count(), 1);
    }

    #[test]
    fn node_invalidation_names_lost_maps() {
        let st = ShuffleStore::new();
        st.put(seg(0, 0, &[1]));
        st.put(seg(0, 1, &[1]));
        st.put(seg(1, 0, &[2]));
        let lost = st.invalidate_node(NodeId(0));
        assert_eq!(lost, vec![0]);
        assert_eq!(st.segment_count(), 1);
        assert!(st.verify_complete(2, 2).is_err());
    }

    #[test]
    fn banned_node_commits_are_fenced_out() {
        // A zombie attempt on a failed node must never overwrite the
        // re-executed map's segment: after invalidation, puts from the
        // dead node are dropped.
        let st = ShuffleStore::new();
        assert!(st.put(seg(0, 0, &[1])));
        st.invalidate_node(NodeId(0));
        assert!(!st.put(seg(0, 0, &[9])), "zombie commit dropped");
        assert!(st.try_fetch(0, 0).is_none());
        // The re-run on a fresh node commits normally…
        let rerun = Segment {
            map: 0,
            partition: 0,
            node: NodeId(7),
            records: RecordBuf::from_pairs([(vec![5u8], vec![5, 5])]),
        };
        assert!(st.put(rerun));
        // …and a late zombie still cannot clobber it.
        assert!(!st.put(seg(0, 0, &[9])));
        assert_eq!(st.try_fetch(0, 0).unwrap().records.key(0), &[5]);
    }

    #[test]
    fn sharding_covers_all_partitions() {
        // More partitions than shards: routing must stay consistent.
        let st = ShuffleStore::with_shards(3);
        assert_eq!(st.n_shards(), 3);
        for p in 0..10u32 {
            st.put(seg(0, p, &[p as u8]));
        }
        for p in 0..10u32 {
            let got = st.fetch_partition(p, 1).unwrap();
            assert_eq!(got[0].records.key(0), &[p as u8]);
        }
        assert_eq!(st.segment_count(), 10);
        st.verify_complete(1, 10).unwrap();
    }

    #[test]
    fn merge_is_sorted_and_complete() {
        let a = seg(0, 0, &[1, 4, 7]);
        let b = seg(1, 0, &[2, 4, 9]);
        let segs = vec![Arc::new(a), Arc::new(b)];
        let merged = merge_to_recordbuf(&segs);
        let keys: Vec<u8> = merged.iter().map(|(k, _)| k[0]).collect();
        assert_eq!(keys, vec![1, 2, 4, 4, 7, 9]);
    }

    #[test]
    fn merge_stable_on_equal_keys() {
        // Equal keys come out in segment (map) order.
        let mk = |map: u32, val: &[u8]| Segment {
            map,
            partition: 0,
            node: NodeId(map),
            records: RecordBuf::from_pairs([(b"\x05".to_vec(), val.to_vec())]),
        };
        let segs = vec![
            Arc::new(mk(0, b"from-map0")),
            Arc::new(mk(1, b"from-map1")),
        ];
        let merged = merge_to_recordbuf(&segs);
        assert_eq!(merged.value(0), b"from-map0");
        assert_eq!(merged.value(1), b"from-map1");
    }

    #[test]
    fn merge_property_equals_flat_sort() {
        props(30, |g| {
            let n_segs = g.usize(1..6);
            let mut segs = Vec::new();
            let mut flat = Vec::new();
            for m in 0..n_segs {
                let mut keys: Vec<u8> =
                    (0..g.usize(0..20)).map(|_| g.u32(0..50) as u8).collect();
                keys.sort_unstable();
                flat.extend(keys.iter().copied());
                segs.push(Arc::new(seg(m as u32, 0, &keys)));
            }
            flat.sort_unstable();
            let merged = merge_to_recordbuf(&segs);
            let keys: Vec<u8> = merged.iter().map(|(k, _)| k[0]).collect();
            assert_eq!(keys, flat);
        });
    }

    /// Parity with the legacy pairs path: merge order, group boundaries,
    /// and stable equal-key ordering across segments all match a reference
    /// model built on `Vec<(Vec<u8>, Vec<u8>)>`.
    #[test]
    fn merge_parity_with_legacy_pairs_path() {
        props(40, |g| {
            let n_segs = g.usize(1..6);
            let mut segs: Vec<Arc<Segment>> = Vec::new();
            let mut legacy: Vec<(Vec<u8>, Vec<u8>)> = Vec::new();
            for m in 0..n_segs {
                let mut pairs: Vec<(Vec<u8>, Vec<u8>)> = (0..g.usize(0..25))
                    .map(|i| {
                        // Small key space → plenty of equal keys, within and
                        // across segments. Values carry (segment, seq).
                        let key = vec![g.u32(0..6) as u8];
                        (key, format!("s{m}-r{i}").into_bytes())
                    })
                    .collect();
                pairs.sort_by(|a, b| a.0.cmp(&b.0)); // legacy map-side sort (stable)
                legacy.extend(pairs.iter().cloned());
                segs.push(Arc::new(Segment {
                    map: m as u32,
                    partition: 0,
                    node: NodeId(m as u32),
                    records: RecordBuf::from_pairs(pairs),
                }));
            }
            // Legacy reference merge: stable sort of the concatenated
            // (already per-segment-sorted, segment-ordered) stream.
            legacy.sort_by(|a, b| a.0.cmp(&b.0));

            let merged = merge_to_recordbuf(&segs);
            assert_eq!(merged.to_pairs(), legacy, "merge order + stability");

            // Group boundaries: walking the merged order groups by key
            // exactly like grouping the legacy merged stream.
            let order = merge_segments(&segs);
            let mut flat_groups: Vec<(Vec<u8>, usize)> = Vec::new();
            for (k, _) in &legacy {
                match flat_groups.last_mut() {
                    Some((lk, n)) if lk == k => *n += 1,
                    _ => flat_groups.push((k.clone(), 1)),
                }
            }
            let mut cursor_groups: Vec<(Vec<u8>, usize)> = Vec::new();
            let mut i = 0;
            while i < order.len() {
                let key = segs[order[i].0 as usize]
                    .records
                    .key(order[i].1 as usize);
                let mut j = i + 1;
                while j < order.len()
                    && segs[order[j].0 as usize].records.key(order[j].1 as usize) == key
                {
                    j += 1;
                }
                cursor_groups.push((key.to_vec(), j - i));
                i = j;
            }
            assert_eq!(cursor_groups, flat_groups, "group boundaries");
        });
    }
}
