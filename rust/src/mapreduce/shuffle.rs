//! The shuffle store: map-output segments keyed by `(map, partition)`.
//!
//! Stands in for the NM-local spill directories + the HTTP shuffle
//! handlers. Segments record the node that produced them so a node failure
//! invalidates exactly the segments Hadoop would lose (map re-execution),
//! and the exactly-once delivery invariant can be property-tested.
//!
//! Perf shape (the Terasort hot path):
//!
//! * the store is **partition-sharded** (`shard = partition % N`), so
//!   concurrent map spills and reduce fetches of different partitions
//!   never contend on one global lock;
//! * segments are stored behind `Arc` and [`ShuffleStore::fetch_partition`]
//!   hands out shared views — no record bytes are copied at fetch time;
//! * [`merge_segments`] is a cursor-based k-way merge over borrowed key
//!   slices: it allocates O(segments) heap entries plus the output index,
//!   never cloning keys or values.
//!
//! Two-level storage (PR 7): when the backing [`Dfs`] offers a
//! [`ShuffleSpill`] sink (`HPCW_MEM_BUDGET` set), resident segment bytes
//! are bounded. Past the budget, unpinned segments (`Arc::strong_count`
//! == the store's own reference — no reduce is holding them) are encoded
//! and **spilled** to the backing tier; [`ShuffleStore::try_fetch`] and
//! [`ShuffleStore::fetch_partition`] transparently **re-materialize**
//! spilled segments on demand, so the reduce-side merge never knows a
//! segment left memory. Without a sink the store is the all-in-RAM PR 2
//! plane, byte for byte.

use crate::cluster::NodeId;
use crate::error::{Error, Result};
use crate::lustre::{Dfs, ShuffleSpill};
use crate::mapreduce::recordbuf::RecordBuf;
use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::collections::{BTreeMap, BTreeSet};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// One spilled map-output segment (already sorted by key).
#[derive(Debug, Clone)]
pub struct Segment {
    pub map: u32,
    pub partition: u32,
    pub node: NodeId,
    /// Flat record storage, sorted by key.
    pub records: RecordBuf,
}

impl Segment {
    pub fn bytes(&self) -> u64 {
        self.records.payload_bytes()
    }

    /// Serialize for the spill tier: fixed header (map, partition, node,
    /// record count), per-record key/value lengths, then the raw payload.
    /// All little-endian u32s — the segment is process-local data, not a
    /// wire format.
    pub fn encode(&self) -> Vec<u8> {
        let n = self.records.len();
        let mut out =
            Vec::with_capacity(16 + 8 * n + self.records.payload_bytes() as usize);
        for v in [self.map, self.partition, self.node.0, n as u32] {
            out.extend_from_slice(&v.to_le_bytes());
        }
        for (k, v) in self.records.iter() {
            out.extend_from_slice(&(k.len() as u32).to_le_bytes());
            out.extend_from_slice(&(v.len() as u32).to_le_bytes());
        }
        for (k, v) in self.records.iter() {
            out.extend_from_slice(k);
            out.extend_from_slice(v);
        }
        out
    }

    /// Inverse of [`Segment::encode`]; re-materializes a spilled segment.
    pub fn decode(data: &[u8]) -> Result<Segment> {
        let rd_u32 = |off: usize| -> Result<u32> {
            data.get(off..off + 4)
                .map(|b| u32::from_le_bytes(b.try_into().unwrap()))
                .ok_or_else(|| Error::MapReduce("spilled segment truncated".into()))
        };
        let map = rd_u32(0)?;
        let partition = rd_u32(4)?;
        let node = NodeId(rd_u32(8)?);
        let n = rd_u32(12)? as usize;
        let mut lens = Vec::with_capacity(n);
        let mut off = 16usize;
        let mut payload = 0usize;
        for _ in 0..n {
            let kl = rd_u32(off)? as usize;
            let vl = rd_u32(off + 4)? as usize;
            lens.push((kl, vl));
            payload += kl + vl;
            off += 8;
        }
        if data.len() != off + payload {
            return Err(Error::MapReduce(format!(
                "spilled segment length mismatch: {} != {}",
                data.len(),
                off + payload
            )));
        }
        let mut records = RecordBuf::with_capacity(n, payload);
        for (kl, vl) in lens {
            records.push_record(&data[off..off + kl + vl], kl);
            off += kl + vl;
        }
        Ok(Segment { map, partition, node, records })
    }
}

/// Default shard count; override with [`ShuffleStore::with_shards`] or the
/// `HPCW_SHUFFLE_SHARDS` environment variable.
pub const DEFAULT_SHUFFLE_SHARDS: usize = 16;

/// One shuffle-matrix cell: the segment, wherever it currently lives.
#[derive(Debug)]
enum Cell {
    /// In memory, fetchable zero-copy.
    Resident(Arc<Segment>),
    /// Encoded in the backing tier under `key`; `bytes` is the payload
    /// size it re-materializes to (resident accounting).
    Spilled { node: NodeId, bytes: u64, key: String },
}

impl Cell {
    fn node(&self) -> NodeId {
        match self {
            Cell::Resident(s) => s.node,
            Cell::Spilled { node, .. } => *node,
        }
    }
}

type Shard = Mutex<BTreeMap<(u32, u32), Cell>>;

/// Thread-safe, partition-sharded shuffle store for one job.
pub struct ShuffleStore {
    shards: Vec<Shard>,
    /// Nodes whose segments are fenced out: a node that failed mid-job
    /// stays banned for the life of the store, so an in-flight zombie
    /// attempt on the dead node can never overwrite a re-executed map's
    /// committed segment (the batch allocator never re-mints a failed
    /// node id).
    banned: Mutex<BTreeSet<NodeId>>,
    /// Spill destination + resident-byte budget; `None` = all-in-RAM.
    spill: Option<ShuffleSpill>,
    /// Payload bytes currently held by `Resident` cells.
    resident_bytes: AtomicU64,
    /// Payload bytes currently parked in `Spilled` cells.
    spilled_now: AtomicU64,
    /// Cumulative encoded bytes ever written to the spill sink.
    spill_bytes_total: AtomicU64,
    /// Spilled segments re-materialized on fetch.
    spill_reloads: AtomicU64,
}

impl std::fmt::Debug for ShuffleStore {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "ShuffleStore(shards={}, resident={}, spilled={})",
            self.shards.len(),
            self.resident_bytes.load(Ordering::Relaxed),
            self.spilled_now.load(Ordering::Relaxed)
        )
    }
}

impl Default for ShuffleStore {
    fn default() -> Self {
        ShuffleStore::new()
    }
}

fn env_shards() -> usize {
    std::env::var("HPCW_SHUFFLE_SHARDS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .unwrap_or(DEFAULT_SHUFFLE_SHARDS)
}

impl ShuffleStore {
    /// All-in-RAM store with the default shard count (`HPCW_SHUFFLE_SHARDS`
    /// overrides).
    pub fn new() -> Self {
        ShuffleStore::with_shards(env_shards())
    }

    /// All-in-RAM store with an explicit shard count (`n >= 1`).
    pub fn with_shards(n: usize) -> Self {
        ShuffleStore::with_shards_and_spill(n, None)
    }

    /// Store that spills past `spill.budget` resident bytes (when `Some`).
    pub fn with_spill(spill: Option<ShuffleSpill>) -> Self {
        ShuffleStore::with_shards_and_spill(env_shards(), spill)
    }

    /// Store wired to `dfs`'s spill tier, when it offers one — the engine
    /// constructor: tiered backends bound the shuffle, others keep the
    /// all-in-RAM behavior.
    pub fn for_dfs(dfs: &dyn Dfs) -> Self {
        ShuffleStore::with_spill(dfs.shuffle_spill())
    }

    pub fn with_shards_and_spill(n: usize, spill: Option<ShuffleSpill>) -> Self {
        ShuffleStore {
            shards: (0..n.max(1)).map(|_| Mutex::new(BTreeMap::new())).collect(),
            banned: Mutex::new(BTreeSet::new()),
            spill,
            resident_bytes: AtomicU64::new(0),
            spilled_now: AtomicU64::new(0),
            spill_bytes_total: AtomicU64::new(0),
            spill_reloads: AtomicU64::new(0),
        }
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    #[inline]
    fn shard_for(&self, partition: u32) -> &Shard {
        &self.shards[partition as usize % self.shards.len()]
    }

    /// Commit a map attempt's segment. Re-commits (speculative duplicate or
    /// re-run after failure) replace the previous segment — Hadoop's
    /// commit-wins-once semantics. A segment from a banned (failed) node
    /// is dropped on the floor; returns whether the segment was stored.
    pub fn put(&self, seg: Segment) -> bool {
        debug_assert!(seg.records.is_sorted_by_key(), "segment must be sorted");
        if self.banned.lock().unwrap().contains(&seg.node) {
            return false;
        }
        let bytes = seg.bytes();
        let cell_key = (seg.map, seg.partition);
        let old = {
            let mut g = self.shard_for(seg.partition).lock().unwrap();
            g.insert(cell_key, Cell::Resident(Arc::new(seg)))
        };
        self.resident_bytes.fetch_add(bytes, Ordering::Relaxed);
        match old {
            Some(Cell::Resident(s)) => {
                self.resident_bytes.fetch_sub(s.bytes(), Ordering::Relaxed);
            }
            Some(Cell::Spilled { bytes, key, .. }) => {
                self.spilled_now.fetch_sub(bytes, Ordering::Relaxed);
                if let Some(sp) = &self.spill {
                    sp.sink.remove(&key);
                }
            }
            None => {}
        }
        self.maybe_spill();
        true
    }

    /// Spill LRU-ish victims (scan order) until resident bytes fit the
    /// budget. A victim must be unpinned: `Arc::strong_count == 1` means
    /// no reduce holds a fetched view, so the zero-copy contract
    /// ([`ShuffleStore::fetch_partition`] handles stay valid) is never
    /// broken by a spill. Encoding and sink I/O happen outside the shard
    /// lock; the swap re-verifies pointer identity, so a racing re-commit
    /// or fetch aborts the spill rather than losing it.
    fn maybe_spill(&self) {
        let Some(sp) = &self.spill else { return };
        if sp.budget == 0 {
            return;
        }
        while self.resident_bytes.load(Ordering::Relaxed) > sp.budget {
            let mut victim: Option<((u32, u32), Arc<Segment>)> = None;
            'scan: for shard in &self.shards {
                let g = shard.lock().unwrap();
                for (k, cell) in g.iter() {
                    if let Cell::Resident(s) = cell {
                        if Arc::strong_count(s) == 1 && !s.records.is_empty() {
                            victim = Some((*k, Arc::clone(s)));
                            break 'scan;
                        }
                    }
                }
            }
            let Some((k, s)) = victim else {
                return; // everything is pinned (or empty): stay resident
            };
            let spill_key = format!("m{}-p{}", k.0, k.1);
            let data = s.encode();
            if sp.sink.write(&spill_key, &data).is_err() {
                return; // sink unavailable: keep segments resident
            }
            let swapped = {
                let mut g = self.shard_for(k.1).lock().unwrap();
                match g.get(&k) {
                    // Still the same segment and still unpinned (our clone
                    // is the only outside reference).
                    Some(Cell::Resident(cur))
                        if Arc::ptr_eq(cur, &s) && Arc::strong_count(cur) == 2 =>
                    {
                        g.insert(
                            k,
                            Cell::Spilled {
                                node: s.node,
                                bytes: s.bytes(),
                                key: spill_key.clone(),
                            },
                        );
                        true
                    }
                    _ => false,
                }
            };
            if swapped {
                self.resident_bytes.fetch_sub(s.bytes(), Ordering::Relaxed);
                self.spilled_now.fetch_add(s.bytes(), Ordering::Relaxed);
                self.spill_bytes_total
                    .fetch_add(data.len() as u64, Ordering::Relaxed);
            } else {
                // Re-committed or fetched mid-spill: drop the orphan copy.
                sp.sink.remove(&spill_key);
                return;
            }
        }
    }

    /// Fetch all segments for one reduce partition, map order. Returns
    /// `Arc`-shared views of the committed segments — no per-record copies
    /// for resident segments; spilled segments are re-materialized first.
    pub fn fetch_partition(&self, partition: u32, n_maps: u32) -> Result<Vec<Arc<Segment>>> {
        let mut out = Vec::with_capacity(n_maps as usize);
        for m in 0..n_maps {
            match self.try_fetch(m, partition) {
                Some(s) => out.push(s),
                None => {
                    return Err(Error::MapReduce(format!(
                        "shuffle: missing segment map={m} partition={partition}"
                    )))
                }
            }
        }
        Ok(out)
    }

    /// Non-blocking per-cell visibility: the segment for `(map, partition)`
    /// if that map has committed, else `None`. Reduce slow-start polls this
    /// to fetch already-committed segments while the remaining maps are
    /// still running. Map tasks commit all their partitions together after
    /// the last sort (see `run_map_task`), so a visible cell always comes
    /// from an attempt that produced its full partition set. A spilled
    /// cell is reloaded from the backing tier and promoted back to
    /// resident — callers cannot tell it ever left memory.
    pub fn try_fetch(&self, map: u32, partition: u32) -> Option<Arc<Segment>> {
        let k = (map, partition);
        let spill_key = {
            let g = self.shard_for(partition).lock().unwrap();
            match g.get(&k) {
                Some(Cell::Resident(s)) => return Some(Arc::clone(s)),
                Some(Cell::Spilled { key, .. }) => key.clone(),
                None => return None,
            }
        };
        // Re-materialize outside the lock.
        let sp = self.spill.as_ref()?; // a Spilled cell implies a sink
        let data = sp.sink.read(&spill_key).ok()?;
        let seg = Arc::new(Segment::decode(&data).ok()?);
        let promoted = {
            let mut g = self.shard_for(partition).lock().unwrap();
            match g.get(&k) {
                Some(Cell::Spilled { bytes, .. }) => {
                    let b = *bytes;
                    g.insert(k, Cell::Resident(Arc::clone(&seg)));
                    Some(b)
                }
                // Another reloader (or a re-commit) won the race.
                Some(Cell::Resident(s)) => return Some(Arc::clone(s)),
                None => return None, // invalidated meanwhile
            }
        };
        if let Some(b) = promoted {
            sp.sink.remove(&spill_key);
            self.spilled_now.fetch_sub(b, Ordering::Relaxed);
            self.resident_bytes.fetch_add(b, Ordering::Relaxed);
            self.spill_reloads.fetch_add(1, Ordering::Relaxed);
            // The caller's handle pins this segment; pressure falls on
            // other cells.
            self.maybe_spill();
        }
        Some(seg)
    }

    /// Drop every segment produced on a failed node — resident or spilled —
    /// and return the map ids whose output was lost (they must re-run).
    /// The node is also banned: any commit from it arriving after this
    /// call is discarded, so a zombie attempt racing the invalidation
    /// cannot resurrect lost (or overwrite re-executed) segments.
    pub fn invalidate_node(&self, node: NodeId) -> Vec<u32> {
        self.banned.lock().unwrap().insert(node);
        let mut maps = Vec::new();
        for shard in &self.shards {
            let mut g = shard.lock().unwrap();
            let lost: Vec<(u32, u32)> = g
                .iter()
                .filter(|(_, c)| c.node() == node)
                .map(|(&k, _)| k)
                .collect();
            for k in lost {
                maps.push(k.0);
                match g.remove(&k) {
                    Some(Cell::Resident(s)) => {
                        self.resident_bytes.fetch_sub(s.bytes(), Ordering::Relaxed);
                    }
                    Some(Cell::Spilled { bytes, key, .. }) => {
                        self.spilled_now.fetch_sub(bytes, Ordering::Relaxed);
                        if let Some(sp) = &self.spill {
                            sp.sink.remove(&key);
                        }
                    }
                    None => {}
                }
            }
        }
        maps.sort_unstable();
        maps.dedup();
        maps
    }

    /// Total payload bytes held (resident + spilled).
    pub fn total_bytes(&self) -> u64 {
        self.resident_bytes.load(Ordering::Relaxed) + self.spilled_now.load(Ordering::Relaxed)
    }

    /// Payload bytes currently resident in memory.
    pub fn resident_bytes(&self) -> u64 {
        self.resident_bytes.load(Ordering::Relaxed)
    }

    /// Cumulative encoded bytes written to the spill sink (the
    /// `SPILL_BYTES`-shaped view from the shuffle's side).
    pub fn spilled_bytes(&self) -> u64 {
        self.spill_bytes_total.load(Ordering::Relaxed)
    }

    /// Spilled segments transparently re-materialized by fetches.
    pub fn spill_reloads(&self) -> u64 {
        self.spill_reloads.load(Ordering::Relaxed)
    }

    pub fn segment_count(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().len()).sum()
    }

    /// Exactly-once check: every (map, partition) cell present exactly once
    /// for the full matrix.
    pub fn verify_complete(&self, n_maps: u32, n_partitions: u32) -> Result<()> {
        let have = self.segment_count();
        if have != (n_maps as usize) * (n_partitions as usize) {
            return Err(Error::MapReduce(format!(
                "shuffle matrix {n_maps}×{n_partitions} has {have} cells"
            )));
        }
        Ok(())
    }
}

/// One merge cursor head: the current key of a segment. Ordered by
/// `(key, segment index)` so equal keys pop in map order — Hadoop's merge
/// stability guarantee.
struct Head<'a> {
    key: &'a [u8],
    seg: u32,
    rec: u32,
}

impl PartialEq for Head<'_> {
    fn eq(&self, other: &Self) -> bool {
        self.key == other.key && self.seg == other.seg
    }
}

impl Eq for Head<'_> {}

impl PartialOrd for Head<'_> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Head<'_> {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.key.cmp(other.key).then(self.seg.cmp(&other.seg))
    }
}

/// Cursor-based k-way merge of sorted segments: returns the merged order
/// as `(segment index, record index)` pairs, stable across segments in map
/// order for equal keys. Allocates the O(segments) heap and the output
/// index — no key or value bytes are cloned; callers read records through
/// the returned indices. Re-materialized (previously spilled) segments
/// merge exactly like always-resident ones: the merge sees only
/// `Arc<Segment>` views.
pub fn merge_segments(segments: &[Arc<Segment>]) -> Vec<(u32, u32)> {
    let total: usize = segments.iter().map(|s| s.records.len()).sum();
    let mut out = Vec::with_capacity(total);
    if segments.len() == 1 {
        out.extend((0..segments[0].records.len() as u32).map(|r| (0u32, r)));
        return out;
    }
    let mut heap: BinaryHeap<Reverse<Head<'_>>> = BinaryHeap::with_capacity(segments.len());
    for (si, s) in segments.iter().enumerate() {
        if !s.records.is_empty() {
            heap.push(Reverse(Head {
                key: s.records.key(0),
                seg: si as u32,
                rec: 0,
            }));
        }
    }
    while let Some(Reverse(h)) = heap.pop() {
        out.push((h.seg, h.rec));
        let next = h.rec as usize + 1;
        let s = &segments[h.seg as usize];
        if next < s.records.len() {
            heap.push(Reverse(Head {
                key: s.records.key(next),
                seg: h.seg,
                rec: next as u32,
            }));
        }
    }
    out
}

/// Materialize a merge into one `RecordBuf` (tests and tools; the reduce
/// path iterates [`merge_segments`]' index order without copying).
pub fn merge_to_recordbuf(segments: &[Arc<Segment>]) -> RecordBuf {
    let order = merge_segments(segments);
    let bytes: usize = segments.iter().map(|s| s.records.payload_bytes() as usize).sum();
    let mut out = RecordBuf::with_capacity(order.len(), bytes);
    for (s, r) in order {
        out.push_from(&segments[s as usize].records, r as usize);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lustre::SpillSink;
    use crate::testkit::props;

    fn seg(map: u32, part: u32, keys: &[u8]) -> Segment {
        Segment {
            map,
            partition: part,
            node: NodeId(map),
            records: RecordBuf::from_pairs(keys.iter().map(|&k| (vec![k], vec![k, k]))),
        }
    }

    /// In-memory [`SpillSink`] test double.
    #[derive(Default)]
    struct MemSpillSink(Mutex<BTreeMap<String, Vec<u8>>>);

    impl SpillSink for MemSpillSink {
        fn write(&self, key: &str, data: &[u8]) -> Result<()> {
            self.0.lock().unwrap().insert(key.to_string(), data.to_vec());
            Ok(())
        }

        fn read(&self, key: &str) -> Result<Vec<u8>> {
            self.0
                .lock()
                .unwrap()
                .get(key)
                .cloned()
                .ok_or_else(|| Error::MapReduce(format!("no spilled '{key}'")))
        }

        fn remove(&self, key: &str) {
            self.0.lock().unwrap().remove(key);
        }
    }

    fn spilling_store(budget: u64) -> (ShuffleStore, Arc<MemSpillSink>) {
        let sink = Arc::new(MemSpillSink::default());
        let st = ShuffleStore::with_shards_and_spill(
            4,
            Some(ShuffleSpill { sink: Arc::clone(&sink) as Arc<dyn SpillSink>, budget }),
        );
        (st, sink)
    }

    #[test]
    fn put_fetch_round_trip() {
        let st = ShuffleStore::new();
        st.put(seg(0, 0, &[1, 3]));
        st.put(seg(1, 0, &[2]));
        let got = st.fetch_partition(0, 2).unwrap();
        assert_eq!(got.len(), 2);
        assert!(st.fetch_partition(1, 2).is_err(), "missing partition 1");
    }

    #[test]
    fn fetch_shares_segments_without_copying() {
        // Zero-copy contract: two fetches of the same partition return the
        // same `Arc` allocation — the store never deep-clones a segment.
        let st = ShuffleStore::new();
        st.put(seg(0, 0, &[1, 2, 3]));
        let a = st.fetch_partition(0, 1).unwrap().remove(0);
        let b = st.fetch_partition(0, 1).unwrap().remove(0);
        assert!(Arc::ptr_eq(&a, &b), "fetch must hand out shared segments");
        // Store + two fetched handles.
        assert_eq!(Arc::strong_count(&a), 3);
    }

    #[test]
    fn try_fetch_sees_partial_commits() {
        // Per-map-commit visibility: cells appear one map at a time, and
        // the handed-out view shares the stored segment.
        let st = ShuffleStore::new();
        assert!(st.try_fetch(0, 0).is_none());
        st.put(seg(1, 0, &[2]));
        assert!(st.try_fetch(0, 0).is_none(), "map 0 not committed yet");
        let got = st.try_fetch(1, 0).unwrap();
        assert_eq!(got.records.key(0), &[2]);
        let again = st.try_fetch(1, 0).unwrap();
        assert!(Arc::ptr_eq(&got, &again));
        // fetch_partition still refuses the incomplete matrix.
        assert!(st.fetch_partition(0, 3).is_err());
        st.put(seg(0, 0, &[1]));
        st.put(seg(2, 0, &[3]));
        assert!((0..3).all(|m| st.try_fetch(m, 0).is_some()));
        assert_eq!(st.fetch_partition(0, 3).unwrap().len(), 3);
    }

    #[test]
    fn recommit_replaces() {
        let st = ShuffleStore::new();
        st.put(seg(0, 0, &[1]));
        st.put(seg(0, 0, &[9])); // speculative duplicate wins once
        let got = st.fetch_partition(0, 1).unwrap();
        assert_eq!(got[0].records.key(0), &[9]);
        assert_eq!(st.segment_count(), 1);
    }

    #[test]
    fn node_invalidation_names_lost_maps() {
        let st = ShuffleStore::new();
        st.put(seg(0, 0, &[1]));
        st.put(seg(0, 1, &[1]));
        st.put(seg(1, 0, &[2]));
        let lost = st.invalidate_node(NodeId(0));
        assert_eq!(lost, vec![0]);
        assert_eq!(st.segment_count(), 1);
        assert!(st.verify_complete(2, 2).is_err());
    }

    #[test]
    fn banned_node_commits_are_fenced_out() {
        // A zombie attempt on a failed node must never overwrite the
        // re-executed map's segment: after invalidation, puts from the
        // dead node are dropped.
        let st = ShuffleStore::new();
        assert!(st.put(seg(0, 0, &[1])));
        st.invalidate_node(NodeId(0));
        assert!(!st.put(seg(0, 0, &[9])), "zombie commit dropped");
        assert!(st.try_fetch(0, 0).is_none());
        // The re-run on a fresh node commits normally…
        let rerun = Segment {
            map: 0,
            partition: 0,
            node: NodeId(7),
            records: RecordBuf::from_pairs([(vec![5u8], vec![5, 5])]),
        };
        assert!(st.put(rerun));
        // …and a late zombie still cannot clobber it.
        assert!(!st.put(seg(0, 0, &[9])));
        assert_eq!(st.try_fetch(0, 0).unwrap().records.key(0), &[5]);
    }

    #[test]
    fn sharding_covers_all_partitions() {
        // More partitions than shards: routing must stay consistent.
        let st = ShuffleStore::with_shards(3);
        assert_eq!(st.n_shards(), 3);
        for p in 0..10u32 {
            st.put(seg(0, p, &[p as u8]));
        }
        for p in 0..10u32 {
            let got = st.fetch_partition(p, 1).unwrap();
            assert_eq!(got[0].records.key(0), &[p as u8]);
        }
        assert_eq!(st.segment_count(), 10);
        st.verify_complete(1, 10).unwrap();
    }

    #[test]
    fn segment_encode_decode_round_trip() {
        props(30, |g| {
            let n = g.usize(0..30);
            let pairs: Vec<(Vec<u8>, Vec<u8>)> = (0..n)
                .map(|_| {
                    let k: Vec<u8> = (0..g.usize(0..12)).map(|_| g.u32(0..256) as u8).collect();
                    let v: Vec<u8> = (0..g.usize(0..40)).map(|_| g.u32(0..256) as u8).collect();
                    (k, v)
                })
                .collect();
            let s = Segment {
                map: g.u32(0..100),
                partition: g.u32(0..100),
                node: NodeId(g.u32(0..100)),
                records: RecordBuf::from_pairs(pairs.clone()),
            };
            let d = Segment::decode(&s.encode()).unwrap();
            assert_eq!((d.map, d.partition, d.node), (s.map, s.partition, s.node));
            assert_eq!(d.records.to_pairs(), pairs);
        });
    }

    #[test]
    fn spill_under_pressure_and_transparent_reload() {
        // 3 maps × ~40 payload bytes with a 64-byte budget: later puts
        // must push earlier segments out to the sink, and fetches must
        // bring them back byte-identical with the all-in-RAM merge.
        let (st, sink) = spilling_store(64);
        let reference = ShuffleStore::new();
        for m in 0..3u32 {
            let keys: Vec<u8> = (0..20).map(|i| (m as u8) * 20 + i).collect();
            st.put(seg(m, 0, &keys));
            reference.put(seg(m, 0, &keys));
        }
        assert!(st.spilled_bytes() > 0, "budget must force spills: {st:?}");
        assert!(st.resident_bytes() <= 64, "{st:?}");
        assert!(!sink.0.lock().unwrap().is_empty(), "sink holds spilled cells");
        assert_eq!(st.segment_count(), 3, "spilled cells still count");
        st.verify_complete(3, 1).unwrap();
        // Transparent re-materialization: fetch_partition sees all three
        // and the merge is byte-identical to the unbounded store's.
        let got = st.fetch_partition(0, 3).unwrap();
        assert!(st.spill_reloads() > 0, "fetch must reload spilled segments");
        let want = merge_to_recordbuf(&reference.fetch_partition(0, 3).unwrap());
        assert_eq!(merge_to_recordbuf(&got).to_pairs(), want.to_pairs());
    }

    #[test]
    fn fetched_segments_are_pinned_against_spill() {
        // A reduce holding a fetched view keeps that segment resident:
        // spilling it would not free memory (the Arc keeps the bytes
        // alive) and the handle must stay valid.
        let (st, _sink) = spilling_store(64);
        st.put(seg(0, 0, &[1, 2, 3, 4, 5, 6, 7, 8, 9, 10]));
        let pinned = st.try_fetch(0, 0).unwrap();
        for m in 1..5u32 {
            let keys: Vec<u8> = (0..20).map(|i| i as u8).collect();
            st.put(seg(m, 0, &keys));
        }
        assert!(st.spilled_bytes() > 0, "pressure must spill something");
        let again = st.try_fetch(0, 0).unwrap();
        assert!(
            Arc::ptr_eq(&pinned, &again),
            "pinned segment must never round-trip through the sink"
        );
    }

    #[test]
    fn invalidate_node_drops_spilled_cells_too() {
        let (st, sink) = spilling_store(32);
        st.put(seg(0, 0, &(0..16).collect::<Vec<u8>>()));
        st.put(seg(1, 0, &(0..16).collect::<Vec<u8>>())); // map 0 spills
        assert!(st.spilled_bytes() > 0);
        let lost = st.invalidate_node(NodeId(0));
        assert_eq!(lost, vec![0]);
        assert!(st.try_fetch(0, 0).is_none(), "spilled cell gone");
        assert!(
            sink.0.lock().unwrap().keys().all(|k| !k.starts_with("m0-")),
            "spilled copy removed from the sink"
        );
        // Map 1's segment (16 records × 3 payload bytes) is all that's left.
        assert_eq!(st.total_bytes(), 48);
    }

    #[test]
    fn merge_is_sorted_and_complete() {
        let a = seg(0, 0, &[1, 4, 7]);
        let b = seg(1, 0, &[2, 4, 9]);
        let segs = vec![Arc::new(a), Arc::new(b)];
        let merged = merge_to_recordbuf(&segs);
        let keys: Vec<u8> = merged.iter().map(|(k, _)| k[0]).collect();
        assert_eq!(keys, vec![1, 2, 4, 4, 7, 9]);
    }

    #[test]
    fn merge_stable_on_equal_keys() {
        // Equal keys come out in segment (map) order.
        let mk = |map: u32, val: &[u8]| Segment {
            map,
            partition: 0,
            node: NodeId(map),
            records: RecordBuf::from_pairs([(b"\x05".to_vec(), val.to_vec())]),
        };
        let segs = vec![
            Arc::new(mk(0, b"from-map0")),
            Arc::new(mk(1, b"from-map1")),
        ];
        let merged = merge_to_recordbuf(&segs);
        assert_eq!(merged.value(0), b"from-map0");
        assert_eq!(merged.value(1), b"from-map1");
    }

    #[test]
    fn merge_property_equals_flat_sort() {
        props(30, |g| {
            let n_segs = g.usize(1..6);
            let mut segs = Vec::new();
            let mut flat = Vec::new();
            for m in 0..n_segs {
                let mut keys: Vec<u8> =
                    (0..g.usize(0..20)).map(|_| g.u32(0..50) as u8).collect();
                keys.sort_unstable();
                flat.extend(keys.iter().copied());
                segs.push(Arc::new(seg(m as u32, 0, &keys)));
            }
            flat.sort_unstable();
            let merged = merge_to_recordbuf(&segs);
            let keys: Vec<u8> = merged.iter().map(|(k, _)| k[0]).collect();
            assert_eq!(keys, flat);
        });
    }

    #[test]
    fn merge_property_spilled_parity() {
        // The k-way merge cannot tell re-materialized segments from
        // resident ones: a budget-bounded store and the all-in-RAM store
        // merge to identical pair streams.
        props(20, |g| {
            let budget = 1 + g.u64(0..96);
            let (st, _sink) = spilling_store(budget);
            let reference = ShuffleStore::new();
            let n_maps = g.usize(1..5) as u32;
            for m in 0..n_maps {
                let mut keys: Vec<u8> =
                    (0..g.usize(1..25)).map(|_| g.u32(0..60) as u8).collect();
                keys.sort_unstable();
                st.put(seg(m, 0, &keys));
                reference.put(seg(m, 0, &keys));
            }
            let constrained = merge_to_recordbuf(&st.fetch_partition(0, n_maps).unwrap());
            let unbounded =
                merge_to_recordbuf(&reference.fetch_partition(0, n_maps).unwrap());
            assert_eq!(constrained.to_pairs(), unbounded.to_pairs());
        });
    }

    /// Parity with the legacy pairs path: merge order, group boundaries,
    /// and stable equal-key ordering across segments all match a reference
    /// model built on `Vec<(Vec<u8>, Vec<u8>)>`.
    #[test]
    fn merge_parity_with_legacy_pairs_path() {
        props(40, |g| {
            let n_segs = g.usize(1..6);
            let mut segs: Vec<Arc<Segment>> = Vec::new();
            let mut legacy: Vec<(Vec<u8>, Vec<u8>)> = Vec::new();
            for m in 0..n_segs {
                let mut pairs: Vec<(Vec<u8>, Vec<u8>)> = (0..g.usize(0..25))
                    .map(|i| {
                        // Small key space → plenty of equal keys, within and
                        // across segments. Values carry (segment, seq).
                        let key = vec![g.u32(0..6) as u8];
                        (key, format!("s{m}-r{i}").into_bytes())
                    })
                    .collect();
                pairs.sort_by(|a, b| a.0.cmp(&b.0)); // legacy map-side sort (stable)
                legacy.extend(pairs.iter().cloned());
                segs.push(Arc::new(Segment {
                    map: m as u32,
                    partition: 0,
                    node: NodeId(m as u32),
                    records: RecordBuf::from_pairs(pairs),
                }));
            }
            // Legacy reference merge: stable sort of the concatenated
            // (already per-segment-sorted, segment-ordered) stream.
            legacy.sort_by(|a, b| a.0.cmp(&b.0));

            let merged = merge_to_recordbuf(&segs);
            assert_eq!(merged.to_pairs(), legacy, "merge order + stability");

            // Group boundaries: walking the merged order groups by key
            // exactly like grouping the legacy merged stream.
            let order = merge_segments(&segs);
            let mut flat_groups: Vec<(Vec<u8>, usize)> = Vec::new();
            for (k, _) in &legacy {
                match flat_groups.last_mut() {
                    Some((lk, n)) if lk == k => *n += 1,
                    _ => flat_groups.push((k.clone(), 1)),
                }
            }
            let mut cursor_groups: Vec<(Vec<u8>, usize)> = Vec::new();
            let mut i = 0;
            while i < order.len() {
                let key = segs[order[i].0 as usize]
                    .records
                    .key(order[i].1 as usize);
                let mut j = i + 1;
                while j < order.len()
                    && segs[order[j].0 as usize].records.key(order[j].1 as usize) == key
                {
                    j += 1;
                }
                cursor_groups.push((key.to_vec(), j - i));
                i = j;
            }
            assert_eq!(cursor_groups, flat_groups, "group boundaries");
        });
    }
}
