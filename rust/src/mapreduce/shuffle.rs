//! The shuffle store: map-output segments keyed by `(map, partition)`.
//!
//! Stands in for the NM-local spill directories + the HTTP shuffle
//! handlers. Segments record the node that produced them so a node failure
//! invalidates exactly the segments Hadoop would lose (map re-execution),
//! and the exactly-once delivery invariant can be property-tested.

use crate::cluster::NodeId;
use crate::error::{Error, Result};
use std::collections::BTreeMap;
use std::sync::Mutex;

/// One spilled map-output segment (already sorted by key).
#[derive(Debug, Clone)]
pub struct Segment {
    pub map: u32,
    pub partition: u32,
    pub node: NodeId,
    /// Sorted (key, value) pairs.
    pub pairs: Vec<(Vec<u8>, Vec<u8>)>,
}

impl Segment {
    pub fn bytes(&self) -> u64 {
        self.pairs
            .iter()
            .map(|(k, v)| (k.len() + v.len()) as u64)
            .sum()
    }
}

/// Thread-safe shuffle store for one job.
#[derive(Debug, Default)]
pub struct ShuffleStore {
    inner: Mutex<BTreeMap<(u32, u32), Segment>>,
}

impl ShuffleStore {
    pub fn new() -> Self {
        ShuffleStore::default()
    }

    /// Commit a map attempt's segment. Re-commits (speculative duplicate or
    /// re-run after failure) replace the previous segment — Hadoop's
    /// commit-wins-once semantics.
    pub fn put(&self, seg: Segment) {
        debug_assert!(
            seg.pairs.windows(2).all(|w| w[0].0 <= w[1].0),
            "segment must be sorted"
        );
        let mut g = self.inner.lock().unwrap();
        g.insert((seg.map, seg.partition), seg);
    }

    /// Fetch all segments for one reduce partition, map order.
    pub fn fetch_partition(&self, partition: u32, n_maps: u32) -> Result<Vec<Segment>> {
        let g = self.inner.lock().unwrap();
        let mut out = Vec::new();
        for m in 0..n_maps {
            match g.get(&(m, partition)) {
                Some(s) => out.push(s.clone()),
                None => {
                    return Err(Error::MapReduce(format!(
                        "shuffle: missing segment map={m} partition={partition}"
                    )))
                }
            }
        }
        Ok(out)
    }

    /// Drop every segment produced on a failed node; returns the map ids
    /// whose output was lost (they must re-run).
    pub fn invalidate_node(&self, node: NodeId) -> Vec<u32> {
        let mut g = self.inner.lock().unwrap();
        let lost: Vec<(u32, u32)> = g
            .iter()
            .filter(|(_, s)| s.node == node)
            .map(|(&k, _)| k)
            .collect();
        let mut maps: Vec<u32> = lost.iter().map(|&(m, _)| m).collect();
        for k in lost {
            g.remove(&k);
        }
        maps.sort_unstable();
        maps.dedup();
        maps
    }

    /// Total bytes held.
    pub fn total_bytes(&self) -> u64 {
        self.inner.lock().unwrap().values().map(Segment::bytes).sum()
    }

    pub fn segment_count(&self) -> usize {
        self.inner.lock().unwrap().len()
    }

    /// Exactly-once check: every (map, partition) cell present exactly once
    /// for the full matrix.
    pub fn verify_complete(&self, n_maps: u32, n_partitions: u32) -> Result<()> {
        let g = self.inner.lock().unwrap();
        if g.len() != (n_maps as usize) * (n_partitions as usize) {
            return Err(Error::MapReduce(format!(
                "shuffle matrix {}×{} has {} cells",
                n_maps,
                n_partitions,
                g.len()
            )));
        }
        Ok(())
    }
}

/// K-way merge of sorted segments into one sorted stream of pairs.
/// Stable across segments in map order (Hadoop merge semantics).
pub fn merge_segments(segments: Vec<Segment>) -> Vec<(Vec<u8>, Vec<u8>)> {
    use std::cmp::Reverse;
    use std::collections::BinaryHeap;

    let total: usize = segments.iter().map(|s| s.pairs.len()).sum();
    let mut out = Vec::with_capacity(total);
    // Heap of (key, segment_idx, pair_idx); Reverse for min-heap. The
    // segment index participates in ordering → stability.
    let mut heap: BinaryHeap<Reverse<(Vec<u8>, usize, usize)>> = BinaryHeap::new();
    for (si, s) in segments.iter().enumerate() {
        if !s.pairs.is_empty() {
            heap.push(Reverse((s.pairs[0].0.clone(), si, 0)));
        }
    }
    while let Some(Reverse((_, si, pi))) = heap.pop() {
        let (k, v) = &segments[si].pairs[pi];
        out.push((k.clone(), v.clone()));
        let next = pi + 1;
        if next < segments[si].pairs.len() {
            heap.push(Reverse((segments[si].pairs[next].0.clone(), si, next)));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::props;

    fn seg(map: u32, part: u32, keys: &[u8]) -> Segment {
        Segment {
            map,
            partition: part,
            node: NodeId(map),
            pairs: keys.iter().map(|&k| (vec![k], vec![k, k])).collect(),
        }
    }

    #[test]
    fn put_fetch_round_trip() {
        let st = ShuffleStore::new();
        st.put(seg(0, 0, &[1, 3]));
        st.put(seg(1, 0, &[2]));
        let got = st.fetch_partition(0, 2).unwrap();
        assert_eq!(got.len(), 2);
        assert!(st.fetch_partition(1, 2).is_err(), "missing partition 1");
    }

    #[test]
    fn recommit_replaces() {
        let st = ShuffleStore::new();
        st.put(seg(0, 0, &[1]));
        st.put(seg(0, 0, &[9])); // speculative duplicate wins once
        let got = st.fetch_partition(0, 1).unwrap();
        assert_eq!(got[0].pairs[0].0, vec![9]);
        assert_eq!(st.segment_count(), 1);
    }

    #[test]
    fn node_invalidation_names_lost_maps() {
        let st = ShuffleStore::new();
        st.put(seg(0, 0, &[1]));
        st.put(seg(0, 1, &[1]));
        st.put(seg(1, 0, &[2]));
        let lost = st.invalidate_node(NodeId(0));
        assert_eq!(lost, vec![0]);
        assert_eq!(st.segment_count(), 1);
        assert!(st.verify_complete(2, 2).is_err());
    }

    #[test]
    fn merge_is_sorted_and_complete() {
        let a = seg(0, 0, &[1, 4, 7]);
        let b = seg(1, 0, &[2, 4, 9]);
        let merged = merge_segments(vec![a, b]);
        let keys: Vec<u8> = merged.iter().map(|(k, _)| k[0]).collect();
        assert_eq!(keys, vec![1, 2, 4, 4, 7, 9]);
    }

    #[test]
    fn merge_stable_on_equal_keys() {
        // Equal keys come out in segment (map) order.
        let mut a = seg(0, 0, &[5]);
        a.pairs[0].1 = b"from-map0".to_vec();
        let mut b = seg(1, 0, &[5]);
        b.pairs[0].1 = b"from-map1".to_vec();
        let merged = merge_segments(vec![a, b]);
        assert_eq!(merged[0].1, b"from-map0".to_vec());
        assert_eq!(merged[1].1, b"from-map1".to_vec());
    }

    #[test]
    fn merge_property_equals_flat_sort() {
        props(30, |g| {
            let n_segs = g.usize(1..6);
            let mut segs = Vec::new();
            let mut flat = Vec::new();
            for m in 0..n_segs {
                let mut keys: Vec<u8> =
                    (0..g.usize(0..20)).map(|_| g.u32(0..50) as u8).collect();
                keys.sort_unstable();
                flat.extend(keys.iter().copied());
                segs.push(seg(m as u32, 0, &keys));
            }
            flat.sort_unstable();
            let merged = merge_segments(segs);
            let keys: Vec<u8> = merged.iter().map(|(k, _)| k[0]).collect();
            assert_eq!(keys, flat);
        });
    }
}
