//! Queue dispatch policies: FIFO, fairshare, capacity (ABL-SCHED).
//!
//! `pick_next` returns the pending job the queue should try to start next,
//! or `None` if policy forbids starting anything (capacity exhausted).

use crate::config::sched::{QueueConfig, QueuePolicy};
use crate::scheduler::job::LsfJob;
use crate::util::ids::LsfJobId;
use std::collections::BTreeMap;

/// Choose the next candidate from `pending` (submit order) for queue `q`.
///
/// * `running_by_user` — nodes currently held per user (fairshare input).
/// * `queue_used` — nodes currently held by this queue (capacity input).
/// * `total_nodes` — cluster size (capacity denominator).
pub fn pick_next(
    q: &QueueConfig,
    pending: &[LsfJobId],
    jobs: &BTreeMap<LsfJobId, LsfJob>,
    running_by_user: &BTreeMap<String, u32>,
    queue_used: u32,
    total_nodes: usize,
) -> Option<LsfJobId> {
    if pending.is_empty() {
        return None;
    }
    match q.policy {
        QueuePolicy::Fifo => Some(pending[0]),
        QueuePolicy::Fairshare => {
            // Deficit fairshare: among pending jobs, pick the one whose user
            // currently holds the fewest nodes; ties go to submit order.
            pending
                .iter()
                .copied()
                .min_by_key(|id| {
                    let user = &jobs[id].req.user;
                    let held = running_by_user.get(user).copied().unwrap_or(0);
                    (held, *id)
                })
        }
        QueuePolicy::Capacity => {
            // The queue may not exceed its share of the cluster. Pick FIFO
            // among jobs that fit under the cap.
            let cap = (q.capacity_share * total_nodes as f64).floor() as u32;
            pending
                .iter()
                .copied()
                .find(|id| queue_used + jobs[id].req.nodes <= cap.max(1))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::job::{JobCommand, JobState, ResourceRequest};
    use crate::util::time::Micros;

    fn queue(policy: QueuePolicy, share: f64) -> QueueConfig {
        QueueConfig {
            name: "q".into(),
            policy,
            exclusive: true,
            capacity_share: share,
            priority: 1,
        }
    }

    fn job(id: u64, user: &str, nodes: u32) -> (LsfJobId, LsfJob) {
        let jid = LsfJobId(id);
        (
            jid,
            LsfJob {
                id: jid,
                req: ResourceRequest {
                    nodes,
                    queue: "q".into(),
                    user: user.into(),
                    wall_limit: None,
                    exclusive: true,
                },
                command: JobCommand::wrapper("x"),
                state: JobState::Pending,
                submitted_at: Micros::ZERO,
                started_at: None,
                finished_at: None,
                nodes: vec![],
            },
        )
    }

    fn jobs(list: Vec<(LsfJobId, LsfJob)>) -> BTreeMap<LsfJobId, LsfJob> {
        list.into_iter().collect()
    }

    #[test]
    fn fifo_takes_head() {
        let js = jobs(vec![job(1, "a", 2), job(2, "b", 2)]);
        let picked = pick_next(
            &queue(QueuePolicy::Fifo, 1.0),
            &[LsfJobId(1), LsfJobId(2)],
            &js,
            &BTreeMap::new(),
            0,
            8,
        );
        assert_eq!(picked, Some(LsfJobId(1)));
    }

    #[test]
    fn fairshare_prefers_starved_user() {
        let js = jobs(vec![job(1, "greedy", 2), job(2, "starved", 2)]);
        let mut held = BTreeMap::new();
        held.insert("greedy".to_string(), 6u32);
        let picked = pick_next(
            &queue(QueuePolicy::Fairshare, 1.0),
            &[LsfJobId(1), LsfJobId(2)],
            &js,
            &held,
            6,
            8,
        );
        assert_eq!(picked, Some(LsfJobId(2)));
    }

    #[test]
    fn fairshare_ties_break_by_submit_order() {
        let js = jobs(vec![job(1, "a", 2), job(2, "b", 2)]);
        let picked = pick_next(
            &queue(QueuePolicy::Fairshare, 1.0),
            &[LsfJobId(1), LsfJobId(2)],
            &js,
            &BTreeMap::new(),
            0,
            8,
        );
        assert_eq!(picked, Some(LsfJobId(1)));
    }

    #[test]
    fn capacity_blocks_over_cap() {
        let js = jobs(vec![job(1, "a", 4), job(2, "a", 1)]);
        // Cap = 0.5 × 8 = 4 nodes; 2 already used → job of 4 blocked, job
        // of 1 admitted.
        let picked = pick_next(
            &queue(QueuePolicy::Capacity, 0.5),
            &[LsfJobId(1), LsfJobId(2)],
            &js,
            &BTreeMap::new(),
            2,
            8,
        );
        assert_eq!(picked, Some(LsfJobId(2)));
        // Fully at cap → nothing.
        let none = pick_next(
            &queue(QueuePolicy::Capacity, 0.5),
            &[LsfJobId(1), LsfJobId(2)],
            &js,
            &BTreeMap::new(),
            4,
            8,
        );
        assert_eq!(none, None);
    }

    #[test]
    fn empty_pending_none() {
        let js = jobs(vec![]);
        assert_eq!(
            pick_next(&queue(QueuePolicy::Fifo, 1.0), &[], &js, &BTreeMap::new(), 0, 8),
            None
        );
    }
}
