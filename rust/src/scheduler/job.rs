//! Job, request and command types for the LSF-like scheduler.

use crate::cluster::NodeId;
use crate::util::ids::LsfJobId;
use crate::util::time::Micros;

/// What the dispatched job runs. The paper's flow always goes through the
/// wrapper script, but plain commands model the coexisting HPC workloads
/// (MPI jobs sharing the machine in the ABL-SCHED ablation).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum JobCommand {
    /// The HPC Wales wrapper: build a YARN cluster, run `app`, tear down.
    Wrapper { app: String },
    /// A plain command (an MPI application, a serial task...).
    Plain { argv: Vec<String> },
}

impl JobCommand {
    pub fn wrapper(app: &str) -> JobCommand {
        JobCommand::Wrapper { app: app.to_string() }
    }

    pub fn plain(argv: &[&str]) -> JobCommand {
        JobCommand::Plain {
            argv: argv.iter().map(|s| s.to_string()).collect(),
        }
    }

    /// Display string for `bjobs`-style listings.
    pub fn display(&self) -> String {
        match self {
            JobCommand::Wrapper { app } => format!("hpcw-wrapper {app}"),
            JobCommand::Plain { argv } => argv.join(" "),
        }
    }
}

/// A `bsub`-style resource request. HPC Wales Big Data jobs request whole
/// nodes (`-n N -R span[ptile=16] -x`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ResourceRequest {
    /// Whole nodes requested.
    pub nodes: u32,
    pub queue: String,
    pub user: String,
    /// Wall-clock limit (jobs past it are killed by the driver).
    pub wall_limit: Option<Micros>,
    /// Force exclusive placement even on a shared queue.
    pub exclusive: bool,
}

impl ResourceRequest {
    /// The paper's standard request: N nodes on the dedicated queue.
    pub fn bigdata(nodes: u32, user: &str) -> ResourceRequest {
        ResourceRequest {
            nodes,
            queue: "bigdata".into(),
            user: user.into(),
            wall_limit: None,
            exclusive: true,
        }
    }
}

/// Lifecycle state (LSF names: PEND, RUN, DONE, EXIT).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobState {
    Pending,
    Running,
    /// Finished with exit 0.
    Done,
    /// Finished with non-zero exit.
    Exited,
    /// Terminated by bkill.
    Killed,
}

impl JobState {
    pub fn is_terminal(self) -> bool {
        matches!(self, JobState::Done | JobState::Exited | JobState::Killed)
    }

    /// LSF display name.
    pub fn lsf_name(self) -> &'static str {
        match self {
            JobState::Pending => "PEND",
            JobState::Running => "RUN",
            JobState::Done => "DONE",
            JobState::Exited => "EXIT",
            JobState::Killed => "EXIT(kill)",
        }
    }
}

/// A tracked job.
#[derive(Debug, Clone)]
pub struct LsfJob {
    pub id: LsfJobId,
    pub req: ResourceRequest,
    pub command: JobCommand,
    pub state: JobState,
    pub submitted_at: Micros,
    pub started_at: Option<Micros>,
    pub finished_at: Option<Micros>,
    /// Nodes held while running (empty otherwise).
    pub nodes: Vec<NodeId>,
}

impl LsfJob {
    /// Queue wait so far / total.
    pub fn wait_time(&self, now: Micros) -> Micros {
        match self.started_at {
            Some(s) => s.saturating_sub(self.submitted_at),
            None => now.saturating_sub(self.submitted_at),
        }
    }

    /// Runtime so far / total.
    pub fn run_time(&self, now: Micros) -> Micros {
        match (self.started_at, self.finished_at) {
            (Some(s), Some(f)) => f.saturating_sub(s),
            (Some(s), None) => now.saturating_sub(s),
            _ => Micros::ZERO,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn terminal_states() {
        assert!(!JobState::Pending.is_terminal());
        assert!(!JobState::Running.is_terminal());
        assert!(JobState::Done.is_terminal());
        assert!(JobState::Exited.is_terminal());
        assert!(JobState::Killed.is_terminal());
    }

    #[test]
    fn lsf_names() {
        assert_eq!(JobState::Pending.lsf_name(), "PEND");
        assert_eq!(JobState::Running.lsf_name(), "RUN");
    }

    #[test]
    fn bigdata_request_is_exclusive() {
        let r = ResourceRequest::bigdata(113, "sid");
        assert!(r.exclusive);
        assert_eq!(r.queue, "bigdata");
        assert_eq!(r.nodes, 113);
    }

    #[test]
    fn times() {
        let j = LsfJob {
            id: LsfJobId(1),
            req: ResourceRequest::bigdata(1, "u"),
            command: JobCommand::wrapper("t"),
            state: JobState::Running,
            submitted_at: Micros::secs(10),
            started_at: Some(Micros::secs(25)),
            finished_at: None,
            nodes: vec![],
        };
        assert_eq!(j.wait_time(Micros::secs(100)), Micros::secs(15));
        assert_eq!(j.run_time(Micros::secs(100)), Micros::secs(75));
    }

    #[test]
    fn command_display() {
        assert_eq!(JobCommand::wrapper("ts").display(), "hpcw-wrapper ts");
        assert_eq!(JobCommand::plain(&["mpirun", "-np", "64"]).display(), "mpirun -np 64");
    }
}
