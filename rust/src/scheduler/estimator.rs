//! Online per-(node, task-shape) runtime estimation for heterogeneous
//! clusters.
//!
//! The paper's dynamic YARN-on-HPC cluster assumes the scheduler can keep
//! thousands of cores busy, but nodes in a shared HPC pool are not
//! identical: Westmere vs Sandy Bridge partitions, burst-buffer vs
//! spinning-disk I/O, co-tenant interference. A single global straggler
//! multiplier (elapsed > factor × mean) mis-fires both ways on such a
//! cluster — the slow class inflates the global mean so genuine stragglers
//! on fast nodes are rescued late, while healthy tasks on slow nodes get
//! pointless duplicates.
//!
//! This module keeps the cheap online model that fixes both: an
//! exponentially-weighted mean/variance of observed attempt durations per
//! `(node, task shape)` cell — no heavy ML, O(1) state and update per
//! cell, in the spirit of the DARL load-balancing estimator. The scheduler
//! consumes it two ways (see `docs/SCHEDULING.md`):
//!
//! * **adaptive speculation** — an attempt is a straggler once it exceeds
//!   the *predicted p95* (`mean + 1.645·σ`) for its own node/shape cell,
//!   not a global multiplier (`HPCW_SPECULATION=adaptive`);
//! * **placement bias** — when locality ties at the any-node tier, long
//!   task shapes are steered onto the fastest node with room.
//!
//! A cell is *cold* until it has [`WARM_SAMPLES`] observations;
//! [`RuntimeEstimator::predicted_p95`] returns `None` for cold cells and
//! callers fall back to the static threshold, so the adaptive mode
//! degrades to the byte-parity oracle instead of guessing.

use crate::cluster::NodeId;
use std::collections::BTreeMap;

/// z-score of the 95th percentile of a normal distribution: the model is
/// "mean + 1.645σ", deliberately crude — it only has to rank attempts,
/// not price them.
pub const Z_P95: f64 = 1.645;

/// Observations before a cell's prediction is trusted. Below this the
/// estimator reports cold and callers use the static threshold.
pub const WARM_SAMPLES: u64 = 3;

/// Default EWMA smoothing factor: ~the last dozen attempts dominate, so
/// the model tracks interference shifts without thrashing on one outlier.
pub const DEFAULT_ALPHA: f64 = 0.25;

/// The two task shapes the MapReduce engine schedules. Map and reduce
/// attempts have wildly different duration distributions (CPU-bound
/// record crunch vs fetch-merge-spill), so they never share a cell.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum TaskShape {
    Map,
    Reduce,
}

impl TaskShape {
    pub fn name(self) -> &'static str {
        match self {
            TaskShape::Map => "map",
            TaskShape::Reduce => "reduce",
        }
    }
}

/// One `(node, shape)` cell: exponentially-weighted mean and variance of
/// observed attempt durations, plus the sample count for warm-up gating.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CellStats {
    /// EWMA of attempt duration, seconds.
    pub mean_s: f64,
    /// EWMA variance, seconds².
    pub var_s2: f64,
    /// Observations folded into this cell.
    pub samples: u64,
}

impl CellStats {
    /// `mean + z·σ` — the duration this cell predicts 95% of healthy
    /// attempts finish within.
    pub fn p95_s(&self) -> f64 {
        self.mean_s + Z_P95 * self.var_s2.max(0.0).sqrt()
    }
}

/// The online estimator: a map of `(node, shape)` → [`CellStats`] updated
/// from every committed attempt. Owned by the MR engine; one instance per
/// job keeps cells honest across elastic grow/shrink (a replacement node
/// re-warms from scratch rather than inheriting its predecessor's speed).
#[derive(Debug)]
pub struct RuntimeEstimator {
    alpha: f64,
    cells: BTreeMap<(NodeId, TaskShape), CellStats>,
    updates: u64,
}

impl Default for RuntimeEstimator {
    fn default() -> Self {
        RuntimeEstimator::new()
    }
}

impl RuntimeEstimator {
    pub fn new() -> Self {
        RuntimeEstimator::with_alpha(DEFAULT_ALPHA)
    }

    /// `alpha` is clamped to (0, 1]; 1.0 degenerates to "last sample
    /// wins", tiny values to "first samples win".
    pub fn with_alpha(alpha: f64) -> Self {
        RuntimeEstimator {
            alpha: alpha.clamp(1e-6, 1.0),
            cells: BTreeMap::new(),
            updates: 0,
        }
    }

    /// Fold one observed attempt duration into its cell.
    ///
    /// Standard EWMA mean/variance recurrence (West 1979 incremental
    /// form): `d = x − mean; mean += α·d; var = (1−α)(var + α·d²)`. The
    /// first sample seeds the mean exactly with zero variance.
    pub fn observe(&mut self, node: NodeId, shape: TaskShape, secs: f64) {
        if !secs.is_finite() || secs < 0.0 {
            return;
        }
        self.updates += 1;
        let cell = self
            .cells
            .entry((node, shape))
            .or_insert(CellStats { mean_s: secs, var_s2: 0.0, samples: 0 });
        if cell.samples > 0 {
            let d = secs - cell.mean_s;
            cell.mean_s += self.alpha * d;
            cell.var_s2 = (1.0 - self.alpha) * (cell.var_s2 + self.alpha * d * d);
        }
        cell.samples += 1;
    }

    /// The cell's stats, warm or cold.
    pub fn stats(&self, node: NodeId, shape: TaskShape) -> Option<&CellStats> {
        self.cells.get(&(node, shape))
    }

    /// Whether the cell has enough samples to be trusted.
    pub fn is_warm(&self, node: NodeId, shape: TaskShape) -> bool {
        self.stats(node, shape)
            .is_some_and(|c| c.samples >= WARM_SAMPLES)
    }

    /// Predicted p95 duration for the cell, or `None` while cold (the
    /// caller then falls back to the static straggler threshold).
    pub fn predicted_p95(&self, node: NodeId, shape: TaskShape) -> Option<f64> {
        self.stats(node, shape)
            .filter(|c| c.samples >= WARM_SAMPLES)
            .map(|c| c.p95_s())
    }

    /// Mean predicted duration of the shape across all warm cells — the
    /// engine's "is this shape long?" signal for placement bias. `None`
    /// until at least one cell is warm.
    pub fn shape_mean_s(&self, shape: TaskShape) -> Option<f64> {
        let mut sum = 0.0;
        let mut n = 0u64;
        for ((_, s), c) in &self.cells {
            if *s == shape && c.samples >= WARM_SAMPLES {
                sum += c.mean_s;
                n += 1;
            }
        }
        if n == 0 {
            None
        } else {
            Some(sum / n as f64)
        }
    }

    /// Total observations folded in (drives the `ESTIMATOR_UPDATES`
    /// counter).
    pub fn updates(&self) -> u64 {
        self.updates
    }

    /// Number of live cells (introspection/tests).
    pub fn cells(&self) -> usize {
        self.cells.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn node(i: u32) -> NodeId {
        NodeId(i)
    }

    #[test]
    fn first_sample_seeds_mean_with_zero_variance() {
        let mut e = RuntimeEstimator::new();
        e.observe(node(1), TaskShape::Map, 2.0);
        let c = e.stats(node(1), TaskShape::Map).unwrap();
        assert_eq!(c.mean_s, 2.0);
        assert_eq!(c.var_s2, 0.0);
        assert_eq!(c.samples, 1);
        assert_eq!(e.updates(), 1);
    }

    #[test]
    fn ewma_converges_to_a_shifted_true_mean() {
        // Feed 1.0s for a while, then shift the true mean to 4.0s: the
        // EWMA must track the shift to within 5% in a few dozen samples.
        let mut e = RuntimeEstimator::new();
        for _ in 0..50 {
            e.observe(node(3), TaskShape::Map, 1.0);
        }
        assert!((e.stats(node(3), TaskShape::Map).unwrap().mean_s - 1.0).abs() < 1e-9);
        for _ in 0..50 {
            e.observe(node(3), TaskShape::Map, 4.0);
        }
        let c = e.stats(node(3), TaskShape::Map).unwrap();
        assert!(
            (c.mean_s - 4.0).abs() < 0.2,
            "mean {} did not converge to shifted true mean 4.0",
            c.mean_s
        );
        // Constant tail ⇒ variance decays back toward zero.
        assert!(c.var_s2 < 0.5, "variance {} did not decay", c.var_s2);
    }

    #[test]
    fn p95_is_monotone_in_variance() {
        // Same mean, different spread: the noisier cell must predict a
        // strictly larger p95.
        let mut quiet = RuntimeEstimator::new();
        let mut noisy = RuntimeEstimator::new();
        for i in 0..40 {
            quiet.observe(node(1), TaskShape::Reduce, 2.0);
            let x = if i % 2 == 0 { 1.0 } else { 3.0 }; // mean 2.0, high var
            noisy.observe(node(1), TaskShape::Reduce, x);
        }
        let q = quiet.predicted_p95(node(1), TaskShape::Reduce).unwrap();
        let n = noisy.predicted_p95(node(1), TaskShape::Reduce).unwrap();
        assert!(
            n > q,
            "p95 must grow with variance (noisy {n} vs quiet {q})"
        );
        // And p95 ≥ mean always.
        let c = noisy.stats(node(1), TaskShape::Reduce).unwrap();
        assert!(n >= c.mean_s);
    }

    #[test]
    fn cold_cell_predicts_none_until_warm() {
        let mut e = RuntimeEstimator::new();
        assert_eq!(e.predicted_p95(node(7), TaskShape::Map), None);
        for k in 0..WARM_SAMPLES {
            assert!(!e.is_warm(node(7), TaskShape::Map), "warm after {k} samples");
            assert_eq!(e.predicted_p95(node(7), TaskShape::Map), None);
            e.observe(node(7), TaskShape::Map, 1.5);
        }
        assert!(e.is_warm(node(7), TaskShape::Map));
        assert!(e.predicted_p95(node(7), TaskShape::Map).is_some());
    }

    #[test]
    fn cells_are_independent_per_node_and_shape() {
        let mut e = RuntimeEstimator::new();
        for _ in 0..5 {
            e.observe(node(1), TaskShape::Map, 1.0);
            e.observe(node(2), TaskShape::Map, 8.0);
            e.observe(node(1), TaskShape::Reduce, 3.0);
        }
        assert_eq!(e.cells(), 3);
        let fast = e.stats(node(1), TaskShape::Map).unwrap().mean_s;
        let slow = e.stats(node(2), TaskShape::Map).unwrap().mean_s;
        assert!(fast < 2.0 && slow > 6.0);
        // Map cell on node 1 is untouched by reduce observations.
        assert!((e.stats(node(1), TaskShape::Reduce).unwrap().mean_s - 3.0).abs() < 1e-9);
    }

    #[test]
    fn shape_mean_averages_only_warm_cells() {
        let mut e = RuntimeEstimator::new();
        assert_eq!(e.shape_mean_s(TaskShape::Map), None);
        for _ in 0..WARM_SAMPLES {
            e.observe(node(1), TaskShape::Map, 2.0);
        }
        e.observe(node(2), TaskShape::Map, 100.0); // cold, must not count
        let m = e.shape_mean_s(TaskShape::Map).unwrap();
        assert!((m - 2.0).abs() < 1e-9, "cold cell leaked into shape mean: {m}");
    }

    #[test]
    fn negative_and_nonfinite_samples_are_ignored() {
        let mut e = RuntimeEstimator::new();
        e.observe(node(1), TaskShape::Map, -1.0);
        e.observe(node(1), TaskShape::Map, f64::NAN);
        e.observe(node(1), TaskShape::Map, f64::INFINITY);
        assert_eq!(e.updates(), 0);
        assert!(e.stats(node(1), TaskShape::Map).is_none());
    }
}
