//! Node allocation: whole-node granularity with exclusive placement.
//!
//! HPC Wales Big Data jobs run with `-x` on a dedicated queue, so the
//! allocator works in whole nodes. Shared (non-exclusive) jobs still
//! occupy whole nodes here but are flagged, which is all the ABL-SCHED
//! ablation needs; core-level packing is out of scope for the paper's
//! experiments (every measured job was exclusive).

use crate::cluster::{ClusterModel, NodeId, NodeState};
use crate::scheduler::job::ResourceRequest;
use std::collections::BTreeSet;

/// Tracks which nodes are free / busy / removed.
#[derive(Debug, Clone)]
pub struct Allocator {
    free: BTreeSet<NodeId>,
    busy: BTreeSet<NodeId>,
    /// Nodes failed/drained out of the pool.
    removed: BTreeSet<NodeId>,
    total: usize,
}

impl Allocator {
    pub fn new(cluster: &ClusterModel) -> Self {
        let free: BTreeSet<NodeId> = cluster
            .nodes()
            .filter(|n| n.state == NodeState::Up)
            .map(|n| n.id)
            .collect();
        let total = free.len();
        Allocator {
            free,
            busy: BTreeSet::new(),
            removed: BTreeSet::new(),
            total,
        }
    }

    pub fn total_nodes(&self) -> usize {
        self.total
    }

    pub fn free_count(&self) -> usize {
        self.free.len()
    }

    pub fn busy_count(&self) -> usize {
        self.busy.len()
    }

    /// Try to allocate `req.nodes` whole nodes (lowest ids first, which
    /// mirrors LSF's host-ordering determinism and makes tests stable).
    /// Returns `None` if not enough free nodes.
    pub fn try_allocate(&mut self, req: &ResourceRequest) -> Option<Vec<NodeId>> {
        if (req.nodes as usize) > self.free.len() {
            return None;
        }
        let picked: Vec<NodeId> = self.free.iter().copied().take(req.nodes as usize).collect();
        for &n in &picked {
            self.free.remove(&n);
            self.busy.insert(n);
        }
        Some(picked)
    }

    /// Return nodes to the pool (job completion). Nodes that failed while
    /// the job ran do not re-enter the free set.
    pub fn release(&mut self, nodes: &[NodeId]) {
        for &n in nodes {
            if self.busy.remove(&n) && !self.removed.contains(&n) {
                self.free.insert(n);
            }
        }
    }

    /// Remove a node from the schedulable pool (failure / drain).
    /// Idempotent: removing an already-removed node is a no-op.
    pub fn remove_node(&mut self, node: NodeId) {
        if !self.removed.insert(node) {
            return;
        }
        if self.free.remove(&node) {
            self.total -= 1;
        } else if self.busy.contains(&node) {
            // Stays "busy" until the owning job releases it; total shrinks
            // now so free+busy accounting stays consistent.
            self.total -= 1;
        }
    }

    /// Re-admit a repaired node. A node restored while a job still holds
    /// it counts toward the total immediately and re-enters the free pool
    /// when that job releases it.
    pub fn restore_node(&mut self, node: NodeId) {
        if self.removed.remove(&node) {
            self.total += 1;
            if !self.busy.contains(&node) {
                self.free.insert(node);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterConfig;
    use crate::testkit::props;

    fn alloc() -> Allocator {
        Allocator::new(&ClusterModel::new(&ClusterConfig::tiny()))
    }

    fn req(n: u32) -> ResourceRequest {
        ResourceRequest::bigdata(n, "u")
    }

    #[test]
    fn allocate_and_release_round_trip() {
        let mut a = alloc();
        let nodes = a.try_allocate(&req(5)).unwrap();
        assert_eq!(nodes.len(), 5);
        assert_eq!(a.free_count(), 3);
        assert_eq!(a.busy_count(), 5);
        a.release(&nodes);
        assert_eq!(a.free_count(), 8);
        assert_eq!(a.busy_count(), 0);
    }

    #[test]
    fn insufficient_nodes_returns_none() {
        let mut a = alloc();
        let _held = a.try_allocate(&req(6)).unwrap();
        assert!(a.try_allocate(&req(3)).is_none());
        assert_eq!(a.free_count(), 2);
    }

    #[test]
    fn failed_node_does_not_return_to_pool() {
        let mut a = alloc();
        let nodes = a.try_allocate(&req(4)).unwrap();
        a.remove_node(nodes[0]);
        a.release(&nodes);
        assert_eq!(a.free_count(), 7);
        assert_eq!(a.total_nodes(), 7);
        a.restore_node(nodes[0]);
        assert_eq!(a.free_count(), 8);
        assert_eq!(a.total_nodes(), 8);
    }

    #[test]
    fn restore_while_busy_keeps_totals_consistent() {
        // Drain a node a running job holds, restore it while still held,
        // then release: the node must return to the pool and the total
        // must be back to the full cluster size (regression: the restore
        // used to skip the total increment when the node was busy).
        let mut a = alloc();
        let nodes = a.try_allocate(&req(3)).unwrap();
        a.remove_node(nodes[0]);
        assert_eq!(a.total_nodes(), 7);
        a.restore_node(nodes[0]);
        assert_eq!(a.total_nodes(), 8);
        a.release(&nodes);
        assert_eq!(a.free_count(), 8);
        assert_eq!(a.busy_count(), 0);
    }

    #[test]
    fn conservation_property() {
        // free + busy + (removed while free) == initial, through arbitrary
        // allocate/release/fail sequences.
        props(50, |g| {
            let mut a = alloc();
            let mut held: Vec<Vec<NodeId>> = Vec::new();
            for _ in 0..g.usize(1..40) {
                match g.u32(0..3) {
                    0 => {
                        let want = g.u32(1..5);
                        if let Some(nodes) = a.try_allocate(&req(want)) {
                            held.push(nodes);
                        }
                    }
                    1 => {
                        if !held.is_empty() {
                            let i = g.usize(0..held.len());
                            let nodes = held.swap_remove(i);
                            a.release(&nodes);
                        }
                    }
                    _ => {
                        let n = NodeId(g.u32(0..8));
                        a.remove_node(n);
                    }
                }
                let held_count: usize = held.iter().map(|h| h.len()).sum();
                assert_eq!(a.busy_count(), held_count, "busy == held");
                assert!(a.free_count() + a.busy_count() <= 8);
            }
        });
    }
}
